//! End-to-end driver (DESIGN.md E2): train a real model for a few hundred
//! steps on synthetic CIFAR-10 through the full three-layer stack —
//! rust coordinator → PJRT → AOT HLO (jax model + Pallas decode kernel) —
//! and log the loss curve. The run recorded in EXPERIMENTS.md §E2 came
//! from this binary.
//!
//! ```bash
//! cargo run --release --example e2e_train -- [model] [pipeline] [epochs]
//! ```

use optorch::coordinator::report;
use optorch::prelude::*;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet_mini18");
    let pipeline = Pipeline::parse(args.get(1).map(String::as_str).unwrap_or("ed+sc"))
        .map_err(|e| anyhow::anyhow!(e))?;
    let epochs: usize = args.get(2).map(|s| s.parse()).transpose()?.unwrap_or(4);

    let mut cfg = TrainConfig::default_for(model, pipeline);
    cfg.epochs = epochs;
    cfg.train_size = 2_000; // 125 steps/epoch at batch 16
    cfg.test_size = 512;
    cfg.augment = "hflip,crop4".into();

    println!(
        "e2e: {model} [{}] — {} epochs × {} steps, batch {}",
        pipeline.label(),
        cfg.epochs,
        cfg.train_size / cfg.batch_size,
        cfg.batch_size
    );
    let mut trainer = Trainer::from_config(&cfg)?;
    let rep = trainer.run()?;
    println!("{}", report::markdown_summary(&rep));

    let csv = std::path::PathBuf::from(format!(
        "reports/e2e_{model}_{}.csv",
        rep.pipeline
    ));
    report::write_history_csv(&csv, &rep)?;
    println!("history → {}", csv.display());
    Ok(())
}
