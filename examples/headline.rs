//! E7: the paper's headline claims, summarized from live runs —
//! "~50% memory at equal accuracy", "E-D saves ≥20% time",
//! "encoding saves up to 16× input payload".
//!
//! ```bash
//! make artifacts && cargo run --release --example headline
//! ```

use optorch::config::Pipeline;
use optorch::data::encode::{encode_batch, EncodeSpec, Encoding, WordType};
use optorch::data::image::ImageBatch;
use optorch::memory::planner::{plan_checkpoints, PlannerKind};
use optorch::memory::simulator::simulate;
use optorch::models::arch_by_name;
use optorch::prelude::*;
use optorch::util::bench::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // ---- claim 1: ~50% memory reduction (simulator, ResNet-50 @ 512²) ----
    let arch = arch_by_name("resnet50", (512, 512, 3), 1000).unwrap();
    let base = simulate(&arch, Pipeline::BASELINE, 16, &[]);
    let plan = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, 16);
    let sc = simulate(&arch, Pipeline::parse("sc").unwrap(), 16, &plan.checkpoints);
    println!("claim 1 — memory: resnet50 baseline {} → S-C {} ({:.0}% reduction; paper: >50%)",
        fmt_bytes(base.peak_bytes),
        fmt_bytes(sc.peak_bytes),
        100.0 * (1.0 - sc.peak_bytes as f64 / base.peak_bytes as f64));

    // ---- claim 2: equal accuracy (real training, both pipelines) ----
    let mut acc = Vec::new();
    for pipe in ["b", "ed+sc"] {
        let mut cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse(pipe).unwrap());
        cfg.epochs = 2;
        cfg.train_size = 800;
        cfg.test_size = 256;
        let rep = Trainer::from_config(&cfg)?.run()?;
        println!(
            "claim 2 — accuracy: tiny_cnn [{}] eval acc {:.3} in {:.1}s",
            rep.pipeline, rep.final_eval_accuracy, rep.total_wall_secs
        );
        acc.push(rep.final_eval_accuracy);
    }
    println!(
        "          Δaccuracy = {:.3} (paper: 'same accuracy')",
        (acc[0] - acc[1]).abs()
    );

    // ---- claim 3: encode payload ratios (honest version, DESIGN.md §4) ----
    let batch = ImageBatch::zeros(8, 512, 512, 3, 10);
    let enc = encode_batch(&batch, EncodeSpec::new(Encoding::Base256, WordType::U64))?;
    println!(
        "claim 3 — encoding: u64 base-256 packs 8 imgs/word: {:.1}× vs f32 batch, {:.1}× vs the paper's f64 baseline",
        enc.ratio_vs_f32(),
        enc.ratio_vs_f64()
    );
    println!("          (the paper's '16 images in one f64' is impossible: 128 bits > 53-bit mantissa)");
    Ok(())
}
