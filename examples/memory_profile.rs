//! Figure-8-style memory profile: simulate one training iteration of
//! ResNet-18 (batch 16 @ 512×512×3, the paper's workload) under each
//! pipeline and print the live-byte timeline + peaks.
//!
//! ```bash
//! cargo run --release --example memory_profile [-- model [height]]
//! ```

use optorch::config::Pipeline;
use optorch::memory::planner::{plan_checkpoints, PlannerKind};
use optorch::memory::simulator::simulate;
use optorch::models::arch_by_name;
use optorch::util::bench::{fmt_bytes, Table};

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let model = args.first().map(String::as_str).unwrap_or("resnet18");
    let h: usize = args.get(1).map(|s| s.parse()).transpose()?.unwrap_or(512);
    let batch = 16;
    let arch = arch_by_name(model, (h, h, 3), 1000)
        .ok_or_else(|| anyhow::anyhow!("unknown model {model}"))?;

    let mut table = Table::new(&["pipeline", "peak", "state", "input", "activations"]);
    for pipe in Pipeline::fig10_set() {
        let ckpts = if pipe.sc {
            plan_checkpoints(&arch, PlannerKind::Optimal, pipe, batch).checkpoints
        } else {
            vec![]
        };
        let rep = simulate(&arch, pipe, batch, &ckpts);
        table.row(&[
            pipe.label(),
            fmt_bytes(rep.peak_bytes),
            fmt_bytes(rep.state_bytes),
            fmt_bytes(rep.input_bytes),
            fmt_bytes(rep.peak_activation_bytes),
        ]);
    }
    println!("{model} @ {h}x{h}, batch {batch} — one training iteration\n");
    table.print();

    // Fig 8 proper: the live-byte timeline for baseline vs S-C.
    println!("\ntimeline (live MiB at each event), baseline vs S-C:");
    let base = simulate(&arch, Pipeline::BASELINE, batch, &[]);
    let sc_pipe = Pipeline::parse("sc").unwrap();
    let plan = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, batch);
    let sc = simulate(&arch, sc_pipe, batch, &plan.checkpoints);
    let mib = |b: u64| b as f64 / (1024.0 * 1024.0);
    println!("  baseline: {} events, peak {:.0} MiB", base.timeline.len(), mib(base.peak_bytes));
    for e in base.timeline.iter().step_by(base.timeline.len() / 12 + 1) {
        println!("    {:<24} {:>8.0} MiB", e.label, mib(e.live_bytes));
    }
    println!("  S-C ({:?}): {} events, peak {:.0} MiB", plan.checkpoints, sc.timeline.len(), mib(sc.peak_bytes));
    for e in sc.timeline.iter().step_by(sc.timeline.len() / 12 + 1) {
        println!("    {:<24} {:>8.0} MiB", e.label, mib(e.live_bytes));
    }
    println!(
        "\npaper Fig 8 shape: baseline ≈ 7000 MB → S-C ≈ 2000 MB; here {:.0} → {:.0} MiB ({:.2}x)",
        mib(base.peak_bytes),
        mib(sc.peak_bytes),
        base.peak_bytes as f64 / sc.peak_bytes as f64
    );
    Ok(())
}
