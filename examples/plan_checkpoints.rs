//! Figure-11 demo: checkpoint placement on a 7-layer autoencoder-shaped
//! net — the paper's recommendation is to checkpoint the narrow middle
//! layer. Compares uniform, √n, bottleneck and optimal planners across
//! the model zoo.
//!
//! ```bash
//! cargo run --release --example plan_checkpoints
//! ```

use optorch::config::Pipeline;
use optorch::coordinator::report;
use optorch::memory::planner::{pareto_frontier, plan_checkpoints, PlannerKind};
use optorch::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};
use optorch::util::bench::{fmt_bytes, Table};

/// The paper's Figure-11 network: wide–narrow–wide dense stack.
fn autoencoder7() -> ArchProfile {
    let widths = [512usize, 256, 64, 16, 64, 256, 512];
    let layers = widths
        .iter()
        .enumerate()
        .map(|(i, &w)| LayerProfile {
            // treat width w as a 64x64 feature map with w channels so the
            // stored boundary tensor is the real layer output
            name: format!("dense{i}(w={w})"),
            kind: LayerKind::Dense,
            out_shape: (64, 64, w),
            act_elems: (3 * 64 * 64 * w) as u64,
            params: (w * 8) as u64,
            flops_per_image: (w * 128) as u64,
        })
        .collect();
    ArchProfile { name: "autoencoder7".into(), input: (1, 1, 512), layers }
}

fn main() {
    let batch = 16;
    println!("=== Fig 11: 7-layer autoencoder, 1 checkpoint ===\n");
    let arch = autoencoder7();
    let mut t = Table::new(&["planner", "checkpoint layer", "peak", "recompute"]);
    for kind in [PlannerKind::Uniform(1), PlannerKind::Bottleneck(1), PlannerKind::Optimal] {
        let plan = plan_checkpoints(&arch, kind, Pipeline::BASELINE, batch);
        let names: Vec<&str> = plan
            .checkpoints
            .iter()
            .map(|&i| arch.layers[i].name.as_str())
            .collect();
        t.row(&[
            format!("{kind:?}"),
            format!("{names:?}"),
            fmt_bytes(plan.peak_bytes),
            format!("{:.0}%", plan.recompute_overhead * 100.0),
        ]);
    }
    t.print();
    println!("\n→ the paper's recommendation: the bottleneck (w=16) layer is the");
    println!("  cheapest checkpoint — autoencoder/UNet shapes have optimal ones.\n");

    println!("=== planner comparison across the zoo (batch 16 @ 224²) ===\n");
    let mut t = Table::new(&["model", "uniform4", "sqrt", "bottleneck4", "optimal"]);
    for model in ["resnet18", "resnet50", "efficientnet_b0", "inception_v3"] {
        let input = if model == "inception_v3" { 299 } else { 224 };
        let arch = arch_by_name(model, (input, input, 3), 1000).unwrap();
        let peak = |k| fmt_bytes(plan_checkpoints(&arch, k, Pipeline::BASELINE, batch).peak_bytes);
        t.row(&[
            model.to_string(),
            peak(PlannerKind::Uniform(4)),
            peak(PlannerKind::Sqrt),
            peak(PlannerKind::Bottleneck(4)),
            peak(PlannerKind::Optimal),
        ]);
    }
    t.print();

    println!("\n=== resnet50 time/memory Pareto frontier (batch 16 @ 224²) ===\n");
    let arch = arch_by_name("resnet50", (224, 224, 3), 1000).unwrap();
    let frontier = pareto_frontier(&arch, Pipeline::BASELINE, batch, 16);
    report::frontier_table(&frontier).print();
    println!(
        "\n→ every row is a non-dominated (memory, recompute-time) trade; train under one\n\
         with `optorch train --pipeline ed+sc --memory_budget <peak>` and the trainer\n\
         auto-selects the cheapest-time plan that fits."
    );
}
