//! Quickstart: train a small CNN with the paper's most-optimized pipeline
//! (E-D + S-C) on synthetic CIFAR-10 and print the loss curve.
//!
//! ```bash
//! make artifacts && cargo run --release --example quickstart
//! ```

use optorch::prelude::*;

fn main() -> anyhow::Result<()> {
    // One line selects the optimization pipeline — the crate-level analogue
    // of the paper's `scmodel = sc(model)`.
    let mut cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse("ed+sc").unwrap());
    cfg.epochs = 3;
    cfg.train_size = 1_000;
    cfg.test_size = 256;

    let mut trainer = Trainer::from_config(&cfg)?;
    let report = trainer.run()?;

    println!("epoch  train_loss  train_acc  eval_acc");
    for e in &report.history.epochs {
        println!(
            "{:>5}  {:>10.4}  {:>9.3}  {:>8}",
            e.epoch,
            e.train_loss,
            e.train_accuracy,
            e.eval_accuracy
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "-".into())
        );
    }
    println!(
        "\nfinal eval accuracy {:.3} in {:.1}s (E-D producer ran {:.1}s in parallel)",
        report.final_eval_accuracy, report.total_wall_secs, report.loader_produce_secs
    );
    Ok(())
}
