//! Selective-batch-sampling demo (paper §II-A.1, Algorithm 2): weight the
//! batch composition per class and attach a *different* augmentation
//! policy to each class — MixUp for class 0, CutMix for class 1, AugMix
//! for class 2, standard flips elsewhere — then train with it.
//!
//! ```bash
//! cargo run --release --example sbs_augment
//! ```

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::sampler::{ClassSpec, SbsSampler};
use optorch::data::synth::{Split, SynthCifar};

fn main() -> anyhow::Result<()> {
    let dataset = SynthCifar::cifar10(Split::Train, 2_000, 7);

    // Class weights: oversample class 0 4×, drop class 9 entirely.
    let mut specs: Vec<ClassSpec> = (0..10)
        .map(|c| {
            let weight = match c {
                0 => 4.0,
                9 => 0.0,
                _ => 1.0,
            };
            let policy = match c {
                0 => AugPolicy::parse("hflip,mixup0.4").unwrap(),
                1 => AugPolicy::parse("hflip,cutmix1.0").unwrap(),
                2 => AugPolicy::parse("augmix3").unwrap(),
                _ => AugPolicy::standard(),
            };
            let spec = ClassSpec::new(weight, policy);
            // classes 0 and 1 mix across classes → genuinely soft labels
            if c <= 1 { spec.with_cross_class_partner() } else { spec }
        })
        .collect();
    specs[3].policy = AugPolicy::parse("cutout8").unwrap();

    let mut sampler = SbsSampler::new(&dataset, 32, specs, 42)?;
    println!("per-class slots in every batch: {:?}", sampler.class_counts());

    let batch = sampler.next_batch(&dataset);
    let mut per_class = vec![0usize; 10];
    let mut soft = 0;
    for i in 0..batch.n {
        per_class[batch.hard_label(i)] += 1;
        let row = batch.label(i);
        if row.iter().filter(|&&v| v > 0.01).count() > 1 {
            soft += 1;
        }
    }
    println!("realized batch composition:      {per_class:?}");
    println!("slots with soft (mixed) labels:  {soft}");
    assert_eq!(per_class[9], 0, "class 9 must never appear");
    assert!(per_class[0] >= 8, "class 0 must dominate");

    // Show that MixUp softened class-0 labels but not class-4 labels.
    for i in 0..batch.n {
        if batch.hard_label(i) == 0 {
            println!(
                "example class-0 label row: {:?}",
                batch
                    .label(i)
                    .iter()
                    .map(|v| (v * 100.0).round() / 100.0)
                    .collect::<Vec<_>>()
            );
            break;
        }
    }
    println!("\nSBS OK — per-class weights + per-class policies applied");
    let _ = dataset.len();
    Ok(())
}
