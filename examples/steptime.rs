//! Perf probe: steady-state train-step latency per pipeline (used by the
//! §Perf pass in EXPERIMENTS.md). Usage: `steptime [model]`.
use optorch::data::loader::BatchPayload;
use optorch::runtime::Runtime;
use std::time::Instant;
fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::new(std::path::Path::new("artifacts"))?;
    let mut rng = optorch::util::rng::Rng::new(1);
    let data: Vec<f32> = (0..16*32*32*3).map(|_| rng.f32()).collect();
    let mut labels = vec![0.0f32; 160];
    for i in 0..16 { labels[i*10 + rng.gen_range(10)] = 1.0; }
    let payload = BatchPayload::Raw { data, labels, n: 16 };
    let model_name = std::env::args().nth(1).unwrap_or("tiny_cnn".into());
    for pipe in ["baseline", "sc", "mp"] {
        let model = rt.load(&model_name, pipe)?;
        let mut state = model.init_state(1)?;
        for _ in 0..5 { model.train_step(&mut state, &payload)?; }
        let t0 = Instant::now();
        let n = 30;
        for _ in 0..n { model.train_step(&mut state, &payload)?; }
        println!("{model_name} {pipe}: {:.2} ms/step", t0.elapsed().as_secs_f64()*1000.0/n as f64);
    }
    Ok(())
}
