"""AOT emitter: lower every (model × pipeline) train/eval/init step to HLO
TEXT and write ``artifacts/manifest.json`` for the rust runtime.

HLO *text* (not ``.serialize()``) is the interchange format: jax ≥ 0.5
emits protos with 64-bit instruction ids that xla_extension 0.5.1 rejects;
the text parser reassigns ids (see /opt/xla-example/README.md).

Usage: ``cd python && python -m compile.aot --out ../artifacts``
"""

import argparse
import json
import os
import sys
import time

import jax

jax.config.update("jax_enable_x64", True)  # f64 packed words cross the boundary

import jax.numpy as jnp  # noqa: E402
import numpy as np  # noqa: E402
from jax._src.lib import xla_client as xc  # noqa: E402

from compile import model as M  # noqa: E402

LR = 0.05
MOMENTUM = 0.9
LOSS_SCALE = 1024.0

# Pipelines per model: the quick models get the full 8-combination grid;
# the deeper minis get the paper's headline subset to bound AOT time.
FULL_GRID = ["baseline", "ed", "mp", "sc", "ed_mp", "ed_sc", "mp_sc", "ed_mp_sc"]
HEADLINE = ["baseline", "mp", "sc", "ed_sc", "ed_mp_sc"]
EMIT = {
    "tiny_cnn": FULL_GRID,
    "resnet_mini18": FULL_GRID,
    "effnet_lite": FULL_GRID,
    "inception_lite": FULL_GRID,
    "resnet_mini34": HEADLINE,
    "resnet_mini50": HEADLINE,
}


def pipeline_flags(name):
    parts = [] if name == "baseline" else name.split("_")
    return {"ed": "ed" in parts, "mp": "mp" in parts, "sc": "sc" in parts}


def to_hlo_text(lowered):
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def state_specs(stages, mp):
    """Manifest tensor specs, in flatten_state order, with path names."""
    params = M.init_params(stages, jax.random.PRNGKey(0))
    names, shapes = [], []
    for (stage_name, _, _), p in zip(stages, params):
        leaves = jax.tree_util.tree_flatten_with_path(p)[0]
        for path, leaf in leaves:
            key = "/".join(str(getattr(k, "key", k)) for k in path)
            names.append(f"{stage_name}/{key}")
            shapes.append(tuple(leaf.shape))
    dtype = "f16" if mp else "f32"
    specs = [
        {"name": n, "shape": list(s), "dtype": dtype} for n, s in zip(names, shapes)
    ]
    # momentum mirrors the parameter list
    specs += [
        {"name": f"mom:{n}", "shape": list(s), "dtype": dtype}
        for n, s in zip(names, shapes)
    ]
    return specs


def batch_spec(flags, hw=(32, 32, 3), batch=M.BATCH):
    h, w, c = hw
    if flags["ed"]:
        groups = -(-batch // M.CAP)
        return (
            {"name": "batch", "shape": [groups, h, w, c], "dtype": "f64"},
            "encoded",
            groups,
        )
    return ({"name": "batch", "shape": [batch, h, w, c], "dtype": "f32"}, "raw", 0)


def emit_entry(out_dir, model_name, pipe_name, classes=M.NUM_CLASSES):
    stages = M.MODELS[model_name]()
    flags = pipeline_flags(pipe_name)
    stem = f"{model_name}_{pipe_name}"
    bspec, bkind, groups = batch_spec(flags)
    specs = state_specs(stages, flags["mp"])

    state_dt = jnp.float16 if flags["mp"] else jnp.float32
    state_args = [
        jax.ShapeDtypeStruct(tuple(s["shape"]), state_dt) for s in specs
    ]
    batch_dt = jnp.float64 if flags["ed"] else jnp.float32
    batch_arg = jax.ShapeDtypeStruct(tuple(bspec["shape"]), batch_dt)
    labels_arg = jax.ShapeDtypeStruct((M.BATCH, classes), jnp.float32)

    t0 = time.time()
    train = M.make_train_step(stages, mom=MOMENTUM, loss_scale=LOSS_SCALE, **flags)
    lr_arg = jax.ShapeDtypeStruct((), jnp.float32)  # runtime LR input
    lowered = jax.jit(train).lower(*state_args, batch_arg, labels_arg, lr_arg)
    with open(os.path.join(out_dir, f"{stem}.train.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    ev = M.make_eval_step(stages, **flags)
    # eval takes the parameter half only (momentum would be dead inputs)
    lowered = jax.jit(ev).lower(*state_args[: len(specs) // 2], batch_arg, labels_arg)
    with open(os.path.join(out_dir, f"{stem}.eval.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    init = M.make_init(stages, mp=flags["mp"])
    lowered = jax.jit(init).lower(jax.ShapeDtypeStruct((2,), jnp.uint32))
    with open(os.path.join(out_dir, f"{stem}.init.hlo.txt"), "w") as f:
        f.write(to_hlo_text(lowered))

    print(f"  {stem}: {len(specs)} state tensors [{time.time() - t0:.1f}s]", flush=True)
    return {
        "model": model_name,
        "pipeline": pipe_name,
        "input": [32, 32, 3],
        "num_classes": classes,
        "batch_size": M.BATCH,
        "groups": groups,
        "group_capacity": M.CAP if flags["ed"] else 0,
        "batch_kind": bkind,
        "batch": bspec,
        "labels": {"name": "labels", "shape": [M.BATCH, classes], "dtype": "f32"},
        "state": specs,
        "train_hlo": f"{stem}.train.hlo.txt",
        "eval_hlo": f"{stem}.eval.hlo.txt",
        "init_hlo": f"{stem}.init.hlo.txt",
        "lr": LR,
        "momentum": MOMENTUM,
        "loss_scale": LOSS_SCALE,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument(
        "--models", default=None, help="comma-separated subset (default: all)"
    )
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)
    only = set(args.models.split(",")) if args.models else None
    entries = []
    t0 = time.time()
    for model_name, pipes in EMIT.items():
        if only and model_name not in only:
            continue
        print(f"{model_name}:", flush=True)
        for pipe in pipes:
            entries.append(emit_entry(args.out, model_name, pipe))
    manifest = {"version": 1, "entries": entries}
    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(entries)} entries in {time.time() - t0:.0f}s → {args.out}/manifest.json")


if __name__ == "__main__":
    main()
