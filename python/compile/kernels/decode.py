"""L1 Pallas kernel: the paper's in-graph *decode layer* (Algorithm 3).

Unpacks base-256 f64 word tensors ``[G, H, W, C]`` into normalized f32
images ``[G*CAP, H, W, C]``. This is the first layer of every E-D model, so
it lowers into the same HLO module as the network (``interpret=True`` —
the CPU PJRT plugin cannot run Mosaic custom-calls).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid walks (group,
row-stripe); each program holds one ``[1, TILE_H, W, C]`` stripe of packed
words in VMEM and emits the ``[1, CAP, TILE_H, W, C]`` decoded stripe. For
CIFAR shapes a stripe is W·C·TILE_H·8 B ≈ 6 KiB of VMEM in and 5×~3 KiB
out — far under the ~16 MiB VMEM budget, so stripes can be widened
(TILE_H up) until the HBM↔VMEM pipeline saturates; the digit loop is pure
VPU element-wise work with no MXU involvement.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Exact f64 capacity for base-256 digits (53-bit mantissa).
CAP = 6


def _decode_kernel(words_ref, out_ref, *, cap):
    """One (group, stripe): peel `cap` base-256 digits from the f64 words."""
    x = words_ref[...].astype(jnp.float64)  # [1, th, w, c]
    for i in range(cap):
        digit = jnp.mod(x, 256.0)
        out_ref[0, i, :, :, :] = (digit[0] / 255.0).astype(jnp.float32)
        x = jnp.floor(x / 256.0)


def _pick_tile_h(h):
    """Largest power-of-two divisor of h, capped at 32 rows per stripe."""
    t = 1
    while t < 32 and h % (t * 2) == 0:
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=("cap",))
def decode_base256_groups(words, cap=CAP):
    """[G,H,W,C] f64 → [G*cap,H,W,C] f32 in [0,1]; see ref.decode_base256_groups."""
    g, h, w, c = words.shape
    tile_h = _pick_tile_h(h)
    out = pl.pallas_call(
        functools.partial(_decode_kernel, cap=cap),
        grid=(g, h // tile_h),
        in_specs=[
            pl.BlockSpec((1, tile_h, w, c), lambda gi, ti: (gi, ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, cap, tile_h, w, c), lambda gi, ti: (gi, 0, ti, 0, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((g, cap, h, w, c), jnp.float32),
        interpret=True,
    )(words)
    return out.reshape(g * cap, h, w, c)


def vmem_bytes_per_program(h, w, c, cap=CAP):
    """Static VMEM footprint estimate for one grid program (perf notes)."""
    tile_h = _pick_tile_h(h)
    words = tile_h * w * c * 8
    out = cap * tile_h * w * c * 4
    scratch = tile_h * w * c * 8  # the running f64 quotient
    return words + out + scratch
