"""L1 Pallas kernel: on-device batch *encode* (Algorithm 1).

Packs uint8 images (shipped as f32 counts 0..255) ``[N, H, W, C]`` into one
f64 word tensor ``[H, W, C]``. The production data path encodes on the host
(rust ``data::encode``); this kernel exists for the paper's "encode inside
the accelerator" variant and is validated against the same oracle.

Grid walks row-stripes; each program reads the ``[N, TILE_H, W, C]`` slab
and reduces over N with exact powers of 256 — element-wise VPU work.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CAP = 6


def _encode_kernel(imgs_ref, out_ref, *, n):
    acc = jnp.zeros(out_ref.shape, dtype=jnp.float64)
    for i in range(n):
        weight = jnp.float64(256.0) ** i
        acc = acc + imgs_ref[i, :, :, :].astype(jnp.float64) * weight
    out_ref[...] = acc


def _pick_tile_h(h):
    t = 1
    while t < 32 and h % (t * 2) == 0:
        t *= 2
    return t


@functools.partial(jax.jit, static_argnames=())
def encode_base256(imgs):
    """[N,H,W,C] (values 0..255) → packed f64 [H,W,C]; N ≤ 6."""
    n, h, w, c = imgs.shape
    if n > CAP:
        raise ValueError(f"base-256 f64 packing holds ≤{CAP} images, got {n}")
    tile_h = _pick_tile_h(h)
    return pl.pallas_call(
        functools.partial(_encode_kernel, n=n),
        grid=(h // tile_h,),
        in_specs=[pl.BlockSpec((n, tile_h, w, c), lambda ti: (0, ti, 0, 0))],
        out_specs=pl.BlockSpec((tile_h, w, c), lambda ti: (ti, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((h, w, c), jnp.float64),
        interpret=True,
    )(imgs)
