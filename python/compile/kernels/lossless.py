"""L1 Pallas kernels: loss-less forced encoding (Algorithm 4).

Base-128 digit packing plus the parity bitplane that makes it exact:
``pixel = 2 · digit + offset``. A f64 word holds 7 digits (53-bit
mantissa), not the paper's claimed 32 — see DESIGN.md §Corrections.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

CAP = 7


def _pick_tile_h(h):
    t = 1
    while t < 32 and h % (t * 2) == 0:
        t *= 2
    return t


def _encode_kernel(imgs_ref, words_ref, offs_ref, *, n):
    acc = jnp.zeros(words_ref.shape, dtype=jnp.float64)
    for i in range(n):
        px = imgs_ref[i, :, :, :].astype(jnp.float64)
        digit = jnp.floor(px / 2.0)
        offs_ref[i, :, :, :] = (px - digit * 2.0).astype(jnp.uint8)
        acc = acc + digit * (jnp.float64(128.0) ** i)
    words_ref[...] = acc


def encode_lossless128(imgs):
    """[N,H,W,C] (0..255) → (words f64 [H,W,C], offsets u8 [N,H,W,C])."""
    n, h, w, c = imgs.shape
    if n > CAP:
        raise ValueError(f"base-128 f64 packing holds ≤{CAP} images, got {n}")
    tile_h = _pick_tile_h(h)
    return pl.pallas_call(
        functools.partial(_encode_kernel, n=n),
        grid=(h // tile_h,),
        in_specs=[pl.BlockSpec((n, tile_h, w, c), lambda ti: (0, ti, 0, 0))],
        out_specs=[
            pl.BlockSpec((tile_h, w, c), lambda ti: (ti, 0, 0)),
            pl.BlockSpec((n, tile_h, w, c), lambda ti: (0, ti, 0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((h, w, c), jnp.float64),
            jax.ShapeDtypeStruct((n, h, w, c), jnp.uint8),
        ],
        interpret=True,
    )(imgs)


def _decode_kernel(words_ref, offs_ref, out_ref, *, n):
    x = words_ref[...].astype(jnp.float64)
    for i in range(n):
        digit = jnp.mod(x, 128.0)
        out_ref[i, :, :, :] = (
            digit * 2.0 + offs_ref[i, :, :, :].astype(jnp.float64)
        ).astype(jnp.uint8)
        x = jnp.floor(x / 128.0)


def decode_lossless128(words, offsets):
    """Exact inverse: (words, offsets) → uint8 [N,H,W,C]."""
    n, h, w, c = offsets.shape
    tile_h = _pick_tile_h(h)
    return pl.pallas_call(
        functools.partial(_decode_kernel, n=n),
        grid=(h // tile_h,),
        in_specs=[
            pl.BlockSpec((tile_h, w, c), lambda ti: (ti, 0, 0)),
            pl.BlockSpec((n, tile_h, w, c), lambda ti: (0, ti, 0, 0)),
        ],
        out_specs=pl.BlockSpec((n, tile_h, w, c), lambda ti: (0, ti, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h, w, c), jnp.uint8),
        interpret=True,
    )(words, offsets)
