"""L1 Pallas kernel: MXU-tiled matmul for the classifier head.

The M-P story on TPU is low-precision operands into the 128×128 MXU
systolic array with f32 accumulation; this kernel expresses exactly that:
inputs may be f32/bf16/f16, tiles are (≤128)×(≤128), and the K reduction
accumulates in f32 VMEM scratch across grid steps.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matmul_kernel(a_ref, b_ref, o_ref, *, k_steps):
    @pl.when(pl.program_id(2) == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jnp.dot(a, b, preferred_element_type=jnp.float32)


def _tile(dim, target):
    """Largest divisor of dim that is ≤ target (MXU-friendly when possible)."""
    t = min(dim, target)
    while dim % t != 0:
        t -= 1
    return t


def _matmul_raw(a, b):
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch: {k} vs {k2}"
    tm, tk, tn = _tile(m, 128), _tile(k, 128), _tile(n, 128)
    k_steps = k // tk
    return pl.pallas_call(
        functools.partial(_matmul_kernel, k_steps=k_steps),
        grid=(m // tm, n // tn, k_steps),
        in_specs=[
            pl.BlockSpec((tm, tk), lambda i, j, s: (i, s)),
            pl.BlockSpec((tk, tn), lambda i, j, s: (s, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j, s: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)


@jax.custom_vjp
def matmul(a, b):
    """[M,K] @ [K,N] → f32 [M,N], tiled for the MXU, f32 accumulate.

    Differentiable: the backward pass reuses the same kernel for
    ``dA = dO·Bᵀ`` and ``dB = Aᵀ·dO`` (three MXU launches total).
    """
    return _matmul_raw(a, b)


def _matmul_fwd(a, b):
    return _matmul_raw(a, b), (a, b)


def _matmul_bwd(res, g):
    a, b = res
    da = _matmul_raw(g, b.T.astype(g.dtype)).astype(a.dtype)
    db = _matmul_raw(a.T.astype(g.dtype), g).astype(b.dtype)
    return da, db


matmul.defvjp(_matmul_fwd, _matmul_bwd)


def mxu_utilization_estimate(m, k, n):
    """Fraction of MXU lanes a (m,k,n) problem fills with these tiles.

    The 128×128 systolic array is fully fed when tm=tk=tn=128; smaller
    tiles idle lanes proportionally. Static estimate for DESIGN.md §Perf.
    """
    tm, tk, tn = _tile(m, 128), _tile(k, 128), _tile(n, 128)
    return (tm / 128.0) * (tk / 128.0) * (tn / 128.0)
