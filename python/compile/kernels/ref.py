"""Pure-jnp oracles for every Pallas kernel (the correctness ground truth).

Each function here is the mathematically-obvious implementation the Pallas
kernels are checked against in ``python/tests/``. Nothing in this module is
performance-relevant; clarity wins.
"""

import jax.numpy as jnp

# Exact packing capacities (DESIGN.md §Corrections): a float64 mantissa has
# 53 bits, so base-256 packs ⌊53/8⌋ = 6 images and base-128 packs 7.
CAP_BASE256_F64 = 6
CAP_BASE128_F64 = 7


def encode_base256(batch):
    """Algorithm 1: pack uint8 images [N,H,W,C] into one f64 word tensor.

    word(p) = Σ_i batch[i, p] · 256^i, exact for N ≤ 6.
    """
    n = batch.shape[0]
    if n > CAP_BASE256_F64:
        raise ValueError(f"base-256 f64 packing holds ≤{CAP_BASE256_F64} images, got {n}")
    weights = (256.0 ** jnp.arange(n, dtype=jnp.float64)).reshape(n, 1, 1, 1)
    return jnp.sum(batch.astype(jnp.float64) * weights, axis=0)


def decode_base256(words, n):
    """Algorithm 3: unpack the first `n` images, normalized to [0,1] f32.

    Returns [n, H, W, C] float32 = digit / 255.
    """
    if n > CAP_BASE256_F64:
        raise ValueError(f"base-256 f64 packing holds ≤{CAP_BASE256_F64} images, got {n}")
    x = words.astype(jnp.float64)
    imgs = []
    for _ in range(n):
        digit = jnp.mod(x, 256.0)
        imgs.append(digit)
        x = jnp.floor(x / 256.0)
    return (jnp.stack(imgs, axis=0) / 255.0).astype(jnp.float32)


def decode_base256_groups(words, cap):
    """Grouped decode: [G,H,W,C] f64 → [G*cap,H,W,C] f32 in [0,1].

    This is the shape the training artifacts consume (the loader packs a
    batch of B images into G = ceil(B / cap) groups; junk tail slots decode
    to zeros and are sliced off by the model).
    """
    x = words.astype(jnp.float64)
    imgs = []
    for _ in range(cap):
        digit = jnp.mod(x, 256.0)
        imgs.append(digit)
        x = jnp.floor(x / 256.0)
    # [G, cap, H, W, C] -> [G*cap, ...]
    stacked = jnp.stack(imgs, axis=1)
    g, h, w, c = words.shape
    return (stacked.reshape(g * cap, h, w, c) / 255.0).astype(jnp.float32)


def encode_lossless128(batch):
    """Algorithm 4: base-128 digits + parity bitplane.

    Returns (words f64 [H,W,C], offsets uint8 [N,H,W,C] of 0/1).
    """
    n = batch.shape[0]
    if n > CAP_BASE128_F64:
        raise ValueError(f"base-128 f64 packing holds ≤{CAP_BASE128_F64} images, got {n}")
    b = batch.astype(jnp.int64)
    digits = b // 2
    offsets = (b % 2).astype(jnp.uint8)
    weights = (128.0 ** jnp.arange(n, dtype=jnp.float64)).reshape(n, 1, 1, 1)
    words = jnp.sum(digits.astype(jnp.float64) * weights, axis=0)
    return words, offsets


def decode_lossless128(words, offsets):
    """Inverse of Algorithm 4: exact uint8 reconstruction."""
    n = offsets.shape[0]
    x = words.astype(jnp.float64)
    out = []
    for i in range(n):
        digit = jnp.mod(x, 128.0)
        out.append((digit * 2 + offsets[i].astype(jnp.float64)).astype(jnp.uint8))
        x = jnp.floor(x / 128.0)
    return jnp.stack(out, axis=0)


def matmul(a, b):
    """Reference for the tiled-matmul kernel: plain f32 matmul."""
    return jnp.matmul(a.astype(jnp.float32), b.astype(jnp.float32))
