"""Functional NN layers for the L2 models (pure jax, no flax).

Every layer is an ``(init, apply)`` pair over explicit parameter dicts, so
the AOT manifest can name and order every tensor deterministically.

BatchNorm note (DESIGN.md §5): we use *batch statistics* in both train and
eval (no running averages), which keeps state = parameters ⊎ momentum and
the artifacts purely functional. At CIFAR scale this costs <1% accuracy and
is a documented deviation.
"""

import jax
import jax.numpy as jnp


def conv_init(key, k, in_c, out_c, bias=False):
    """He-normal conv kernel [k,k,in_c,out_c] (+ optional bias)."""
    fan_in = k * k * in_c
    w = jax.random.normal(key, (k, k, in_c, out_c), jnp.float32) * jnp.sqrt(2.0 / fan_in)
    p = {"w": w}
    if bias:
        p["b"] = jnp.zeros((out_c,), jnp.float32)
    return p


def conv_apply(p, x, stride=1):
    """NHWC conv, SAME padding."""
    y = jax.lax.conv_general_dilated(
        x,
        p["w"],
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if "b" in p:
        y = y + p["b"]
    return y


def bn_init(c):
    return {"scale": jnp.ones((c,), jnp.float32), "shift": jnp.zeros((c,), jnp.float32)}


def bn_apply(p, x, eps=1e-5):
    """Batch-statistics normalization over (N,H,W)."""
    mean = jnp.mean(x, axis=(0, 1, 2), keepdims=True)
    var = jnp.var(x, axis=(0, 1, 2), keepdims=True)
    y = (x - mean) * jax.lax.rsqrt(var + eps)
    return y * p["scale"] + p["shift"]


def dense_init(key, in_d, out_d):
    w = jax.random.normal(key, (in_d, out_d), jnp.float32) * jnp.sqrt(1.0 / in_d)
    return {"w": w, "b": jnp.zeros((out_d,), jnp.float32)}


def dense_apply(p, x, use_kernel=False):
    """Dense layer; `use_kernel=True` routes through the Pallas MXU matmul."""
    if use_kernel:
        from compile.kernels import matmul as mm

        return mm.matmul(x, p["w"]) + p["b"]
    return jnp.matmul(x, p["w"]) + p["b"]


def global_avg_pool(x):
    return jnp.mean(x, axis=(1, 2))


def max_pool(x, window=2, stride=2):
    return jax.lax.reduce_window(
        x,
        -jnp.inf,
        jax.lax.max,
        (1, window, window, 1),
        (1, stride, stride, 1),
        "SAME",
    )


def softmax_cross_entropy(logits, soft_labels):
    """Mean CE against soft labels (MixUp/CutMix flow through here)."""
    logp = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.sum(soft_labels * logp, axis=-1))


def correct_count(logits, soft_labels):
    """#(argmax(logits) == argmax(labels)), as f32 for uniform outputs."""
    pred = jnp.argmax(logits, axis=-1)
    truth = jnp.argmax(soft_labels, axis=-1)
    return jnp.sum((pred == truth).astype(jnp.float32))
