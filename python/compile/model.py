"""L2: the trainable model zoo + pipeline-composable train/eval/init steps.

Models mirror the rust analytic profiles (``rust/src/models/``): tiny_cnn,
resnet_mini18/34/50, effnet_lite, inception_lite — all at CIFAR scale so
they train end-to-end on CPU.

A model is a list of *stages*; sequential checkpointing (S-C) is
``jax.checkpoint`` around each stage, exactly the paper's "segments".
Pipelines compose:

* **E-D**  — the batch arrives as packed f64 words [G,H,W,C]; stage 0 is
  the L1 Pallas decode kernel; junk tail slots are sliced off.
* **M-P**  — state stored f16; upcast to f32 at step entry, grads scaled
  by a static loss scale, update in f32, store back f16 (paper Fig. 3).
* **S-C**  — every stage rematerialized in the backward pass.
"""

import functools

import jax
import jax.numpy as jnp

from compile import layers as L

BATCH = 16
CAP = 6  # base-256 f64 packing capacity
NUM_CLASSES = 10

# --------------------------------------------------------------------------
# model zoo: each builder returns a list of stages; a stage is
# (name, init(key)->params, apply(params, x)->x)
# --------------------------------------------------------------------------


def _conv_bn_stage(name, k, in_c, out_c, stride):
    def init(key):
        return {"conv": L.conv_init(key, k, in_c, out_c), "bn": L.bn_init(out_c)}

    def apply(p, x):
        return jax.nn.relu(L.bn_apply(p["bn"], L.conv_apply(p["conv"], x, stride)))

    return (name, init, apply)


def _basic_block(prefix, in_c, out_c, stride):
    """ResNet basic block as one stage."""

    def init(key):
        k1, k2, k3 = jax.random.split(key, 3)
        p = {
            "conv1": L.conv_init(k1, 3, in_c, out_c),
            "bn1": L.bn_init(out_c),
            "conv2": L.conv_init(k2, 3, out_c, out_c),
            "bn2": L.bn_init(out_c),
        }
        if stride != 1 or in_c != out_c:
            p["proj"] = L.conv_init(k3, 1, in_c, out_c)
            p["bnp"] = L.bn_init(out_c)
        return p

    def apply(p, x):
        y = jax.nn.relu(L.bn_apply(p["bn1"], L.conv_apply(p["conv1"], x, stride)))
        y = L.bn_apply(p["bn2"], L.conv_apply(p["conv2"], y, 1))
        sc = x
        if "proj" in p:
            sc = L.bn_apply(p["bnp"], L.conv_apply(p["proj"], x, stride))
        return jax.nn.relu(y + sc)

    return (prefix, init, apply)


def _bottleneck_block(prefix, in_c, mid_c, stride):
    out_c = mid_c * 4

    def init(key):
        k1, k2, k3, k4 = jax.random.split(key, 4)
        p = {
            "conv1": L.conv_init(k1, 1, in_c, mid_c),
            "bn1": L.bn_init(mid_c),
            "conv2": L.conv_init(k2, 3, mid_c, mid_c),
            "bn2": L.bn_init(mid_c),
            "conv3": L.conv_init(k3, 1, mid_c, out_c),
            "bn3": L.bn_init(out_c),
        }
        if stride != 1 or in_c != out_c:
            p["proj"] = L.conv_init(k4, 1, in_c, out_c)
            p["bnp"] = L.bn_init(out_c)
        return p

    def apply(p, x):
        y = jax.nn.relu(L.bn_apply(p["bn1"], L.conv_apply(p["conv1"], x, 1)))
        y = jax.nn.relu(L.bn_apply(p["bn2"], L.conv_apply(p["conv2"], y, stride)))
        y = L.bn_apply(p["bn3"], L.conv_apply(p["conv3"], y, 1))
        sc = x
        if "proj" in p:
            sc = L.bn_apply(p["bnp"], L.conv_apply(p["proj"], x, stride))
        return jax.nn.relu(y + sc)

    return (prefix, init, apply)


def _head_stage(in_c, classes):
    def init(key):
        return {"fc": L.dense_init(key, in_c, classes)}

    def apply(p, x):
        # Pallas MXU matmul kernel on the classifier head
        return L.dense_apply(p["fc"], L.global_avg_pool(x), use_kernel=True)

    return ("head", init, apply)


def _mbconv_block(prefix, in_c, out_c, stride, expand=6):
    exp_c = in_c * expand

    def init(key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        se_c = max(1, in_c // 4)
        return {
            "expand": L.conv_init(k1, 1, in_c, exp_c),
            "bn1": L.bn_init(exp_c),
            "dw": jax.random.normal(k2, (3, 3, 1, exp_c), jnp.float32) * 0.1,
            "bn2": L.bn_init(exp_c),
            "se_r": L.dense_init(k3, exp_c, se_c),
            "se_e": L.dense_init(k4, se_c, exp_c),
            "project": L.conv_init(k5, 1, exp_c, out_c),
            "bn3": L.bn_init(out_c),
        }

    def apply(p, x):
        y = jax.nn.relu(L.bn_apply(p["bn1"], L.conv_apply(p["expand"], x, 1)))
        # depthwise conv
        y = jax.lax.conv_general_dilated(
            y,
            p["dw"],
            window_strides=(stride, stride),
            padding="SAME",
            dimension_numbers=("NHWC", "HWIO", "NHWC"),
            feature_group_count=exp_c,
        )
        y = jax.nn.relu(L.bn_apply(p["bn2"], y))
        # squeeze-excite
        s = L.global_avg_pool(y)
        s = jax.nn.relu(L.dense_apply(p["se_r"], s))
        s = jax.nn.sigmoid(L.dense_apply(p["se_e"], s))
        y = y * s[:, None, None, :]
        y = L.bn_apply(p["bn3"], L.conv_apply(p["project"], y, 1))
        if stride == 1 and in_c == out_c:
            y = y + x
        return y

    return (prefix, init, apply)


def _inception_mini_block(prefix, in_c):
    """Small inception-A-style block: 1×1 / 1×1→3×3 / 1×1→5×5 concat → 96ch."""

    def init(key):
        k1, k2, k3, k4, k5 = jax.random.split(key, 5)
        return {
            "b1": L.conv_init(k1, 1, in_c, 32),
            "bn1": L.bn_init(32),
            "b3r": L.conv_init(k2, 1, in_c, 24),
            "bn3r": L.bn_init(24),
            "b3": L.conv_init(k3, 3, 24, 32),
            "bn3": L.bn_init(32),
            "b5r": L.conv_init(k4, 1, in_c, 16),
            "bn5r": L.bn_init(16),
            "b5": L.conv_init(k5, 5, 16, 32),
            "bn5": L.bn_init(32),
        }

    def apply(p, x):
        a = jax.nn.relu(L.bn_apply(p["bn1"], L.conv_apply(p["b1"], x, 1)))
        b = jax.nn.relu(L.bn_apply(p["bn3r"], L.conv_apply(p["b3r"], x, 1)))
        b = jax.nn.relu(L.bn_apply(p["bn3"], L.conv_apply(p["b3"], b, 1)))
        c = jax.nn.relu(L.bn_apply(p["bn5r"], L.conv_apply(p["b5r"], x, 1)))
        c = jax.nn.relu(L.bn_apply(p["bn5"], L.conv_apply(p["b5"], c, 1)))
        return jnp.concatenate([a, b, c], axis=-1)

    return (prefix, init, apply)


def _pool_stage(name):
    return (name, lambda key: {}, lambda p, x: L.max_pool(x))


def tiny_cnn(classes=NUM_CLASSES):
    return [
        _conv_bn_stage("conv1", 3, 3, 16, 1),
        _conv_bn_stage("conv2", 3, 16, 32, 2),
        _conv_bn_stage("conv3", 3, 32, 64, 2),
        _head_stage(64, classes),
    ]


def _resnet_mini(blocks, bottleneck, classes, width=16):
    stages = [_conv_bn_stage("conv1", 3, 3, width, 1)]
    widths = [width, width * 2, width * 4, width * 8]
    in_c = width
    for si, (n, w) in enumerate(zip(blocks, widths)):
        for b in range(n):
            stride = 2 if (si > 0 and b == 0) else 1
            if bottleneck:
                stages.append(_bottleneck_block(f"layer{si+1}.{b}", in_c, w, stride))
                in_c = w * 4
            else:
                stages.append(_basic_block(f"layer{si+1}.{b}", in_c, w, stride))
                in_c = w
    stages.append(_head_stage(in_c, classes))
    return stages


def resnet_mini18(classes=NUM_CLASSES):
    return _resnet_mini([2, 2, 2, 2], False, classes)


def resnet_mini34(classes=NUM_CLASSES):
    return _resnet_mini([3, 4, 6, 3], False, classes)


def resnet_mini50(classes=NUM_CLASSES):
    return _resnet_mini([3, 4, 6, 3], True, classes)


def effnet_lite(classes=NUM_CLASSES):
    stages = [_conv_bn_stage("stem", 3, 3, 16, 1)]
    in_c = 16
    for i, (out_c, stride, reps) in enumerate([(24, 2, 2), (40, 2, 2), (80, 2, 1)]):
        for r in range(reps):
            s = stride if r == 0 else 1
            stages.append(_mbconv_block(f"mb{i+1}.{r}", in_c, out_c, s))
            in_c = out_c
    stages.append(_conv_bn_stage("head_conv", 1, in_c, 160, 1))
    stages.append(_head_stage(160, classes))
    return stages


def inception_lite(classes=NUM_CLASSES):
    return [
        _conv_bn_stage("stem", 3, 3, 32, 1),
        _pool_stage("pool1"),
        _inception_mini_block("mini_a1", 32),
        _pool_stage("pool2"),
        _inception_mini_block("mini_a2", 96),
        _head_stage(96, classes),
    ]


MODELS = {
    "tiny_cnn": tiny_cnn,
    "resnet_mini18": resnet_mini18,
    "resnet_mini34": resnet_mini34,
    "resnet_mini50": resnet_mini50,
    "effnet_lite": effnet_lite,
    "inception_lite": inception_lite,
}

# --------------------------------------------------------------------------
# pipeline-composable init / apply / steps
# --------------------------------------------------------------------------


def init_params(stages, key):
    """Per-stage parameter list (ordering = manifest ordering)."""
    keys = jax.random.split(key, len(stages))
    return [init(k) for (_, init, _), k in zip(stages, keys)]


def apply_model(stages, params, x, sc=False):
    """Forward pass; S-C wraps each stage in jax.checkpoint (remat)."""
    for (name, _, apply), p in zip(stages, params):
        f = (lambda pp, xx, _a=apply: _a(pp, xx))
        if sc:
            f = jax.checkpoint(f)
        x = f(p, x)
    return x


def decode_input(batch_words, batch_size):
    """E-D stage 0: Pallas decode + junk-slice; f64 [G,H,W,C] → f32 [B,...]."""
    from compile.kernels import decode as dk

    imgs = dk.decode_base256_groups(batch_words, CAP)
    return imgs[:batch_size]


def _loss_fn(stages, params, x, labels, sc):
    logits = apply_model(stages, params, x, sc=sc)
    loss = L.softmax_cross_entropy(logits, labels)
    return loss, logits


def flatten_state(params, momentum):
    """Deterministic flat list: params leaves then momentum leaves."""
    p_leaves = jax.tree_util.tree_leaves(params)
    m_leaves = jax.tree_util.tree_leaves(momentum)
    return tuple(p_leaves) + tuple(m_leaves)


def state_treedef(stages):
    """Tree structure of the parameter list (computed once, outside jit)."""
    template = init_params(stages, jax.random.PRNGKey(0))
    return jax.tree_util.tree_structure(template)


def unflatten_state(treedef, flat):
    """Inverse of flatten_state given the stage treedef."""
    n = treedef.num_leaves
    params = jax.tree_util.tree_unflatten(treedef, list(flat[:n]))
    momentum = jax.tree_util.tree_unflatten(treedef, list(flat[n : 2 * n]))
    return params, momentum


def make_init(stages, mp=False):
    """(seed u32[2]) → flat state (params ⊎ zero momentum)."""

    def init(seed):
        key = jax.random.wrap_key_data(seed.astype(jnp.uint32), impl="threefry2x32")
        params = init_params(stages, key)
        if mp:
            params = jax.tree_util.tree_map(lambda a: a.astype(jnp.float16), params)
        momentum = jax.tree_util.tree_map(jnp.zeros_like, params)
        return flatten_state(params, momentum)

    return init


def make_train_step(stages, *, ed=False, mp=False, sc=False, mom=0.9,
                    loss_scale=1024.0, batch_size=BATCH):
    """(state…, batch, labels, lr) → (state'…, loss, correct).

    The learning rate is a *runtime input* (scalar f32), so the rust
    coordinator can drive LR schedules without recompiling artifacts.
    M-P follows the paper's Figure 3: f16 storage, f32 compute, static loss
    scaling; the momentum update runs in f32 and is stored back as f16.
    """

    treedef = state_treedef(stages)

    def step(*args):
        flat = args[:-3]
        batch, labels, lr = args[-3], args[-2], args[-1]
        params, momentum = unflatten_state(treedef, flat)
        if mp:
            params32 = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), params)
        else:
            params32 = params
        x = decode_input(batch, batch_size) if ed else batch

        def scaled_loss(p):
            loss, logits = _loss_fn(stages, p, x, labels, sc)
            scale = loss_scale if mp else 1.0
            return loss * scale, (loss, logits)

        grads, (loss, logits) = jax.grad(scaled_loss, has_aux=True)(params32)
        if mp:
            grads = jax.tree_util.tree_map(lambda g: g / loss_scale, grads)
        mom32 = jax.tree_util.tree_map(
            lambda a: a.astype(jnp.float32) if mp else a, momentum
        )
        new_mom32 = jax.tree_util.tree_map(lambda m, g: mom * m + g, mom32, grads)
        new_params32 = jax.tree_util.tree_map(
            lambda p, m: p - lr * m, params32, new_mom32
        )
        if mp:
            new_params = jax.tree_util.tree_map(
                lambda a: a.astype(jnp.float16), new_params32
            )
            new_mom = jax.tree_util.tree_map(
                lambda a: a.astype(jnp.float16), new_mom32
            )
        else:
            new_params, new_mom = new_params32, new_mom32
        correct = L.correct_count(logits, labels)
        return flatten_state(new_params, new_mom) + (loss, correct)

    return step


def make_eval_step(stages, *, ed=False, mp=False, sc=False, batch_size=BATCH):
    """(params…, batch, labels) → (loss, correct).

    Takes only the parameter half of the state: XLA dead-parameter
    elimination would strip unused momentum inputs from the compiled
    executable anyway, so the artifact signature excludes them.
    """

    treedef = state_treedef(stages)

    def step(*args):
        flat = args[:-2]
        batch, labels = args[-2], args[-1]
        params = jax.tree_util.tree_unflatten(treedef, list(flat))
        if mp:
            params = jax.tree_util.tree_map(lambda a: a.astype(jnp.float32), params)
        x = decode_input(batch, batch_size) if ed else batch
        # eval never needs remat — sc affects memory, not numerics
        loss, logits = _loss_fn(stages, params, x, labels, sc=False)
        return loss, L.correct_count(logits, labels)

    return step
