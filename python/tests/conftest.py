"""Shared test config: x64 must be on before jax initializes."""

import jax

jax.config.update("jax_enable_x64", True)
