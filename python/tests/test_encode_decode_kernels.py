"""L1 correctness: Pallas encode/decode vs the pure-jnp oracle.

Hypothesis sweeps shapes and values; every case must be bit-exact (the
packing is integer arithmetic in f64, exact below 2^53 by construction).
"""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import decode as dk
from compile.kernels import encode as ek
from compile.kernels import ref

DIMS = st.sampled_from([(4, 4, 1), (8, 8, 3), (16, 8, 3), (32, 32, 3), (6, 10, 2)])


def random_batch(rng, n, hwc):
    h, w, c = hwc
    return rng.integers(0, 256, (n, h, w, c)).astype(np.float64)


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 6), hwc=DIMS, seed=st.integers(0, 2**32 - 1))
def test_encode_kernel_matches_ref(n, hwc, seed):
    batch = random_batch(np.random.default_rng(seed), n, hwc)
    got = ek.encode_base256(jnp.asarray(batch))
    want = ref.encode_base256(jnp.asarray(batch))
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=20, deadline=None)
@given(g=st.integers(1, 4), hwc=DIMS, seed=st.integers(0, 2**32 - 1))
def test_decode_kernel_matches_ref(g, hwc, seed):
    rng = np.random.default_rng(seed)
    h, w, c = hwc
    words = rng.integers(0, 2**48, (g, h, w, c)).astype(np.float64)
    got = dk.decode_base256_groups(jnp.asarray(words), 6)
    want = ref.decode_base256_groups(jnp.asarray(words), 6)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


@settings(max_examples=15, deadline=None)
@given(n=st.integers(1, 6), hwc=DIMS, seed=st.integers(0, 2**32 - 1))
def test_roundtrip_exact(n, hwc, seed):
    """decode(encode(x)) == x / 255 for every image, bit-exact digits."""
    batch = random_batch(np.random.default_rng(seed), n, hwc)
    words = ek.encode_base256(jnp.asarray(batch))
    imgs = dk.decode_base256_groups(words[None, ...], 6)[:n]
    np.testing.assert_allclose(
        np.asarray(imgs), batch.astype(np.float32) / 255.0, rtol=0, atol=0
    )


def test_roundtrip_saturated_pixels():
    """All-255 images maximize the packed value; still exact at capacity."""
    batch = np.full((6, 8, 8, 3), 255.0)
    words = ek.encode_base256(jnp.asarray(batch))
    assert float(jnp.max(words)) < 2.0**53, "packed value must stay exact"
    imgs = dk.decode_base256_groups(words[None, ...], 6)
    np.testing.assert_array_equal(np.asarray(imgs), np.ones_like(imgs))


def test_junk_tail_slots_decode_to_zero():
    """Partial group: un-encoded digit positions decode to black images."""
    batch = np.full((2, 4, 4, 3), 200.0)
    words = ref.encode_base256(jnp.asarray(batch))
    imgs = dk.decode_base256_groups(words[None, ...], 6)
    assert np.all(np.asarray(imgs[2:]) == 0)


def test_encode_rejects_over_capacity():
    batch = np.zeros((7, 4, 4, 3))
    with pytest.raises(ValueError, match="≤6"):
        ek.encode_base256(jnp.asarray(batch))
    with pytest.raises(ValueError, match="≤6"):
        ref.encode_base256(jnp.asarray(batch))


def test_paper_capacity_claim_is_impossible():
    """The paper's '16 images in one float64' cannot be exact: 16 base-256
    digits need 128 bits, f64 has 53. Verify the 7th image already breaks
    exactness if capacity were ignored."""
    # 256^6 > 2^48: the 7th digit would need bits ≥ 2^48·255 ≳ 2^53
    assert 256.0**7 > 2.0**53
    assert 256.0**6 < 2.0**53
