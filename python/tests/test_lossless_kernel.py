"""L1 correctness: loss-less forced encoding (Algorithm 4) vs oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import lossless as lk
from compile.kernels import ref

DIMS = st.sampled_from([(4, 4, 1), (8, 8, 3), (16, 8, 2), (32, 32, 3)])


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 7), hwc=DIMS, seed=st.integers(0, 2**32 - 1))
def test_encode_matches_ref(n, hwc, seed):
    rng = np.random.default_rng(seed)
    h, w, c = hwc
    batch = rng.integers(0, 256, (n, h, w, c)).astype(np.float64)
    words, offs = lk.encode_lossless128(jnp.asarray(batch))
    rwords, roffs = ref.encode_lossless128(jnp.asarray(batch))
    np.testing.assert_array_equal(np.asarray(words), np.asarray(rwords))
    np.testing.assert_array_equal(np.asarray(offs), np.asarray(roffs))


@settings(max_examples=20, deadline=None)
@given(n=st.integers(1, 7), hwc=DIMS, seed=st.integers(0, 2**32 - 1))
def test_roundtrip_bit_exact(n, hwc, seed):
    """The whole point of Algorithm 4: exact uint8 reconstruction."""
    rng = np.random.default_rng(seed)
    h, w, c = hwc
    batch = rng.integers(0, 256, (n, h, w, c)).astype(np.float64)
    words, offs = lk.encode_lossless128(jnp.asarray(batch))
    back = lk.decode_lossless128(words, offs)
    np.testing.assert_array_equal(np.asarray(back), batch.astype(np.uint8))


def test_parity_plane_is_the_lsb():
    batch = np.array([[[[255.0]]], [[[254.0]]]])  # odd, even
    _, offs = lk.encode_lossless128(jnp.asarray(batch))
    assert int(offs[0, 0, 0, 0]) == 1
    assert int(offs[1, 0, 0, 0]) == 0


def test_capacity_is_seven_not_thirty_two():
    """Paper claims 32 images; 32·7 = 224 bits ≫ 53. Exact max is 7."""
    assert 128.0**7 < 2.0**53 < 128.0**8
    batch = np.zeros((8, 4, 4, 1))
    with pytest.raises(ValueError, match="≤7"):
        lk.encode_lossless128(jnp.asarray(batch))


def test_decode_matches_ref_decoder():
    rng = np.random.default_rng(7)
    batch = rng.integers(0, 256, (7, 8, 8, 3)).astype(np.float64)
    words, offs = ref.encode_lossless128(jnp.asarray(batch))
    a = lk.decode_lossless128(jnp.asarray(words), jnp.asarray(offs))
    b = ref.decode_lossless128(jnp.asarray(words), jnp.asarray(offs))
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
