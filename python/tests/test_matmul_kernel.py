"""L1 correctness: MXU-tiled matmul kernel vs jnp, incl. gradients."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul as mm
from compile.kernels import ref


@settings(max_examples=15, deadline=None)
@given(
    m=st.sampled_from([8, 16, 64, 128, 130]),
    k=st.sampled_from([16, 64, 96, 256]),
    n=st.sampled_from([10, 32, 128]),
    seed=st.integers(0, 2**32 - 1),
)
def test_matches_ref_f32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    a = rng.standard_normal((m, k)).astype(np.float32)
    b = rng.standard_normal((k, n)).astype(np.float32)
    got = mm.matmul(a, b)
    want = ref.matmul(a, b)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4)


def test_low_precision_inputs_accumulate_f32():
    rng = np.random.default_rng(0)
    a = rng.standard_normal((32, 64)).astype(np.float16)
    b = rng.standard_normal((64, 32)).astype(np.float16)
    got = mm.matmul(a, b)
    assert got.dtype == jnp.float32
    want = np.matmul(a.astype(np.float32), b.astype(np.float32))
    np.testing.assert_allclose(np.asarray(got), want, rtol=2e-3, atol=2e-3)


def test_gradients_flow_through_custom_vjp():
    rng = np.random.default_rng(1)
    a = rng.standard_normal((8, 16)).astype(np.float32)
    b = rng.standard_normal((16, 4)).astype(np.float32)

    def loss_kernel(a, b):
        return jnp.sum(mm.matmul(a, b) ** 2)

    def loss_ref(a, b):
        return jnp.sum(jnp.matmul(a, b) ** 2)

    ga_k, gb_k = jax.grad(loss_kernel, argnums=(0, 1))(a, b)
    ga_r, gb_r = jax.grad(loss_ref, argnums=(0, 1))(a, b)
    np.testing.assert_allclose(np.asarray(ga_k), np.asarray(ga_r), rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(np.asarray(gb_k), np.asarray(gb_r), rtol=1e-4, atol=1e-4)


def test_tile_sizes_divide_dims():
    assert mm._tile(256, 128) == 128
    assert mm._tile(96, 128) == 96
    assert mm._tile(130, 128) == 65  # largest divisor ≤ 128
    assert mm._tile(7, 128) == 7


def test_mxu_utilization_estimate():
    assert mm.mxu_utilization_estimate(128, 128, 128) == 1.0
    assert mm.mxu_utilization_estimate(16, 64, 10) < 0.05
