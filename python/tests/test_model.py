"""L2 tests: model zoo shapes, pipeline equivalences, training behaviour."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def make_batch(seed=0, batch=M.BATCH):
    rng = np.random.default_rng(seed)
    x = rng.random((batch, 32, 32, 3)).astype(np.float32)
    labels = np.eye(10, dtype=np.float32)[rng.integers(0, 10, batch)]
    return x, labels


def encoded_from_raw(x):
    """Pack a [B,32,32,3] f32 (0..1) batch into [G,32,32,3] f64 words."""
    from compile.kernels import ref

    b = x.shape[0]
    imgs = np.round(x * 255.0).astype(np.float64)
    groups = []
    for start in range(0, b, M.CAP):
        chunk = imgs[start : start + M.CAP]
        groups.append(np.asarray(ref.encode_base256(jnp.asarray(chunk))))
    return np.stack(groups, 0)


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_forward_shapes(name):
    stages = M.MODELS[name]()
    params = M.init_params(stages, jax.random.PRNGKey(0))
    x, _ = make_batch()
    logits = M.apply_model(stages, params, jnp.asarray(x))
    assert logits.shape == (M.BATCH, M.NUM_CLASSES)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("name", sorted(M.MODELS))
def test_remat_is_numerically_identical(name):
    """S-C changes the schedule, not the math."""
    stages = M.MODELS[name]()
    params = M.init_params(stages, jax.random.PRNGKey(1))
    x, _ = make_batch(1)
    a = M.apply_model(stages, params, jnp.asarray(x), sc=False)
    b = M.apply_model(stages, params, jnp.asarray(x), sc=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6, atol=1e-6)


def test_decode_input_recovers_images():
    x, _ = make_batch(2)
    words = encoded_from_raw(x)
    decoded = M.decode_input(jnp.asarray(words), M.BATCH)
    np.testing.assert_allclose(
        np.asarray(decoded), np.round(x * 255) / 255.0, rtol=0, atol=1e-7
    )


def test_init_deterministic_and_seed_sensitive():
    stages = M.MODELS["tiny_cnn"]()
    init = jax.jit(M.make_init(stages))
    s1 = init(np.array([0, 7], np.uint32))
    s2 = init(np.array([0, 7], np.uint32))
    s3 = init(np.array([0, 8], np.uint32))
    for a, b in zip(s1, s2):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # compare a *random* leaf (BN scales are deterministic ones): the first
    # conv kernel has shape [3,3,3,16]
    conv1 = next(i for i, t in enumerate(s1) if t.shape == (3, 3, 3, 16))
    assert not np.array_equal(np.asarray(s1[conv1]), np.asarray(s3[conv1]))
    # momentum half starts at zero
    n = len(s1) // 2
    assert all(float(jnp.sum(jnp.abs(t))) == 0.0 for t in s1[n:])


def train_n_steps(name, pipeline_flags, steps=8, seed=0):
    stages = M.MODELS[name]()
    init = jax.jit(M.make_init(stages, mp=pipeline_flags.get("mp", False)))
    state = init(np.array([0, 42], np.uint32))
    step = jax.jit(M.make_train_step(stages, **pipeline_flags))
    x, labels = make_batch(seed)
    batch = (
        encoded_from_raw(x) if pipeline_flags.get("ed") else x
    )
    losses = []
    out = None
    for _ in range(steps):
        args = state if out is None else out[:-2]
        out = step(*args, batch, labels, np.float32(0.05))
        losses.append(float(out[-2]))
        state = out[:-2]
    return losses


def test_all_pipelines_learn_tiny_cnn():
    for flags in [
        {},
        {"ed": True},
        {"mp": True},
        {"sc": True},
        {"ed": True, "mp": True, "sc": True},
    ]:
        losses = train_n_steps("tiny_cnn", flags, steps=16)
        assert losses[-1] < losses[0] * 0.8, f"{flags}: {losses}"


def test_pipelines_agree_on_initial_loss():
    """Same seed ⇒ same initial loss across pipelines (the paper's
    'same accuracy' claim starts here). MP is looser (f16 storage)."""
    base = train_n_steps("tiny_cnn", {}, steps=1)[0]
    ed = train_n_steps("tiny_cnn", {"ed": True}, steps=1)[0]
    sc = train_n_steps("tiny_cnn", {"sc": True}, steps=1)[0]
    mp = train_n_steps("tiny_cnn", {"mp": True}, steps=1)[0]
    assert abs(base - sc) < 1e-5
    assert abs(base - ed) < 0.05  # ed quantizes pixels to uint8 first
    assert abs(base - mp) < 0.02  # f16 weights
    # and after 8 steps everyone is in the same neighbourhood
    finals = [
        train_n_steps("tiny_cnn", f)[-1]
        for f in [{}, {"ed": True}, {"mp": True}, {"sc": True}]
    ]
    assert max(finals) - min(finals) < 0.35, finals


def test_mp_state_is_f16_and_loss_finite():
    stages = M.MODELS["tiny_cnn"]()
    init = jax.jit(M.make_init(stages, mp=True))
    state = init(np.array([0, 1], np.uint32))
    assert all(t.dtype == jnp.float16 for t in state)
    losses = train_n_steps("tiny_cnn", {"mp": True}, steps=4)
    assert all(np.isfinite(losses))


def test_eval_step_params_only():
    stages = M.MODELS["tiny_cnn"]()
    init = jax.jit(M.make_init(stages))
    state = init(np.array([0, 3], np.uint32))
    n = len(state) // 2
    ev = jax.jit(M.make_eval_step(stages))
    x, labels = make_batch(3)
    loss, correct = ev(*state[:n], x, labels)
    assert np.isfinite(float(loss))
    assert 0 <= float(correct) <= M.BATCH


def test_soft_labels_cross_entropy():
    """Mixed labels (MixUp) produce a loss between the two hard losses."""
    stages = M.MODELS["tiny_cnn"]()
    params = M.init_params(stages, jax.random.PRNGKey(0))
    x, _ = make_batch(4)
    logits = M.apply_model(stages, params, jnp.asarray(x))
    from compile import layers as L

    hard_a = np.eye(10, dtype=np.float32)[np.zeros(M.BATCH, int)]
    hard_b = np.eye(10, dtype=np.float32)[np.ones(M.BATCH, int)]
    mixed = 0.5 * hard_a + 0.5 * hard_b
    la = float(L.softmax_cross_entropy(logits, jnp.asarray(hard_a)))
    lb = float(L.softmax_cross_entropy(logits, jnp.asarray(hard_b)))
    lm = float(L.softmax_cross_entropy(logits, jnp.asarray(mixed)))
    assert min(la, lb) <= lm <= max(la, lb) + 1e-6
