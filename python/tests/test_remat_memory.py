"""E9: XLA-measured validation of the remat (S-C) and M-P mechanisms.

Findings (recorded in EXPERIMENTS.md §E9): on the XLA **CPU** backend,
`jax.checkpoint` verifiably inserts the recompute (the optimized HLO has
more convolutions in the backward pass), but CPU buffer assignment already
reuses buffers so aggressively that the *temp allocation* does not shrink —
remat's memory win materializes on accelerator backends, which is where the
paper measured it. These tests therefore check:

* the recompute is structurally present (S-C ≠ no-op),
* temp memory does not *regress* badly under S-C,
* M-P halves the state bytes on the wire,
* E-D shrinks the batch argument by the exact packed amount.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


def compiled(name, flags, batch=M.BATCH):
    stages = M.MODELS[name]()
    specs = M.init_params(stages, jax.random.PRNGKey(0))
    dt = jnp.float16 if flags.get("mp") else jnp.float32
    state_args = [
        jax.ShapeDtypeStruct(l.shape, dt) for l in jax.tree_util.tree_leaves(specs)
    ] * 2
    if flags.get("ed"):
        groups = -(-batch // M.CAP)
        batch_arg = jax.ShapeDtypeStruct((groups, 32, 32, 3), jnp.float64)
    else:
        batch_arg = jax.ShapeDtypeStruct((batch, 32, 32, 3), jnp.float32)
    labels_arg = jax.ShapeDtypeStruct((batch, 10), jnp.float32)
    lr_arg = jax.ShapeDtypeStruct((), jnp.float32)
    step = M.make_train_step(stages, **flags)
    return jax.jit(step).lower(*state_args, batch_arg, labels_arg, lr_arg).compile()


def conv_count(c):
    txt = c.as_text()
    return txt.count(" convolution(") + txt.count(" convolution.")


@pytest.mark.slow
def test_remat_recompute_is_structurally_present():
    """S-C must add recompute ops to the backward pass — jax.checkpoint
    survives the AOT path (it is not silently dropped)."""
    base = compiled("resnet_mini18", {})
    sc = compiled("resnet_mini18", {"sc": True})
    nb, ns = conv_count(base), conv_count(sc)
    assert ns > nb, f"sc convs {ns} !> base convs {nb}"


@pytest.mark.slow
def test_remat_temp_overhead_bounded_on_cpu():
    """XLA CPU does not realize remat's temp savings (its buffer assignment
    already reuses aggressively); assert the barrier overhead stays small
    so a regression would be caught. The *accelerator* story is what the
    rust analytic simulator models (DESIGN.md §5)."""
    base = compiled("resnet_mini18", {})
    sc = compiled("resnet_mini18", {"sc": True})
    ratio = sc.memory_analysis().temp_size_in_bytes / base.memory_analysis().temp_size_in_bytes
    assert ratio < 1.25, f"temp ratio {ratio:.2f}"


def test_mp_halves_state_argument_bytes():
    base = compiled("tiny_cnn", {})
    mp = compiled("tiny_cnn", {"mp": True})
    # argument bytes = state + batch + labels (+ lr); isolate the state by
    # subtracting the fixed batch/labels/lr payload
    fixed = 16 * 32 * 32 * 3 * 4 + 16 * 10 * 4 + 4
    sb = base.memory_analysis().argument_size_in_bytes - fixed
    sm = mp.memory_analysis().argument_size_in_bytes - fixed
    ratio = sm / sb
    assert abs(ratio - 0.5) < 0.02, f"state ratio {ratio:.3f}"


def test_ed_shrinks_batch_argument():
    base = compiled("tiny_cnn", {})
    ed = compiled("tiny_cnn", {"ed": True})
    delta = (
        base.memory_analysis().argument_size_in_bytes
        - ed.memory_analysis().argument_size_in_bytes
    )
    raw_batch = 16 * 32 * 32 * 3 * 4
    enc_batch = 3 * 32 * 32 * 3 * 8
    assert delta == raw_batch - enc_batch, delta
