//! Activation-arena benchmark: interval-packing quality and speed across
//! the model zoo (fragmentation ratio vs the exact DP peak, pack time vs
//! layer count) plus the step-scratch hot path (heap staging vs the
//! generation-tagged slab allocator).
//!
//! Emits `BENCH_arena.json`. `OPTORCH_BENCH_CHECK=1` runs a fast smoke
//! pass that *fails the process* when an invariant breaks: overlapping or
//! out-of-slab offsets, a layout whose slab + static bytes fall below the
//! exact DP peak, fragmentation above 1.25 on the paper profiles, or any
//! heap allocation inside the slab path's steady state (counted by a
//! global allocator shim, same harness as `planner_frontier`).
//!
//! All planning flows through the `PlanRequest` facade (the `pack`-only
//! sweep still times the low-level packer against facade-staged
//! lifetimes).

use optorch::memory::arena::{pack, validate, ArenaAllocator};
use optorch::memory::pipeline::PlanRequest;
use optorch::models::{ArchProfile, LayerKind, LayerProfile};
use optorch::util::bench::{bench, fmt_bytes, fmt_ns, Table};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; only adds a counter.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

struct ArchRow {
    name: String,
    depth: usize,
    tensors: usize,
    slab: u64,
    base: u64,
    peak: u64,
    frag: f64,
    request_ns: f64,
}

/// Deterministic synthetic chain for the pack-time-vs-depth sweep.
fn synth_chain(depth: usize) -> ArchProfile {
    let widths = [64usize, 48, 32, 24, 16, 32, 64, 96];
    let layers = (0..depth)
        .map(|i| {
            let c = widths[i % widths.len()];
            let out = (8 * 8 * c) as u64;
            LayerProfile {
                name: format!("l{i}"),
                kind: LayerKind::Conv,
                out_shape: (8, 8, c),
                act_elems: out * 2,
                params: (c * 9) as u64,
                flops_per_image: c as u64 * 10_000,
            }
        })
        .collect();
    ArchProfile { name: format!("chain{depth}"), input: (8, 8, 3), layers }
}

fn write_json(
    batch: usize,
    rows: &[ArchRow],
    sweep: &[(usize, usize, f64)],
    heap_step_ns: f64,
    arena_step_ns: f64,
    steady_allocs: u64,
) -> std::io::Result<()> {
    let mut j = format!("{{\n  \"batch\": {batch},\n  \"archs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"arch\": \"{}\", \"depth\": {}, \"tensors\": {}, \"slab_bytes\": {}, \
             \"base_bytes\": {}, \"peak_bytes\": {}, \"fragmentation_ratio\": {:.4}, \
             \"request_ns\": {:.0}}}{}\n",
            r.name,
            r.depth,
            r.tensors,
            r.slab,
            r.base,
            r.peak,
            r.frag,
            r.request_ns,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n  \"pack_time_sweep\": [\n");
    for (i, (depth, tensors, ns)) in sweep.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"depth\": {depth}, \"tensors\": {tensors}, \"pack_ns\": {ns:.0}}}{}\n",
            if i + 1 < sweep.len() { "," } else { "" }
        ));
    }
    j.push_str(&format!(
        "  ],\n  \"step_scratch\": {{\"heap_ns\": {heap_step_ns:.0}, \
         \"arena_ns\": {arena_step_ns:.0}, \"arena_steady_allocs\": {steady_allocs}}}\n}}\n"
    ));
    std::fs::write("BENCH_arena.json", j)
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let iters = if check { 3 } else { 20 };
    let batch = 16;
    let mut failures = 0u32;
    let mut rows: Vec<ArchRow> = Vec::new();

    println!("=== activation arena: slab packing vs the exact DP peak (batch {batch}) ===\n");
    let mut t = Table::new(&[
        "arch",
        "depth",
        "tensors",
        "slab",
        "static",
        "exact peak",
        "fragmentation",
        "request (plan+pack)",
    ]);
    for name in ["resnet18", "resnet50", "efficientnet_b0", "inception_v3"] {
        let hw = if name == "inception_v3" { 299 } else { 224 };
        let request = PlanRequest::for_model(name, (hw, hw, 3), 1000).batch(batch);
        let outcome = request.run().expect("zoo model plans");
        let plan = &outcome.plan;
        let lt = outcome.lifetimes().expect("arena staged by default");
        let layout = outcome.layout().expect("arena staged by default");

        if let Err(e) = validate(lt, layout) {
            eprintln!("FAIL {name}: invalid layout: {e}");
            failures += 1;
        }
        if layout.peak_bytes != plan.peak_bytes {
            eprintln!(
                "FAIL {name}: layout peak {} != plan peak {}",
                layout.peak_bytes, plan.peak_bytes
            );
            failures += 1;
        }
        if layout.total_bytes() < plan.peak_bytes {
            eprintln!(
                "FAIL {name}: slab + static {} below the exact peak {}",
                layout.total_bytes(),
                plan.peak_bytes
            );
            failures += 1;
        }
        if outcome.device_peak_packed() != layout.total_bytes() {
            eprintln!("FAIL {name}: device_peak_packed disagrees with the packed layout");
            failures += 1;
        }
        let frag = layout.fragmentation_ratio();
        if frag > 1.25 {
            eprintln!("FAIL {name}: fragmentation ratio {frag:.3} > 1.25");
            failures += 1;
        }

        // one full facade drive per iteration: plan + lifetimes + pack
        // (+ the staged memory report)
        let stats = bench(1, iters, || {
            let outcome = request.run().expect("zoo model plans");
            std::hint::black_box((
                outcome.plan.checkpoints.len(),
                outcome.layout().map(|l| l.slab_bytes),
            ));
        });

        t.row(&[
            name.to_string(),
            format!("{}", outcome.arch.depth()),
            format!("{}", lt.tensors.len()),
            fmt_bytes(layout.slab_bytes),
            fmt_bytes(layout.base_bytes),
            fmt_bytes(layout.peak_bytes),
            format!("{frag:.3}x"),
            fmt_ns(stats.median_ns),
        ]);
        rows.push(ArchRow {
            name: name.to_string(),
            depth: outcome.arch.depth(),
            tensors: lt.tensors.len(),
            slab: layout.slab_bytes,
            base: layout.base_bytes,
            peak: layout.peak_bytes,
            frag,
            request_ns: stats.median_ns,
        });
    }
    t.print();

    // ---- pack time vs layer count (packing only, lifetimes precomputed) ----
    println!("\n=== offset assignment: pack time vs layer count ===\n");
    let mut t = Table::new(&["depth", "tensors", "pack"]);
    let mut sweep: Vec<(usize, usize, f64)> = Vec::new();
    for depth in [8usize, 16, 32, 64, 96] {
        let outcome = PlanRequest::for_arch(synth_chain(depth))
            .batch(batch)
            .run()
            .expect("chain plans");
        let lt = outcome.lifetimes().expect("arena staged by default");
        let stats = bench(1, iters, || {
            let layout = pack(lt);
            std::hint::black_box(layout.slab_bytes);
        });
        t.row(&[
            format!("{depth}"),
            format!("{}", lt.tensors.len()),
            fmt_ns(stats.median_ns),
        ]);
        sweep.push((depth, lt.tensors.len(), stats.median_ns));
    }
    t.print();

    // ---- step scratch: heap staging vs the slab allocator ----
    // Emulates the runtime's encoded-batch staging pattern (3 groups of
    // CIFAR words + one label matrix per step) with both strategies. The
    // real `batch_literal_arena` path is pjrt-gated, so this bench gates
    // the allocator itself; `runtime::exec` tests pin the real path to
    // the slab via `fallback_allocs == 0`.
    let groups = 3usize;
    let px = 32 * 32 * 3;
    let labels_len = 16 * 10;
    let src: Vec<f64> = (0..px).map(|i| i as f64).collect();
    let src_labels: Vec<f32> = vec![0.1; labels_len];

    let heap_stats = bench(8, iters * 50, || {
        let mut data: Vec<f64> = Vec::with_capacity(groups * px);
        for _ in 0..groups {
            data.extend_from_slice(&src);
        }
        let mut lab: Vec<f32> = Vec::with_capacity(labels_len);
        lab.extend_from_slice(&src_labels);
        std::hint::black_box((data.len(), lab.len()));
    });

    let mut arena = ArenaAllocator::new(groups * px * 8 + labels_len * 4);
    let arena_step = |arena: &mut ArenaAllocator| {
        arena.begin_step();
        let hw = arena.alloc_f64(groups * px).expect("slab sized for the step");
        let buf = arena.f64_mut(&hw);
        for dst in buf.chunks_exact_mut(px) {
            dst.copy_from_slice(&src);
        }
        let hl = arena.alloc_f32(labels_len).expect("slab sized for the step");
        arena.f32_mut(&hl).copy_from_slice(&src_labels);
        std::hint::black_box(arena.high_water_bytes());
    };
    let arena_stats = bench(8, iters * 50, || arena_step(&mut arena));

    // steady-state allocation audit: N arena steps must not touch the heap
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    for _ in 0..256 {
        arena_step(&mut arena);
    }
    let steady_allocs = ALLOC_COUNT.load(Ordering::Relaxed) - before;
    if steady_allocs != 0 {
        eprintln!("FAIL: {steady_allocs} heap allocations across 256 arena steps");
        failures += 1;
    }
    if arena.fallback_allocs() != 0 {
        eprintln!("FAIL: {} slab fallbacks in the arena step path", arena.fallback_allocs());
        failures += 1;
    }

    println!("\n=== step scratch staging: heap vs slab ===\n");
    let mut t = Table::new(&["path", "per step", "steady-state heap allocs"]);
    t.row(&["heap (old)".into(), fmt_ns(heap_stats.median_ns), "2 per step".into()]);
    t.row(&[
        "arena slab".into(),
        fmt_ns(arena_stats.median_ns),
        format!("{steady_allocs} per 256 steps"),
    ]);
    t.print();

    match write_json(
        batch,
        &rows,
        &sweep,
        heap_stats.median_ns,
        arena_stats.median_ns,
        steady_allocs,
    ) {
        Ok(()) => println!("\nwrote BENCH_arena.json"),
        Err(e) => eprintln!("\ncould not write BENCH_arena.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: all arena invariants hold");
    }
}
