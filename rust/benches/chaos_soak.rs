//! Chaos soak: the fault-injection matrix run end to end, timed, and
//! checked against the recovery invariants.
//!
//! Three sweeps:
//!
//! 1. **loader matrix** — fault specs × worker counts on the E-D pool
//!    loader; every faulted stream must be byte-identical to the
//!    fault-free reference, with the expected respawn/corruption counts;
//! 2. **link-fault engine** — failure probabilities × slowdowns on the
//!    offload engine; stats must be deterministic across reruns, retries
//!    must be bounded, and a healthy link must record zero faults;
//! 3. **degradation ladder** — budgets from generous to absurd through
//!    `run_degraded`; every outcome must land on a real Pareto-frontier
//!    point and re-run to the identical report.
//!
//! Emits `BENCH_fault.json`. `OPTORCH_BENCH_CHECK=1` runs a fast smoke
//! pass that *fails the process* (exit 1) when any invariant breaks.

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{EncodeSpec, Encoding, WordType};
use optorch::data::loader::{dump, BatchPayload, EdLoader, LoaderMode};
use optorch::data::pool::BufferPool;
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::fault::{DegradeTrigger, FaultInjector, FaultSpec};
use optorch::memory::offload::{LinkFaults, OffloadEngine, SpillPlan};
use optorch::memory::pipeline::{PlanError, PlanRequest};
use optorch::memory::planner::{pareto_frontier, DEFAULT_FRONTIER_LEVELS};
use optorch::models::arch_by_name;
use optorch::util::bench::{fmt_bytes, Table};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Instant;

fn loader_with(
    seed: u64,
    batches: usize,
    workers: usize,
    faults: Option<Arc<FaultInjector>>,
) -> EdLoader {
    let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 240, 9));
    let sampler = SbsSampler::uniform(
        d.as_ref(),
        16,
        AugPolicy::parse("hflip,crop4").unwrap(),
        seed,
    )
    .unwrap();
    EdLoader::with_faults(
        d,
        sampler,
        Some(EncodeSpec::new(Encoding::Base256, WordType::F64)),
        batches,
        LoaderMode::Parallel { prefetch_depth: 2, num_workers: workers },
        Arc::new(BufferPool::default()),
        faults,
        None,
    )
}

fn payload_bytes(p: &BatchPayload) -> Vec<u8> {
    match p {
        BatchPayload::Raw { data, labels, n } => {
            let mut out = (*n as u64).to_le_bytes().to_vec();
            for v in data.iter().chain(labels) {
                out.extend_from_slice(&v.to_le_bytes());
            }
            out
        }
        BatchPayload::Encoded(groups) => {
            let mut out = Vec::new();
            for g in groups {
                out.extend_from_slice(&dump::to_bytes(g));
            }
            out
        }
    }
}

/// Drain a loader; `(stream, respawns, corruptions, error, wall ms)`.
fn drain(mut l: EdLoader) -> (Vec<Vec<u8>>, u64, u64, Option<String>, f64) {
    let start = Instant::now();
    let mut out = Vec::new();
    let mut err = None;
    loop {
        match l.try_next() {
            Ok(Some(p)) => {
                out.push(payload_bytes(&p));
                l.recycle(p);
            }
            Ok(None) => break,
            Err(e) => {
                err = Some(e.to_string());
                break;
            }
        }
    }
    let wall_ms = start.elapsed().as_secs_f64() * 1e3;
    let stats = l.stats();
    (
        out,
        stats.respawns.load(Ordering::Relaxed),
        stats.corruptions_detected.load(Ordering::Relaxed),
        err,
        wall_ms,
    )
}

struct LoaderRow {
    spec: String,
    workers: usize,
    respawns: u64,
    corruptions: u64,
    stream_ok: bool,
    wall_ms: f64,
}

struct LinkRow {
    fail_prob: f64,
    slow_factor: f64,
    steps: u64,
    evictions: u64,
    prefetches: u64,
    link_faults: u64,
    link_retries: u64,
    retry_stall_ms: f64,
}

struct DegradeRow {
    budget: u64,
    met_budget: bool,
    rungs: usize,
    device_total: u64,
    json: String,
}

fn write_json(loader: &[LoaderRow], link: &[LinkRow], degrade: &[DegradeRow]) -> std::io::Result<()> {
    let mut j = String::from("{\n  \"loader\": [\n");
    for (i, r) in loader.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"spec\": \"{}\", \"workers\": {}, \"respawns\": {}, \
             \"corruptions\": {}, \"stream_ok\": {}, \"wall_ms\": {:.3}}}{}\n",
            r.spec,
            r.workers,
            r.respawns,
            r.corruptions,
            r.stream_ok,
            r.wall_ms,
            if i + 1 < loader.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n  \"link\": [\n");
    for (i, r) in link.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"fail_prob\": {:.2}, \"slow_factor\": {:.1}, \"steps\": {}, \
             \"evictions\": {}, \"prefetches\": {}, \"link_faults\": {}, \
             \"link_retries\": {}, \"retry_stall_ms\": {:.4}}}{}\n",
            r.fail_prob,
            r.slow_factor,
            r.steps,
            r.evictions,
            r.prefetches,
            r.link_faults,
            r.link_retries,
            r.retry_stall_ms,
            if i + 1 < link.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n  \"degrade\": [\n");
    for (i, r) in degrade.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"budget\": {}, \"met_budget\": {}, \"rungs\": {}, \
             \"device_total\": {}, \"report\": {}}}{}\n",
            r.budget,
            r.met_budget,
            r.rungs,
            r.device_total,
            r.json,
            if i + 1 < degrade.len() { "," } else { "" }
        ));
    }
    j.push_str("  ]\n}\n");
    std::fs::write("BENCH_fault.json", j)
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let mut failures = 0u32;

    // ---- 1. loader chaos matrix ----
    let batches = if check { 8 } else { 32 };
    println!("=== chaos soak: E-D loader under injected faults ({batches} batches) ===\n");
    let mut loader_rows: Vec<LoaderRow> = Vec::new();
    let mut t = Table::new(&["fault spec", "workers", "respawns", "corruptions", "stream", "wall"]);
    let kill = batches / 2;
    let specs = [
        String::new(),
        format!("worker-panic@{kill}"),
        format!("corrupt@{}", batches / 3),
        format!("seed=3;worker-panic@1;corrupt@{}", batches - 1),
    ];
    for workers in [1usize, 2, 4] {
        let (reference, _, _, ref_err, _) = drain(loader_with(11, batches, workers, None));
        if ref_err.is_some() || reference.len() != batches {
            eprintln!("FAIL: fault-free reference broke (workers={workers}): {ref_err:?}");
            failures += 1;
            continue;
        }
        for spec_text in &specs {
            let (spec, inj) = if spec_text.is_empty() {
                (None, None)
            } else {
                let s = FaultSpec::parse(spec_text).expect("matrix specs parse");
                let i = Arc::new(FaultInjector::new(&s));
                (Some(s), Some(i))
            };
            let (stream, respawns, corruptions, err, wall_ms) =
                drain(loader_with(11, batches, workers, inj));
            let stream_ok = err.is_none() && stream == reference;
            if !stream_ok {
                eprintln!(
                    "FAIL: faulted stream diverged (spec='{spec_text}', workers={workers}, \
                     err={err:?})"
                );
                failures += 1;
            }
            let want_respawns = spec_text.contains("worker-panic") as u64;
            let want_corruptions = spec_text.contains("corrupt@") as u64;
            if respawns != want_respawns || corruptions != want_corruptions {
                eprintln!(
                    "FAIL: recovery counters off (spec='{spec_text}', workers={workers}): \
                     {respawns} respawns, {corruptions} corruptions"
                );
                failures += 1;
            }
            let label = spec.map_or_else(|| "(none)".to_string(), |s| s.to_string());
            t.row(&[
                label.clone(),
                format!("{workers}"),
                format!("{respawns}"),
                format!("{corruptions}"),
                if stream_ok { "identical".into() } else { "DIVERGED".into() },
                format!("{wall_ms:.1} ms"),
            ]);
            loader_rows.push(LoaderRow {
                spec: label,
                workers,
                respawns,
                corruptions,
                stream_ok,
                wall_ms,
            });
        }
    }
    t.print();

    // ---- 2. link-fault engine sweep ----
    let steps = if check { 32u64 } else { 256 };
    println!("\n=== chaos soak: offload engine under link faults ({steps} steps) ===\n");
    let floor = match PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
        .batch(16)
        .memory_budget(1)
        .run()
    {
        Err(PlanError::BudgetBelowSpilled(e)) => e.min_device_bytes,
        other => {
            eprintln!("FAIL: 1-byte probe did not hit the spilled floor: {other:?}");
            failures += 1;
            0
        }
    };
    let spill: Option<SpillPlan> = (floor > 0)
        .then(|| {
            PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
                .batch(16)
                .memory_budget(floor)
                .run()
                .expect("floor budget plans")
                .spill
                .expect("floor budget spills")
        });
    let mut link_rows: Vec<LinkRow> = Vec::new();
    if let Some(spill) = &spill {
        let mut t = Table::new(&[
            "fail prob",
            "slowdown",
            "evict/prefetch",
            "faults",
            "retries",
            "retry stall",
        ]);
        for &(fail_prob, factor) in
            &[(0.0f64, 1.0f64), (0.05, 4.0), (0.15, 4.0), (0.3, 8.0)]
        {
            let link = LinkFaults {
                seed: 0xC0A5,
                fail_prob,
                slow: (0.3, factor),
                ..LinkFaults::default()
            };
            let run = || {
                let mut e = OffloadEngine::with_link_faults(spill, link);
                for _ in 0..steps {
                    // give-ups are the degradation under test, not failures
                    let _ = e.try_step();
                }
                e.stats()
            };
            let s = run();
            if s != run() {
                eprintln!("FAIL: link sweep not deterministic at p={fail_prob}");
                failures += 1;
            }
            if fail_prob == 0.0 && (s.link_faults != 0 || s.link_retries != 0) {
                eprintln!(
                    "FAIL: healthy link recorded {} faults / {} retries",
                    s.link_faults, s.link_retries
                );
                failures += 1;
            }
            if s.prefetches > s.evictions {
                eprintln!(
                    "FAIL: {} prefetches for {} evictions at p={fail_prob}",
                    s.prefetches, s.evictions
                );
                failures += 1;
            }
            t.row(&[
                format!("{fail_prob:.2}"),
                format!("x{factor:.0}"),
                format!("{}/{}", s.evictions, s.prefetches),
                format!("{}", s.link_faults),
                format!("{}", s.link_retries),
                format!("{:.3} ms", s.retry_stall_secs * 1e3),
            ]);
            link_rows.push(LinkRow {
                fail_prob,
                slow_factor: factor,
                steps,
                evictions: s.evictions,
                prefetches: s.prefetches,
                link_faults: s.link_faults,
                link_retries: s.link_retries,
                retry_stall_ms: s.retry_stall_secs * 1e3,
            });
        }
        t.print();
    }

    // ---- 3. degradation ladder sweep ----
    println!("\n=== chaos soak: degradation ladder vs shrinking budgets ===\n");
    let peak = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
        .batch(16)
        .run()
        .expect("unbudgeted plan stages")
        .device_peak_packed();
    let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
    let frontier = pareto_frontier(
        &arch,
        optorch::config::Pipeline::BASELINE,
        16,
        DEFAULT_FRONTIER_LEVELS,
    );
    let mut degrade_rows: Vec<DegradeRow> = Vec::new();
    let mut t = Table::new(&["budget", "met", "rungs", "device total"]);
    for pct in [100u64, 60, 30, 10, 3, 0] {
        let budget = if pct == 0 { 1 } else { peak * pct / 100 };
        let request = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .batch(16)
            .memory_budget(budget)
            .spill(false);
        let trigger = DegradeTrigger::BudgetShrink { from: Some(peak), to: budget };
        let (outcome, report) = match request.run_degraded(trigger) {
            Ok(pair) => pair,
            Err(e) => {
                eprintln!("FAIL: ladder errored at {} ({e})", fmt_bytes(budget));
                failures += 1;
                continue;
            }
        };
        match request.run_degraded(trigger) {
            Ok((_, again)) if again == report => {}
            _ => {
                eprintln!("FAIL: ladder not deterministic at {}", fmt_bytes(budget));
                failures += 1;
            }
        }
        if !frontier.iter().any(|p| p.checkpoints == outcome.plan.checkpoints) {
            eprintln!(
                "FAIL: ladder left the frontier at {} (checkpoints {:?})",
                fmt_bytes(budget),
                outcome.plan.checkpoints
            );
            failures += 1;
        }
        if pct == 100 && !report.actions.is_empty() {
            eprintln!("FAIL: full budget should not degrade, took {} rungs", report.actions.len());
            failures += 1;
        }
        t.row(&[
            format!("{pct}% = {}", fmt_bytes(budget)),
            format!("{}", report.met_budget),
            format!("{}", report.actions.len()),
            fmt_bytes(report.device_total),
        ]);
        degrade_rows.push(DegradeRow {
            budget,
            met_budget: report.met_budget,
            rungs: report.actions.len(),
            device_total: report.device_total,
            json: report.to_json().to_string(),
        });
    }
    t.print();

    match write_json(&loader_rows, &link_rows, &degrade_rows) {
        Ok(()) => println!("\nwrote BENCH_fault.json"),
        Err(e) => eprintln!("\ncould not write BENCH_fault.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: all fault-recovery invariants hold");
    }
}
