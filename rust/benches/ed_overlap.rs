//! E5 / Figure 1 + "≥20% training time" claim: the parallel encode–decode
//! loader overlaps augmentation+encoding with training.
//!
//! Measures epoch wall time with the producer inline (synchronous), on a
//! single background thread (`num_workers = 0`), and on the worker pool
//! (`num_workers ≥ 1`), against a simulated train step — then, when the
//! PJRT artifacts are available, on a real training loop. The simulated
//! rows show the overlap bound; the training rows the realized saving.

use optorch::config::{Pipeline, TrainConfig};
use optorch::coordinator::Trainer;
use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{EncodeSpec, Encoding, WordType};
use optorch::data::loader::{EdLoader, LoaderMode};
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::util::bench::Table;
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Loader-only comparison with a simulated train step of `step_ms`.
fn loader_epoch(mode: LoaderMode, batches: usize, step_ms: u64, heavy: bool) -> f64 {
    let (len, hw) = if heavy { (batches * 16, 160) } else { (batches * 16, 32) };
    let d: Arc<dyn Dataset> =
        Arc::new(SynthCifar::cifar10(Split::Train, len, 7).with_shape(hw, hw));
    let sampler = SbsSampler::uniform(
        d.as_ref(),
        16,
        AugPolicy::parse("hflip,crop4,augmix2").unwrap(),
        1,
    )
    .unwrap();
    let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::F64));
    let mut loader = EdLoader::new(d, sampler, spec, batches, mode);
    let t0 = Instant::now();
    while let Some(payload) = loader.next() {
        assert!(!payload.is_empty());
        std::thread::sleep(Duration::from_millis(step_ms)); // the "train step"
        loader.recycle(payload);
    }
    t0.elapsed().as_secs_f64()
}

fn main() -> anyhow::Result<()> {
    println!("=== E5 / Fig 1: parallel E-D overlap ===\n");

    println!("-- loader-only (simulated step, augmix-heavy producer) --");
    let mut t = Table::new(&[
        "workload",
        "sync (s)",
        "1 thread (s)",
        "pool x2 (s)",
        "pool x4 (s)",
        "best saving",
    ]);
    for (name, heavy, batches, step_ms) in
        [("CIFAR 32²", false, 40, 30u64), ("512² imagery", true, 12, 120u64)]
    {
        let sync = loader_epoch(LoaderMode::Synchronous, batches, step_ms, heavy);
        let single = loader_epoch(
            LoaderMode::Parallel { prefetch_depth: 4, num_workers: 0 },
            batches,
            step_ms,
            heavy,
        );
        let pool2 = loader_epoch(
            LoaderMode::Parallel { prefetch_depth: 4, num_workers: 2 },
            batches,
            step_ms,
            heavy,
        );
        let pool4 = loader_epoch(
            LoaderMode::Parallel { prefetch_depth: 4, num_workers: 4 },
            batches,
            step_ms,
            heavy,
        );
        let best = single.min(pool2).min(pool4);
        t.row(&[
            name.to_string(),
            format!("{sync:.2}"),
            format!("{single:.2}"),
            format!("{pool2:.2}"),
            format!("{pool4:.2}"),
            format!("{:.0}%", 100.0 * (1.0 - best / sync)),
        ]);
    }
    t.print();

    println!("\n-- full training (tiny_cnn, 2 epochs x 50 steps, real PJRT steps) --");
    let mut t = Table::new(&["loader", "wall (s)", "producer (s)", "blocked (s)"]);
    let mut trained = false;
    for (name, pipe, workers) in [
        ("synchronous (sc)", "sc", None),
        ("parallel E-D, 1 thread", "ed+sc", Some(0)),
        ("parallel E-D, pool x4", "ed+sc", Some(4)),
    ] {
        let mut cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse(pipe).unwrap());
        cfg.epochs = 2;
        cfg.train_size = 800;
        cfg.test_size = 160;
        cfg.augment = "hflip,crop4,augmix2".into();
        cfg.eval_every = 0;
        cfg.num_workers = workers;
        let rep = match Trainer::from_config(&cfg) {
            Ok(mut trainer) => trainer.run()?,
            Err(e) => {
                println!("  (skipping real-training rows: {e})");
                break;
            }
        };
        trained = true;
        t.row(&[
            name.to_string(),
            format!("{:.2}", rep.total_wall_secs),
            format!("{:.2}", rep.loader_produce_secs),
            format!("{:.2}", rep.loader_blocked_secs),
        ]);
    }
    if trained {
        t.print();
    }
    println!(
        "\npaper claim: parallel E-D cuts ≥20% of training time when the producer\n\
         (augment+encode) is a significant fraction of the step; the loader-only\n\
         rows show the overlap bound, the training rows show the realized saving."
    );
    Ok(())
}
