//! E6 / "up to 16×" claim: encode/decode correctness at capacity, honest
//! payload ratios vs f32/f64 baselines (DESIGN.md §Corrections), host-side
//! encode/decode throughput for the paper's 512×512×3 images, and the
//! producer-pool sweep: aggregate encode MB/s and steady-state allocations
//! per batch for `num_workers ∈ {0, 1, 2, 4, 8}`.
//!
//! Emits `BENCH_encode.json` so future changes can track the perf
//! trajectory (fields: single-thread MB/s per spec, and per worker count
//! the aggregate MB/s + pool allocs per steady-state batch).

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{
    decode_batch, encode_batch, encode_batch_into, EncodeSpec, EncodedBatch, Encoding, WordType,
};
use optorch::data::image::ImageBatch;
use optorch::data::loader::{EdLoader, LoaderMode};
use optorch::data::pool::BufferPool;
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::util::bench::{bench, fmt_bytes, fmt_ns, Table};
use optorch::util::rng::Rng;
use std::sync::Arc;
use std::time::Instant;

fn random_batch(n: usize, h: usize, w: usize) -> ImageBatch {
    let mut rng = Rng::new(7);
    let mut b = ImageBatch::zeros(n, h, w, 3, 10);
    for v in b.data.iter_mut() {
        *v = (rng.next_u32() & 0xff) as u8;
    }
    b
}

struct SpecRow {
    name: &'static str,
    mb_per_s: f64,
    mb_per_s_into: f64,
}

/// Single-thread encode table (the paper's E6 numbers) + the `*_into`
/// buffer-reusing variant, which shows the allocation tax the pool removes.
fn single_thread(rows: &mut Vec<SpecRow>) {
    println!("=== E6: batch encoding (Algorithms 1/3/4) ===\n");
    let specs = [
        ("base-256 / u64", EncodeSpec::new(Encoding::Base256, WordType::U64)),
        ("base-256 / f64", EncodeSpec::new(Encoding::Base256, WordType::F64)),
        ("lossless-128 / u64", EncodeSpec::new(Encoding::Lossless128, WordType::U64)),
        ("lossless-128 / f64", EncodeSpec::new(Encoding::Lossless128, WordType::F64)),
    ];

    let mut t = Table::new(&[
        "encoding",
        "capacity",
        "payload",
        "vs f32 batch",
        "vs f64 batch",
        "encode",
        "encode_into",
        "decode",
        "MB/s enc",
    ]);
    for (name, spec) in specs {
        let n = spec.capacity();
        let batch = random_batch(n, 512, 512);
        let enc = encode_batch(&batch, spec).unwrap();
        assert_eq!(decode_batch(&enc), batch, "{name} roundtrip");
        let raw_bytes = batch.data.len() as f64;
        let e_stats = bench(2, 10, || {
            let _ = encode_batch(&batch, spec).unwrap();
        });
        let mut shell = EncodedBatch::empty(spec);
        let i_stats = bench(2, 10, || {
            encode_batch_into(&batch, spec, &mut shell).unwrap();
        });
        let d_stats = bench(2, 10, || {
            let _ = decode_batch(&enc);
        });
        let mbs = raw_bytes / (e_stats.median_ns / 1e9) / 1e6;
        let mbs_into = raw_bytes / (i_stats.median_ns / 1e9) / 1e6;
        t.row(&[
            name.to_string(),
            format!("{n} imgs/word"),
            fmt_bytes(enc.payload_bytes()),
            format!("{:.1}x", enc.ratio_vs_f32()),
            format!("{:.1}x", enc.ratio_vs_f64()),
            fmt_ns(e_stats.median_ns),
            fmt_ns(i_stats.median_ns),
            fmt_ns(d_stats.median_ns),
            format!("{mbs:.0}"),
        ]);
        rows.push(SpecRow { name, mb_per_s: mbs, mb_per_s_into: mbs_into });
    }
    t.print();
}

struct SweepRow {
    num_workers: usize,
    mb_per_s: f64,
    allocs_steady_per_batch: f64,
}

/// Run one loader epoch to completion, recycling every payload; returns
/// (wall seconds, raw uint8 bytes produced).
fn run_epoch(
    seed: u64,
    batches: usize,
    hw: usize,
    mode: LoaderMode,
    pool: Arc<BufferPool>,
) -> (f64, u64) {
    let d: Arc<dyn Dataset> =
        Arc::new(SynthCifar::cifar10(Split::Train, batches * 16, 3).with_shape(hw, hw));
    let sampler = SbsSampler::uniform(d.as_ref(), 16, AugPolicy::none(), seed).unwrap();
    let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
    let mut loader = EdLoader::with_pool(d, sampler, spec, batches, mode, pool);
    let bytes_per_batch = (16 * hw * hw * 3) as u64;
    let t0 = Instant::now();
    let mut n = 0u64;
    while let Some(p) = loader.next() {
        assert!(!p.is_empty());
        loader.recycle(p);
        n += 1;
    }
    (t0.elapsed().as_secs_f64(), n * bytes_per_batch)
}

/// The producer-pool sweep: aggregate throughput of the full produce path
/// (sample + encode) per worker count, plus steady-state pool allocations.
fn worker_sweep(rows: &mut Vec<SweepRow>) {
    println!("\n=== producer-pool sweep (batch 16 @ 128², base-256/u64, recycling consumer) ===\n");
    let (batches, hw) = (48usize, 128usize);
    let mut t = Table::new(&["num_workers", "wall (s)", "aggregate MB/s", "allocs/steady batch"]);
    for workers in [0usize, 1, 2, 4, 8] {
        let mode = LoaderMode::Parallel { prefetch_depth: 4, num_workers: workers };
        let pool = Arc::new(BufferPool::default());
        // epoch 1 warms the pool; epoch 2 is the measured steady state
        let _ = run_epoch(1, batches, hw, mode, pool.clone());
        let warm_allocs = pool.allocs();
        let (secs, bytes) = run_epoch(2, batches, hw, mode, pool.clone());
        let steady_allocs = (pool.allocs() - warm_allocs) as f64 / batches as f64;
        let mbs = bytes as f64 / secs / 1e6;
        t.row(&[
            format!("{workers}"),
            format!("{secs:.2}"),
            format!("{mbs:.0}"),
            format!("{steady_allocs:.2}"),
        ]);
        rows.push(SweepRow { num_workers: workers, mb_per_s: mbs, allocs_steady_per_batch: steady_allocs });
    }
    t.print();
    let base = rows.iter().find(|r| r.num_workers == 0).map(|r| r.mb_per_s);
    if let (Some(base), Some(four)) = (base, rows.iter().find(|r| r.num_workers == 4)) {
        println!(
            "\nnum_workers=4 vs single producer: {:.2}x aggregate encode throughput \
             (target ≥ 2x on ≥4-core hosts)",
            four.mb_per_s / base
        );
    }
}

fn json_escape_free(s: &str) -> String {
    // bench names contain only [a-z0-9 /-]; keep it simple
    s.replace('"', "'")
}

fn write_json(specs: &[SpecRow], sweep: &[SweepRow]) -> std::io::Result<()> {
    let mut j = String::from("{\n  \"single_thread\": [\n");
    for (i, r) in specs.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"encoding\": \"{}\", \"mb_per_s\": {:.1}, \"mb_per_s_into\": {:.1}}}{}\n",
            json_escape_free(r.name),
            r.mb_per_s,
            r.mb_per_s_into,
            if i + 1 < specs.len() { "," } else { "" }
        ));
    }
    j.push_str("  ],\n  \"worker_sweep\": [\n");
    for (i, r) in sweep.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"num_workers\": {}, \"mb_per_s\": {:.1}, \"allocs_steady_per_batch\": {:.3}}}{}\n",
            r.num_workers,
            r.mb_per_s,
            r.allocs_steady_per_batch,
            if i + 1 < sweep.len() { "," } else { "" }
        ));
    }
    j.push_str("  ]\n}\n");
    std::fs::write("BENCH_encode.json", j)
}

fn main() {
    let mut spec_rows = Vec::new();
    let mut sweep_rows = Vec::new();
    single_thread(&mut spec_rows);
    worker_sweep(&mut sweep_rows);

    println!(
        "\npaper claim: 'save memory up-to 16X'. Honest accounting (DESIGN.md §4):\n\
         a f64 word holds 6 base-256 images exactly (53-bit mantissa), not 16;\n\
         the 16x figure only holds vs a f64-materialized batch with u64 words at\n\
         8 imgs/word → 8x, or counting the paper's own f64-vs-f64 baseline: {:.1}x.",
        encode_batch(&random_batch(6, 64, 64), EncodeSpec::new(Encoding::Base256, WordType::F64))
            .unwrap()
            .ratio_vs_f64()
    );

    match write_json(&spec_rows, &sweep_rows) {
        Ok(()) => println!("\nwrote BENCH_encode.json"),
        Err(e) => eprintln!("\ncould not write BENCH_encode.json: {e}"),
    }
}
