//! E6 / "up to 16×" claim: encode/decode correctness at capacity, honest
//! payload ratios vs f32/f64 baselines (DESIGN.md §Corrections), and
//! host-side encode/decode throughput for the paper's 512×512×3 images.

use optorch::data::encode::{
    decode_batch, encode_batch, EncodeSpec, Encoding, WordType,
};
use optorch::data::image::ImageBatch;
use optorch::util::bench::{bench, fmt_bytes, fmt_ns, Table};
use optorch::util::rng::Rng;

fn random_batch(n: usize, h: usize, w: usize) -> ImageBatch {
    let mut rng = Rng::new(7);
    let mut b = ImageBatch::zeros(n, h, w, 3, 10);
    for v in b.data.iter_mut() {
        *v = (rng.next_u32() & 0xff) as u8;
    }
    b
}

fn main() {
    println!("=== E6: batch encoding (Algorithms 1/3/4) ===\n");
    let specs = [
        ("base-256 / u64", EncodeSpec::new(Encoding::Base256, WordType::U64)),
        ("base-256 / f64", EncodeSpec::new(Encoding::Base256, WordType::F64)),
        ("lossless-128 / u64", EncodeSpec::new(Encoding::Lossless128, WordType::U64)),
        ("lossless-128 / f64", EncodeSpec::new(Encoding::Lossless128, WordType::F64)),
    ];

    let mut t = Table::new(&[
        "encoding",
        "capacity",
        "payload",
        "vs f32 batch",
        "vs f64 batch",
        "encode",
        "decode",
        "MB/s enc",
    ]);
    for (name, spec) in specs {
        let n = spec.capacity();
        let batch = random_batch(n, 512, 512);
        let enc = encode_batch(&batch, spec).unwrap();
        assert_eq!(decode_batch(&enc), batch, "{name} roundtrip");
        let raw_bytes = batch.data.len() as f64;
        let e_stats = bench(2, 10, || {
            let _ = encode_batch(&batch, spec).unwrap();
        });
        let d_stats = bench(2, 10, || {
            let _ = decode_batch(&enc);
        });
        t.row(&[
            name.to_string(),
            format!("{n} imgs/word"),
            fmt_bytes(enc.payload_bytes()),
            format!("{:.1}x", enc.ratio_vs_f32()),
            format!("{:.1}x", enc.ratio_vs_f64()),
            fmt_ns(e_stats.median_ns),
            fmt_ns(d_stats.median_ns),
            format!("{:.0}", raw_bytes / (e_stats.median_ns / 1e9) / 1e6),
        ]);
    }
    t.print();

    println!(
        "\npaper claim: 'save memory up-to 16X'. Honest accounting (DESIGN.md §4):\n\
         a f64 word holds 6 base-256 images exactly (53-bit mantissa), not 16;\n\
         the 16x figure only holds vs a f64-materialized batch with u64 words at\n\
         8 imgs/word → 8x, or counting the paper's own f64-vs-f64 baseline: {:.1}x.",
        encode_batch(&random_batch(6, 64, 64), EncodeSpec::new(Encoding::Base256, WordType::F64))
            .unwrap()
            .ratio_vs_f64()
    );
}
