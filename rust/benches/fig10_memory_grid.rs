//! E3 / paper Figure 10: memory consumption of all 12 paper models ×
//! 6 pipelines for one batch iteration (16 images @ 512×512×3), from the
//! analytic simulator. The paper's shape: M-P ≈ ½ B; S-C < ½ B on deep
//! nets; S-C+M-P ≈ ¼ B; E-D trims the input term.
//!
//! Emits `BENCH_memory.json` (model × pipeline peak bytes) alongside the
//! table, matching the `BENCH_encode.json` convention, so future memory
//! regressions are machine-checkable.

use optorch::config::Pipeline;
use optorch::memory::planner::{plan_checkpoints, PlannerKind};
use optorch::memory::simulator::simulate;
use optorch::models::{arch_by_name, paper_fig10_models};
use optorch::util::bench::Table;

fn write_json(
    batch: usize,
    pipes: &[Pipeline],
    grid: &[(String, Vec<u64>)],
) -> std::io::Result<()> {
    let mut j = format!("{{\n  \"batch\": {batch},\n  \"resolution\": 512,\n  \"grid\": [\n");
    for (i, (model, peaks)) in grid.iter().enumerate() {
        j.push_str(&format!("    {{\"model\": \"{model}\", \"peak_bytes\": {{"));
        for (k, (pipe, peak)) in pipes.iter().zip(peaks).enumerate() {
            j.push_str(&format!(
                "\"{}\": {peak}{}",
                pipe.name(),
                if k + 1 < peaks.len() { ", " } else { "" }
            ));
        }
        j.push_str(&format!("}}}}{}\n", if i + 1 < grid.len() { "," } else { "" }));
    }
    j.push_str("  ]\n}\n");
    std::fs::write("BENCH_memory.json", j)
}

fn main() {
    let batch = 16;
    println!("=== Fig 10: memory (GiB) per model x pipeline, batch 16 @ 512² ===\n");
    let pipes = Pipeline::fig10_set();
    let mut headers: Vec<String> = vec!["model".into()];
    headers.extend(pipes.iter().map(|p| p.label()));
    let hdr_refs: Vec<&str> = headers.iter().map(String::as_str).collect();
    let mut table = Table::new(&hdr_refs);
    let gib = |b: u64| format!("{:.2}", b as f64 / (1024.0 * 1024.0 * 1024.0));

    let mut grid: Vec<(String, Vec<u64>)> = Vec::new();
    for model in paper_fig10_models() {
        // EfficientNets at their native resolutions would OOM a P100 at 512²
        // too; the paper plots them all at the same workload, so we do.
        let arch = arch_by_name(&model, (512, 512, 3), 1000).unwrap();
        let mut row = vec![model.clone()];
        let mut peaks = Vec::new();
        for &pipe in &pipes {
            let ckpts = if pipe.sc {
                plan_checkpoints(&arch, PlannerKind::Optimal, pipe, batch).checkpoints
            } else {
                vec![]
            };
            let peak = simulate(&arch, pipe, batch, &ckpts).peak_bytes;
            row.push(gib(peak));
            peaks.push(peak);
        }
        table.row(&row);
        grid.push((model, peaks));
    }
    table.print();

    match write_json(batch, &pipes, &grid) {
        Ok(()) => println!("\nwrote BENCH_memory.json"),
        Err(e) => eprintln!("\ncould not write BENCH_memory.json: {e}"),
    }

    // The paper's quoted ResNet-50 row: B 2 GB, M-P 1 GB, S-C 0.8, S-C+M-P 0.4.
    let arch = arch_by_name("resnet50", (512, 512, 3), 1000).unwrap();
    let b = simulate(&arch, Pipeline::BASELINE, batch, &[]).peak_bytes as f64;
    let scplan = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, batch);
    let mp = simulate(&arch, Pipeline::parse("mp").unwrap(), batch, &[]).peak_bytes as f64;
    let sc = simulate(&arch, Pipeline::parse("sc").unwrap(), batch, &scplan.checkpoints).peak_bytes as f64;
    let scmp = simulate(&arch, Pipeline::parse("mp+sc").unwrap(), batch, &scplan.checkpoints).peak_bytes as f64;
    println!("\nresnet50 ratios vs baseline — paper: M-P 0.50, S-C 0.40, S-C+M-P 0.20");
    println!(
        "                          simulated: M-P {:.2}, S-C {:.2}, S-C+M-P {:.2}",
        mp / b,
        sc / b,
        scmp / b
    );
}
