//! E4 / paper Figure 11 + §IV recommendation: where to place checkpoints.
//!
//! Sweeps planner strategies over (a) the paper's 7-layer autoencoder
//! shape, (b) a flat 7-layer net (no bottleneck) as the contrast case,
//! and (c) the real model zoo. The paper's claims to reproduce:
//! * the optimal single checkpoint sits on the *narrow* layer;
//! * autoencoder/UNet-shaped nets checkpoint cheaper than flat nets of the
//!   same total activation volume.

use optorch::config::Pipeline;
use optorch::memory::planner::{plan_checkpoints, PlannerKind};
use optorch::memory::simulator::simulate;
use optorch::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};
use optorch::util::bench::{fmt_bytes, Table};

fn dense_net(name: &str, widths: &[usize]) -> ArchProfile {
    let layers = widths
        .iter()
        .enumerate()
        .map(|(i, &w)| LayerProfile {
            // treat width w as a 64x64 feature map with w channels so the
            // stored boundary tensor is the real layer output
            name: format!("dense{i}(w={w})"),
            kind: LayerKind::Dense,
            out_shape: (64, 64, w),
            act_elems: (3 * 64 * 64 * w) as u64,
            params: (w * 8) as u64,
            flops_per_image: (w * 128) as u64,
        })
        .collect();
    ArchProfile { name: name.into(), input: (1, 1, widths[0]), layers }
}

fn main() {
    // OPTORCH_BENCH_CHECK=1: fail the process when a reproduced claim or a
    // planner invariant breaks (the CI bench-smoke gate).
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let mut failures = 0u32;
    let batch = 16;
    // Same total activation volume, different shapes.
    let auto = dense_net("autoencoder7", &[512, 256, 64, 16, 64, 256, 512]);
    let flat_w = (512 + 256 + 64 + 16 + 64 + 256 + 512) / 7;
    let flat = dense_net("flat7", &[flat_w; 7]);

    println!("=== Fig 11: single-checkpoint placement, 7-layer nets ===\n");
    let mut t = Table::new(&["net", "planner", "checkpoint", "peak", "recompute"]);
    for arch in [&auto, &flat] {
        for kind in [PlannerKind::Uniform(1), PlannerKind::Bottleneck(1), PlannerKind::Optimal] {
            let plan = plan_checkpoints(arch, kind, Pipeline::BASELINE, batch);
            t.row(&[
                arch.name.clone(),
                format!("{kind:?}"),
                format!(
                    "{:?}",
                    plan.checkpoints
                        .iter()
                        .map(|&i| arch.layers[i].name.clone())
                        .collect::<Vec<_>>()
                ),
                fmt_bytes(plan.peak_bytes),
                format!("{:.0}%", plan.recompute_overhead * 100.0),
            ]);
        }
    }
    t.print();

    // Figure 11 proper: the same single-checkpoint schedule anchored at the
    // narrow middle (C2 = w16) vs anchored on a wide layer.
    let narrow = simulate(&auto, Pipeline::parse("sc").unwrap(), batch, &[3]);
    let wide = simulate(&auto, Pipeline::parse("sc").unwrap(), batch, &[1]);
    println!(
        "\nsingle checkpoint at the w=16 bottleneck: {} peak; at the w=256 encoder\n\
         layer: {} peak — the paper's 'checkpoint the narrow middle' recommendation: {}",
        fmt_bytes(narrow.peak_bytes),
        fmt_bytes(wide.peak_bytes),
        if narrow.peak_bytes < wide.peak_bytes { "HOLDS" } else { "VIOLATED" }
    );
    if narrow.peak_bytes >= wide.peak_bytes {
        failures += 1;
    }

    println!("\n=== checkpoint-count sweep (resnet50 @ 512², batch 16) ===\n");
    let arch = arch_by_name("resnet50", (512, 512, 3), 1000).unwrap();
    let base = simulate(&arch, Pipeline::BASELINE, batch, &[]).peak_bytes;
    let mut t = Table::new(&["k checkpoints", "peak", "vs baseline", "recompute overhead"]);
    for k in [1, 2, 4, 6, 8, 12] {
        let plan = plan_checkpoints(&arch, PlannerKind::Uniform(k), Pipeline::BASELINE, batch);
        t.row(&[
            format!("{k}"),
            fmt_bytes(plan.peak_bytes),
            format!("{:.2}x", base as f64 / plan.peak_bytes as f64),
            format!("{:.0}%", plan.recompute_overhead * 100.0),
        ]);
    }
    let opt = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, batch);
    t.row(&[
        format!("optimal ({})", opt.checkpoints.len()),
        fmt_bytes(opt.peak_bytes),
        format!("{:.2}x", base as f64 / opt.peak_bytes as f64),
        format!("{:.0}%", opt.recompute_overhead * 100.0),
    ]);
    t.print();

    // The exact DP must never lose to the uniform sweep it is printed under.
    for k in [1, 2, 4, 6, 8, 12] {
        let u = plan_checkpoints(&arch, PlannerKind::Uniform(k), Pipeline::BASELINE, batch);
        if opt.peak_bytes > u.peak_bytes {
            eprintln!("FAIL: optimal {} worse than uniform{k} {}", opt.peak_bytes, u.peak_bytes);
            failures += 1;
        }
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        if check {
            std::process::exit(1);
        }
    } else if check {
        println!("\ncheck mode: all Fig-11 invariants hold");
    }
}
