//! E1 / paper Figure 8: GPU-memory usage across one training iteration of
//! ResNet-18 (batch 16 @ 512×512×3) for the optimization pipelines.
//!
//! Regenerates the figure's series from the analytic memory simulator
//! (DESIGN.md §5): prints peak per pipeline plus the live-byte timeline
//! CSV for baseline vs S-C. The paper's shape to reproduce: baseline
//! ≈ 7000 MB vs sequential-checkpoints ≈ 2000 MB (a ≥2× gap; we report
//! the exact simulated ratio).

use optorch::config::Pipeline;
use optorch::memory::planner::{plan_checkpoints, PlannerKind};
use optorch::memory::simulator::simulate;
use optorch::models::arch_by_name;
use optorch::util::bench::{fmt_bytes, Table};

fn main() {
    let batch = 16;
    let arch = arch_by_name("resnet18", (512, 512, 3), 1000).unwrap();
    println!("=== Fig 8: ResNet-18, 1 iteration, batch 16 @ 512x512x3 ===\n");

    let mut table = Table::new(&["pipeline", "peak", "vs baseline"]);
    let base_peak = simulate(&arch, Pipeline::BASELINE, batch, &[]).peak_bytes;
    for pipe in Pipeline::fig10_set() {
        let ckpts = if pipe.sc {
            plan_checkpoints(&arch, PlannerKind::Optimal, pipe, batch).checkpoints
        } else {
            vec![]
        };
        let rep = simulate(&arch, pipe, batch, &ckpts);
        table.row(&[
            pipe.label(),
            fmt_bytes(rep.peak_bytes),
            format!("{:.2}x", base_peak as f64 / rep.peak_bytes as f64),
        ]);
    }
    table.print();

    // The timeline series itself (what the paper plots on the x-axis).
    println!("\n--- timeline CSV (baseline) ---");
    let rep = simulate(&arch, Pipeline::BASELINE, batch, &[]);
    print!("{}", optorch::coordinator::report::timeline_csv(&rep));
    println!("--- timeline CSV (S-C, optimal plan) ---");
    let plan = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, batch);
    let rep = simulate(&arch, Pipeline::parse("sc").unwrap(), batch, &plan.checkpoints);
    print!("{}", optorch::coordinator::report::timeline_csv(&rep));

    let sc_peak = rep.peak_bytes;
    println!(
        "\npaper: 7000 MB -> 2000 MB (3.5x); simulated: {} -> {} ({:.2}x)",
        fmt_bytes(base_peak),
        fmt_bytes(sc_peak),
        base_peak as f64 / sc_peak as f64
    );
}
