//! E2 / paper Figure 9: accuracy vs training time for the model zoo under
//! each optimization pipeline — REAL end-to-end training through the
//! three-layer stack on synthetic CIFAR-10.
//!
//! Default grid is scaled for CI wall-time (tiny_cnn full pipeline grid +
//! resnet_mini18 headline pipelines, 2 epochs × 40 steps). Set
//! `OPTORCH_FIG9_FULL=1` for the full grid (4 models × 6 pipelines,
//! 3 epochs × 125 steps — tens of minutes).
//!
//! The paper's shape to reproduce: all pipelines reach ≈ equal accuracy;
//! S-C costs extra time; E-D + S-C recovers it; M-P combinations are the
//! fastest.

use optorch::config::{Pipeline, TrainConfig};
use optorch::coordinator::{report, Trainer};
use optorch::util::bench::Table;

fn run_cell(model: &str, pipe: Pipeline, epochs: usize, steps: usize) -> anyhow::Result<(f64, f64, f64)> {
    let mut cfg = TrainConfig::default_for(model, pipe);
    cfg.epochs = epochs;
    cfg.train_size = steps * cfg.batch_size;
    cfg.test_size = 256;
    cfg.max_batches_per_epoch = steps;
    let rep = Trainer::from_config(&cfg)?.run()?;
    let row = report::fig9_row(&rep);
    eprint!("  {row}");
    Ok((rep.total_wall_secs, rep.final_eval_accuracy, rep.loader_produce_secs))
}

fn main() -> anyhow::Result<()> {
    let full = std::env::var("OPTORCH_FIG9_FULL").is_ok();
    let (models, pipes, epochs, steps): (Vec<&str>, Vec<&str>, usize, usize) = if full {
        (
            vec!["tiny_cnn", "resnet_mini18", "effnet_lite", "inception_lite"],
            vec!["b", "ed", "mp", "sc", "ed+sc", "ed+mp+sc"],
            3,
            125,
        )
    } else {
        (
            vec!["tiny_cnn", "inception_lite"],
            vec!["b", "ed", "mp", "sc", "ed+sc", "ed+mp+sc"],
            2,
            40,
        )
    };
    println!(
        "=== Fig 9: accuracy vs time ({} epochs x {} steps, batch 16, synthetic CIFAR-10) ===\n",
        epochs, steps
    );
    let mut table = Table::new(&["model", "pipeline", "time (s)", "eval acc", "Δacc vs B", "time vs B"]);
    for model in &models {
        let mut base: Option<(f64, f64)> = None;
        for pipe in &pipes {
            let p = Pipeline::parse(pipe).unwrap();
            let (t, a, _) = match run_cell(model, p, epochs, steps) {
                Ok(cell) => cell,
                Err(e) => {
                    // no PJRT backend / artifacts in this environment
                    println!("(skipping Fig 9 grid: {e})");
                    return Ok(());
                }
            };
            let (bt, ba) = *base.get_or_insert((t, a));
            table.row(&[
                model.to_string(),
                p.label(),
                format!("{t:.1}"),
                format!("{a:.3}"),
                format!("{:+.3}", a - ba),
                format!("{:.2}x", t / bt),
            ]);
        }
    }
    table.print();
    println!(
        "\npaper shape: equal accuracy everywhere; S-C ≈1.16x time (resnet50: 3800→4400 s);\n\
         E-D+S-C ≈ baseline time at far lower memory; M-P fastest."
    );
    Ok(())
}
