//! Joint recompute/spill planner benchmark: sequential plan→spill vs the
//! joint optimizer across arch × budget × host-bandwidth, param-gradient
//! offload included.
//!
//! Emits `BENCH_joint.json`. `OPTORCH_BENCH_CHECK=1` runs the same sweep
//! and *fails the process* when the dominance contract breaks:
//!
//! * joint predicted step time worse than sequential at any point where
//!   both are feasible;
//! * joint infeasible at a point sequential satisfies;
//! * no strict joint win on the parameter-heavy profile at ≤ 60% budget;
//! * no point where sequential is infeasible but param-gradient offload
//!   makes the budget reachable;
//! * a "fitting" joint plan whose device total exceeds its budget.
//!
//! Both sides run the same cost models — the sequential column is
//! `select_for_budget`, the joint column `plan_joint` — so every gap in
//! the table is planning quality, not simulator drift.

use optorch::config::Pipeline;
use optorch::memory::joint::plan_joint;
use optorch::memory::offload::{select_for_budget, OverlapModel, SpillClass};
use optorch::memory::pipeline::PlanRequest;
use optorch::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};
use optorch::util::bench::{fmt_bytes, Table};

/// Checkpoint-heavy uniform chain (same family as `offload_overlap`'s
/// sweep): Σ boundary outputs dominates any single backward working set.
fn spill_chain(depth: usize) -> ArchProfile {
    let widths = [64usize, 72, 80, 88];
    let layers = (0..depth)
        .map(|i| {
            let c = widths[i % widths.len()];
            let out = (8 * 8 * c) as u64;
            LayerProfile {
                name: format!("l{i}"),
                kind: LayerKind::Conv,
                out_shape: (8, 8, c),
                act_elems: out * 2,
                params: (c * 9) as u64,
                flops_per_image: c as u64 * 50_000,
            }
        })
        .collect();
    ArchProfile { name: format!("spill_chain{depth}"), input: (8, 8, 3), layers }
}

/// Parameter-heavy chain: state + resident gradients alone are ~69% of
/// the all-stored packed total, so no amount of checkpoint spilling
/// reaches a 60% budget — but evicted param-gradients leave the slab for
/// good, putting the joint floor near 50%. The profile is sized so the
/// 60% sweep point falls squarely between the two floors.
fn param_heavy_chain(depth: usize) -> ArchProfile {
    let layers = (0..depth)
        .map(|i| {
            let out = (8 * 8 * 64) as u64;
            LayerProfile {
                name: format!("fc{i}"),
                kind: LayerKind::Dense,
                out_shape: (8, 8, 64),
                act_elems: out * 2,
                // grad bytes ≈ 0.4× a boundary output at batch 16
                params: 26_000,
                flops_per_image: 2_000_000,
            }
        })
        .collect();
    ArchProfile { name: format!("fc_chain{depth}"), input: (8, 8, 3), layers }
}

struct SweepRow {
    arch: String,
    budget_pct: u64,
    host_bw: u64,
    seq_feasible: bool,
    joint_feasible: bool,
    seq_step_ms: f64,
    joint_step_ms: f64,
    joint_grad_spills: usize,
    joint_device_total: u64,
    speedup_pct: f64,
}

fn write_json(rows: &[SweepRow]) -> std::io::Result<()> {
    let mut j = String::from("{\n  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"arch\": \"{}\", \"budget_pct\": {}, \"host_bw\": {}, \
             \"seq_feasible\": {}, \"joint_feasible\": {}, \
             \"seq_step_ms\": {:.4}, \"joint_step_ms\": {:.4}, \
             \"joint_grad_spills\": {}, \"joint_device_total\": {}, \
             \"speedup_pct\": {:.2}}}{}\n",
            r.arch,
            r.budget_pct,
            r.host_bw,
            r.seq_feasible,
            r.joint_feasible,
            r.seq_step_ms,
            r.joint_step_ms,
            r.joint_grad_spills,
            r.joint_device_total,
            r.speedup_pct,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    j.push_str("  ]\n}\n");
    std::fs::write("BENCH_joint.json", j)
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let mut failures = 0u32;
    let batch = 16usize;
    let lookahead = 2usize;
    let sc = Pipeline::parse("sc").unwrap();

    println!("=== joint vs sequential: predicted step time under budget (batch {batch}) ===\n");
    let archs = [
        spill_chain(24),
        param_heavy_chain(40),
        arch_by_name("resnet18", (64, 64, 3), 10).unwrap(),
    ];
    let mut rows: Vec<SweepRow> = Vec::new();
    let mut t = Table::new(&[
        "arch",
        "budget",
        "host bw",
        "sequential step",
        "joint step",
        "grad spills",
        "verdict",
    ]);
    let mut strict_param_heavy_win = false;
    let mut grad_spill_rescue = false;
    for arch in &archs {
        // The reference total every budget fraction scales from: the
        // packed all-stored layout (the most checkpoint-rich frontier
        // point), staged once through the facade.
        let full_total = PlanRequest::for_arch(arch.clone())
            .pipeline(sc)
            .batch(batch)
            .with_checkpoints((0..arch.layers.len().saturating_sub(1)).collect())
            .run()
            .expect("all-stored plan packs")
            .device_peak_packed();
        for pct in [90u64, 75, 60, 45, 30] {
            let budget = full_total * pct / 100;
            for bw_gib in [4u64, 12, 32] {
                let host_bw = bw_gib * (1 << 30);
                let model = OverlapModel {
                    host_bw_bytes_per_sec: host_bw as f64,
                    ..OverlapModel::default()
                };
                let seq = select_for_budget(arch, sc, batch, budget, lookahead, &model);
                let joint = plan_joint(arch, sc, batch, budget, lookahead, &model, true);
                let (seq_ms, seq_ok) = match &seq {
                    Ok(d) => (d.overlap.predicted_step_secs * 1e3, true),
                    Err(_) => (0.0, false),
                };
                let (joint_ms, joint_ok, grad_spills, device_total) = match &joint {
                    Ok(d) => (
                        d.overlap.predicted_step_secs * 1e3,
                        true,
                        d.spill
                            .steps
                            .iter()
                            .filter(|s| s.class == SpillClass::ParamGrad)
                            .count(),
                        d.spill.device_total(),
                    ),
                    Err(e) => (0.0, false, 0, e.min_device_bytes),
                };
                if seq_ok && !joint_ok {
                    eprintln!(
                        "FAIL {}: joint infeasible at {pct}% where sequential fits",
                        arch.name
                    );
                    failures += 1;
                }
                if joint_ok && device_total > budget {
                    eprintln!(
                        "FAIL {}: 'fitting' joint plan at {device_total} exceeds its \
                         budget {budget}",
                        arch.name
                    );
                    failures += 1;
                }
                if seq_ok && joint_ok && joint_ms > seq_ms {
                    eprintln!(
                        "FAIL {}: joint {joint_ms:.4} ms > sequential {seq_ms:.4} ms \
                         at {pct}% / {bw_gib} GiB/s",
                        arch.name
                    );
                    failures += 1;
                }
                // "strictly better" at a tight budget: a faster step where
                // both fit, or a budget only the joint planner reaches.
                if pct <= 60
                    && arch.name.starts_with("fc_chain")
                    && joint_ok
                    && (!seq_ok || joint_ms < seq_ms - 1e-9)
                {
                    strict_param_heavy_win = true;
                }
                if !seq_ok && joint_ok && grad_spills > 0 {
                    grad_spill_rescue = true;
                }
                let verdict = match (seq_ok, joint_ok) {
                    (true, true) if joint_ms < seq_ms - 1e-9 => {
                        format!("joint -{:.1}%", (1.0 - joint_ms / seq_ms) * 100.0)
                    }
                    (true, true) => "tie".to_string(),
                    (false, true) => "joint only".to_string(),
                    (true, false) => "SEQ ONLY (bug)".to_string(),
                    (false, false) => "both infeasible".to_string(),
                };
                t.row(&[
                    arch.name.clone(),
                    format!("{pct}% = {}", fmt_bytes(budget)),
                    format!("{bw_gib} GiB/s"),
                    if seq_ok { format!("{seq_ms:.3} ms") } else { "infeasible".into() },
                    if joint_ok { format!("{joint_ms:.3} ms") } else { "infeasible".into() },
                    format!("{grad_spills}"),
                    verdict,
                ]);
                rows.push(SweepRow {
                    arch: arch.name.clone(),
                    budget_pct: pct,
                    host_bw,
                    seq_feasible: seq_ok,
                    joint_feasible: joint_ok,
                    seq_step_ms: seq_ms,
                    joint_step_ms: joint_ms,
                    joint_grad_spills: grad_spills,
                    joint_device_total: device_total,
                    speedup_pct: if seq_ok && joint_ok && seq_ms > 0.0 {
                        (1.0 - joint_ms / seq_ms) * 100.0
                    } else {
                        0.0
                    },
                });
            }
        }
    }
    t.print();

    // Derived floors on the parameter-heavy profile: the smallest device
    // total each planner can reach (feasibility is pack-based, so the
    // probe is bandwidth-independent). One extra row pins the budget just
    // below the sequential floor — the rescue the unit tests prove.
    {
        let arch = &archs[1];
        let model = OverlapModel::default();
        let full_total = PlanRequest::for_arch(arch.clone())
            .pipeline(sc)
            .batch(batch)
            .with_checkpoints((0..arch.layers.len() - 1).collect())
            .run()
            .expect("all-stored plan packs")
            .device_peak_packed();
        let seq_floor = select_for_budget(arch, sc, batch, 1, lookahead, &model)
            .expect_err("1-byte budget cannot be feasible")
            .min_device_bytes;
        let joint_floor = plan_joint(arch, sc, batch, 1, lookahead, &model, true)
            .expect_err("1-byte budget cannot be feasible")
            .min_device_bytes;
        println!(
            "\n{}: all-stored total {}, sequential floor {} ({}%), joint floor {} ({}%)",
            arch.name,
            fmt_bytes(full_total),
            fmt_bytes(seq_floor),
            seq_floor * 100 / full_total,
            fmt_bytes(joint_floor),
            joint_floor * 100 / full_total,
        );
        if joint_floor >= seq_floor {
            eprintln!(
                "FAIL {}: joint floor {joint_floor} not below the sequential \
                 floor {seq_floor}",
                arch.name
            );
            failures += 1;
        }
        let budget = seq_floor - 1;
        match plan_joint(arch, sc, batch, budget, lookahead, &model, true) {
            Ok(d) => {
                let grad_spills = d
                    .spill
                    .steps
                    .iter()
                    .filter(|s| s.class == SpillClass::ParamGrad)
                    .count();
                if grad_spills == 0 {
                    eprintln!(
                        "FAIL {}: sub-sequential-floor budget met without \
                         param-gradient spills",
                        arch.name
                    );
                    failures += 1;
                }
                grad_spill_rescue = true;
                rows.push(SweepRow {
                    arch: arch.name.clone(),
                    budget_pct: budget * 100 / full_total,
                    host_bw: model.host_bw_bytes_per_sec as u64,
                    seq_feasible: false,
                    joint_feasible: true,
                    seq_step_ms: 0.0,
                    joint_step_ms: d.overlap.predicted_step_secs * 1e3,
                    joint_grad_spills: grad_spills,
                    joint_device_total: d.spill.device_total(),
                    speedup_pct: 0.0,
                });
            }
            Err(e) => {
                eprintln!(
                    "FAIL {}: budget {budget} just below the sequential floor is \
                     joint-infeasible (joint floor {})",
                    arch.name, e.min_device_bytes
                );
                failures += 1;
            }
        }
    }

    // The two headline acceptance scenarios must show up in the sweep.
    if !strict_param_heavy_win {
        eprintln!("FAIL: no strict joint win on the parameter-heavy profile at ≤ 60% budget");
        failures += 1;
    }
    if !grad_spill_rescue {
        eprintln!(
            "FAIL: no sweep point where param-gradient offload rescues a budget \
             sequential reports infeasible"
        );
        failures += 1;
    }

    let wins = rows.iter().filter(|r| r.speedup_pct > 0.01).count();
    let mut rescues = 0usize;
    for r in &rows {
        if r.joint_feasible && !r.seq_feasible {
            rescues += 1;
        }
    }
    println!(
        "\n{} sweep points: {wins} strict joint wins, {rescues} joint-only \
         (sequential infeasible) points",
        rows.len()
    );

    match write_json(&rows) {
        Ok(()) => println!("\nwrote BENCH_joint.json"),
        Err(e) => eprintln!("\ncould not write BENCH_joint.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: joint dominance holds at every sweep point");
    }
}
