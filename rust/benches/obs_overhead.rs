//! Metrics overhead: the cost of live-metrics sampling on the train
//! loop's hot path, metered vs unmetered.
//!
//! Two measurements:
//!
//! 1. **baseline** — drain the E-D pool loader with no metrics at all
//!    (the pre-observability hot path);
//! 2. **metered** — the same drain with the trainer's per-step sampling:
//!    one `StepSample` built from live gauges (loader queue depth, step
//!    wall time) and pushed through `MetricsHub::record_step` per batch.
//!
//! Wall time per run is the minimum over several trials (the minimum
//! tracks the true cost, the rest is scheduler noise). A per-sample
//! microbench (spin on `record_step` against a full ring, so it also
//! exercises the drop path) and a `/metrics` render microbench ride
//! along for the absolute numbers.
//!
//! Emits `BENCH_obs.json`. `OPTORCH_BENCH_CHECK=1` runs a fast smoke
//! pass that *fails the process* (exit 1) when enabled-metrics overhead
//! reaches 5%.

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{EncodeSpec, Encoding, WordType};
use optorch::data::loader::{EdLoader, LoaderMode};
use optorch::data::pool::BufferPool;
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::obs::{MetricsHub, StepSample};
use optorch::util::bench::Table;
use std::sync::Arc;
use std::time::Instant;

fn loader(batches: usize, workers: usize) -> EdLoader {
    let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 240, 9));
    let sampler = SbsSampler::uniform(
        d.as_ref(),
        16,
        AugPolicy::parse("hflip,crop4").unwrap(),
        11,
    )
    .unwrap();
    let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::F64));
    let mode = LoaderMode::Parallel { prefetch_depth: 2, num_workers: workers };
    let pool = Arc::new(BufferPool::default());
    EdLoader::with_faults(d, sampler, spec, batches, mode, pool, None, None)
}

/// Drain one loader; wall seconds (consumer side, batch count asserted).
/// With a hub, every batch pays the trainer's full sampling cost: read
/// the live gauges, build the `StepSample`, `record_step`.
fn drain_secs(mut l: EdLoader, batches: usize, hub: Option<&MetricsHub>) -> f64 {
    let stats = l.stats();
    let start = Instant::now();
    let mut n = 0usize;
    let mut step_start = Instant::now();
    loop {
        match l.try_next() {
            Ok(Some(p)) => {
                n += 1;
                l.recycle(p);
                if let Some(hub) = hub {
                    let step_secs = step_start.elapsed().as_secs_f64();
                    hub.record_step(StepSample {
                        step: n as u64 - 1,
                        slab_high_water_bytes: 48 << 20,
                        host_resident_bytes: 4 << 20,
                        scratch_used_bytes: 4096,
                        scratch_high_water_bytes: 8192,
                        link_retry_backlog: 0,
                        loader_queue_depth: stats.queue_depth(),
                        degrade_rung: 0,
                        step_secs,
                    });
                    step_start = Instant::now();
                }
            }
            Ok(None) => break,
            Err(e) => panic!("loader errored mid-bench: {e}"),
        }
    }
    assert_eq!(n, batches, "short stream");
    start.elapsed().as_secs_f64()
}

/// Minimum wall seconds across `trials` fresh loaders.
fn best_of(
    trials: usize,
    batches: usize,
    workers: usize,
    make: impl Fn() -> Option<MetricsHub>,
) -> f64 {
    (0..trials)
        .map(|_| {
            let hub = make();
            drain_secs(loader(batches, workers), batches, hub.as_ref())
        })
        .fold(f64::INFINITY, f64::min)
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let mut failures = 0u32;
    let (batches, trials) = if check { (16, 3) } else { (32, 3) };
    let workers = 2;

    println!("=== metrics overhead: E-D pool loader ({batches} batches, {workers} workers, best of {trials}) ===\n");

    let baseline = best_of(trials, batches, workers, || None);
    let metered = best_of(trials, batches, workers, || Some(MetricsHub::new()));
    let metered_pct = (metered / baseline - 1.0) * 100.0;

    let mut t = Table::new(&["variant", "wall", "overhead"]);
    t.row(&["baseline (no metrics)".into(), format!("{:.1} ms", baseline * 1e3), "—".into()]);
    t.row(&[
        "metrics enabled".into(),
        format!("{:.1} ms", metered * 1e3),
        format!("{metered_pct:+.2}%"),
    ]);
    t.print();

    // ---- per-sample microbench ----
    // A small ring keeps the spin in the steady state a long run reaches
    // (ring full, every push takes the drop-and-count path too).
    let spins: u64 = if check { 100_000 } else { 400_000 };
    let hub = MetricsHub::with_capacity(256);
    let start = Instant::now();
    for i in 0..spins {
        hub.record_step(StepSample {
            step: i,
            slab_high_water_bytes: 48 << 20,
            host_resident_bytes: 4 << 20,
            scratch_used_bytes: 4096,
            scratch_high_water_bytes: 8192,
            link_retry_backlog: 1,
            loader_queue_depth: 2,
            degrade_rung: 0,
            step_secs: 0.004,
        });
    }
    let ns_per_sample = start.elapsed().as_nanos() as f64 / spins as f64;
    let recorded = hub.steps();
    let dropped = hub.dropped();

    // ---- scrape-render microbench ----
    let renders: u64 = if check { 2_000 } else { 10_000 };
    let start = Instant::now();
    let mut exposition_len = 0usize;
    for _ in 0..renders {
        exposition_len = hub.prometheus_text().len();
    }
    let us_per_scrape = start.elapsed().as_micros() as f64 / renders as f64;

    println!(
        "\nper sample (record_step, ring full): {ns_per_sample:.0} ns; \
         per scrape (prometheus_text, {exposition_len} B): {us_per_scrape:.1} µs"
    );

    // ---- invariants ----
    if !(metered_pct < 5.0) {
        eprintln!("FAIL: enabled-metrics overhead {metered_pct:.2}% (gate < 5%)");
        failures += 1;
    }
    if recorded != spins {
        eprintln!("FAIL: hub counted {recorded} of {spins} samples");
        failures += 1;
    }
    if dropped != spins - 256 {
        eprintln!("FAIL: full ring dropped {dropped}, expected {}", spins - 256);
        failures += 1;
    }
    if !(ns_per_sample < 10_000.0) {
        eprintln!("FAIL: {ns_per_sample:.0} ns per sample (sanity gate < 10 µs)");
        failures += 1;
    }
    if exposition_len == 0 {
        eprintln!("FAIL: empty /metrics exposition");
        failures += 1;
    }

    let json = format!(
        "{{\n  \"batches\": {batches},\n  \"workers\": {workers},\n  \"trials\": {trials},\n  \
         \"baseline_ms\": {:.3},\n  \"metered_ms\": {:.3},\n  \
         \"overhead_pct\": {metered_pct:.3},\n  \
         \"ns_per_sample\": {ns_per_sample:.1},\n  \
         \"us_per_scrape\": {us_per_scrape:.2},\n  \
         \"exposition_bytes\": {exposition_len}\n}}\n",
        baseline * 1e3,
        metered * 1e3,
    );
    match std::fs::write("BENCH_obs.json", json) {
        Ok(()) => println!("\nwrote BENCH_obs.json"),
        Err(e) => eprintln!("\ncould not write BENCH_obs.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: metrics overhead within gates");
    }
}
