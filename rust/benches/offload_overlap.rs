//! Host-spill offload benchmark: predicted stall vs device budget at
//! several host-bandwidth settings, plus the runtime engine's host-pool
//! recycle behavior and the per-worker staging-scratch audit.
//!
//! Emits `BENCH_offload.json`. `OPTORCH_BENCH_CHECK=1` runs a fast smoke
//! pass that *fails the process* when an invariant breaks: a "fitting"
//! spill plan whose resident total exceeds its budget, a prefetch issued
//! at or after its need step, a 60%-of-cheapest-point budget that the
//! planner cannot satisfy on the checkpoint-heavy chain profile, host-pool
//! steady-state allocations, or worker staging scratch (label rows *and*
//! the `Dataset::get_into` fetch path) falling back to the heap (counted
//! by the same global-allocator shim as `arena_packing`).
//!
//! All planning flows through the `PlanRequest` facade: the frontier and
//! its packed totals come from one staged run per arch, and each sweep
//! point is a budgeted run over the explicit most-checkpoint-rich plan.

use optorch::data::dataset::Dataset;
use optorch::data::image::Image;
use optorch::data::synth::{Split, SynthCifar};
use optorch::memory::arena::{validate, ArenaAllocator};
use optorch::memory::offload::{OffloadEngine, SpillPlan};
use optorch::memory::pipeline::{PlanError, PlanRequest};
use optorch::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};
use optorch::util::bench::{bench, fmt_bytes, fmt_ns, Table};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; only adds a counter.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// Checkpoint-heavy uniform chain (same family as `arena_packing`'s
/// synthetic sweep): Σ boundary outputs dominates any single backward
/// working set, the regime where host spilling has real headroom.
fn spill_chain(depth: usize) -> ArchProfile {
    let widths = [64usize, 72, 80, 88];
    let layers = (0..depth)
        .map(|i| {
            let c = widths[i % widths.len()];
            let out = (8 * 8 * c) as u64;
            LayerProfile {
                name: format!("l{i}"),
                kind: LayerKind::Conv,
                out_shape: (8, 8, c),
                act_elems: out * 2,
                params: (c * 9) as u64,
                flops_per_image: c as u64 * 50_000,
            }
        })
        .collect();
    ArchProfile { name: format!("spill_chain{depth}"), input: (8, 8, 3), layers }
}

struct SweepRow {
    arch: String,
    budget_pct: u64,
    host_bw: u64,
    feasible: bool,
    spilled_tensors: usize,
    spilled_bytes: u64,
    device_total: u64,
    stall_ms: f64,
    step_ms: f64,
}

fn write_json(rows: &[SweepRow], pool: &PoolRow) -> std::io::Result<()> {
    let mut j = String::from("{\n  \"sweep\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"arch\": \"{}\", \"budget_pct\": {}, \"host_bw\": {}, \
             \"feasible\": {}, \"spilled_tensors\": {}, \"spilled_bytes\": {}, \
             \"device_total\": {}, \"stall_ms\": {:.4}, \"step_ms\": {:.4}}}{}\n",
            r.arch,
            r.budget_pct,
            r.host_bw,
            r.feasible,
            r.spilled_tensors,
            r.spilled_bytes,
            r.device_total,
            r.stall_ms,
            r.step_ms,
            if i + 1 < rows.len() { "," } else { "" }
        ));
    }
    j.push_str(&format!(
        "  ],\n  \"host_pool\": {{\"steps\": {}, \"hit_rate\": {:.4}, \
         \"steady_allocs\": {}, \"step_ns\": {:.0}}},\n",
        pool.steps, pool.hit_rate, pool.steady_allocs, pool.step_ns
    ));
    j.push_str(&format!(
        "  \"worker_scratch\": {{\"steady_allocs\": {}, \"fallbacks\": {}}}\n}}\n",
        pool.scratch_steady_allocs, pool.scratch_fallbacks
    ));
    std::fs::write("BENCH_offload.json", j)
}

struct PoolRow {
    steps: u64,
    hit_rate: f64,
    steady_allocs: u64,
    step_ns: f64,
    scratch_steady_allocs: u64,
    scratch_fallbacks: u64,
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let mut failures = 0u32;
    let batch = 16usize;
    let lookahead = 2usize;

    // ---- stall vs budget sweep at several host bandwidths ----
    println!("=== host-spill: predicted stall vs device budget (batch {batch}) ===\n");
    let mut rows: Vec<SweepRow> = Vec::new();
    let mut t = Table::new(&[
        "arch",
        "budget",
        "host bw",
        "spilled",
        "device total",
        "stall / step",
    ]);
    // One facade run per arch stages the frontier *and* a packed total
    // per point ("cheapest point" = the smallest packed total — budgets
    // below it are unreachable by pure recompute); the most
    // checkpoint-rich (last) point is the spill sweep's raw input.
    let archs: Vec<(ArchProfile, Vec<usize>, u64)> =
        [spill_chain(48), arch_by_name("resnet18", (64, 64, 3), 10).unwrap()]
            .into_iter()
            .map(|arch| {
                let staged = PlanRequest::for_arch(arch.clone())
                    .batch(batch)
                    .frontier(true)
                    .run()
                    .expect("frontier stages");
                let totals = staged.frontier_packed_totals.expect("arena on by default");
                let cheapest_total = *totals.iter().min().unwrap();
                let full = staged
                    .frontier
                    .expect("frontier requested")
                    .last()
                    .unwrap()
                    .checkpoints
                    .clone();
                (arch, full, cheapest_total)
            })
            .collect();
    for (arch, full, cheapest_total) in &archs {
        for pct in [90u64, 75, 60, 45] {
            let budget = cheapest_total * pct / 100;
            for bw_gib in [4u64, 12, 32] {
                let host_bw = bw_gib * (1 << 30);
                let outcome = PlanRequest::for_arch(arch.clone())
                    .batch(batch)
                    .with_checkpoints(full.clone())
                    .memory_budget(budget)
                    .host_bw(host_bw)
                    .spill_lookahead(lookahead)
                    .run();
                match outcome {
                    Ok(outcome) => {
                        let spill = outcome.spill.as_ref().expect("budgeted outcome");
                        if spill.device_total() > budget {
                            eprintln!(
                                "FAIL {}: 'fitting' plan at {} exceeds its budget {}",
                                arch.name,
                                spill.device_total(),
                                budget
                            );
                            failures += 1;
                        }
                        if let Err(e) = validate(&spill.lifetimes, &spill.layout) {
                            eprintln!("FAIL {}: resident layout invalid: {e}", arch.name);
                            failures += 1;
                        }
                        for s in &spill.steps {
                            if s.prefetch_step >= s.need_step {
                                eprintln!("FAIL {}: prefetch at/after need: {s:?}", arch.name);
                                failures += 1;
                            }
                        }
                        let rep = outcome.overlap.as_ref().expect("budgeted outcome");
                        t.row(&[
                            arch.name.clone(),
                            format!("{pct}% = {}", fmt_bytes(budget)),
                            format!("{bw_gib} GiB/s"),
                            format!(
                                "{} ({})",
                                spill.steps.len(),
                                fmt_bytes(spill.spilled_bytes)
                            ),
                            fmt_bytes(spill.device_total()),
                            format!(
                                "{:.3} / {:.3} ms",
                                rep.stall_secs * 1e3,
                                rep.predicted_step_secs * 1e3
                            ),
                        ]);
                        rows.push(SweepRow {
                            arch: arch.name.clone(),
                            budget_pct: pct,
                            host_bw,
                            feasible: true,
                            spilled_tensors: spill.steps.len(),
                            spilled_bytes: spill.spilled_bytes,
                            device_total: spill.device_total(),
                            stall_ms: rep.stall_secs * 1e3,
                            step_ms: rep.predicted_step_secs * 1e3,
                        });
                    }
                    Err(PlanError::BudgetBelowSpilled(e)) => {
                        if e.min_device_bytes <= budget {
                            eprintln!(
                                "FAIL {}: infeasibility floor {} not above budget {}",
                                arch.name, e.min_device_bytes, budget
                            );
                            failures += 1;
                        }
                        if arch.name.starts_with("spill_chain") && pct >= 60 {
                            // the checkpoint-heavy chain must satisfy the
                            // acceptance scenario: 60% of the cheapest
                            // pure point is reachable by spilling
                            eprintln!(
                                "FAIL {}: {pct}% of the cheapest point must be spillable",
                                arch.name
                            );
                            failures += 1;
                        }
                        t.row(&[
                            arch.name.clone(),
                            format!("{pct}% = {}", fmt_bytes(budget)),
                            format!("{bw_gib} GiB/s"),
                            "-".into(),
                            format!("infeasible (min {})", fmt_bytes(e.min_device_bytes)),
                            "-".into(),
                        ]);
                        rows.push(SweepRow {
                            arch: arch.name.clone(),
                            budget_pct: pct,
                            host_bw,
                            feasible: false,
                            spilled_tensors: 0,
                            spilled_bytes: 0,
                            device_total: e.min_device_bytes,
                            stall_ms: 0.0,
                            step_ms: 0.0,
                        });
                    }
                    Err(other) => {
                        eprintln!("FAIL {}: unexpected plan error: {other}", arch.name);
                        failures += 1;
                    }
                }
            }
        }
    }
    t.print();

    // monotonicity sanity on the chain rows: slower links never stall less
    for pct in [90u64, 75, 60, 45] {
        let mut stalls: Vec<(u64, f64)> = rows
            .iter()
            .filter(|r| r.arch.starts_with("spill_chain") && r.budget_pct == pct && r.feasible)
            .map(|r| (r.host_bw, r.stall_ms))
            .collect();
        stalls.sort_unstable_by_key(|&(bw, _)| bw);
        for w in stalls.windows(2) {
            if w[1].1 > w[0].1 + 1e-9 {
                eprintln!(
                    "FAIL spill_chain: stall grew with bandwidth at {pct}% \
                     ({} → {} ms)",
                    w[0].1, w[1].1
                );
                failures += 1;
            }
        }
    }

    // ---- runtime engine: host-pool recycle + steady-state allocs ----
    println!("\n=== host-spill engine: pool recycle at steady state ===\n");
    let (chain, full, _) = &archs[0];
    let full_total = PlanRequest::for_arch(chain.clone())
        .batch(batch)
        .with_checkpoints(full.clone())
        .run()
        .expect("chain packs")
        .device_peak_packed();
    let budget = full_total * 3 / 5;
    let spill: SpillPlan = PlanRequest::for_arch(chain.clone())
        .batch(batch)
        .with_checkpoints(full.clone())
        .memory_budget(budget)
        .spill_lookahead(lookahead)
        .run()
        .expect("60% chain budget")
        .spill
        .expect("budgeted outcome");
    let mut engine = OffloadEngine::new(&spill);
    engine.run_step(); // warmup: populates the pool
    let warm_allocs = engine.stats().pool_allocs;
    let iters = if check { 64 } else { 512 };
    let stats = bench(1, iters, || engine.run_step());
    // the allocation audit runs outside `bench` (its sample buffer would
    // otherwise count against the engine)
    let before = ALLOC_COUNT.load(Ordering::Relaxed);
    for _ in 0..256 {
        engine.run_step();
    }
    let steady_allocs = ALLOC_COUNT.load(Ordering::Relaxed) - before;
    if steady_allocs != 0 {
        eprintln!("FAIL: {steady_allocs} heap allocations across 256 engine steps");
        failures += 1;
    }
    let es = engine.stats();
    if es.pool_allocs != warm_allocs {
        eprintln!(
            "FAIL: host pool allocated {} fresh buffers after warmup",
            es.pool_allocs - warm_allocs
        );
        failures += 1;
    }
    if es.evictions != es.prefetches {
        eprintln!("FAIL: {} evictions vs {} prefetches", es.evictions, es.prefetches);
        failures += 1;
    }
    let mut t = Table::new(&["steps", "evictions/step", "pool hit rate", "per step"]);
    t.row(&[
        format!("{}", es.steps),
        format!("{}", spill.steps.len()),
        format!("{:.1}%", es.hit_rate() * 100.0),
        fmt_ns(stats.median_ns),
    ]);
    t.print();

    // ---- worker staging scratch: the zero-alloc audit, extended ----
    // Emulates the producer hot loop's scratch pattern against the
    // per-worker staging: two k-wide label rows per batch from the slab,
    // plus the `Dataset::get_into` fetch path into a warm Image buffer —
    // the per-image allocation `Dataset::get` used to make on every slot.
    let classes = 10usize;
    let dataset = SynthCifar::cifar10(Split::Train, 512, 7);
    let mut scratch = ArenaAllocator::new(2 * classes * 4);
    let mut img = Image::zeros(32, 32, 3);
    let _ = dataset.get_into(0, &mut img); // warm the fetch buffer
    let scratch_before = ALLOC_COUNT.load(Ordering::Relaxed);
    for step in 0..256usize {
        scratch.begin_step();
        let h = scratch.alloc_f32(2 * classes).expect("slab sized for the rows");
        let rows = scratch.f32_mut(&h);
        let (a, b) = rows.split_at_mut(classes);
        a.fill(0.0);
        b.fill(0.0);
        a[3] = 1.0;
        b[7] = 1.0;
        let label = dataset.get_into(step % dataset.len(), &mut img);
        std::hint::black_box((a[3], b[7], label, img.data[0]));
    }
    let scratch_steady = ALLOC_COUNT.load(Ordering::Relaxed) - scratch_before;
    if scratch_steady != 0 {
        eprintln!(
            "FAIL: {scratch_steady} heap allocations across 256 scratch+fetch steps \
             (get_into must stay zero-alloc)"
        );
        failures += 1;
    }
    if scratch.fallback_allocs() != 0 {
        eprintln!("FAIL: {} scratch slab fallbacks", scratch.fallback_allocs());
        failures += 1;
    }
    println!(
        "\nworker scratch: 256 steps (label rows + get_into fetch), {} heap allocs, \
         {} slab fallbacks",
        scratch_steady,
        scratch.fallback_allocs()
    );

    let pool_row = PoolRow {
        steps: es.steps,
        hit_rate: es.hit_rate(),
        steady_allocs,
        step_ns: stats.median_ns,
        scratch_steady_allocs: scratch_steady,
        scratch_fallbacks: scratch.fallback_allocs(),
    };
    match write_json(&rows, &pool_row) {
        Ok(()) => println!("\nwrote BENCH_offload.json"),
        Err(e) => eprintln!("\ncould not write BENCH_offload.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: all offload invariants hold");
    }
}
