//! Checkpoint-planner benchmark: the exact DP (PR 2) vs the pre-PR-2
//! budget search that called the timeline-materializing simulator per
//! candidate. Reports plan time, plan quality (peak bytes), the per-call
//! cost of the zero-allocation peak evaluator vs `simulate`, and the
//! time/memory Pareto frontier per architecture.
//!
//! Emits `BENCH_planner.json` (per arch: old/new plan ns + speedup, old/new
//! peak bytes, evaluator vs simulate ns, frontier points).
//!
//! `OPTORCH_BENCH_CHECK=1` runs a fast smoke pass that *fails the process*
//! when an invariant breaks: DP peak worse than the old search, a
//! non-Pareto frontier, or any heap allocation inside `PeakEvaluator::peak`
//! (counted by a global allocator shim).

use optorch::config::Pipeline;
use optorch::memory::peak::PeakEvaluator;
use optorch::memory::planner::{
    pareto_frontier, plan_checkpoints, CheckpointPlan, PlannerKind, DEFAULT_FRONTIER_LEVELS,
};
use optorch::memory::simulator::simulate;
use optorch::models::{arch_by_name, ArchProfile};
use optorch::util::bench::{bench, fmt_bytes, fmt_ns, Table};
use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOC_COUNT: AtomicU64 = AtomicU64::new(0);

struct CountingAlloc;

// SAFETY: delegates every operation to `System`; only adds a counter.
unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc(l)
    }
    unsafe fn dealloc(&self, p: *mut u8, l: Layout) {
        System.dealloc(p, l)
    }
    unsafe fn realloc(&self, p: *mut u8, l: Layout, new_size: usize) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.realloc(p, l, new_size)
    }
    unsafe fn alloc_zeroed(&self, l: Layout) -> *mut u8 {
        ALLOC_COUNT.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(l)
    }
}

#[global_allocator]
static ALLOC: CountingAlloc = CountingAlloc;

/// The pre-PR-2 `PlannerKind::Optimal` — O(n²) candidate interior budgets,
/// greedy packing, one full `simulate` call per candidate — kept verbatim
/// as the speed/quality reference the DP is measured against.
fn old_budget_search(arch: &ArchProfile, pipeline: Pipeline, batch: usize) -> Vec<usize> {
    let n = arch.layers.len();
    let acts: Vec<u64> = arch.layers.iter().map(|l| l.act_elems).collect();
    let mut candidates: Vec<u64> = Vec::new();
    for i in 0..n {
        let mut s = 0u64;
        for a in acts.iter().skip(i) {
            s += a;
            candidates.push(s);
        }
    }
    candidates.sort_unstable();
    candidates.dedup();
    let mut best: Option<(u64, Vec<usize>)> = None;
    for &budget in &candidates {
        let mut cps = Vec::new();
        let mut interior = 0u64;
        let mut feasible = true;
        for (i, &a) in acts.iter().enumerate() {
            if a > budget {
                feasible = false;
                break;
            }
            if interior + a > budget {
                cps.push(i.saturating_sub(1));
                interior = 0;
            }
            interior += a;
        }
        if !feasible {
            continue;
        }
        cps.dedup();
        let peak = simulate(arch, pipeline, batch, &cps).peak_bytes;
        match &best {
            Some((bp, _)) if *bp <= peak => {}
            _ => best = Some((peak, cps)),
        }
        if best.as_ref().map(|(_, c)| c.is_empty()).unwrap_or(false) {
            break;
        }
    }
    best.map(|(_, c)| c).unwrap_or_default()
}

struct ArchRow {
    name: String,
    depth: usize,
    old_ns: f64,
    new_ns: f64,
    old_peak: u64,
    new_peak: u64,
    eval_ns: f64,
    simulate_ns: f64,
    frontier: Vec<CheckpointPlan>,
}

fn write_json(batch: usize, rows: &[ArchRow]) -> std::io::Result<()> {
    let mut j = format!("{{\n  \"batch\": {batch},\n  \"archs\": [\n");
    for (i, r) in rows.iter().enumerate() {
        j.push_str(&format!(
            "    {{\"arch\": \"{}\", \"depth\": {}, \"old_plan_ns\": {:.0}, \
             \"new_plan_ns\": {:.0}, \"speedup\": {:.1}, \"old_peak_bytes\": {}, \
             \"new_peak_bytes\": {}, \"peak_eval_ns\": {:.0}, \"simulate_ns\": {:.0}, \
             \"frontier\": [",
            r.name,
            r.depth,
            r.old_ns,
            r.new_ns,
            r.old_ns / r.new_ns,
            r.old_peak,
            r.new_peak,
            r.eval_ns,
            r.simulate_ns,
        ));
        for (k, p) in r.frontier.iter().enumerate() {
            j.push_str(&format!(
                "{{\"peak_bytes\": {}, \"n_checkpoints\": {}, \"recompute_overhead\": {:.4}}}{}",
                p.peak_bytes,
                p.checkpoints.len(),
                p.recompute_overhead,
                if k + 1 < r.frontier.len() { ", " } else { "" }
            ));
        }
        j.push_str(&format!("]}}{}\n", if i + 1 < rows.len() { "," } else { "" }));
    }
    j.push_str("  ]\n}\n");
    std::fs::write("BENCH_planner.json", j)
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let iters = if check { 3 } else { 20 };
    let batch = 16;
    let mut sc = Pipeline::BASELINE;
    sc.sc = true;
    let mut failures = 0u32;
    let mut rows: Vec<ArchRow> = Vec::new();

    println!("=== checkpoint planner: exact DP vs pre-PR-2 budget search (batch {batch}) ===\n");
    let mut t = Table::new(&[
        "arch",
        "depth",
        "old plan",
        "new plan",
        "speedup",
        "old peak",
        "new peak",
        "frontier pts",
    ]);
    for name in ["resnet18", "resnet50", "resnet101", "efficientnet_b0", "inception_v3"] {
        let hw = if name == "inception_v3" { 299 } else { 224 };
        let arch = arch_by_name(name, (hw, hw, 3), 1000).unwrap();

        let old_stats = bench(1, iters, || {
            let _ = old_budget_search(&arch, sc, batch);
        });
        let old_plan = old_budget_search(&arch, sc, batch);
        let old_peak = simulate(&arch, sc, batch, &old_plan).peak_bytes;

        let new_stats = bench(1, iters, || {
            let _ = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, batch);
        });
        let new_plan = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, batch);

        if new_plan.peak_bytes > old_peak {
            eprintln!(
                "FAIL {name}: DP peak {} worse than old search {}",
                new_plan.peak_bytes, old_peak
            );
            failures += 1;
        }

        // per-call cost: zero-alloc evaluator vs timeline simulator
        let probe = plan_checkpoints(&arch, PlannerKind::Sqrt, Pipeline::BASELINE, batch);
        let mut ev = PeakEvaluator::new(&arch, sc, batch);
        let eval_stats = bench(2, iters * 5, || {
            let _ = ev.peak(&probe.checkpoints);
        });
        let sim_stats = bench(2, iters, || {
            let _ = simulate(&arch, sc, batch, &probe.checkpoints);
        });

        // allocation audit: N evaluator calls must not touch the heap
        let before = ALLOC_COUNT.load(Ordering::Relaxed);
        let mut sink = 0u64;
        for _ in 0..256 {
            sink = sink.wrapping_add(ev.peak(&probe.checkpoints));
        }
        std::hint::black_box(sink);
        let allocs = ALLOC_COUNT.load(Ordering::Relaxed) - before;
        if allocs != 0 {
            eprintln!("FAIL {name}: {allocs} allocations across 256 peak() calls");
            failures += 1;
        }

        let frontier = pareto_frontier(&arch, Pipeline::BASELINE, batch, DEFAULT_FRONTIER_LEVELS);
        for w in frontier.windows(2) {
            if w[0].peak_bytes >= w[1].peak_bytes
                || w[0].recompute_overhead <= w[1].recompute_overhead
            {
                eprintln!("FAIL {name}: frontier not strictly Pareto");
                failures += 1;
                break;
            }
        }

        t.row(&[
            name.to_string(),
            format!("{}", arch.depth()),
            fmt_ns(old_stats.median_ns),
            fmt_ns(new_stats.median_ns),
            format!("{:.1}x", old_stats.median_ns / new_stats.median_ns),
            fmt_bytes(old_peak),
            fmt_bytes(new_plan.peak_bytes),
            format!("{}", frontier.len()),
        ]);
        rows.push(ArchRow {
            name: name.to_string(),
            depth: arch.depth(),
            old_ns: old_stats.median_ns,
            new_ns: new_stats.median_ns,
            old_peak,
            new_peak: new_plan.peak_bytes,
            eval_ns: eval_stats.median_ns,
            simulate_ns: sim_stats.median_ns,
            frontier,
        });
    }
    t.print();

    println!("\n=== peak evaluation: zero-alloc closed form vs timeline simulator ===\n");
    let mut t = Table::new(&["arch", "evaluator", "simulate", "speedup"]);
    for r in &rows {
        t.row(&[
            r.name.clone(),
            fmt_ns(r.eval_ns),
            fmt_ns(r.simulate_ns),
            format!("{:.0}x", r.simulate_ns / r.eval_ns),
        ]);
    }
    t.print();

    if let Some(r50) = rows.iter().find(|r| r.name == "resnet50") {
        println!(
            "\nresnet50 frontier: {} non-dominated plans from {} (min peak, +{:.0}% FLOPs) \
             to {} (zero recompute); planning {:.1}x faster than the old budget search",
            r50.frontier.len(),
            fmt_bytes(r50.frontier.first().unwrap().peak_bytes),
            r50.frontier.first().unwrap().recompute_overhead * 100.0,
            fmt_bytes(r50.frontier.last().unwrap().peak_bytes),
            r50.old_ns / r50.new_ns
        );
    }

    match write_json(batch, &rows) {
        Ok(()) => println!("\nwrote BENCH_planner.json"),
        Err(e) => eprintln!("\ncould not write BENCH_planner.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: all planner invariants hold");
    }
}
