//! Serving throughput: the closed-loop serving tier under nominal load
//! and under overload, plus the plan-cache lookup microbench.
//!
//! Three measurements:
//!
//! 1. **nominal** — the `configs/serve_resnet.toml` shape (resnet18
//!    behind a 2 GiB budget): sustained req/s with zero sheds and p99
//!    under the deadline, all on the deterministic virtual clock (the
//!    figures are bit-stable across runs);
//! 2. **overload sweep** — client fleets from matched to saturating
//!    against a tiny queue and deadline: the shed rate climbs and the
//!    degradation ladder walks (smaller max batch, then heap fallback);
//! 3. **cached-plan microbench** — wall-clock `PlanCache` hit cost vs
//!    one cold forward DP, the "admission costs a probe, not a plan"
//!    claim in numbers.
//!
//! Emits `BENCH_serve.json`. `OPTORCH_BENCH_CHECK=1` runs a fast smoke
//! pass that *fails the process* (exit 1) when a gate breaks: sheds
//! under nominal load, p99 over deadline, a forward slab not strictly
//! below the training slab, an overload run that fails to shed or walk
//! the ladder, or a cached lookup slower than 10 µs.

use optorch::memory::outcome::PlanOutcome;
use optorch::memory::pipeline::{PlanError, PlanMode, PlanRequest};
use optorch::obs::MetricsHub;
use optorch::serve::{self, PlanCache, PlanKey, ServeConfig, ServeReport};
use optorch::util::bench::{fmt_bytes, Table};
use std::time::Instant;

fn run(cfg: &ServeConfig) -> ServeReport {
    let hub = MetricsHub::new();
    serve::run(cfg, &hub).expect("serve run")
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let mut failures = 0u32;
    let requests = if check { 192 } else { 512 };

    // ---- nominal: the serve_resnet.toml shape ----
    let nominal = ServeConfig {
        budget: Some(2 << 30),
        requests,
        ..ServeConfig::default_for("resnet18")
    };
    let rep = run(&nominal);
    println!(
        "=== serve: {} nominal ({} requests, {} clients, deadline {} ms, budget {}) ===\n",
        nominal.model,
        requests,
        nominal.clients,
        nominal.deadline_ms,
        fmt_bytes(nominal.budget.unwrap()),
    );
    println!("{}", rep.to_markdown());

    if rep.shed_total() != 0 {
        eprintln!("FAIL: {} sheds under nominal load (gate: zero)", rep.shed_total());
        failures += 1;
    }
    if !(rep.p99_ms <= rep.deadline_ms) {
        eprintln!("FAIL: nominal p99 {:.2} ms over the {:.0} ms deadline", rep.p99_ms, rep.deadline_ms);
        failures += 1;
    }
    if rep.completed != rep.requests {
        eprintln!("FAIL: completed {} of {} issued", rep.completed, rep.requests);
        failures += 1;
    }
    let train_slab = rep.train_slab_bytes.unwrap_or(0);
    if !(rep.forward_slab_bytes < train_slab) {
        eprintln!(
            "FAIL: forward slab {} not strictly below training slab {}",
            rep.forward_slab_bytes, train_slab
        );
        failures += 1;
    }
    if rep.cache_hits <= rep.cache_misses {
        eprintln!(
            "FAIL: plan cache not warm ({} hits / {} misses)",
            rep.cache_hits, rep.cache_misses
        );
        failures += 1;
    }

    // ---- overload sweep: matched → saturating ----
    println!("=== overload sweep (tiny queue, {} ms deadline) ===\n", 0.05);
    let mut t = Table::new(&["clients", "shed rate", "rungs", "final max batch"]);
    let mut overload_shed_rate = 0.0f64;
    let mut overload_rungs = 0u64;
    for clients in [8usize, 16, 32] {
        let cfg = ServeConfig {
            clients,
            requests: if check { 300 } else { 600 },
            think_ms: 0.0,
            queue_cap: 2,
            deadline_ms: 0.05,
            max_batch: 16,
            shed_window: 16,
            overload_shed_rate: 0.25,
            ..ServeConfig::default_for("resnet18")
        };
        let r = run(&cfg);
        let rate = r.shed_total() as f64 / r.requests as f64;
        let rungs = r.degradation.as_ref().map(|d| d.actions.len() as u64).unwrap_or(0);
        t.row(&[
            format!("{clients}"),
            format!("{:.1}%", rate * 100.0),
            format!("{rungs}"),
            format!("{}", r.max_batch_final),
        ]);
        if clients == 32 {
            overload_shed_rate = rate;
            overload_rungs = rungs;
            if r.shed_total() == 0 {
                eprintln!("FAIL: saturating load shed nothing");
                failures += 1;
            }
            if rungs == 0 || r.max_batch_final >= r.max_batch_start {
                eprintln!("FAIL: sustained overload did not walk the degradation ladder");
                failures += 1;
            }
        }
    }
    t.print();

    // ---- cached-plan microbench ----
    let mut cache = PlanCache::new(4);
    let key = PlanKey {
        arch: "resnet18".to_string(),
        batch: 16,
        budget: Some(2 << 30),
        host_bw: nominal.host_bw,
    };
    let plan_once = || -> Result<PlanOutcome, PlanError> {
        PlanRequest::for_model("resnet18", (64, 64, 3), 10)
            .batch(16)
            .host_bw(nominal.host_bw)
            .memory_budget(2 << 30)
            .mode(PlanMode::Infer)
            .run()
    };
    let cold_start = Instant::now();
    cache.get_or_insert_with(&key, plan_once).expect("cold plan");
    let us_cold_plan = cold_start.elapsed().as_micros() as f64;
    let lookups: u64 = if check { 50_000 } else { 200_000 };
    let start = Instant::now();
    for _ in 0..lookups {
        cache.get_or_insert_with(&key, plan_once).expect("cached plan");
    }
    let us_per_cached_plan = start.elapsed().as_micros() as f64 / lookups as f64;
    println!(
        "\ncold forward plan {us_cold_plan:.0} µs; cached lookup {us_per_cached_plan:.3} µs \
         ({} hits, {} misses)",
        cache.hits(),
        cache.misses()
    );
    if cache.misses() != 1 {
        eprintln!("FAIL: cached lookups replanned ({} misses)", cache.misses());
        failures += 1;
    }
    if !(us_per_cached_plan < 10.0) {
        eprintln!("FAIL: cached plan lookup {us_per_cached_plan:.3} µs (gate < 10 µs)");
        failures += 1;
    }

    let json = format!(
        "{{\n  \"requests\": {requests},\n  \
         \"req_per_sec_nominal\": {:.3},\n  \
         \"p50_ms_nominal\": {:.4},\n  \"p99_ms_nominal\": {:.4},\n  \
         \"shed_total_nominal\": {},\n  \
         \"forward_slab_bytes\": {},\n  \"train_slab_bytes\": {train_slab},\n  \
         \"overload_shed_rate\": {overload_shed_rate:.4},\n  \
         \"overload_ladder_rungs\": {overload_rungs},\n  \
         \"us_per_cold_plan\": {us_cold_plan:.1},\n  \
         \"us_per_cached_plan\": {us_per_cached_plan:.4}\n}}\n",
        rep.requests_per_sec,
        rep.p50_ms,
        rep.p99_ms,
        rep.shed_total(),
        rep.forward_slab_bytes,
    );
    match std::fs::write("BENCH_serve.json", json) {
        Ok(()) => println!("\nwrote BENCH_serve.json"),
        Err(e) => eprintln!("\ncould not write BENCH_serve.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: serving gates hold");
    }
}
