//! L3 hot-path microbenchmarks: per-step latency decomposition —
//! sampler+augment, encode, literal marshaling, PJRT execute — the
//! numbers the §Perf pass optimizes against.

use optorch::config::{Pipeline, TrainConfig};
use optorch::coordinator::Trainer;
use optorch::data::augment::AugPolicy;
use optorch::data::encode::{encode_batch_grouped, EncodeSpec, Encoding, WordType};
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::util::bench::{bench, fmt_ns, Table};

fn main() -> anyhow::Result<()> {
    println!("=== step-latency decomposition (batch 16 @ 32x32x3) ===\n");
    let d = SynthCifar::cifar10(Split::Train, 2_000, 7);
    let mut sampler =
        SbsSampler::uniform(&d, 16, AugPolicy::parse("hflip,crop4").unwrap(), 1).unwrap();
    let mut t = Table::new(&["stage", "median", "mean"]);

    let s = bench(3, 50, || {
        let _ = sampler.next_batch(&d);
    });
    t.row(&["sample+augment".into(), fmt_ns(s.median_ns), fmt_ns(s.mean_ns)]);

    let batch = sampler.next_batch(&d);
    let spec = EncodeSpec::new(Encoding::Base256, WordType::F64);
    let s = bench(3, 100, || {
        let _ = encode_batch_grouped(&batch, spec).unwrap();
    });
    t.row(&["encode (3 groups)".into(), fmt_ns(s.median_ns), fmt_ns(s.mean_ns)]);

    let s = bench(3, 100, || {
        let _ = batch.to_f32();
    });
    t.row(&["widen to f32 (baseline)".into(), fmt_ns(s.median_ns), fmt_ns(s.mean_ns)]);

    // full PJRT train step via the trainer (includes literal marshaling)
    for pipe in ["b", "ed", "mp", "sc", "ed+mp+sc"] {
        let mut cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse(pipe).unwrap());
        cfg.train_size = 320;
        cfg.eval_every = 0;
        cfg.epochs = 1;
        let mut trainer = match Trainer::from_config(&cfg) {
            Ok(t) => t,
            Err(e) => {
                println!("(skipping PJRT step rows: {e})");
                break;
            }
        };
        let rec = trainer.run_epoch(0)?;
        let per_step = rec.wall_secs / (rec.images as f64 / 16.0);
        t.row(&[
            format!("train step [{}]", pipe),
            fmt_ns(per_step * 1e9),
            format!("{:.0} img/s", rec.images_per_sec()),
        ]);
    }
    t.print();
    Ok(())
}
