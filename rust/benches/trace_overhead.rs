//! Tracing overhead: the cost of the structured tracing layer on the
//! data-pipeline hot path, traced vs untraced.
//!
//! Three measurements:
//!
//! 1. **baseline** — `EdLoader::with_faults` (no tracer plumbed at all);
//! 2. **disabled** — `with_observability` with `Tracer::disabled()`: the
//!    shipped default, which must cost ~nothing (one branch per event
//!    site);
//! 3. **enabled** — `Tracer::enabled()`: full span/instant recording.
//!
//! Wall time per run is the **minimum over several trials** (standard
//! latency-bench practice: the minimum tracks the true cost, the rest is
//! scheduler noise). A per-event microbench (spin on `begin`/`end_span`)
//! rides along for the absolute numbers.
//!
//! Emits `BENCH_trace.json`. `OPTORCH_BENCH_CHECK=1` runs a fast smoke
//! pass that *fails the process* (exit 1) when enabled-tracing overhead
//! reaches 5% or disabled-tracing overhead is measurably nonzero (same
//! 5% noise bound — the code paths are identical, so anything beyond
//! noise is a regression).

use optorch::data::augment::AugPolicy;
use optorch::data::dataset::Dataset;
use optorch::data::encode::{EncodeSpec, Encoding, WordType};
use optorch::data::loader::{EdLoader, LoaderMode};
use optorch::data::pool::BufferPool;
use optorch::data::sampler::SbsSampler;
use optorch::data::synth::{Split, SynthCifar};
use optorch::trace::Tracer;
use optorch::util::bench::Table;
use std::sync::Arc;
use std::time::Instant;

fn loader_with(batches: usize, workers: usize, tracer: Option<Tracer>) -> EdLoader {
    let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 240, 9));
    let sampler = SbsSampler::uniform(
        d.as_ref(),
        16,
        AugPolicy::parse("hflip,crop4").unwrap(),
        11,
    )
    .unwrap();
    let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::F64));
    let mode = LoaderMode::Parallel { prefetch_depth: 2, num_workers: workers };
    let pool = Arc::new(BufferPool::default());
    match tracer {
        None => EdLoader::with_faults(d, sampler, spec, batches, mode, pool, None, None),
        Some(tr) => {
            EdLoader::with_observability(d, sampler, spec, batches, mode, pool, None, None, tr)
        }
    }
}

/// Drain one loader; wall seconds (consumer side, batch count asserted).
fn drain_secs(mut l: EdLoader, batches: usize) -> f64 {
    let start = Instant::now();
    let mut n = 0usize;
    loop {
        match l.try_next() {
            Ok(Some(p)) => {
                n += 1;
                l.recycle(p);
            }
            Ok(None) => break,
            Err(e) => panic!("loader errored mid-bench: {e}"),
        }
    }
    assert_eq!(n, batches, "short stream");
    start.elapsed().as_secs_f64()
}

/// Minimum wall seconds across `trials` fresh loaders.
fn best_of(trials: usize, batches: usize, workers: usize, make: impl Fn() -> Option<Tracer>) -> f64 {
    (0..trials)
        .map(|_| drain_secs(loader_with(batches, workers, make()), batches))
        .fold(f64::INFINITY, f64::min)
}

fn main() {
    let check = std::env::var("OPTORCH_BENCH_CHECK").is_ok();
    let mut failures = 0u32;
    let (batches, trials) = if check { (16, 3) } else { (32, 3) };
    let workers = 2;

    println!("=== tracing overhead: E-D pool loader ({batches} batches, {workers} workers, best of {trials}) ===\n");

    let baseline = best_of(trials, batches, workers, || None);
    let disabled = best_of(trials, batches, workers, || Some(Tracer::disabled()));
    // Keep the traced runs' logs: the last one reports the event volume.
    let enabled_tracer = Tracer::enabled();
    let mut enabled = f64::INFINITY;
    for _ in 0..trials {
        enabled =
            enabled.min(drain_secs(loader_with(batches, workers, Some(enabled_tracer.clone())), batches));
    }
    let log = enabled_tracer.drain();
    let events = log.event_count();
    let dropped = log.dropped();

    let disabled_pct = (disabled / baseline - 1.0) * 100.0;
    let enabled_pct = (enabled / baseline - 1.0) * 100.0;

    let mut t = Table::new(&["variant", "wall", "overhead"]);
    t.row(&["baseline (no tracer)".into(), format!("{:.1} ms", baseline * 1e3), "—".into()]);
    t.row(&[
        "tracing disabled".into(),
        format!("{:.1} ms", disabled * 1e3),
        format!("{disabled_pct:+.2}%"),
    ]);
    t.row(&[
        "tracing enabled".into(),
        format!("{:.1} ms", enabled * 1e3),
        format!("{enabled_pct:+.2}%"),
    ]);
    t.print();
    println!("\ntraced runs recorded {events} events ({dropped} dropped)");

    // ---- per-event microbench ----
    let spins: u64 = if check { 50_000 } else { 200_000 };
    let tr = Tracer::with_capacity(1 << 18);
    let mut hot = tr.thread("bench/hot");
    let start = Instant::now();
    for _ in 0..spins {
        let t0 = hot.begin();
        hot.end_span("spin", "bench", t0);
    }
    let ns_enabled = start.elapsed().as_nanos() as f64 / spins as f64;
    hot.finish();
    let micro_events = tr.drain().event_count();

    let off = Tracer::disabled();
    let mut cold = off.thread("bench/hot");
    let start = Instant::now();
    for _ in 0..spins {
        let t0 = cold.begin();
        cold.end_span("spin", "bench", t0);
    }
    let ns_disabled = start.elapsed().as_nanos() as f64 / spins as f64;
    cold.finish();

    println!(
        "per span (begin + end_span): {ns_enabled:.0} ns enabled, {ns_disabled:.1} ns disabled"
    );

    // ---- invariants ----
    if !(enabled_pct < 5.0) {
        eprintln!("FAIL: enabled-tracing overhead {enabled_pct:.2}% (gate < 5%)");
        failures += 1;
    }
    if !(disabled_pct < 5.0) {
        eprintln!("FAIL: disabled-tracing overhead {disabled_pct:.2}% (gate ~0, noise bound 5%)");
        failures += 1;
    }
    if events == 0 {
        eprintln!("FAIL: traced runs recorded no events");
        failures += 1;
    }
    if micro_events as u64 != spins.min(1 << 18) {
        eprintln!("FAIL: microbench recorded {micro_events} of {spins} spans");
        failures += 1;
    }
    if !(ns_enabled < 10_000.0) {
        eprintln!("FAIL: {ns_enabled:.0} ns per traced span (sanity gate < 10 µs)");
        failures += 1;
    }

    let json = format!(
        "{{\n  \"batches\": {batches},\n  \"workers\": {workers},\n  \"trials\": {trials},\n  \
         \"baseline_ms\": {:.3},\n  \"disabled_ms\": {:.3},\n  \"enabled_ms\": {:.3},\n  \
         \"disabled_overhead_pct\": {disabled_pct:.3},\n  \
         \"enabled_overhead_pct\": {enabled_pct:.3},\n  \"events\": {events},\n  \
         \"dropped\": {dropped},\n  \"ns_per_span_enabled\": {ns_enabled:.1},\n  \
         \"ns_per_span_disabled\": {ns_disabled:.2}\n}}\n",
        baseline * 1e3,
        disabled * 1e3,
        enabled * 1e3,
    );
    match std::fs::write("BENCH_trace.json", json) {
        Ok(()) => println!("\nwrote BENCH_trace.json"),
        Err(e) => eprintln!("\ncould not write BENCH_trace.json: {e}"),
    }

    if failures > 0 {
        eprintln!("\n{failures} invariant failure(s)");
        std::process::exit(1);
    }
    if check {
        println!("\ncheck mode: tracing overhead within gates");
    }
}
