//! CLI argument parsing (clap substitute — DESIGN.md §5).
//!
//! Grammar: `optorch <subcommand> [--key value]... [--flag]...`
//! Unknown keys are collected as config overrides, so every `TrainConfig`
//! field is settable from the command line without bespoke plumbing.

use std::collections::BTreeMap;

/// Parsed command line.
#[derive(Clone, Debug, Default)]
pub struct Cli {
    pub subcommand: String,
    /// `--key value` pairs.
    pub opts: BTreeMap<String, String>,
    /// bare `--flag`s.
    pub flags: Vec<String>,
    /// positional arguments after the subcommand.
    pub positional: Vec<String>,
}

impl Cli {
    /// Parse from an iterator of args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(args: I) -> Result<Cli, String> {
        let mut it = args.into_iter().peekable();
        let subcommand = it.next().unwrap_or_else(|| "help".to_string());
        let mut cli = Cli { subcommand, ..Default::default() };
        while let Some(arg) = it.next() {
            if let Some(key) = arg.strip_prefix("--") {
                if key.is_empty() {
                    return Err("bare '--' is not supported".into());
                }
                // --key=value form
                if let Some((k, v)) = key.split_once('=') {
                    cli.opts.insert(k.to_string(), v.to_string());
                    continue;
                }
                // --key value form, unless next token is another option
                match it.peek() {
                    Some(next) if !next.starts_with("--") => {
                        let v = it.next().unwrap();
                        cli.opts.insert(key.to_string(), v);
                    }
                    _ => cli.flags.push(key.to_string()),
                }
            } else {
                cli.positional.push(arg);
            }
        }
        Ok(cli)
    }

    pub fn from_env() -> Result<Cli, String> {
        Self::parse(std::env::args().skip(1))
    }

    pub fn has_flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    pub fn get(&self, name: &str) -> Option<&str> {
        self.opts.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str) -> Result<Option<usize>, String> {
        match self.get(name) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("--{name}: expected integer, got '{v}'")),
        }
    }
}

/// Top-level usage text.
pub const USAGE: &str = "\
optorch — OpTorch reproduction (rust coordinator)

USAGE:
  optorch <command> [--key value]...

COMMANDS:
  train     Train a model.            --model NAME --pipeline b|ed|mp|sc|ed+sc|...
            [--epochs N] [--batch_size N] [--dataset synth10|synth100|cifar10]
            [--config FILE] [--train_size N] [--seed N]
            [--num_workers N|auto] [--prefetch_depth N]
            [--memory_budget BYTES] [--host_bw BYTES/s] [--spill_lookahead N]
            [--planner dp|sqrt|uniformK|bottleneckK|joint] [--grad_spill BOOL] ...
            E-D producer pool: num_workers sizes the encode-worker pool
            (0 = single producer thread, auto = cores-1, default auto);
            prefetch_depth bounds how far producers run ahead.
            memory_budget (S-C pipelines; accepts 786432 / 512MiB / 1.5GB)
            trains under the cheapest-predicted-time plan whose *packed*
            bytes fit — composing a host-spill offload plan (budget-driven
            checkpoint eviction + double-buffered prefetch, modeled at
            host_bw with spill_lookahead steps of lookahead) when no pure
            recompute plan fits. planner=joint switches the budgeted
            composition to the joint recompute/spill optimizer, which may
            also offload param-gradient optimizer updates to the host
            (grad_spill, default true) — it never predicts a slower step
            than the sequential plan→spill pipeline.
            [--faults SPEC] injects deterministic faults for chaos testing:
            `;`-separated events `worker-panic@K`, `corrupt@K`,
            `budget-shrink@K=BYTES`, `link-fail:P`, `link-slow:P,xF`,
            `seed=N` (e.g. --faults 'seed=7;worker-panic@4;link-fail:0.1').
            The run recovers (respawn + requeue, detect + re-encode,
            bounded retries, degradation ladder) and reports what it took.
            [--loader_watchdog_secs N] turns a stalled loader into a typed
            error naming the suspect stage instead of a hang.
            [--trace FILE] records a Chrome trace-event timeline (load it
            in Perfetto / chrome://tracing): one track per loader worker,
            the offload link and the train-step loop, plus fault instants;
            the run summary then includes per-phase p50/p95/p99 timings,
            the unified counter table and a predicted-vs-observed drift
            line when a spill plan made a step-time prediction.
            [--metrics_addr HOST:PORT] serves live metrics while the run
            is up: Prometheus text exposition on /metrics, liveness on
            /healthz, readiness on /readyz (503 once the degradation
            ladder has been walked or the loader watchdog fired).
            [--memlog FILE] writes the per-step memory timeline as CSV
            (slab high-water, host residency, scratch occupancy, queue
            depth, degrade rung, step seconds) — replayable offline with
            `plan --memdrift FILE`.
  memsim    Simulate training memory. --model NAME [--pipeline P] [--batch N]
            [--height N] [--width N] [--timeline]
  plan      Plan checkpoint placement. --model NAME [--batch N] [--height N]
            [--kind dp|sqrt|uniformK|bottleneckK|joint] [--frontier] [--arena]
            [--budget BYTES] [--spill BYTES [--host_bw B/s] [--lookahead N]]
            [--compare [--grad_spill BOOL]] [--degrade] [--drift FILE]
            [--memdrift FILE] [--json]
            (--frontier prints the DP time/memory Pareto frontier; --budget
            picks the cheapest-time plan whose packed total fits; --arena
            packs the plan into a memory slab and prints its size,
            fragmentation ratio and per-class offsets; --spill composes a
            host-spill plan for the budget and prints the per-tensor
            evict/prefetch table + predicted stall; --degrade walks the
            graceful-degradation ladder for an infeasible --budget/--spill
            instead of erroring, printing the typed episode; --compare
            solves the same --spill/--budget twice — sequential plan→spill
            vs the joint recompute/spill optimizer (kind=joint, optionally
            spilling param-gradients) — and prints the two outcomes side by
            side as markdown, or one JSON document under --json; --drift
            replays a `train --trace` export: the observed `train-step`
            span quantiles against the step time the same flags predict,
            as one drift line (or JSON under --json); --memdrift replays
            a `train --memlog` CSV the same way for memory: observed
            slab/host high-water marks against the watermarks the same
            flags predict, as one mem-watermark line; --json renders
            the one staged PlanRequest→PlanOutcome run as a stable JSON
            document — arena always included, --spill preferred over
            --budget)
  serve     Serve inference under a device budget. --arch NAME
            [--budget BYTES] [--max_batch N] [--deadline_ms MS]
            [--batch_window_ms MS] [--clients N] [--requests N]
            [--think_ms MS] [--queue_cap N] [--host_bw B/s] [--seed N]
            [--config FILE] [--metrics_addr HOST:PORT] [--json]
            Drives a closed-loop synthetic client fleet against the
            forward-only serving tier: requests coalesce into the largest
            micro-batch whose cached inference plan (PlanMode::Infer —
            forward lifetimes only, packed into a slab strictly smaller
            than training's) fits the budget within the coalescing
            window; requests the tier cannot finish are shed with a
            typed reason (queue-full / budget-exceeded /
            deadline-exceeded), and sustained overload walks the
            degradation ladder (smaller max batch, then heap-fallback
            arena). Prints a ServeReport — req/s, p50/p99 latency, shed
            counts by reason, batch-size histogram, plan-cache and
            buffer-pool counters, forward-vs-training slab — as
            markdown, plus JSON under --json. --metrics_addr exposes
            live queue depth, admitted/shed counters and per-phase
            latency quantiles on /metrics; /readyz turns 503 while the
            shed rate over the sample window is nonzero.
  models    List architecture profiles and parameter counts.
  figures   Regenerate all paper figures (shortcut for the benches).
  help      Show this message.
";

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn parses_subcommand_opts_flags() {
        let c = parse("train extra --model tiny_cnn --epochs 3 --timeline");
        assert_eq!(c.subcommand, "train");
        assert_eq!(c.get("model"), Some("tiny_cnn"));
        assert_eq!(c.get_usize("epochs").unwrap(), Some(3));
        assert!(c.has_flag("timeline"));
        assert_eq!(c.positional, vec!["extra"]);
    }

    #[test]
    fn parses_key_equals_value() {
        let c = parse("train --model=resnet_mini18 --lr=0.1");
        assert_eq!(c.get("model"), Some("resnet_mini18"));
        assert_eq!(c.get("lr"), Some("0.1"));
    }

    #[test]
    fn flag_followed_by_option() {
        let c = parse("memsim --timeline --model tiny_cnn");
        assert!(c.has_flag("timeline"));
        assert_eq!(c.get("model"), Some("tiny_cnn"));
    }

    #[test]
    fn empty_args_yield_help() {
        let c = Cli::parse(std::iter::empty::<String>()).unwrap();
        assert_eq!(c.subcommand, "help");
    }

    #[test]
    fn bad_int_reports_key() {
        let c = parse("train --epochs three");
        let err = c.get_usize("epochs").unwrap_err();
        assert!(err.contains("epochs"));
    }
}
