//! Key–value config-file parser (TOML-subset: `key = value` lines,
//! `#` comments, optional `[section]` headers that prefix keys with
//! `section.`). The vendor set has no `toml`/`serde`, so configs use this.

use std::collections::BTreeMap;

#[derive(Debug, PartialEq)]
pub struct KvError {
    pub line: usize,
    pub msg: String,
}

impl std::fmt::Display for KvError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "config line {}: {}", self.line, self.msg)
    }
}

impl std::error::Error for KvError {}

/// Parse config text into a flat `section.key → value` map.
/// Values keep everything after the first `=` (trimmed, quotes stripped).
pub fn parse_kv(text: &str) -> Result<BTreeMap<String, String>, KvError> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        if let Some(rest) = line.strip_prefix('[') {
            let name = rest
                .strip_suffix(']')
                .ok_or_else(|| KvError { line: i + 1, msg: "unterminated section".into() })?
                .trim();
            if name.is_empty() {
                return Err(KvError { line: i + 1, msg: "empty section name".into() });
            }
            section = format!("{name}.");
            continue;
        }
        let eq = line.find('=').ok_or_else(|| KvError {
            line: i + 1,
            msg: format!("expected 'key = value', got: {line}"),
        })?;
        let key = line[..eq].trim();
        if key.is_empty() {
            return Err(KvError { line: i + 1, msg: "empty key".into() });
        }
        let mut val = line[eq + 1..].trim();
        // strip matching quotes
        if val.len() >= 2
            && ((val.starts_with('"') && val.ends_with('"'))
                || (val.starts_with('\'') && val.ends_with('\'')))
        {
            val = &val[1..val.len() - 1];
        }
        out.insert(format!("{section}{key}"), val.to_string());
    }
    Ok(out)
}

/// Typed getters over the parsed map.
pub trait KvGet {
    fn get_str(&self, key: &str) -> Option<&str>;

    fn get_usize(&self, key: &str) -> Result<Option<usize>, String> {
        match self.get_str(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("{key}: expected integer, got '{v}'")),
        }
    }

    fn get_f64(&self, key: &str) -> Result<Option<f64>, String> {
        match self.get_str(key) {
            None => Ok(None),
            Some(v) => v
                .parse()
                .map(Some)
                .map_err(|_| format!("{key}: expected number, got '{v}'")),
        }
    }

    fn get_bool(&self, key: &str) -> Result<Option<bool>, String> {
        match self.get_str(key) {
            None => Ok(None),
            Some("true") | Some("1") | Some("yes") => Ok(Some(true)),
            Some("false") | Some("0") | Some("no") => Ok(Some(false)),
            Some(v) => Err(format!("{key}: expected bool, got '{v}'")),
        }
    }
}

impl KvGet for BTreeMap<String, String> {
    fn get_str(&self, key: &str) -> Option<&str> {
        self.get(key).map(|s| s.as_str())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_flat_and_sections() {
        let m = parse_kv(
            "# comment\nmodel = resnet_mini18\n\n[train]\nepochs = 10\nlr = 0.05\n[data]\nname = \"synth10\"\n",
        )
        .unwrap();
        assert_eq!(m.get_str("model"), Some("resnet_mini18"));
        assert_eq!(m.get_str("train.epochs"), Some("10"));
        assert_eq!(m.get_str("data.name"), Some("synth10"));
    }

    #[test]
    fn typed_getters() {
        let m = parse_kv("a = 5\nb = 2.5\nc = true\nd = nope\n").unwrap();
        assert_eq!(m.get_usize("a").unwrap(), Some(5));
        assert_eq!(m.get_f64("b").unwrap(), Some(2.5));
        assert_eq!(m.get_bool("c").unwrap(), Some(true));
        assert!(m.get_bool("d").is_err());
        assert!(m.get_usize("b").is_err());
        assert_eq!(m.get_usize("missing").unwrap(), None);
    }

    #[test]
    fn value_may_contain_equals() {
        let m = parse_kv("aug = hflip,crop4\nexpr = a=b\n").unwrap();
        assert_eq!(m.get_str("expr"), Some("a=b"));
    }

    #[test]
    fn errors_carry_line_numbers() {
        let e = parse_kv("ok = 1\nbroken line\n").unwrap_err();
        assert_eq!(e.line, 2);
        let e = parse_kv("[open\n").unwrap_err();
        assert_eq!(e.line, 1);
        let e = parse_kv("= v\n").unwrap_err();
        assert_eq!(e.line, 1);
    }

    #[test]
    fn quotes_stripped() {
        let m = parse_kv("a = \"x y\"\nb = 'z'\n").unwrap();
        assert_eq!(m.get_str("a"), Some("x y"));
        assert_eq!(m.get_str("b"), Some("z"));
    }
}
