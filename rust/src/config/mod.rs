//! Config system: pipeline flags, training configuration, and a small
//! key–value config-file format with CLI overrides.

mod kv;
mod pipeline;
mod train;

pub use kv::{parse_kv, KvError, KvGet};
pub use pipeline::Pipeline;
pub use train::{parse_bytes, DatasetChoice, TrainConfig};
