//! Optimization-pipeline selection — the paper's B / E-D / M-P / S-C grid.

/// Which OpTorch optimizations are active. The paper's pipelines are
/// combinations of three independent switches over the baseline:
/// encode–decode data flow, mixed precision, sequential checkpoints.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq, Hash)]
pub struct Pipeline {
    /// E-D: packed input batches + in-graph decode layer + parallel loader.
    pub ed: bool,
    /// M-P: f16 state, f32 compute (Figure 3).
    pub mp: bool,
    /// S-C: sequential checkpoints / rematerialization.
    pub sc: bool,
}

impl Pipeline {
    pub const BASELINE: Pipeline = Pipeline { ed: false, mp: false, sc: false };

    /// Parse `"b"`, `"ed"`, `"mp"`, `"sc"`, `"ed+sc"`, `"ed+mp+sc"` … in any
    /// order. `"b"`/`"baseline"` must appear alone.
    pub fn parse(s: &str) -> Result<Pipeline, String> {
        let toks: Vec<&str> = s
            .split(['+', ','])
            .map(str::trim)
            .filter(|t| !t.is_empty())
            .collect();
        if toks.is_empty() {
            return Err("empty pipeline spec".into());
        }
        let mut p = Pipeline::default();
        for t in &toks {
            match t.to_ascii_lowercase().as_str() {
                "b" | "baseline" => {
                    if toks.len() > 1 {
                        return Err(format!("'{t}' cannot be combined: {s}"));
                    }
                }
                "ed" | "e-d" => p.ed = true,
                "mp" | "m-p" => p.mp = true,
                "sc" | "s-c" => p.sc = true,
                other => return Err(format!("unknown pipeline component '{other}'")),
            }
        }
        Ok(p)
    }

    /// Canonical name used in artifact files and reports
    /// (`baseline`, `ed`, `mp`, `sc`, `ed_sc`, `ed_mp_sc`, …).
    pub fn name(&self) -> String {
        let mut parts = Vec::new();
        if self.ed {
            parts.push("ed");
        }
        if self.mp {
            parts.push("mp");
        }
        if self.sc {
            parts.push("sc");
        }
        if parts.is_empty() {
            "baseline".to_string()
        } else {
            parts.join("_")
        }
    }

    /// Paper-style display label (`B`, `E-D + S-C`, …).
    pub fn label(&self) -> String {
        let mut parts = Vec::new();
        if self.ed {
            parts.push("E-D");
        }
        if self.mp {
            parts.push("M-P");
        }
        if self.sc {
            parts.push("S-C");
        }
        if parts.is_empty() {
            "B".to_string()
        } else {
            parts.join(" + ")
        }
    }

    /// Whether this pipeline runs the background producer pool (the E-D
    /// data flow is what overlaps encode with training; all other
    /// pipelines materialize batches inline).
    pub fn parallel_loader(&self) -> bool {
        self.ed
    }

    /// The 8 combinations, baseline first (Fig 9/10 grids).
    pub fn all() -> Vec<Pipeline> {
        let mut v = Vec::new();
        for ed in [false, true] {
            for mp in [false, true] {
                for sc in [false, true] {
                    v.push(Pipeline { ed, mp, sc });
                }
            }
        }
        v.sort_by_key(|p| (p.ed as u8) + (p.mp as u8) + (p.sc as u8));
        v
    }

    /// The 6 pipelines Figure 10 plots.
    pub fn fig10_set() -> Vec<Pipeline> {
        vec![
            Pipeline::BASELINE,
            Pipeline { ed: true, ..Default::default() },
            Pipeline { mp: true, ..Default::default() },
            Pipeline { sc: true, ..Default::default() },
            Pipeline { sc: true, mp: true, ..Default::default() },
            Pipeline { ed: true, sc: true, ..Default::default() },
        ]
    }
}

impl std::fmt::Display for Pipeline {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_singletons() {
        assert_eq!(Pipeline::parse("b").unwrap(), Pipeline::BASELINE);
        assert_eq!(Pipeline::parse("baseline").unwrap(), Pipeline::BASELINE);
        assert_eq!(Pipeline::parse("ed").unwrap().name(), "ed");
        assert_eq!(Pipeline::parse("MP").unwrap().name(), "mp");
        assert_eq!(Pipeline::parse("S-C").unwrap().name(), "sc");
    }

    #[test]
    fn parse_combos_any_order() {
        let a = Pipeline::parse("ed+mp+sc").unwrap();
        let b = Pipeline::parse("sc,mp,ed").unwrap();
        assert_eq!(a, b);
        assert_eq!(a.name(), "ed_mp_sc");
        assert_eq!(a.label(), "E-D + M-P + S-C");
    }

    #[test]
    fn parse_rejects_bad() {
        assert!(Pipeline::parse("").is_err());
        assert!(Pipeline::parse("warp").is_err());
        assert!(Pipeline::parse("b+sc").is_err());
    }

    #[test]
    fn all_has_8_unique() {
        let all = Pipeline::all();
        assert_eq!(all.len(), 8);
        let names: std::collections::HashSet<_> = all.iter().map(|p| p.name()).collect();
        assert_eq!(names.len(), 8);
        assert_eq!(all[0], Pipeline::BASELINE);
    }

    #[test]
    fn fig10_set_matches_paper() {
        let labels: Vec<String> = Pipeline::fig10_set().iter().map(|p| p.label()).collect();
        assert_eq!(
            labels,
            vec!["B", "E-D", "M-P", "S-C", "M-P + S-C", "E-D + S-C"]
        );
    }

    #[test]
    fn only_ed_pipelines_use_the_parallel_loader() {
        for p in Pipeline::all() {
            assert_eq!(p.parallel_loader(), p.ed, "{p}");
        }
    }

    #[test]
    fn name_parse_roundtrip() {
        for p in Pipeline::all() {
            let spec = if p == Pipeline::BASELINE { "b".to_string() } else { p.name().replace('_', "+") };
            assert_eq!(Pipeline::parse(&spec).unwrap(), p);
        }
    }
}
