//! Training configuration: the launcher's single source of truth.

use crate::config::kv::KvGet;
use crate::config::{parse_kv, Pipeline};
use crate::data::encode::{EncodeSpec, Encoding, WordType};
use crate::data::loader::LoaderMode;
use crate::fault::FaultSpec;
use std::collections::BTreeMap;
use std::path::PathBuf;

/// Which dataset the run uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum DatasetChoice {
    /// Synthetic CIFAR-10-shaped data (default; always available).
    Synth10,
    /// Synthetic CIFAR-100-shaped data.
    Synth100,
    /// Real CIFAR-10 binaries if discoverable, else an error.
    Cifar10,
}

impl DatasetChoice {
    pub fn parse(s: &str) -> Result<DatasetChoice, String> {
        match s {
            "synth10" | "synth" => Ok(DatasetChoice::Synth10),
            "synth100" => Ok(DatasetChoice::Synth100),
            "cifar10" => Ok(DatasetChoice::Cifar10),
            other => Err(format!("unknown dataset '{other}'")),
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            DatasetChoice::Synth10 => "synth10",
            DatasetChoice::Synth100 => "synth100",
            DatasetChoice::Cifar10 => "cifar10",
        }
    }
}

/// Parse a byte count: a plain integer, or a number with a `B`/`KB`/`MB`/
/// `GB` (decimal) or `KiB`/`MiB`/`GiB` (binary) suffix, case-insensitive
/// (`512MiB`, `1.5GB`, `786432`). Underscores may group digits in the
/// integer part (`512_000`, `1_024MiB`); they are not allowed after the
/// decimal point.
pub fn parse_bytes(s: &str) -> Result<u64, String> {
    let t = s.trim();
    if t.starts_with('-') {
        return Err(format!("byte count '{s}' is negative — sizes must be ≥ 1 B"));
    }
    let split = t
        .find(|c: char| !(c.is_ascii_digit() || c == '.' || c == '_'))
        .unwrap_or(t.len());
    let (num, suffix) = t.split_at(split);
    if let Some(frac) = num.split_once('.').map(|(_, f)| f) {
        if frac.contains('_') {
            return Err(format!(
                "bad byte count '{s}' — underscores may only group digits in the \
                 integer part (e.g. 512_000), not the fraction"
            ));
        }
    }
    if num.starts_with('_') || num.ends_with('_') || num.contains("__") {
        return Err(format!(
            "bad byte count '{s}' — underscores must sit between digits (e.g. 512_000)"
        ));
    }
    let num = num.replace('_', "");
    let num: f64 = num
        .parse()
        .map_err(|_| format!("bad byte count '{s}' (expected e.g. 786432, 512MiB, 1.5GB)"))?;
    let mult: f64 = match suffix.trim().to_ascii_lowercase().as_str() {
        "" | "b" => 1.0,
        "kb" => 1e3,
        "mb" => 1e6,
        "gb" => 1e9,
        "kib" => 1024.0,
        "mib" => 1024.0 * 1024.0,
        "gib" => 1024.0 * 1024.0 * 1024.0,
        other => return Err(format!("unknown byte suffix '{other}' in '{s}'")),
    };
    let v = num * mult;
    if !v.is_finite() || v < 1.0 {
        return Err(format!("byte count '{s}' must be ≥ 1 B"));
    }
    Ok(v.round() as u64)
}

/// Full configuration for one training run.
#[derive(Clone, Debug)]
pub struct TrainConfig {
    /// L2 model name — must exist in the artifact manifest.
    pub model: String,
    pub pipeline: Pipeline,
    pub dataset: DatasetChoice,
    pub train_size: usize,
    pub test_size: usize,
    pub batch_size: usize,
    pub epochs: usize,
    pub seed: u64,
    /// Prefetch queue depth for the parallel E-D loader.
    pub prefetch_depth: usize,
    /// Encode workers in the E-D producer pool. `Some(0)` keeps the classic
    /// single producer thread; `None` (default) sizes the pool to
    /// `available_parallelism - 1`. Any worker count yields byte-identical
    /// batches for the same seed.
    pub num_workers: Option<usize>,
    /// Peak-training-memory budget in bytes (S-C pipelines only). When
    /// set, the trainer ranks the DP Pareto frontier by *packed* bytes
    /// (`base + slab`), composes host-spill plans for points that do not
    /// fit, and trains under the minimum-predicted-step-time choice;
    /// errors when even full spilling cannot reach the budget. `None` =
    /// minimize peak outright.
    pub memory_budget: Option<u64>,
    /// Modeled host↔device bandwidth (bytes/s) for the offload engine's
    /// overlap simulation (accepts `12GiB` etc.). Only consulted when
    /// `memory_budget` forces host spilling.
    pub host_bw: u64,
    /// How many schedule steps before its first backward use a spilled
    /// checkpoint's prefetch is issued (the double-buffer window, ≥ 1).
    pub spill_lookahead: usize,
    /// Checkpoint planner spec (`sqrt`, `dp`, `uniformK`, `bottleneckK`,
    /// `joint`). `joint` switches budgeted S-C runs to the joint
    /// recompute/spill optimizer.
    pub planner: String,
    /// Let the `joint` planner offload param-gradient optimizer updates
    /// to the host (ignored by every other planner).
    pub grad_spill: bool,
    /// Augmentation policy applied to every class (SBS per-class policies
    /// are configured programmatically via [`crate::data::sampler`]).
    pub augment: String,
    pub artifacts_dir: PathBuf,
    /// Evaluate every N epochs (0 = only at the end).
    pub eval_every: usize,
    /// Cap on train batches per epoch (0 = full epoch) — used by examples
    /// and benches to bound wall-time.
    pub max_batches_per_epoch: usize,
    /// Learning-rate schedule (`const:LR`, `step:LR:N:F`, `cosine:LR:T`).
    pub lr_schedule: crate::coordinator::LrSchedule,
    /// Deterministic fault-injection spec (chaos testing): worker panics,
    /// payload corruption, link faults, mid-run budget shrinks. `None` (the
    /// default) injects nothing. See [`crate::fault::FaultSpec`] grammar.
    pub faults: Option<FaultSpec>,
    /// Watchdog deadline (seconds) for the parallel loader: if no batch
    /// arrives within it, `try_next` returns a typed stall error naming the
    /// suspect stage instead of blocking forever. `None` = no deadline.
    pub loader_watchdog_secs: Option<u64>,
    /// Write a Chrome trace-event JSON timeline of the run here (loadable
    /// in Perfetto / `chrome://tracing`): one track per loader thread, the
    /// offload link and the train-step loop. `None` (the default) disables
    /// tracing entirely — the hot paths then pay one branch per would-be
    /// event.
    pub trace: Option<PathBuf>,
    /// Serve live metrics and health probes over HTTP while the run is
    /// up: Prometheus text exposition on `/metrics`, liveness on
    /// `/healthz`, readiness on `/readyz` (503 while the degradation
    /// ladder is active or the loader watchdog has fired). The value is
    /// a `HOST:PORT` socket address (port 0 picks a free port); `None`
    /// (the default) starts no listener.
    pub metrics_addr: Option<String>,
    /// Write the per-step memory timeline here as CSV (one row per
    /// train step: slab high-water, host residency, scratch occupancy,
    /// queue depth, degrade rung, step seconds). Replayable offline via
    /// `plan --memdrift FILE`. `None` (the default) keeps no timeline.
    pub memlog: Option<PathBuf>,
}

impl TrainConfig {
    /// Sensible defaults for a given model + pipeline (used by examples).
    pub fn default_for(model: &str, pipeline: Pipeline) -> TrainConfig {
        TrainConfig {
            model: model.to_string(),
            pipeline,
            dataset: DatasetChoice::Synth10,
            train_size: 2_000,
            test_size: 512,
            batch_size: 16,
            epochs: 3,
            seed: 42,
            prefetch_depth: 4,
            num_workers: None,
            memory_budget: None,
            host_bw: crate::memory::offload::DEFAULT_HOST_BW_BYTES_PER_SEC,
            spill_lookahead: 2,
            planner: "dp".into(),
            grad_spill: true,
            augment: "hflip,crop4".into(),
            artifacts_dir: PathBuf::from("artifacts"),
            eval_every: 1,
            max_batches_per_epoch: 0,
            lr_schedule: crate::coordinator::LrSchedule::default(),
            faults: None,
            loader_watchdog_secs: None,
            trace: None,
            metrics_addr: None,
            memlog: None,
        }
    }

    /// Parse a config file + `--key value` CLI overrides.
    pub fn from_sources(
        file_text: Option<&str>,
        overrides: &BTreeMap<String, String>,
    ) -> Result<TrainConfig, String> {
        let mut kv = match file_text {
            Some(t) => parse_kv(t).map_err(|e| e.to_string())?,
            None => BTreeMap::new(),
        };
        for (k, v) in overrides {
            kv.insert(k.clone(), v.clone());
        }
        let mut cfg = TrainConfig::default_for("tiny_cnn", Pipeline::BASELINE);
        if let Some(m) = kv.get_str("model") {
            cfg.model = m.to_string();
        }
        if let Some(p) = kv.get_str("pipeline") {
            cfg.pipeline = Pipeline::parse(p)?;
        }
        if let Some(d) = kv.get_str("dataset") {
            cfg.dataset = DatasetChoice::parse(d)?;
        }
        if let Some(v) = kv.get_usize("train_size")? {
            cfg.train_size = v;
        }
        if let Some(v) = kv.get_usize("test_size")? {
            cfg.test_size = v;
        }
        if let Some(v) = kv.get_usize("batch_size")? {
            cfg.batch_size = v;
        }
        if let Some(v) = kv.get_usize("epochs")? {
            cfg.epochs = v;
        }
        if let Some(v) = kv.get_usize("seed")? {
            cfg.seed = v as u64;
        }
        if let Some(v) = kv.get_usize("prefetch_depth")? {
            cfg.prefetch_depth = v;
        }
        if let Some(v) = kv.get_str("num_workers") {
            cfg.num_workers = match v {
                "auto" => None,
                n => Some(
                    n.parse()
                        .map_err(|_| format!("num_workers: expected integer or 'auto', got '{n}'"))?,
                ),
            };
        }
        // Both route through the memory facade's shared byte parser, so
        // the config, the CLI flags and the manifest's `device_budget`
        // all report the same "<field>: <reason>" error shape.
        if let Some(v) = kv.get_str("memory_budget") {
            cfg.memory_budget = Some(
                crate::memory::pipeline::parse_bytes_field("memory_budget", v)
                    .map_err(|e| e.to_string())?,
            );
        }
        if let Some(v) = kv.get_str("host_bw") {
            cfg.host_bw = crate::memory::pipeline::parse_bytes_field("host_bw", v)
                .map_err(|e| e.to_string())?;
        }
        if let Some(v) = kv.get_usize("spill_lookahead")? {
            cfg.spill_lookahead = v;
        }
        if let Some(v) = kv.get_str("planner") {
            cfg.planner = v.to_string();
        }
        if let Some(v) = kv.get_bool("grad_spill")? {
            cfg.grad_spill = v;
        }
        if let Some(a) = kv.get_str("augment") {
            cfg.augment = a.to_string();
        }
        if let Some(d) = kv.get_str("artifacts_dir") {
            cfg.artifacts_dir = PathBuf::from(d);
        }
        if let Some(v) = kv.get_usize("eval_every")? {
            cfg.eval_every = v;
        }
        if let Some(v) = kv.get_usize("max_batches_per_epoch")? {
            cfg.max_batches_per_epoch = v;
        }
        if let Some(v) = kv.get_str("lr_schedule") {
            cfg.lr_schedule = crate::coordinator::LrSchedule::parse(v)?;
        }
        if let Some(v) = kv.get_str("faults") {
            let spec = FaultSpec::parse(v).map_err(|e| format!("faults: {e}"))?;
            cfg.faults = if spec.is_empty() { None } else { Some(spec) };
        }
        if let Some(v) = kv.get_usize("loader_watchdog_secs")? {
            cfg.loader_watchdog_secs = if v == 0 { None } else { Some(v as u64) };
        }
        if let Some(v) = kv.get_str("trace") {
            cfg.trace = if v.is_empty() { None } else { Some(PathBuf::from(v)) };
        }
        if let Some(v) = kv.get_str("metrics_addr") {
            cfg.metrics_addr = if v.is_empty() { None } else { Some(v.to_string()) };
        }
        if let Some(v) = kv.get_str("memlog") {
            cfg.memlog = if v.is_empty() { None } else { Some(PathBuf::from(v)) };
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.batch_size == 0 {
            return Err("batch_size must be ≥ 1".into());
        }
        if self.train_size == 0 {
            return Err("train_size must be ≥ 1".into());
        }
        if self.model.is_empty() {
            return Err("model must be set".into());
        }
        if self.memory_budget.is_some() && !self.pipeline.sc {
            return Err(
                "memory_budget only constrains checkpoint planning — add S-C to the \
                 pipeline (e.g. `--pipeline sc` or `ed+sc`)"
                    .into(),
            );
        }
        if self.spill_lookahead == 0 {
            return Err(
                "spill_lookahead must be ≥ 1 — a prefetch issued at its need step \
                 cannot overlap anything"
                    .into(),
            );
        }
        crate::memory::planner::PlannerKind::parse(&self.planner)
            .map_err(|e| format!("planner: {e}"))?;
        crate::data::augment::AugPolicy::parse(&self.augment)?;
        if let Some(a) = &self.metrics_addr {
            a.parse::<std::net::SocketAddr>().map_err(|_| {
                format!("metrics_addr: expected HOST:PORT (e.g. 127.0.0.1:9184), got '{a}'")
            })?;
        }
        Ok(())
    }

    /// The configured worker count with the `auto` default resolved.
    pub fn resolved_num_workers(&self) -> usize {
        self.num_workers
            .unwrap_or_else(crate::data::loader::default_num_workers)
    }

    /// Loader mode implied by the pipeline: E-D runs the producer pool.
    pub fn loader_mode(&self) -> LoaderMode {
        if self.pipeline.parallel_loader() {
            LoaderMode::Parallel {
                prefetch_depth: self.prefetch_depth,
                num_workers: self.resolved_num_workers(),
            }
        } else {
            LoaderMode::Synchronous
        }
    }

    /// Encode spec implied by the pipeline: E-D ships f64 base-256 words
    /// (what the L1 decode kernel consumes); other pipelines ship raw f32.
    pub fn encode_spec(&self) -> Option<EncodeSpec> {
        if self.pipeline.ed {
            Some(EncodeSpec::new(Encoding::Base256, WordType::F64))
        } else {
            None
        }
    }

    /// Artifact basename for this (model, pipeline).
    pub fn artifact_stem(&self) -> String {
        format!("{}_{}", self.model, self.pipeline.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        TrainConfig::default_for("tiny_cnn", Pipeline::BASELINE)
            .validate()
            .unwrap();
    }

    #[test]
    fn file_plus_overrides() {
        let file = "model = resnet_mini18\npipeline = ed+sc\nepochs = 7\n";
        let mut ov = BTreeMap::new();
        ov.insert("epochs".to_string(), "2".to_string());
        ov.insert("batch_size".to_string(), "8".to_string());
        let cfg = TrainConfig::from_sources(Some(file), &ov).unwrap();
        assert_eq!(cfg.model, "resnet_mini18");
        assert_eq!(cfg.pipeline.name(), "ed_sc");
        assert_eq!(cfg.epochs, 2); // override wins
        assert_eq!(cfg.batch_size, 8);
    }

    #[test]
    fn rejects_bad_values() {
        let mut ov = BTreeMap::new();
        ov.insert("batch_size".to_string(), "0".to_string());
        assert!(TrainConfig::from_sources(None, &ov).is_err());
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "warp9".to_string());
        assert!(TrainConfig::from_sources(None, &ov).is_err());
        let mut ov = BTreeMap::new();
        ov.insert("augment".to_string(), "teleport".to_string());
        assert!(TrainConfig::from_sources(None, &ov).is_err());
        let mut ov = BTreeMap::new();
        ov.insert("dataset".to_string(), "imagenet".to_string());
        assert!(TrainConfig::from_sources(None, &ov).is_err());
    }

    #[test]
    fn pipeline_implies_loader_and_encoding() {
        let b = TrainConfig::default_for("m", Pipeline::BASELINE);
        assert_eq!(b.loader_mode(), LoaderMode::Synchronous);
        assert!(b.encode_spec().is_none());
        let ed = TrainConfig::default_for("m", Pipeline::parse("ed").unwrap());
        assert!(matches!(ed.loader_mode(), LoaderMode::Parallel { .. }));
        let spec = ed.encode_spec().unwrap();
        assert_eq!(spec.capacity(), 6); // f64 base-256
    }

    #[test]
    fn num_workers_parses_and_reaches_loader_mode() {
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "ed".to_string());
        ov.insert("num_workers".to_string(), "3".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert_eq!(cfg.num_workers, Some(3));
        assert_eq!(
            cfg.loader_mode(),
            LoaderMode::Parallel { prefetch_depth: 4, num_workers: 3 }
        );
        // 0 = classic single producer thread
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "ed".to_string());
        ov.insert("num_workers".to_string(), "0".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert!(matches!(
            cfg.loader_mode(),
            LoaderMode::Parallel { num_workers: 0, .. }
        ));
        // auto resolves to ≥ 1
        let mut ov = BTreeMap::new();
        ov.insert("num_workers".to_string(), "auto".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert_eq!(cfg.num_workers, None);
        assert!(cfg.resolved_num_workers() >= 1);
        // junk rejected
        let mut ov = BTreeMap::new();
        ov.insert("num_workers".to_string(), "many".to_string());
        assert!(TrainConfig::from_sources(None, &ov).is_err());
    }

    #[test]
    fn parse_bytes_forms() {
        assert_eq!(parse_bytes("786432").unwrap(), 786_432);
        assert_eq!(parse_bytes("2KB").unwrap(), 2_000);
        assert_eq!(parse_bytes("512MiB").unwrap(), 512 * 1024 * 1024);
        assert_eq!(parse_bytes("1.5GB").unwrap(), 1_500_000_000);
        assert_eq!(parse_bytes(" 4 GiB ").unwrap(), 4 * 1024 * 1024 * 1024);
        assert_eq!(parse_bytes("100b").unwrap(), 100);
        assert!(parse_bytes("").is_err());
        assert!(parse_bytes("MiB").is_err());
        assert!(parse_bytes("12parsecs").is_err());
        assert!(parse_bytes("0").is_err());
    }

    #[test]
    fn parse_bytes_fractional_suffixes() {
        assert_eq!(parse_bytes("1.5GiB").unwrap(), 3 * 512 * 1024 * 1024);
        assert_eq!(parse_bytes("0.5MiB").unwrap(), 512 * 1024);
        assert_eq!(parse_bytes("2.5KB").unwrap(), 2_500);
        assert_eq!(parse_bytes("0.25KiB").unwrap(), 256);
    }

    #[test]
    fn parse_bytes_underscore_grouping() {
        assert_eq!(parse_bytes("512_000").unwrap(), 512_000);
        assert_eq!(parse_bytes("1_024MiB").unwrap(), 1024 * 1024 * 1024);
        assert_eq!(parse_bytes("786_432").unwrap(), 786_432);
        assert_eq!(parse_bytes("1_000_000KB").unwrap(), 1_000_000_000);
        // underscores group the integer part only
        let err = parse_bytes("1.5_0MB").unwrap_err();
        assert!(err.contains("fraction"), "{err}");
        // and must sit between digits
        assert!(parse_bytes("_512").is_err());
        assert!(parse_bytes("512_").is_err());
        assert!(parse_bytes("5__12").is_err());
    }

    #[test]
    fn planner_and_grad_spill_parse() {
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "sc".to_string());
        ov.insert("planner".to_string(), "joint".to_string());
        ov.insert("grad_spill".to_string(), "false".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert_eq!(cfg.planner, "joint");
        assert!(!cfg.grad_spill);
        // defaults
        let d = TrainConfig::default_for("m", Pipeline::BASELINE);
        assert_eq!(d.planner, "dp");
        assert!(d.grad_spill);
        // junk planner rejected with the key named
        let mut ov = BTreeMap::new();
        ov.insert("planner".to_string(), "clairvoyant".to_string());
        let err = TrainConfig::from_sources(None, &ov).unwrap_err();
        assert!(err.contains("planner"), "{err}");
    }

    #[test]
    fn parse_bytes_rejects_negatives_with_clear_error() {
        for s in ["-1MiB", "-786432", "-0.5GiB", " -2KB "] {
            let err = parse_bytes(s).unwrap_err();
            assert!(err.contains("negative"), "{s}: {err}");
        }
    }

    #[test]
    fn memory_budget_parses_and_requires_sc() {
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "ed+sc".to_string());
        ov.insert("memory_budget".to_string(), "512MiB".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert_eq!(cfg.memory_budget, Some(512 * 1024 * 1024));
        // budget without S-C is a config error
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "ed".to_string());
        ov.insert("memory_budget".to_string(), "512MiB".to_string());
        let err = TrainConfig::from_sources(None, &ov).unwrap_err();
        assert!(err.contains("S-C"), "{err}");
        // junk rejected with the key named
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "sc".to_string());
        ov.insert("memory_budget".to_string(), "lots".to_string());
        let err = TrainConfig::from_sources(None, &ov).unwrap_err();
        assert!(err.contains("memory_budget"), "{err}");
    }

    #[test]
    fn offload_knobs_parse_and_validate() {
        let mut ov = BTreeMap::new();
        ov.insert("pipeline".to_string(), "sc".to_string());
        ov.insert("host_bw".to_string(), "4GiB".to_string());
        ov.insert("spill_lookahead".to_string(), "3".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert_eq!(cfg.host_bw, 4 * 1024 * 1024 * 1024);
        assert_eq!(cfg.spill_lookahead, 3);
        // defaults
        let d = TrainConfig::default_for("m", Pipeline::BASELINE);
        assert_eq!(d.host_bw, crate::memory::offload::DEFAULT_HOST_BW_BYTES_PER_SEC);
        assert_eq!(d.spill_lookahead, 2);
        // zero lookahead rejected
        let mut ov = BTreeMap::new();
        ov.insert("spill_lookahead".to_string(), "0".to_string());
        let err = TrainConfig::from_sources(None, &ov).unwrap_err();
        assert!(err.contains("spill_lookahead"), "{err}");
        // junk bandwidth rejected with the key named
        let mut ov = BTreeMap::new();
        ov.insert("host_bw".to_string(), "fast".to_string());
        let err = TrainConfig::from_sources(None, &ov).unwrap_err();
        assert!(err.contains("host_bw"), "{err}");
    }

    #[test]
    fn faults_and_watchdog_parse() {
        let mut ov = BTreeMap::new();
        ov.insert("faults".to_string(), "seed=9;worker-panic@3;link-fail:0.1".to_string());
        ov.insert("loader_watchdog_secs".to_string(), "30".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        let spec = cfg.faults.unwrap();
        assert_eq!(spec.seed, 9);
        assert_eq!(spec.events.len(), 2);
        assert_eq!(cfg.loader_watchdog_secs, Some(30));
        // defaults: no faults, no watchdog
        let d = TrainConfig::default_for("m", Pipeline::BASELINE);
        assert!(d.faults.is_none());
        assert!(d.loader_watchdog_secs.is_none());
        // a seed-only spec injects nothing and normalizes to None
        let mut ov = BTreeMap::new();
        ov.insert("faults".to_string(), "seed=4".to_string());
        assert!(TrainConfig::from_sources(None, &ov).unwrap().faults.is_none());
        // watchdog 0 = disabled
        let mut ov = BTreeMap::new();
        ov.insert("loader_watchdog_secs".to_string(), "0".to_string());
        assert!(TrainConfig::from_sources(None, &ov).unwrap().loader_watchdog_secs.is_none());
        // junk rejected with the key named
        let mut ov = BTreeMap::new();
        ov.insert("faults".to_string(), "meteor-strike@1".to_string());
        let err = TrainConfig::from_sources(None, &ov).unwrap_err();
        assert!(err.contains("faults"), "{err}");
    }

    #[test]
    fn trace_path_parses() {
        let mut ov = BTreeMap::new();
        ov.insert("trace".to_string(), "out/trace.json".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert_eq!(cfg.trace, Some(PathBuf::from("out/trace.json")));
        // default off; empty string normalizes to off
        assert!(TrainConfig::default_for("m", Pipeline::BASELINE).trace.is_none());
        let mut ov = BTreeMap::new();
        ov.insert("trace".to_string(), String::new());
        assert!(TrainConfig::from_sources(None, &ov).unwrap().trace.is_none());
    }

    #[test]
    fn metrics_addr_and_memlog_parse() {
        let mut ov = BTreeMap::new();
        ov.insert("metrics_addr".to_string(), "127.0.0.1:9184".to_string());
        ov.insert("memlog".to_string(), "out/mem.csv".to_string());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert_eq!(cfg.metrics_addr.as_deref(), Some("127.0.0.1:9184"));
        assert_eq!(cfg.memlog, Some(PathBuf::from("out/mem.csv")));
        // defaults off; empty strings normalize to off
        let d = TrainConfig::default_for("m", Pipeline::BASELINE);
        assert!(d.metrics_addr.is_none());
        assert!(d.memlog.is_none());
        let mut ov = BTreeMap::new();
        ov.insert("metrics_addr".to_string(), String::new());
        ov.insert("memlog".to_string(), String::new());
        let cfg = TrainConfig::from_sources(None, &ov).unwrap();
        assert!(cfg.metrics_addr.is_none());
        assert!(cfg.memlog.is_none());
        // a junk address is rejected with the key named
        let mut ov = BTreeMap::new();
        ov.insert("metrics_addr".to_string(), "localhost".to_string());
        let err = TrainConfig::from_sources(None, &ov).unwrap_err();
        assert!(err.contains("metrics_addr"), "{err}");
    }

    #[test]
    fn artifact_stem_format() {
        let cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse("ed+mp").unwrap());
        assert_eq!(cfg.artifact_stem(), "tiny_cnn_ed_mp");
    }
}
