//! The training coordinator: composes dataset, SBS sampler, (parallel)
//! loader, PJRT runtime and metrics into the paper's training pipelines.

pub mod report;
pub mod schedule;
pub mod trainer;

pub use schedule::LrSchedule;
pub use trainer::{Trainer, TrainReport};
