//! Report writers: CSV + figure-series emission shared by examples and
//! benches (`reports/` directory by default).
//!
//! The per-stage memory summaries (checkpoint plan, arena, host-spill
//! offload, frontier tables) live in [`crate::memory::outcome`] — the one
//! set of renderers the trainer report and `plan --json`/`PlanOutcome`
//! share — and are re-exported here for the examples and benches that
//! always imported them from this module.

use crate::coordinator::TrainReport;
use crate::memory::simulator::MemoryReport;
use crate::trace::{CounterRegistry, PhaseStat};
use std::io::Write;
use std::path::Path;

pub use crate::memory::outcome::{
    arena_summary, frontier_csv, frontier_markdown, frontier_table, offload_summary,
    plan_summary,
};

/// Write the per-epoch history CSV.
pub fn write_history_csv(path: &Path, report: &TrainReport) -> std::io::Result<()> {
    if let Some(parent) = path.parent() {
        std::fs::create_dir_all(parent)?;
    }
    std::fs::File::create(path)?.write_all(report.history.to_csv().as_bytes())
}

/// Figure-8-style timeline CSV: `event_index,label,live_mb`.
pub fn timeline_csv(report: &MemoryReport) -> String {
    let mut s = String::from("event,label,live_mb\n");
    for (i, e) in report.timeline.iter().enumerate() {
        s.push_str(&format!(
            "{i},{},{:.1}\n",
            e.label.replace(',', ";"),
            e.live_bytes as f64 / (1024.0 * 1024.0)
        ));
    }
    s
}

/// Figure-9-style row: model, pipeline, wall seconds, accuracy.
pub fn fig9_row(report: &TrainReport) -> String {
    format!(
        "{},{},{:.1},{:.4}\n",
        report.model, report.pipeline, report.total_wall_secs, report.final_eval_accuracy
    )
}

/// Markdown summary of one run (EXPERIMENTS.md fragments).
pub fn markdown_summary(report: &TrainReport) -> String {
    let mut s = format!(
        "### {} / {}\n\n| epoch | train loss | train acc | eval acc | wall s |\n|---|---|---|---|---|\n",
        report.model, report.pipeline
    );
    for e in &report.history.epochs {
        s.push_str(&format!(
            "| {} | {:.4} | {:.3} | {} | {:.1} |\n",
            e.epoch,
            e.train_loss,
            e.train_accuracy,
            e.eval_accuracy
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "—".into()),
            e.wall_secs
        ));
    }
    s.push_str(&format!(
        "\nfinal eval accuracy **{:.3}**, total {:.1}s (producer {:.1}s, blocked {:.1}s)\n",
        report.final_eval_accuracy,
        report.total_wall_secs,
        report.loader_produce_secs,
        report.loader_blocked_secs
    ));
    s.push_str(&loader_summary(report));
    if let Some(plan) = &report.plan {
        s.push_str(&plan_summary(plan));
    }
    if let Some(arena) = &report.arena {
        s.push_str(&arena_summary(arena));
    }
    if let Some(offload) = &report.offload {
        s.push_str(&offload_summary(offload));
    }
    if let Some(d) = &report.degradation {
        s.push_str(&d.to_markdown());
        s.push('\n');
    }
    if !report.phase_stats.is_empty() {
        s.push_str(&phase_table(&report.phase_stats));
    }
    if let Some(d) = &report.drift {
        s.push_str(&d.to_markdown_line());
        s.push('\n');
    }
    if let Some(m) = &report.mem {
        s.push_str(&m.to_markdown_line());
        s.push('\n');
    }
    if !report.counters.is_empty() {
        s.push_str(&counter_summary(&report.counters));
    }
    s
}

/// Markdown table of per-phase wall-time quantiles from a traced run
/// (`trace=PATH`): one row per span name, p50/p95/p99.
pub fn phase_table(stats: &[PhaseStat]) -> String {
    let mut s = String::from(
        "\nphase timings:\n\n| phase | count | p50 | p95 | p99 |\n|---|---|---|---|---|\n",
    );
    for p in stats {
        s.push_str(&format!(
            "| {} | {} | {} | {} | {} |\n",
            p.name,
            p.count,
            fmt_secs(p.p50_secs),
            fmt_secs(p.p95_secs),
            fmt_secs(p.p99_secs)
        ));
    }
    s
}

fn fmt_secs(v: f64) -> String {
    if v >= 1.0 {
        format!("{v:.2} s")
    } else if v >= 1e-3 {
        format!("{:.2} ms", v * 1e3)
    } else {
        format!("{:.1} µs", v * 1e6)
    }
}

/// One-line rendering of the unified counter registry (name order, so
/// output is byte-stable across runs with the same counts).
pub fn counter_summary(counters: &CounterRegistry) -> String {
    let mut s = String::from("\ncounters: ");
    for (i, (name, v)) in counters.iter().enumerate() {
        if i > 0 {
            s.push_str(" · ");
        }
        s.push_str(&format!("{name} {v}"));
    }
    s.push('\n');
    s
}

/// One-line producer-pool summary: per-worker overlap accounting plus the
/// buffer-pool counters (how to read them: `produce` is time the worker
/// spent materializing+encoding, `blocked` is backpressure wait; pool
/// `allocs` flat across epochs ⇒ the hot path ran allocation-free).
pub fn loader_summary(report: &TrainReport) -> String {
    let mut s = String::new();
    if !report.loader_workers.is_empty() {
        s.push_str("loader workers: ");
        for (i, w) in report.loader_workers.iter().enumerate() {
            if i > 0 {
                s.push_str(" · ");
            }
            s.push_str(&format!(
                "w{i} {:.1}s+{:.1}s/{}b",
                w.produce_secs, w.blocked_secs, w.batches
            ));
        }
        s.push('\n');
    }
    s.push_str(&format!(
        "buffer pool: {} allocs, {} reuses\n",
        report.pool_allocs, report.pool_reuses
    ));
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pipeline;
    use crate::memory::arena::ArenaReport;
    use crate::memory::offload::OffloadReport;
    use crate::memory::planner::CheckpointPlan;
    use crate::memory::simulator::simulate;
    use crate::metrics::{EpochRecord, History};
    use crate::models::arch_by_name;

    fn fake_report() -> TrainReport {
        let mut history = History::default();
        history.push(EpochRecord {
            epoch: 0,
            train_loss: 1.9,
            train_accuracy: 0.3,
            eval_loss: Some(1.8),
            eval_accuracy: Some(0.35),
            wall_secs: 2.0,
            images: 320,
            step_p50_secs: None,
            step_p99_secs: None,
            slab_high_water_bytes: 0,
            host_resident_bytes: 0,
        });
        TrainReport {
            model: "tiny_cnn".into(),
            pipeline: "ed_sc".into(),
            history,
            final_eval_accuracy: 0.35,
            final_eval_loss: 1.8,
            total_wall_secs: 2.0,
            loader_produce_secs: 0.4,
            loader_blocked_secs: 0.1,
            loader_workers: vec![
                crate::data::loader::WorkerSummary {
                    produce_secs: 0.3,
                    blocked_secs: 0.05,
                    batches: 12,
                    scratch_fallbacks: 0,
                },
                crate::data::loader::WorkerSummary {
                    produce_secs: 0.1,
                    blocked_secs: 0.05,
                    batches: 8,
                    scratch_fallbacks: 0,
                },
            ],
            pool_allocs: 9,
            pool_reuses: 151,
            plan: Some(CheckpointPlan {
                kind: crate::memory::planner::PlannerKind::Optimal,
                checkpoints: vec![2, 5],
                peak_bytes: 3 * 1024 * 1024,
                recompute_overhead: 0.42,
            }),
            arena: Some(ArenaReport {
                slab_bytes: 2 * 1024 * 1024,
                base_bytes: 1024 * 1024,
                peak_bytes: 2_900_000,
                tensor_count: 17,
                fragmentation: 1.08,
                by_class: vec![
                    crate::memory::arena::ClassStat {
                        class: crate::memory::arena::TensorClass::Checkpoint,
                        count: 3,
                        bytes: 512 * 1024,
                    },
                    crate::memory::arena::ClassStat {
                        class: crate::memory::arena::TensorClass::ParamGrad,
                        count: 8,
                        bytes: 256 * 1024,
                    },
                ],
            }),
            offload: None,
            degradation: None,
            phase_stats: Vec::new(),
            counters: CounterRegistry::new(),
            drift: None,
            mem: None,
        }
    }

    fn fake_offload() -> OffloadReport {
        OffloadReport {
            budget: 3 * 1024 * 1024,
            device_total: 2_900_000,
            spilled_tensors: 4,
            spilled_bytes: 512 * 1024,
            host_peak_bytes: 384 * 1024,
            predicted_stall_secs: 0.0012,
            predicted_step_secs: 0.016,
            host_bw_bytes_per_sec: 12 * (1 << 30),
            lookahead: 2,
            evictions: 0,
            prefetches: 0,
            pool_hit_rate: 0.0,
            link_faults: 0,
            link_retries: 0,
            retry_stall_secs: 0.0,
        }
    }

    #[test]
    fn csv_roundtrip_to_disk() {
        let dir = std::env::temp_dir().join(format!("optorch_report_{}", std::process::id()));
        let path = dir.join("history.csv");
        write_history_csv(&path, &fake_report()).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("epoch,"));
        assert_eq!(text.lines().count(), 2);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn timeline_csv_has_all_events() {
        let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let r = simulate(&arch, Pipeline::BASELINE, 4, &[]);
        let csv = timeline_csv(&r);
        assert_eq!(csv.lines().count(), r.timeline.len() + 1);
    }

    #[test]
    fn fig9_row_format() {
        let row = fig9_row(&fake_report());
        assert_eq!(row.trim().split(',').count(), 4);
        assert!(row.starts_with("tiny_cnn,ed_sc,"));
    }

    #[test]
    fn markdown_mentions_final_accuracy() {
        let md = markdown_summary(&fake_report());
        assert!(md.contains("**0.350**"));
        assert!(md.contains("| 0 |"));
    }

    #[test]
    fn markdown_includes_worker_and_pool_stats() {
        let md = markdown_summary(&fake_report());
        assert!(md.contains("loader workers:"), "{md}");
        assert!(md.contains("w0 0.3s+0.1s/12b"), "{md}");
        assert!(md.contains("w1"), "{md}");
        assert!(md.contains("buffer pool: 9 allocs, 151 reuses"), "{md}");
    }

    #[test]
    fn loader_summary_omits_worker_line_for_sync_runs() {
        let mut rep = fake_report();
        rep.loader_workers.clear();
        let s = loader_summary(&rep);
        assert!(!s.contains("loader workers"));
        assert!(s.contains("buffer pool"));
    }

    #[test]
    fn markdown_includes_checkpoint_plan_line() {
        let md = markdown_summary(&fake_report());
        assert!(md.contains("checkpoint plan: 2 checkpoints [2, 5]"), "{md}");
        assert!(md.contains("+42.0% fwd FLOPs"), "{md}");
        let mut rep = fake_report();
        rep.plan = None;
        assert!(!markdown_summary(&rep).contains("checkpoint plan"));
    }

    #[test]
    fn markdown_includes_arena_line() {
        let md = markdown_summary(&fake_report());
        assert!(md.contains("activation arena: slab 2.0 MiB"), "{md}");
        assert!(md.contains("fragmentation 1.08x"), "{md}");
        assert!(md.contains("3 checkpoint · 8 param-grad"), "{md}");
        let mut rep = fake_report();
        rep.arena = None;
        assert!(!markdown_summary(&rep).contains("activation arena"));
    }

    #[test]
    fn markdown_includes_offload_line_when_spilling() {
        let mut rep = fake_report();
        assert!(!markdown_summary(&rep).contains("host-spill"));
        rep.offload = Some(fake_offload());
        let md = markdown_summary(&rep);
        assert!(md.contains("host-spill offload:"), "{md}");
        assert!(md.contains("4 checkpoints to host"), "{md}");
        assert!(md.contains("predicted stall 1.20 ms/step"), "{md}");
        // engine counters only appear once a run has filled them in
        assert!(!md.contains("host-spill engine:"), "{md}");
        let mut with_counters = fake_offload();
        with_counters.evictions = 400;
        with_counters.prefetches = 400;
        with_counters.pool_hit_rate = 0.99;
        rep.offload = Some(with_counters);
        let md = markdown_summary(&rep);
        assert!(md.contains("host-spill engine: 400 evictions"), "{md}");
        assert!(md.contains("pool hit rate 99.0%"), "{md}");
    }

    #[test]
    fn markdown_includes_degradation_and_link_fault_lines() {
        use crate::fault::{DegradationAction, DegradationReport, DegradeTrigger};
        let mut rep = fake_report();
        assert!(!markdown_summary(&rep).contains("degradation:"));
        rep.degradation = Some(DegradationReport {
            trigger: DegradeTrigger::BudgetShrink { from: Some(8 << 20), to: 2 << 20 },
            actions: vec![DegradationAction::SteppedDownFrontier {
                device_total: 1 << 20,
                recompute_overhead: 0.3,
            }],
            met_budget: true,
            budget: 2 << 20,
            device_total: 1 << 20,
            predicted_step_secs: Some(0.01),
        });
        let mut off = fake_offload();
        off.evictions = 12;
        off.link_faults = 5;
        off.link_retries = 3;
        off.retry_stall_secs = 0.002;
        rep.offload = Some(off);
        let md = markdown_summary(&rep);
        assert!(md.contains("degradation: budget shrink"), "{md}");
        assert!(md.contains("stepped down the frontier"), "{md}");
        assert!(md.contains("host-link faults: 5 observed, 3 transfers retried"), "{md}");
        // a healthy run never mentions the link-fault line
        let mut healthy = fake_report();
        healthy.offload = Some(fake_offload());
        assert!(!markdown_summary(&healthy).contains("host-link faults"));
    }

    #[test]
    fn markdown_includes_phase_table_drift_and_counters() {
        let mut rep = fake_report();
        let md = markdown_summary(&rep);
        assert!(!md.contains("phase timings"), "{md}");
        assert!(!md.contains("counters:"), "{md}");
        rep.phase_stats = vec![PhaseStat {
            name: "train-step".into(),
            count: 100,
            p50_secs: 0.012,
            p95_secs: 0.015,
            p99_secs: 0.02,
        }];
        rep.counters.set("pool_allocs", 9);
        rep.counters.set("trace_dropped", 0);
        rep.drift = Some(crate::trace::DriftReport {
            predicted_step_secs: 0.016,
            observed_mean_secs: 0.018,
            observed_p50_secs: 0.017,
            observed_p99_secs: 0.02,
            steps: 100,
        });
        let md = markdown_summary(&rep);
        assert!(md.contains("| train-step | 100 | 12.00 ms | 15.00 ms | 20.00 ms |"), "{md}");
        assert!(md.contains("drift: predicted 0.016000 s/step"), "{md}");
        assert!(md.contains("counters: pool_allocs 9 · trace_dropped 0"), "{md}");
    }

    #[test]
    fn markdown_includes_mem_watermark_line() {
        let mut rep = fake_report();
        assert!(!markdown_summary(&rep).contains("mem-watermark:"));
        rep.mem = Some(crate::obs::MemWatermarkReport {
            predicted_peak_bytes: 3 * 1024 * 1024,
            predicted_packed_bytes: 3 * 1024 * 1024 + 64 * 1024,
            predicted_host_peak_bytes: None,
            observed_peak_bytes: 3 * 1024 * 1024,
            observed_slab_high_water_bytes: 2 * 1024 * 1024,
            observed_host_peak_bytes: 0,
            steps: 40,
        });
        let md = markdown_summary(&rep);
        assert!(md.contains("mem-watermark: predicted peak 3.0 MiB"), "{md}");
        assert!(md.contains("no spill over 40 steps"), "{md}");
    }

    #[test]
    fn frontier_outputs_cover_every_plan() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let frontier =
            crate::memory::planner::pareto_frontier(&arch, Pipeline::BASELINE, 8, 12);
        let csv = frontier_csv(&frontier);
        assert_eq!(csv.lines().count(), frontier.len() + 1);
        assert!(csv.starts_with("peak_mb,"));
        let md = frontier_markdown(&frontier);
        assert_eq!(md.lines().count(), frontier.len() + 2);
    }
}
