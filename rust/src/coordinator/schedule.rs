//! Learning-rate schedules — driven from the coordinator per epoch.
//!
//! The train-step artifacts take the LR as a runtime scalar input, so
//! schedules need no recompilation. Parse from config strings:
//! `const:0.05`, `step:0.05:2:0.5` (halve every 2 epochs),
//! `cosine:0.05:10` (cosine decay to 0 over 10 epochs).

/// A learning-rate schedule.
#[derive(Clone, Debug, PartialEq)]
pub enum LrSchedule {
    Const(f64),
    /// base, every-N-epochs, multiplicative factor.
    Step { base: f64, every: usize, factor: f64 },
    /// base, total epochs (cosine from base to ~0).
    Cosine { base: f64, total: usize },
}

impl LrSchedule {
    pub fn parse(s: &str) -> Result<LrSchedule, String> {
        let parts: Vec<&str> = s.split(':').collect();
        let f = |i: usize| -> Result<f64, String> {
            parts
                .get(i)
                .ok_or_else(|| format!("schedule '{s}': missing field {i}"))?
                .parse()
                .map_err(|_| format!("schedule '{s}': bad number at field {i}"))
        };
        let u = |i: usize| -> Result<usize, String> {
            parts
                .get(i)
                .ok_or_else(|| format!("schedule '{s}': missing field {i}"))?
                .parse()
                .map_err(|_| format!("schedule '{s}': bad integer at field {i}"))
        };
        match parts[0] {
            "const" => Ok(LrSchedule::Const(f(1)?)),
            "step" => {
                let every = u(2)?;
                if every == 0 {
                    return Err(format!("schedule '{s}': every must be ≥ 1"));
                }
                Ok(LrSchedule::Step { base: f(1)?, every, factor: f(3)? })
            }
            "cosine" => {
                let total = u(2)?;
                if total == 0 {
                    return Err(format!("schedule '{s}': total must be ≥ 1"));
                }
                Ok(LrSchedule::Cosine { base: f(1)?, total })
            }
            other => Err(format!("unknown schedule kind '{other}' (const|step|cosine)")),
        }
    }

    /// LR for the given epoch (0-based).
    pub fn at(&self, epoch: usize) -> f64 {
        match self {
            LrSchedule::Const(lr) => *lr,
            LrSchedule::Step { base, every, factor } => {
                base * factor.powi((epoch / every) as i32)
            }
            LrSchedule::Cosine { base, total } => {
                let t = (epoch.min(*total) as f64) / (*total as f64);
                base * 0.5 * (1.0 + (std::f64::consts::PI * t).cos())
            }
        }
    }
}

impl Default for LrSchedule {
    fn default() -> Self {
        LrSchedule::Const(0.05)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_all_kinds() {
        assert_eq!(LrSchedule::parse("const:0.1").unwrap(), LrSchedule::Const(0.1));
        assert_eq!(
            LrSchedule::parse("step:0.1:2:0.5").unwrap(),
            LrSchedule::Step { base: 0.1, every: 2, factor: 0.5 }
        );
        assert_eq!(
            LrSchedule::parse("cosine:0.1:10").unwrap(),
            LrSchedule::Cosine { base: 0.1, total: 10 }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(LrSchedule::parse("linear:0.1").is_err());
        assert!(LrSchedule::parse("const").is_err());
        assert!(LrSchedule::parse("step:0.1:0:0.5").is_err());
        assert!(LrSchedule::parse("cosine:0.1:x").is_err());
    }

    #[test]
    fn const_is_flat() {
        let s = LrSchedule::Const(0.05);
        assert_eq!(s.at(0), 0.05);
        assert_eq!(s.at(100), 0.05);
    }

    #[test]
    fn step_decays_every_n() {
        let s = LrSchedule::Step { base: 0.1, every: 2, factor: 0.5 };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!((s.at(1) - 0.1).abs() < 1e-12);
        assert!((s.at(2) - 0.05).abs() < 1e-12);
        assert!((s.at(5) - 0.025).abs() < 1e-12);
    }

    #[test]
    fn cosine_decays_to_zero() {
        let s = LrSchedule::Cosine { base: 0.1, total: 10 };
        assert!((s.at(0) - 0.1).abs() < 1e-12);
        assert!(s.at(5) < 0.06);
        assert!(s.at(10) < 1e-12);
        // clamped past the horizon
        assert!(s.at(20) < 1e-12);
    }

    #[test]
    fn monotone_nonincreasing() {
        for s in [
            LrSchedule::Step { base: 0.1, every: 3, factor: 0.3 },
            LrSchedule::Cosine { base: 0.1, total: 8 },
        ] {
            let mut prev = f64::INFINITY;
            for e in 0..12 {
                let v = s.at(e);
                assert!(v <= prev + 1e-12, "{s:?} rose at epoch {e}");
                prev = v;
            }
        }
    }
}
