//! Epoch/step training loop over the AOT artifacts.

use crate::config::{DatasetChoice, TrainConfig};
use crate::data::augment::AugPolicy;
use crate::data::dataset::Dataset;
use crate::data::encode::encode_batch_grouped;
use crate::data::image::ImageBatch;
use crate::data::loader::{BatchPayload, EdLoader, LoaderError, LoaderStats, WorkerSummary};
use crate::data::pool::BufferPool;
use crate::data::sampler::SbsSampler;
use crate::data::synth::{Split, SynthCifar};
use crate::fault::{DegradationReport, DegradeTrigger, FaultInjector};
use crate::memory::arena::ArenaReport;
use crate::memory::offload::{LinkFaults, OffloadReport};
use crate::memory::outcome::PlanOutcome;
use crate::memory::pipeline::{PlanError, PlanRequest};
use crate::memory::planner::CheckpointPlan;
use crate::metrics::{EpochRecord, Histogram, History, Mean, Timer};
use crate::obs::{MemTimeline, MemWatermarkReport, MetricsHub, ObsServer, StepSample};
use crate::runtime::{LoadedModel, Runtime, TrainState};
use crate::trace::{CounterRegistry, DriftReport, PhaseStat, Tracer};
use crate::{debug, info, warn_};
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::Ordering;
use std::sync::Arc;
use std::time::Duration;

/// Result of a full training run.
#[derive(Clone, Debug)]
pub struct TrainReport {
    pub model: String,
    pub pipeline: String,
    pub history: History,
    pub final_eval_accuracy: f64,
    pub final_eval_loss: f64,
    pub total_wall_secs: f64,
    /// Producer-side seconds (encode+augment) — Fig 1 overlap accounting,
    /// summed over all producer workers and epochs.
    pub loader_produce_secs: f64,
    pub loader_blocked_secs: f64,
    /// Per-worker produce/blocked/batch totals (empty for synchronous
    /// loaders; one entry for the legacy single-producer mode).
    pub loader_workers: Vec<WorkerSummary>,
    /// Buffer-pool counters over the whole run: hot-path allocations and
    /// recycled-buffer hits. At steady state `pool_allocs` stops growing.
    pub pool_allocs: u64,
    pub pool_reuses: u64,
    /// The checkpoint plan the run trained under (S-C pipelines with a
    /// known architecture profile): simulated peak, placement, recompute
    /// overhead — and, with `memory_budget` set, the cheapest-time
    /// frontier point that fit the budget.
    pub plan: Option<CheckpointPlan>,
    /// The packed activation-arena layout for that plan: slab size vs the
    /// exact simulated peak (fragmentation) and per-class tensor totals.
    /// When host spilling is active this describes the *resident* layout.
    pub arena: Option<ArenaReport>,
    /// The host-spill composition, when the budget sat below every pure
    /// recompute frontier point: spilled bytes, predicted stall, and the
    /// runtime engine's transfer/pool counters.
    pub offload: Option<OffloadReport>,
    /// The graceful-degradation episode, when an injected (or real)
    /// mid-run fault forced a re-plan down the ladder: what triggered it,
    /// every rung taken, and where the plan landed.
    pub degradation: Option<DegradationReport>,
    /// Per-phase wall-time quantiles (p50/p95/p99) aggregated from the
    /// structured tracer's span timeline — empty unless the run traced
    /// (`trace=PATH` / `--trace`).
    pub phase_stats: Vec<PhaseStat>,
    /// Unified named-counter registry: the pipeline's pool, fault, link
    /// and tracer counters in one deterministically-ordered table.
    pub counters: CounterRegistry,
    /// Predicted-vs-observed step time, when the planner produced a
    /// `predicted_step_secs` (host-spill compositions) and at least one
    /// train step was timed.
    pub drift: Option<DriftReport>,
    /// Predicted-vs-observed memory watermarks — the DP peak, packed slab
    /// total and spilled host floor against the per-step high-water marks
    /// the run touched. `None` when the run staged no lifetimes or took
    /// no train steps.
    pub mem: Option<MemWatermarkReport>,
}

/// Orchestrates one training run.
pub struct Trainer {
    cfg: TrainConfig,
    model: LoadedModel,
    state: TrainState,
    train_data: Arc<dyn Dataset>,
    test_data: Arc<dyn Dataset>,
    history: History,
    produce_secs: f64,
    blocked_secs: f64,
    /// Per-worker accumulators across epochs (the loader is epoch-scoped).
    worker_acc: Vec<WorkerSummary>,
    /// Payload buffers recycle through this pool across all epochs
    /// (§Perf iteration 3) — see [`crate::data::pool`].
    pool: Arc<BufferPool>,
    /// Eval batches are deterministic — built once, reused every epoch
    /// (§Perf iteration 2).
    eval_cache: Option<Vec<BatchPayload>>,
    /// Checkpoint plan selected for S-C pipelines (see [`TrainReport::plan`]).
    plan: Option<CheckpointPlan>,
    /// Packed arena layout for that plan (see [`TrainReport::arena`]).
    arena: Option<ArenaReport>,
    /// Host-spill summary when the budget forced offloading
    /// (see [`TrainReport::offload`]).
    offload: Option<OffloadReport>,
    /// Deterministic fault injector shared with the loader's producers
    /// (`None` when the config injects nothing).
    faults: Option<Arc<FaultInjector>>,
    /// Global train-step counter across epochs — the clock fire-once
    /// fault events key on.
    global_step: usize,
    /// Last degradation episode (see [`TrainReport::degradation`]).
    degradation: Option<DegradationReport>,
    /// Structured tracer behind every instrumented thread (loader
    /// workers, offload link, train loop). Disabled unless `cfg.trace`
    /// names an output path; disabled it costs one branch per event site.
    tracer: Tracer,
    /// Nanosecond `train_step_lr` durations across the whole run —
    /// recorded unconditionally (one `Instant::now` pair per step) so
    /// drift and the CSV step quantiles work without tracing.
    step_hist: Histogram,
    /// Loader counters accumulated across the epoch-scoped loaders.
    respawns: u64,
    corruptions: u64,
    /// Live metrics hub behind `/metrics` and the `--memlog` timeline.
    /// Always recording — one ring push plus a few relaxed atomics per
    /// step, never a hot-path allocation.
    hub: Arc<MetricsHub>,
    /// HTTP listener serving the hub's exposition and health probes
    /// (`None` unless `metrics_addr` is configured). Held for its thread:
    /// dropping the trainer shuts the listener down.
    obs_server: Option<ObsServer>,
    /// Per-schedule-step live-bytes replay of the resident plan, kept in
    /// lockstep with `plan` across degradation replans.
    mem_timeline: Option<MemTimeline>,
    /// Every recorded step sample, kept only when `memlog` names a path
    /// (the hub's ring is a bounded scrape window, not an archive).
    memlog_rows: Vec<StepSample>,
}

/// Link-fault parameters for the offload engine, distilled from the
/// injector's spec (`None` when the spec carries no link faults).
fn link_faults_for(faults: Option<&FaultInjector>, host_bw: u64) -> Option<LinkFaults> {
    let f = faults?;
    if !f.has_link_faults() {
        return None;
    }
    Some(LinkFaults {
        seed: f.seed(),
        fail_prob: f.link_fail_prob(),
        slow: f.link_slow(),
        bytes_per_sec: host_bw as f64,
        ..LinkFaults::default()
    })
}

/// Choose the run's memory plan for an S-C pipeline — one
/// [`PlanRequest`] drive of the whole plan → pack → spill stack. Without
/// a budget: the exact minimum-peak plan, packed into an arena layout.
/// With a budget: every Pareto-frontier point is ranked by its *packed*
/// total (`base + slab`), the cheapest host-spill composition is planned
/// for points that do not fit, and the minimum-predicted-step-time
/// candidate wins — an error names the smallest achievable device total
/// when even full spilling cannot reach the budget. `None` when the model
/// has no analytic profile to plan over (tolerated only without a
/// budget).
fn select_plan(
    cfg: &TrainConfig,
    input: (usize, usize, usize),
    classes: usize,
) -> Result<Option<PlanOutcome>> {
    if !cfg.pipeline.sc {
        return Ok(None);
    }
    let mut request = PlanRequest::for_model(&cfg.model, input, classes)
        .pipeline(cfg.pipeline)
        .batch(cfg.batch_size)
        .planner_named(&cfg.planner)
        .grad_spill(cfg.grad_spill)
        .host_bw(cfg.host_bw)
        .spill_lookahead(cfg.spill_lookahead);
    if let Some(budget) = cfg.memory_budget {
        request = request.memory_budget(budget);
    }
    let outcome = match request.run() {
        Ok(outcome) => outcome,
        Err(PlanError::UnknownArch { .. }) if cfg.memory_budget.is_none() => {
            debug!("no architecture profile for '{}': skipping checkpoint planning", cfg.model);
            return Ok(None);
        }
        Err(e @ PlanError::UnknownArch { .. }) => {
            // An explicit budget that cannot be honored must not be
            // silently dropped.
            bail!("memory_budget is set but {e}");
        }
        Err(e) => return Err(anyhow!(e.to_string())),
    };
    if let Some(report) = outcome.offload_report() {
        info!(
            "host-spill offload for {}: {} checkpoints + {} param-grads to host ({} KiB), \
             device {} KiB ≤ budget {} KiB, predicted stall {:.2} ms/step",
            cfg.model,
            report.spilled_tensors - report.spilled_grad_tensors,
            report.spilled_grad_tensors,
            report.spilled_bytes / 1024,
            report.device_total / 1024,
            report.budget / 1024,
            report.predicted_stall_secs * 1e3
        );
    }
    info!(
        "checkpoint plan for {}: {} checkpoints, simulated peak {} KiB, recompute +{:.1}% fwd FLOPs",
        cfg.model,
        outcome.plan.checkpoints.len(),
        outcome.plan.peak_bytes / 1024,
        outcome.plan.recompute_overhead * 100.0
    );
    if let Some(arena) = &outcome.arena {
        info!(
            "activation arena for {}: slab {} KiB over {} tensors, fragmentation {:.2}x",
            cfg.model,
            arena.slab_bytes / 1024,
            arena.tensor_count,
            arena.fragmentation
        );
    }
    Ok(Some(outcome))
}

fn make_dataset(choice: DatasetChoice, split: Split, len: usize, seed: u64) -> Result<Arc<dyn Dataset>> {
    Ok(match choice {
        DatasetChoice::Synth10 => Arc::new(SynthCifar::cifar10(split, len, seed)),
        DatasetChoice::Synth100 => Arc::new(SynthCifar::cifar100(split, len, seed)),
        DatasetChoice::Cifar10 => {
            let d = crate::data::cifar::Cifar10::discover(split == Split::Train)
                .ok_or_else(|| anyhow!("real CIFAR-10 not found (set OPTORCH_CIFAR_DIR)"))?;
            Arc::new(d)
        }
    })
}

impl Trainer {
    /// Build a trainer: datasets + runtime + compiled artifacts + init state.
    pub fn from_config(cfg: &TrainConfig) -> Result<Trainer> {
        cfg.validate().map_err(|e| anyhow!(e))?;
        let mut runtime = Runtime::new(&cfg.artifacts_dir)?;
        let model = runtime.load(&cfg.model, &cfg.pipeline.name())?;
        if model.entry.batch_size != cfg.batch_size {
            bail!(
                "artifact for {}/{} was compiled for batch_size={}, config asks {} \
                 (re-run aot.py to add more batch sizes)",
                cfg.model,
                cfg.pipeline.name(),
                model.entry.batch_size,
                cfg.batch_size
            );
        }
        let num_classes = model.entry.num_classes;
        let train_data = make_dataset(cfg.dataset, Split::Train, cfg.train_size, cfg.seed)?;
        let test_data = make_dataset(cfg.dataset, Split::Test, cfg.test_size, cfg.seed)?;
        if train_data.num_classes() != num_classes {
            bail!(
                "dataset has {} classes, artifact expects {num_classes}",
                train_data.num_classes()
            );
        }
        let (h, w, c) = train_data.shape();
        // An artifact compiled for a known device budget plans against it
        // unless the config names an explicit budget of its own.
        let mut plan_cfg = cfg.clone();
        if plan_cfg.memory_budget.is_none() && plan_cfg.pipeline.sc {
            if let Some(b) = model.entry.device_budget {
                info!("using the artifact's device budget: {} KiB", b / 1024);
                plan_cfg.memory_budget = Some(b);
            }
        }
        let faults = cfg
            .faults
            .as_ref()
            .filter(|s| !s.is_empty())
            .map(|s| Arc::new(FaultInjector::new(s)));
        if let Some(spec) = cfg.faults.as_ref().filter(|s| !s.is_empty()) {
            warn_!("fault injection active: {spec}");
        }
        let tracer = match cfg.trace {
            Some(_) => Tracer::enabled(),
            None => Tracer::disabled(),
        };
        let (plan, arena, offload, mem_timeline) = match select_plan(&plan_cfg, (h, w, c), num_classes)? {
            Some(outcome) => {
                let mem_timeline = MemTimeline::from_outcome(&outcome);
                let offload = match outcome.offload_report() {
                    Some(report) => {
                        // The runtime half replays the spill schedule
                        // (host-pool evictions/prefetches) every step.
                        model.configure_offload(outcome.spill.as_ref().expect("spilling outcome"));
                        model.configure_link_faults(link_faults_for(faults.as_deref(), cfg.host_bw));
                        if tracer.is_enabled() {
                            model.configure_trace(tracer.thread("offload/link"));
                        }
                        Some(report)
                    }
                    None => None,
                };
                (Some(outcome.plan), outcome.arena, offload, mem_timeline)
            }
            None => (None, None, None, None),
        };
        let hub = Arc::new(MetricsHub::new());
        let obs_server = crate::obs::spawn_obs_server(cfg.metrics_addr.as_deref(), &hub)?;
        if let Some(server) = &obs_server {
            info!(
                "metrics endpoint on http://{0}/metrics (health: /healthz, /readyz)",
                server.local_addr()
            );
        }
        let state = model.init_state(cfg.seed)?;
        info!(
            "initialized {}/{}: {} state tensors, {} KiB",
            cfg.model,
            cfg.pipeline.name(),
            state.len(),
            state.bytes() / 1024
        );
        Ok(Trainer {
            cfg: cfg.clone(),
            model,
            state,
            train_data,
            test_data,
            history: History::default(),
            produce_secs: 0.0,
            blocked_secs: 0.0,
            worker_acc: Vec::new(),
            pool: Arc::new(BufferPool::default()),
            eval_cache: None,
            plan,
            arena,
            offload,
            faults,
            global_step: 0,
            degradation: None,
            tracer,
            step_hist: Histogram::new(),
            respawns: 0,
            corruptions: 0,
            hub,
            obs_server,
            mem_timeline,
            memlog_rows: Vec::new(),
        })
    }

    /// The live metrics hub this run records into (what `/metrics`
    /// serves). Exposed so callers embedding the trainer can scrape or
    /// assert on the same series the HTTP endpoint would.
    pub fn metrics(&self) -> &Arc<MetricsHub> {
        &self.hub
    }

    /// Address the metrics endpoint actually bound (`None` unless
    /// `metrics_addr` was configured) — useful with port 0.
    pub fn metrics_local_addr(&self) -> Option<std::net::SocketAddr> {
        self.obs_server.as_ref().map(|s| s.local_addr())
    }

    /// The checkpoint plan this run trains under (S-C pipelines only).
    pub fn plan(&self) -> Option<&CheckpointPlan> {
        self.plan.as_ref()
    }

    /// The packed activation-arena summary for this run's plan.
    pub fn arena(&self) -> Option<&ArenaReport> {
        self.arena.as_ref()
    }

    /// The host-spill summary, when the budget forced offloading.
    pub fn offload(&self) -> Option<&OffloadReport> {
        self.offload.as_ref()
    }

    /// The last graceful-degradation episode, when a mid-run fault forced
    /// a re-plan down the ladder.
    pub fn degradation(&self) -> Option<&DegradationReport> {
        self.degradation.as_ref()
    }

    fn train_loader(&self, epoch: usize) -> Result<EdLoader> {
        let policy = AugPolicy::parse(&self.cfg.augment).map_err(|e| anyhow!(e))?;
        let sampler = SbsSampler::uniform(
            self.train_data.as_ref(),
            self.cfg.batch_size,
            policy,
            self.cfg.seed ^ (epoch as u64).wrapping_mul(0x9E37_79B9),
        )
        .map_err(|e| anyhow!(e.to_string()))?;
        let mut batches = sampler.batches_per_epoch(self.train_data.as_ref());
        if self.cfg.max_batches_per_epoch > 0 {
            batches = batches.min(self.cfg.max_batches_per_epoch);
        }
        Ok(EdLoader::with_observability(
            self.train_data.clone(),
            sampler,
            self.cfg.encode_spec(),
            batches,
            self.cfg.loader_mode(),
            self.pool.clone(),
            self.faults.clone(),
            self.cfg.loader_watchdog_secs.map(Duration::from_secs),
            self.tracer.clone(),
        ))
    }

    /// Absorb a mid-run device-budget shrink: walk the degradation ladder
    /// ([`PlanRequest::run_degraded`]) for the new budget, swap the
    /// runtime's spill engine for the re-planned one (or drop it on the
    /// heap-fallback rung) and record the episode for the report.
    fn replan_for_budget(&mut self, to: u64) -> Result<()> {
        let from = self
            .offload
            .as_ref()
            .map(|o| o.budget)
            .or(self.cfg.memory_budget);
        if !self.cfg.pipeline.sc {
            warn_!(
                "injected budget shrink to {} KiB ignored: pipeline has no S-C planning stage",
                to / 1024
            );
            return Ok(());
        }
        let (h, w, c) = self.train_data.shape();
        let request = PlanRequest::for_model(&self.cfg.model, (h, w, c), self.train_data.num_classes())
            .pipeline(self.cfg.pipeline)
            .batch(self.cfg.batch_size)
            .host_bw(self.cfg.host_bw)
            .spill_lookahead(self.cfg.spill_lookahead)
            .memory_budget(to);
        let (outcome, report) = request
            .run_degraded(DegradeTrigger::BudgetShrink { from, to })
            .map_err(|e| anyhow!("budget shrink to {to} B could not be re-planned: {e}"))?;
        warn_!(
            "device budget shrank to {} KiB at step {}: took {} degradation rung(s), \
             device total now {} KiB ({})",
            to / 1024,
            self.global_step,
            report.actions.len(),
            report.device_total / 1024,
            if report.met_budget { "budget met" } else { "budget MISSED" }
        );
        match outcome.spill.as_ref() {
            Some(spill) => {
                self.model.configure_offload(spill);
                self.model
                    .configure_link_faults(link_faults_for(self.faults.as_deref(), self.cfg.host_bw));
                // configure_offload replaced the engine (the old one
                // flushed its track on drop) — re-hand it a buffer.
                if self.tracer.is_enabled() {
                    self.model.configure_trace(self.tracer.thread("offload/link"));
                }
            }
            None => self.model.clear_offload(),
        }
        self.mem_timeline = MemTimeline::from_outcome(&outcome);
        self.plan = Some(outcome.plan.clone());
        self.arena = outcome.arena.clone();
        self.offload = outcome.offload_report();
        // The hub mirrors the episode so `/metrics` and `/readyz` agree
        // with the report: every rung counts, and readiness goes (and
        // stays) 503 once the ladder has been walked.
        self.hub.note_degrade_event(report.actions.len() as u64);
        self.degradation = Some(report);
        Ok(())
    }

    /// Sequential, augmentation-free eval batches matching the artifact's
    /// batch kind. Remainder images are dropped (fixed-shape artifacts).
    fn eval_payloads(&self) -> Vec<BatchPayload> {
        let b = self.cfg.batch_size;
        let n = (self.test_data.len() / b) * b;
        let (h, w, c) = self.test_data.shape();
        let k = self.test_data.num_classes();
        let mut out = Vec::new();
        for start in (0..n).step_by(b) {
            let mut batch = ImageBatch::zeros(b, h, w, c, k);
            for i in 0..b {
                let (img, label) = self.test_data.get(start + i);
                batch.put(i, &img, label);
            }
            let payload = match self.cfg.encode_spec() {
                None => BatchPayload::Raw {
                    data: batch.to_f32(),
                    labels: batch.labels.clone(),
                    n: b,
                },
                Some(spec) => {
                    BatchPayload::Encoded(encode_batch_grouped(&batch, spec).expect("encode"))
                }
            };
            out.push(payload);
        }
        out
    }

    /// Evaluate current state on the held-out split.
    pub fn evaluate(&mut self) -> Result<(f64, f64)> {
        if self.eval_cache.is_none() {
            self.eval_cache = Some(self.eval_payloads());
        }
        let mut loss = Mean::default();
        let mut acc = Mean::default();
        for payload in self.eval_cache.as_ref().unwrap() {
            let out = self.model.eval_step(&self.state, payload)?;
            loss.add_weighted(out.loss as f64, out.batch_size as u64);
            acc.add_weighted(out.accuracy(), out.batch_size as u64);
        }
        Ok((loss.mean(), acc.mean()))
    }

    /// Run one epoch; returns its record.
    pub fn run_epoch(&mut self, epoch: usize) -> Result<EpochRecord> {
        let timer = Timer::start();
        let mut loader = self.train_loader(epoch)?;
        let loader_stats: Arc<LoaderStats> = loader.stats();
        let lr = self.cfg.lr_schedule.at(epoch) as f32;
        let mut loss = Mean::default();
        let mut acc = Mean::default();
        let mut images: u64 = 0;
        let mut step = 0usize;
        // The train loop's own trace track, one per epoch: "next-batch"
        // and "train-step" spans plus fault instants. Flushed when the
        // tracer drops at the end of the epoch (abort paths included).
        let mut step_trace = self.tracer.thread("train/step");
        let mut epoch_hist = Histogram::new();
        let mut epoch_slab_hw = 0u64;
        let mut epoch_host_hw = 0u64;
        loop {
            let next0 = step_trace.begin();
            let payload = match loader.try_next() {
                Ok(Some(p)) => p,
                Ok(None) => break,
                // Typed loader failures (respawn budget exhausted, watchdog
                // stall, encode error) abort the epoch cleanly instead of
                // panicking the train thread.
                Err(e) => {
                    if matches!(e, LoaderError::Stalled { .. }) {
                        self.hub.set_watchdog_fired();
                    }
                    bail!("epoch {epoch} aborted: {e}");
                }
            };
            step_trace.end_span_arg(
                "next-batch",
                "train",
                next0,
                Some(("step", self.global_step as f64)),
            );
            // Fire-once budget shrinks key on the global step counter —
            // re-plan down the degradation ladder before the step runs.
            if let Some(faults) = self.faults.clone() {
                if let Some(to) = faults.budget_shrink_due(self.global_step) {
                    step_trace.instant_arg("budget-shrink", "fault", Some(("to_bytes", to as f64)));
                    self.replan_for_budget(to)?;
                    if let Some(report) = self.degradation.as_ref() {
                        for action in &report.actions {
                            step_trace.instant_label("degrade-rung", "fault", &action.to_string());
                        }
                    }
                }
            }
            let t0 = step_trace.begin();
            let started = std::time::Instant::now();
            let out = self.model.train_step_lr(&mut self.state, &payload, lr)?;
            let step_elapsed = started.elapsed();
            epoch_hist.record(step_elapsed.as_nanos() as u64);
            step_trace.end_span_arg(
                "train-step",
                "train",
                t0,
                Some(("step", self.global_step as f64)),
            );
            // Spent payload buffers go back to the pool for the producers;
            // this is what makes steady-state epochs allocation-free.
            loader.recycle(payload);
            loss.add_weighted(out.loss as f64, out.batch_size as u64);
            acc.add_weighted(out.accuracy(), out.batch_size as u64);
            images += out.batch_size as u64;
            step += 1;
            self.global_step += 1;
            // One metrics sample per step: the plan-side slab replay plus
            // the runtime engine/loader gauges. `record_step` is a ring
            // push and a few relaxed atomics — no allocation.
            let (scratch_used, scratch_hw) = {
                let arena = self.model.scratch_arena().borrow();
                (arena.used_bytes() as u64, arena.high_water_bytes() as u64)
            };
            let sample = StepSample {
                step: (self.global_step - 1) as u64,
                slab_high_water_bytes: self
                    .mem_timeline
                    .as_ref()
                    .map(MemTimeline::slab_high_water_bytes)
                    .unwrap_or(0),
                host_resident_bytes: self.model.offload_step_host_peak().unwrap_or(0),
                scratch_used_bytes: scratch_used,
                scratch_high_water_bytes: scratch_hw,
                link_retry_backlog: self
                    .model
                    .offload_stats()
                    .map(|s| s.link_retries)
                    .unwrap_or(0),
                loader_queue_depth: loader_stats.queue_depth(),
                degrade_rung: self.hub.degrade_rungs(),
                step_secs: step_elapsed.as_secs_f64(),
            };
            epoch_slab_hw = epoch_slab_hw.max(sample.slab_high_water_bytes);
            epoch_host_hw = epoch_host_hw.max(sample.host_resident_bytes);
            self.hub.record_step(sample);
            if self.cfg.memlog.is_some() {
                self.memlog_rows.push(sample);
            }
            if step % 50 == 0 {
                debug!(
                    "epoch {epoch} step {step}: loss {:.4} acc {:.3}",
                    loss.mean(),
                    acc.mean()
                );
            }
        }
        step_trace.finish();
        let stats = loader_stats;
        drop(loader); // joins producer threads → counters are final
        self.produce_secs += stats.produce_secs();
        self.blocked_secs += stats.blocked_secs();
        self.respawns += stats.respawns.load(Ordering::Relaxed);
        self.corruptions += stats.corruptions_detected.load(Ordering::Relaxed);
        let per_worker = stats.worker_summaries();
        if self.worker_acc.len() < per_worker.len() {
            self.worker_acc.resize(per_worker.len(), WorkerSummary::default());
        }
        for (acc_w, w) in self.worker_acc.iter_mut().zip(&per_worker) {
            acc_w.produce_secs += w.produce_secs;
            acc_w.blocked_secs += w.blocked_secs;
            acc_w.batches += w.batches;
        }
        let wall = timer.secs();
        let (eval_loss, eval_acc) = if self.cfg.eval_every > 0
            && (epoch + 1) % self.cfg.eval_every == 0
        {
            let (l, a) = self.evaluate()?;
            (Some(l), Some(a))
        } else {
            (None, None)
        };
        let (step_p50_secs, step_p99_secs) = if epoch_hist.is_empty() {
            (None, None)
        } else {
            (
                Some(epoch_hist.p50() as f64 / 1e9),
                Some(epoch_hist.p99() as f64 / 1e9),
            )
        };
        self.step_hist.merge(&epoch_hist);
        let rec = EpochRecord {
            epoch,
            train_loss: loss.mean(),
            train_accuracy: acc.mean(),
            eval_loss,
            eval_accuracy: eval_acc,
            wall_secs: wall,
            images,
            step_p50_secs,
            step_p99_secs,
            slab_high_water_bytes: epoch_slab_hw,
            host_resident_bytes: epoch_host_hw,
        };
        info!(
            "epoch {epoch}: loss {:.4} acc {:.3} eval_acc {} [{:.1}s, {:.0} img/s]",
            rec.train_loss,
            rec.train_accuracy,
            rec.eval_accuracy
                .map(|a| format!("{a:.3}"))
                .unwrap_or_else(|| "-".into()),
            rec.wall_secs,
            rec.images_per_sec()
        );
        Ok(rec)
    }

    /// Train for the configured number of epochs.
    pub fn run(&mut self) -> Result<TrainReport> {
        for epoch in 0..self.cfg.epochs {
            let rec = self.run_epoch(epoch)?;
            self.history.push(rec);
        }
        // ensure a final eval exists
        let (final_loss, final_acc) = match (
            self.history.epochs.last().and_then(|e| e.eval_loss),
            self.history.final_eval_accuracy(),
        ) {
            (Some(l), Some(a)) => (l, a),
            _ => self.evaluate()?,
        };
        // Fold the runtime engine's counters into the offload report.
        if let (Some(off), Some(stats)) = (self.offload.as_mut(), self.model.offload_stats()) {
            off.evictions = stats.evictions;
            off.prefetches = stats.prefetches;
            off.pool_hit_rate = stats.hit_rate();
            off.link_faults = stats.link_faults;
            off.link_retries = stats.link_retries;
            off.retry_stall_secs = stats.retry_stall_secs;
        }
        // The unified counter table absorbs the previously ad-hoc
        // counters; names sort deterministically (BTreeMap) for reports.
        let mut counters = CounterRegistry::new();
        counters.set("pool_allocs", self.pool.allocs());
        counters.set("pool_reuses", self.pool.reuses());
        counters.set("loader_respawns", self.respawns);
        counters.set("corruptions_detected", self.corruptions);
        if let Some(off) = self.offload.as_ref() {
            counters.set("offload_evictions", off.evictions);
            counters.set("offload_prefetches", off.prefetches);
            counters.set("link_faults", off.link_faults);
            counters.set("link_retries", off.link_retries);
        }
        // Degradation counters come from the hub so the report's table
        // and the `/metrics` exposition agree; per-kind rung counts use
        // the same stable tags as the episode's JSON.
        if let Some(deg) = self.degradation.as_ref() {
            counters.set("degrade_events", self.hub.degrade_events());
            counters.set("degrade_rungs", self.hub.degrade_rungs());
            for action in &deg.actions {
                counters.add(&format!("degrade_rung_{}", action.kind()), 1);
            }
        }
        let mut phase_stats = Vec::new();
        if self.tracer.is_enabled() {
            // The offload engine owns a trace buffer that only flushes on
            // drop — retire it (stats were folded above) before draining.
            if self.model.offload_stats().is_some() {
                self.model.clear_offload();
            }
            let log = self.tracer.drain();
            counters.set("trace_events", log.event_count() as u64);
            counters.set("trace_dropped", log.dropped());
            phase_stats = log.phase_stats();
            if let Some(path) = self.cfg.trace.as_ref() {
                match log.write_chrome(path) {
                    Ok(()) => info!(
                        "wrote trace timeline to {} ({} events)",
                        path.display(),
                        log.event_count()
                    ),
                    Err(e) => warn_!("could not write trace to {}: {e}", path.display()),
                }
            }
        }
        // Promote the per-phase quantile tables into the hub so the last
        // scrapes of a finishing run expose them as
        // `optorch_phase_seconds{phase,quantile}` gauges, with the
        // always-recorded step histogram as a `train-step` phase.
        let mut hub_phases = phase_stats.clone();
        if !self.step_hist.is_empty() {
            hub_phases.push(PhaseStat::from_histogram("train-step".to_string(), &self.step_hist));
        }
        self.hub.update_phase_stats(&hub_phases);
        // Drift needs no tracing: the step histogram is always recorded,
        // and the prediction comes from the spill planner's cost model.
        let drift = self
            .offload
            .as_ref()
            .and_then(|o| DriftReport::from_observed(o.predicted_step_secs, &self.step_hist));
        // Its memory twin: predicted watermarks vs the maxima the hub saw.
        let mem = self.mem_timeline.as_ref().and_then(|tl| {
            MemWatermarkReport::from_observed(tl, self.hub.max_host_resident_bytes(), self.hub.steps())
        });
        if let Some(path) = self.cfg.memlog.as_ref() {
            if let Some(parent) = path.parent().filter(|p| !p.as_os_str().is_empty()) {
                std::fs::create_dir_all(parent).ok();
            }
            match std::fs::write(path, crate::obs::memlog_csv(&self.memlog_rows)) {
                Ok(()) => info!(
                    "wrote per-step memory timeline to {} ({} rows)",
                    path.display(),
                    self.memlog_rows.len()
                ),
                Err(e) => warn_!("could not write memlog to {}: {e}", path.display()),
            }
        }
        Ok(TrainReport {
            model: self.cfg.model.clone(),
            pipeline: self.cfg.pipeline.name(),
            final_eval_accuracy: final_acc,
            final_eval_loss: final_loss,
            total_wall_secs: self.history.total_wall_secs(),
            loader_produce_secs: self.produce_secs,
            loader_blocked_secs: self.blocked_secs,
            loader_workers: self.worker_acc.clone(),
            pool_allocs: self.pool.allocs(),
            pool_reuses: self.pool.reuses(),
            plan: self.plan.clone(),
            arena: self.arena.clone(),
            offload: self.offload.clone(),
            degradation: self.degradation.clone(),
            phase_stats,
            counters,
            drift,
            mem,
            history: std::mem::take(&mut self.history),
        })
    }

    pub fn state(&self) -> &TrainState {
        &self.state
    }

    /// Persist the current training state (params ⊎ momentum) to disk.
    pub fn save_state(&self, path: &std::path::Path) -> Result<()> {
        crate::runtime::state_io::save(path, &self.model.entry, &self.state)
    }

    /// Replace the training state from a checkpoint written by
    /// [`Trainer::save_state`] for the same (model, pipeline).
    pub fn load_state(&mut self, path: &std::path::Path) -> Result<()> {
        self.state = crate::runtime::state_io::load(path, &self.model.entry)?;
        Ok(())
    }

    pub fn config(&self) -> &TrainConfig {
        &self.cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pipeline;

    #[test]
    fn select_plan_skips_non_sc_pipelines() {
        let cfg = TrainConfig::default_for("tiny_cnn", Pipeline::BASELINE);
        assert!(select_plan(&cfg, (32, 32, 3), 10).unwrap().is_none());
    }

    #[test]
    fn select_plan_picks_optimal_without_budget_and_packs_an_arena() {
        let cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse("sc").unwrap());
        let sel = select_plan(&cfg, (32, 32, 3), 10).unwrap().unwrap();
        assert!(sel.offload_report().is_none(), "no budget → no spilling");
        let arena = sel.arena.as_ref().unwrap();
        let plan = &sel.plan;
        assert!(plan.peak_bytes > 0);
        assert!(plan.checkpoints.iter().all(|&c| c < 4)); // tiny_cnn has 5 layers
        assert!(arena.slab_bytes > 0);
        assert_eq!(arena.peak_bytes, plan.peak_bytes);
        assert!(arena.base_bytes + arena.slab_bytes >= plan.peak_bytes);
        assert!((1.0..=1.25).contains(&arena.fragmentation), "{}", arena.fragmentation);
        // the memory report is staged alongside the plan
        assert_eq!(sel.memory.peak_bytes, plan.peak_bytes);
    }

    #[test]
    fn select_plan_generous_budget_fits_without_spilling() {
        let mut cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse("sc").unwrap());
        cfg.memory_budget = Some(1 << 30);
        let sel = select_plan(&cfg, (32, 32, 3), 10).unwrap().unwrap();
        assert!(!sel.is_spill(), "a 1 GiB budget fits a pure plan");
        // the fit decision uses packed bytes, so the packed total obeys it
        assert!(sel.fits(1 << 30));
        assert!(sel.device_peak_packed() <= 1 << 30);
        assert_eq!(sel.plan.recompute_overhead, 0.0, "generous budget → cheapest time");
    }

    #[test]
    fn select_plan_budget_without_profile_is_an_error() {
        let mut cfg = TrainConfig::default_for("mystery_net", Pipeline::parse("sc").unwrap());
        cfg.memory_budget = Some(1 << 30);
        let err = select_plan(&cfg, (32, 32, 3), 10).unwrap_err();
        assert!(err.to_string().contains("architecture profile"), "{err}");
        // without a budget the missing profile is tolerated quietly
        cfg.memory_budget = None;
        assert!(select_plan(&cfg, (32, 32, 3), 10).unwrap().is_none());
    }

    #[test]
    fn select_plan_impossible_budget_is_an_error() {
        let mut cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse("sc").unwrap());
        cfg.memory_budget = Some(1);
        let err = select_plan(&cfg, (32, 32, 3), 10).unwrap_err();
        assert!(err.to_string().contains("minimum achievable peak"), "{err}");
    }
}
