//! Augmentations — the per-class policies SBS applies (paper §II-A.1).
//!
//! Single-image ops (flip / pad-crop / cutout / jitter / AugMix-lite) plus
//! the pair mixers MixUp and CutMix. Pair mixers produce soft labels, which
//! flow through the whole stack (`ImageBatch.labels` is `n × num_classes`).

pub mod ops;
pub mod pair;
pub mod policy;

pub use ops::{augmix_lite, brightness_jitter, cutout, hflip, pad_crop};
pub use pair::{cutmix, mixup};
pub use policy::{AugOp, AugPolicy};
