//! Single-image augmentation primitives.

use crate::data::image::Image;
use crate::util::rng::Rng;

/// Horizontal flip in place.
pub fn hflip(img: &mut Image) {
    for y in 0..img.h {
        for x in 0..img.w / 2 {
            for c in 0..img.c {
                let a = img.idx(y, x, c);
                let b = img.idx(y, img.w - 1 - x, c);
                img.data.swap(a, b);
            }
        }
    }
}

/// Zero-pad by `pad` on all sides, then take a random crop of the original
/// size (the standard CIFAR augmentation).
pub fn pad_crop(img: &mut Image, pad: usize, rng: &mut Rng) {
    if pad == 0 {
        return;
    }
    let oy = rng.gen_range(2 * pad + 1) as isize - pad as isize;
    let ox = rng.gen_range(2 * pad + 1) as isize - pad as isize;
    let src = img.clone();
    for y in 0..img.h {
        for x in 0..img.w {
            let sy = y as isize + oy;
            let sx = x as isize + ox;
            for c in 0..img.c {
                let v = if sy >= 0 && sy < img.h as isize && sx >= 0 && sx < img.w as isize {
                    src.get(sy as usize, sx as usize, c)
                } else {
                    0
                };
                img.set(y, x, c, v);
            }
        }
    }
}

/// Zero out a random `size × size` square (DeVries & Taylor cutout).
pub fn cutout(img: &mut Image, size: usize, rng: &mut Rng) {
    if size == 0 || img.h == 0 || img.w == 0 {
        return;
    }
    let cy = rng.gen_range(img.h);
    let cx = rng.gen_range(img.w);
    let half = size / 2;
    let y0 = cy.saturating_sub(half);
    let y1 = (cy + half + size % 2).min(img.h);
    let x0 = cx.saturating_sub(half);
    let x1 = (cx + half + size % 2).min(img.w);
    for y in y0..y1 {
        for x in x0..x1 {
            for c in 0..img.c {
                img.set(y, x, c, 0);
            }
        }
    }
}

/// Multiply all pixels by a factor in `[1-amount, 1+amount]`.
pub fn brightness_jitter(img: &mut Image, amount: f64, rng: &mut Rng) {
    let f = 1.0 + amount * (2.0 * rng.f64() - 1.0);
    for v in img.data.iter_mut() {
        *v = (*v as f64 * f).clamp(0.0, 255.0) as u8;
    }
}

/// Channel-preserving contrast adjustment around the mean.
pub fn contrast_jitter(img: &mut Image, amount: f64, rng: &mut Rng) {
    let mean = img.data.iter().map(|&v| v as f64).sum::<f64>() / img.data.len().max(1) as f64;
    let f = 1.0 + amount * (2.0 * rng.f64() - 1.0);
    for v in img.data.iter_mut() {
        *v = ((*v as f64 - mean) * f + mean).clamp(0.0, 255.0) as u8;
    }
}


/// Rotate by a random multiple of 90° (square images only; no-op otherwise).
pub fn rotate90(img: &mut Image, rng: &mut Rng) {
    if img.h != img.w {
        return;
    }
    let quarter_turns = rng.gen_range(4);
    for _ in 0..quarter_turns {
        let src = img.clone();
        for y in 0..img.h {
            for x in 0..img.w {
                for c in 0..img.c {
                    // (y, x) <- (h-1-x, y)
                    img.set(y, x, c, src.get(img.h - 1 - x, y, c));
                }
            }
        }
    }
}

/// Desaturate toward the per-pixel luma by a random amount in [0, max].
pub fn desaturate(img: &mut Image, max: f64, rng: &mut Rng) {
    if img.c != 3 {
        return;
    }
    let amount = max * rng.f64();
    for y in 0..img.h {
        for x in 0..img.w {
            let (r, g, b) = (
                img.get(y, x, 0) as f64,
                img.get(y, x, 1) as f64,
                img.get(y, x, 2) as f64,
            );
            let luma = 0.299 * r + 0.587 * g + 0.114 * b;
            for (c, v) in [(0usize, r), (1, g), (2, b)] {
                img.set(y, x, c, (v + amount * (luma - v)).clamp(0.0, 255.0) as u8);
            }
        }
    }
}

/// Add zero-mean uniform pixel noise of amplitude ±amp.
pub fn pixel_noise(img: &mut Image, amp: f64, rng: &mut Rng) {
    for v in img.data.iter_mut() {
        let n = amp * (2.0 * rng.f64() - 1.0);
        *v = (*v as f64 + n).clamp(0.0, 255.0) as u8;
    }
}

/// AugMix-lite (Hendrycks et al., simplified): mix `width` independently
/// augmented chains of this image with Dirichlet-ish random weights, then
/// blend with the original. Uses only the primitives above, so it stays
/// uint8-exact and dependency-free.
pub fn augmix_lite(img: &mut Image, width: usize, rng: &mut Rng) {
    if width == 0 {
        return;
    }
    let orig = img.clone();
    // Random positive weights, normalized.
    let mut ws: Vec<f64> = (0..width).map(|_| rng.f64() + 1e-3).collect();
    let total: f64 = ws.iter().sum();
    for w in ws.iter_mut() {
        *w /= total;
    }
    let mut acc = vec![0.0f64; img.data.len()];
    for &w in &ws {
        let mut chain = orig.clone();
        let depth = 1 + rng.gen_range(3);
        for _ in 0..depth {
            match rng.gen_range(4) {
                0 => hflip(&mut chain),
                1 => pad_crop(&mut chain, 2, rng),
                2 => brightness_jitter(&mut chain, 0.3, rng),
                _ => contrast_jitter(&mut chain, 0.3, rng),
            }
        }
        for (a, &v) in acc.iter_mut().zip(&chain.data) {
            *a += w * v as f64;
        }
    }
    // Blend augmented mixture with the original (m ~ U[0.3, 0.7]).
    let m = 0.3 + 0.4 * rng.f64();
    for (dst, (&o, &a)) in img.data.iter_mut().zip(orig.data.iter().zip(&acc)) {
        *dst = ((1.0 - m) * o as f64 + m * a).clamp(0.0, 255.0) as u8;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn gradient_image(h: usize, w: usize) -> Image {
        let mut img = Image::zeros(h, w, 3);
        for y in 0..h {
            for x in 0..w {
                for c in 0..3 {
                    img.set(y, x, c, ((x * 7 + y * 3 + c * 11) % 256) as u8);
                }
            }
        }
        img
    }

    #[test]
    fn hflip_involutive() {
        let orig = gradient_image(8, 6);
        let mut img = orig.clone();
        hflip(&mut img);
        assert_ne!(img, orig);
        hflip(&mut img);
        assert_eq!(img, orig);
    }

    #[test]
    fn hflip_mirrors_columns() {
        let mut img = Image::zeros(1, 3, 1);
        img.data.copy_from_slice(&[1, 2, 3]);
        hflip(&mut img);
        assert_eq!(img.data, vec![3, 2, 1]);
    }

    #[test]
    fn pad_crop_zero_is_identity() {
        let orig = gradient_image(8, 8);
        let mut img = orig.clone();
        let mut rng = Rng::new(1);
        pad_crop(&mut img, 0, &mut rng);
        assert_eq!(img, orig);
    }

    #[test]
    fn pad_crop_preserves_shape() {
        let mut img = gradient_image(8, 8);
        let mut rng = Rng::new(2);
        pad_crop(&mut img, 4, &mut rng);
        assert_eq!((img.h, img.w, img.c), (8, 8, 3));
    }

    #[test]
    fn cutout_zeroes_some_pixels() {
        let mut img = gradient_image(16, 16);
        // fill with nonzero
        for v in img.data.iter_mut() {
            *v = v.saturating_add(1);
        }
        let before_zeros = img.data.iter().filter(|&&v| v == 0).count();
        let mut rng = Rng::new(3);
        cutout(&mut img, 8, &mut rng);
        let after_zeros = img.data.iter().filter(|&&v| v == 0).count();
        assert!(after_zeros > before_zeros);
        assert!(after_zeros <= 9 * 9 * 3 + before_zeros);
    }

    #[test]
    fn cutout_zero_size_noop() {
        let orig = gradient_image(8, 8);
        let mut img = orig.clone();
        let mut rng = Rng::new(4);
        cutout(&mut img, 0, &mut rng);
        assert_eq!(img, orig);
    }

    #[test]
    fn brightness_bounds() {
        let mut img = gradient_image(8, 8);
        let mut rng = Rng::new(5);
        brightness_jitter(&mut img, 0.5, &mut rng);
        // all values still valid u8 (implicit) and not all identical to 0
        assert!(img.data.iter().any(|&v| v > 0));
    }

    #[test]
    fn contrast_preserves_mean_roughly() {
        let mut img = gradient_image(16, 16);
        let mean_before =
            img.data.iter().map(|&v| v as f64).sum::<f64>() / img.data.len() as f64;
        let mut rng = Rng::new(6);
        contrast_jitter(&mut img, 0.4, &mut rng);
        let mean_after =
            img.data.iter().map(|&v| v as f64).sum::<f64>() / img.data.len() as f64;
        assert!((mean_before - mean_after).abs() < 12.0);
    }


    #[test]
    fn rotate90_four_times_is_identity() {
        let orig = gradient_image(8, 8);
        let mut img = orig.clone();
        // force exactly one quarter turn 4 times via rng probing
        let mut turned = 0;
        let mut seed = 0u64;
        while turned < 4 {
            let mut r = Rng::new(seed);
            let probe = r.gen_range(4);
            if probe == 1 {
                let mut r = Rng::new(seed);
                rotate90(&mut img, &mut r);
                turned += 1;
            }
            seed += 1;
        }
        assert_eq!(img, orig);
    }

    #[test]
    fn rotate90_nonsquare_noop() {
        let mut img = gradient_image(4, 6);
        let orig = img.clone();
        rotate90(&mut img, &mut Rng::new(1));
        assert_eq!(img, orig);
    }

    #[test]
    fn desaturate_full_makes_channels_equal() {
        let mut img = gradient_image(4, 4);
        // find a seed where amount ≈ max by using max so large that any
        // positive draw saturates... instead call with deterministic rng and
        // check channels move toward each other
        let before_spread: i32 = (0..4)
            .map(|y| {
                let r = img.get(y, 0, 0) as i32;
                let b = img.get(y, 0, 2) as i32;
                (r - b).abs()
            })
            .sum();
        desaturate(&mut img, 1.0, &mut Rng::new(3));
        let after_spread: i32 = (0..4)
            .map(|y| {
                let r = img.get(y, 0, 0) as i32;
                let b = img.get(y, 0, 2) as i32;
                (r - b).abs()
            })
            .sum();
        assert!(after_spread <= before_spread);
    }

    #[test]
    fn pixel_noise_bounded() {
        let mut img = gradient_image(8, 8);
        let orig = img.clone();
        pixel_noise(&mut img, 10.0, &mut Rng::new(4));
        let max_delta = img
            .data
            .iter()
            .zip(&orig.data)
            .map(|(&a, &b)| (a as i32 - b as i32).abs())
            .max()
            .unwrap();
        assert!(max_delta <= 10, "{max_delta}");
        assert_ne!(img, orig);
    }

    #[test]
    fn augmix_changes_image_but_stays_close() {
        let orig = gradient_image(16, 16);
        let mut img = orig.clone();
        let mut rng = Rng::new(7);
        augmix_lite(&mut img, 3, &mut rng);
        assert_ne!(img, orig);
        let mad = img
            .data
            .iter()
            .zip(&orig.data)
            .map(|(&a, &b)| (a as f64 - b as f64).abs())
            .sum::<f64>()
            / img.data.len() as f64;
        assert!(mad < 128.0, "augmix wandered too far: {mad}");
    }

    #[test]
    fn augmix_zero_width_noop() {
        let orig = gradient_image(8, 8);
        let mut img = orig.clone();
        let mut rng = Rng::new(8);
        augmix_lite(&mut img, 0, &mut rng);
        assert_eq!(img, orig);
    }

    #[test]
    fn deterministic_given_rng() {
        let orig = gradient_image(8, 8);
        let mut a = orig.clone();
        let mut b = orig.clone();
        augmix_lite(&mut a, 3, &mut Rng::new(9));
        augmix_lite(&mut b, 3, &mut Rng::new(9));
        assert_eq!(a, b);
    }
}
