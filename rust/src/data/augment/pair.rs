//! Pair mixers: MixUp (Zhang et al.) and CutMix (Yun et al.).
//!
//! Both return the mix coefficient λ actually applied so the caller can
//! blend the soft labels: `label = λ·label_a + (1-λ)·label_b`.

use crate::data::image::Image;
use crate::util::rng::Rng;

/// Sample λ from a symmetric Beta(α, α) via two Gamma draws
/// (Marsaglia–Tsang needs α ≥ 1; for α < 1 use the boost trick).
pub fn sample_beta(alpha: f64, rng: &mut Rng) -> f64 {
    let x = sample_gamma(alpha, rng);
    let y = sample_gamma(alpha, rng);
    if x + y == 0.0 {
        0.5
    } else {
        x / (x + y)
    }
}

fn sample_gamma(alpha: f64, rng: &mut Rng) -> f64 {
    if alpha < 1.0 {
        // boost: Gamma(α) = Gamma(α+1) · U^(1/α)
        let u: f64 = rng.f64().max(1e-12);
        return sample_gamma(alpha + 1.0, rng) * u.powf(1.0 / alpha);
    }
    let d = alpha - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = rng.normal();
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u: f64 = rng.f64().max(1e-12);
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

/// MixUp: pixel-wise convex combination, `out = λ·a + (1-λ)·b`.
/// Returns λ. `a` is modified in place.
pub fn mixup(a: &mut Image, b: &Image, alpha: f64, rng: &mut Rng) -> f64 {
    assert_eq!((a.h, a.w, a.c), (b.h, b.w, b.c), "mixup shape mismatch");
    let lam = sample_beta(alpha, rng);
    for (va, &vb) in a.data.iter_mut().zip(&b.data) {
        *va = (lam * *va as f64 + (1.0 - lam) * vb as f64).round().clamp(0.0, 255.0) as u8;
    }
    lam
}

/// CutMix: paste a random rectangle of `b` into `a`; λ is the fraction of
/// `a` that survives (area-exact, as in the paper). Returns λ.
pub fn cutmix(a: &mut Image, b: &Image, alpha: f64, rng: &mut Rng) -> f64 {
    assert_eq!((a.h, a.w, a.c), (b.h, b.w, b.c), "cutmix shape mismatch");
    let lam = sample_beta(alpha, rng);
    // Box with area (1-λ)·H·W centred at a random point, clipped to bounds.
    let cut_ratio = (1.0 - lam).sqrt();
    let cut_h = ((a.h as f64) * cut_ratio) as usize;
    let cut_w = ((a.w as f64) * cut_ratio) as usize;
    if cut_h == 0 || cut_w == 0 {
        return 1.0;
    }
    let cy = rng.gen_range(a.h);
    let cx = rng.gen_range(a.w);
    let y0 = cy.saturating_sub(cut_h / 2);
    let y1 = (cy + (cut_h + 1) / 2).min(a.h);
    let x0 = cx.saturating_sub(cut_w / 2);
    let x1 = (cx + (cut_w + 1) / 2).min(a.w);
    for y in y0..y1 {
        for x in x0..x1 {
            for c in 0..a.c {
                let v = b.get(y, x, c);
                a.set(y, x, c, v);
            }
        }
    }
    // Exact λ from the clipped box area.
    1.0 - ((y1 - y0) * (x1 - x0)) as f64 / (a.h * a.w) as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    fn constant(h: usize, w: usize, v: u8) -> Image {
        let mut img = Image::zeros(h, w, 3);
        img.data.fill(v);
        img
    }

    #[test]
    fn beta_in_unit_interval() {
        let mut rng = Rng::new(1);
        for &alpha in &[0.2, 1.0, 5.0] {
            for _ in 0..1000 {
                let l = sample_beta(alpha, &mut rng);
                assert!((0.0..=1.0).contains(&l), "alpha {alpha} lam {l}");
            }
        }
    }

    #[test]
    fn beta_symmetric_mean_half() {
        let mut rng = Rng::new(2);
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| sample_beta(0.4, &mut rng)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn mixup_blends_toward_lambda() {
        let mut rng = Rng::new(3);
        let mut a = constant(8, 8, 200);
        let b = constant(8, 8, 0);
        let lam = mixup(&mut a, &b, 1.0, &mut rng);
        let expect = (lam * 200.0).round() as i32;
        for &v in &a.data {
            assert!((v as i32 - expect).abs() <= 1, "v {v} expect {expect}");
        }
    }

    #[test]
    fn mixup_extremes_preserve_inputs() {
        // With alpha tiny, λ concentrates at 0 or 1 — output is one input.
        let mut rng = Rng::new(4);
        let mut a = constant(4, 4, 100);
        let b = constant(4, 4, 50);
        let lam = mixup(&mut a, &b, 0.05, &mut rng);
        assert!(lam <= 1.0 && lam >= 0.0);
    }

    #[test]
    fn cutmix_lambda_matches_surviving_area() {
        let mut rng = Rng::new(5);
        for _ in 0..50 {
            let mut a = constant(16, 16, 255);
            let b = constant(16, 16, 0);
            let lam = cutmix(&mut a, &b, 1.0, &mut rng);
            let surviving =
                a.data.iter().filter(|&&v| v == 255).count() as f64 / a.data.len() as f64;
            assert!((surviving - lam).abs() < 1e-9, "lam {lam} surviving {surviving}");
        }
    }

    #[test]
    fn cutmix_pastes_b_content() {
        let mut rng = Rng::new(6);
        let mut a = constant(16, 16, 255);
        let b = constant(16, 16, 7);
        let lam = cutmix(&mut a, &b, 1.0, &mut rng);
        if lam < 1.0 {
            assert!(a.data.iter().any(|&v| v == 7));
        }
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn mixup_rejects_shape_mismatch() {
        let mut rng = Rng::new(7);
        let mut a = constant(4, 4, 1);
        let b = constant(5, 5, 1);
        mixup(&mut a, &b, 1.0, &mut rng);
    }
}
