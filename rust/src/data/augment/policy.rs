//! Augmentation policies — the per-class pipelines SBS schedules.
//!
//! A policy is an ordered list of ops applied to each selected image; pair
//! ops (MixUp/CutMix) additionally draw a partner image from the same batch
//! slot stream and blend labels. Policies parse from compact config strings
//! such as `"hflip,crop4,cutout8"` or `"hflip,mixup0.2"`.

use crate::data::augment::{ops, pair};
use crate::data::image::Image;
use crate::util::rng::Rng;

/// One augmentation step.
#[derive(Clone, Debug, PartialEq)]
pub enum AugOp {
    HFlip,
    /// `crop<P>`: pad by P then random-crop back.
    PadCrop(usize),
    /// `cutout<S>`: zero a random S×S square.
    Cutout(usize),
    /// `bright<A>`: brightness jitter ±A.
    Brightness(f64),
    /// `augmix<W>`: AugMix-lite with W chains.
    AugMix(usize),
    /// `rot90`: random multiple of 90°.
    Rot90,
    /// `desat<A>`: desaturate toward luma by up to A.
    Desaturate(f64),
    /// `noise<A>`: uniform pixel noise ±A.
    Noise(f64),
    /// `mixup<α>`: MixUp with Beta(α, α).
    MixUp(f64),
    /// `cutmix<α>`: CutMix with Beta(α, α).
    CutMix(f64),
}

/// An ordered augmentation pipeline.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct AugPolicy {
    pub ops: Vec<AugOp>,
}

impl AugPolicy {
    pub fn none() -> AugPolicy {
        AugPolicy { ops: vec![] }
    }

    /// The standard CIFAR recipe.
    pub fn standard() -> AugPolicy {
        AugPolicy { ops: vec![AugOp::HFlip, AugOp::PadCrop(4)] }
    }

    /// Parse `"hflip,crop4,cutout8,mixup0.2"`. Unknown ops are errors.
    pub fn parse(s: &str) -> Result<AugPolicy, String> {
        let mut ops = Vec::new();
        for tok in s.split(',').map(str::trim).filter(|t| !t.is_empty()) {
            let op = if tok == "hflip" {
                AugOp::HFlip
            } else if tok == "none" {
                continue;
            } else if let Some(rest) = tok.strip_prefix("crop") {
                AugOp::PadCrop(rest.parse().map_err(|_| format!("bad crop arg: {tok}"))?)
            } else if let Some(rest) = tok.strip_prefix("cutout") {
                AugOp::Cutout(rest.parse().map_err(|_| format!("bad cutout arg: {tok}"))?)
            } else if let Some(rest) = tok.strip_prefix("bright") {
                AugOp::Brightness(rest.parse().map_err(|_| format!("bad bright arg: {tok}"))?)
            } else if tok == "rot90" {
                AugOp::Rot90
            } else if let Some(rest) = tok.strip_prefix("augmix") {
                AugOp::AugMix(rest.parse().map_err(|_| format!("bad augmix arg: {tok}"))?)
            } else if let Some(rest) = tok.strip_prefix("desat") {
                AugOp::Desaturate(rest.parse().map_err(|_| format!("bad desat arg: {tok}"))?)
            } else if let Some(rest) = tok.strip_prefix("noise") {
                AugOp::Noise(rest.parse().map_err(|_| format!("bad noise arg: {tok}"))?)
            } else if let Some(rest) = tok.strip_prefix("mixup") {
                AugOp::MixUp(rest.parse().map_err(|_| format!("bad mixup arg: {tok}"))?)
            } else if let Some(rest) = tok.strip_prefix("cutmix") {
                AugOp::CutMix(rest.parse().map_err(|_| format!("bad cutmix arg: {tok}"))?)
            } else {
                return Err(format!("unknown augmentation op: {tok}"));
            };
            ops.push(op);
        }
        Ok(AugPolicy { ops })
    }

    /// True if any op needs a partner image.
    pub fn needs_partner(&self) -> bool {
        self.ops
            .iter()
            .any(|op| matches!(op, AugOp::MixUp(_) | AugOp::CutMix(_)))
    }

    /// Apply the policy to `img` (labels in `label`, one-hot or soft).
    /// `partner` supplies the second image + label for pair ops.
    pub fn apply(
        &self,
        img: &mut Image,
        label: &mut [f32],
        partner: Option<(&Image, &[f32])>,
        rng: &mut Rng,
    ) {
        for op in &self.ops {
            match op {
                AugOp::HFlip => {
                    if rng.bool(0.5) {
                        ops::hflip(img);
                    }
                }
                AugOp::PadCrop(p) => ops::pad_crop(img, *p, rng),
                AugOp::Cutout(s) => ops::cutout(img, *s, rng),
                AugOp::Brightness(a) => ops::brightness_jitter(img, *a, rng),
                AugOp::AugMix(w) => ops::augmix_lite(img, *w, rng),
                AugOp::Rot90 => ops::rotate90(img, rng),
                AugOp::Desaturate(a) => ops::desaturate(img, *a, rng),
                AugOp::Noise(a) => ops::pixel_noise(img, *a, rng),
                AugOp::MixUp(alpha) => {
                    if let Some((pimg, plabel)) = partner {
                        let lam = pair::mixup(img, pimg, *alpha, rng);
                        blend_labels(label, plabel, lam);
                    }
                }
                AugOp::CutMix(alpha) => {
                    if let Some((pimg, plabel)) = partner {
                        let lam = pair::cutmix(img, pimg, *alpha, rng);
                        blend_labels(label, plabel, lam);
                    }
                }
            }
        }
    }
}

fn blend_labels(a: &mut [f32], b: &[f32], lam: f64) {
    for (va, &vb) in a.iter_mut().zip(b) {
        *va = (lam as f32) * *va + (1.0 - lam as f32) * vb;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_roundtrip() {
        let p = AugPolicy::parse("hflip,crop4,cutout8,bright0.3,augmix3,mixup0.2,cutmix1.0")
            .unwrap();
        assert_eq!(
            p.ops,
            vec![
                AugOp::HFlip,
                AugOp::PadCrop(4),
                AugOp::Cutout(8),
                AugOp::Brightness(0.3),
                AugOp::AugMix(3),
                AugOp::MixUp(0.2),
                AugOp::CutMix(1.0),
            ]
        );
        assert!(p.needs_partner());
    }

    #[test]
    fn parse_new_ops() {
        let p = AugPolicy::parse("rot90,desat0.5,noise8").unwrap();
        assert_eq!(
            p.ops,
            vec![AugOp::Rot90, AugOp::Desaturate(0.5), AugOp::Noise(8.0)]
        );
        assert!(!p.needs_partner());
    }

    #[test]
    fn parse_rejects_unknown() {
        assert!(AugPolicy::parse("hflip,teleport").is_err());
        assert!(AugPolicy::parse("crop").is_err());
        assert!(AugPolicy::parse("mixupX").is_err());
    }

    #[test]
    fn parse_empty_and_none() {
        assert_eq!(AugPolicy::parse("").unwrap(), AugPolicy::none());
        assert_eq!(AugPolicy::parse("none").unwrap(), AugPolicy::none());
        assert!(!AugPolicy::none().needs_partner());
    }

    #[test]
    fn standard_has_no_pair_ops() {
        assert!(!AugPolicy::standard().needs_partner());
    }

    #[test]
    fn apply_without_partner_skips_pair_ops() {
        let p = AugPolicy::parse("mixup1.0").unwrap();
        let mut img = Image::zeros(4, 4, 1);
        img.data.fill(100);
        let mut label = vec![1.0, 0.0];
        let mut rng = Rng::new(1);
        p.apply(&mut img, &mut label, None, &mut rng);
        assert!(img.data.iter().all(|&v| v == 100));
        assert_eq!(label, vec![1.0, 0.0]);
    }

    #[test]
    fn apply_mixup_blends_labels() {
        let p = AugPolicy::parse("mixup1.0").unwrap();
        let mut img = Image::zeros(4, 4, 1);
        img.data.fill(255);
        let partner = Image::zeros(4, 4, 1);
        let mut label = vec![1.0f32, 0.0];
        let plabel = vec![0.0f32, 1.0];
        let mut rng = Rng::new(2);
        p.apply(&mut img, &mut label, Some((&partner, &plabel)), &mut rng);
        let sum: f32 = label.iter().sum();
        assert!((sum - 1.0).abs() < 1e-6, "labels stay a distribution: {label:?}");
        assert!(label[0] < 1.0 && label[1] > 0.0);
    }

    #[test]
    fn deterministic_under_same_rng() {
        let p = AugPolicy::parse("hflip,crop4,cutout4").unwrap();
        let mk = || {
            let mut img = Image::zeros(8, 8, 3);
            for (i, v) in img.data.iter_mut().enumerate() {
                *v = (i % 251) as u8;
            }
            img
        };
        let mut a = mk();
        let mut b = mk();
        let mut la = vec![1.0, 0.0];
        let mut lb = vec![1.0, 0.0];
        p.apply(&mut a, &mut la, None, &mut Rng::new(3));
        p.apply(&mut b, &mut lb, None, &mut Rng::new(3));
        assert_eq!(a, b);
    }
}
