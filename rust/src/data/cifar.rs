//! Reader for the real CIFAR-10 binary format.
//!
//! Keeps the data path honest when a copy of `cifar-10-batches-bin` exists
//! (`OPTORCH_CIFAR_DIR` or a `data/` directory); all experiments fall back
//! to [`crate::data::synth::SynthCifar`] otherwise (DESIGN.md §5).
//!
//! Format (per record, 3073 bytes): 1 label byte, then 3×1024 bytes of
//! channel-planar pixels (all R, all G, all B), row-major 32×32.

use crate::data::dataset::Dataset;
use crate::data::image::Image;
use std::io::Read;
use std::path::{Path, PathBuf};

const REC: usize = 3073;
const SIDE: usize = 32;
const PLANE: usize = SIDE * SIDE;

/// CIFAR-10 loaded fully into memory (HWC uint8).
pub struct Cifar10 {
    data: Vec<u8>, // n × 3072, already HWC
    labels: Vec<usize>,
}

impl Cifar10 {
    /// Load one or more `*_batch*.bin` files.
    pub fn from_files(paths: &[PathBuf]) -> std::io::Result<Cifar10> {
        let mut data = Vec::new();
        let mut labels = Vec::new();
        for p in paths {
            let mut raw = Vec::new();
            std::fs::File::open(p)?.read_to_end(&mut raw)?;
            if raw.len() % REC != 0 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("{}: size {} not a multiple of {REC}", p.display(), raw.len()),
                ));
            }
            for rec in raw.chunks_exact(REC) {
                let label = rec[0] as usize;
                if label > 9 {
                    return Err(std::io::Error::new(
                        std::io::ErrorKind::InvalidData,
                        format!("{}: label {label} out of range", p.display()),
                    ));
                }
                labels.push(label);
                // planar CHW → interleaved HWC
                let px = &rec[1..];
                for i in 0..PLANE {
                    data.push(px[i]); // R
                    data.push(px[PLANE + i]); // G
                    data.push(px[2 * PLANE + i]); // B
                }
            }
        }
        Ok(Cifar10 { data, labels })
    }

    /// Try the conventional locations; `None` when the dataset is absent.
    pub fn discover(train: bool) -> Option<Cifar10> {
        let dir = std::env::var("OPTORCH_CIFAR_DIR")
            .map(PathBuf::from)
            .unwrap_or_else(|_| PathBuf::from("data/cifar-10-batches-bin"));
        if !dir.is_dir() {
            return None;
        }
        let names: Vec<PathBuf> = if train {
            (1..=5).map(|i| dir.join(format!("data_batch_{i}.bin"))).collect()
        } else {
            vec![dir.join("test_batch.bin")]
        };
        if !names.iter().all(|p| p.is_file()) {
            return None;
        }
        Self::from_files(&names).ok()
    }

    /// Parse records from an in-memory buffer (used by tests).
    pub fn from_bytes(raw: &[u8]) -> std::io::Result<Cifar10> {
        let tmp = std::env::temp_dir().join(format!(
            "optorch_cifar_test_{}.bin",
            std::process::id()
        ));
        std::fs::write(&tmp, raw)?;
        let out = Self::from_files(&[tmp.clone()]);
        let _ = std::fs::remove_file(&tmp);
        out
    }
}

impl Dataset for Cifar10 {
    fn len(&self) -> usize {
        self.labels.len()
    }

    fn num_classes(&self) -> usize {
        10
    }

    fn shape(&self) -> (usize, usize, usize) {
        (SIDE, SIDE, 3)
    }

    fn get(&self, index: usize) -> (Image, usize) {
        let mut img = Image::zeros(SIDE, SIDE, 3);
        self.get_into(index, &mut img);
        (img, self.labels[index])
    }

    fn get_into(&self, index: usize, out: &mut Image) -> usize {
        out.reset(SIDE, SIDE, 3);
        out.data
            .copy_from_slice(&self.data[index * PLANE * 3..(index + 1) * PLANE * 3]);
        self.labels[index]
    }
}

/// True when a real CIFAR-10 copy is discoverable at `path`.
pub fn available_at(path: &Path) -> bool {
    (1..=5).all(|i| path.join(format!("data_batch_{i}.bin")).is_file())
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fake_record(label: u8, fill: u8) -> Vec<u8> {
        let mut rec = vec![label];
        // R plane = fill, G = fill+1, B = fill+2
        for ch in 0..3u8 {
            rec.extend(std::iter::repeat(fill.wrapping_add(ch)).take(PLANE));
        }
        rec
    }

    #[test]
    fn parses_planar_to_hwc() {
        let mut raw = fake_record(3, 10);
        raw.extend(fake_record(7, 100));
        let d = Cifar10::from_bytes(&raw).unwrap();
        assert_eq!(d.len(), 2);
        let (img, label) = d.get(0);
        assert_eq!(label, 3);
        assert_eq!(img.get(0, 0, 0), 10);
        assert_eq!(img.get(0, 0, 1), 11);
        assert_eq!(img.get(0, 0, 2), 12);
        let (img, label) = d.get(1);
        assert_eq!(label, 7);
        assert_eq!(img.get(31, 31, 2), 102);
    }

    #[test]
    fn rejects_truncated_file() {
        let raw = vec![0u8; 100];
        assert!(Cifar10::from_bytes(&raw).is_err());
    }

    #[test]
    fn rejects_bad_label() {
        let raw = fake_record(11, 0);
        assert!(Cifar10::from_bytes(&raw).is_err());
    }

    #[test]
    fn discover_absent_returns_none() {
        std::env::set_var("OPTORCH_CIFAR_DIR", "/nonexistent/cifar");
        assert!(Cifar10::discover(true).is_none());
        std::env::remove_var("OPTORCH_CIFAR_DIR");
    }
}
