//! Dataset abstraction shared by the synthetic generator, the real CIFAR-10
//! binary reader, and in-memory test datasets.

use crate::data::image::Image;

/// A labeled image dataset with random access.
pub trait Dataset: Send + Sync {
    fn len(&self) -> usize;

    fn is_empty(&self) -> bool {
        self.len() == 0
    }

    fn num_classes(&self) -> usize;

    /// `(h, w, c)` of every image.
    fn shape(&self) -> (usize, usize, usize);

    /// Fetch image `index` and its class label.
    fn get(&self, index: usize) -> (Image, usize);

    /// Fetch image `index` into a caller-provided buffer (reshaped via
    /// [`Image::reset`]) and return its class label. This is the worker
    /// hot-loop path: with a warm buffer an override allocates nothing,
    /// eliminating the per-image `Image` heap traffic of [`Dataset::get`].
    /// The default delegates to `get` (correct but allocating) so
    /// third-party datasets keep working unchanged.
    fn get_into(&self, index: usize, out: &mut Image) -> usize {
        let (img, label) = self.get(index);
        out.copy_from(&img);
        label
    }

    /// Indices grouped by class — the structure SBS sampling needs.
    /// Default implementation scans the whole dataset once.
    fn indices_by_class(&self) -> Vec<Vec<usize>> {
        let mut by_class = vec![Vec::new(); self.num_classes()];
        for i in 0..self.len() {
            let (_, c) = self.get(i);
            by_class[c].push(i);
        }
        by_class
    }
}

/// A fully in-memory dataset (tests, tiny corpora).
pub struct MemDataset {
    pub images: Vec<Image>,
    pub labels: Vec<usize>,
    pub num_classes: usize,
}

impl MemDataset {
    pub fn new(images: Vec<Image>, labels: Vec<usize>, num_classes: usize) -> MemDataset {
        assert_eq!(images.len(), labels.len());
        assert!(labels.iter().all(|&l| l < num_classes));
        MemDataset { images, labels, num_classes }
    }
}

impl Dataset for MemDataset {
    fn len(&self) -> usize {
        self.images.len()
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn shape(&self) -> (usize, usize, usize) {
        let i = &self.images[0];
        (i.h, i.w, i.c)
    }

    fn get(&self, index: usize) -> (Image, usize) {
        (self.images[index].clone(), self.labels[index])
    }

    fn get_into(&self, index: usize, out: &mut Image) -> usize {
        out.copy_from(&self.images[index]);
        self.labels[index]
    }
}

/// Cheap label-only override: `indices_by_class` for a `MemDataset` without
/// cloning images.
impl MemDataset {
    pub fn class_index(&self) -> Vec<Vec<usize>> {
        let mut by_class = vec![Vec::new(); self.num_classes];
        for (i, &c) in self.labels.iter().enumerate() {
            by_class[c].push(i);
        }
        by_class
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> MemDataset {
        let images = (0..6)
            .map(|i| {
                let mut im = Image::zeros(2, 2, 1);
                im.data.fill(i as u8);
                im
            })
            .collect();
        MemDataset::new(images, vec![0, 1, 2, 0, 1, 2], 3)
    }

    #[test]
    fn mem_dataset_roundtrip() {
        let d = tiny();
        assert_eq!(d.len(), 6);
        assert_eq!(d.shape(), (2, 2, 1));
        let (img, l) = d.get(4);
        assert_eq!(l, 1);
        assert_eq!(img.data, vec![4, 4, 4, 4]);
    }

    #[test]
    fn get_into_matches_get_and_reuses_the_buffer() {
        let d = tiny();
        let mut buf = Image::zeros(2, 2, 1);
        let cap = buf.data.capacity();
        for i in 0..d.len() {
            let label = d.get_into(i, &mut buf);
            let (img, l) = d.get(i);
            assert_eq!(buf, img, "image {i}");
            assert_eq!(label, l, "label {i}");
            assert_eq!(buf.data.capacity(), cap, "buffer reallocated at {i}");
        }
    }

    #[test]
    fn indices_by_class_partitions() {
        let d = tiny();
        let by = d.indices_by_class();
        assert_eq!(by, vec![vec![0, 3], vec![1, 4], vec![2, 5]]);
        assert_eq!(by, d.class_index());
    }

    #[test]
    #[should_panic]
    fn rejects_label_out_of_range() {
        MemDataset::new(vec![Image::zeros(1, 1, 1)], vec![5], 3);
    }
}
