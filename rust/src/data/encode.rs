//! Batch encoding/decoding — the paper's Algorithms 1, 3 and 4.
//!
//! Packs N uint8 images positionally into one same-shaped tensor of wider
//! words: pixel position p of the packed tensor holds
//! `Σ_i digit_i(p) · B^i` with base `B = 256` (Algorithm 1) or `B = 128`
//! plus a parity bitplane (Algorithm 4, "loss-less forced encoding").
//!
//! ## Capacity corrections (DESIGN.md §4)
//!
//! The paper claims 16 images per float64 word (and 32 with the offset
//! trick); both are arithmetically impossible. Exact capacities enforced
//! here:
//!
//! | encoding          | u64 word | f64 word (53-bit mantissa) |
//! |-------------------|----------|----------------------------|
//! | base-256 (Alg 1)  | 8        | 6                          |
//! | base-128 (Alg 4)  | 9        | 7                          |
//!
//! (The paper also indexes `256^i` from `i = 1`, which would waste the
//! lowest digit; we index from 0 as the decode algorithm implies.)
//!
//! The f64 flavour is what crosses the PJRT boundary (the L1 Pallas decode
//! kernel consumes it); the u64 flavour maximizes density for host-side
//! storage and transfer.
//!
//! ## Hot path (§Perf iteration 3)
//!
//! The packing loop is tiled: the word array is walked **once** in
//! L1-resident blocks of [`PACK_BLOCK`] words, and all ≤9 images' digits
//! for a block are packed before moving on. The per-image inner loop is a
//! straight `u8 → u64` widen/shift/or over contiguous slices, which the
//! compiler auto-vectorizes. The earlier shape — one full pass over the
//! whole word array per image — streamed `images × h·w·c × 8` bytes
//! through cache; the blocked form touches each word's cache line once.
//!
//! Every encode entry point has a `*_into` variant writing into
//! caller-provided storage so the loader's [`BufferPool`] can recycle
//! word/parity/label buffers across batches (zero allocation at steady
//! state); the grouped forms slice images straight out of the source batch
//! instead of copying into per-group sub-batches.
//!
//! [`BufferPool`]: crate::data::pool::BufferPool

use crate::data::image::ImageBatch;
use crate::data::pool::BufferPool;

/// Word type the packed tensor uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WordType {
    U64,
    F64,
}

/// Packing scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    /// Algorithm 1: exact base-256 digits.
    Base256,
    /// Algorithm 4: base-128 digits + parity bitplane (lossless).
    Lossless128,
}

impl Encoding {
    /// Bits per image digit.
    pub fn digit_bits(self) -> u32 {
        match self {
            Encoding::Base256 => 8,
            Encoding::Lossless128 => 7,
        }
    }

    pub fn base(self) -> u64 {
        1u64 << self.digit_bits()
    }

    /// Maximum number of images a single word can hold exactly.
    pub fn capacity(self, word: WordType) -> usize {
        let mantissa_bits = match word {
            WordType::U64 => 64,
            WordType::F64 => 53, // IEEE-754 double significand (incl. implicit bit)
        };
        (mantissa_bits / self.digit_bits()) as usize
    }
}

/// A fully-specified encoder configuration.
#[derive(Clone, Copy, Debug)]
pub struct EncodeSpec {
    pub encoding: Encoding,
    pub word: WordType,
}

impl EncodeSpec {
    pub fn new(encoding: Encoding, word: WordType) -> EncodeSpec {
        EncodeSpec { encoding, word }
    }

    pub fn capacity(&self) -> usize {
        self.encoding.capacity(self.word)
    }
}

/// A packed batch: one word per pixel position plus (for lossless mode) the
/// parity bitplane, and the pass-through labels.
#[derive(Clone, Debug)]
pub struct EncodedBatch {
    pub spec_encoding: Encoding,
    pub spec_word: WordType,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Packed words (length `h*w*c`), valid when `spec_word == U64`.
    pub words_u64: Vec<u64>,
    /// Packed words (length `h*w*c`), valid when `spec_word == F64`.
    pub words_f64: Vec<f64>,
    /// Parity bitplane for [`Encoding::Lossless128`], bit i of byte
    /// `(img*pixels + p) / 8` — empty for Base256.
    pub offsets: Vec<u8>,
    pub labels: Vec<f32>,
    pub num_classes: usize,
}

impl EncodedBatch {
    /// An empty shell for `*_into` reuse: repeated encodes into the same
    /// shell allocate only until its buffers reach steady-state capacity.
    pub fn empty(spec: EncodeSpec) -> EncodedBatch {
        EncodedBatch {
            spec_encoding: spec.encoding,
            spec_word: spec.word,
            n: 0,
            h: 0,
            w: 0,
            c: 0,
            words_u64: Vec::new(),
            words_f64: Vec::new(),
            offsets: Vec::new(),
            labels: Vec::new(),
            num_classes: 0,
        }
    }

    /// Payload bytes actually shipped (words + offsets + labels excluded).
    pub fn payload_bytes(&self) -> u64 {
        let words = match self.spec_word {
            WordType::U64 => self.words_u64.len() * 8,
            WordType::F64 => self.words_f64.len() * 8,
        };
        (words + self.offsets.len()) as u64
    }

    /// Compression ratio vs a f32-materialized batch of the same images.
    pub fn ratio_vs_f32(&self) -> f64 {
        (self.n * self.h * self.w * self.c * 4) as f64 / self.payload_bytes() as f64
    }

    /// Compression ratio vs the paper's f64-materialized baseline.
    pub fn ratio_vs_f64(&self) -> f64 {
        (self.n * self.h * self.w * self.c * 8) as f64 / self.payload_bytes() as f64
    }
}

/// Errors from encode/decode.
#[derive(Debug, PartialEq)]
pub enum EncodeError {
    /// Batch has more images than the (encoding, word) pair can hold.
    OverCapacity { n: usize, capacity: usize },
    /// Batch is empty.
    Empty,
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::OverCapacity { n, capacity } => {
                write!(f, "batch of {n} images exceeds encoding capacity {capacity}")
            }
            EncodeError::Empty => write!(f, "cannot encode an empty batch"),
        }
    }
}

impl std::error::Error for EncodeError {}

#[inline]
fn offset_index(img: usize, pixel: usize, pixels: usize) -> (usize, u8) {
    let bit = img * pixels + pixel;
    (bit / 8, 1u8 << (bit % 8))
}

/// Words per tile of the blocked packing loop: 4096 × 8 B = 32 KiB, sized
/// to keep the tile L1-resident while every image's digits land in it.
const PACK_BLOCK: usize = 4096;

/// Algorithm 1 inner loop: word(p) = Σ_i img_i(p) << (8 i), tiled so the
/// word array is traversed once.
fn pack_base256(batch: &ImageBatch, start: usize, n: usize, words: &mut [u64]) {
    let pixels = words.len();
    let mut b0 = 0;
    while b0 < pixels {
        let b1 = (b0 + PACK_BLOCK).min(pixels);
        for i in 0..n {
            let shift = (8 * i) as u32;
            let img = batch.image(start + i);
            for (w, &px) in words[b0..b1].iter_mut().zip(&img[b0..b1]) {
                *w |= (px as u64) << shift;
            }
        }
        b0 = b1;
    }
}

/// Algorithm 4 inner loop: digit = pixel >> 1 packed base-128, parity bit
/// recorded in the plane. Same tiling as [`pack_base256`].
fn pack_lossless128(
    batch: &ImageBatch,
    start: usize,
    n: usize,
    words: &mut [u64],
    offsets: &mut [u8],
) {
    let pixels = words.len();
    let mut b0 = 0;
    while b0 < pixels {
        let b1 = (b0 + PACK_BLOCK).min(pixels);
        for i in 0..n {
            let shift = (7 * i) as u32;
            let img = batch.image(start + i);
            for p in b0..b1 {
                let px = img[p] as u64;
                words[p] |= (px >> 1) << shift;
                if px & 1 == 1 {
                    let (byte, mask) = offset_index(i, p, pixels);
                    offsets[byte] |= mask;
                }
            }
        }
        b0 = b1;
    }
}

/// Pack images `[start, start+n)` of `batch` into `out`, reusing `out`'s
/// buffers (existing capacity is kept; no allocation once warm).
fn encode_range_core(
    batch: &ImageBatch,
    start: usize,
    n: usize,
    spec: EncodeSpec,
    out: &mut EncodedBatch,
) {
    let pixels = batch.image_len();
    out.spec_encoding = spec.encoding;
    out.spec_word = spec.word;
    out.n = n;
    out.h = batch.h;
    out.w = batch.w;
    out.c = batch.c;
    out.num_classes = batch.num_classes;
    out.words_u64.clear();
    out.words_u64.resize(pixels, 0);
    out.offsets.clear();
    match spec.encoding {
        Encoding::Base256 => pack_base256(batch, start, n, &mut out.words_u64),
        Encoding::Lossless128 => {
            out.offsets.resize((n * pixels + 7) / 8, 0);
            pack_lossless128(batch, start, n, &mut out.words_u64, &mut out.offsets);
        }
    }
    out.words_f64.clear();
    if spec.word == WordType::F64 {
        // Exactness guaranteed by the capacity check: value < 2^53. The u64
        // vector doubles as packing scratch and keeps its capacity for the
        // next reuse of this shell.
        out.words_f64.extend(out.words_u64.iter().map(|&w| w as f64));
        out.words_u64.clear();
    }
    let k = batch.num_classes;
    out.labels.clear();
    out.labels.extend_from_slice(&batch.labels[start * k..(start + n) * k]);
}

/// Encode images `[start, start+n)` of `batch` into `out` (buffer-reusing
/// form; see [`encode_batch_into`] for the whole-batch convenience).
pub fn encode_range_into(
    batch: &ImageBatch,
    start: usize,
    n: usize,
    spec: EncodeSpec,
    out: &mut EncodedBatch,
) -> Result<(), EncodeError> {
    if n == 0 {
        return Err(EncodeError::Empty);
    }
    let cap = spec.capacity();
    if n > cap {
        return Err(EncodeError::OverCapacity { n, capacity: cap });
    }
    assert!(start + n <= batch.n, "range {start}+{n} out of batch of {}", batch.n);
    encode_range_core(batch, start, n, spec, out);
    Ok(())
}

/// Algorithm 1 / 4 into caller-provided storage: `out`'s buffers are
/// reused, so steady-state encoding allocates nothing.
pub fn encode_batch_into(
    batch: &ImageBatch,
    spec: EncodeSpec,
    out: &mut EncodedBatch,
) -> Result<(), EncodeError> {
    encode_range_into(batch, 0, batch.n, spec, out)
}

/// Algorithm 1 / 4: pack `batch` according to `spec` (allocating form).
pub fn encode_batch(batch: &ImageBatch, spec: EncodeSpec) -> Result<EncodedBatch, EncodeError> {
    let mut out = EncodedBatch::empty(spec);
    encode_batch_into(batch, spec, &mut out)?;
    Ok(out)
}

/// Algorithm 3 (+ offset reapplication for Algorithm 4): unpack to uint8.
pub fn decode_batch(enc: &EncodedBatch) -> ImageBatch {
    let pixels = enc.h * enc.w * enc.c;
    let mut out = ImageBatch::zeros(enc.n, enc.h, enc.w, enc.c, enc.num_classes.max(1));
    out.labels = enc.labels.clone();
    out.num_classes = enc.num_classes;
    let widened: Vec<u64>;
    let words: &[u64] = match enc.spec_word {
        WordType::U64 => &enc.words_u64,
        WordType::F64 => {
            widened = enc.words_f64.iter().map(|&w| w as u64).collect();
            &widened
        }
    };
    let bits = enc.spec_encoding.digit_bits();
    let mask = enc.spec_encoding.base() - 1;
    for i in 0..enc.n {
        let shift = bits * i as u32;
        let dst = out.image_mut(i);
        match enc.spec_encoding {
            Encoding::Base256 => {
                for (p, &w) in words.iter().enumerate() {
                    dst[p] = ((w >> shift) & mask) as u8;
                }
            }
            Encoding::Lossless128 => {
                for (p, &w) in words.iter().enumerate() {
                    let digit = ((w >> shift) & mask) as u8;
                    let (byte, bmask) = offset_index(i, p, pixels);
                    let parity = (enc.offsets[byte] & bmask != 0) as u8;
                    dst[p] = (digit << 1) | parity;
                }
            }
        }
    }
    out
}

/// Split an oversized batch into capacity-sized packed groups — how the
/// loader ships batches larger than one word's capacity. Groups slice
/// images directly out of `batch` (no per-group sub-batch copy).
pub fn encode_batch_grouped(
    batch: &ImageBatch,
    spec: EncodeSpec,
) -> Result<Vec<EncodedBatch>, EncodeError> {
    if batch.n == 0 {
        return Err(EncodeError::Empty);
    }
    let cap = spec.capacity();
    let mut out = Vec::new();
    let mut start = 0;
    while start < batch.n {
        let take = cap.min(batch.n - start);
        let mut e = EncodedBatch::empty(spec);
        encode_range_into(batch, start, take, spec, &mut e)?;
        out.push(e);
        start += take;
    }
    Ok(out)
}

/// [`encode_batch_grouped`] with every buffer drawn from `pool` — the E-D
/// producer hot path. `out` must be empty (take it from
/// [`BufferPool::take_shells`]); on success it holds the packed groups,
/// and recycling the payload returns every buffer to the pool.
pub fn encode_batch_grouped_into(
    batch: &ImageBatch,
    spec: EncodeSpec,
    pool: &BufferPool,
    out: &mut Vec<EncodedBatch>,
) -> Result<(), EncodeError> {
    debug_assert!(out.is_empty(), "grouped encode target must start empty");
    if batch.n == 0 {
        return Err(EncodeError::Empty);
    }
    let cap = spec.capacity();
    let pixels = batch.image_len();
    let k = batch.num_classes;
    let mut start = 0;
    while start < batch.n {
        let take = cap.min(batch.n - start);
        let mut e = EncodedBatch::empty(spec);
        e.words_u64 = pool.take_u64(pixels);
        if spec.word == WordType::F64 {
            e.words_f64 = pool.take_f64(pixels);
        }
        if spec.encoding == Encoding::Lossless128 {
            e.offsets = pool.take_u8((take * pixels + 7) / 8);
        }
        e.labels = pool.take_f32(take * k);
        encode_range_into(batch, start, take, spec, &mut e)?;
        out.push(e);
        start += take;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_batch(rng: &mut Rng, n: usize, h: usize, w: usize, c: usize) -> ImageBatch {
        let mut b = ImageBatch::zeros(n, h, w, c, 10);
        for v in b.data.iter_mut() {
            *v = (rng.next_u32() & 0xff) as u8;
        }
        for i in 0..n {
            let cls = rng.gen_range(10);
            b.label_mut(i)[cls] = 1.0;
        }
        b
    }

    #[test]
    fn capacities_match_design() {
        assert_eq!(Encoding::Base256.capacity(WordType::U64), 8);
        assert_eq!(Encoding::Base256.capacity(WordType::F64), 6);
        assert_eq!(Encoding::Lossless128.capacity(WordType::U64), 9);
        assert_eq!(Encoding::Lossless128.capacity(WordType::F64), 7);
    }

    #[test]
    fn base256_u64_roundtrip_exact() {
        let mut rng = Rng::new(1);
        let b = random_batch(&mut rng, 8, 7, 5, 3);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(decode_batch(&enc), b);
    }

    #[test]
    fn base256_f64_roundtrip_exact_at_capacity() {
        let mut rng = Rng::new(2);
        let b = random_batch(&mut rng, 6, 4, 4, 3);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::F64)).unwrap();
        assert_eq!(decode_batch(&enc), b);
    }

    #[test]
    fn base256_f64_saturated_pixels() {
        // All-255 pixels maximize the packed value; must still be exact.
        let mut b = ImageBatch::zeros(6, 2, 2, 1, 2);
        b.data.fill(255);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::F64)).unwrap();
        assert_eq!(decode_batch(&enc).data, b.data);
    }

    #[test]
    fn blocked_pack_spans_tile_boundaries() {
        // An image larger than PACK_BLOCK pixels forces multiple tiles; the
        // roundtrip must still be exact across the boundary.
        let mut rng = Rng::new(77);
        let h = 80; // 80*80*1 = 6400 pixels > PACK_BLOCK
        let b = random_batch(&mut rng, 8, h, h, 1);
        assert!(b.image_len() > PACK_BLOCK);
        for spec in [
            EncodeSpec::new(Encoding::Base256, WordType::U64),
            EncodeSpec::new(Encoding::Lossless128, WordType::U64),
        ] {
            let enc = encode_batch(&b, spec).unwrap();
            assert_eq!(decode_batch(&enc), b, "{spec:?}");
        }
    }

    #[test]
    fn lossless128_roundtrip_all_word_types() {
        let mut rng = Rng::new(3);
        for (word, n) in [(WordType::U64, 9), (WordType::F64, 7)] {
            let b = random_batch(&mut rng, n, 5, 3, 3);
            let enc = encode_batch(&b, EncodeSpec::new(Encoding::Lossless128, word)).unwrap();
            assert_eq!(decode_batch(&enc), b, "word {word:?}");
        }
    }

    #[test]
    fn lossless128_parity_extremes() {
        let mut b = ImageBatch::zeros(9, 2, 2, 1, 2);
        // alternate odd/even pixels
        for (i, v) in b.data.iter_mut().enumerate() {
            *v = if i % 2 == 0 { 255 } else { 254 };
        }
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Lossless128, WordType::U64)).unwrap();
        assert_eq!(decode_batch(&enc).data, b.data);
    }

    #[test]
    fn over_capacity_rejected() {
        let b = ImageBatch::zeros(9, 2, 2, 1, 2);
        let err = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap_err();
        assert_eq!(err, EncodeError::OverCapacity { n: 9, capacity: 8 });
        let b7 = ImageBatch::zeros(7, 2, 2, 1, 2);
        assert!(encode_batch(&b7, EncodeSpec::new(Encoding::Base256, WordType::F64)).is_err());
    }

    #[test]
    fn empty_rejected() {
        let b = ImageBatch::zeros(0, 2, 2, 1, 2);
        assert_eq!(
            encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap_err(),
            EncodeError::Empty
        );
    }

    #[test]
    fn partial_batch_roundtrip() {
        // Fewer images than capacity: upper digits stay zero.
        let mut rng = Rng::new(4);
        let b = random_batch(&mut rng, 3, 4, 4, 3);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(decode_batch(&enc), b);
    }

    #[test]
    fn grouped_encode_covers_whole_batch() {
        let mut rng = Rng::new(5);
        let b = random_batch(&mut rng, 20, 3, 3, 3);
        let groups =
            encode_batch_grouped(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(groups.iter().map(|g| g.n).collect::<Vec<_>>(), vec![8, 8, 4]);
        // Re-assemble and compare.
        let mut rebuilt = Vec::new();
        for g in &groups {
            rebuilt.extend_from_slice(&decode_batch(g).data);
        }
        assert_eq!(rebuilt, b.data);
    }

    #[test]
    fn grouped_labels_follow_their_group() {
        let mut rng = Rng::new(15);
        let b = random_batch(&mut rng, 14, 3, 3, 1);
        let groups =
            encode_batch_grouped(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        let mut labels = Vec::new();
        for g in &groups {
            labels.extend_from_slice(&g.labels);
        }
        assert_eq!(labels, b.labels);
    }

    #[test]
    fn into_variant_reuses_buffers_and_matches() {
        let mut rng = Rng::new(6);
        let spec = EncodeSpec::new(Encoding::Lossless128, WordType::F64);
        let mut shell = EncodedBatch::empty(spec);
        for round in 0..3 {
            let b = random_batch(&mut rng, 7, 6, 6, 3);
            encode_batch_into(&b, spec, &mut shell).unwrap();
            let fresh = encode_batch(&b, spec).unwrap();
            assert_eq!(shell.words_f64, fresh.words_f64, "round {round}");
            assert_eq!(shell.offsets, fresh.offsets, "round {round}");
            assert_eq!(shell.labels, fresh.labels, "round {round}");
            assert_eq!(decode_batch(&shell), b, "round {round}");
        }
    }

    #[test]
    fn pooled_grouped_encode_matches_plain() {
        use crate::data::pool::BufferPool;
        let pool = BufferPool::default();
        let mut rng = Rng::new(7);
        let spec = EncodeSpec::new(Encoding::Base256, WordType::F64);
        // 12 images at capacity 6 → two same-shaped groups, so steady-state
        // pool hits are exact (no LIFO size-mismatch regrows).
        for _ in 0..3 {
            let b = random_batch(&mut rng, 12, 8, 8, 3);
            let plain = encode_batch_grouped(&b, spec).unwrap();
            let mut pooled = pool.take_shells();
            encode_batch_grouped_into(&b, spec, &pool, &mut pooled).unwrap();
            assert_eq!(plain.len(), pooled.len());
            for (a, x) in plain.iter().zip(&pooled) {
                assert_eq!(a.words_f64, x.words_f64);
                assert_eq!(a.labels, x.labels);
                assert_eq!(a.n, x.n);
            }
            // return everything (shell included) so the next round reuses
            pool.recycle_payload(crate::data::loader::BatchPayload::Encoded(pooled));
        }
        // 3 rounds, but only round 1 may allocate (shells vec + 2 groups ×
        // (words_u64 + words_f64 + labels)).
        assert_eq!(pool.allocs(), 1 + 2 * 3, "steady-state rounds must not allocate");
    }

    #[test]
    fn payload_ratios_vs_baselines() {
        // 8 images packed into u64 words: 8·pixels bytes vs 4·8·pixels (f32)
        // → 4×, vs 8·8·pixels (f64) → 8×.
        let b = ImageBatch::zeros(8, 8, 8, 3, 10);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert!((enc.ratio_vs_f32() - 4.0).abs() < 1e-9);
        assert!((enc.ratio_vs_f64() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn labels_pass_through() {
        let mut rng = Rng::new(6);
        let b = random_batch(&mut rng, 4, 2, 2, 1);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(enc.labels, b.labels);
        assert_eq!(decode_batch(&enc).labels, b.labels);
    }
}
