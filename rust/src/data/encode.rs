//! Batch encoding/decoding — the paper's Algorithms 1, 3 and 4.
//!
//! Packs N uint8 images positionally into one same-shaped tensor of wider
//! words: pixel position p of the packed tensor holds
//! `Σ_i digit_i(p) · B^i` with base `B = 256` (Algorithm 1) or `B = 128`
//! plus a parity bitplane (Algorithm 4, "loss-less forced encoding").
//!
//! ## Capacity corrections (DESIGN.md §4)
//!
//! The paper claims 16 images per float64 word (and 32 with the offset
//! trick); both are arithmetically impossible. Exact capacities enforced
//! here:
//!
//! | encoding          | u64 word | f64 word (53-bit mantissa) |
//! |-------------------|----------|----------------------------|
//! | base-256 (Alg 1)  | 8        | 6                          |
//! | base-128 (Alg 4)  | 9        | 7                          |
//!
//! (The paper also indexes `256^i` from `i = 1`, which would waste the
//! lowest digit; we index from 0 as the decode algorithm implies.)
//!
//! The f64 flavour is what crosses the PJRT boundary (the L1 Pallas decode
//! kernel consumes it); the u64 flavour maximizes density for host-side
//! storage and transfer.

use crate::data::image::ImageBatch;

/// Word type the packed tensor uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WordType {
    U64,
    F64,
}

/// Packing scheme.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Encoding {
    /// Algorithm 1: exact base-256 digits.
    Base256,
    /// Algorithm 4: base-128 digits + parity bitplane (lossless).
    Lossless128,
}

impl Encoding {
    /// Bits per image digit.
    pub fn digit_bits(self) -> u32 {
        match self {
            Encoding::Base256 => 8,
            Encoding::Lossless128 => 7,
        }
    }

    pub fn base(self) -> u64 {
        1u64 << self.digit_bits()
    }

    /// Maximum number of images a single word can hold exactly.
    pub fn capacity(self, word: WordType) -> usize {
        let mantissa_bits = match word {
            WordType::U64 => 64,
            WordType::F64 => 53, // IEEE-754 double significand (incl. implicit bit)
        };
        (mantissa_bits / self.digit_bits()) as usize
    }
}

/// A fully-specified encoder configuration.
#[derive(Clone, Copy, Debug)]
pub struct EncodeSpec {
    pub encoding: Encoding,
    pub word: WordType,
}

impl EncodeSpec {
    pub fn new(encoding: Encoding, word: WordType) -> EncodeSpec {
        EncodeSpec { encoding, word }
    }

    pub fn capacity(&self) -> usize {
        self.encoding.capacity(self.word)
    }
}

/// A packed batch: one word per pixel position plus (for lossless mode) the
/// parity bitplane, and the pass-through labels.
#[derive(Clone, Debug)]
pub struct EncodedBatch {
    pub spec_encoding: Encoding,
    pub spec_word: WordType,
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    /// Packed words (length `h*w*c`), valid when `spec_word == U64`.
    pub words_u64: Vec<u64>,
    /// Packed words (length `h*w*c`), valid when `spec_word == F64`.
    pub words_f64: Vec<f64>,
    /// Parity bitplane for [`Encoding::Lossless128`], bit i of byte
    /// `(img*pixels + p) / 8` — empty for Base256.
    pub offsets: Vec<u8>,
    pub labels: Vec<f32>,
    pub num_classes: usize,
}

impl EncodedBatch {
    /// Payload bytes actually shipped (words + offsets + labels excluded).
    pub fn payload_bytes(&self) -> u64 {
        let words = match self.spec_word {
            WordType::U64 => self.words_u64.len() * 8,
            WordType::F64 => self.words_f64.len() * 8,
        };
        (words + self.offsets.len()) as u64
    }

    /// Compression ratio vs a f32-materialized batch of the same images.
    pub fn ratio_vs_f32(&self) -> f64 {
        (self.n * self.h * self.w * self.c * 4) as f64 / self.payload_bytes() as f64
    }

    /// Compression ratio vs the paper's f64-materialized baseline.
    pub fn ratio_vs_f64(&self) -> f64 {
        (self.n * self.h * self.w * self.c * 8) as f64 / self.payload_bytes() as f64
    }
}

/// Errors from encode/decode.
#[derive(Debug, PartialEq)]
pub enum EncodeError {
    /// Batch has more images than the (encoding, word) pair can hold.
    OverCapacity { n: usize, capacity: usize },
    /// Batch is empty.
    Empty,
}

impl std::fmt::Display for EncodeError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            EncodeError::OverCapacity { n, capacity } => {
                write!(f, "batch of {n} images exceeds encoding capacity {capacity}")
            }
            EncodeError::Empty => write!(f, "cannot encode an empty batch"),
        }
    }
}

impl std::error::Error for EncodeError {}

#[inline]
fn offset_index(img: usize, pixel: usize, pixels: usize) -> (usize, u8) {
    let bit = img * pixels + pixel;
    (bit / 8, 1u8 << (bit % 8))
}

/// Algorithm 1 / 4: pack `batch` according to `spec`.
pub fn encode_batch(batch: &ImageBatch, spec: EncodeSpec) -> Result<EncodedBatch, EncodeError> {
    if batch.n == 0 {
        return Err(EncodeError::Empty);
    }
    let cap = spec.capacity();
    if batch.n > cap {
        return Err(EncodeError::OverCapacity { n: batch.n, capacity: cap });
    }
    let pixels = batch.image_len();
    let mut words = vec![0u64; pixels];
    let mut offsets = Vec::new();
    match spec.encoding {
        Encoding::Base256 => {
            // word(p) = Σ_i img_i(p) << (8 i)
            for i in 0..batch.n {
                let img = batch.image(i);
                let shift = 8 * i as u32;
                for (p, w) in words.iter_mut().enumerate() {
                    *w |= (img[p] as u64) << shift;
                }
            }
        }
        Encoding::Lossless128 => {
            // digit = pixel >> 1 (0..=127); parity bit recorded in the plane.
            offsets = vec![0u8; (batch.n * pixels + 7) / 8];
            for i in 0..batch.n {
                let img = batch.image(i);
                let shift = 7 * i as u32;
                for (p, w) in words.iter_mut().enumerate() {
                    let px = img[p] as u64;
                    *w |= (px >> 1) << shift;
                    if px & 1 == 1 {
                        let (byte, mask) = offset_index(i, p, pixels);
                        offsets[byte] |= mask;
                    }
                }
            }
        }
    }
    let (words_u64, words_f64) = match spec.word {
        WordType::U64 => (words, Vec::new()),
        WordType::F64 => {
            // Exactness guaranteed by the capacity check: value < 2^53.
            (Vec::new(), words.iter().map(|&w| w as f64).collect())
        }
    };
    Ok(EncodedBatch {
        spec_encoding: spec.encoding,
        spec_word: spec.word,
        n: batch.n,
        h: batch.h,
        w: batch.w,
        c: batch.c,
        words_u64,
        words_f64,
        offsets,
        labels: batch.labels.clone(),
        num_classes: batch.num_classes,
    })
}

/// Algorithm 3 (+ offset reapplication for Algorithm 4): unpack to uint8.
pub fn decode_batch(enc: &EncodedBatch) -> ImageBatch {
    let pixels = enc.h * enc.w * enc.c;
    let mut out = ImageBatch::zeros(enc.n, enc.h, enc.w, enc.c, enc.num_classes.max(1));
    out.labels = enc.labels.clone();
    out.num_classes = enc.num_classes;
    let words: Vec<u64> = match enc.spec_word {
        WordType::U64 => enc.words_u64.clone(),
        WordType::F64 => enc.words_f64.iter().map(|&w| w as u64).collect(),
    };
    let bits = enc.spec_encoding.digit_bits();
    let mask = enc.spec_encoding.base() - 1;
    for i in 0..enc.n {
        let shift = bits * i as u32;
        let dst = out.image_mut(i);
        match enc.spec_encoding {
            Encoding::Base256 => {
                for (p, &w) in words.iter().enumerate() {
                    dst[p] = ((w >> shift) & mask) as u8;
                }
            }
            Encoding::Lossless128 => {
                for (p, &w) in words.iter().enumerate() {
                    let digit = ((w >> shift) & mask) as u8;
                    let (byte, bmask) = offset_index(i, p, pixels);
                    let parity = (enc.offsets[byte] & bmask != 0) as u8;
                    dst[p] = (digit << 1) | parity;
                }
            }
        }
    }
    out
}

/// Split an oversized batch into capacity-sized packed groups — how the
/// loader ships batches larger than one word's capacity.
pub fn encode_batch_grouped(
    batch: &ImageBatch,
    spec: EncodeSpec,
) -> Result<Vec<EncodedBatch>, EncodeError> {
    if batch.n == 0 {
        return Err(EncodeError::Empty);
    }
    let cap = spec.capacity();
    let mut out = Vec::new();
    let mut start = 0;
    while start < batch.n {
        let take = cap.min(batch.n - start);
        let mut sub = ImageBatch::zeros(take, batch.h, batch.w, batch.c, batch.num_classes);
        let len = batch.image_len();
        sub.data
            .copy_from_slice(&batch.data[start * len..(start + take) * len]);
        sub.labels.copy_from_slice(
            &batch.labels[start * batch.num_classes..(start + take) * batch.num_classes],
        );
        out.push(encode_batch(&sub, spec)?);
        start += take;
    }
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn random_batch(rng: &mut Rng, n: usize, h: usize, w: usize, c: usize) -> ImageBatch {
        let mut b = ImageBatch::zeros(n, h, w, c, 10);
        for v in b.data.iter_mut() {
            *v = (rng.next_u32() & 0xff) as u8;
        }
        for i in 0..n {
            let cls = rng.gen_range(10);
            b.label_mut(i)[cls] = 1.0;
        }
        b
    }

    #[test]
    fn capacities_match_design() {
        assert_eq!(Encoding::Base256.capacity(WordType::U64), 8);
        assert_eq!(Encoding::Base256.capacity(WordType::F64), 6);
        assert_eq!(Encoding::Lossless128.capacity(WordType::U64), 9);
        assert_eq!(Encoding::Lossless128.capacity(WordType::F64), 7);
    }

    #[test]
    fn base256_u64_roundtrip_exact() {
        let mut rng = Rng::new(1);
        let b = random_batch(&mut rng, 8, 7, 5, 3);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(decode_batch(&enc), b);
    }

    #[test]
    fn base256_f64_roundtrip_exact_at_capacity() {
        let mut rng = Rng::new(2);
        let b = random_batch(&mut rng, 6, 4, 4, 3);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::F64)).unwrap();
        assert_eq!(decode_batch(&enc), b);
    }

    #[test]
    fn base256_f64_saturated_pixels() {
        // All-255 pixels maximize the packed value; must still be exact.
        let mut b = ImageBatch::zeros(6, 2, 2, 1, 2);
        b.data.fill(255);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::F64)).unwrap();
        assert_eq!(decode_batch(&enc).data, b.data);
    }

    #[test]
    fn lossless128_roundtrip_all_word_types() {
        let mut rng = Rng::new(3);
        for (word, n) in [(WordType::U64, 9), (WordType::F64, 7)] {
            let b = random_batch(&mut rng, n, 5, 3, 3);
            let enc = encode_batch(&b, EncodeSpec::new(Encoding::Lossless128, word)).unwrap();
            assert_eq!(decode_batch(&enc), b, "word {word:?}");
        }
    }

    #[test]
    fn lossless128_parity_extremes() {
        let mut b = ImageBatch::zeros(9, 2, 2, 1, 2);
        // alternate odd/even pixels
        for (i, v) in b.data.iter_mut().enumerate() {
            *v = if i % 2 == 0 { 255 } else { 254 };
        }
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Lossless128, WordType::U64)).unwrap();
        assert_eq!(decode_batch(&enc).data, b.data);
    }

    #[test]
    fn over_capacity_rejected() {
        let b = ImageBatch::zeros(9, 2, 2, 1, 2);
        let err = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap_err();
        assert_eq!(err, EncodeError::OverCapacity { n: 9, capacity: 8 });
        let b7 = ImageBatch::zeros(7, 2, 2, 1, 2);
        assert!(encode_batch(&b7, EncodeSpec::new(Encoding::Base256, WordType::F64)).is_err());
    }

    #[test]
    fn empty_rejected() {
        let b = ImageBatch::zeros(0, 2, 2, 1, 2);
        assert_eq!(
            encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap_err(),
            EncodeError::Empty
        );
    }

    #[test]
    fn partial_batch_roundtrip() {
        // Fewer images than capacity: upper digits stay zero.
        let mut rng = Rng::new(4);
        let b = random_batch(&mut rng, 3, 4, 4, 3);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(decode_batch(&enc), b);
    }

    #[test]
    fn grouped_encode_covers_whole_batch() {
        let mut rng = Rng::new(5);
        let b = random_batch(&mut rng, 20, 3, 3, 3);
        let groups =
            encode_batch_grouped(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(groups.iter().map(|g| g.n).collect::<Vec<_>>(), vec![8, 8, 4]);
        // Re-assemble and compare.
        let mut rebuilt = Vec::new();
        for g in &groups {
            rebuilt.extend_from_slice(&decode_batch(g).data);
        }
        assert_eq!(rebuilt, b.data);
    }

    #[test]
    fn payload_ratios_vs_baselines() {
        // 8 images packed into u64 words: 8·pixels bytes vs 4·8·pixels (f32)
        // → 4×, vs 8·8·pixels (f64) → 8×.
        let b = ImageBatch::zeros(8, 8, 8, 3, 10);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert!((enc.ratio_vs_f32() - 4.0).abs() < 1e-9);
        assert!((enc.ratio_vs_f64() - 8.0).abs() < 1e-9);
    }

    #[test]
    fn labels_pass_through() {
        let mut rng = Rng::new(6);
        let b = random_batch(&mut rng, 4, 2, 2, 1);
        let enc = encode_batch(&b, EncodeSpec::new(Encoding::Base256, WordType::U64)).unwrap();
        assert_eq!(enc.labels, b.labels);
        assert_eq!(decode_batch(&enc).labels, b.labels);
    }
}
