//! Image and batch containers (uint8 HWC, batches NHWC-contiguous).
//!
//! These are the host-side types the data pipeline operates on before a
//! batch is packed by [`crate::data::encode`] (E-D pipelines) or widened to
//! f32 (baseline pipelines) and handed to the PJRT runtime.

/// A single uint8 image, HWC layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
}

impl Image {
    pub fn zeros(h: usize, w: usize, c: usize) -> Image {
        Image { h, w, c, data: vec![0; h * w * c] }
    }

    #[inline]
    pub fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> u8 {
        self.data[self.idx(y, x, ch)]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: u8) {
        let i = self.idx(y, x, ch);
        self.data[i] = v;
    }

    pub fn pixels(&self) -> usize {
        self.h * self.w * self.c
    }
}

/// A batch of same-shaped uint8 images, contiguous NHWC.
#[derive(Clone, Debug, PartialEq)]
pub struct ImageBatch {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
    /// Soft labels, `n × num_classes`, row-major. One-hot for plain samples;
    /// mixed for MixUp/CutMix outputs.
    pub labels: Vec<f32>,
    pub num_classes: usize,
}

impl ImageBatch {
    pub fn zeros(n: usize, h: usize, w: usize, c: usize, num_classes: usize) -> ImageBatch {
        ImageBatch {
            n,
            h,
            w,
            c,
            data: vec![0; n * h * w * c],
            labels: vec![0.0; n * num_classes],
            num_classes,
        }
    }

    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow image `i`'s bytes.
    pub fn image(&self, i: usize) -> &[u8] {
        let len = self.image_len();
        &self.data[i * len..(i + 1) * len]
    }

    /// Mutably borrow image `i`'s bytes.
    pub fn image_mut(&mut self, i: usize) -> &mut [u8] {
        let len = self.image_len();
        &mut self.data[i * len..(i + 1) * len]
    }

    /// Copy an [`Image`] + one-hot label into slot `i`.
    pub fn put(&mut self, i: usize, img: &Image, class: usize) {
        assert_eq!((img.h, img.w, img.c), (self.h, self.w, self.c), "shape mismatch");
        assert!(class < self.num_classes);
        self.image_mut(i).copy_from_slice(&img.data);
        let row = &mut self.labels[i * self.num_classes..(i + 1) * self.num_classes];
        row.fill(0.0);
        row[class] = 1.0;
    }

    /// Soft-label row for image `i`.
    pub fn label(&self, i: usize) -> &[f32] {
        &self.labels[i * self.num_classes..(i + 1) * self.num_classes]
    }

    pub fn label_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.labels[i * self.num_classes..(i + 1) * self.num_classes]
    }

    /// Hard label = argmax of the soft row.
    pub fn hard_label(&self, i: usize) -> usize {
        let row = self.label(i);
        let mut best = 0;
        for (j, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = j;
            }
        }
        best
    }

    /// Widen the batch to f32 in `[0,1)` (the baseline pipelines' payload).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&b| b as f32 / 255.0).collect()
    }

    /// Bytes of the raw uint8 payload.
    pub fn payload_bytes_u8(&self) -> u64 {
        self.data.len() as u64
    }

    /// Bytes if materialized as f32 (what standard loaders ship).
    pub fn payload_bytes_f32(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Bytes if materialized as f64 (the paper's stated baseline).
    pub fn payload_bytes_f64(&self) -> u64 {
        (self.data.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_indexing_roundtrip() {
        let mut img = Image::zeros(4, 5, 3);
        img.set(2, 3, 1, 200);
        assert_eq!(img.get(2, 3, 1), 200);
        assert_eq!(img.get(2, 3, 0), 0);
        assert_eq!(img.pixels(), 60);
    }

    #[test]
    fn batch_put_and_read_back() {
        let mut b = ImageBatch::zeros(2, 2, 2, 1, 3);
        let mut img = Image::zeros(2, 2, 1);
        img.data.copy_from_slice(&[1, 2, 3, 4]);
        b.put(1, &img, 2);
        assert_eq!(b.image(1), &[1, 2, 3, 4]);
        assert_eq!(b.image(0), &[0, 0, 0, 0]);
        assert_eq!(b.label(1), &[0.0, 0.0, 1.0]);
        assert_eq!(b.hard_label(1), 2);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn batch_put_rejects_wrong_shape() {
        let mut b = ImageBatch::zeros(1, 2, 2, 1, 2);
        let img = Image::zeros(3, 3, 1);
        b.put(0, &img, 0);
    }

    #[test]
    fn payload_sizes() {
        let b = ImageBatch::zeros(16, 32, 32, 3, 10);
        assert_eq!(b.payload_bytes_u8(), 16 * 32 * 32 * 3);
        assert_eq!(b.payload_bytes_f32(), 4 * 16 * 32 * 32 * 3);
        assert_eq!(b.payload_bytes_f64(), 8 * 16 * 32 * 32 * 3);
    }

    #[test]
    fn to_f32_normalizes() {
        let mut b = ImageBatch::zeros(1, 1, 1, 1, 2);
        b.data[0] = 255;
        let f = b.to_f32();
        assert!((f[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn soft_labels_mix() {
        let mut b = ImageBatch::zeros(1, 1, 1, 1, 2);
        b.label_mut(0).copy_from_slice(&[0.3, 0.7]);
        assert_eq!(b.hard_label(0), 1);
    }
}
