//! Image and batch containers (uint8 HWC, batches NHWC-contiguous).
//!
//! These are the host-side types the data pipeline operates on before a
//! batch is packed by [`crate::data::encode`] (E-D pipelines) or widened to
//! f32 (baseline pipelines) and handed to the PJRT runtime.

/// A single uint8 image, HWC layout.
#[derive(Clone, Debug, PartialEq)]
pub struct Image {
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
}

impl Image {
    pub fn zeros(h: usize, w: usize, c: usize) -> Image {
        Image { h, w, c, data: vec![0; h * w * c] }
    }

    #[inline]
    pub fn idx(&self, y: usize, x: usize, ch: usize) -> usize {
        (y * self.w + x) * self.c + ch
    }

    #[inline]
    pub fn get(&self, y: usize, x: usize, ch: usize) -> u8 {
        self.data[self.idx(y, x, ch)]
    }

    #[inline]
    pub fn set(&mut self, y: usize, x: usize, ch: usize, v: u8) {
        let i = self.idx(y, x, ch);
        self.data[i] = v;
    }

    pub fn pixels(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Re-shape in place for reuse as a staging buffer (the
    /// [`Dataset::get_into`](crate::data::dataset::Dataset::get_into) hot
    /// path): the existing allocation is kept when large enough. Contents
    /// are **unspecified** (only newly grown regions are zero-filled) —
    /// callers overwrite every pixel.
    pub fn reset(&mut self, h: usize, w: usize, c: usize) {
        self.h = h;
        self.w = w;
        self.c = c;
        self.data.resize(h * w * c, 0);
    }

    /// Copy `src` into `self`, reusing `self`'s allocation (shape is
    /// adopted from `src`). With warm capacity this never allocates.
    pub fn copy_from(&mut self, src: &Image) {
        self.reset(src.h, src.w, src.c);
        self.data.copy_from_slice(&src.data);
    }
}

/// A batch of same-shaped uint8 images, contiguous NHWC.
#[derive(Clone, Debug, PartialEq)]
pub struct ImageBatch {
    pub n: usize,
    pub h: usize,
    pub w: usize,
    pub c: usize,
    pub data: Vec<u8>,
    /// Soft labels, `n × num_classes`, row-major. One-hot for plain samples;
    /// mixed for MixUp/CutMix outputs.
    pub labels: Vec<f32>,
    pub num_classes: usize,
}

impl ImageBatch {
    pub fn zeros(n: usize, h: usize, w: usize, c: usize, num_classes: usize) -> ImageBatch {
        ImageBatch {
            n,
            h,
            w,
            c,
            data: vec![0; n * h * w * c],
            labels: vec![0.0; n * num_classes],
            num_classes,
        }
    }

    /// Re-shape in place for reuse as a staging buffer (hot path): existing
    /// allocations are kept when large enough. Contents are **unspecified**
    /// (only newly grown regions are zero-filled) — callers such as
    /// [`crate::data::sampler::materialize_plan_into`] overwrite every slot,
    /// so re-zeroing the whole buffer per batch would be pure memset waste.
    pub fn reset(&mut self, n: usize, h: usize, w: usize, c: usize, num_classes: usize) {
        self.n = n;
        self.h = h;
        self.w = w;
        self.c = c;
        self.num_classes = num_classes;
        self.data.resize(n * h * w * c, 0);
        self.labels.resize(n * num_classes, 0.0);
    }

    pub fn image_len(&self) -> usize {
        self.h * self.w * self.c
    }

    /// Borrow image `i`'s bytes.
    pub fn image(&self, i: usize) -> &[u8] {
        let len = self.image_len();
        &self.data[i * len..(i + 1) * len]
    }

    /// Mutably borrow image `i`'s bytes.
    pub fn image_mut(&mut self, i: usize) -> &mut [u8] {
        let len = self.image_len();
        &mut self.data[i * len..(i + 1) * len]
    }

    /// Copy an [`Image`] + one-hot label into slot `i`.
    pub fn put(&mut self, i: usize, img: &Image, class: usize) {
        assert_eq!((img.h, img.w, img.c), (self.h, self.w, self.c), "shape mismatch");
        assert!(class < self.num_classes);
        self.image_mut(i).copy_from_slice(&img.data);
        let row = &mut self.labels[i * self.num_classes..(i + 1) * self.num_classes];
        row.fill(0.0);
        row[class] = 1.0;
    }

    /// Soft-label row for image `i`.
    pub fn label(&self, i: usize) -> &[f32] {
        &self.labels[i * self.num_classes..(i + 1) * self.num_classes]
    }

    pub fn label_mut(&mut self, i: usize) -> &mut [f32] {
        &mut self.labels[i * self.num_classes..(i + 1) * self.num_classes]
    }

    /// Hard label = argmax of the soft row.
    pub fn hard_label(&self, i: usize) -> usize {
        let row = self.label(i);
        let mut best = 0;
        for (j, v) in row.iter().enumerate() {
            if *v > row[best] {
                best = j;
            }
        }
        best
    }

    /// Widen the batch to f32 in `[0,1)` (the baseline pipelines' payload).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&b| b as f32 / 255.0).collect()
    }

    /// [`ImageBatch::to_f32`] into a caller-provided (pooled) buffer; `out`
    /// is cleared first, so with warm capacity this never allocates.
    pub fn to_f32_into(&self, out: &mut Vec<f32>) {
        out.clear();
        out.extend(self.data.iter().map(|&b| b as f32 / 255.0));
    }

    /// Bytes of the raw uint8 payload.
    pub fn payload_bytes_u8(&self) -> u64 {
        self.data.len() as u64
    }

    /// Bytes if materialized as f32 (what standard loaders ship).
    pub fn payload_bytes_f32(&self) -> u64 {
        (self.data.len() * 4) as u64
    }

    /// Bytes if materialized as f64 (the paper's stated baseline).
    pub fn payload_bytes_f64(&self) -> u64 {
        (self.data.len() * 8) as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn image_indexing_roundtrip() {
        let mut img = Image::zeros(4, 5, 3);
        img.set(2, 3, 1, 200);
        assert_eq!(img.get(2, 3, 1), 200);
        assert_eq!(img.get(2, 3, 0), 0);
        assert_eq!(img.pixels(), 60);
    }

    #[test]
    fn image_reset_keeps_allocation_and_copy_from_matches() {
        let mut buf = Image::zeros(8, 8, 3);
        buf.data.fill(7);
        let cap = buf.data.capacity();
        buf.reset(4, 4, 3);
        assert_eq!((buf.h, buf.w, buf.c), (4, 4, 3));
        assert_eq!(buf.data.len(), 48);
        assert_eq!(buf.data.capacity(), cap, "reset must keep the allocation");
        let mut src = Image::zeros(2, 3, 1);
        src.data.copy_from_slice(&[1, 2, 3, 4, 5, 6]);
        buf.copy_from(&src);
        assert_eq!(buf, src);
        assert_eq!(buf.data.capacity(), cap, "copy_from must keep the allocation");
    }

    #[test]
    fn batch_put_and_read_back() {
        let mut b = ImageBatch::zeros(2, 2, 2, 1, 3);
        let mut img = Image::zeros(2, 2, 1);
        img.data.copy_from_slice(&[1, 2, 3, 4]);
        b.put(1, &img, 2);
        assert_eq!(b.image(1), &[1, 2, 3, 4]);
        assert_eq!(b.image(0), &[0, 0, 0, 0]);
        assert_eq!(b.label(1), &[0.0, 0.0, 1.0]);
        assert_eq!(b.hard_label(1), 2);
    }

    #[test]
    #[should_panic(expected = "shape mismatch")]
    fn batch_put_rejects_wrong_shape() {
        let mut b = ImageBatch::zeros(1, 2, 2, 1, 2);
        let img = Image::zeros(3, 3, 1);
        b.put(0, &img, 0);
    }

    #[test]
    fn payload_sizes() {
        let b = ImageBatch::zeros(16, 32, 32, 3, 10);
        assert_eq!(b.payload_bytes_u8(), 16 * 32 * 32 * 3);
        assert_eq!(b.payload_bytes_f32(), 4 * 16 * 32 * 32 * 3);
        assert_eq!(b.payload_bytes_f64(), 8 * 16 * 32 * 32 * 3);
    }

    #[test]
    fn reset_reshapes_and_keeps_allocation() {
        let mut b = ImageBatch::zeros(2, 4, 4, 1, 3);
        b.data.fill(9);
        b.labels.fill(0.5);
        let cap = b.data.capacity();
        b.reset(1, 4, 4, 1, 3);
        assert_eq!(b.n, 1);
        assert_eq!(b.data.len(), 16);
        assert_eq!(b.labels.len(), 3);
        assert_eq!(b.data.capacity(), cap, "reset must keep the allocation");
        // growing re-extends with zeroed tails
        b.reset(4, 4, 4, 1, 3);
        assert_eq!(b.data.len(), 64);
        assert!(b.data[32..].iter().all(|&v| v == 0), "grown region is zeroed");
    }

    #[test]
    fn to_f32_into_matches_to_f32() {
        let mut b = ImageBatch::zeros(1, 2, 2, 1, 2);
        b.data.copy_from_slice(&[0, 64, 128, 255]);
        let mut out = vec![9.0f32; 1]; // stale contents must be discarded
        b.to_f32_into(&mut out);
        assert_eq!(out, b.to_f32());
    }

    #[test]
    fn to_f32_normalizes() {
        let mut b = ImageBatch::zeros(1, 1, 1, 1, 2);
        b.data[0] = 255;
        let f = b.to_f32();
        assert!((f[0] - 1.0).abs() < 1e-6);
    }

    #[test]
    fn soft_labels_mix() {
        let mut b = ImageBatch::zeros(1, 1, 1, 1, 2);
        b.label_mut(0).copy_from_slice(&[0.3, 0.7]);
        assert_eq!(b.hard_label(0), 1);
    }
}
