//! Parallel encode–decode (E-D) loader — the paper's Figure 1 pipeline.
//!
//! A producer thread samples, augments and **encodes** batches for the next
//! steps while the trainer consumes the current one; a bounded channel
//! provides backpressure so the producer never runs more than
//! `prefetch_depth` batches ahead. The baseline (synchronous) mode performs
//! the same work inline on the consumer thread, which is exactly the
//! pipeline difference Figure 1 illustrates.
//!
//! The paper also "dumps" encoded batches for reuse across epochs; the
//! [`dump`] submodule provides that binary cache.

use crate::data::dataset::Dataset;
use crate::data::encode::{encode_batch_grouped, EncodeSpec, EncodedBatch};
use crate::data::image::ImageBatch;
use crate::data::sampler::SbsSampler;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::Arc;
use std::time::Instant;

/// What the loader hands the trainer per step.
#[derive(Clone, Debug)]
pub enum BatchPayload {
    /// Baseline pipelines: f32 pixels in `[0,1)` + soft labels.
    Raw { data: Vec<f32>, labels: Vec<f32>, n: usize },
    /// E-D pipelines: capacity-sized packed groups (see `encode`).
    Encoded(Vec<EncodedBatch>),
}

impl BatchPayload {
    /// Number of images carried.
    pub fn len(&self) -> usize {
        match self {
            BatchPayload::Raw { n, .. } => *n,
            BatchPayload::Encoded(gs) => gs.iter().map(|g| g.n).sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host-side payload bytes (the quantity the paper's 16× claim is about).
    pub fn payload_bytes(&self) -> u64 {
        match self {
            BatchPayload::Raw { data, .. } => (data.len() * 4) as u64,
            BatchPayload::Encoded(gs) => gs.iter().map(|g| g.payload_bytes()).sum(),
        }
    }
}

/// Loader operating mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoaderMode {
    /// Produce batches inline on `next()` (standard pipeline).
    Synchronous,
    /// Produce on a background thread with a bounded prefetch queue
    /// (the paper's parallel E-D pipeline).
    Parallel { prefetch_depth: usize },
}

/// Producer-side counters for the Fig-1 overlap analysis.
#[derive(Default, Debug)]
pub struct LoaderStats {
    /// ns the producer spent generating+encoding batches.
    pub produce_ns: AtomicU64,
    /// ns the producer spent blocked on the full queue (backpressure).
    pub blocked_ns: AtomicU64,
    pub batches: AtomicU64,
}

impl LoaderStats {
    pub fn produce_secs(&self) -> f64 {
        self.produce_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
    pub fn blocked_secs(&self) -> f64 {
        self.blocked_ns.load(Ordering::Relaxed) as f64 / 1e9
    }
}

fn make_payload(
    batch: &ImageBatch,
    spec: Option<EncodeSpec>,
) -> Result<BatchPayload, crate::data::encode::EncodeError> {
    Ok(match spec {
        None => BatchPayload::Raw {
            data: batch.to_f32(),
            labels: batch.labels.clone(),
            n: batch.n,
        },
        Some(s) => BatchPayload::Encoded(encode_batch_grouped(batch, s)?),
    })
}

/// Epoch-scoped batch source with both modes behind one interface.
pub enum EdLoader {
    Sync {
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        remaining: usize,
        stats: Arc<LoaderStats>,
    },
    Par {
        rx: Receiver<BatchPayload>,
        handle: Option<std::thread::JoinHandle<()>>,
        stats: Arc<LoaderStats>,
    },
}

impl EdLoader {
    /// Build a loader producing `num_batches` batches.
    ///
    /// `spec = None` ships raw f32 batches (B / M-P / S-C pipelines);
    /// `spec = Some(_)` ships packed batches (E-D pipelines).
    pub fn new(
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        mode: LoaderMode,
    ) -> EdLoader {
        let stats = Arc::new(LoaderStats::default());
        match mode {
            LoaderMode::Synchronous => EdLoader::Sync {
                dataset,
                sampler,
                spec,
                remaining: num_batches,
                stats,
            },
            LoaderMode::Parallel { prefetch_depth } => {
                let (tx, rx) = sync_channel(prefetch_depth.max(1));
                let pstats = stats.clone();
                let mut sampler = sampler;
                let handle = std::thread::Builder::new()
                    .name("optorch-ed-producer".into())
                    .spawn(move || {
                        for _ in 0..num_batches {
                            let t0 = Instant::now();
                            let batch = sampler.next_batch(dataset.as_ref());
                            let payload = match make_payload(&batch, spec) {
                                Ok(p) => p,
                                Err(e) => {
                                    // capacity violations are programming errors
                                    // upstream; surface loudly.
                                    panic!("E-D producer encode failed: {e}");
                                }
                            };
                            pstats
                                .produce_ns
                                .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            let t1 = Instant::now();
                            if tx.send(payload).is_err() {
                                return; // consumer dropped; stop quietly
                            }
                            pstats
                                .blocked_ns
                                .fetch_add(t1.elapsed().as_nanos() as u64, Ordering::Relaxed);
                            pstats.batches.fetch_add(1, Ordering::Relaxed);
                        }
                    })
                    .expect("spawn E-D producer");
                EdLoader::Par { rx, handle: Some(handle), stats }
            }
        }
    }

    /// Next batch, or `None` at end of the configured run.
    pub fn next(&mut self) -> Option<BatchPayload> {
        match self {
            EdLoader::Sync { dataset, sampler, spec, remaining, stats } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                let t0 = Instant::now();
                let batch = sampler.next_batch(dataset.as_ref());
                let payload = make_payload(&batch, *spec).expect("encode failed");
                stats
                    .produce_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.batches.fetch_add(1, Ordering::Relaxed);
                Some(payload)
            }
            EdLoader::Par { rx, .. } => rx.recv().ok(),
        }
    }

    pub fn stats(&self) -> Arc<LoaderStats> {
        match self {
            EdLoader::Sync { stats, .. } => stats.clone(),
            EdLoader::Par { stats, .. } => stats.clone(),
        }
    }
}

impl Drop for EdLoader {
    fn drop(&mut self) {
        if let EdLoader::Par { rx, handle, .. } = self {
            // Drain so the producer unblocks, then join.
            while rx.try_recv().is_ok() {}
            // Dropping the receiver ends the producer's send loop.
            if let Some(h) = handle.take() {
                // Receiver is still alive here; drain until the channel closes.
                loop {
                    match rx.recv_timeout(std::time::Duration::from_millis(50)) {
                        Ok(_) => continue,
                        Err(_) => break,
                    }
                }
                let _ = h.join();
            }
        }
    }
}

/// Binary cache for encoded batches — the paper's "dump" step in Figure 1.
pub mod dump {
    use super::*;
    use crate::data::encode::{Encoding, WordType};
    use std::io::{Read, Write};
    use std::path::Path;

    const MAGIC: &[u8; 8] = b"OPTORCH1";

    fn push_u32(buf: &mut Vec<u8>, v: u32) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Serialize one encoded batch.
    pub fn to_bytes(e: &EncodedBatch) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.push(match e.spec_encoding {
            Encoding::Base256 => 0,
            Encoding::Lossless128 => 1,
        });
        buf.push(match e.spec_word {
            WordType::U64 => 0,
            WordType::F64 => 1,
        });
        for v in [e.n, e.h, e.w, e.c, e.num_classes] {
            push_u32(&mut buf, v as u32);
        }
        push_u32(&mut buf, e.words_u64.len() as u32);
        for w in &e.words_u64 {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        push_u32(&mut buf, e.words_f64.len() as u32);
        for w in &e.words_f64 {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        push_u32(&mut buf, e.offsets.len() as u32);
        buf.extend_from_slice(&e.offsets);
        push_u32(&mut buf, e.labels.len() as u32);
        for l in &e.labels {
            buf.extend_from_slice(&l.to_le_bytes());
        }
        buf
    }

    fn take<'a>(b: &mut &'a [u8], n: usize) -> std::io::Result<&'a [u8]> {
        if b.len() < n {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "truncated dump",
            ));
        }
        let (head, tail) = b.split_at(n);
        *b = tail;
        Ok(head)
    }

    fn take_u32(b: &mut &[u8]) -> std::io::Result<u32> {
        Ok(u32::from_le_bytes(take(b, 4)?.try_into().unwrap()))
    }

    /// Deserialize one encoded batch.
    pub fn from_bytes(mut b: &[u8]) -> std::io::Result<EncodedBatch> {
        let magic = take(&mut b, 8)?;
        if magic != MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad magic",
            ));
        }
        let enc = match take(&mut b, 1)?[0] {
            0 => Encoding::Base256,
            1 => Encoding::Lossless128,
            x => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad encoding tag {x}"),
                ))
            }
        };
        let word = match take(&mut b, 1)?[0] {
            0 => WordType::U64,
            1 => WordType::F64,
            x => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad word tag {x}"),
                ))
            }
        };
        let n = take_u32(&mut b)? as usize;
        let h = take_u32(&mut b)? as usize;
        let w = take_u32(&mut b)? as usize;
        let c = take_u32(&mut b)? as usize;
        let num_classes = take_u32(&mut b)? as usize;
        let nu = take_u32(&mut b)? as usize;
        let mut words_u64 = Vec::with_capacity(nu);
        for _ in 0..nu {
            words_u64.push(u64::from_le_bytes(take(&mut b, 8)?.try_into().unwrap()));
        }
        let nf = take_u32(&mut b)? as usize;
        let mut words_f64 = Vec::with_capacity(nf);
        for _ in 0..nf {
            words_f64.push(f64::from_le_bytes(take(&mut b, 8)?.try_into().unwrap()));
        }
        let no = take_u32(&mut b)? as usize;
        let offsets = take(&mut b, no)?.to_vec();
        let nl = take_u32(&mut b)? as usize;
        let mut labels = Vec::with_capacity(nl);
        for _ in 0..nl {
            labels.push(f32::from_le_bytes(take(&mut b, 4)?.try_into().unwrap()));
        }
        Ok(EncodedBatch {
            spec_encoding: enc,
            spec_word: word,
            n,
            h,
            w,
            c,
            words_u64,
            words_f64,
            offsets,
            labels,
            num_classes,
        })
    }

    /// Write a batch to `path`.
    pub fn write(path: &Path, e: &EncodedBatch) -> std::io::Result<()> {
        std::fs::File::create(path)?.write_all(&to_bytes(e))
    }

    /// Read a batch from `path`.
    pub fn read(path: &Path) -> std::io::Result<EncodedBatch> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::augment::AugPolicy;
    use crate::data::encode::{decode_batch, Encoding, WordType};
    use crate::data::synth::{Split, SynthCifar};

    fn setup(
        batches: usize,
        spec: Option<EncodeSpec>,
        mode: LoaderMode,
    ) -> EdLoader {
        let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 200, 7));
        let sampler = SbsSampler::uniform(d.as_ref(), 16, AugPolicy::none(), 1).unwrap();
        EdLoader::new(d, sampler, spec, batches, mode)
    }

    #[test]
    fn sync_loader_yields_exact_count() {
        let mut l = setup(5, None, LoaderMode::Synchronous);
        let mut n = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.len(), 16);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn parallel_loader_yields_exact_count() {
        let mut l = setup(7, None, LoaderMode::Parallel { prefetch_depth: 2 });
        let mut n = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.len(), 16);
            n += 1;
        }
        assert_eq!(n, 7);
    }

    #[test]
    fn parallel_and_sync_agree_given_same_seed() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut a = setup(3, spec, LoaderMode::Synchronous);
        let mut b = setup(3, spec, LoaderMode::Parallel { prefetch_depth: 4 });
        loop {
            match (a.next(), b.next()) {
                (None, None) => break,
                (Some(BatchPayload::Encoded(x)), Some(BatchPayload::Encoded(y))) => {
                    assert_eq!(x.len(), y.len());
                    for (gx, gy) in x.iter().zip(&y) {
                        assert_eq!(gx.words_u64, gy.words_u64);
                        assert_eq!(gx.labels, gy.labels);
                    }
                }
                other => panic!("mismatch: {other:?}"),
            }
        }
    }

    #[test]
    fn encoded_payload_decodes_to_valid_images() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        match l.next().unwrap() {
            BatchPayload::Encoded(groups) => {
                assert_eq!(groups.iter().map(|g| g.n).sum::<usize>(), 16);
                for g in &groups {
                    let img = decode_batch(g);
                    assert_eq!(img.h, 32);
                    // labels are soft distributions
                    for i in 0..img.n {
                        let s: f32 = img.label(i).iter().sum();
                        assert!((s - 1.0).abs() < 1e-5);
                    }
                }
            }
            other => panic!("expected encoded, got {other:?}"),
        }
    }

    #[test]
    fn payload_bytes_encoded_smaller_than_raw() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut raw = setup(1, None, LoaderMode::Synchronous);
        let mut enc = setup(1, spec, LoaderMode::Synchronous);
        let rb = raw.next().unwrap().payload_bytes();
        let eb = enc.next().unwrap().payload_bytes();
        assert!(eb * 3 < rb, "encoded {eb} raw {rb}"); // 4× expected
    }

    #[test]
    fn stats_accumulate() {
        let mut l = setup(4, None, LoaderMode::Parallel { prefetch_depth: 1 });
        while l.next().is_some() {}
        let stats = l.stats();
        assert_eq!(stats.batches.load(Ordering::Relaxed), 4);
        assert!(stats.produce_ns.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn dropping_parallel_loader_midway_is_clean() {
        let mut l = setup(100, None, LoaderMode::Parallel { prefetch_depth: 2 });
        let _ = l.next();
        drop(l); // must not hang or panic
    }

    #[test]
    fn dump_roundtrip() {
        let spec = Some(EncodeSpec::new(Encoding::Lossless128, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        if let Some(BatchPayload::Encoded(groups)) = l.next() {
            for g in &groups {
                let bytes = dump::to_bytes(g);
                let back = dump::from_bytes(&bytes).unwrap();
                assert_eq!(back.words_u64, g.words_u64);
                assert_eq!(back.offsets, g.offsets);
                assert_eq!(back.labels, g.labels);
                assert_eq!(decode_batch(&back), decode_batch(g));
            }
        } else {
            panic!("expected encoded payload");
        }
    }

    #[test]
    fn dump_rejects_corruption() {
        assert!(dump::from_bytes(b"short").is_err());
        assert!(dump::from_bytes(b"NOTMAGIC________________").is_err());
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        if let Some(BatchPayload::Encoded(groups)) = l.next() {
            let mut bytes = dump::to_bytes(&groups[0]);
            bytes.truncate(bytes.len() / 2);
            assert!(dump::from_bytes(&bytes).is_err());
        }
    }
}
