//! Parallel encode–decode (E-D) loader — the paper's Figure 1 pipeline,
//! rebuilt as a multi-worker producer pool.
//!
//! # Architecture
//!
//! ```text
//!             plans (ordered)        payloads (any order)      (re-ordered)
//! ┌─────────┐  step,BatchPlan  ┌──────────┐  step,payload  ┌───────────┐
//! │ planner ├───────┬─────────▶│ worker 0 ├───────┬───────▶│ sequencer ├──▶ trainer
//! │ thread  │       ├─────────▶│ worker 1 ├───────┤        │ (reorder  │
//! │ (SBS    │       └─────────▶│   ...    ├───────┘        │  buffer)  │
//! │ sampler)│                  │ worker N │                └───────────┘
//! └─────────┘                  └──────────┘      bounded channel = prefetch_depth
//! ```
//!
//! * The **planner** runs the sequential, cheap half of sampling
//!   ([`SbsSampler::plan_batch`]): it owns the RNG/pool state and emits one
//!   [`BatchPlan`] per step, in step order, into a bounded queue.
//! * **Workers** (`num_workers` threads) pull plans, materialize them
//!   (fetch + augment, [`materialize_plan_arena`]) into a thread-local
//!   staging batch — label rows and fetch images staged in a per-worker
//!   [`StageScratch`] (slab + recycled [`Dataset::get_into`] buffers) —
//!   and encode/widen into payload buffers drawn from the shared
//!   [`BufferPool`]. Materialization is a pure function of the plan, so
//!   any thread may produce any step, and the whole fetch→augment→encode
//!   loop allocates nothing at steady state.
//! * The **sequencer** restores step order with a reorder buffer and feeds
//!   the bounded output channel (depth `prefetch_depth`). A permit gate
//!   ([`Gate`]) provides the Figure-1 backpressure with a hard bound: at
//!   most `prefetch_depth + num_workers` materialized payloads exist at any
//!   moment (each worker may hold one while a full prefetch window is
//!   parked), released as the consumer takes batches.
//!
//! `num_workers = 0` keeps the classic single-producer thread (plan +
//! materialize + encode inline on one background thread), and
//! [`LoaderMode::Synchronous`] performs the same work inline on the
//! consumer thread — exactly the pipeline difference Figure 1 illustrates.
//! All modes and worker counts produce **byte-identical batch sequences**
//! for the same seed, because all stochastic state lives in the
//! sequentially-generated plans.
//!
//! # Buffers
//!
//! Payload buffers (f32 pixels, packed words, parity bitplanes, label rows,
//! group shells) cycle through the shared [`BufferPool`]: the trainer
//! returns spent payloads via [`EdLoader::recycle`], workers take them for
//! the next batch. After a two-batch warmup (the second batch settles LIFO
//! size mismatches from a short tail group), steady-state epochs perform no
//! pool-managed allocation — observable via [`BufferPool::allocs`] /
//! [`BufferPool::reuses`], which the trainer surfaces in its report.
//!
//! # Stats
//!
//! [`LoaderStats`] keeps the Figure-1 overlap accounting (aggregate
//! produce/blocked time) plus per-worker counters and sequencer
//! reorder-depth gauges; see [`LoaderStats::worker_summaries`].
//!
//! The paper also "dumps" encoded batches for reuse across epochs; the
//! [`dump`] submodule provides that binary cache.

use crate::data::dataset::Dataset;
use crate::data::encode::{encode_batch_grouped_into, EncodeError, EncodeSpec, EncodedBatch};
use crate::data::image::ImageBatch;
use crate::data::pool::BufferPool;
use crate::data::sampler::{materialize_plan_arena, BatchPlan, ClassSpec, SbsSampler, StageScratch};
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver};
use std::sync::{Arc, Condvar, Mutex};
use std::time::Instant;

/// What the loader hands the trainer per step.
#[derive(Clone, Debug)]
pub enum BatchPayload {
    /// Baseline pipelines: f32 pixels in `[0,1)` + soft labels.
    Raw { data: Vec<f32>, labels: Vec<f32>, n: usize },
    /// E-D pipelines: capacity-sized packed groups (see `encode`).
    Encoded(Vec<EncodedBatch>),
}

impl BatchPayload {
    /// Number of images carried.
    pub fn len(&self) -> usize {
        match self {
            BatchPayload::Raw { n, .. } => *n,
            BatchPayload::Encoded(gs) => gs.iter().map(|g| g.n).sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host-side payload bytes (the quantity the paper's 16× claim is about).
    pub fn payload_bytes(&self) -> u64 {
        match self {
            BatchPayload::Raw { data, .. } => (data.len() * 4) as u64,
            BatchPayload::Encoded(gs) => gs.iter().map(|g| g.payload_bytes()).sum(),
        }
    }
}

/// Loader operating mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoaderMode {
    /// Produce batches inline on `next()` (standard pipeline).
    Synchronous,
    /// Produce on background threads with a bounded prefetch queue (the
    /// paper's parallel E-D pipeline). `num_workers = 0` keeps the classic
    /// single producer thread; `n ≥ 1` runs the planner/worker/sequencer
    /// pool with `n` encode workers.
    Parallel { prefetch_depth: usize, num_workers: usize },
}

/// Default worker count for the producer pool: one core is left for the
/// consuming trainer thread.
pub fn default_num_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1))
        .unwrap_or(1)
        .max(1)
}

/// One worker's counters (all thread-shared atomics).
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// ns this worker spent materializing + encoding batches.
    pub produce_ns: AtomicU64,
    /// ns this worker spent blocked handing batches downstream.
    pub blocked_ns: AtomicU64,
    pub batches: AtomicU64,
    /// Heap fallbacks of the worker's staging-scratch arena (see
    /// [`materialize_plan_arena`]); 0 ⇒ the scratch path ran entirely in
    /// the per-worker slab.
    pub scratch_fallbacks: AtomicU64,
}

/// Plain-data snapshot of one worker's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkerSummary {
    pub produce_secs: f64,
    pub blocked_secs: f64,
    pub batches: u64,
    /// Staging-scratch requests the worker's arena could not serve.
    pub scratch_fallbacks: u64,
}

/// Producer-side counters for the Fig-1 overlap analysis.
#[derive(Debug, Default)]
pub struct LoaderStats {
    /// ns producers spent generating+encoding batches (sum over workers).
    pub produce_ns: AtomicU64,
    /// ns producers spent blocked on full queues (backpressure).
    pub blocked_ns: AtomicU64,
    pub batches: AtomicU64,
    /// Per-worker counters (empty for the synchronous mode; one entry for
    /// the legacy single-producer mode).
    pub workers: Vec<WorkerStats>,
    /// High-water mark of the sequencer's reorder buffer.
    pub seq_max_depth: AtomicU64,
    /// Batches that arrived at the sequencer ahead of their turn.
    pub seq_out_of_order: AtomicU64,
}

impl LoaderStats {
    fn with_workers(n: usize) -> LoaderStats {
        LoaderStats {
            workers: (0..n).map(|_| WorkerStats::default()).collect(),
            ..LoaderStats::default()
        }
    }

    pub fn produce_secs(&self) -> f64 {
        self.produce_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn blocked_secs(&self) -> f64 {
        self.blocked_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Per-worker snapshots (empty when the loader ran synchronously).
    pub fn worker_summaries(&self) -> Vec<WorkerSummary> {
        self.workers
            .iter()
            .map(|w| WorkerSummary {
                produce_secs: w.produce_ns.load(Ordering::Relaxed) as f64 / 1e9,
                blocked_secs: w.blocked_ns.load(Ordering::Relaxed) as f64 / 1e9,
                batches: w.batches.load(Ordering::Relaxed),
                scratch_fallbacks: w.scratch_fallbacks.load(Ordering::Relaxed),
            })
            .collect()
    }
}

/// Build one payload from a staged batch, drawing every buffer from `pool`.
fn make_payload(
    batch: &ImageBatch,
    spec: Option<EncodeSpec>,
    pool: &BufferPool,
) -> Result<BatchPayload, EncodeError> {
    Ok(match spec {
        None => {
            let mut data = pool.take_f32(batch.data.len());
            batch.to_f32_into(&mut data);
            let mut labels = pool.take_f32(batch.labels.len());
            labels.extend_from_slice(&batch.labels);
            BatchPayload::Raw { data, labels, n: batch.n }
        }
        Some(s) => {
            let mut groups = pool.take_shells();
            encode_batch_grouped_into(batch, s, pool, &mut groups)?;
            BatchPayload::Encoded(groups)
        }
    })
}

/// Counting semaphore bounding materialized payloads in flight. A worker
/// acquires a permit **before** dequeuing a plan (so the holder of the
/// lowest outstanding step always owns a permit and the sequencer can
/// always make progress — acquiring after the dequeue could strand the
/// next-in-order step behind parked future ones); the consumer releases it
/// when a payload leaves the output channel. Hard bound:
/// `prefetch_depth + num_workers` payloads exist at any moment.
struct Gate {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    fn new(permits: usize) -> Gate {
        Gate { permits: Mutex::new(permits), cv: Condvar::new() }
    }

    /// Take a permit; returns `false` if `cancel` was raised while waiting.
    fn acquire(&self, cancel: &AtomicBool) -> bool {
        let mut p = self.permits.lock().unwrap();
        loop {
            if cancel.load(Ordering::Relaxed) {
                return false;
            }
            if *p > 0 {
                *p -= 1;
                return true;
            }
            p = self.cv.wait(p).unwrap();
        }
    }

    fn release(&self) {
        *self.permits.lock().unwrap() += 1;
        self.cv.notify_one();
    }

    /// Wake every waiter (used with the cancel flag on shutdown; taking the
    /// mutex first makes the wakeup race-free against a check-then-wait).
    fn wake_all(&self) {
        let _guard = self.permits.lock().unwrap();
        self.cv.notify_all();
    }
}

/// Shared context for every producer thread.
struct ProducerCtx {
    dataset: Arc<dyn Dataset>,
    specs: Arc<Vec<ClassSpec>>,
    spec: Option<EncodeSpec>,
    pool: Arc<BufferPool>,
    stats: Arc<LoaderStats>,
    cancel: Arc<AtomicBool>,
}

impl ProducerCtx {
    /// Per-worker staging scratch: the label-row slab plus the recycled
    /// fetch-image buffers [`materialize_plan_arena`] stages through.
    fn worker_scratch(&self) -> StageScratch {
        StageScratch::new(self.dataset.num_classes())
    }

    /// Materialize + encode one plan, accounting to worker `wid`.
    fn produce(
        &self,
        wid: usize,
        plan: &BatchPlan,
        stage: &mut ImageBatch,
        scratch: &mut StageScratch,
    ) -> BatchPayload {
        let t0 = Instant::now();
        let (h, w, c) = self.dataset.shape();
        stage.reset(plan.len(), h, w, c, self.dataset.num_classes());
        materialize_plan_arena(&self.specs, self.dataset.as_ref(), plan, stage, scratch);
        self.stats.workers[wid]
            .scratch_fallbacks
            .store(scratch.fallback_allocs(), Ordering::Relaxed);
        let payload = match make_payload(stage, self.spec, &self.pool) {
            Ok(p) => p,
            // capacity violations are programming errors upstream; surface loudly.
            Err(e) => panic!("E-D producer encode failed: {e}"),
        };
        let dt = t0.elapsed().as_nanos() as u64;
        self.stats.workers[wid].produce_ns.fetch_add(dt, Ordering::Relaxed);
        self.stats.produce_ns.fetch_add(dt, Ordering::Relaxed);
        payload
    }

    /// Account a completed (sent) batch to worker `wid`.
    fn sent(&self, wid: usize, blocked: Instant) {
        let dt = blocked.elapsed().as_nanos() as u64;
        self.stats.workers[wid].blocked_ns.fetch_add(dt, Ordering::Relaxed);
        self.stats.blocked_ns.fetch_add(dt, Ordering::Relaxed);
        self.stats.workers[wid].batches.fetch_add(1, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
    }
}

/// Epoch-scoped batch source with all modes behind one interface.
pub enum EdLoader {
    Sync {
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        remaining: usize,
        stats: Arc<LoaderStats>,
        pool: Arc<BufferPool>,
        /// Reused staging batch (allocated once per loader).
        stage: ImageBatch,
        /// Staging scratch (label-row slab + fetch images, recycled).
        scratch: StageScratch,
    },
    Par {
        rx: Receiver<BatchPayload>,
        handles: Vec<std::thread::JoinHandle<()>>,
        stats: Arc<LoaderStats>,
        pool: Arc<BufferPool>,
        cancel: Arc<AtomicBool>,
        /// In-flight payload bound for the worker pool (`None` for the
        /// single-producer mode, where the output channel already bounds it).
        gate: Option<Arc<Gate>>,
    },
}

impl EdLoader {
    /// Build a loader producing `num_batches` batches with a private
    /// buffer pool. Prefer [`EdLoader::with_pool`] when a pool outlives the
    /// epoch (the trainer shares one across all epochs).
    ///
    /// `spec = None` ships raw f32 batches (B / M-P / S-C pipelines);
    /// `spec = Some(_)` ships packed batches (E-D pipelines).
    pub fn new(
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        mode: LoaderMode,
    ) -> EdLoader {
        Self::with_pool(dataset, sampler, spec, num_batches, mode, Arc::new(BufferPool::default()))
    }

    /// [`EdLoader::new`] with a caller-owned [`BufferPool`] so payload
    /// buffers recycle across epochs.
    pub fn with_pool(
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        mode: LoaderMode,
        pool: Arc<BufferPool>,
    ) -> EdLoader {
        match mode {
            LoaderMode::Synchronous => {
                let (h, w, c) = dataset.shape();
                let stage = ImageBatch::zeros(sampler.batch_size, h, w, c, dataset.num_classes());
                let scratch = StageScratch::new(dataset.num_classes());
                EdLoader::Sync {
                    dataset,
                    sampler,
                    spec,
                    remaining: num_batches,
                    stats: Arc::new(LoaderStats::with_workers(0)),
                    pool,
                    stage,
                    scratch,
                }
            }
            LoaderMode::Parallel { prefetch_depth, num_workers: 0 } => {
                Self::spawn_single_producer(dataset, sampler, spec, num_batches, prefetch_depth, pool)
            }
            LoaderMode::Parallel { prefetch_depth, num_workers } => Self::spawn_worker_pool(
                dataset,
                sampler,
                spec,
                num_batches,
                prefetch_depth,
                num_workers,
                pool,
            ),
        }
    }

    /// The classic Figure-1 shape: one background thread does plan +
    /// materialize + encode sequentially (`num_workers = 0`).
    fn spawn_single_producer(
        dataset: Arc<dyn Dataset>,
        mut sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        prefetch_depth: usize,
        pool: Arc<BufferPool>,
    ) -> EdLoader {
        let stats = Arc::new(LoaderStats::with_workers(1));
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel(prefetch_depth.max(1));
        let ctx = ProducerCtx {
            dataset: dataset.clone(),
            specs: Arc::new(sampler.specs().to_vec()),
            spec,
            pool: pool.clone(),
            stats: stats.clone(),
            cancel: cancel.clone(),
        };
        let handle = std::thread::Builder::new()
            .name("optorch-ed-producer".into())
            .spawn(move || {
                let mut stage = ImageBatch::zeros(0, 0, 0, 0, 1);
                let mut scratch = ctx.worker_scratch();
                for _ in 0..num_batches {
                    if ctx.cancel.load(Ordering::Relaxed) {
                        return;
                    }
                    let plan = sampler.plan_batch(ctx.dataset.as_ref());
                    let payload = ctx.produce(0, &plan, &mut stage, &mut scratch);
                    let t1 = Instant::now();
                    if tx.send(payload).is_err() {
                        return; // consumer dropped; stop quietly
                    }
                    ctx.sent(0, t1);
                }
            })
            .expect("spawn E-D producer");
        EdLoader::Par { rx, handles: vec![handle], stats, pool, cancel, gate: None }
    }

    /// The producer pool: planner → N workers → sequencer (see module docs).
    fn spawn_worker_pool(
        dataset: Arc<dyn Dataset>,
        mut sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        prefetch_depth: usize,
        num_workers: usize,
        pool: Arc<BufferPool>,
    ) -> EdLoader {
        let depth = prefetch_depth.max(1);
        let stats = Arc::new(LoaderStats::with_workers(num_workers));
        let cancel = Arc::new(AtomicBool::new(false));
        let specs = Arc::new(sampler.specs().to_vec());
        let gate = Arc::new(Gate::new(depth + num_workers));
        let mut handles = Vec::with_capacity(num_workers + 2);

        // Plans flow through a bounded queue so the planner (and its RNG
        // state) never runs more than depth + num_workers steps ahead.
        let (plan_tx, plan_rx) = sync_channel::<(usize, BatchPlan)>(depth + num_workers);
        let plan_rx = Arc::new(Mutex::new(plan_rx));
        // Workers hand finished payloads (tagged with their step) to the
        // sequencer. The gate (not this capacity) is what bounds payload
        // memory; the sequencer drains this queue eagerly into its reorder
        // buffer, so a small capacity cannot deadlock.
        let (seq_tx, seq_rx) = sync_channel::<(usize, BatchPayload)>(depth);
        // The sequencer feeds the consumer in step order; this channel's
        // depth is the Figure-1 prefetch bound.
        let (out_tx, out_rx) = sync_channel::<BatchPayload>(depth);

        {
            let dataset = dataset.clone();
            let cancel = cancel.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("optorch-ed-planner".into())
                    .spawn(move || {
                        for step in 0..num_batches {
                            if cancel.load(Ordering::Relaxed) {
                                return;
                            }
                            let plan = sampler.plan_batch(dataset.as_ref());
                            if plan_tx.send((step, plan)).is_err() {
                                return; // workers gone
                            }
                        }
                    })
                    .expect("spawn E-D planner"),
            );
        }

        for wid in 0..num_workers {
            let ctx = ProducerCtx {
                dataset: dataset.clone(),
                specs: specs.clone(),
                spec,
                pool: pool.clone(),
                stats: stats.clone(),
                cancel: cancel.clone(),
            };
            let plan_rx = plan_rx.clone();
            let seq_tx = seq_tx.clone();
            let gate = gate.clone();
            handles.push(
                std::thread::Builder::new()
                    .name(format!("optorch-ed-worker-{wid}"))
                    .spawn(move || {
                        let mut stage = ImageBatch::zeros(0, 0, 0, 0, 1);
                        let mut scratch = ctx.worker_scratch();
                        loop {
                            // A permit caps in-flight payloads; taking it
                            // before the dequeue keeps step order live (see
                            // Gate docs). False = canceled.
                            if !gate.acquire(&ctx.cancel) {
                                return;
                            }
                            // Lock scope: held only across the blocking
                            // recv (plans are cheap and arrive fast).
                            let msg = plan_rx.lock().unwrap().recv();
                            let Ok((step, plan)) = msg else {
                                gate.release(); // permit unused: no more plans
                                return;
                            };
                            let payload = ctx.produce(wid, &plan, &mut stage, &mut scratch);
                            let t1 = Instant::now();
                            if seq_tx.send((step, payload)).is_err() {
                                return; // sequencer gone
                            }
                            ctx.sent(wid, t1);
                        }
                    })
                    .expect("spawn E-D worker"),
            );
        }
        drop(seq_tx); // sequencer sees disconnect once all workers exit

        {
            let stats = stats.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("optorch-ed-sequencer".into())
                    .spawn(move || {
                        let mut next = 0usize;
                        let mut parked: BTreeMap<usize, BatchPayload> = BTreeMap::new();
                        while next < num_batches {
                            let Ok((step, payload)) = seq_rx.recv() else { return };
                            if step != next {
                                stats.seq_out_of_order.fetch_add(1, Ordering::Relaxed);
                            }
                            parked.insert(step, payload);
                            stats
                                .seq_max_depth
                                .fetch_max(parked.len() as u64, Ordering::Relaxed);
                            while let Some(ready) = parked.remove(&next) {
                                if out_tx.send(ready).is_err() {
                                    return; // consumer dropped
                                }
                                next += 1;
                            }
                        }
                    })
                    .expect("spawn E-D sequencer"),
            );
        }

        EdLoader::Par { rx: out_rx, handles, stats, pool, cancel, gate: Some(gate) }
    }

    /// Next batch, or `None` at end of the configured run.
    pub fn next(&mut self) -> Option<BatchPayload> {
        match self {
            EdLoader::Sync { dataset, sampler, spec, remaining, stats, pool, stage, scratch } => {
                if *remaining == 0 {
                    return None;
                }
                *remaining -= 1;
                let t0 = Instant::now();
                sampler.next_batch_arena(dataset.as_ref(), stage, scratch);
                let payload = make_payload(stage, *spec, pool).expect("encode failed");
                stats
                    .produce_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.batches.fetch_add(1, Ordering::Relaxed);
                Some(payload)
            }
            EdLoader::Par { rx, gate, .. } => {
                let payload = rx.recv().ok();
                if let (Some(_), Some(g)) = (payload.as_ref(), gate.as_ref()) {
                    g.release(); // one payload left the pipeline
                }
                payload
            }
        }
    }

    /// Return a spent payload's buffers to the loader's pool. Optional but
    /// strongly recommended on the training path: it is what makes
    /// steady-state epochs allocation-free.
    pub fn recycle(&self, payload: BatchPayload) {
        self.pool().recycle_payload(payload);
    }

    /// The loader's buffer pool (shared with its producer threads).
    pub fn pool(&self) -> &Arc<BufferPool> {
        match self {
            EdLoader::Sync { pool, .. } => pool,
            EdLoader::Par { pool, .. } => pool,
        }
    }

    pub fn stats(&self) -> Arc<LoaderStats> {
        match self {
            EdLoader::Sync { stats, .. } => stats.clone(),
            EdLoader::Par { stats, .. } => stats.clone(),
        }
    }
}

impl Drop for EdLoader {
    fn drop(&mut self) {
        if let EdLoader::Par { rx, handles, cancel, gate, .. } = self {
            // Ask producers to stop, then drain so nothing stays blocked on
            // a full queue. Producers exit on: cancel flag (workers parked
            // on the gate are woken to observe it), plan-channel disconnect,
            // or send failure; the drain ends when the last sender (the
            // sequencer / single producer) has exited.
            cancel.store(true, Ordering::Relaxed);
            if let Some(g) = gate {
                g.wake_all();
            }
            while rx.recv().is_ok() {}
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Binary cache for encoded batches — the paper's "dump" step in Figure 1.
pub mod dump {
    use super::*;
    use crate::data::encode::{Encoding, WordType};
    use std::io::{Read, Write};
    use std::path::Path;

    const MAGIC: &[u8; 8] = b"OPTORCH1";

    fn push_u32(buf: &mut Vec<u8>, v: u32) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Serialize one encoded batch.
    pub fn to_bytes(e: &EncodedBatch) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.push(match e.spec_encoding {
            Encoding::Base256 => 0,
            Encoding::Lossless128 => 1,
        });
        buf.push(match e.spec_word {
            WordType::U64 => 0,
            WordType::F64 => 1,
        });
        for v in [e.n, e.h, e.w, e.c, e.num_classes] {
            push_u32(&mut buf, v as u32);
        }
        push_u32(&mut buf, e.words_u64.len() as u32);
        for w in &e.words_u64 {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        push_u32(&mut buf, e.words_f64.len() as u32);
        for w in &e.words_f64 {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        push_u32(&mut buf, e.offsets.len() as u32);
        buf.extend_from_slice(&e.offsets);
        push_u32(&mut buf, e.labels.len() as u32);
        for l in &e.labels {
            buf.extend_from_slice(&l.to_le_bytes());
        }
        buf
    }

    fn take<'a>(b: &mut &'a [u8], n: usize) -> std::io::Result<&'a [u8]> {
        if b.len() < n {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "truncated dump",
            ));
        }
        let (head, tail) = b.split_at(n);
        *b = tail;
        Ok(head)
    }

    fn take_u32(b: &mut &[u8]) -> std::io::Result<u32> {
        Ok(u32::from_le_bytes(take(b, 4)?.try_into().unwrap()))
    }

    /// Deserialize one encoded batch.
    pub fn from_bytes(mut b: &[u8]) -> std::io::Result<EncodedBatch> {
        let magic = take(&mut b, 8)?;
        if magic != MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad magic",
            ));
        }
        let enc = match take(&mut b, 1)?[0] {
            0 => Encoding::Base256,
            1 => Encoding::Lossless128,
            x => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad encoding tag {x}"),
                ))
            }
        };
        let word = match take(&mut b, 1)?[0] {
            0 => WordType::U64,
            1 => WordType::F64,
            x => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad word tag {x}"),
                ))
            }
        };
        let n = take_u32(&mut b)? as usize;
        let h = take_u32(&mut b)? as usize;
        let w = take_u32(&mut b)? as usize;
        let c = take_u32(&mut b)? as usize;
        let num_classes = take_u32(&mut b)? as usize;
        let nu = take_u32(&mut b)? as usize;
        let mut words_u64 = Vec::with_capacity(nu);
        for _ in 0..nu {
            words_u64.push(u64::from_le_bytes(take(&mut b, 8)?.try_into().unwrap()));
        }
        let nf = take_u32(&mut b)? as usize;
        let mut words_f64 = Vec::with_capacity(nf);
        for _ in 0..nf {
            words_f64.push(f64::from_le_bytes(take(&mut b, 8)?.try_into().unwrap()));
        }
        let no = take_u32(&mut b)? as usize;
        let offsets = take(&mut b, no)?.to_vec();
        let nl = take_u32(&mut b)? as usize;
        let mut labels = Vec::with_capacity(nl);
        for _ in 0..nl {
            labels.push(f32::from_le_bytes(take(&mut b, 4)?.try_into().unwrap()));
        }
        Ok(EncodedBatch {
            spec_encoding: enc,
            spec_word: word,
            n,
            h,
            w,
            c,
            words_u64,
            words_f64,
            offsets,
            labels,
            num_classes,
        })
    }

    /// Write a batch to `path`.
    pub fn write(path: &Path, e: &EncodedBatch) -> std::io::Result<()> {
        std::fs::File::create(path)?.write_all(&to_bytes(e))
    }

    /// Read a batch from `path`.
    pub fn read(path: &Path) -> std::io::Result<EncodedBatch> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::augment::AugPolicy;
    use crate::data::encode::{decode_batch, Encoding, WordType};
    use crate::data::synth::{Split, SynthCifar};

    fn setup(
        batches: usize,
        spec: Option<EncodeSpec>,
        mode: LoaderMode,
    ) -> EdLoader {
        let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 200, 7));
        let sampler = SbsSampler::uniform(d.as_ref(), 16, AugPolicy::none(), 1).unwrap();
        EdLoader::new(d, sampler, spec, batches, mode)
    }

    fn par(depth: usize, workers: usize) -> LoaderMode {
        LoaderMode::Parallel { prefetch_depth: depth, num_workers: workers }
    }

    #[test]
    fn sync_loader_yields_exact_count() {
        let mut l = setup(5, None, LoaderMode::Synchronous);
        let mut n = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.len(), 16);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn parallel_loader_yields_exact_count_for_any_worker_count() {
        for workers in [0, 1, 2, 4] {
            let mut l = setup(7, None, par(2, workers));
            let mut n = 0;
            while let Some(b) = l.next() {
                assert_eq!(b.len(), 16, "workers={workers}");
                n += 1;
            }
            assert_eq!(n, 7, "workers={workers}");
        }
    }

    #[test]
    fn parallel_and_sync_agree_given_same_seed() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        for workers in [0, 1, 3] {
            let mut a = setup(3, spec, LoaderMode::Synchronous);
            let mut b = setup(3, spec, par(4, workers));
            loop {
                match (a.next(), b.next()) {
                    (None, None) => break,
                    (Some(BatchPayload::Encoded(x)), Some(BatchPayload::Encoded(y))) => {
                        assert_eq!(x.len(), y.len(), "workers={workers}");
                        for (gx, gy) in x.iter().zip(&y) {
                            assert_eq!(gx.words_u64, gy.words_u64, "workers={workers}");
                            assert_eq!(gx.labels, gy.labels, "workers={workers}");
                        }
                    }
                    other => panic!("mismatch (workers={workers}): {other:?}"),
                }
            }
        }
    }

    #[test]
    fn worker_pool_preserves_step_order() {
        // With more workers than prefetch depth, out-of-order completion is
        // likely; the sequencer must still emit the sync sequence.
        let spec = Some(EncodeSpec::new(Encoding::Lossless128, WordType::U64));
        let mut reference = setup(12, spec, par(1, 0));
        let mut pooled = setup(12, spec, par(1, 4));
        let mut step = 0;
        loop {
            match (reference.next(), pooled.next()) {
                (None, None) => break,
                (Some(BatchPayload::Encoded(x)), Some(BatchPayload::Encoded(y))) => {
                    for (gx, gy) in x.iter().zip(&y) {
                        assert_eq!(gx.words_u64, gy.words_u64, "step {step}");
                        assert_eq!(gx.offsets, gy.offsets, "step {step}");
                    }
                }
                other => panic!("step {step}: {other:?}"),
            }
            step += 1;
        }
    }

    #[test]
    fn encoded_payload_decodes_to_valid_images() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        match l.next().unwrap() {
            BatchPayload::Encoded(groups) => {
                assert_eq!(groups.iter().map(|g| g.n).sum::<usize>(), 16);
                for g in &groups {
                    let img = decode_batch(g);
                    assert_eq!(img.h, 32);
                    // labels are soft distributions
                    for i in 0..img.n {
                        let s: f32 = img.label(i).iter().sum();
                        assert!((s - 1.0).abs() < 1e-5);
                    }
                }
            }
            other => panic!("expected encoded, got {other:?}"),
        }
    }

    #[test]
    fn payload_bytes_encoded_smaller_than_raw() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut raw = setup(1, None, LoaderMode::Synchronous);
        let mut enc = setup(1, spec, LoaderMode::Synchronous);
        let rb = raw.next().unwrap().payload_bytes();
        let eb = enc.next().unwrap().payload_bytes();
        assert!(eb * 3 < rb, "encoded {eb} raw {rb}"); // 4× expected
    }

    #[test]
    fn stats_accumulate_per_worker() {
        let mut l = setup(8, None, par(1, 2));
        let stats = l.stats();
        while l.next().is_some() {}
        drop(l); // join producers so the post-send counter updates land
        assert_eq!(stats.batches.load(Ordering::Relaxed), 8);
        assert!(stats.produce_ns.load(Ordering::Relaxed) > 0);
        let per_worker = stats.worker_summaries();
        assert_eq!(per_worker.len(), 2);
        assert_eq!(per_worker.iter().map(|w| w.batches).sum::<u64>(), 8);
        assert!(stats.seq_max_depth.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn worker_scratch_stays_inside_the_per_worker_slab() {
        // Every producer stages its label rows in a per-worker arena; the
        // slab is sized exactly for them, so no worker may ever fall back
        // to the heap for scratch.
        let mut l = setup(8, None, par(2, 3));
        let stats = l.stats();
        while l.next().is_some() {}
        drop(l);
        let per_worker = stats.worker_summaries();
        assert_eq!(per_worker.len(), 3);
        for (i, w) in per_worker.iter().enumerate() {
            assert_eq!(w.scratch_fallbacks, 0, "worker {i} fell back to the heap");
        }
    }

    #[test]
    fn legacy_single_producer_reports_one_worker() {
        let mut l = setup(4, None, par(1, 0));
        let stats = l.stats();
        while l.next().is_some() {}
        drop(l);
        assert_eq!(stats.batches.load(Ordering::Relaxed), 4);
        let per_worker = stats.worker_summaries();
        assert_eq!(per_worker.len(), 1);
        assert_eq!(per_worker[0].batches, 4);
    }

    #[test]
    fn recycling_makes_steady_state_allocation_free() {
        // Sync mode is deterministic: the first batch warms the pool and the
        // second settles LIFO size mismatches (a short group's label buffer
        // can be popped for a full group and regrown once); from then on
        // every batch must be served entirely from recycled buffers.
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::F64));
        let mut l = setup(6, spec, LoaderMode::Synchronous);
        for _ in 0..2 {
            let p = l.next().unwrap();
            l.recycle(p);
        }
        let warm_allocs = l.pool().allocs();
        while let Some(p) = l.next() {
            l.recycle(p);
        }
        assert_eq!(l.pool().allocs(), warm_allocs, "steady state allocated");
        assert!(l.pool().reuses() > 0);
    }

    #[test]
    fn dropping_parallel_loader_midway_is_clean() {
        for workers in [0, 3] {
            let mut l = setup(100, None, par(2, workers));
            let _ = l.next();
            drop(l); // must not hang or panic
        }
    }

    #[test]
    fn dump_roundtrip() {
        let spec = Some(EncodeSpec::new(Encoding::Lossless128, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        if let Some(BatchPayload::Encoded(groups)) = l.next() {
            for g in &groups {
                let bytes = dump::to_bytes(g);
                let back = dump::from_bytes(&bytes).unwrap();
                assert_eq!(back.words_u64, g.words_u64);
                assert_eq!(back.offsets, g.offsets);
                assert_eq!(back.labels, g.labels);
                assert_eq!(decode_batch(&back), decode_batch(g));
            }
        } else {
            panic!("expected encoded payload");
        }
    }

    #[test]
    fn dump_rejects_corruption() {
        assert!(dump::from_bytes(b"short").is_err());
        assert!(dump::from_bytes(b"NOTMAGIC________________").is_err());
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        if let Some(BatchPayload::Encoded(groups)) = l.next() {
            let mut bytes = dump::to_bytes(&groups[0]);
            bytes.truncate(bytes.len() / 2);
            assert!(dump::from_bytes(&bytes).is_err());
        }
    }
}
