//! Parallel encode–decode (E-D) loader — the paper's Figure 1 pipeline,
//! rebuilt as a multi-worker producer pool.
//!
//! # Architecture
//!
//! ```text
//!             plans (ordered)        payloads (any order)      (re-ordered)
//! ┌─────────┐  step,BatchPlan  ┌──────────┐  step,payload  ┌───────────┐
//! │ planner ├───────┬─────────▶│ worker 0 ├───────┬───────▶│ sequencer ├──▶ trainer
//! │ thread  │       ├─────────▶│ worker 1 ├───────┤        │ (reorder  │
//! │ (SBS    │       └─────────▶│   ...    ├───────┘        │  buffer)  │
//! │ sampler)│                  │ worker N │                └───────────┘
//! └─────────┘                  └──────────┘      bounded channel = prefetch_depth
//! ```
//!
//! * The **planner** runs the sequential, cheap half of sampling
//!   ([`SbsSampler::plan_batch`]): it owns the RNG/pool state and emits one
//!   [`BatchPlan`] per step, in step order, into a bounded queue.
//! * **Workers** (`num_workers` threads) pull plans, materialize them
//!   (fetch + augment, [`materialize_plan_arena`]) into a thread-local
//!   staging batch — label rows and fetch images staged in a per-worker
//!   [`StageScratch`] (slab + recycled [`Dataset::get_into`] buffers) —
//!   and encode/widen into payload buffers drawn from the shared
//!   [`BufferPool`]. Materialization is a pure function of the plan, so
//!   any thread may produce any step, and the whole fetch→augment→encode
//!   loop allocates nothing at steady state.
//! * The **sequencer** restores step order with a reorder buffer and feeds
//!   the bounded output channel (depth `prefetch_depth`). A permit gate
//!   ([`Gate`]) provides the Figure-1 backpressure with a hard bound: at
//!   most `prefetch_depth + num_workers` materialized payloads exist at any
//!   moment (each worker may hold one while a full prefetch window is
//!   parked), released as the consumer takes batches.
//!
//! `num_workers = 0` keeps the classic single-producer thread (plan +
//! materialize + encode inline on one background thread), and
//! [`LoaderMode::Synchronous`] performs the same work inline on the
//! consumer thread — exactly the pipeline difference Figure 1 illustrates.
//! All modes and worker counts produce **byte-identical batch sequences**
//! for the same seed, because all stochastic state lives in the
//! sequentially-generated plans.
//!
//! # Buffers
//!
//! Payload buffers (f32 pixels, packed words, parity bitplanes, label rows,
//! group shells) cycle through the shared [`BufferPool`]: the trainer
//! returns spent payloads via [`EdLoader::recycle`], workers take them for
//! the next batch. After a two-batch warmup (the second batch settles LIFO
//! size mismatches from a short tail group), steady-state epochs perform no
//! pool-managed allocation — observable via [`BufferPool::allocs`] /
//! [`BufferPool::reuses`], which the trainer surfaces in its report.
//!
//! # Stats
//!
//! [`LoaderStats`] keeps the Figure-1 overlap accounting (aggregate
//! produce/blocked time) plus per-worker counters and sequencer
//! reorder-depth gauges; see [`LoaderStats::worker_summaries`].
//!
//! The paper also "dumps" encoded batches for reuse across epochs; the
//! [`dump`] submodule provides that binary cache.

use crate::data::dataset::Dataset;
use crate::data::encode::{encode_batch_grouped_into, EncodeError, EncodeSpec, EncodedBatch};
use crate::data::image::ImageBatch;
use crate::data::pool::BufferPool;
use crate::data::sampler::{materialize_plan_arena, BatchPlan, ClassSpec, SbsSampler, StageScratch};
use crate::fault::FaultInjector;
use crate::trace::{ThreadTracer, Tracer};
use crate::util::crc::Crc32;
use std::collections::{BTreeMap, VecDeque};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::mpsc::{sync_channel, Receiver, RecvTimeoutError, Sender, SyncSender};
use std::sync::{Arc, Condvar, Mutex, MutexGuard};
use std::time::{Duration, Instant};

/// Lock a mutex, recovering the guard if a previous holder panicked.
/// Every mutex in this module protects plain-old-data whose invariants
/// hold between statements, so a poisoned lock is safe to adopt — and
/// required for fault tolerance: one panicking worker must not wedge
/// every thread sharing the plan queue or the permit gate.
fn lock_recover<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|poisoned| poisoned.into_inner())
}

/// Typed failure surfaced by [`EdLoader::try_next`] instead of a panic or
/// a silent hang.
#[derive(Clone, Debug, PartialEq)]
pub enum LoaderError {
    /// A producer's encode step failed (capacity violation upstream).
    Encode { step: usize, reason: String },
    /// A worker died holding `step`'s plan and the respawn budget was
    /// exhausted; the batch cannot be produced.
    WorkerPanicked { step: usize, respawns: u64 },
    /// No message arrived within the watchdog deadline.
    Stalled { stage: String, waited: Duration, produced: u64 },
}

impl std::fmt::Display for LoaderError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            LoaderError::Encode { step, reason } => {
                write!(f, "E-D producer encode failed at step {step}: {reason}")
            }
            LoaderError::WorkerPanicked { step, respawns } => write!(
                f,
                "E-D worker died holding step {step}'s plan after {respawns} respawns; \
                 giving up on this batch"
            ),
            LoaderError::Stalled { stage, waited, produced } => write!(
                f,
                "E-D loader stalled: no batch within {:.1}s; stalled stage: {stage} \
                 (producers sent {produced} batches so far)",
                waited.as_secs_f64()
            ),
        }
    }
}

impl std::error::Error for LoaderError {}

/// What the loader hands the trainer per step.
#[derive(Clone, Debug)]
pub enum BatchPayload {
    /// Baseline pipelines: f32 pixels in `[0,1)` + soft labels.
    Raw { data: Vec<f32>, labels: Vec<f32>, n: usize },
    /// E-D pipelines: capacity-sized packed groups (see `encode`).
    Encoded(Vec<EncodedBatch>),
}

impl BatchPayload {
    /// Number of images carried.
    pub fn len(&self) -> usize {
        match self {
            BatchPayload::Raw { n, .. } => *n,
            BatchPayload::Encoded(gs) => gs.iter().map(|g| g.n).sum(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Host-side payload bytes (the quantity the paper's 16× claim is about).
    pub fn payload_bytes(&self) -> u64 {
        match self {
            BatchPayload::Raw { data, .. } => (data.len() * 4) as u64,
            BatchPayload::Encoded(gs) => gs.iter().map(|g| g.payload_bytes()).sum(),
        }
    }
}

/// Loader operating mode.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LoaderMode {
    /// Produce batches inline on `next()` (standard pipeline).
    Synchronous,
    /// Produce on background threads with a bounded prefetch queue (the
    /// paper's parallel E-D pipeline). `num_workers = 0` keeps the classic
    /// single producer thread; `n ≥ 1` runs the planner/worker/sequencer
    /// pool with `n` encode workers.
    Parallel { prefetch_depth: usize, num_workers: usize },
}

/// Default worker count for the producer pool: one core is left for the
/// consuming trainer thread.
pub fn default_num_workers() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get().saturating_sub(1))
        .unwrap_or(1)
        .max(1)
}

/// One worker's counters (all thread-shared atomics).
#[derive(Debug, Default)]
pub struct WorkerStats {
    /// ns this worker spent materializing + encoding batches.
    pub produce_ns: AtomicU64,
    /// ns this worker spent blocked handing batches downstream.
    pub blocked_ns: AtomicU64,
    pub batches: AtomicU64,
    /// Heap fallbacks of the worker's staging-scratch arena (see
    /// [`materialize_plan_arena`]); 0 ⇒ the scratch path ran entirely in
    /// the per-worker slab.
    pub scratch_fallbacks: AtomicU64,
}

/// Plain-data snapshot of one worker's counters.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct WorkerSummary {
    pub produce_secs: f64,
    pub blocked_secs: f64,
    pub batches: u64,
    /// Staging-scratch requests the worker's arena could not serve.
    pub scratch_fallbacks: u64,
}

/// Producer-side counters for the Fig-1 overlap analysis.
#[derive(Debug, Default)]
pub struct LoaderStats {
    /// ns producers spent generating+encoding batches (sum over workers).
    pub produce_ns: AtomicU64,
    /// ns producers spent blocked on full queues (backpressure).
    pub blocked_ns: AtomicU64,
    pub batches: AtomicU64,
    /// Per-worker counters (empty for the synchronous mode; one entry for
    /// the legacy single-producer mode).
    pub workers: Vec<WorkerStats>,
    /// High-water mark of the sequencer's reorder buffer.
    pub seq_max_depth: AtomicU64,
    /// Batches that arrived at the sequencer ahead of their turn.
    pub seq_out_of_order: AtomicU64,
    /// Workers the supervisor respawned after a panic.
    pub respawns: AtomicU64,
    /// Corrupted payloads detected by checksum and re-encoded.
    pub corruptions_detected: AtomicU64,
    /// Decoded batches currently queued between the loader and the
    /// consumer (incremented before each downstream send, decremented as
    /// the consumer receives — a live gauge, not a cumulative counter).
    pub out_queue_depth: AtomicU64,
}

impl LoaderStats {
    fn with_workers(n: usize) -> LoaderStats {
        LoaderStats {
            workers: (0..n).map(|_| WorkerStats::default()).collect(),
            ..LoaderStats::default()
        }
    }

    pub fn produce_secs(&self) -> f64 {
        self.produce_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    pub fn blocked_secs(&self) -> f64 {
        self.blocked_ns.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Current loader → consumer queue depth (0 in synchronous mode).
    pub fn queue_depth(&self) -> u64 {
        self.out_queue_depth.load(Ordering::Relaxed)
    }

    /// Per-worker snapshots (empty when the loader ran synchronously).
    pub fn worker_summaries(&self) -> Vec<WorkerSummary> {
        self.workers
            .iter()
            .map(|w| WorkerSummary {
                produce_secs: w.produce_ns.load(Ordering::Relaxed) as f64 / 1e9,
                blocked_secs: w.blocked_ns.load(Ordering::Relaxed) as f64 / 1e9,
                batches: w.batches.load(Ordering::Relaxed),
                scratch_fallbacks: w.scratch_fallbacks.load(Ordering::Relaxed),
            })
            .collect()
    }
}

/// Build one payload from a staged batch, drawing every buffer from `pool`.
fn make_payload(
    batch: &ImageBatch,
    spec: Option<EncodeSpec>,
    pool: &BufferPool,
) -> Result<BatchPayload, EncodeError> {
    Ok(match spec {
        None => {
            let mut data = pool.take_f32(batch.data.len());
            batch.to_f32_into(&mut data);
            let mut labels = pool.take_f32(batch.labels.len());
            labels.extend_from_slice(&batch.labels);
            BatchPayload::Raw { data, labels, n: batch.n }
        }
        Some(s) => {
            let mut groups = pool.take_shells();
            encode_batch_grouped_into(batch, s, pool, &mut groups)?;
            BatchPayload::Encoded(groups)
        }
    })
}

/// Counting semaphore bounding materialized payloads in flight. A worker
/// acquires a permit **before** dequeuing a plan (so the holder of the
/// lowest outstanding step always owns a permit and the sequencer can
/// always make progress — acquiring after the dequeue could strand the
/// next-in-order step behind parked future ones); the consumer releases it
/// when a payload leaves the output channel. Hard bound:
/// `prefetch_depth + num_workers` payloads exist at any moment.
struct Gate {
    permits: Mutex<usize>,
    cv: Condvar,
}

impl Gate {
    fn new(permits: usize) -> Gate {
        Gate { permits: Mutex::new(permits), cv: Condvar::new() }
    }

    /// Take a permit; returns `false` if `cancel` was raised while waiting.
    /// Poison-tolerant: a worker that panicked while holding the permit
    /// mutex must not wedge the remaining workers (see [`lock_recover`]).
    fn acquire(&self, cancel: &AtomicBool) -> bool {
        let mut p = lock_recover(&self.permits);
        loop {
            if cancel.load(Ordering::Relaxed) {
                return false;
            }
            if *p > 0 {
                *p -= 1;
                return true;
            }
            p = match self.cv.wait(p) {
                Ok(guard) => guard,
                Err(poisoned) => poisoned.into_inner(),
            };
        }
    }

    fn release(&self) {
        *lock_recover(&self.permits) += 1;
        self.cv.notify_one();
    }

    /// Wake every waiter (used with the cancel flag on shutdown; taking the
    /// mutex first makes the wakeup race-free against a check-then-wait).
    fn wake_all(&self) {
        let _guard = lock_recover(&self.permits);
        self.cv.notify_all();
    }
}

/// Checksum of the bytes a payload ships (used by the corruption
/// detect-and-reencode path; not on the fault-free hot path).
fn payload_crc(p: &BatchPayload) -> u32 {
    let mut c = Crc32::new();
    match p {
        BatchPayload::Raw { data, labels, n } => {
            c.update(&(*n as u64).to_le_bytes());
            for v in data.iter().chain(labels) {
                c.update(&v.to_le_bytes());
            }
        }
        BatchPayload::Encoded(groups) => {
            for g in groups {
                for w in &g.words_u64 {
                    c.update(&w.to_le_bytes());
                }
                for w in &g.words_f64 {
                    c.update(&w.to_le_bytes());
                }
                c.update(&g.offsets);
                for l in &g.labels {
                    c.update(&l.to_le_bytes());
                }
            }
        }
    }
    c.finish()
}

/// Flip one bit in the payload's first shipped buffer (the injected
/// corruption the checksum must catch).
fn corrupt_payload(p: &mut BatchPayload) {
    match p {
        BatchPayload::Raw { data, .. } => {
            if let Some(v) = data.first_mut() {
                *v = f32::from_bits(v.to_bits() ^ 1);
            }
        }
        BatchPayload::Encoded(groups) => {
            if let Some(g) = groups.first_mut() {
                if let Some(w) = g.words_u64.first_mut() {
                    *w ^= 1;
                } else if let Some(w) = g.words_f64.first_mut() {
                    *w = f64::from_bits(w.to_bits() ^ 1);
                } else if let Some(l) = g.labels.first_mut() {
                    *l = f32::from_bits(l.to_bits() ^ 1);
                }
            }
        }
    }
}

/// Shared context for every producer thread.
#[derive(Clone)]
struct ProducerCtx {
    dataset: Arc<dyn Dataset>,
    specs: Arc<Vec<ClassSpec>>,
    spec: Option<EncodeSpec>,
    pool: Arc<BufferPool>,
    stats: Arc<LoaderStats>,
    cancel: Arc<AtomicBool>,
    faults: Option<Arc<FaultInjector>>,
    /// Tracing handle each pipeline thread derives its buffer from
    /// (disabled unless built via [`EdLoader::with_observability`]).
    tracer: Tracer,
}

impl ProducerCtx {
    /// Per-worker staging scratch: the label-row slab plus the recycled
    /// fetch-image buffers [`materialize_plan_arena`] stages through.
    fn worker_scratch(&self) -> StageScratch {
        StageScratch::new(self.dataset.num_classes())
    }

    /// The pure materialize + encode path (a function of the plan alone,
    /// so a retry or a respawned worker reproduces identical bytes).
    fn produce_inner(
        &self,
        wid: usize,
        plan: &BatchPlan,
        stage: &mut ImageBatch,
        scratch: &mut StageScratch,
    ) -> Result<BatchPayload, EncodeError> {
        let (h, w, c) = self.dataset.shape();
        stage.reset(plan.len(), h, w, c, self.dataset.num_classes());
        materialize_plan_arena(&self.specs, self.dataset.as_ref(), plan, stage, scratch);
        self.stats.workers[wid]
            .scratch_fallbacks
            .store(scratch.fallback_allocs(), Ordering::Relaxed);
        make_payload(stage, self.spec, &self.pool)
    }

    /// Materialize + encode one plan, accounting to worker `wid`. Encode
    /// failures surface as a typed [`LoaderError`] (not a panic, so one
    /// bad batch cannot wedge the threads sharing this context's mutexes);
    /// injected faults fire here: a scheduled worker panic (recovered by
    /// the pool supervisor) or payload corruption, which the checksum
    /// catches and a clean re-encode repairs.
    fn produce(
        &self,
        wid: usize,
        step: usize,
        plan: &BatchPlan,
        stage: &mut ImageBatch,
        scratch: &mut StageScratch,
        trace: &mut ThreadTracer,
    ) -> Result<BatchPayload, LoaderError> {
        let t0 = Instant::now();
        let span0 = trace.begin();
        if let Some(f) = &self.faults {
            if f.worker_panic_due(step) {
                // The instant survives the unwind: the thread's trace
                // buffer flushes from the ThreadTracer Drop guard.
                trace.instant_arg("worker-panic", "fault", Some(("step", step as f64)));
                panic!("injected fault: worker {wid} panics holding step {step}");
            }
        }
        let fallbacks_before = scratch.fallback_allocs();
        let encode = |e: &EncodeError| LoaderError::Encode { step, reason: e.to_string() };
        let mut payload =
            self.produce_inner(wid, plan, stage, scratch).map_err(|e| encode(&e))?;
        if let Some(f) = &self.faults {
            if f.corrupt_due(step) {
                let expect = payload_crc(&payload);
                corrupt_payload(&mut payload);
                if payload_crc(&payload) != expect {
                    self.stats.corruptions_detected.fetch_add(1, Ordering::Relaxed);
                    trace.instant_arg(
                        "corruption-reencode",
                        "fault",
                        Some(("step", step as f64)),
                    );
                    self.pool.recycle_payload(payload);
                    payload =
                        self.produce_inner(wid, plan, stage, scratch).map_err(|e| encode(&e))?;
                }
            }
        }
        if scratch.fallback_allocs() > fallbacks_before {
            trace.instant_arg(
                "scratch-heap-fallback",
                "arena",
                Some(("total", scratch.fallback_allocs() as f64)),
            );
        }
        trace.end_span_arg("produce", "loader", span0, Some(("step", step as f64)));
        let dt = t0.elapsed().as_nanos() as u64;
        self.stats.workers[wid].produce_ns.fetch_add(dt, Ordering::Relaxed);
        self.stats.produce_ns.fetch_add(dt, Ordering::Relaxed);
        Ok(payload)
    }

    /// Account a completed (sent) batch to worker `wid`.
    fn sent(&self, wid: usize, blocked: Instant) {
        let dt = blocked.elapsed().as_nanos() as u64;
        self.stats.workers[wid].blocked_ns.fetch_add(dt, Ordering::Relaxed);
        self.stats.blocked_ns.fetch_add(dt, Ordering::Relaxed);
        self.stats.workers[wid].batches.fetch_add(1, Ordering::Relaxed);
        self.stats.batches.fetch_add(1, Ordering::Relaxed);
    }
}

/// Epoch-scoped batch source with all modes behind one interface.
pub enum EdLoader {
    Sync {
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        remaining: usize,
        stats: Arc<LoaderStats>,
        pool: Arc<BufferPool>,
        /// Reused staging batch (allocated once per loader).
        stage: ImageBatch,
        /// Staging scratch (label-row slab + fetch images, recycled).
        scratch: StageScratch,
    },
    Par {
        rx: Receiver<Result<BatchPayload, LoaderError>>,
        handles: Vec<std::thread::JoinHandle<()>>,
        stats: Arc<LoaderStats>,
        pool: Arc<BufferPool>,
        cancel: Arc<AtomicBool>,
        /// In-flight payload bound for the worker pool (`None` for the
        /// single-producer mode, where the output channel already bounds it).
        gate: Option<Arc<Gate>>,
        /// Watchdog deadline for [`EdLoader::try_next`] (`None` = wait
        /// forever, the historical behavior).
        watchdog: Option<Duration>,
    },
}

/// Worker respawn budget per loader: past this the supervisor reports the
/// in-flight step as a typed error instead of looping on a crashing host.
const MAX_RESPAWNS: u64 = 8;

/// A dead-man switch each pool worker holds: dropped on unwind with
/// `clean = false`, telling the supervisor the worker panicked.
struct DeathNotice {
    wid: usize,
    tx: Sender<(usize, bool)>,
    clean: bool,
}

impl Drop for DeathNotice {
    fn drop(&mut self) {
        let _ = self.tx.send((self.wid, self.clean));
    }
}

/// Last known state of a worker's in-flight step, published before the
/// work starts so the supervisor can recover it after a panic.
#[derive(Default)]
struct InFlight {
    /// The worker holds a gate permit not yet transferred to a payload.
    permit: bool,
    /// The `(step, plan)` being produced (cleared once sent downstream).
    work: Option<(usize, BatchPlan)>,
}

/// Everything a pool worker (or its respawned replacement) needs.
#[derive(Clone)]
struct WorkerShared {
    plan_rx: Arc<Mutex<Receiver<(usize, BatchPlan)>>>,
    /// Recovered in-flight plans, produced before fresh ones so the
    /// sequenced stream stays gap-free.
    requeue: Arc<Mutex<VecDeque<(usize, BatchPlan)>>>,
    seq_tx: SyncSender<(usize, Result<BatchPayload, LoaderError>)>,
    gate: Arc<Gate>,
    slots: Arc<Vec<Mutex<InFlight>>>,
    death_tx: Sender<(usize, bool)>,
}

/// Spawn one pool worker thread (used at startup and by the supervisor
/// when it replaces a dead worker).
fn spawn_pool_worker(
    wid: usize,
    ctx: ProducerCtx,
    shared: WorkerShared,
) -> std::thread::JoinHandle<()> {
    std::thread::Builder::new()
        .name(format!("optorch-ed-worker-{wid}"))
        .spawn(move || {
            let mut notice = DeathNotice { wid, tx: shared.death_tx.clone(), clean: false };
            let mut stage = ImageBatch::zeros(0, 0, 0, 0, 1);
            let mut scratch = ctx.worker_scratch();
            // Per-thread trace buffer; a respawned replacement registers
            // the same name with a later seq, so its track sorts after its
            // predecessor's in the drained log.
            let mut trace = ctx.tracer.thread(format!("loader/worker-{wid}"));
            loop {
                // A permit caps in-flight payloads; taking it before the
                // dequeue keeps step order live (see Gate docs). False =
                // canceled.
                let gate0 = trace.begin();
                if !shared.gate.acquire(&ctx.cancel) {
                    break;
                }
                trace.end_span("gate-blocked", "loader", gate0);
                lock_recover(&shared.slots[wid]).permit = true;
                // Recovered plans outrank fresh ones; the lock scope on the
                // plan queue is held only across the blocking recv (plans
                // are cheap and arrive fast).
                let requeued = lock_recover(&shared.requeue).pop_front();
                let (step, plan) = match requeued {
                    Some(w) => w,
                    None => match lock_recover(&shared.plan_rx).recv() {
                        Ok(w) => w,
                        Err(_) => {
                            // permit unused: no more plans
                            shared.gate.release();
                            lock_recover(&shared.slots[wid]).permit = false;
                            break;
                        }
                    },
                };
                lock_recover(&shared.slots[wid]).work = Some((step, plan.clone()));
                let result = ctx.produce(wid, step, &plan, &mut stage, &mut scratch, &mut trace);
                // From here the permit travels with the payload (the
                // consumer releases it), so clear the recovery slot first.
                {
                    let mut s = lock_recover(&shared.slots[wid]);
                    s.permit = false;
                    s.work = None;
                }
                let t1 = Instant::now();
                let send0 = trace.begin();
                if shared.seq_tx.send((step, result)).is_err() {
                    break; // sequencer gone (shutdown)
                }
                trace.end_span("send-blocked", "loader", send0);
                ctx.sent(wid, t1);
            }
            notice.clean = true;
        })
        .expect("spawn E-D worker")
}

impl EdLoader {
    /// Build a loader producing `num_batches` batches with a private
    /// buffer pool. Prefer [`EdLoader::with_pool`] when a pool outlives the
    /// epoch (the trainer shares one across all epochs).
    ///
    /// `spec = None` ships raw f32 batches (B / M-P / S-C pipelines);
    /// `spec = Some(_)` ships packed batches (E-D pipelines).
    pub fn new(
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        mode: LoaderMode,
    ) -> EdLoader {
        Self::with_pool(dataset, sampler, spec, num_batches, mode, Arc::new(BufferPool::default()))
    }

    /// [`EdLoader::new`] with a caller-owned [`BufferPool`] so payload
    /// buffers recycle across epochs.
    pub fn with_pool(
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        mode: LoaderMode,
        pool: Arc<BufferPool>,
    ) -> EdLoader {
        Self::with_faults(dataset, sampler, spec, num_batches, mode, pool, None, None)
    }

    /// [`EdLoader::with_pool`] plus the robustness knobs: an optional
    /// [`FaultInjector`] (worker panics / payload corruption fire in the
    /// producers) and an optional watchdog deadline for
    /// [`EdLoader::try_next`]. Both apply to the parallel modes; the
    /// synchronous loader has no threads to kill or queues to stall.
    #[allow(clippy::too_many_arguments)]
    pub fn with_faults(
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        mode: LoaderMode,
        pool: Arc<BufferPool>,
        faults: Option<Arc<FaultInjector>>,
        watchdog: Option<Duration>,
    ) -> EdLoader {
        Self::with_observability(
            dataset,
            sampler,
            spec,
            num_batches,
            mode,
            pool,
            faults,
            watchdog,
            Tracer::disabled(),
        )
    }

    /// [`EdLoader::with_faults`] plus a [`Tracer`]: every pipeline thread
    /// (planner, encode workers, sequencer, supervisor) registers its own
    /// trace buffer and records produce / gate-blocked / send-blocked
    /// spans, fault instants (worker panics, corruption re-encodes,
    /// respawns) and the sequencer's reorder-depth counter. A
    /// [`Tracer::disabled`] handle makes every record a single branch; the
    /// synchronous mode has no pipeline threads and stays untraced.
    #[allow(clippy::too_many_arguments)]
    pub fn with_observability(
        dataset: Arc<dyn Dataset>,
        sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        mode: LoaderMode,
        pool: Arc<BufferPool>,
        faults: Option<Arc<FaultInjector>>,
        watchdog: Option<Duration>,
        tracer: Tracer,
    ) -> EdLoader {
        match mode {
            LoaderMode::Synchronous => {
                let (h, w, c) = dataset.shape();
                let stage = ImageBatch::zeros(sampler.batch_size, h, w, c, dataset.num_classes());
                let scratch = StageScratch::new(dataset.num_classes());
                EdLoader::Sync {
                    dataset,
                    sampler,
                    spec,
                    remaining: num_batches,
                    stats: Arc::new(LoaderStats::with_workers(0)),
                    pool,
                    stage,
                    scratch,
                }
            }
            LoaderMode::Parallel { prefetch_depth, num_workers: 0 } => {
                Self::spawn_single_producer(
                    dataset,
                    sampler,
                    spec,
                    num_batches,
                    prefetch_depth,
                    pool,
                    faults,
                    watchdog,
                    tracer,
                )
            }
            LoaderMode::Parallel { prefetch_depth, num_workers } => Self::spawn_worker_pool(
                dataset,
                sampler,
                spec,
                num_batches,
                prefetch_depth,
                num_workers,
                pool,
                faults,
                watchdog,
                tracer,
            ),
        }
    }

    /// The classic Figure-1 shape: one background thread does plan +
    /// materialize + encode sequentially (`num_workers = 0`). With no
    /// worker pool there is no supervisor: an injected worker panic here
    /// surfaces as a typed [`LoaderError::WorkerPanicked`] instead of a
    /// respawn (the sampler state died with the producer).
    #[allow(clippy::too_many_arguments)]
    fn spawn_single_producer(
        dataset: Arc<dyn Dataset>,
        mut sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        prefetch_depth: usize,
        pool: Arc<BufferPool>,
        faults: Option<Arc<FaultInjector>>,
        watchdog: Option<Duration>,
        tracer: Tracer,
    ) -> EdLoader {
        let stats = Arc::new(LoaderStats::with_workers(1));
        let cancel = Arc::new(AtomicBool::new(false));
        let (tx, rx) = sync_channel(prefetch_depth.max(1));
        let ctx = ProducerCtx {
            dataset: dataset.clone(),
            specs: Arc::new(sampler.specs().to_vec()),
            spec,
            pool: pool.clone(),
            stats: stats.clone(),
            cancel: cancel.clone(),
            faults,
            tracer,
        };
        let handle = std::thread::Builder::new()
            .name("optorch-ed-producer".into())
            .spawn(move || {
                let mut stage = ImageBatch::zeros(0, 0, 0, 0, 1);
                let mut scratch = ctx.worker_scratch();
                let mut trace = ctx.tracer.thread("loader/producer");
                for step in 0..num_batches {
                    if ctx.cancel.load(Ordering::Relaxed) {
                        return;
                    }
                    let plan0 = trace.begin();
                    let plan = sampler.plan_batch(ctx.dataset.as_ref());
                    trace.end_span_arg("plan", "loader", plan0, Some(("step", step as f64)));
                    if let Some(f) = &ctx.faults {
                        // A panic would silently truncate the stream (there
                        // is nothing to respawn a single producer's sampler
                        // state into); report it typed instead.
                        if f.worker_panic_due(step) {
                            trace.instant_arg(
                                "worker-panic",
                                "fault",
                                Some(("step", step as f64)),
                            );
                            ctx.stats.out_queue_depth.fetch_add(1, Ordering::Relaxed);
                            let _ = tx.send(Err(LoaderError::WorkerPanicked {
                                step,
                                respawns: 0,
                            }));
                            return;
                        }
                    }
                    let result = ctx.produce(0, step, &plan, &mut stage, &mut scratch, &mut trace);
                    let failed = result.is_err();
                    let t1 = Instant::now();
                    let send0 = trace.begin();
                    // Counted before the send so the consumer-side
                    // decrement can never observe the gauge at zero.
                    ctx.stats.out_queue_depth.fetch_add(1, Ordering::Relaxed);
                    if tx.send(result).is_err() {
                        return; // consumer dropped; stop quietly
                    }
                    trace.end_span("send-blocked", "loader", send0);
                    if failed {
                        return; // typed error delivered; end the stream
                    }
                    ctx.sent(0, t1);
                }
            })
            .expect("spawn E-D producer");
        EdLoader::Par { rx, handles: vec![handle], stats, pool, cancel, gate: None, watchdog }
    }

    /// The producer pool: planner → N workers → sequencer (see module
    /// docs), plus a supervisor that watches for worker deaths. When a
    /// worker panics the supervisor releases its stranded gate permit,
    /// requeues its in-flight `(step, plan)` (materialization is a pure
    /// function of the plan, so whoever re-produces it emits identical
    /// bytes and the sequenced stream stays byte-identical to a
    /// fault-free run), and spawns a replacement — up to [`MAX_RESPAWNS`],
    /// after which the step surfaces as a typed error.
    #[allow(clippy::too_many_arguments)]
    fn spawn_worker_pool(
        dataset: Arc<dyn Dataset>,
        mut sampler: SbsSampler,
        spec: Option<EncodeSpec>,
        num_batches: usize,
        prefetch_depth: usize,
        num_workers: usize,
        pool: Arc<BufferPool>,
        faults: Option<Arc<FaultInjector>>,
        watchdog: Option<Duration>,
        tracer: Tracer,
    ) -> EdLoader {
        let depth = prefetch_depth.max(1);
        let stats = Arc::new(LoaderStats::with_workers(num_workers));
        let cancel = Arc::new(AtomicBool::new(false));
        let specs = Arc::new(sampler.specs().to_vec());
        let gate = Arc::new(Gate::new(depth + num_workers));
        let mut handles = Vec::with_capacity(num_workers + 3);

        // Plans flow through a bounded queue so the planner (and its RNG
        // state) never runs more than depth + num_workers steps ahead.
        let (plan_tx, plan_rx) = sync_channel::<(usize, BatchPlan)>(depth + num_workers);
        let plan_rx = Arc::new(Mutex::new(plan_rx));
        // Workers hand finished payloads (tagged with their step) to the
        // sequencer. The gate (not this capacity) is what bounds payload
        // memory; the sequencer drains this queue eagerly into its reorder
        // buffer, so a small capacity cannot deadlock.
        let (seq_tx, seq_rx) =
            sync_channel::<(usize, Result<BatchPayload, LoaderError>)>(depth);
        // The sequencer feeds the consumer in step order; this channel's
        // depth is the Figure-1 prefetch bound.
        let (out_tx, out_rx) = sync_channel::<Result<BatchPayload, LoaderError>>(depth);
        // Unbounded: a worker's death notice (sent from a Drop guard during
        // unwind) must never block.
        let (death_tx, death_rx) = std::sync::mpsc::channel::<(usize, bool)>();

        {
            let dataset = dataset.clone();
            let cancel = cancel.clone();
            let tracer = tracer.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("optorch-ed-planner".into())
                    .spawn(move || {
                        let mut trace = tracer.thread("loader/planner");
                        for step in 0..num_batches {
                            if cancel.load(Ordering::Relaxed) {
                                return;
                            }
                            let plan0 = trace.begin();
                            let plan = sampler.plan_batch(dataset.as_ref());
                            trace.end_span_arg(
                                "plan",
                                "loader",
                                plan0,
                                Some(("step", step as f64)),
                            );
                            let send0 = trace.begin();
                            if plan_tx.send((step, plan)).is_err() {
                                return; // workers gone
                            }
                            trace.end_span("send-blocked", "loader", send0);
                        }
                    })
                    .expect("spawn E-D planner"),
            );
        }

        let ctx = ProducerCtx {
            dataset,
            specs,
            spec,
            pool: pool.clone(),
            stats: stats.clone(),
            cancel: cancel.clone(),
            faults,
            tracer: tracer.clone(),
        };
        let shared = WorkerShared {
            plan_rx,
            requeue: Arc::new(Mutex::new(VecDeque::new())),
            seq_tx: seq_tx.clone(),
            gate: gate.clone(),
            slots: Arc::new((0..num_workers).map(|_| Mutex::new(InFlight::default())).collect()),
            death_tx,
        };
        for wid in 0..num_workers {
            handles.push(spawn_pool_worker(wid, ctx.clone(), shared.clone()));
        }

        {
            // The supervisor: consumes death notices until every worker
            // (original or replacement) has exited cleanly.
            let ctx = ctx.clone();
            let shared = shared.clone();
            let stats = stats.clone();
            let cancel = cancel.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("optorch-ed-supervisor".into())
                    .spawn(move || {
                        let mut trace = ctx.tracer.thread("loader/supervisor");
                        let mut live = num_workers;
                        let mut respawns = 0u64;
                        let mut replacements: Vec<std::thread::JoinHandle<()>> = Vec::new();
                        while live > 0 {
                            let Ok((wid, clean)) = death_rx.recv() else { break };
                            if clean || cancel.load(Ordering::Relaxed) {
                                live -= 1;
                                continue;
                            }
                            // Unclean death: recover the permit and the
                            // in-flight plan the worker took with it.
                            let (permit, work) = {
                                let mut s = lock_recover(&shared.slots[wid]);
                                (std::mem::replace(&mut s.permit, false), s.work.take())
                            };
                            if respawns < MAX_RESPAWNS {
                                respawns += 1;
                                stats.respawns.fetch_add(1, Ordering::Relaxed);
                                trace.instant_arg(
                                    "worker-respawn",
                                    "fault",
                                    Some(("worker", wid as f64)),
                                );
                                if permit {
                                    // The replacement acquires its own
                                    // permit; free the dead worker's.
                                    shared.gate.release();
                                }
                                if let Some(w) = work {
                                    lock_recover(&shared.requeue).push_front(w);
                                }
                                replacements.push(spawn_pool_worker(
                                    wid,
                                    ctx.clone(),
                                    shared.clone(),
                                ));
                            } else {
                                live -= 1;
                                trace.instant_arg(
                                    "worker-giveup",
                                    "fault",
                                    Some(("worker", wid as f64)),
                                );
                                if let Some((step, _)) = work {
                                    // The permit travels with the error
                                    // message (the consumer releases it);
                                    // send only if the worker still held it.
                                    if !permit {
                                        shared.gate.acquire(&ctx.cancel);
                                    }
                                    let _ = shared.seq_tx.send((
                                        step,
                                        Err(LoaderError::WorkerPanicked { step, respawns }),
                                    ));
                                } else if permit {
                                    shared.gate.release();
                                }
                            }
                        }
                        for h in replacements {
                            let _ = h.join();
                        }
                    })
                    .expect("spawn E-D supervisor"),
            );
        }
        drop(seq_tx); // sequencer sees disconnect once workers + supervisor exit
        drop(ctx);

        {
            let stats = stats.clone();
            handles.push(
                std::thread::Builder::new()
                    .name("optorch-ed-sequencer".into())
                    .spawn(move || {
                        let mut trace = tracer.thread("loader/sequencer");
                        let mut next = 0usize;
                        let mut parked: BTreeMap<usize, Result<BatchPayload, LoaderError>> =
                            BTreeMap::new();
                        while next < num_batches {
                            let Ok((step, payload)) = seq_rx.recv() else { return };
                            if step != next {
                                stats.seq_out_of_order.fetch_add(1, Ordering::Relaxed);
                                trace.instant_arg(
                                    "out-of-order",
                                    "loader",
                                    Some(("step", step as f64)),
                                );
                            }
                            parked.insert(step, payload);
                            stats
                                .seq_max_depth
                                .fetch_max(parked.len() as u64, Ordering::Relaxed);
                            trace.counter("reorder-depth", "loader", parked.len() as f64);
                            while let Some(ready) = parked.remove(&next) {
                                stats.out_queue_depth.fetch_add(1, Ordering::Relaxed);
                                if out_tx.send(ready).is_err() {
                                    return; // consumer dropped
                                }
                                next += 1;
                            }
                        }
                    })
                    .expect("spawn E-D sequencer"),
            );
        }

        EdLoader::Par { rx: out_rx, handles, stats, pool, cancel, gate: Some(gate), watchdog }
    }

    /// Next batch, or `Ok(None)` at end of the configured run. Typed
    /// failures — an encode error, a worker dead past its respawn budget,
    /// a watchdog-detected stall — surface as `Err` instead of a panic;
    /// [`EdLoader::next`] is the panicking convenience wrapper.
    pub fn try_next(&mut self) -> Result<Option<BatchPayload>, LoaderError> {
        match self {
            EdLoader::Sync { dataset, sampler, spec, remaining, stats, pool, stage, scratch } => {
                if *remaining == 0 {
                    return Ok(None);
                }
                *remaining -= 1;
                let t0 = Instant::now();
                sampler.next_batch_arena(dataset.as_ref(), stage, scratch);
                let step = stats.batches.load(Ordering::Relaxed) as usize;
                let payload = make_payload(stage, *spec, pool)
                    .map_err(|e| LoaderError::Encode { step, reason: e.to_string() })?;
                stats
                    .produce_ns
                    .fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.batches.fetch_add(1, Ordering::Relaxed);
                Ok(Some(payload))
            }
            EdLoader::Par { rx, gate, stats, watchdog, .. } => {
                let msg = match watchdog {
                    None => rx.recv().ok(),
                    Some(d) => match rx.recv_timeout(*d) {
                        Ok(m) => Some(m),
                        Err(RecvTimeoutError::Disconnected) => None,
                        Err(RecvTimeoutError::Timeout) => {
                            let produced = stats.batches.load(Ordering::Relaxed);
                            let stage = if produced == 0 {
                                "planner/encode workers (no batch produced yet)"
                            } else {
                                "sequencer/output channel"
                            };
                            return Err(LoaderError::Stalled {
                                stage: stage.into(),
                                waited: *d,
                                produced,
                            });
                        }
                    },
                };
                match msg {
                    Some(res) => {
                        // Paired with the producer-side increment (which
                        // happens-before this recv, so no underflow).
                        stats.out_queue_depth.fetch_sub(1, Ordering::Relaxed);
                        if let Some(g) = gate.as_ref() {
                            // One message (payload or error) left the
                            // pipeline; its permit comes back here.
                            g.release();
                        }
                        res.map(Some)
                    }
                    None => Ok(None),
                }
            }
        }
    }

    /// Next batch, or `None` at end of the configured run. Panics on a
    /// typed loader failure; use [`EdLoader::try_next`] to handle those.
    pub fn next(&mut self) -> Option<BatchPayload> {
        match self.try_next() {
            Ok(p) => p,
            Err(e) => panic!("E-D loader failed: {e}"),
        }
    }

    /// Return a spent payload's buffers to the loader's pool. Optional but
    /// strongly recommended on the training path: it is what makes
    /// steady-state epochs allocation-free.
    pub fn recycle(&self, payload: BatchPayload) {
        self.pool().recycle_payload(payload);
    }

    /// The loader's buffer pool (shared with its producer threads).
    pub fn pool(&self) -> &Arc<BufferPool> {
        match self {
            EdLoader::Sync { pool, .. } => pool,
            EdLoader::Par { pool, .. } => pool,
        }
    }

    pub fn stats(&self) -> Arc<LoaderStats> {
        match self {
            EdLoader::Sync { stats, .. } => stats.clone(),
            EdLoader::Par { stats, .. } => stats.clone(),
        }
    }
}

impl Drop for EdLoader {
    fn drop(&mut self) {
        if let EdLoader::Par { rx, handles, cancel, gate, .. } = self {
            // Ask producers to stop, then drain so nothing stays blocked on
            // a full queue. Producers exit on: cancel flag (workers parked
            // on the gate are woken to observe it), plan-channel disconnect,
            // or send failure; the drain ends when the last sender (the
            // sequencer / single producer) has exited.
            cancel.store(true, Ordering::Relaxed);
            if let Some(g) = gate {
                g.wake_all();
            }
            while rx.recv().is_ok() {}
            for h in handles.drain(..) {
                let _ = h.join();
            }
        }
    }
}

/// Binary cache for encoded batches — the paper's "dump" step in Figure 1.
pub mod dump {
    use super::*;
    use crate::data::encode::{Encoding, WordType};
    use std::io::{Read, Write};
    use std::path::Path;

    /// Current format: `OPTORCH2` payload + trailing CRC-32 of everything
    /// before it, so silent media corruption surfaces as a typed error
    /// instead of a scrambled batch.
    const MAGIC: &[u8; 8] = b"OPTORCH2";
    /// Pre-checksum format, still accepted on read (no CRC to verify).
    const LEGACY_MAGIC: &[u8; 8] = b"OPTORCH1";

    fn push_u32(buf: &mut Vec<u8>, v: u32) {
        buf.extend_from_slice(&v.to_le_bytes());
    }

    /// Serialize one encoded batch.
    pub fn to_bytes(e: &EncodedBatch) -> Vec<u8> {
        let mut buf = Vec::new();
        buf.extend_from_slice(MAGIC);
        buf.push(match e.spec_encoding {
            Encoding::Base256 => 0,
            Encoding::Lossless128 => 1,
        });
        buf.push(match e.spec_word {
            WordType::U64 => 0,
            WordType::F64 => 1,
        });
        for v in [e.n, e.h, e.w, e.c, e.num_classes] {
            push_u32(&mut buf, v as u32);
        }
        push_u32(&mut buf, e.words_u64.len() as u32);
        for w in &e.words_u64 {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        push_u32(&mut buf, e.words_f64.len() as u32);
        for w in &e.words_f64 {
            buf.extend_from_slice(&w.to_le_bytes());
        }
        push_u32(&mut buf, e.offsets.len() as u32);
        buf.extend_from_slice(&e.offsets);
        push_u32(&mut buf, e.labels.len() as u32);
        for l in &e.labels {
            buf.extend_from_slice(&l.to_le_bytes());
        }
        let crc = crate::util::crc::crc32(&buf);
        push_u32(&mut buf, crc);
        buf
    }

    fn take<'a>(b: &mut &'a [u8], n: usize) -> std::io::Result<&'a [u8]> {
        if b.len() < n {
            return Err(std::io::Error::new(
                std::io::ErrorKind::UnexpectedEof,
                "truncated dump",
            ));
        }
        let (head, tail) = b.split_at(n);
        *b = tail;
        Ok(head)
    }

    fn take_u32(b: &mut &[u8]) -> std::io::Result<u32> {
        Ok(u32::from_le_bytes(take(b, 4)?.try_into().unwrap()))
    }

    /// Deserialize one encoded batch. `OPTORCH2` dumps are CRC-verified;
    /// legacy `OPTORCH1` dumps parse without a checksum.
    pub fn from_bytes(mut b: &[u8]) -> std::io::Result<EncodedBatch> {
        let all = b;
        let magic = take(&mut b, 8)?;
        if magic == MAGIC {
            if b.len() < 4 {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "truncated dump (missing checksum)",
                ));
            }
            let (payload, stored) = b.split_at(b.len() - 4);
            let stored = u32::from_le_bytes(stored.try_into().unwrap());
            let computed = crate::util::crc::crc32(&all[..all.len() - 4]);
            if stored != computed {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("dump checksum mismatch: stored {stored:#010x}, computed {computed:#010x}"),
                ));
            }
            b = payload;
        } else if magic != LEGACY_MAGIC {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "bad magic",
            ));
        }
        let enc = match take(&mut b, 1)?[0] {
            0 => Encoding::Base256,
            1 => Encoding::Lossless128,
            x => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad encoding tag {x}"),
                ))
            }
        };
        let word = match take(&mut b, 1)?[0] {
            0 => WordType::U64,
            1 => WordType::F64,
            x => {
                return Err(std::io::Error::new(
                    std::io::ErrorKind::InvalidData,
                    format!("bad word tag {x}"),
                ))
            }
        };
        let n = take_u32(&mut b)? as usize;
        let h = take_u32(&mut b)? as usize;
        let w = take_u32(&mut b)? as usize;
        let c = take_u32(&mut b)? as usize;
        let num_classes = take_u32(&mut b)? as usize;
        let nu = take_u32(&mut b)? as usize;
        let mut words_u64 = Vec::with_capacity(nu);
        for _ in 0..nu {
            words_u64.push(u64::from_le_bytes(take(&mut b, 8)?.try_into().unwrap()));
        }
        let nf = take_u32(&mut b)? as usize;
        let mut words_f64 = Vec::with_capacity(nf);
        for _ in 0..nf {
            words_f64.push(f64::from_le_bytes(take(&mut b, 8)?.try_into().unwrap()));
        }
        let no = take_u32(&mut b)? as usize;
        let offsets = take(&mut b, no)?.to_vec();
        let nl = take_u32(&mut b)? as usize;
        let mut labels = Vec::with_capacity(nl);
        for _ in 0..nl {
            labels.push(f32::from_le_bytes(take(&mut b, 4)?.try_into().unwrap()));
        }
        Ok(EncodedBatch {
            spec_encoding: enc,
            spec_word: word,
            n,
            h,
            w,
            c,
            words_u64,
            words_f64,
            offsets,
            labels,
            num_classes,
        })
    }

    /// Write a batch to `path`.
    pub fn write(path: &Path, e: &EncodedBatch) -> std::io::Result<()> {
        std::fs::File::create(path)?.write_all(&to_bytes(e))
    }

    /// Read a batch from `path`.
    pub fn read(path: &Path) -> std::io::Result<EncodedBatch> {
        let mut buf = Vec::new();
        std::fs::File::open(path)?.read_to_end(&mut buf)?;
        from_bytes(&buf)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::augment::AugPolicy;
    use crate::data::encode::{decode_batch, Encoding, WordType};
    use crate::data::synth::{Split, SynthCifar};

    fn setup(
        batches: usize,
        spec: Option<EncodeSpec>,
        mode: LoaderMode,
    ) -> EdLoader {
        let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 200, 7));
        let sampler = SbsSampler::uniform(d.as_ref(), 16, AugPolicy::none(), 1).unwrap();
        EdLoader::new(d, sampler, spec, batches, mode)
    }

    fn par(depth: usize, workers: usize) -> LoaderMode {
        LoaderMode::Parallel { prefetch_depth: depth, num_workers: workers }
    }

    #[test]
    fn sync_loader_yields_exact_count() {
        let mut l = setup(5, None, LoaderMode::Synchronous);
        let mut n = 0;
        while let Some(b) = l.next() {
            assert_eq!(b.len(), 16);
            n += 1;
        }
        assert_eq!(n, 5);
    }

    #[test]
    fn parallel_loader_yields_exact_count_for_any_worker_count() {
        for workers in [0, 1, 2, 4] {
            let mut l = setup(7, None, par(2, workers));
            let mut n = 0;
            while let Some(b) = l.next() {
                assert_eq!(b.len(), 16, "workers={workers}");
                n += 1;
            }
            assert_eq!(n, 7, "workers={workers}");
        }
    }

    #[test]
    fn parallel_and_sync_agree_given_same_seed() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        for workers in [0, 1, 3] {
            let mut a = setup(3, spec, LoaderMode::Synchronous);
            let mut b = setup(3, spec, par(4, workers));
            loop {
                match (a.next(), b.next()) {
                    (None, None) => break,
                    (Some(BatchPayload::Encoded(x)), Some(BatchPayload::Encoded(y))) => {
                        assert_eq!(x.len(), y.len(), "workers={workers}");
                        for (gx, gy) in x.iter().zip(&y) {
                            assert_eq!(gx.words_u64, gy.words_u64, "workers={workers}");
                            assert_eq!(gx.labels, gy.labels, "workers={workers}");
                        }
                    }
                    other => panic!("mismatch (workers={workers}): {other:?}"),
                }
            }
        }
    }

    #[test]
    fn worker_pool_preserves_step_order() {
        // With more workers than prefetch depth, out-of-order completion is
        // likely; the sequencer must still emit the sync sequence.
        let spec = Some(EncodeSpec::new(Encoding::Lossless128, WordType::U64));
        let mut reference = setup(12, spec, par(1, 0));
        let mut pooled = setup(12, spec, par(1, 4));
        let mut step = 0;
        loop {
            match (reference.next(), pooled.next()) {
                (None, None) => break,
                (Some(BatchPayload::Encoded(x)), Some(BatchPayload::Encoded(y))) => {
                    for (gx, gy) in x.iter().zip(&y) {
                        assert_eq!(gx.words_u64, gy.words_u64, "step {step}");
                        assert_eq!(gx.offsets, gy.offsets, "step {step}");
                    }
                }
                other => panic!("step {step}: {other:?}"),
            }
            step += 1;
        }
    }

    #[test]
    fn encoded_payload_decodes_to_valid_images() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        match l.next().unwrap() {
            BatchPayload::Encoded(groups) => {
                assert_eq!(groups.iter().map(|g| g.n).sum::<usize>(), 16);
                for g in &groups {
                    let img = decode_batch(g);
                    assert_eq!(img.h, 32);
                    // labels are soft distributions
                    for i in 0..img.n {
                        let s: f32 = img.label(i).iter().sum();
                        assert!((s - 1.0).abs() < 1e-5);
                    }
                }
            }
            other => panic!("expected encoded, got {other:?}"),
        }
    }

    #[test]
    fn payload_bytes_encoded_smaller_than_raw() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut raw = setup(1, None, LoaderMode::Synchronous);
        let mut enc = setup(1, spec, LoaderMode::Synchronous);
        let rb = raw.next().unwrap().payload_bytes();
        let eb = enc.next().unwrap().payload_bytes();
        assert!(eb * 3 < rb, "encoded {eb} raw {rb}"); // 4× expected
    }

    #[test]
    fn stats_accumulate_per_worker() {
        let mut l = setup(8, None, par(1, 2));
        let stats = l.stats();
        while l.next().is_some() {}
        drop(l); // join producers so the post-send counter updates land
        assert_eq!(stats.batches.load(Ordering::Relaxed), 8);
        assert!(stats.produce_ns.load(Ordering::Relaxed) > 0);
        let per_worker = stats.worker_summaries();
        assert_eq!(per_worker.len(), 2);
        assert_eq!(per_worker.iter().map(|w| w.batches).sum::<u64>(), 8);
        assert!(stats.seq_max_depth.load(Ordering::Relaxed) >= 1);
    }

    #[test]
    fn worker_scratch_stays_inside_the_per_worker_slab() {
        // Every producer stages its label rows in a per-worker arena; the
        // slab is sized exactly for them, so no worker may ever fall back
        // to the heap for scratch.
        let mut l = setup(8, None, par(2, 3));
        let stats = l.stats();
        while l.next().is_some() {}
        drop(l);
        let per_worker = stats.worker_summaries();
        assert_eq!(per_worker.len(), 3);
        for (i, w) in per_worker.iter().enumerate() {
            assert_eq!(w.scratch_fallbacks, 0, "worker {i} fell back to the heap");
        }
    }

    #[test]
    fn legacy_single_producer_reports_one_worker() {
        let mut l = setup(4, None, par(1, 0));
        let stats = l.stats();
        while l.next().is_some() {}
        drop(l);
        assert_eq!(stats.batches.load(Ordering::Relaxed), 4);
        let per_worker = stats.worker_summaries();
        assert_eq!(per_worker.len(), 1);
        assert_eq!(per_worker[0].batches, 4);
    }

    #[test]
    fn recycling_makes_steady_state_allocation_free() {
        // Sync mode is deterministic: the first batch warms the pool and the
        // second settles LIFO size mismatches (a short group's label buffer
        // can be popped for a full group and regrown once); from then on
        // every batch must be served entirely from recycled buffers.
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::F64));
        let mut l = setup(6, spec, LoaderMode::Synchronous);
        for _ in 0..2 {
            let p = l.next().unwrap();
            l.recycle(p);
        }
        let warm_allocs = l.pool().allocs();
        while let Some(p) = l.next() {
            l.recycle(p);
        }
        assert_eq!(l.pool().allocs(), warm_allocs, "steady state allocated");
        assert!(l.pool().reuses() > 0);
    }

    #[test]
    fn dropping_parallel_loader_midway_is_clean() {
        for workers in [0, 3] {
            let mut l = setup(100, None, par(2, workers));
            let _ = l.next();
            drop(l); // must not hang or panic
        }
    }

    #[test]
    fn dump_roundtrip() {
        let spec = Some(EncodeSpec::new(Encoding::Lossless128, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        if let Some(BatchPayload::Encoded(groups)) = l.next() {
            for g in &groups {
                let bytes = dump::to_bytes(g);
                let back = dump::from_bytes(&bytes).unwrap();
                assert_eq!(back.words_u64, g.words_u64);
                assert_eq!(back.offsets, g.offsets);
                assert_eq!(back.labels, g.labels);
                assert_eq!(decode_batch(&back), decode_batch(g));
            }
        } else {
            panic!("expected encoded payload");
        }
    }

    #[test]
    fn dump_rejects_corruption() {
        assert!(dump::from_bytes(b"short").is_err());
        assert!(dump::from_bytes(b"NOTMAGIC________________").is_err());
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        if let Some(BatchPayload::Encoded(groups)) = l.next() {
            let mut bytes = dump::to_bytes(&groups[0]);
            bytes.truncate(bytes.len() / 2);
            assert!(dump::from_bytes(&bytes).is_err());
        }
    }

    #[test]
    fn dump_detects_single_bit_flips() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        let Some(BatchPayload::Encoded(groups)) = l.next() else { panic!("expected encoded") };
        let bytes = dump::to_bytes(&groups[0]);
        for pos in [9, bytes.len() / 2, bytes.len() - 5] {
            let mut bad = bytes.clone();
            bad[pos] ^= 0x10;
            assert!(dump::from_bytes(&bad).is_err(), "flip at {pos} went undetected");
        }
    }

    #[test]
    fn dump_accepts_legacy_unchecksummed_format() {
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let mut l = setup(1, spec, LoaderMode::Synchronous);
        let Some(BatchPayload::Encoded(groups)) = l.next() else { panic!("expected encoded") };
        let bytes = dump::to_bytes(&groups[0]);
        // A legacy dump is the same payload with the old magic and no
        // trailing checksum.
        let mut legacy = bytes[..bytes.len() - 4].to_vec();
        legacy[..8].copy_from_slice(b"OPTORCH1");
        let back = dump::from_bytes(&legacy).unwrap();
        assert_eq!(back.words_u64, groups[0].words_u64);
        assert_eq!(back.labels, groups[0].labels);
    }

    // ---- fault injection & recovery ----

    fn setup_faults(
        batches: usize,
        mode: LoaderMode,
        faults: &str,
        watchdog: Option<Duration>,
    ) -> EdLoader {
        let d: Arc<dyn Dataset> = Arc::new(SynthCifar::cifar10(Split::Train, 200, 7));
        let sampler = SbsSampler::uniform(d.as_ref(), 16, AugPolicy::none(), 1).unwrap();
        let spec = Some(EncodeSpec::new(Encoding::Base256, WordType::U64));
        let parsed = crate::fault::FaultSpec::parse(faults).unwrap();
        let injector = (!parsed.is_empty()).then(|| Arc::new(FaultInjector::new(&parsed)));
        EdLoader::with_faults(
            d,
            sampler,
            spec,
            batches,
            mode,
            Arc::new(BufferPool::default()),
            injector,
            watchdog,
        )
    }

    /// Drain the loader, serializing every batch for byte-exact comparison.
    fn stream_bytes(l: &mut EdLoader) -> Vec<Vec<u8>> {
        let mut out = Vec::new();
        while let Some(p) = l.next() {
            match p {
                BatchPayload::Encoded(gs) => {
                    out.push(gs.iter().flat_map(dump::to_bytes).collect())
                }
                other => panic!("expected encoded payload, got {other:?}"),
            }
        }
        out
    }

    #[test]
    fn injected_worker_panic_respawns_without_changing_the_stream() {
        let mut healthy = setup_faults(10, par(2, 3), "", None);
        let reference = stream_bytes(&mut healthy);
        let mut faulted = setup_faults(10, par(2, 3), "worker-panic@4", None);
        let stats = faulted.stats();
        let stream = stream_bytes(&mut faulted);
        assert_eq!(stream.len(), reference.len());
        assert_eq!(stream, reference, "recovered stream diverged from the fault-free run");
        assert_eq!(stats.respawns.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn injected_corruption_is_detected_and_reencoded() {
        let mut healthy = setup_faults(6, par(2, 2), "", None);
        let reference = stream_bytes(&mut healthy);
        let mut faulted = setup_faults(6, par(2, 2), "corrupt@3", None);
        let stats = faulted.stats();
        let stream = stream_bytes(&mut faulted);
        assert_eq!(stream, reference, "re-encoded stream diverged from the fault-free run");
        assert_eq!(stats.corruptions_detected.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn respawn_budget_exhaustion_surfaces_a_typed_error() {
        // One panic event per allowed respawn plus one: the supervisor
        // gives up on step 0 and reports it typed instead of looping.
        let spec = vec!["worker-panic@0"; MAX_RESPAWNS as usize + 1].join(";");
        let mut l = setup_faults(6, par(1, 2), &spec, None);
        let stats = l.stats();
        match l.try_next() {
            Err(LoaderError::WorkerPanicked { step: 0, respawns }) => {
                assert_eq!(respawns, MAX_RESPAWNS);
            }
            other => panic!("expected worker-panicked error, got {other:?}"),
        }
        // The surviving workers still deliver every other step.
        let mut delivered = 0;
        while let Ok(Some(p)) = l.try_next() {
            delivered += 1;
            l.recycle(p);
        }
        assert_eq!(delivered, 5);
        assert_eq!(stats.respawns.load(Ordering::Relaxed), MAX_RESPAWNS);
    }

    #[test]
    fn single_producer_panic_fault_is_typed_not_silent() {
        let mut l = setup_faults(5, par(2, 0), "worker-panic@2", None);
        let mut seen = 0;
        loop {
            match l.try_next() {
                Ok(Some(p)) => {
                    seen += 1;
                    l.recycle(p);
                }
                Err(LoaderError::WorkerPanicked { step: 2, respawns: 0 }) => break,
                other => panic!("unexpected loader result: {other:?}"),
            }
        }
        assert_eq!(seen, 2, "steps before the fault must still arrive");
    }

    /// Dataset wrapper that sleeps on every fetch — drives the watchdog.
    struct SlowDataset {
        inner: SynthCifar,
        delay: Duration,
    }

    impl Dataset for SlowDataset {
        fn len(&self) -> usize {
            self.inner.len()
        }
        fn num_classes(&self) -> usize {
            self.inner.num_classes()
        }
        fn shape(&self) -> (usize, usize, usize) {
            self.inner.shape()
        }
        fn get(&self, index: usize) -> (crate::data::image::Image, usize) {
            std::thread::sleep(self.delay);
            self.inner.get(index)
        }
        fn get_into(&self, index: usize, out: &mut crate::data::image::Image) -> usize {
            std::thread::sleep(self.delay);
            self.inner.get_into(index, out)
        }
    }

    #[test]
    fn watchdog_names_the_stalled_stage() {
        let d: Arc<dyn Dataset> = Arc::new(SlowDataset {
            inner: SynthCifar::cifar10(Split::Train, 200, 7),
            delay: Duration::from_millis(25),
        });
        let sampler = SbsSampler::uniform(d.as_ref(), 16, AugPolicy::none(), 1).unwrap();
        let mut l = EdLoader::with_faults(
            d,
            sampler,
            None,
            4,
            par(2, 2),
            Arc::new(BufferPool::default()),
            None,
            Some(Duration::from_millis(50)),
        );
        match l.try_next() {
            Err(LoaderError::Stalled { stage, produced, .. }) => {
                assert!(stage.contains("planner"), "stage was {stage:?}");
                assert_eq!(produced, 0);
            }
            other => panic!("expected a stall, got {other:?}"),
        }
        // Dropping after the timeout must still shut the pool down cleanly.
    }

    #[test]
    fn dropping_with_workers_parked_on_a_full_gate_cannot_deadlock() {
        // depth 1 + many batches: the prefetch window fills and workers
        // park on the gate; dropping the loader without consuming must
        // wake them (cancel-then-wake ordering) rather than deadlock.
        let (done_tx, done_rx) = std::sync::mpsc::channel();
        std::thread::spawn(move || {
            let l = setup(100, None, par(1, 4));
            std::thread::sleep(Duration::from_millis(100));
            drop(l);
            let _ = done_tx.send(());
        });
        done_rx
            .recv_timeout(Duration::from_secs(30))
            .expect("loader drop deadlocked with a full gate");
    }

    #[test]
    fn loader_errors_name_the_failure() {
        let e = LoaderError::Stalled {
            stage: "sequencer/output channel".into(),
            waited: Duration::from_secs(5),
            produced: 3,
        };
        let msg = e.to_string();
        assert!(msg.contains("stalled"), "{msg}");
        assert!(msg.contains("sequencer"), "{msg}");
        let e = LoaderError::WorkerPanicked { step: 7, respawns: 8 };
        assert!(e.to_string().contains("step 7"), "{e}");
        let e = LoaderError::Encode { step: 1, reason: "capacity".into() };
        assert!(e.to_string().contains("encode failed"), "{e}");
    }
}
