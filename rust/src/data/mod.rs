//! Data-flow layer: datasets, augmentation, SBS sampling, batch encoding,
//! buffer recycling, and the multi-worker parallel encode–decode loader
//! (the paper's §II-A).

pub mod augment;
pub mod cifar;
pub mod dataset;
pub mod encode;
pub mod image;
pub mod loader;
pub mod pool;
pub mod sampler;
pub mod synth;
