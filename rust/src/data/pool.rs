//! Buffer recycling for the E-D hot path.
//!
//! Every batch the loader ships needs several heap buffers: the f32 pixel
//! payload (raw pipelines) or packed-word vectors + parity bitplanes +
//! label rows (encoded pipelines), plus the `Vec<EncodedBatch>` shell that
//! groups them. Allocating those per step is pure churn: sizes are
//! identical every batch. [`BufferPool`] keeps returned buffers and hands
//! them back out, so after a two-batch warmup (LIFO size mismatches from a
//! short tail group settle on the second batch) the sampler → augment →
//! encode chain performs **no pool-managed heap allocation** — verified by
//! the [`allocs`](BufferPool::allocs)/[`reuses`](BufferPool::reuses)
//! counters, which the trainer surfaces in [`TrainReport`] and the
//! `encode_throughput` bench records in `BENCH_encode.json`.
//!
//! The pool is shared by every producer (sync loader, the worker pool's N
//! encode workers, and the consumer returning spent payloads via
//! [`EdLoader::recycle`]), so buffers cycle: consumer → pool → worker →
//! consumer. All methods take `&self`; buckets are mutex-guarded (the lock
//! is held only for a `Vec::pop`/`push`, never across real work).
//!
//! [`TrainReport`]: crate::coordinator::TrainReport
//! [`EdLoader::recycle`]: crate::data::loader::EdLoader::recycle
//!
//! `take_*` returns an **empty** vector (len 0) whose capacity is warm when
//! a recycled buffer fits `capacity_hint`; callers size it themselves
//! (`resize`/`extend`), which keeps zeroing to exactly the buffers that
//! need it (packed words, parity planes) and off the ones that are fully
//! overwritten (pixels, labels).

use crate::data::encode::EncodedBatch;
use crate::data::loader::BatchPayload;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Per-bucket cap so a pathological consumer cannot grow the pool without
/// bound; beyond this, returned buffers are simply dropped.
const MAX_POOLED_PER_BUCKET: usize = 64;

/// Recycles the data-path buffers (see module docs).
#[derive(Debug, Default)]
pub struct BufferPool {
    u8s: Mutex<Vec<Vec<u8>>>,
    f32s: Mutex<Vec<Vec<f32>>>,
    u64s: Mutex<Vec<Vec<u64>>>,
    f64s: Mutex<Vec<Vec<f64>>>,
    shells: Mutex<Vec<Vec<EncodedBatch>>>,
    allocs: AtomicU64,
    reuses: AtomicU64,
}

macro_rules! pool_accessors {
    ($take:ident, $put:ident, $bucket:ident, $t:ty) => {
        /// Take an empty buffer; capacity is warm when a recycled buffer of
        /// at least `capacity_hint` was available (counted as a reuse),
        /// otherwise the (re)allocation is counted against the pool.
        pub fn $take(&self, capacity_hint: usize) -> Vec<$t> {
            let popped = self.$bucket.lock().unwrap().pop();
            match popped {
                Some(mut v) => {
                    if v.capacity() >= capacity_hint {
                        self.reuses.fetch_add(1, Ordering::Relaxed);
                    } else {
                        self.allocs.fetch_add(1, Ordering::Relaxed);
                        v.reserve(capacity_hint);
                    }
                    v.clear();
                    v
                }
                None => {
                    self.allocs.fetch_add(1, Ordering::Relaxed);
                    Vec::with_capacity(capacity_hint)
                }
            }
        }

        /// Return a buffer. Zero-capacity vectors are dropped (pooling them
        /// would hand out useless buffers); so are buffers beyond the
        /// per-bucket cap.
        pub fn $put(&self, v: Vec<$t>) {
            if v.capacity() == 0 {
                return;
            }
            let mut bucket = self.$bucket.lock().unwrap();
            if bucket.len() < MAX_POOLED_PER_BUCKET {
                bucket.push(v);
            }
        }
    };
}

impl BufferPool {
    pool_accessors!(take_u8, put_u8, u8s, u8);
    pool_accessors!(take_f32, put_f32, f32s, f32);
    pool_accessors!(take_u64, put_u64, u64s, u64);
    pool_accessors!(take_f64, put_f64, f64s, f64);

    /// Take an empty `Vec<EncodedBatch>` shell (groups of one payload).
    pub fn take_shells(&self) -> Vec<EncodedBatch> {
        let popped = self.shells.lock().unwrap().pop();
        match popped {
            Some(v) => {
                self.reuses.fetch_add(1, Ordering::Relaxed);
                debug_assert!(v.is_empty());
                v
            }
            None => {
                self.allocs.fetch_add(1, Ordering::Relaxed);
                Vec::new()
            }
        }
    }

    fn put_shells(&self, mut v: Vec<EncodedBatch>) {
        debug_assert!(v.is_empty());
        v.clear();
        let mut bucket = self.shells.lock().unwrap();
        if bucket.len() < MAX_POOLED_PER_BUCKET {
            bucket.push(v);
        }
    }

    /// Dismantle one encoded group back into the buckets.
    pub fn recycle_encoded(&self, e: EncodedBatch) {
        self.put_u64(e.words_u64);
        self.put_f64(e.words_f64);
        self.put_u8(e.offsets);
        self.put_f32(e.labels);
    }

    /// Dismantle a spent loader payload back into the buckets. The trainer
    /// calls this (via [`EdLoader::recycle`]) after each step; skipping it
    /// is safe but reintroduces per-batch allocation.
    ///
    /// [`EdLoader::recycle`]: crate::data::loader::EdLoader::recycle
    pub fn recycle_payload(&self, payload: BatchPayload) {
        match payload {
            BatchPayload::Raw { data, labels, .. } => {
                self.put_f32(data);
                self.put_f32(labels);
            }
            BatchPayload::Encoded(mut groups) => {
                for e in groups.drain(..) {
                    self.recycle_encoded(e);
                }
                self.put_shells(groups);
            }
        }
    }

    /// Buffers created (or regrown) because the pool could not serve the
    /// request — the hot path's allocation count.
    pub fn allocs(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }

    /// Requests served from recycled buffers without allocating.
    pub fn reuses(&self) -> u64 {
        self.reuses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::image::ImageBatch;

    #[test]
    fn take_put_cycles_without_new_allocs() {
        let pool = BufferPool::default();
        let v = pool.take_u64(1024);
        assert_eq!(pool.allocs(), 1);
        assert!(v.capacity() >= 1024);
        pool.put_u64(v);
        let v2 = pool.take_u64(1024);
        assert_eq!(pool.allocs(), 1, "second take must reuse");
        assert_eq!(pool.reuses(), 1);
        assert!(v2.is_empty() && v2.capacity() >= 1024);
    }

    #[test]
    fn undersized_recycled_buffer_counts_as_alloc() {
        let pool = BufferPool::default();
        let v = pool.take_f32(8);
        pool.put_f32(v);
        let v = pool.take_f32(1 << 20); // forces a regrow
        assert!(v.capacity() >= 1 << 20);
        assert_eq!(pool.allocs(), 2);
    }

    #[test]
    fn zero_capacity_buffers_are_not_pooled() {
        let pool = BufferPool::default();
        pool.put_u8(Vec::new());
        let v = pool.take_u8(4);
        assert_eq!(pool.allocs(), 1, "empty vec must not have been pooled");
        assert!(v.capacity() >= 4);
    }

    #[test]
    fn bucket_cap_bounds_memory() {
        let pool = BufferPool::default();
        for _ in 0..(MAX_POOLED_PER_BUCKET + 10) {
            pool.put_u8(vec![0u8; 16]);
        }
        assert_eq!(pool.u8s.lock().unwrap().len(), MAX_POOLED_PER_BUCKET);
    }

    #[test]
    fn payload_recycling_dismantles_groups() {
        use crate::data::encode::{encode_batch, EncodeSpec, Encoding, WordType};
        let pool = BufferPool::default();
        let mut b = ImageBatch::zeros(4, 4, 4, 3, 10);
        b.data.iter_mut().enumerate().for_each(|(i, v)| *v = i as u8);
        let e = encode_batch(&b, EncodeSpec::new(Encoding::Lossless128, WordType::U64)).unwrap();
        pool.recycle_payload(BatchPayload::Encoded(vec![e]));
        // words, offsets and labels all came back
        assert!(!pool.u64s.lock().unwrap().is_empty());
        assert!(!pool.u8s.lock().unwrap().is_empty());
        assert!(!pool.f32s.lock().unwrap().is_empty());
    }
}
