//! Selective-batch-sampling (SBS) — the paper's Algorithm 2.
//!
//! Composes each batch from a *controlled* number of examples per class
//! (`round(weight[c] · batch_size)`), then applies that class's
//! augmentation policy to exactly those slots. A uniform-weight SBS with
//! the same policy everywhere degrades to a standard shuffled sampler,
//! which is the paper's baseline.
//!
//! ## Plan / materialize split (§Perf iteration 3)
//!
//! Batch production is factored into two phases so the loader's worker
//! pool can parallelize the heavy part without giving up determinism:
//!
//! * [`SbsSampler::plan_batch`] — *sequential, cheap*: advances the RNG
//!   and per-class pools exactly as the classic `next_batch` did and
//!   captures everything stochastic (drawn indices, partner indices, one
//!   pre-split RNG per slot) in a [`BatchPlan`].
//! * [`materialize_plan_into`] — *pure, heavy*: fetch + augment + write
//!   each slot, a function of only `(specs, dataset, plan)`. It can run on
//!   any thread, for any subset of outstanding plans, in any order, and
//!   always produces byte-identical batches.
//!
//! `next_batch` is now just `plan_batch` + `materialize_plan_into`, so
//! every worker count (including the synchronous path) yields the same
//! batch sequence for the same seed.

use crate::data::augment::AugPolicy;
use crate::data::dataset::Dataset;
use crate::data::image::{Image, ImageBatch};
use crate::memory::arena::ArenaAllocator;
use crate::util::rng::Rng;

/// Per-class sampling weight + augmentation policy.
#[derive(Clone, Debug)]
pub struct ClassSpec {
    pub weight: f64,
    pub policy: AugPolicy,
    /// Pair ops (MixUp/CutMix) draw their partner from the whole dataset
    /// instead of the same class — produces genuinely soft labels (the
    /// paper's "specific combination of classes").
    pub partner_from_any_class: bool,
}

impl ClassSpec {
    pub fn new(weight: f64, policy: AugPolicy) -> ClassSpec {
        ClassSpec { weight, policy, partner_from_any_class: false }
    }

    pub fn with_cross_class_partner(mut self) -> ClassSpec {
        self.partner_from_any_class = true;
        self
    }
}

/// Everything stochastic about one batch, captured by
/// [`SbsSampler::plan_batch`]: materialization is a pure function of
/// `(specs, dataset, plan)` and may run on any thread.
#[derive(Clone, Debug)]
pub struct BatchPlan {
    /// slot → destination index in the batch (the shuffle permutation).
    perm: Vec<usize>,
    /// One entry per slot, in class-block order.
    items: Vec<PlanItem>,
}

impl BatchPlan {
    /// Number of images this plan produces.
    pub fn len(&self) -> usize {
        self.items.len()
    }

    pub fn is_empty(&self) -> bool {
        self.items.is_empty()
    }
}

#[derive(Clone, Debug)]
struct PlanItem {
    class: usize,
    index: usize,
    partner: Option<usize>,
    /// Pre-split augmentation stream for this slot.
    rng: Rng,
}

/// Phase 2 (pure, heavy, thread-safe): fetch + augment + place every slot
/// of `plan` into `out`. `out` must already be sized `plan.len()` ×
/// dataset shape (use [`ImageBatch::reset`] on a pooled batch). Callable
/// concurrently from the loader's encode workers; identical inputs give
/// byte-identical batches regardless of thread or call order.
pub fn materialize_plan_into(
    specs: &[ClassSpec],
    dataset: &dyn Dataset,
    plan: &BatchPlan,
    out: &mut ImageBatch,
) {
    let k = out.num_classes;
    let mut label_row = vec![0.0f32; k];
    let mut prow = vec![0.0f32; k];
    let mut img = Image::zeros(0, 0, 0);
    let mut partner = Image::zeros(0, 0, 0);
    materialize_core(
        specs, dataset, plan, out, &mut label_row, &mut prow, &mut img, &mut partner,
    );
}

/// Per-worker staging scratch for [`materialize_plan_arena`]: the
/// label-row slab plus the two recycled [`Image`] buffers the slot loop
/// fetches into via [`Dataset::get_into`]. One per worker, reused across
/// every batch, so the materialize hot loop performs **zero** heap
/// allocations at steady state — the per-image `Image` that
/// [`Dataset::get`] used to return was the last heap traffic in the
/// worker hot loop.
#[derive(Debug)]
pub struct StageScratch {
    /// Label-row slab (two `num_classes`-wide f32 rows per batch).
    arena: ArenaAllocator,
    /// Slot image, fetched and augmented in place.
    img: Image,
    /// MixUp/CutMix partner image.
    partner: Image,
}

impl StageScratch {
    /// Scratch sized for `num_classes` label rows; image buffers warm up
    /// to the dataset's shape on first use and then stay put.
    pub fn new(num_classes: usize) -> StageScratch {
        StageScratch {
            arena: ArenaAllocator::new(2 * num_classes * 4),
            img: Image::zeros(0, 0, 0),
            partner: Image::zeros(0, 0, 0),
        }
    }

    /// Label-row requests the slab could not serve (see
    /// [`ArenaAllocator::fallback_allocs`]); 0 ⇒ the scratch path ran
    /// entirely in the per-worker slab.
    pub fn fallback_allocs(&self) -> u64 {
        self.arena.fallback_allocs()
    }
}

/// [`materialize_plan_into`] with every staging buffer drawn from one
/// per-worker [`StageScratch`]: label rows from its recycled slab, slot
/// and partner images via [`Dataset::get_into`] into its warm buffers. At
/// steady state the hot loop allocates nothing. An undersized slab falls
/// back to heap label rows — counted by [`StageScratch::fallback_allocs`],
/// surfaced per worker in `LoaderStats`.
pub fn materialize_plan_arena(
    specs: &[ClassSpec],
    dataset: &dyn Dataset,
    plan: &BatchPlan,
    out: &mut ImageBatch,
    scratch: &mut StageScratch,
) {
    let k = out.num_classes;
    let StageScratch { arena, img, partner } = scratch;
    arena.begin_step();
    match arena.alloc_f32(2 * k) {
        Some(handle) => {
            let rows = arena.f32_mut(&handle);
            let (label_row, prow) = rows.split_at_mut(k);
            materialize_core(specs, dataset, plan, out, label_row, prow, img, partner);
        }
        None => {
            let mut label_row = vec![0.0f32; k];
            let mut prow = vec![0.0f32; k];
            materialize_core(
                specs, dataset, plan, out, &mut label_row, &mut prow, img, partner,
            );
        }
    }
}

#[allow(clippy::too_many_arguments)]
fn materialize_core(
    specs: &[ClassSpec],
    dataset: &dyn Dataset,
    plan: &BatchPlan,
    out: &mut ImageBatch,
    label_row: &mut [f32],
    prow: &mut [f32],
    img: &mut Image,
    partner: &mut Image,
) {
    assert_eq!(out.n, plan.len(), "output batch not sized for the plan");
    for (slot, item) in plan.items.iter().enumerate() {
        let partner_label = item.partner.map(|p| dataset.get_into(p, partner));
        let label = dataset.get_into(item.index, img);
        debug_assert_eq!(label, item.class);
        label_row.fill(0.0);
        label_row[label] = 1.0;
        let mut rng = item.rng.clone();
        let policy = &specs[item.class].policy;
        if let Some(plabel) = partner_label {
            prow.fill(0.0);
            prow[plabel] = 1.0;
            policy.apply(img, label_row, Some((&*partner, &*prow)), &mut rng);
        } else {
            policy.apply(img, label_row, None, &mut rng);
        }
        let dst = plan.perm[slot];
        out.image_mut(dst).copy_from_slice(&img.data);
        out.label_mut(dst).copy_from_slice(label_row);
    }
}

/// Selective batch sampler.
#[derive(Debug)]
pub struct SbsSampler {
    pub batch_size: usize,
    specs: Vec<ClassSpec>,
    /// Per-class index pools; refilled (reshuffled) when exhausted.
    pools: Vec<Vec<usize>>,
    cursors: Vec<usize>,
    by_class: Vec<Vec<usize>>,
    rng: Rng,
}

/// Errors from sampler construction.
#[derive(Debug, PartialEq)]
pub enum SamplerError {
    WeightSumZero,
    WrongSpecCount { got: usize, want: usize },
    EmptyClass(usize),
    BatchTooSmall,
}

impl std::fmt::Display for SamplerError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SamplerError::WeightSumZero => write!(f, "class weights sum to zero"),
            SamplerError::WrongSpecCount { got, want } => {
                write!(f, "got {got} class specs, dataset has {want} classes")
            }
            SamplerError::EmptyClass(c) => {
                write!(f, "class {c} has weight > 0 but no examples")
            }
            SamplerError::BatchTooSmall => write!(f, "batch size must be ≥ 1"),
        }
    }
}

impl std::error::Error for SamplerError {}

impl SbsSampler {
    /// Uniform weights, one shared policy — the standard pipeline.
    pub fn uniform(
        dataset: &dyn Dataset,
        batch_size: usize,
        policy: AugPolicy,
        seed: u64,
    ) -> Result<SbsSampler, SamplerError> {
        let specs = (0..dataset.num_classes())
            .map(|_| ClassSpec::new(1.0, policy.clone()))
            .collect();
        Self::new(dataset, batch_size, specs, seed)
    }

    /// Fully-specified SBS.
    pub fn new(
        dataset: &dyn Dataset,
        batch_size: usize,
        specs: Vec<ClassSpec>,
        seed: u64,
    ) -> Result<SbsSampler, SamplerError> {
        if batch_size == 0 {
            return Err(SamplerError::BatchTooSmall);
        }
        if specs.len() != dataset.num_classes() {
            return Err(SamplerError::WrongSpecCount {
                got: specs.len(),
                want: dataset.num_classes(),
            });
        }
        let total: f64 = specs.iter().map(|s| s.weight.max(0.0)).sum();
        if total <= 0.0 {
            return Err(SamplerError::WeightSumZero);
        }
        let by_class = dataset.indices_by_class();
        for (c, spec) in specs.iter().enumerate() {
            if spec.weight > 0.0 && by_class[c].is_empty() {
                return Err(SamplerError::EmptyClass(c));
            }
        }
        let pools = by_class.clone();
        let cursors = vec![0; by_class.len()];
        Ok(SbsSampler {
            batch_size,
            specs,
            pools,
            cursors,
            by_class,
            rng: Rng::new(seed).split(0x5B5),
        })
    }

    /// Integer per-class counts for one batch: largest-remainder rounding of
    /// `weight[c]/Σweights · batch_size`, guaranteeing Σ counts == batch.
    pub fn class_counts(&self) -> Vec<usize> {
        let total: f64 = self.specs.iter().map(|s| s.weight.max(0.0)).sum();
        let exact: Vec<f64> = self
            .specs
            .iter()
            .map(|s| s.weight.max(0.0) / total * self.batch_size as f64)
            .collect();
        let mut counts: Vec<usize> = exact.iter().map(|&x| x.floor() as usize).collect();
        let mut assigned: usize = counts.iter().sum();
        // distribute remainders by largest fractional part (stable by class id)
        let mut order: Vec<usize> = (0..counts.len()).collect();
        order.sort_by(|&a, &b| {
            let fa = exact[a] - exact[a].floor();
            let fb = exact[b] - exact[b].floor();
            fb.partial_cmp(&fa).unwrap().then(a.cmp(&b))
        });
        let mut i = 0;
        while assigned < self.batch_size {
            let c = order[i % order.len()];
            if self.specs[c].weight > 0.0 {
                counts[c] += 1;
                assigned += 1;
            }
            i += 1;
        }
        counts
    }

    fn draw_index(&mut self, class: usize) -> usize {
        if self.cursors[class] >= self.pools[class].len() {
            // refill + reshuffle this class's pool
            self.pools[class] = self.by_class[class].clone();
            let mut r = self.rng.split(class as u64 ^ 0xF00D);
            r.shuffle(&mut self.pools[class]);
            // keep the stream moving so refills differ over time
            let salt = self.rng.next_u64();
            let mut r2 = Rng::new(salt);
            r2.shuffle(&mut self.pools[class]);
            self.cursors[class] = 0;
        }
        let idx = self.pools[class][self.cursors[class]];
        self.cursors[class] += 1;
        idx
    }

    /// Phase 1 (sequential, cheap): decide everything stochastic about the
    /// next batch — per-class counts, drawn indices, partner indices, the
    /// slot permutation and one pre-split RNG per slot — advancing this
    /// sampler's state exactly as `next_batch` does. The returned plan can
    /// be materialized on any thread (see [`materialize_plan_into`]).
    pub fn plan_batch(&mut self, dataset: &dyn Dataset) -> BatchPlan {
        let counts = self.class_counts();
        // Slot permutation up front so class blocks don't create ordered
        // batches; images land in their final position directly.
        let mut perm: Vec<usize> = (0..self.batch_size).collect();
        self.rng.shuffle(&mut perm);
        let mut items = Vec::with_capacity(self.batch_size);
        let mut slot = 0usize;
        for (class, &count) in counts.iter().enumerate() {
            for _ in 0..count {
                let index = self.draw_index(class);
                let partner = if self.specs[class].policy.needs_partner() {
                    // partner from the same class pool by default (keeps the
                    // SBS class ratio exact); cross-class when requested.
                    Some(if self.specs[class].partner_from_any_class {
                        let mut r = Rng::new(self.rng.next_u64());
                        r.gen_range(dataset.len())
                    } else {
                        self.draw_index(class)
                    })
                } else {
                    None
                };
                let rng = self.rng.split(slot as u64 ^ 0xA06);
                // advance parent stream so consecutive batches differ
                let _ = self.rng.next_u64();
                items.push(PlanItem { class, index, partner, rng });
                slot += 1;
            }
        }
        debug_assert_eq!(slot, self.batch_size);
        BatchPlan { perm, items }
    }

    /// Produce the next batch: select per-class counts, fetch, pre-process
    /// each class with its own policy (Algorithm 2's "pre-process & dump").
    pub fn next_batch(&mut self, dataset: &dyn Dataset) -> ImageBatch {
        let (h, w, c) = dataset.shape();
        let k = dataset.num_classes();
        let mut batch = ImageBatch::zeros(self.batch_size, h, w, c, k);
        let plan = self.plan_batch(dataset);
        materialize_plan_into(&self.specs, dataset, &plan, &mut batch);
        batch
    }

    /// `next_batch` into a caller-provided (pooled) batch — the hot-path
    /// form; `out` is [`ImageBatch::reset`] to the right geometry.
    pub fn next_batch_into(&mut self, dataset: &dyn Dataset, out: &mut ImageBatch) {
        let (h, w, c) = dataset.shape();
        out.reset(self.batch_size, h, w, c, dataset.num_classes());
        let plan = self.plan_batch(dataset);
        materialize_plan_into(&self.specs, dataset, &plan, out);
    }

    /// [`SbsSampler::next_batch_into`] with every staging buffer (label
    /// rows + fetch images) drawn from `scratch` (see
    /// [`materialize_plan_arena`]).
    pub fn next_batch_arena(
        &mut self,
        dataset: &dyn Dataset,
        out: &mut ImageBatch,
        scratch: &mut StageScratch,
    ) {
        let (h, w, c) = dataset.shape();
        out.reset(self.batch_size, h, w, c, dataset.num_classes());
        let plan = self.plan_batch(dataset);
        materialize_plan_arena(&self.specs, dataset, &plan, out, scratch);
    }

    /// The per-class specs (what [`materialize_plan_into`] needs); the
    /// loader clones these once per epoch for its workers.
    pub fn specs(&self) -> &[ClassSpec] {
        &self.specs
    }

    /// Number of batches in one nominal epoch over `dataset`.
    pub fn batches_per_epoch(&self, dataset: &dyn Dataset) -> usize {
        (dataset.len() + self.batch_size - 1) / self.batch_size
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::dataset::MemDataset;
    use crate::data::image::Image;

    fn dataset(per_class: usize, classes: usize) -> MemDataset {
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for c in 0..classes {
            for i in 0..per_class {
                let mut img = Image::zeros(4, 4, 3);
                img.data.fill((c * 16 + i) as u8);
                images.push(img);
                labels.push(c);
            }
        }
        MemDataset::new(images, labels, classes)
    }

    #[test]
    fn uniform_counts_sum_to_batch() {
        let d = dataset(20, 10);
        let s = SbsSampler::uniform(&d, 16, AugPolicy::none(), 1).unwrap();
        let counts = s.class_counts();
        assert_eq!(counts.iter().sum::<usize>(), 16);
        // 16/10 → all classes get 1, six get 2
        assert!(counts.iter().all(|&c| c == 1 || c == 2));
    }

    #[test]
    fn weighted_counts_respect_ratio() {
        let d = dataset(50, 4);
        let specs = vec![
            ClassSpec::new(0.5, AugPolicy::none()),
            ClassSpec::new(0.25, AugPolicy::none()),
            ClassSpec::new(0.25, AugPolicy::none()),
            ClassSpec::new(0.0, AugPolicy::none()),
        ];
        let s = SbsSampler::new(&d, 16, specs, 1).unwrap();
        assert_eq!(s.class_counts(), vec![8, 4, 4, 0]);
    }

    #[test]
    fn zero_weight_class_never_sampled() {
        let d = dataset(10, 3);
        let specs = vec![
            ClassSpec::new(1.0, AugPolicy::none()),
            ClassSpec::new(1.0, AugPolicy::none()),
            ClassSpec::new(0.0, AugPolicy::none()),
        ];
        let mut s = SbsSampler::new(&d, 8, specs, 2).unwrap();
        for _ in 0..5 {
            let b = s.next_batch(&d);
            for i in 0..b.n {
                assert_ne!(b.hard_label(i), 2);
            }
        }
    }

    #[test]
    fn arena_materialization_matches_heap_and_counts_fallbacks() {
        let d = dataset(30, 5);
        let policy = AugPolicy::parse("hflip,crop4").unwrap();
        let mut heap = SbsSampler::uniform(&d, 10, policy.clone(), 9).unwrap();
        let mut arena = SbsSampler::uniform(&d, 10, policy, 9).unwrap();
        // slab sized for the two k-wide label rows → zero fallbacks
        let mut scratch = StageScratch::new(5);
        let (h, w, c) = d.shape();
        let mut a = ImageBatch::zeros(10, h, w, c, 5);
        let mut b = ImageBatch::zeros(10, h, w, c, 5);
        for _ in 0..4 {
            heap.next_batch_into(&d, &mut a);
            arena.next_batch_arena(&d, &mut b, &mut scratch);
            assert_eq!(a.data, b.data, "pixel bytes must be identical");
            assert_eq!(a.labels, b.labels, "labels must be identical");
        }
        assert_eq!(scratch.fallback_allocs(), 0, "sized slab must serve every step");
        // an undersized slab falls back to heap label rows, byte-identically
        let mut tiny = StageScratch { arena: ArenaAllocator::new(0), ..StageScratch::new(5) };
        heap.next_batch_into(&d, &mut a);
        arena.next_batch_arena(&d, &mut b, &mut tiny);
        assert_eq!(a.data, b.data);
        assert_eq!(a.labels, b.labels);
        assert_eq!(tiny.fallback_allocs(), 1);
    }

    #[test]
    fn batch_composition_matches_counts() {
        let d = dataset(30, 5);
        let mut s = SbsSampler::uniform(&d, 20, AugPolicy::none(), 3).unwrap();
        let b = s.next_batch(&d);
        let mut per_class = vec![0usize; 5];
        for i in 0..b.n {
            per_class[b.hard_label(i)] += 1;
        }
        assert_eq!(per_class, vec![4, 4, 4, 4, 4]);
    }

    #[test]
    fn epoch_covers_distinct_examples_before_repeat() {
        // With batch = per_class·classes, one batch should touch each class's
        // pool without repeats until the pool refills.
        let d = dataset(8, 2);
        let mut s = SbsSampler::uniform(&d, 8, AugPolicy::none(), 4).unwrap();
        let b1 = s.next_batch(&d);
        let b2 = s.next_batch(&d);
        // each batch has 4 from each class; 8 per class total → the two
        // batches together must cover all 16 images exactly once
        let mut seen = std::collections::HashSet::new();
        for b in [&b1, &b2] {
            for i in 0..b.n {
                seen.insert(b.image(i).to_vec());
            }
        }
        assert_eq!(seen.len(), 16);
    }

    #[test]
    fn construction_errors() {
        let d = dataset(5, 2);
        assert_eq!(
            SbsSampler::uniform(&d, 0, AugPolicy::none(), 1).unwrap_err(),
            SamplerError::BatchTooSmall
        );
        let wrong = vec![ClassSpec::new(1.0, AugPolicy::none())];
        assert!(matches!(
            SbsSampler::new(&d, 4, wrong, 1).unwrap_err(),
            SamplerError::WrongSpecCount { .. }
        ));
        let zeros = vec![
            ClassSpec::new(0.0, AugPolicy::none()),
            ClassSpec::new(0.0, AugPolicy::none()),
        ];
        assert_eq!(
            SbsSampler::new(&d, 4, zeros, 1).unwrap_err(),
            SamplerError::WeightSumZero
        );
    }

    #[test]
    fn per_class_policies_apply_only_to_their_class() {
        // Class 0 gets cutout (guaranteed zero pixels on a 255-filled
        // dataset); class 1 gets none.
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for c in 0..2 {
            for _ in 0..10 {
                let mut img = Image::zeros(8, 8, 1);
                img.data.fill(255);
                images.push(img);
                labels.push(c);
            }
        }
        let d = MemDataset::new(images, labels, 2);
        let specs = vec![
            ClassSpec::new(1.0, AugPolicy::parse("cutout6").unwrap()),
            ClassSpec::new(1.0, AugPolicy::none()),
        ];
        let mut s = SbsSampler::new(&d, 8, specs, 5).unwrap();
        let b = s.next_batch(&d);
        for i in 0..b.n {
            let zeros = b.image(i).iter().filter(|&&v| v == 0).count();
            if b.hard_label(i) == 0 {
                assert!(zeros > 0, "class-0 slot missing cutout");
            } else {
                assert_eq!(zeros, 0, "class-1 slot unexpectedly augmented");
            }
        }
    }

    #[test]
    fn mixup_policy_produces_soft_labels_within_class() {
        let d = dataset(20, 2);
        let specs = vec![
            ClassSpec::new(1.0, AugPolicy::parse("mixup1.0").unwrap()),
            ClassSpec::new(1.0, AugPolicy::none()),
        ];
        let mut s = SbsSampler::new(&d, 8, specs, 6).unwrap();
        let b = s.next_batch(&d);
        for i in 0..b.n {
            let sum: f32 = b.label(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-5);
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let d = dataset(16, 4);
        let mut a = SbsSampler::uniform(&d, 8, AugPolicy::standard(), 9).unwrap();
        let mut b = SbsSampler::uniform(&d, 8, AugPolicy::standard(), 9).unwrap();
        for _ in 0..3 {
            let ba = a.next_batch(&d);
            let bb = b.next_batch(&d);
            assert_eq!(ba.data, bb.data);
            assert_eq!(ba.labels, bb.labels);
        }
    }

    #[test]
    fn plan_then_materialize_equals_next_batch() {
        let d = dataset(16, 4);
        let mut a = SbsSampler::uniform(&d, 8, AugPolicy::standard(), 11).unwrap();
        let mut b = SbsSampler::uniform(&d, 8, AugPolicy::standard(), 11).unwrap();
        for _ in 0..3 {
            let direct = a.next_batch(&d);
            let plan = b.plan_batch(&d);
            let mut via_plan = ImageBatch::zeros(8, 4, 4, 3, 4);
            materialize_plan_into(b.specs(), &d, &plan, &mut via_plan);
            assert_eq!(direct.data, via_plan.data);
            assert_eq!(direct.labels, via_plan.labels);
        }
    }

    #[test]
    fn materialize_is_repeatable_from_the_same_plan() {
        // The property the worker pool relies on: a plan can be realized
        // any number of times, on any thread, with identical bytes.
        let d = dataset(16, 4);
        let mut s = SbsSampler::uniform(&d, 8, AugPolicy::parse("hflip,crop4,cutout4").unwrap(), 5)
            .unwrap();
        let plan = s.plan_batch(&d);
        let mut x = ImageBatch::zeros(8, 4, 4, 3, 4);
        let mut y = ImageBatch::zeros(8, 4, 4, 3, 4);
        materialize_plan_into(s.specs(), &d, &plan, &mut x);
        materialize_plan_into(s.specs(), &d, &plan, &mut y);
        assert_eq!(x.data, y.data);
        assert_eq!(x.labels, y.labels);
    }

    #[test]
    fn next_batch_into_reuses_buffer() {
        let d = dataset(16, 4);
        let mut a = SbsSampler::uniform(&d, 8, AugPolicy::standard(), 13).unwrap();
        let mut b = SbsSampler::uniform(&d, 8, AugPolicy::standard(), 13).unwrap();
        let mut reused = ImageBatch::zeros(0, 0, 0, 0, 1);
        for _ in 0..3 {
            let fresh = a.next_batch(&d);
            b.next_batch_into(&d, &mut reused);
            assert_eq!(fresh.data, reused.data);
            assert_eq!(fresh.labels, reused.labels);
        }
    }

    #[test]
    fn batches_per_epoch_rounds_up() {
        let d = dataset(13, 2); // 26 examples
        let s = SbsSampler::uniform(&d, 8, AugPolicy::none(), 1).unwrap();
        assert_eq!(s.batches_per_epoch(&d), 4);
    }
}

#[cfg(test)]
mod cross_class_tests {
    use super::*;
    use crate::data::dataset::MemDataset;
    use crate::data::image::Image;

    #[test]
    fn cross_class_mixup_softens_labels() {
        let mut images = Vec::new();
        let mut labels = Vec::new();
        for c in 0..2 {
            for _ in 0..20 {
                let mut img = Image::zeros(4, 4, 1);
                img.data.fill(if c == 0 { 255 } else { 0 });
                images.push(img);
                labels.push(c);
            }
        }
        let d = MemDataset::new(images, labels, 2);
        let specs = vec![
            ClassSpec::new(1.0, AugPolicy::parse("mixup1.0").unwrap())
                .with_cross_class_partner(),
            ClassSpec::new(1.0, AugPolicy::none()),
        ];
        let mut s = SbsSampler::new(&d, 16, specs, 3).unwrap();
        let mut soft = 0;
        for _ in 0..4 {
            let b = s.next_batch(&d);
            for i in 0..b.n {
                if b.label(i).iter().filter(|&&v| v > 0.01 && v < 0.99).count() >= 2 {
                    soft += 1;
                }
            }
        }
        assert!(soft > 0, "cross-class mixup must produce soft labels");
    }
}
