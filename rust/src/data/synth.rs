//! Synthetic CIFAR — deterministic, learnable stand-in for CIFAR-10/100.
//!
//! The real datasets are not downloadable in this environment (DESIGN.md
//! §5); this generator produces 32×32×3 uint8 images whose class signal is
//! strong enough for a small CNN to learn quickly, while instance noise,
//! random phase and brightness keep the task non-trivial. Every image is a
//! pure function of `(seed, split, index)` — epochs, workers and reruns see
//! identical data.
//!
//! Class structure: each class owns an oriented sinusoidal grating
//! (angle/frequency derived from the class id), a 2-color palette, and a
//! radial mask flavour; instances perturb phase, brightness and pixel noise.

use crate::data::dataset::Dataset;
use crate::data::image::Image;
use crate::util::rng::Rng;

/// Split tag folded into the per-image seed.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Split {
    Train,
    Test,
}

/// Synthetic CIFAR-like dataset (32×32×3).
#[derive(Clone, Debug)]
pub struct SynthCifar {
    pub num_classes: usize,
    pub len: usize,
    pub split: Split,
    pub seed: u64,
    pub h: usize,
    pub w: usize,
}

impl SynthCifar {
    /// CIFAR-10-shaped: 10 classes.
    pub fn cifar10(split: Split, len: usize, seed: u64) -> SynthCifar {
        SynthCifar { num_classes: 10, len, split, seed, h: 32, w: 32 }
    }

    /// CIFAR-100-shaped: 100 classes.
    pub fn cifar100(split: Split, len: usize, seed: u64) -> SynthCifar {
        SynthCifar { num_classes: 100, len, split, seed, h: 32, w: 32 }
    }

    /// Arbitrary geometry (used by Fig-8-style 512×512 workloads).
    pub fn with_shape(mut self, h: usize, w: usize) -> SynthCifar {
        self.h = h;
        self.w = w;
        self
    }

    fn class_params(&self, class: usize) -> ClassParams {
        // Pure function of the class id: stable across the whole run.
        // Palettes come from a SHARED pool (class % 3): color alone cannot
        // identify a class — the model must read texture (angle/frequency)
        // and shape (radial flavour), which keeps accuracy meaningfully
        // below 100% for small models.
        let mut r = Rng::new(self.seed ^ 0x5EED_C1A5).split((class % 3) as u64);
        let angle = std::f64::consts::PI * ((class * 37) % 180) as f64 / 180.0;
        let freq = 0.10 + 0.05 * ((class % 5) as f64);
        let c0 = [r.gen_range(200) as u8 + 40, r.gen_range(200) as u8 + 40, r.gen_range(200) as u8];
        let c1 = [
            255 - c0[0],
            (c0[1] as i32 + 96).min(255) as u8,
            255 - c0[2].min(200),
        ];
        let radial = class % 3; // 0: none, 1: disc, 2: ring
        ClassParams { angle, freq, c0, c1, radial }
    }

    /// Generate image `index`. Label is `index % num_classes`, so every
    /// class is equally represented in both splits.
    pub fn generate(&self, index: usize) -> (Image, usize) {
        let mut img = Image::zeros(self.h, self.w, 3);
        let class = self.generate_into(index, &mut img);
        (img, class)
    }

    /// [`SynthCifar::generate`] into a caller-provided buffer (reshaped
    /// via [`Image::reset`]); every pixel is overwritten, and with a warm
    /// buffer nothing allocates. Byte-identical to `generate` for the
    /// same `(seed, split, index)`.
    pub fn generate_into(&self, index: usize, img: &mut Image) -> usize {
        let class = index % self.num_classes;
        let p = self.class_params(class);
        let split_tag = match self.split {
            Split::Train => 0x7121u64,
            Split::Test => 0x7e57u64,
        };
        let mut r = Rng::new(self.seed).split(split_tag).split(index as u64);
        let phase = r.f64() * std::f64::consts::TAU;
        let brightness = 0.6 + 0.8 * r.f64();
        // strong instance noise keeps the task non-trivial (tiny_cnn lands
        // around 85-95% after a few epochs instead of saturating instantly)
        let noise_amp = 48.0 + 48.0 * r.f64();
        let (cy, cx) = (
            self.h as f64 * (0.35 + 0.3 * r.f64()),
            self.w as f64 * (0.35 + 0.3 * r.f64()),
        );

        img.reset(self.h, self.w, 3);
        let (sin_a, cos_a) = p.angle.sin_cos();
        for y in 0..self.h {
            for x in 0..self.w {
                let u = x as f64 * cos_a + y as f64 * sin_a;
                let mut v = (std::f64::consts::TAU * p.freq * u + phase).sin();
                // Radial flavour distinguishes classes sharing orientation.
                // occasional occluder patch adds intra-class variance
                if p.radial != 0 {
                    let dy = y as f64 - cy;
                    let dx = x as f64 - cx;
                    let d = (dy * dy + dx * dx).sqrt() / self.w as f64;
                    let m = if p.radial == 1 {
                        (0.45 - d).clamp(0.0, 1.0) * 2.0
                    } else {
                        (1.0 - (d * 4.0 - 1.2).abs()).clamp(0.0, 1.0)
                    };
                    v = 0.6 * v + 0.8 * (m * 2.0 - 1.0);
                }
                let t = (v.clamp(-1.0, 1.0) + 1.0) * 0.5;
                for ch in 0..3 {
                    let base =
                        p.c0[ch] as f64 + t * (p.c1[ch] as f64 - p.c0[ch] as f64);
                    let noisy = base * brightness + noise_amp * (r.f64() - 0.5);
                    img.set(y, x, ch, noisy.clamp(0.0, 255.0) as u8);
                }
            }
        }
        class
    }
}

struct ClassParams {
    angle: f64,
    freq: f64,
    c0: [u8; 3],
    c1: [u8; 3],
    radial: usize,
}

impl Dataset for SynthCifar {
    fn len(&self) -> usize {
        self.len
    }

    fn num_classes(&self) -> usize {
        self.num_classes
    }

    fn shape(&self) -> (usize, usize, usize) {
        (self.h, self.w, 3)
    }

    fn get(&self, index: usize) -> (Image, usize) {
        assert!(index < self.len, "index {index} out of range {}", self.len);
        self.generate(index)
    }

    fn get_into(&self, index: usize, out: &mut Image) -> usize {
        assert!(index < self.len, "index {index} out of range {}", self.len);
        self.generate_into(index, out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_index() {
        let d = SynthCifar::cifar10(Split::Train, 100, 7);
        let (a, la) = d.get(13);
        let (b, lb) = d.get(13);
        assert_eq!(a, b);
        assert_eq!(la, lb);
    }

    #[test]
    fn get_into_is_byte_identical_to_get() {
        let d = SynthCifar::cifar10(Split::Train, 64, 7);
        let mut buf = Image::zeros(32, 32, 3);
        let cap = buf.data.capacity();
        for i in [0usize, 3, 13, 63] {
            let label = d.get_into(i, &mut buf);
            let (img, l) = d.get(i);
            assert_eq!(buf, img, "index {i}");
            assert_eq!(label, l, "index {i}");
            assert_eq!(buf.data.capacity(), cap, "buffer reallocated at {i}");
        }
    }

    #[test]
    fn labels_cycle_classes() {
        let d = SynthCifar::cifar10(Split::Train, 50, 7);
        for i in 0..50 {
            assert_eq!(d.get(i).1, i % 10);
        }
    }

    #[test]
    fn splits_differ() {
        let tr = SynthCifar::cifar10(Split::Train, 10, 7);
        let te = SynthCifar::cifar10(Split::Test, 10, 7);
        assert_ne!(tr.get(0).0, te.get(0).0);
    }

    #[test]
    fn seeds_differ() {
        let a = SynthCifar::cifar10(Split::Train, 10, 1);
        let b = SynthCifar::cifar10(Split::Train, 10, 2);
        assert_ne!(a.get(0).0, b.get(0).0);
    }

    #[test]
    fn instances_of_same_class_differ() {
        let d = SynthCifar::cifar10(Split::Train, 100, 7);
        let (a, _) = d.get(0);
        let (b, _) = d.get(10); // same class (0), different instance
        assert_ne!(a, b);
    }

    #[test]
    fn same_class_images_are_more_similar_than_cross_class() {
        // The class signal must dominate instance noise or nothing is learnable.
        let d = SynthCifar::cifar10(Split::Train, 1000, 7);
        let dist = |a: &Image, b: &Image| -> f64 {
            a.data
                .iter()
                .zip(&b.data)
                .map(|(&x, &y)| {
                    let d = x as f64 - y as f64;
                    d * d
                })
                .sum::<f64>()
                / a.data.len() as f64
        };
        let mut within = 0.0;
        let mut across = 0.0;
        let mut wn = 0;
        let mut an = 0;
        for k in 0..40 {
            let (a, _) = d.get(k);
            let (b, _) = d.get(k + 10 * 3); // same class, 3 instances later
            within += dist(&a, &b);
            wn += 1;
            let (c, _) = d.get(k + 1); // next class
            across += dist(&a, &c);
            an += 1;
        }
        // the hardened generator (shared palettes, heavy noise) narrows the
        // margin by design — the signal just has to exist
        assert!(
            within / wn as f64 * 1.05 < across / an as f64,
            "within {within} across {across}"
        );
    }

    #[test]
    fn custom_shape() {
        let d = SynthCifar::cifar10(Split::Train, 4, 7).with_shape(64, 48);
        let (img, _) = d.get(1);
        assert_eq!((img.h, img.w, img.c), (64, 48, 3));
    }

    #[test]
    fn cifar100_has_100_classes() {
        let d = SynthCifar::cifar100(Split::Train, 200, 7);
        assert_eq!(d.num_classes(), 100);
        assert_eq!(d.get(150).1, 50);
    }

    #[test]
    #[should_panic(expected = "out of range")]
    fn oob_panics() {
        let d = SynthCifar::cifar10(Split::Train, 10, 7);
        d.get(10);
    }
}
