//! Typed record of a graceful-degradation episode.
//!
//! When the planning facade is asked to absorb a fault — a budget that
//! shrank mid-run, a host link that stopped cooperating — it walks a
//! fixed ladder of cheaper-memory fallbacks (documented on
//! [`PlanRequest::run_degraded`]) and reports every rung it took here, so
//! the trainer's report and the CLI can say exactly *how* the run kept
//! going and at what predicted cost.
//!
//! [`PlanRequest::run_degraded`]: crate::memory::pipeline::PlanRequest::run_degraded

use crate::util::json::{arr, n, obj, s, Json};
use std::fmt;

/// What forced the re-plan.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DegradeTrigger {
    /// The device budget shrank (e.g. a co-tenant claimed memory).
    BudgetShrink { from: Option<u64>, to: u64 },
    /// The host link degraded past the retry budget.
    LinkFailure { retries_exhausted: u64 },
    /// Sustained serving overload: the shed rate over the sample window
    /// exceeded the configured threshold.
    Overload { shed_rate: f64, window: usize },
}

impl fmt::Display for DegradeTrigger {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DegradeTrigger::BudgetShrink { from: Some(from), to } => {
                write!(f, "budget shrink {from} → {to} bytes")
            }
            DegradeTrigger::BudgetShrink { from: None, to } => {
                write!(f, "budget shrink → {to} bytes")
            }
            DegradeTrigger::LinkFailure { retries_exhausted } => {
                write!(f, "host link failure ({retries_exhausted} retries exhausted)")
            }
            DegradeTrigger::Overload { shed_rate, window } => {
                write!(
                    f,
                    "sustained overload ({:.0}% shed over {window}-request window)",
                    shed_rate * 100.0
                )
            }
        }
    }
}

/// One rung of the degradation ladder, in the order it was taken.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum DegradationAction {
    /// Re-planned at a cheaper-memory Pareto-frontier point.
    SteppedDownFrontier { device_total: u64, recompute_overhead: f64 },
    /// Shrank the spill prefetch lookahead (fewer resident buffers).
    ShrunkLookahead { from: usize, to: usize },
    /// Halved the serving micro-batcher's maximum batch size (smaller
    /// cached forward plans, lower per-dispatch latency).
    ReducedMaxBatch { from: usize, to: usize },
    /// Gave up on the budget: cheapest-memory plan, heap-backed arena.
    HeapFallbackArena,
}

impl DegradationAction {
    /// Stable kebab-case tag for this rung — shared by the JSON renderer,
    /// the trainer's counter registry (`degrade_rung_<kind>`) and the
    /// `/metrics` exposition, so every surface names rungs identically.
    pub fn kind(&self) -> &'static str {
        match self {
            DegradationAction::SteppedDownFrontier { .. } => "stepped-down-frontier",
            DegradationAction::ShrunkLookahead { .. } => "shrunk-lookahead",
            DegradationAction::ReducedMaxBatch { .. } => "reduced-max-batch",
            DegradationAction::HeapFallbackArena => "heap-fallback-arena",
        }
    }
}

impl fmt::Display for DegradationAction {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            DegradationAction::SteppedDownFrontier { device_total, recompute_overhead } => {
                write!(
                    f,
                    "stepped down the frontier (device total {device_total} B, \
                     recompute overhead {recompute_overhead:.3})"
                )
            }
            DegradationAction::ShrunkLookahead { from, to } => {
                write!(f, "shrank spill lookahead {from} → {to}")
            }
            DegradationAction::ReducedMaxBatch { from, to } => {
                write!(f, "reduced max batch {from} → {to}")
            }
            DegradationAction::HeapFallbackArena => {
                write!(f, "heap-fallback arena (budget abandoned)")
            }
        }
    }
}

/// The full episode: what triggered it, which rungs were taken, and where
/// the plan landed.
#[derive(Clone, Debug, PartialEq)]
pub struct DegradationReport {
    pub trigger: DegradeTrigger,
    pub actions: Vec<DegradationAction>,
    /// True when the final plan fits the (possibly shrunk) budget.
    pub met_budget: bool,
    /// The budget the ladder was solving for.
    pub budget: u64,
    /// Device-resident total of the chosen plan.
    pub device_total: u64,
    /// Predicted step time of the chosen plan, when a spill schedule was
    /// simulated.
    pub predicted_step_secs: Option<f64>,
}

impl DegradationReport {
    /// Stable JSON (same builder conventions as `PlanOutcome::to_json`).
    pub fn to_json(&self) -> Json {
        let trigger = match self.trigger {
            DegradeTrigger::BudgetShrink { from, to } => {
                let mut fields = vec![("kind", s("budget-shrink")), ("to", n(to as f64))];
                if let Some(from) = from {
                    fields.push(("from", n(from as f64)));
                }
                obj(fields)
            }
            DegradeTrigger::LinkFailure { retries_exhausted } => obj(vec![
                ("kind", s("link-failure")),
                ("retries_exhausted", n(retries_exhausted as f64)),
            ]),
            DegradeTrigger::Overload { shed_rate, window } => obj(vec![
                ("kind", s("overload")),
                ("shed_rate", n(shed_rate)),
                ("window", n(window as f64)),
            ]),
        };
        let actions = arr(
            self.actions
                .iter()
                .map(|a| {
                    let mut fields = vec![("kind", s(a.kind()))];
                    match a {
                        DegradationAction::SteppedDownFrontier {
                            device_total,
                            recompute_overhead,
                        } => {
                            fields.push(("device_total", n(*device_total as f64)));
                            fields.push(("recompute_overhead", n(*recompute_overhead)));
                        }
                        DegradationAction::ShrunkLookahead { from, to } => {
                            fields.push(("from", n(*from as f64)));
                            fields.push(("to", n(*to as f64)));
                        }
                        DegradationAction::ReducedMaxBatch { from, to } => {
                            fields.push(("from", n(*from as f64)));
                            fields.push(("to", n(*to as f64)));
                        }
                        DegradationAction::HeapFallbackArena => {}
                    }
                    obj(fields)
                })
                .collect(),
        );
        let mut fields = vec![
            ("trigger", trigger),
            ("actions", actions),
            ("met_budget", Json::Bool(self.met_budget)),
            ("budget", n(self.budget as f64)),
            ("device_total", n(self.device_total as f64)),
        ];
        if let Some(p) = self.predicted_step_secs {
            fields.push(("predicted_step_secs", n(p)));
        }
        obj(fields)
    }

    /// One-paragraph markdown summary for the train report.
    pub fn to_markdown(&self) -> String {
        let mut out = format!("degradation: {} → ", self.trigger);
        if self.actions.is_empty() {
            out.push_str("re-planned without stepping down");
        } else {
            let rungs: Vec<String> = self.actions.iter().map(|a| a.to_string()).collect();
            out.push_str(&rungs.join("; "));
        }
        out.push_str(&format!(
            " ({} budget {} B, device total {} B",
            if self.met_budget { "met" } else { "MISSED" },
            self.budget,
            self.device_total
        ));
        if let Some(p) = self.predicted_step_secs {
            out.push_str(&format!(", predicted step {:.3} ms", p * 1e3));
        }
        out.push(')');
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> DegradationReport {
        DegradationReport {
            trigger: DegradeTrigger::BudgetShrink { from: Some(8 << 20), to: 4 << 20 },
            actions: vec![
                DegradationAction::SteppedDownFrontier {
                    device_total: 3 << 20,
                    recompute_overhead: 0.21,
                },
                DegradationAction::ShrunkLookahead { from: 2, to: 1 },
            ],
            met_budget: true,
            budget: 4 << 20,
            device_total: 3 << 20,
            predicted_step_secs: Some(0.0123),
        }
    }

    #[test]
    fn json_has_trigger_actions_and_outcome() {
        let j = sample().to_json();
        assert_eq!(j.get("trigger").unwrap().get("kind").unwrap().as_str().unwrap(), "budget-shrink");
        let actions = j.get("actions").unwrap().as_arr().unwrap();
        assert_eq!(actions.len(), 2);
        assert_eq!(
            actions[0].get("kind").unwrap().as_str().unwrap(),
            "stepped-down-frontier"
        );
        assert_eq!(j.get("met_budget").unwrap().as_bool().unwrap(), true);
        // stable rendering + reparse
        let text = j.to_string();
        assert_eq!(text, sample().to_json().to_string());
        crate::util::json::Json::parse(&text).unwrap();
    }

    #[test]
    fn markdown_names_every_rung() {
        let md = sample().to_markdown();
        assert!(md.contains("budget shrink"), "{md}");
        assert!(md.contains("stepped down the frontier"), "{md}");
        assert!(md.contains("shrank spill lookahead 2 → 1"), "{md}");
        assert!(md.contains("met budget"), "{md}");
    }

    #[test]
    fn overload_rung_renders_and_serializes() {
        let r = DegradationReport {
            trigger: DegradeTrigger::Overload { shed_rate: 0.42, window: 64 },
            actions: vec![DegradationAction::ReducedMaxBatch { from: 32, to: 16 }],
            met_budget: true,
            budget: 0,
            device_total: 1 << 20,
            predicted_step_secs: None,
        };
        let md = r.to_markdown();
        assert!(md.contains("sustained overload"), "{md}");
        assert!(md.contains("reduced max batch 32 → 16"), "{md}");
        let j = r.to_json();
        assert_eq!(
            j.get("trigger").unwrap().get("kind").unwrap().as_str().unwrap(),
            "overload"
        );
        let a = &j.get("actions").unwrap().as_arr().unwrap()[0];
        assert_eq!(a.get("kind").unwrap().as_str().unwrap(), "reduced-max-batch");
        assert_eq!(a.get("from").unwrap().as_f64().unwrap(), 32.0);
        crate::util::json::Json::parse(&j.to_string()).unwrap();
    }

    #[test]
    fn heap_fallback_renders_as_missed() {
        let r = DegradationReport {
            trigger: DegradeTrigger::LinkFailure { retries_exhausted: 3 },
            actions: vec![DegradationAction::HeapFallbackArena],
            met_budget: false,
            budget: 1 << 20,
            device_total: 5 << 20,
            predicted_step_secs: None,
        };
        let md = r.to_markdown();
        assert!(md.contains("MISSED"), "{md}");
        assert!(md.contains("heap-fallback arena"), "{md}");
        assert_eq!(
            r.to_json().get("trigger").unwrap().get("kind").unwrap().as_str().unwrap(),
            "link-failure"
        );
    }
}
