//! Deterministic fault injector shared across the pipeline's threads.
//!
//! Two delivery mechanisms, both timing-independent so a faulted run is
//! exactly reproducible no matter how worker threads interleave:
//!
//! * **fire-once step events** (worker panic, payload corruption, budget
//!   shrink) key on the *batch/step index* — whichever thread holds that
//!   step triggers the event, and an atomic swap guarantees the respawned
//!   worker re-producing the requeued plan does not re-trigger it;
//! * **probabilistic link faults** are a *stateless* hash draw over
//!   `(seed, step, slot, attempt)` — no shared RNG stream, so the outcome
//!   of a given transfer attempt is a pure function of its coordinates.

use super::spec::{FaultEvent, FaultSpec};
use std::sync::atomic::{AtomicBool, Ordering};

/// Outcome of one host-link transfer attempt under the injected link model.
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum LinkOutcome {
    /// Transfer proceeds at full bandwidth.
    Healthy,
    /// Transfer completes, slowed by the given factor (≥ 1).
    Slow(f64),
    /// Transfer fails; the caller should retry.
    Fail,
}

#[inline]
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Stateless uniform draw in `[0, 1)` from mixed coordinates.
fn unit_draw(seed: u64, label: u64, step: u64, slot: u64, attempt: u64) -> f64 {
    let mut s = seed
        ^ label.wrapping_mul(0xD2B7_4407_B1CE_6E93)
        ^ step.wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ slot.rotate_left(21).wrapping_mul(0xBF58_476D_1CE4_E5B9)
        ^ attempt.rotate_left(42);
    let z = splitmix64(&mut s);
    (z >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
}

/// Thread-shareable injector built from a [`FaultSpec`]. Cheap to probe:
/// the hot-path queries are a linear scan over the (tiny) event list.
#[derive(Debug)]
pub struct FaultInjector {
    seed: u64,
    events: Vec<FaultEvent>,
    /// Parallel to `events`; set when a fire-once event has triggered.
    fired: Vec<AtomicBool>,
}

impl FaultInjector {
    pub fn new(spec: &FaultSpec) -> FaultInjector {
        FaultInjector {
            seed: spec.seed,
            fired: spec.events.iter().map(|_| AtomicBool::new(false)).collect(),
            events: spec.events.clone(),
        }
    }

    /// Atomically claim the first unfired event matching `pick`.
    fn fire_once<T>(&self, pick: impl Fn(&FaultEvent) -> Option<T>) -> Option<T> {
        for (i, e) in self.events.iter().enumerate() {
            if let Some(v) = pick(e) {
                if !self.fired[i].swap(true, Ordering::AcqRel) {
                    return Some(v);
                }
            }
        }
        None
    }

    /// Should the worker holding `step`'s plan panic now? Fires once.
    pub fn worker_panic_due(&self, step: usize) -> bool {
        self.fire_once(|e| match e {
            FaultEvent::WorkerPanic { step: s } if *s == step => Some(()),
            _ => None,
        })
        .is_some()
    }

    /// Should `step`'s encoded payload be corrupted? Fires once.
    pub fn corrupt_due(&self, step: usize) -> bool {
        self.fire_once(|e| match e {
            FaultEvent::CorruptPayload { step: s } if *s == step => Some(()),
            _ => None,
        })
        .is_some()
    }

    /// New device budget if a shrink is scheduled at `step`. Fires once.
    pub fn budget_shrink_due(&self, step: usize) -> Option<u64> {
        self.fire_once(|e| match e {
            FaultEvent::BudgetShrink { step: s, bytes } if *s == step => Some(*bytes),
            _ => None,
        })
    }

    /// True when the spec carries any probabilistic link fault.
    pub fn has_link_faults(&self) -> bool {
        self.events
            .iter()
            .any(|e| matches!(e, FaultEvent::LinkFail { .. } | FaultEvent::LinkSlow { .. }))
    }

    /// Configured link failure probability (0 when absent).
    pub fn link_fail_prob(&self) -> f64 {
        self.events
            .iter()
            .find_map(|e| match e {
                FaultEvent::LinkFail { prob } => Some(*prob),
                _ => None,
            })
            .unwrap_or(0.0)
    }

    /// Configured link slowdown `(prob, factor)` (`(0, 1)` when absent).
    pub fn link_slow(&self) -> (f64, f64) {
        self.events
            .iter()
            .find_map(|e| match e {
                FaultEvent::LinkSlow { prob, factor } => Some((*prob, *factor)),
                _ => None,
            })
            .unwrap_or((0.0, 1.0))
    }

    /// The injector's seed (forwarded into stateless link draws).
    pub fn seed(&self) -> u64 {
        self.seed
    }

    /// Deterministic outcome for transfer `attempt` of `(step, slot)`.
    /// A pure function of its arguments and the spec — thread timing
    /// cannot change it. Failure takes precedence over slowdown.
    pub fn link_outcome(&self, step: u64, slot: u64, attempt: u64) -> LinkOutcome {
        let fail_p = self.link_fail_prob();
        if fail_p > 0.0 && unit_draw(self.seed, 0xFA11, step, slot, attempt) < fail_p {
            return LinkOutcome::Fail;
        }
        let (slow_p, factor) = self.link_slow();
        if slow_p > 0.0 && unit_draw(self.seed, 0x510E, step, slot, attempt) < slow_p {
            return LinkOutcome::Slow(factor);
        }
        LinkOutcome::Healthy
    }
}

/// Stateless link draw for callers that hold a spec's parameters but not
/// an injector (the offload engine keeps only the numbers it needs).
pub fn link_draw(
    seed: u64,
    fail_prob: f64,
    slow: (f64, f64),
    step: u64,
    slot: u64,
    attempt: u64,
) -> LinkOutcome {
    if fail_prob > 0.0 && unit_draw(seed, 0xFA11, step, slot, attempt) < fail_prob {
        return LinkOutcome::Fail;
    }
    let (slow_p, factor) = slow;
    if slow_p > 0.0 && unit_draw(seed, 0x510E, step, slot, attempt) < slow_p {
        return LinkOutcome::Slow(factor);
    }
    LinkOutcome::Healthy
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(text: &str) -> FaultSpec {
        FaultSpec::parse(text).unwrap()
    }

    #[test]
    fn step_events_fire_exactly_once() {
        let inj = FaultInjector::new(&spec("worker-panic@3;corrupt@3;budget-shrink@3=1MiB"));
        assert!(!inj.worker_panic_due(2));
        assert!(inj.worker_panic_due(3));
        assert!(!inj.worker_panic_due(3), "must not re-fire for the requeued plan");
        assert!(inj.corrupt_due(3));
        assert!(!inj.corrupt_due(3));
        assert_eq!(inj.budget_shrink_due(3), Some(1 << 20));
        assert_eq!(inj.budget_shrink_due(3), None);
    }

    #[test]
    fn duplicate_events_fire_independently() {
        let inj = FaultInjector::new(&spec("corrupt@1;corrupt@1"));
        assert!(inj.corrupt_due(1));
        assert!(inj.corrupt_due(1));
        assert!(!inj.corrupt_due(1));
    }

    #[test]
    fn link_outcomes_are_pure_functions_of_coordinates() {
        let a = FaultInjector::new(&spec("seed=9;link-fail:0.3;link-slow:0.3,x4"));
        let b = FaultInjector::new(&spec("seed=9;link-fail:0.3;link-slow:0.3,x4"));
        for step in 0..16u64 {
            for slot in 0..4u64 {
                for attempt in 0..3u64 {
                    assert_eq!(
                        a.link_outcome(step, slot, attempt),
                        b.link_outcome(step, slot, attempt)
                    );
                }
            }
        }
    }

    #[test]
    fn link_fail_rate_tracks_probability() {
        let inj = FaultInjector::new(&spec("seed=1;link-fail:0.25"));
        let n = 10_000u64;
        let fails = (0..n)
            .filter(|&s| inj.link_outcome(s, 0, 0) == LinkOutcome::Fail)
            .count();
        let rate = fails as f64 / n as f64;
        assert!((rate - 0.25).abs() < 0.02, "rate {rate}");
    }

    #[test]
    fn healthy_spec_never_faults() {
        let inj = FaultInjector::new(&FaultSpec::default());
        assert!(!inj.has_link_faults());
        assert_eq!(inj.link_outcome(0, 0, 0), LinkOutcome::Healthy);
        assert!(!inj.worker_panic_due(0));
        assert_eq!(inj.budget_shrink_due(0), None);
    }

    #[test]
    fn retries_see_fresh_draws() {
        // with p = 0.5 some (step, slot) must fail on attempt 0 yet pass
        // on attempt 1 — the retry path depends on it
        let inj = FaultInjector::new(&spec("seed=3;link-fail:0.5"));
        let recovered = (0..256u64).any(|s| {
            inj.link_outcome(s, 0, 0) == LinkOutcome::Fail
                && inj.link_outcome(s, 0, 1) == LinkOutcome::Healthy
        });
        assert!(recovered);
    }
}
