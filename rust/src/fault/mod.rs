//! Seeded, deterministic fault injection and the graceful-degradation
//! vocabulary the rest of the pipeline speaks.
//!
//! * [`spec`] — the `--faults` grammar ([`FaultSpec`]): worker panics,
//!   payload corruption, budget shrinks, probabilistic link faults.
//! * [`injector`] — [`FaultInjector`], the thread-shareable trigger:
//!   fire-once step events plus stateless per-transfer link draws, both
//!   independent of thread timing so faulted runs replay exactly.
//! * [`degrade`] — [`DegradationReport`]: the typed record of which rungs
//!   of the degradation ladder a re-plan took and where it landed.
//!
//! The recovery machinery itself lives with the components it protects:
//! worker respawn in `data::loader`, transfer retries in
//! `memory::offload`, and the ladder in `memory::pipeline::PlanRequest::run_degraded`.

pub mod degrade;
pub mod injector;
pub mod spec;

pub use degrade::{DegradationAction, DegradationReport, DegradeTrigger};
pub use injector::{link_draw, FaultInjector, LinkOutcome};
pub use spec::{FaultEvent, FaultSpec};
