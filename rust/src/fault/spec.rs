//! Fault specification: a small, `;`-separated grammar describing the
//! deterministic fault schedule a run should inject.
//!
//! ```text
//! worker-panic@K          kill the encode worker holding batch K's plan
//! corrupt@K               flip bits in batch K's encoded payload
//! budget-shrink@K=BYTES   shrink the device budget to BYTES before step K
//! link-fail:P             each host transfer fails with probability P
//! link-slow:P,xF          each host transfer slows by F× with probability P
//! seed=N                  seed for the probabilistic link draws (default 0)
//! ```
//!
//! `BYTES` accepts the same suffixes as every other byte knob
//! (`512MiB`, `1GiB`, …). Parsing round-trips through [`Display`], so a
//! spec can be logged and replayed verbatim.

use std::fmt;

/// One injected fault event.
#[derive(Clone, Debug, PartialEq)]
pub enum FaultEvent {
    /// Panic the worker that dequeued batch `step`'s plan (fires once).
    WorkerPanic { step: usize },
    /// Corrupt batch `step`'s encoded payload after encode (fires once).
    CorruptPayload { step: usize },
    /// Shrink the device budget to `bytes` before global step `step`.
    BudgetShrink { step: usize, bytes: u64 },
    /// Every host-link transfer attempt fails with probability `prob`.
    LinkFail { prob: f64 },
    /// Every host-link transfer slows by `factor`× with probability `prob`.
    LinkSlow { prob: f64, factor: f64 },
}

impl fmt::Display for FaultEvent {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            FaultEvent::WorkerPanic { step } => write!(f, "worker-panic@{step}"),
            FaultEvent::CorruptPayload { step } => write!(f, "corrupt@{step}"),
            FaultEvent::BudgetShrink { step, bytes } => {
                write!(f, "budget-shrink@{step}={bytes}")
            }
            FaultEvent::LinkFail { prob } => write!(f, "link-fail:{prob}"),
            FaultEvent::LinkSlow { prob, factor } => {
                write!(f, "link-slow:{prob},x{factor}")
            }
        }
    }
}

/// A parsed fault schedule: the events plus the seed that makes the
/// probabilistic ones (link faults) reproducible.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct FaultSpec {
    pub seed: u64,
    pub events: Vec<FaultEvent>,
}

fn parse_prob(what: &str, s: &str) -> Result<f64, String> {
    let p: f64 = s
        .parse()
        .map_err(|_| format!("{what}: probability `{s}` is not a number"))?;
    if !(0.0..=1.0).contains(&p) {
        return Err(format!("{what}: probability {p} outside [0, 1]"));
    }
    Ok(p)
}

fn parse_step(what: &str, s: &str) -> Result<usize, String> {
    s.parse()
        .map_err(|_| format!("{what}: step `{s}` is not an integer"))
}

impl FaultSpec {
    /// Parse the `;`-separated grammar described in the module docs.
    pub fn parse(text: &str) -> Result<FaultSpec, String> {
        let mut spec = FaultSpec::default();
        for part in text.split(';') {
            let part = part.trim();
            if part.is_empty() {
                continue;
            }
            if let Some(rest) = part.strip_prefix("worker-panic@") {
                let step = parse_step("worker-panic", rest)?;
                spec.events.push(FaultEvent::WorkerPanic { step });
            } else if let Some(rest) = part.strip_prefix("corrupt@") {
                let step = parse_step("corrupt", rest)?;
                spec.events.push(FaultEvent::CorruptPayload { step });
            } else if let Some(rest) = part.strip_prefix("budget-shrink@") {
                let (step, bytes) = rest
                    .split_once('=')
                    .ok_or_else(|| format!("budget-shrink: `{rest}` needs `@K=BYTES`"))?;
                let step = parse_step("budget-shrink", step)?;
                let bytes = crate::config::parse_bytes(bytes)
                    .map_err(|e| format!("budget-shrink: {e}"))?;
                spec.events.push(FaultEvent::BudgetShrink { step, bytes });
            } else if let Some(rest) = part.strip_prefix("link-fail:") {
                let prob = parse_prob("link-fail", rest)?;
                spec.events.push(FaultEvent::LinkFail { prob });
            } else if let Some(rest) = part.strip_prefix("link-slow:") {
                let (prob, factor) = rest
                    .split_once(",x")
                    .ok_or_else(|| format!("link-slow: `{rest}` needs `P,xF`"))?;
                let prob = parse_prob("link-slow", prob)?;
                let factor: f64 = factor
                    .parse()
                    .map_err(|_| format!("link-slow: factor `{factor}` is not a number"))?;
                if factor < 1.0 {
                    return Err(format!("link-slow: factor {factor} must be ≥ 1"));
                }
                spec.events.push(FaultEvent::LinkSlow { prob, factor });
            } else if let Some(rest) = part.strip_prefix("seed=") {
                spec.seed = rest
                    .parse()
                    .map_err(|_| format!("seed: `{rest}` is not an integer"))?;
            } else {
                return Err(format!(
                    "unknown fault event `{part}` (expected worker-panic@K, corrupt@K, \
                     budget-shrink@K=BYTES, link-fail:P, link-slow:P,xF, or seed=N)"
                ));
            }
        }
        Ok(spec)
    }

    /// True when the spec injects nothing.
    pub fn is_empty(&self) -> bool {
        self.events.is_empty()
    }
}

impl fmt::Display for FaultSpec {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut first = true;
        if self.seed != 0 {
            write!(f, "seed={}", self.seed)?;
            first = false;
        }
        for e in &self.events {
            if !first {
                write!(f, ";")?;
            }
            write!(f, "{e}")?;
            first = false;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_event_kind() {
        let s = FaultSpec::parse(
            "worker-panic@3;corrupt@5;budget-shrink@8=4MiB;link-fail:0.2;link-slow:0.1,x4;seed=7",
        )
        .unwrap();
        assert_eq!(s.seed, 7);
        assert_eq!(
            s.events,
            vec![
                FaultEvent::WorkerPanic { step: 3 },
                FaultEvent::CorruptPayload { step: 5 },
                FaultEvent::BudgetShrink { step: 8, bytes: 4 << 20 },
                FaultEvent::LinkFail { prob: 0.2 },
                FaultEvent::LinkSlow { prob: 0.1, factor: 4.0 },
            ]
        );
    }

    #[test]
    fn display_roundtrips() {
        for text in [
            "worker-panic@3",
            "seed=7;corrupt@5",
            "budget-shrink@8=4194304",
            "link-fail:0.2;link-slow:0.1,x4",
            "",
        ] {
            let spec = FaultSpec::parse(text).unwrap();
            let back = FaultSpec::parse(&spec.to_string()).unwrap();
            assert_eq!(spec, back, "`{text}`");
        }
    }

    #[test]
    fn rejects_malformed_events() {
        for bad in [
            "explode@3",
            "worker-panic@x",
            "budget-shrink@3",
            "budget-shrink@3=chunky",
            "link-fail:1.5",
            "link-slow:0.2",
            "link-slow:0.2,x0.5",
            "seed=abc",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "`{bad}` should fail");
        }
    }

    #[test]
    fn empty_and_whitespace_specs_are_empty() {
        assert!(FaultSpec::parse("").unwrap().is_empty());
        assert!(FaultSpec::parse(" ; ;").unwrap().is_empty());
    }
}
