//! # OpTorch (reproduction)
//!
//! A Rust + JAX + Pallas reproduction of *"OpTorch: Optimized deep learning
//! architectures for resource limited environments"* (Ahmed & Naveed, 2021).
//!
//! OpTorch trains CNN image classifiers under tight memory budgets by
//! combining **data-flow** optimizations (packed batch encoding, a decoding
//! layer inside the network, selective-batch-sampling, a parallel
//! encode–decode loader) with **gradient-flow** optimizations (sequential
//! activation checkpoints and mixed-precision state).
//!
//! The crate is the **Layer-3 coordinator** of a three-layer stack:
//!
//! * Layer 1 — Pallas kernels (decode/encode/lossless/matmul) authored in
//!   `python/compile/kernels/`, lowered at build time.
//! * Layer 2 — JAX model zoo + train/eval/init steps in
//!   `python/compile/model.py`, AOT-lowered to `artifacts/*.hlo.txt`.
//! * Layer 3 — this crate: data pipeline, memory simulator, checkpoint
//!   planner, PJRT runtime and the training coordinator. Python never runs
//!   on the training path.
//!
//! ## Feature flags
//!
//! * `pjrt` (default **off**) — compiles the real PJRT runtime against the
//!   `xla` crate. Without it the crate builds a stub runtime
//!   ([`runtime`]): everything except artifact execution — the E-D
//!   producer pool, encoder, SBS sampler, memory simulator, planner and
//!   their tests — works in environments with no PJRT toolchain.
//!
//! ## The E-D producer pool
//!
//! The parallel encode–decode loader ([`data::loader`]) is a multi-worker
//! pipeline: a planner thread runs the sequential half of SBS sampling, N
//! workers materialize + encode batches concurrently, and a sequencer
//! restores step order. Buffers recycle through [`data::pool::BufferPool`]
//! so steady-state epochs allocate nothing on the hot path, and any worker
//! count reproduces the single-threaded batch stream bit-for-bit. Knobs:
//! `num_workers` / `prefetch_depth` on [`config::TrainConfig`].
//!
//! ## Memory hierarchy
//!
//! [`memory`] is a planning stack: the simulator/`PeakEvaluator` prove a
//! schedule's exact peak, the DP planner picks checkpoint placements (and
//! the full time/memory Pareto frontier), the arena packs a plan into a
//! concrete slab, and [`memory::offload`] spills the coldest checkpoints
//! to host memory — with a double-buffered prefetch schedule and a
//! predicted-stall model — when `memory_budget` sits below even the
//! packed slab. [`memory::joint`] replaces that plan-then-spill sequence
//! with one optimizer over keep / recompute / spill per tensor
//! (param-gradients included) that never predicts a slower step.
//!
//! **The primary planning surface is
//! [`PlanRequest`](memory::pipeline::PlanRequest)**: one typed builder
//! drives the whole plan → pack → spill composition and returns a staged
//! [`PlanOutcome`](memory::outcome::PlanOutcome) with unified accessors
//! and stable JSON/markdown renderers. The trainer, the `plan` CLI and
//! the memory benches all plan through it; the per-subsystem free
//! functions remain the documented low-level API.
//!
//! ## Quickstart
//!
//! ```no_run
//! use optorch::prelude::*;
//!
//! let cfg = TrainConfig::default_for("tiny_cnn", Pipeline::parse("ed+sc").unwrap());
//! let mut trainer = Trainer::from_config(&cfg).unwrap();
//! let report = trainer.run().unwrap();
//! println!("final accuracy {:.3}", report.final_eval_accuracy);
//! ```
//!
//! Planning without training — one request stages the whole memory
//! pipeline:
//!
//! ```no_run
//! use optorch::prelude::*;
//!
//! let outcome = PlanRequest::for_model("resnet18", (64, 64, 3), 10)
//!     .pipeline(Pipeline::parse("sc").unwrap())
//!     .batch(8)
//!     .memory_budget(512 * 1024 * 1024)
//!     .frontier(true)
//!     .run()
//!     .unwrap();
//! println!(
//!     "{} checkpoints, device bytes {}, spills: {}",
//!     outcome.plan.checkpoints.len(),
//!     outcome.device_peak_packed(),
//!     outcome.is_spill(),
//! );
//! println!("{}", outcome.to_json().to_string());
//! ```
//!
//! ## Inference serving
//!
//! [`serve`] is the forward-only twin of the trainer: a closed-loop
//! synthetic client fleet drives a dynamic micro-batcher whose every
//! dispatch resolves through a cached `PlanMode::Infer` plan — forward
//! lifetimes only, packed into a slab strictly smaller than the training
//! slab for the same arch/batch — with typed admission control (shed
//! reasons, overload → degradation ladder) and live `/metrics` gauges:
//!
//! ```no_run
//! use optorch::prelude::*;
//!
//! let cfg = ServeConfig::default_for("resnet18");
//! let hub = std::sync::Arc::new(MetricsHub::new());
//! let report = optorch::serve::run(&cfg, &hub).unwrap();
//! assert!(report.forward_slab_bytes < report.train_slab_bytes.unwrap());
//! println!("{}", report.to_markdown());
//! ```

pub mod cli;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod fault;
pub mod memory;
pub mod metrics;
pub mod models;
pub mod obs;
pub mod runtime;
pub mod serve;
pub mod trace;
pub mod util;

/// Convenience re-exports for downstream users.
pub mod prelude {
    pub use crate::config::{Pipeline, TrainConfig};
    pub use crate::coordinator::{Trainer, TrainReport};
    pub use crate::data::encode::{EncodeSpec, Encoding};
    pub use crate::data::loader::{EdLoader, LoaderMode};
    pub use crate::data::pool::BufferPool;
    pub use crate::data::sampler::SbsSampler;
    pub use crate::data::synth::SynthCifar;
    pub use crate::fault::{
        DegradationAction, DegradationReport, DegradeTrigger, FaultInjector, FaultSpec,
    };
    pub use crate::memory::arena::{plan_arena, ArenaAllocator, ArenaLayout, ArenaReport};
    pub use crate::memory::joint::{joint_spill_for_checkpoints, plan_joint};
    pub use crate::memory::offload::{
        plan_spill, select_for_budget, simulate_overlap, OffloadEngine, OffloadReport,
        OverlapModel, SpillClass, SpillPlan,
    };
    pub use crate::memory::outcome::PlanOutcome;
    pub use crate::memory::peak::PeakEvaluator;
    pub use crate::memory::pipeline::{parse_bytes_field, PlanError, PlanMode, PlanRequest};
    pub use crate::memory::planner::{
        pareto_frontier, plan_checkpoints, plan_for_budget, plan_for_budget_packed,
        CheckpointPlan, PlannerKind,
    };
    pub use crate::memory::simulator::{simulate, MemoryReport};
    pub use crate::models::{arch_by_name, ArchProfile};
    pub use crate::obs::{MemTimeline, MemWatermarkReport, MetricsHub, ObsServer, StepSample};
    pub use crate::runtime::Runtime;
    pub use crate::serve::{
        MicroBatcher, PlanCache, ServeConfig, ServeError, ServeReport, ShedReason,
    };
    pub use crate::trace::{CounterRegistry, DriftReport, ThreadTracer, TraceLog, Tracer};
}
