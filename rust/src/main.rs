//! `optorch` launcher — the Layer-3 entrypoint.
//!
//! See `optorch help` (or [`optorch::cli::USAGE`]) for the command set.

use anyhow::{anyhow, Result};
use optorch::cli::{Cli, USAGE};
use optorch::config::{parse_bytes, Pipeline, TrainConfig};
use optorch::coordinator::{report, Trainer};
use optorch::memory::arena::{plan_arena, summarize};
use optorch::memory::offload::{
    select_for_budget, OverlapModel, DEFAULT_DEVICE_FLOPS_PER_SEC, DEFAULT_HOST_BW_BYTES_PER_SEC,
};
use optorch::memory::planner::{
    pareto_frontier, plan_checkpoints, plan_for_budget_packed, PlannerKind,
    DEFAULT_FRONTIER_LEVELS,
};
use optorch::memory::simulator::simulate;
use optorch::models::{all_arch_names, arch_by_name};
use optorch::util::bench::{fmt_bytes, Table};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn main() {
    let cli = match Cli::from_env() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cli.subcommand.as_str() {
        "train" => cmd_train(&cli),
        "memsim" => cmd_memsim(&cli),
        "plan" => cmd_plan(&cli),
        "models" => cmd_models(),
        "figures" => cmd_figures(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let file_text = match cli.get("config") {
        Some(path) => Some(std::fs::read_to_string(path)?),
        None => None,
    };
    let mut overrides: BTreeMap<String, String> = cli.opts.clone();
    overrides.remove("config");
    overrides.remove("out_csv");
    overrides.remove("save_state");
    overrides.remove("load_state");
    let cfg = TrainConfig::from_sources(file_text.as_deref(), &overrides)
        .map_err(|e| anyhow!(e))?;
    println!(
        "training {} with pipeline {} ({} epochs, batch {})",
        cfg.model,
        cfg.pipeline.label(),
        cfg.epochs,
        cfg.batch_size
    );
    let mut trainer = Trainer::from_config(&cfg)?;
    if let Some(path) = cli.get("load_state") {
        trainer.load_state(std::path::Path::new(path))?;
        println!("resumed state from {path}");
    }
    let rep = trainer.run()?;
    if let Some(path) = cli.get("save_state") {
        trainer.save_state(std::path::Path::new(path))?;
        println!("state saved to {path}");
    }
    println!("{}", report::markdown_summary(&rep));
    if let Some(out) = cli.get("out_csv") {
        report::write_history_csv(&PathBuf::from(out), &rep)?;
        println!("history written to {out}");
    }
    Ok(())
}

fn cmd_memsim(cli: &Cli) -> Result<()> {
    let model = cli.get("model").unwrap_or("resnet18");
    let pipeline = Pipeline::parse(cli.get("pipeline").unwrap_or("b")).map_err(|e| anyhow!(e))?;
    let batch = cli.get_usize("batch").map_err(|e| anyhow!(e))?.unwrap_or(16);
    let h = cli.get_usize("height").map_err(|e| anyhow!(e))?.unwrap_or(512);
    let w = cli.get_usize("width").map_err(|e| anyhow!(e))?.unwrap_or(512);
    let classes = cli.get_usize("classes").map_err(|e| anyhow!(e))?.unwrap_or(1000);
    let arch = arch_by_name(model, (h, w, 3), classes)
        .ok_or_else(|| anyhow!("unknown model '{model}' (try `optorch models`)"))?;
    let ckpts = if pipeline.sc {
        plan_checkpoints(&arch, PlannerKind::Optimal, pipeline, batch).checkpoints
    } else {
        vec![]
    };
    let rep = simulate(&arch, pipeline, batch, &ckpts);
    println!(
        "{model} [{}] batch {batch} @{h}x{w}: peak {} (state {}, input {}, activations {})",
        pipeline.label(),
        fmt_bytes(rep.peak_bytes),
        fmt_bytes(rep.state_bytes),
        fmt_bytes(rep.input_bytes),
        fmt_bytes(rep.peak_activation_bytes),
    );
    if cli.has_flag("timeline") {
        print!("{}", report::timeline_csv(&rep));
    }
    Ok(())
}

fn cmd_plan(cli: &Cli) -> Result<()> {
    let model = cli.get("model").unwrap_or("resnet18");
    let batch = cli.get_usize("batch").map_err(|e| anyhow!(e))?.unwrap_or(16);
    let h = cli.get_usize("height").map_err(|e| anyhow!(e))?.unwrap_or(224);
    let arch = arch_by_name(model, (h, h, 3), 1000)
        .ok_or_else(|| anyhow!("unknown model '{model}'"))?;
    let kinds: Vec<PlannerKind> = match cli.get("kind") {
        Some(k) => vec![PlannerKind::parse(k).map_err(|e| anyhow!(e))?],
        None => vec![
            PlannerKind::Uniform(4),
            PlannerKind::Sqrt,
            PlannerKind::Bottleneck(4),
            PlannerKind::Optimal,
        ],
    };
    let mut table = Table::new(&["planner", "checkpoints", "peak", "recompute overhead"]);
    // The last kind in the table (the explicit --kind, or Optimal in the
    // default set) is the one --arena packs — no second planning pass.
    let mut arena_plan = None;
    for kind in kinds {
        let plan = plan_checkpoints(&arch, kind, Pipeline::BASELINE, batch);
        table.row(&[
            format!("{kind:?}"),
            format!("{:?}", plan.checkpoints),
            fmt_bytes(plan.peak_bytes),
            format!("{:.1}% of fwd FLOPs", plan.recompute_overhead * 100.0),
        ]);
        arena_plan = Some((kind, plan));
    }
    table.print();

    if cli.has_flag("arena") {
        let (kind, plan) = arena_plan.expect("at least one planner kind is always run");
        let (lifetimes, layout) = plan_arena(&arch, Pipeline::BASELINE, batch, &plan.checkpoints);
        let rep = summarize(&lifetimes, &layout);
        println!(
            "\nactivation arena ({model}, batch {batch}, {kind:?} plan): \
             slab {} + static {} = {} vs simulated peak {} — fragmentation {:.3}x, {} tensors",
            fmt_bytes(rep.slab_bytes),
            fmt_bytes(rep.base_bytes),
            fmt_bytes(layout.total_bytes()),
            fmt_bytes(rep.peak_bytes),
            rep.fragmentation,
            rep.tensor_count,
        );
        let mut t = Table::new(&["class", "tensors", "bytes", "first offsets"]);
        for c in &rep.by_class {
            let mut offs: Vec<u64> = lifetimes
                .tensors
                .iter()
                .enumerate()
                .filter(|(_, tl)| tl.class == c.class)
                .map(|(i, _)| layout.offsets[i])
                .collect();
            offs.sort_unstable();
            offs.dedup();
            let shown = offs
                .iter()
                .take(4)
                .map(|o| o.to_string())
                .collect::<Vec<_>>()
                .join(", ");
            let suffix = if offs.len() > 4 { ", …" } else { "" };
            t.row(&[
                c.class.name().to_string(),
                format!("{}", c.count),
                fmt_bytes(c.bytes),
                format!("{shown}{suffix}"),
            ]);
        }
        t.print();
    }

    let budget = match cli.get("budget") {
        Some(b) => Some(parse_bytes(b).map_err(|e| anyhow!("--budget: {e}"))?),
        None => None,
    };
    if budget.is_some() || cli.has_flag("frontier") {
        let frontier = pareto_frontier(&arch, Pipeline::BASELINE, batch, DEFAULT_FRONTIER_LEVELS);
        println!("\ntime/memory Pareto frontier ({} points):\n", frontier.len());
        report::frontier_table(&frontier).print();
        if let Some(b) = budget {
            // fit decision on *packed* totals (base + slab), so packing
            // fragmentation participates
            let (plan, _, layout) = plan_for_budget_packed(&arch, Pipeline::BASELINE, batch, b)
                .map_err(|e| anyhow!("{e} — try `plan --spill <budget>` for a host-spill plan"))?;
            println!(
                "\nbudget {}: cheapest-time plan fits at packed total {} (simulated peak {}) \
                 with {} checkpoints {:?} (+{:.1}% fwd FLOPs)",
                fmt_bytes(b),
                fmt_bytes(layout.total_bytes()),
                fmt_bytes(plan.peak_bytes),
                plan.checkpoints.len(),
                plan.checkpoints,
                plan.recompute_overhead * 100.0
            );
        }
    }

    if let Some(s) = cli.get("spill") {
        let budget = parse_bytes(s).map_err(|e| anyhow!("--spill: {e}"))?;
        cmd_plan_spill(cli, &arch, batch, budget)?;
    }
    Ok(())
}

/// `plan --spill <budget>`: compose the best host-spill plan for the
/// budget and print its per-tensor evict/prefetch table + predicted stall.
fn cmd_plan_spill(
    cli: &Cli,
    arch: &optorch::models::ArchProfile,
    batch: usize,
    budget: u64,
) -> Result<()> {
    let lookahead = cli.get_usize("lookahead").map_err(|e| anyhow!(e))?.unwrap_or(2).max(1);
    let host_bw = match cli.get("host_bw") {
        Some(v) => parse_bytes(v).map_err(|e| anyhow!("--host_bw: {e}"))?,
        None => DEFAULT_HOST_BW_BYTES_PER_SEC,
    };
    let model = OverlapModel {
        host_bw_bytes_per_sec: host_bw as f64,
        device_flops_per_sec: DEFAULT_DEVICE_FLOPS_PER_SEC,
    };
    let decision = select_for_budget(arch, Pipeline::BASELINE, batch, budget, lookahead, &model)
        .map_err(|e| anyhow!(e.to_string()))?;
    println!(
        "\nhost-spill plan for budget {} (bw {}/s, lookahead {lookahead}):",
        fmt_bytes(budget),
        fmt_bytes(host_bw)
    );
    println!(
        "  plan: {} checkpoints {:?} (+{:.1}% fwd FLOPs), device total {} = static {} + \
         resident slab {}",
        decision.plan.checkpoints.len(),
        decision.plan.checkpoints,
        decision.plan.recompute_overhead * 100.0,
        fmt_bytes(decision.spill.device_total()),
        fmt_bytes(decision.spill.layout.base_bytes),
        fmt_bytes(decision.spill.layout.slab_bytes),
    );
    if decision.is_spill() {
        let mut t = Table::new(&["layer", "bytes", "evict@", "prefetch@", "need@", "idle steps"]);
        for s in &decision.spill.steps {
            t.row(&[
                format!("{}", s.layer),
                fmt_bytes(s.bytes),
                format!("{}", s.evict_step),
                format!("{}", s.prefetch_step),
                format!("{}", s.need_step),
                format!("{}", s.gap_steps),
            ]);
        }
        t.print();
        println!(
            "  {} tensors spilled ({} out, host peak {}) — predicted stall {:.3} ms/step \
             ({:.1}% of {:.3} ms predicted step)",
            decision.spill.steps.len(),
            fmt_bytes(decision.spill.spilled_bytes),
            fmt_bytes(decision.spill.host_peak_bytes),
            decision.overlap.stall_secs * 1e3,
            decision.overlap.stall_frac() * 100.0,
            decision.overlap.predicted_step_secs * 1e3,
        );
    } else {
        println!(
            "  fits without spilling — predicted step {:.3} ms (no stall)",
            decision.overlap.predicted_step_secs * 1e3
        );
    }
    Ok(())
}

fn cmd_models() -> Result<()> {
    let mut table = Table::new(&["model", "input", "layers", "params", "fwd GFLOPs/img"]);
    for name in all_arch_names() {
        let input = if name.contains("inception_v3") {
            (299, 299, 3)
        } else if name.contains("mini") || name.contains("lite") || name == "tiny_cnn" {
            (32, 32, 3)
        } else {
            (224, 224, 3)
        };
        let classes = if input.0 == 32 { 10 } else { 1000 };
        let p = arch_by_name(&name, input, classes).unwrap();
        table.row(&[
            name.clone(),
            format!("{}x{}x{}", input.0, input.1, input.2),
            format!("{}", p.depth()),
            format!("{}", p.param_count()),
            format!("{:.2}", p.flops(1) as f64 / 1e9),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_figures() -> Result<()> {
    println!("regenerate figures with:");
    for b in [
        "fig8_memory_timeline",
        "fig9_time_accuracy",
        "fig10_memory_grid",
        "fig11_checkpoint_placement",
        "ed_overlap",
        "encode_throughput",
        "step_latency",
    ] {
        println!("  cargo bench --bench {b}");
    }
    Ok(())
}
