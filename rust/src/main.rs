//! `optorch` launcher — the Layer-3 entrypoint.
//!
//! See `optorch help` (or [`optorch::cli::USAGE`]) for the command set.
//! Every planning surface (`plan`, `memsim`'s S-C placement, the
//! trainer's budget composition) drives the memory stack through one
//! typed [`PlanRequest`] → [`PlanOutcome`] pipeline.

use anyhow::{anyhow, Result};
use optorch::cli::{Cli, USAGE};
use optorch::config::{Pipeline, TrainConfig};
use optorch::coordinator::{report, Trainer};
use optorch::fault::DegradeTrigger;
use optorch::memory::outcome::PlanOutcome;
use optorch::memory::pipeline::{parse_bytes_field, PlanError, PlanRequest};
use optorch::memory::simulator::simulate;
use optorch::models::{all_arch_names, arch_by_name};
use optorch::obs::MetricsHub;
use optorch::serve::ServeConfig;
use optorch::util::bench::{fmt_bytes, Table};
use std::collections::BTreeMap;
use std::path::PathBuf;

fn main() {
    let cli = match Cli::from_env() {
        Ok(c) => c,
        Err(e) => {
            eprintln!("error: {e}\n{USAGE}");
            std::process::exit(2);
        }
    };
    let result = match cli.subcommand.as_str() {
        "train" => cmd_train(&cli),
        "memsim" => cmd_memsim(&cli),
        "plan" => cmd_plan(&cli),
        "serve" => cmd_serve(&cli),
        "models" => cmd_models(),
        "figures" => cmd_figures(),
        "help" | "--help" | "-h" => {
            println!("{USAGE}");
            Ok(())
        }
        other => Err(anyhow!("unknown command '{other}'\n{USAGE}")),
    };
    if let Err(e) = result {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn cmd_train(cli: &Cli) -> Result<()> {
    let file_text = match cli.get("config") {
        Some(path) => Some(std::fs::read_to_string(path)?),
        None => None,
    };
    let mut overrides: BTreeMap<String, String> = cli.opts.clone();
    overrides.remove("config");
    overrides.remove("out_csv");
    overrides.remove("save_state");
    overrides.remove("load_state");
    let cfg = TrainConfig::from_sources(file_text.as_deref(), &overrides)
        .map_err(|e| anyhow!(e))?;
    println!(
        "training {} with pipeline {} ({} epochs, batch {})",
        cfg.model,
        cfg.pipeline.label(),
        cfg.epochs,
        cfg.batch_size
    );
    let mut trainer = Trainer::from_config(&cfg)?;
    if let Some(path) = cli.get("load_state") {
        trainer.load_state(std::path::Path::new(path))?;
        println!("resumed state from {path}");
    }
    let rep = trainer.run()?;
    if let Some(path) = cli.get("save_state") {
        trainer.save_state(std::path::Path::new(path))?;
        println!("state saved to {path}");
    }
    println!("{}", report::markdown_summary(&rep));
    if let Some(out) = cli.get("out_csv") {
        report::write_history_csv(&PathBuf::from(out), &rep)?;
        println!("history written to {out}");
    }
    Ok(())
}

fn cmd_memsim(cli: &Cli) -> Result<()> {
    let model = cli.get("model").unwrap_or("resnet18");
    let pipeline = Pipeline::parse(cli.get("pipeline").unwrap_or("b")).map_err(|e| anyhow!(e))?;
    let batch = cli.get_usize("batch").map_err(|e| anyhow!(e))?.unwrap_or(16);
    let h = cli.get_usize("height").map_err(|e| anyhow!(e))?.unwrap_or(512);
    let w = cli.get_usize("width").map_err(|e| anyhow!(e))?.unwrap_or(512);
    let classes = cli.get_usize("classes").map_err(|e| anyhow!(e))?.unwrap_or(1000);
    let arch = arch_by_name(model, (h, w, 3), classes)
        .ok_or_else(|| anyhow!("unknown model '{model}' (try `optorch models`)"))?;
    let ckpts = if pipeline.sc {
        // One facade drive for the placement; the simulation below uses
        // the pipeline exactly as given (memsim also models non-S-C).
        PlanRequest::for_arch(arch.clone())
            .pipeline(pipeline)
            .batch(batch)
            .arena(false)
            .run()
            .map_err(|e| anyhow!(e.to_string()))?
            .plan
            .checkpoints
    } else {
        vec![]
    };
    let rep = simulate(&arch, pipeline, batch, &ckpts);
    println!(
        "{model} [{}] batch {batch} @{h}x{w}: peak {} (state {}, input {}, activations {})",
        pipeline.label(),
        fmt_bytes(rep.peak_bytes),
        fmt_bytes(rep.state_bytes),
        fmt_bytes(rep.input_bytes),
        fmt_bytes(rep.peak_activation_bytes),
    );
    if cli.has_flag("timeline") {
        print!("{}", report::timeline_csv(&rep));
    }
    Ok(())
}

/// Attach the CLI's budget hint to a packed-infeasibility error.
fn plan_err(e: PlanError) -> anyhow::Error {
    match e {
        e @ PlanError::BudgetBelowPacked(_) => {
            anyhow!("{e} — try `plan --spill <budget>` for a host-spill plan")
        }
        e => anyhow!(e.to_string()),
    }
}

fn cmd_plan(cli: &Cli) -> Result<()> {
    let model = cli.get("model").unwrap_or("resnet18");
    let batch = cli.get_usize("batch").map_err(|e| anyhow!(e))?.unwrap_or(16);
    let h = cli.get_usize("height").map_err(|e| anyhow!(e))?.unwrap_or(224);
    let lookahead = cli.get_usize("lookahead").map_err(|e| anyhow!(e))?.unwrap_or(2).max(1);
    let want_arena = cli.has_flag("arena");
    let want_frontier = cli.has_flag("frontier") || cli.get("budget").is_some();

    // Every drive below derives from this scaffold; unknown models and
    // bad byte counts surface as the facade's typed errors.
    let mut base = PlanRequest::for_model(model, (h, h, 3), 1000)
        .batch(batch)
        .spill_lookahead(lookahead);
    if let Some(bw) = cli.get("host_bw") {
        base = base.host_bw_field("--host_bw", bw);
    }

    // Planner kinds for the comparison table; the last (the explicit
    // --kind, or Optimal in the default set) is the one --arena packs
    // and --json reports.
    let kind_specs: Vec<&str> = match cli.get("kind") {
        Some(k) => vec![k],
        None => vec!["uniform4", "sqrt", "bottleneck4", "dp"],
    };

    if let Some(path) = cli.get("drift") {
        // Predicted-vs-observed replay: read the `train --trace` export
        // back in and compare its observed `train-step` spans against the
        // step time the same planning flags predict today.
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("--drift: could not read {path}: {e}"))?;
        let doc = optorch::util::json::Json::parse(&text)
            .map_err(|e| anyhow!("--drift: {path} is not valid JSON: {e}"))?;
        let observed = optorch::trace::observed_span_histogram(&doc, "train-step");
        let mut req = base
            .clone()
            .planner_named(kind_specs.last().expect("kind set is never empty"));
        if let Some(v) = cli.get("spill") {
            req = req.memory_budget_field("--spill", v);
        } else if let Some(v) = cli.get("budget") {
            req = req.memory_budget_field("--budget", v);
        }
        let outcome = req.run().map_err(plan_err)?;
        let predicted = outcome.predicted_step_secs().ok_or_else(|| {
            anyhow!("--drift needs a cost-model prediction: add --spill or --budget BYTES")
        })?;
        let drift = optorch::trace::DriftReport::from_observed(predicted, &observed)
            .ok_or_else(|| anyhow!("--drift: no 'train-step' spans found in {path}"))?;
        if cli.has_flag("json") {
            println!("{}", drift.to_json().to_string());
        } else {
            println!("{}", drift.to_markdown_line());
        }
        return Ok(());
    }

    if let Some(path) = cli.get("memdrift") {
        // Memory twin of --drift: read a `train --memlog` CSV back in and
        // compare its observed high-water marks against the watermarks
        // the same planning flags predict today.
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow!("--memdrift: could not read {path}: {e}"))?;
        let observed = optorch::obs::MemlogObserved::parse_csv(&text)
            .map_err(|e| anyhow!("--memdrift: {e}"))?;
        let mut req = base
            .clone()
            .planner_named(kind_specs.last().expect("kind set is never empty"))
            .arena(true);
        if let Some(v) = cli.get("spill") {
            req = req.memory_budget_field("--spill", v);
        } else if let Some(v) = cli.get("budget") {
            req = req.memory_budget_field("--budget", v);
        }
        let outcome = req.run().map_err(plan_err)?;
        let timeline = optorch::obs::MemTimeline::from_outcome(&outcome).ok_or_else(|| {
            anyhow!("--memdrift: the plan staged no lifetimes to compare against")
        })?;
        let rep = observed
            .against(&timeline)
            .ok_or_else(|| anyhow!("--memdrift: no data rows in {path}"))?;
        if cli.has_flag("json") {
            println!("{}", rep.to_json().to_string());
        } else {
            println!("{}", rep.to_markdown_line());
        }
        return Ok(());
    }

    if cli.has_flag("degrade") {
        // Walk the graceful-degradation ladder instead of erroring on an
        // infeasible budget: cheaper frontier point → shrunk lookahead →
        // heap-fallback arena, with a typed episode of every rung taken.
        let v = cli
            .get("budget")
            .or_else(|| cli.get("spill"))
            .ok_or_else(|| anyhow!("--degrade needs a --budget (or --spill) to solve for"))?;
        let to = parse_bytes_field("--budget", v).map_err(|e| anyhow!(e.to_string()))?;
        let req = base
            .clone()
            .planner_named(kind_specs.last().expect("kind set is never empty"))
            .arena(true)
            .memory_budget(to);
        let (outcome, episode) = req
            .run_degraded(DegradeTrigger::BudgetShrink { from: None, to })
            .map_err(plan_err)?;
        if cli.has_flag("json") {
            let doc = optorch::util::json::obj(vec![
                ("outcome", outcome.to_json()),
                ("degradation", episode.to_json()),
            ]);
            println!("{}", doc.to_string());
        } else {
            print!("{}", outcome.to_markdown());
            println!("\n{}", episode.to_markdown());
        }
        return Ok(());
    }

    if cli.has_flag("compare") {
        // Sequential plan→spill vs the joint recompute/spill optimizer,
        // side by side, under the same budget (--spill wins over
        // --budget, matching the --json precedence).
        let (field, v) = match (cli.get("spill"), cli.get("budget")) {
            (Some(v), _) => ("--spill", v),
            (None, Some(v)) => ("--budget", v),
            (None, None) => {
                return Err(anyhow!("--compare needs a --spill (or --budget) to solve for"))
            }
        };
        let grad_spill = match cli.get("grad_spill") {
            None | Some("true") | Some("on") | Some("1") => true,
            Some("false") | Some("off") | Some("0") => false,
            Some(other) => {
                return Err(anyhow!("--grad_spill: expected true/false, got '{other}'"))
            }
        };
        // The joint side always runs `joint`; the sequential side runs
        // the explicit --kind, or the budgeted default (dp) when --kind
        // is absent or itself `joint`.
        let seq_spec = match cli.get("kind") {
            Some("joint") | None => "dp",
            Some(k) => k,
        };
        let budgeted = base.clone().memory_budget_field(field, v).arena(true);
        let sequential = budgeted.clone().planner_named(seq_spec).run();
        let joint = budgeted.planner_named("joint").grad_spill(grad_spill).run();
        if sequential.is_err() && joint.is_err() {
            // Both sides infeasible: surface it as an error exit, with
            // the joint side's floor (the smaller of the two).
            return Err(plan_err(joint.unwrap_err()));
        }
        if cli.has_flag("json") {
            println!(
                "{}",
                optorch::memory::outcome::compare_json(&sequential, &joint).to_string()
            );
        } else {
            print!(
                "{}",
                optorch::memory::outcome::compare_markdown(&sequential, &joint)
            );
        }
        return Ok(());
    }

    if cli.has_flag("json") {
        // One fully-staged outcome, rendered as the stable JSON schema
        // (--spill wins over --budget: it is the stronger composition).
        let mut req = base
            .clone()
            .planner_named(kind_specs.last().expect("kind set is never empty"))
            .arena(true)
            .frontier(want_frontier);
        if let Some(v) = cli.get("spill") {
            req = req.memory_budget_field("--spill", v);
        } else if let Some(v) = cli.get("budget") {
            req = req.memory_budget_field("--budget", v).spill(false);
        }
        let outcome = req.run().map_err(plan_err)?;
        println!("{}", outcome.to_json().to_string());
        return Ok(());
    }

    // 1. Planner comparison table; the last kind also stages the --arena
    //    layout and the --frontier curve (no second planning pass).
    let mut table = Table::new(&["planner", "checkpoints", "peak", "recompute overhead"]);
    let mut primary: Option<PlanOutcome> = None;
    for (i, spec) in kind_specs.iter().enumerate() {
        let last = i + 1 == kind_specs.len();
        let outcome = base
            .clone()
            .planner_named(spec)
            .arena(last && want_arena)
            .frontier(last && want_frontier)
            .run()
            .map_err(plan_err)?;
        table.row(&[
            format!("{:?}", outcome.plan.kind),
            format!("{:?}", outcome.plan.checkpoints),
            fmt_bytes(outcome.plan.peak_bytes),
            format!("{:.1}% of fwd FLOPs", outcome.plan.recompute_overhead * 100.0),
        ]);
        if last {
            primary = Some(outcome);
        }
    }
    table.print();
    let primary = primary.expect("at least one planner kind is always run");

    // 2. --arena: the packed slab of the primary plan.
    if want_arena {
        print_arena(&primary, model, batch);
    }

    // 3. --frontier (also staged for --budget, matching the legacy CLI).
    if let Some(frontier) = &primary.frontier {
        println!("\ntime/memory Pareto frontier ({} points):\n", frontier.len());
        report::frontier_table(frontier).print();
    }

    // 4. --budget: fit decision on *packed* totals, no spilling allowed.
    if let Some(v) = cli.get("budget") {
        let outcome = base
            .clone()
            .memory_budget_field("--budget", v)
            .spill(false)
            .run()
            .map_err(plan_err)?;
        println!(
            "\nbudget {}: cheapest-time plan fits at packed total {} (simulated peak {}) \
             with {} checkpoints {:?} (+{:.1}% fwd FLOPs)",
            fmt_bytes(outcome.budget.expect("budgeted request")),
            fmt_bytes(outcome.device_peak_packed()),
            fmt_bytes(outcome.plan.peak_bytes),
            outcome.plan.checkpoints.len(),
            outcome.plan.checkpoints,
            outcome.plan.recompute_overhead * 100.0
        );
    }

    // 5. --spill: the best host-spill composition for the budget.
    if let Some(v) = cli.get("spill") {
        let outcome = base.memory_budget_field("--spill", v).run().map_err(plan_err)?;
        print_spill(&outcome);
    }
    Ok(())
}

/// `plan --arena` block: slab totals plus per-class first offsets.
fn print_arena(outcome: &PlanOutcome, model: &str, batch: usize) {
    let (Some(rep), Some(lifetimes), Some(layout)) =
        (&outcome.arena, outcome.lifetimes(), outcome.layout())
    else {
        return;
    };
    println!(
        "\nactivation arena ({model}, batch {batch}, {:?} plan): \
         slab {} + static {} = {} vs simulated peak {} — fragmentation {:.3}x, {} tensors",
        outcome.plan.kind,
        fmt_bytes(rep.slab_bytes),
        fmt_bytes(rep.base_bytes),
        fmt_bytes(layout.total_bytes()),
        fmt_bytes(rep.peak_bytes),
        rep.fragmentation,
        rep.tensor_count,
    );
    let mut t = Table::new(&["class", "tensors", "bytes", "first offsets"]);
    for c in &rep.by_class {
        let mut offs: Vec<u64> = lifetimes
            .tensors
            .iter()
            .enumerate()
            .filter(|(_, tl)| tl.class == c.class)
            .map(|(i, _)| layout.offsets[i])
            .collect();
        offs.sort_unstable();
        offs.dedup();
        let shown = offs
            .iter()
            .take(4)
            .map(|o| o.to_string())
            .collect::<Vec<_>>()
            .join(", ");
        let suffix = if offs.len() > 4 { ", …" } else { "" };
        t.row(&[
            c.class.name().to_string(),
            format!("{}", c.count),
            fmt_bytes(c.bytes),
            format!("{shown}{suffix}"),
        ]);
    }
    t.print();
}

/// `plan --spill` block: the per-tensor evict/prefetch table + predicted
/// stall of a budgeted outcome.
fn print_spill(outcome: &PlanOutcome) {
    let Some(spill) = &outcome.spill else { return };
    println!(
        "\nhost-spill plan for budget {} (bw {}/s, lookahead {}):",
        fmt_bytes(outcome.budget.expect("budgeted request")),
        fmt_bytes(outcome.host_bw),
        outcome.lookahead,
    );
    println!(
        "  plan: {} checkpoints {:?} (+{:.1}% fwd FLOPs), device total {} = static {} + \
         resident slab {}",
        outcome.plan.checkpoints.len(),
        outcome.plan.checkpoints,
        outcome.plan.recompute_overhead * 100.0,
        fmt_bytes(spill.device_total()),
        fmt_bytes(spill.layout.base_bytes),
        fmt_bytes(spill.layout.slab_bytes),
    );
    let Some(overlap) = &outcome.overlap else { return };
    if outcome.is_spill() {
        let mut t = Table::new(&["layer", "bytes", "evict@", "prefetch@", "need@", "idle steps"]);
        for s in &spill.steps {
            t.row(&[
                format!("{}", s.layer),
                fmt_bytes(s.bytes),
                format!("{}", s.evict_step),
                format!("{}", s.prefetch_step),
                format!("{}", s.need_step),
                format!("{}", s.gap_steps),
            ]);
        }
        t.print();
        println!(
            "  {} tensors spilled ({} out, host peak {}) — predicted stall {:.3} ms/step \
             ({:.1}% of {:.3} ms predicted step)",
            spill.steps.len(),
            fmt_bytes(spill.spilled_bytes),
            fmt_bytes(spill.host_peak_bytes),
            overlap.stall_secs * 1e3,
            overlap.stall_frac() * 100.0,
            overlap.predicted_step_secs * 1e3,
        );
    } else {
        println!(
            "  fits without spilling — predicted step {:.3} ms (no stall)",
            overlap.predicted_step_secs * 1e3
        );
    }
}

fn cmd_serve(cli: &Cli) -> Result<()> {
    let file_text = match cli.get("config") {
        Some(path) => Some(std::fs::read_to_string(path)?),
        None => None,
    };
    let mut overrides: BTreeMap<String, String> = cli.opts.clone();
    overrides.remove("config");
    let cfg = ServeConfig::from_sources(file_text.as_deref(), &overrides)
        .map_err(|e| anyhow!(e))?;
    let hub = std::sync::Arc::new(MetricsHub::new());
    let obs_server = optorch::obs::spawn_obs_server(cfg.metrics_addr.as_deref(), &hub)?;
    if let Some(server) = &obs_server {
        println!(
            "metrics endpoint on http://{}/metrics (health: /healthz, /readyz)",
            server.local_addr()
        );
    }
    println!(
        "serving {} (max batch {}, deadline {} ms, {} clients, {} requests)",
        cfg.model, cfg.max_batch, cfg.deadline_ms, cfg.clients, cfg.requests
    );
    let rep = optorch::serve::run(&cfg, &hub)?;
    println!("{}", rep.to_markdown());
    if cli.has_flag("json") {
        println!("{}", rep.to_json().to_string());
    }
    drop(obs_server);
    Ok(())
}

fn cmd_models() -> Result<()> {
    let mut table = Table::new(&["model", "input", "layers", "params", "fwd GFLOPs/img"]);
    for name in all_arch_names() {
        let input = if name.contains("inception_v3") {
            (299, 299, 3)
        } else if name.contains("mini") || name.contains("lite") || name == "tiny_cnn" {
            (32, 32, 3)
        } else {
            (224, 224, 3)
        };
        let classes = if input.0 == 32 { 10 } else { 1000 };
        let p = arch_by_name(&name, input, classes).unwrap();
        table.row(&[
            name.clone(),
            format!("{}x{}x{}", input.0, input.1, input.2),
            format!("{}", p.depth()),
            format!("{}", p.param_count()),
            format!("{:.2}", p.flops(1) as f64 / 1e9),
        ]);
    }
    table.print();
    Ok(())
}

fn cmd_figures() -> Result<()> {
    println!("regenerate figures with:");
    for b in [
        "fig8_memory_timeline",
        "fig9_time_accuracy",
        "fig10_memory_grid",
        "fig11_checkpoint_placement",
        "ed_overlap",
        "encode_throughput",
        "step_latency",
    ] {
        println!("  cargo bench --bench {b}");
    }
    Ok(())
}
