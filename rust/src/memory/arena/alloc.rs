//! `ArenaAllocator`: one preallocated slab with generation-tagged handles.
//!
//! The planning half of the arena ([`pack`](crate::memory::arena::pack))
//! decides how big a slab a schedule needs; this module is the runtime
//! half: a bump allocator over a single preallocated, 8-byte-aligned slab
//! that the training hot path recycles every step. Steady-state steps
//! therefore perform **zero heap allocations** for staging buffers
//! (audited by the counting global allocator in `benches/arena_packing.rs`).
//!
//! * [`ArenaAllocator::begin_step`] recycles the whole slab and bumps the
//!   generation; every [`ArenaHandle`] minted before it becomes stale and
//!   panics on use — the use-after-recycle analogue of a use-after-free.
//! * Debug builds poison the recycled slab with `0xA5` so stale data is
//!   never silently mistaken for a freshly written buffer.
//! * A request that outgrows the slab returns `None` (callers fall back
//!   to the heap); [`ArenaAllocator::fallback_allocs`] counts them, so a
//!   mis-sized slab is visible instead of fatal.

/// Bump allocator over one preallocated slab (see module docs).
#[derive(Debug)]
pub struct ArenaAllocator {
    /// Backing store in 8-byte words — guarantees every handed-out offset
    /// is aligned for f64 views.
    slab: Vec<u64>,
    /// Bump pointer, in bytes.
    top: usize,
    generation: u64,
    high_water: usize,
    fallbacks: u64,
}

/// A generation-tagged range of the slab. Copyable and cheap; resolves to
/// a slice only through the allocator, which checks the generation.
#[derive(Clone, Copy, Debug)]
pub struct ArenaHandle {
    offset: usize,
    bytes: usize,
    generation: u64,
}

impl ArenaHandle {
    pub fn len_bytes(&self) -> usize {
        self.bytes
    }
}

const POISON: u64 = 0xA5A5_A5A5_A5A5_A5A5;

impl ArenaAllocator {
    /// Preallocate a slab of at least `slab_bytes` (rounded up to whole
    /// 8-byte words). This is the only heap allocation the arena makes.
    pub fn new(slab_bytes: usize) -> ArenaAllocator {
        let words = slab_bytes.div_ceil(8);
        ArenaAllocator {
            slab: vec![0u64; words],
            top: 0,
            generation: 0,
            high_water: 0,
            fallbacks: 0,
        }
    }

    pub fn slab_bytes(&self) -> usize {
        self.slab.len() * 8
    }

    /// Recycle the slab for a new step: resets the bump pointer and bumps
    /// the generation so every outstanding handle goes stale. Debug builds
    /// poison the slab so recycled bytes are recognizable.
    pub fn begin_step(&mut self) {
        self.generation += 1;
        self.top = 0;
        if cfg!(debug_assertions) {
            self.slab.fill(POISON);
        }
    }

    /// Claim `bytes` from the slab (offset and advance rounded up to the
    /// 8-byte alignment). `None` when the slab cannot fit the request —
    /// counted in [`fallback_allocs`](ArenaAllocator::fallback_allocs).
    pub fn alloc(&mut self, bytes: usize) -> Option<ArenaHandle> {
        let need = bytes.div_ceil(8) * 8;
        if self.top + need > self.slab_bytes() {
            self.fallbacks += 1;
            return None;
        }
        let h = ArenaHandle { offset: self.top, bytes, generation: self.generation };
        self.top += need;
        self.high_water = self.high_water.max(self.top);
        Some(h)
    }

    /// [`alloc`](ArenaAllocator::alloc) sized for `n` f32 elements.
    pub fn alloc_f32(&mut self, n: usize) -> Option<ArenaHandle> {
        self.alloc(n * 4)
    }

    /// [`alloc`](ArenaAllocator::alloc) sized for `n` f64 elements.
    pub fn alloc_f64(&mut self, n: usize) -> Option<ArenaHandle> {
        self.alloc(n * 8)
    }

    fn check(&self, h: &ArenaHandle) {
        assert!(
            h.generation == self.generation,
            "stale arena handle: minted in step generation {} but the arena is at {} — \
             the slab has been recycled under it",
            h.generation,
            self.generation
        );
        debug_assert!(h.offset % 8 == 0 && h.offset + h.bytes <= self.slab_bytes());
    }

    /// The handle's range as bytes. Panics on a stale handle.
    pub fn bytes_mut(&mut self, h: &ArenaHandle) -> &mut [u8] {
        self.check(h);
        let base = self.slab.as_mut_ptr() as *mut u8;
        // SAFETY: offset + bytes lie inside the live `slab` allocation
        // (checked above), u8 has alignment 1, and the returned slice
        // borrows `self` mutably so no aliasing view can coexist.
        unsafe { std::slice::from_raw_parts_mut(base.add(h.offset), h.bytes) }
    }

    /// The handle's range as f32s (its byte length must be a multiple
    /// of 4). Panics on a stale handle.
    pub fn f32_mut(&mut self, h: &ArenaHandle) -> &mut [f32] {
        self.check(h);
        assert!(h.bytes % 4 == 0, "arena handle of {} B viewed as f32", h.bytes);
        let base = self.slab.as_mut_ptr() as *mut u8;
        // SAFETY: the range is in-bounds (checked), the offset is 8-byte
        // aligned (alloc only hands out multiples of 8, exceeding f32's
        // alignment), and the mutable borrow of `self` is exclusive.
        unsafe { std::slice::from_raw_parts_mut(base.add(h.offset) as *mut f32, h.bytes / 4) }
    }

    /// The handle's range as f64s (its byte length must be a multiple
    /// of 8). Panics on a stale handle.
    pub fn f64_mut(&mut self, h: &ArenaHandle) -> &mut [f64] {
        self.check(h);
        assert!(h.bytes % 8 == 0, "arena handle of {} B viewed as f64", h.bytes);
        let base = self.slab.as_mut_ptr() as *mut u8;
        // SAFETY: in-bounds (checked), 8-byte aligned offsets match f64's
        // alignment, and the mutable borrow of `self` is exclusive.
        unsafe { std::slice::from_raw_parts_mut(base.add(h.offset) as *mut f64, h.bytes / 8) }
    }

    /// Current step generation (bumped by every `begin_step`).
    pub fn generation(&self) -> u64 {
        self.generation
    }

    /// Current bump-pointer position — live slab occupancy within the
    /// step (resets to 0 at every `begin_step`).
    pub fn used_bytes(&self) -> usize {
        self.top
    }

    /// Largest bump-pointer position ever reached — how much of the slab
    /// a workload actually uses.
    pub fn high_water_bytes(&self) -> usize {
        self.high_water
    }

    /// Requests the slab could not serve (callers fell back to the heap).
    /// Flat across steps ⇒ the hot path runs entirely inside the slab.
    pub fn fallback_allocs(&self) -> u64 {
        self.fallbacks
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn slab_rounds_up_and_bump_aligns() {
        let mut a = ArenaAllocator::new(10);
        assert_eq!(a.slab_bytes(), 16);
        a.begin_step();
        let h1 = a.alloc(3).unwrap();
        let h2 = a.alloc(8).unwrap();
        assert_eq!(h1.len_bytes(), 3);
        assert_eq!(a.bytes_mut(&h1).len(), 3);
        assert_eq!(a.bytes_mut(&h2).len(), 8);
        assert_eq!(a.high_water_bytes(), 16); // 3 rounds to 8, + 8
    }

    #[test]
    fn typed_views_roundtrip() {
        let mut a = ArenaAllocator::new(64);
        a.begin_step();
        let hf = a.alloc_f32(4).unwrap();
        a.f32_mut(&hf).copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        let hd = a.alloc_f64(3).unwrap();
        a.f64_mut(&hd).copy_from_slice(&[5.0, 6.0, 7.0]);
        let floats: Vec<f32> = a.f32_mut(&hf).to_vec();
        let doubles: Vec<f64> = a.f64_mut(&hd).to_vec();
        // each view sees its own writes; neither clobbers the other
        assert_eq!(floats, vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(doubles, vec![5.0, 6.0, 7.0]);
    }

    #[test]
    fn oversize_requests_fall_back_and_are_counted() {
        let mut a = ArenaAllocator::new(16);
        a.begin_step();
        assert!(a.alloc(24).is_none());
        assert_eq!(a.fallback_allocs(), 1);
        assert!(a.alloc(16).is_some());
        assert!(a.alloc(1).is_none(), "slab exhausted");
        assert_eq!(a.fallback_allocs(), 2);
        a.begin_step();
        assert!(a.alloc(16).is_some(), "recycling frees the slab");
    }

    #[test]
    #[should_panic(expected = "stale arena handle")]
    fn stale_handle_panics() {
        let mut a = ArenaAllocator::new(32);
        a.begin_step();
        let h = a.alloc(8).unwrap();
        a.begin_step(); // recycles the slab under the handle
        let _ = a.bytes_mut(&h);
    }

    #[test]
    fn begin_step_poisons_in_debug() {
        if !cfg!(debug_assertions) {
            return; // release builds skip the poison fill
        }
        let mut a = ArenaAllocator::new(16);
        a.begin_step();
        let h = a.alloc(16).unwrap();
        a.bytes_mut(&h).fill(0);
        a.begin_step();
        let h2 = a.alloc(16).unwrap();
        assert!(a.bytes_mut(&h2).iter().all(|&b| b == 0xA5));
    }

    #[test]
    fn zero_sized_slab_and_allocs_are_fine() {
        let mut a = ArenaAllocator::new(0);
        assert_eq!(a.slab_bytes(), 0);
        a.begin_step();
        let h = a.alloc(0).unwrap();
        assert!(a.bytes_mut(&h).is_empty());
        assert!(a.f64_mut(&h).is_empty());
        assert!(a.alloc(1).is_none());
    }
}
