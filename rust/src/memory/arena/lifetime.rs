//! Lifetime extraction: replay a checkpoint plan's schedule into
//! per-tensor live intervals.
//!
//! [`PeakEvaluator`](crate::memory::peak::PeakEvaluator) answers "how many
//! bytes peak"; the arena needs to know *which tensors* are live *when* so
//! it can assign each one a concrete slab offset. [`Lifetimes::extract`]
//! replays the exact event order of
//! [`simulate`](crate::memory::simulator::simulate) — forward, loss
//! gradient, (per-segment recompute under S-C,) backward, optimizer — and
//! records every dynamic tensor as an interval `[start, end)` in schedule
//! steps together with its byte size and [`TensorClass`].
//!
//! The extraction is *exact*: at every step the sum of live interval
//! sizes equals the simulator's live bytes minus the static base, so
//!
//! ```text
//! base_bytes + max_live_bytes() == PeakEvaluator::peak(checkpoints)
//! ```
//!
//! (property-tested in `tests/prop_arena.rs`). Like the planner's segment
//! decomposition, this assumes `act_elems ≥ out_elems` per layer — every
//! registry profile stores at least its boundary tensor (see the
//! `memory::peak` module docs); the non-S-C path sizes activations as
//! `max(act, out)` to stay safe on degenerate profiles.

use crate::memory::peak::PeakEvaluator;

/// What a dynamic tensor is — drives reporting and packing diagnostics.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TensorClass {
    /// Boundary output kept live from the forward pass for a later
    /// backward segment (S-C).
    Checkpoint,
    /// Stored or recomputed activation footprint, consumed by its layer's
    /// backward step.
    Activation,
    /// Activation gradient flowing between adjacent backward steps.
    ActGrad,
    /// Parameter gradient, resident from its layer's backward step through
    /// the optimizer step.
    ParamGrad,
    /// Transient: a discarded forward output (S-C, unstored layer) or the
    /// weight-gradient workspace of one backward step.
    Workspace,
}

impl TensorClass {
    pub const ALL: [TensorClass; 5] = [
        TensorClass::Checkpoint,
        TensorClass::Activation,
        TensorClass::ActGrad,
        TensorClass::ParamGrad,
        TensorClass::Workspace,
    ];

    pub fn name(self) -> &'static str {
        match self {
            TensorClass::Checkpoint => "checkpoint",
            TensorClass::Activation => "activation",
            TensorClass::ActGrad => "act-grad",
            TensorClass::ParamGrad => "param-grad",
            TensorClass::Workspace => "workspace",
        }
    }
}

/// One tensor's live interval: `[start, end)` in schedule steps.
#[derive(Clone, Debug)]
pub struct TensorLife {
    pub class: TensorClass,
    /// Layer that defines the tensor.
    pub layer: usize,
    pub bytes: u64,
    /// First step the tensor is live at.
    pub start: usize,
    /// Exclusive end step.
    pub end: usize,
}

impl TensorLife {
    /// Whether two live intervals intersect in time (tensors that do must
    /// occupy disjoint slab ranges).
    pub fn overlaps(&self, other: &TensorLife) -> bool {
        self.start < other.end && other.start < self.end
    }
}

/// Event times of one (arch, pipeline, batch, plan) schedule — the exact
/// step indices [`Lifetimes::extract`] assigns and the host-spill planner
/// (`memory::offload`) reasons about.
#[derive(Clone, Debug)]
pub struct ScheduleTimes {
    /// Forward step of layer `i`.
    pub t_fwd: Vec<usize>,
    /// Loss-gradient step (right after the last forward).
    pub t_loss: usize,
    /// Recompute step of layer `i` under S-C (`None` when the layer adds
    /// no bytes at recompute time).
    pub t_rec: Vec<Option<usize>>,
    /// Backward step of layer `i`.
    pub t_bwd: Vec<usize>,
    /// Optimizer step (the final step).
    pub t_opt: usize,
    /// Total schedule steps (`t_opt + 1`).
    pub steps: usize,
    /// Forward-stored flag per layer (S-C plan applied; all-true otherwise).
    pub stored: Vec<bool>,
}

impl ScheduleTimes {
    /// Replay the evaluator's event order for `checkpoints` into step
    /// indices (same conventions as [`Lifetimes::extract`]).
    pub fn compute(ev: &PeakEvaluator, checkpoints: &[usize]) -> ScheduleTimes {
        let n = ev.depth();
        if n == 0 {
            return ScheduleTimes {
                t_fwd: Vec::new(),
                t_loss: 0,
                t_rec: Vec::new(),
                t_bwd: Vec::new(),
                t_opt: 0,
                steps: 1,
                stored: Vec::new(),
            };
        }
        let sc = ev.is_sc();
        let mut stored = vec![!sc; n];
        if sc {
            for &c in checkpoints {
                if c < n {
                    stored[c] = true;
                }
            }
            stored[n - 1] = true;
        }
        let mut t = 0usize;
        let t_fwd: Vec<usize> = (0..n)
            .map(|_| {
                let s = t;
                t += 1;
                s
            })
            .collect();
        let t_loss = t;
        t += 1;
        let mut t_rec: Vec<Option<usize>> = vec![None; n];
        let mut t_bwd = vec![0usize; n];
        if sc {
            let mut hi = n;
            while hi > 0 {
                let lo = (0..hi.saturating_sub(1))
                    .rev()
                    .find(|&i| stored[i])
                    .map(|i| i + 1)
                    .unwrap_or(0);
                for i in lo..hi {
                    let delta = if stored[i] {
                        ev.act_bytes(i).saturating_sub(ev.out_bytes(i))
                    } else {
                        ev.act_bytes(i)
                    };
                    if delta > 0 {
                        t_rec[i] = Some(t);
                        t += 1;
                    }
                }
                for i in (lo..hi).rev() {
                    t_bwd[i] = t;
                    t += 1;
                }
                hi = lo;
            }
        } else {
            for i in (0..n).rev() {
                t_bwd[i] = t;
                t += 1;
            }
        }
        let t_opt = t;
        ScheduleTimes { t_fwd, t_loss, t_rec, t_bwd, t_opt, steps: t_opt + 1, stored }
    }
}

/// All dynamic-tensor lifetimes of one (arch, pipeline, batch, plan).
#[derive(Clone, Debug)]
pub struct Lifetimes {
    /// Every dynamic tensor with a non-zero size.
    pub tensors: Vec<TensorLife>,
    /// Number of schedule steps (every interval ends at or before this).
    pub steps: usize,
    /// Static (params + momentum + input) bytes outside the arena.
    pub base_bytes: u64,
}

impl Lifetimes {
    /// Replay the evaluator's schedule for `checkpoints` into live
    /// intervals. `checkpoints` follows the simulator convention
    /// (out-of-range indices ignored, final layer implicitly stored;
    /// ignored entirely when the pipeline is not S-C).
    pub fn extract(ev: &PeakEvaluator, checkpoints: &[usize]) -> Lifetimes {
        let n = ev.depth();
        let base_bytes = ev.base_bytes();
        if n == 0 {
            return Lifetimes { tensors: Vec::new(), steps: 1, base_bytes };
        }
        let sc = ev.is_sc();
        let out = |i: usize| ev.out_bytes(i);
        let act = |i: usize| ev.act_bytes(i);

        // ---- pass 1: event times, mirroring the simulator's order ----
        let times = ScheduleTimes::compute(ev, checkpoints);
        let ScheduleTimes { t_fwd, t_loss, t_rec, t_bwd, t_opt, steps, stored } = times;

        // ---- pass 2: tensors ----
        let mut tensors: Vec<TensorLife> = Vec::with_capacity(4 * n);
        let mut push = |class: TensorClass, layer: usize, bytes: u64, start: usize, end: usize| {
            if bytes > 0 {
                tensors.push(TensorLife { class, layer, bytes, start, end });
            }
        };
        for i in 0..n {
            if !sc {
                // Standard training holds the full stored footprint from
                // the layer's forward step to its backward step.
                push(TensorClass::Activation, i, act(i).max(out(i)), t_fwd[i], t_bwd[i] + 1);
            } else if stored[i] {
                push(TensorClass::Checkpoint, i, out(i), t_fwd[i], t_bwd[i] + 1);
                if let Some(tr) = t_rec[i] {
                    // internals recomputed next to the resident boundary
                    push(
                        TensorClass::Activation,
                        i,
                        act(i).saturating_sub(out(i)),
                        tr,
                        t_bwd[i] + 1,
                    );
                }
            } else {
                // discarded forward output: live only while the layer runs
                push(TensorClass::Workspace, i, out(i), t_fwd[i], t_fwd[i] + 1);
                if let Some(tr) = t_rec[i] {
                    push(TensorClass::Activation, i, act(i), tr, t_bwd[i] + 1);
                }
            }
            // activation gradient d/d(out i): born at the downstream
            // backward step (the loss gradient for the final layer),
            // consumed by layer i's backward
            let g_start = if i + 1 == n { t_loss } else { t_bwd[i + 1] };
            push(TensorClass::ActGrad, i, out(i), g_start, t_bwd[i] + 1);
            // parameter gradient: backward of i through the optimizer step
            push(TensorClass::ParamGrad, i, ev.param_grad_bytes(i), t_bwd[i], t_opt + 1);
            // weight-gradient workspace during layer i's backward
            push(TensorClass::Workspace, i, out(i), t_bwd[i], t_bwd[i] + 1);
        }
        Lifetimes { tensors, steps, base_bytes }
    }

    /// Replay the *forward-only* (inference) schedule into live intervals:
    /// backward, recompute, and optimizer events are dropped entirely, so
    /// every tensor dies as soon as the forward pass stops needing it.
    /// Layer `i`'s boundary output lives `[i, i+2)` — defined at its own
    /// step, consumed by layer `i+1` — except the final output, which is
    /// the response payload and lives to the end of the schedule. Layer
    /// internals beyond the boundary are a one-step workspace.
    ///
    /// `base_bytes` is [`PeakEvaluator::infer_base_bytes`] (params + input,
    /// no momentum) and the exactness invariant becomes
    /// `base_bytes + max_live_bytes() == PeakEvaluator::forward_peak()`
    /// (property-tested in `tests/prop_serve.rs`). Checkpoint placement is
    /// irrelevant — nothing is retained for a backward pass — so this takes
    /// no plan argument.
    pub fn extract_infer(ev: &PeakEvaluator) -> Lifetimes {
        let n = ev.depth();
        let base_bytes = ev.infer_base_bytes();
        if n == 0 {
            return Lifetimes { tensors: Vec::new(), steps: 1, base_bytes };
        }
        let steps = n;
        let mut tensors: Vec<TensorLife> = Vec::with_capacity(2 * n);
        let mut push = |class: TensorClass, layer: usize, bytes: u64, start: usize, end: usize| {
            if bytes > 0 {
                tensors.push(TensorLife { class, layer, bytes, start, end });
            }
        };
        for i in 0..n {
            let out = ev.out_bytes(i);
            let act = ev.act_bytes(i);
            // Boundary output: consumed by the next layer's step; the final
            // layer's output is the response and lives to schedule end.
            let end = if i + 1 < n { i + 2 } else { n };
            push(TensorClass::Activation, i, out, i, end);
            // Internals beyond the boundary exist only while the layer runs.
            push(TensorClass::Workspace, i, act.saturating_sub(out), i, i + 1);
        }
        Lifetimes { tensors, steps, base_bytes }
    }

    /// Maximum concurrent live bytes over the schedule — the exact
    /// activation-peak lower bound any slab must cover.
    pub fn max_live_bytes(&self) -> u64 {
        let mut delta = vec![0i128; self.steps + 1];
        for t in &self.tensors {
            delta[t.start] += t.bytes as i128;
            delta[t.end] -= t.bytes as i128;
        }
        let mut live = 0i128;
        let mut max = 0i128;
        for d in &delta {
            live += *d;
            max = max.max(live);
        }
        max as u64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pipeline;
    use crate::models::{arch_by_name, ArchProfile};

    fn pipe(s: &str) -> Pipeline {
        Pipeline::parse(s).unwrap()
    }

    #[test]
    fn replay_matches_evaluator_peak_across_zoo() {
        for name in ["resnet18", "efficientnet_b0", "tiny_cnn"] {
            let arch = arch_by_name(name, (64, 64, 3), 10).unwrap();
            let n = arch.layers.len();
            let plans: Vec<Vec<usize>> =
                vec![vec![], (0..n).step_by(3).collect(), vec![n / 2], (0..n).collect()];
            for p in ["b", "sc", "mp", "ed+sc", "ed+mp+sc"] {
                let mut ev = PeakEvaluator::new(&arch, pipe(p), 8);
                for plan in &plans {
                    let lt = Lifetimes::extract(&ev, plan);
                    assert_eq!(
                        lt.base_bytes + lt.max_live_bytes(),
                        ev.peak(plan),
                        "{name} [{p}] plan {plan:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn intervals_are_well_formed() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        let n = arch.layers.len();
        let lt = Lifetimes::extract(&ev, &[n / 3, 2 * n / 3]);
        assert!(!lt.tensors.is_empty());
        for t in &lt.tensors {
            assert!(t.start < t.end, "{t:?}");
            assert!(t.end <= lt.steps, "{t:?} beyond {} steps", lt.steps);
            assert!(t.bytes > 0, "{t:?}");
            assert!(t.layer < n, "{t:?}");
        }
        // the implicitly stored final layer yields a checkpoint tensor
        assert!(lt
            .tensors
            .iter()
            .any(|t| t.class == TensorClass::Checkpoint && t.layer == n - 1));
        // parameter gradients all persist to the final (optimizer) step
        assert!(lt
            .tensors
            .iter()
            .filter(|t| t.class == TensorClass::ParamGrad)
            .all(|t| t.end == lt.steps));
    }

    #[test]
    fn class_mix_follows_the_schedule() {
        let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let n = arch.layers.len();
        let ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        let lt = Lifetimes::extract(&ev, &[1]);
        let count = |c: TensorClass| lt.tensors.iter().filter(|t| t.class == c).count();
        // checkpoints: layer 1 + implicit final layer
        assert_eq!(count(TensorClass::Checkpoint), 2);
        // workspaces: one per backward step + one per unstored forward
        assert_eq!(count(TensorClass::Workspace), n + (n - 2));
        assert_eq!(count(TensorClass::ActGrad), n);
        // baseline pipeline has no checkpoints and no forward transients
        let evb = PeakEvaluator::new(&arch, pipe("b"), 4);
        let ltb = Lifetimes::extract(&evb, &[]);
        let countb = |c: TensorClass| ltb.tensors.iter().filter(|t| t.class == c).count();
        assert_eq!(countb(TensorClass::Checkpoint), 0);
        assert_eq!(countb(TensorClass::Activation), n);
        assert_eq!(countb(TensorClass::Workspace), n);
    }

    #[test]
    fn empty_arch_has_no_tensors() {
        let arch = ArchProfile { name: "empty".into(), input: (8, 8, 3), layers: vec![] };
        let ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        let lt = Lifetimes::extract(&ev, &[]);
        assert!(lt.tensors.is_empty());
        assert_eq!(lt.steps, 1);
        assert_eq!(lt.max_live_bytes(), 0);
        assert_eq!(lt.base_bytes, ev.base_bytes());
    }

    #[test]
    fn schedule_times_match_extracted_intervals() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        let n = arch.layers.len();
        let cps = vec![n / 3, 2 * n / 3];
        let times = ScheduleTimes::compute(&ev, &cps);
        let lt = Lifetimes::extract(&ev, &cps);
        assert_eq!(times.steps, lt.steps);
        assert_eq!(times.t_opt + 1, lt.steps);
        assert!(times.stored[n - 1], "final layer implicitly stored");
        for t in &lt.tensors {
            match t.class {
                TensorClass::Checkpoint => {
                    assert_eq!(t.start, times.t_fwd[t.layer], "{t:?}");
                    assert_eq!(t.end, times.t_bwd[t.layer] + 1, "{t:?}");
                }
                TensorClass::ParamGrad => {
                    assert_eq!(t.start, times.t_bwd[t.layer], "{t:?}");
                    assert_eq!(t.end, times.t_opt + 1, "{t:?}");
                }
                _ => {}
            }
        }
    }

    #[test]
    fn infer_replay_matches_forward_peak() {
        for name in ["resnet18", "efficientnet_b0", "tiny_cnn"] {
            let arch = arch_by_name(name, (64, 64, 3), 10).unwrap();
            for p in ["b", "sc", "mp", "ed+mp+sc"] {
                let ev = PeakEvaluator::new(&arch, pipe(p), 8);
                let lt = Lifetimes::extract_infer(&ev);
                assert_eq!(
                    lt.base_bytes + lt.max_live_bytes(),
                    ev.forward_peak(),
                    "{name} [{p}]"
                );
                assert_eq!(lt.base_bytes, ev.infer_base_bytes());
                assert_eq!(lt.steps, arch.layers.len());
                for t in &lt.tensors {
                    assert!(t.start < t.end && t.end <= lt.steps, "{t:?}");
                    assert!(
                        matches!(t.class, TensorClass::Activation | TensorClass::Workspace),
                        "forward-only replay must not emit backward classes: {t:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn infer_replay_empty_arch() {
        let arch = ArchProfile { name: "empty".into(), input: (8, 8, 3), layers: vec![] };
        let ev = PeakEvaluator::new(&arch, pipe("b"), 4);
        let lt = Lifetimes::extract_infer(&ev);
        assert!(lt.tensors.is_empty());
        assert_eq!(lt.steps, 1);
        assert_eq!(lt.base_bytes + lt.max_live_bytes(), ev.forward_peak());
    }

    #[test]
    fn out_of_range_checkpoints_ignored() {
        let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        let a = Lifetimes::extract(&ev, &[1, 99]);
        let b = Lifetimes::extract(&ev, &[1]);
        assert_eq!(a.tensors.len(), b.tensors.len());
        assert_eq!(a.max_live_bytes(), b.max_live_bytes());
    }
}
