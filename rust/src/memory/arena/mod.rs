//! Lifetime-aware activation arena: static slab planning, offset
//! assignment, and a pooled runtime allocator.
//!
//! The checkpoint planner (`memory::planner`) proves how many bytes a
//! schedule peaks at; this subsystem is the bridge from that *simulated*
//! peak to bytes a runtime actually touches, in the spirit of OLLA
//! (Steiner et al., 2022) planning tensor *locations* on top of Chen et
//! al.'s (2016) sublinear-memory schedules:
//!
//! 1. [`lifetime`] replays a plan's exact schedule into per-tensor live
//!    intervals `[def_step, last_use_step) × bytes`, classed as
//!    checkpoint / activation / act-grad / param-grad / workspace.
//! 2. [`pack`](crate::memory::arena::pack) assigns each tensor a concrete
//!    slab offset by greedy best-fit interval packing over a coalescing
//!    free-list, yielding an [`ArenaLayout`] whose slab is compared
//!    against the exact DP peak (the fragmentation ratio).
//! 3. [`alloc`] is the runtime half: [`ArenaAllocator`], one preallocated
//!    slab with generation-tagged handles that backs the train-step
//!    staging buffers so steady state allocates nothing.
//!
//! Entry points: [`plan_arena`] (plan → lifetimes + layout) and
//! [`summarize`] (layout → the [`ArenaReport`] surfaced by
//! `TrainReport` and `optorch plan --arena`).

pub mod alloc;
pub mod lifetime;
pub mod pack;

pub use alloc::{ArenaAllocator, ArenaHandle};
pub use lifetime::{Lifetimes, ScheduleTimes, TensorClass, TensorLife};
pub use pack::{aligned, pack, validate, ArenaLayout, ARENA_ALIGN};

use crate::config::Pipeline;
use crate::memory::peak::PeakEvaluator;
use crate::models::ArchProfile;

/// Per-class rollup of an arena layout.
#[derive(Clone, Debug)]
pub struct ClassStat {
    pub class: TensorClass,
    pub count: usize,
    /// Total (unaligned) bytes of the class's tensors.
    pub bytes: u64,
}

/// Arena summary surfaced in `TrainReport` and `plan --arena`.
#[derive(Clone, Debug)]
pub struct ArenaReport {
    /// Dynamic slab bytes the layout needs.
    pub slab_bytes: u64,
    /// Static (params + momentum + input) bytes outside the slab.
    pub base_bytes: u64,
    /// Exact replayed peak of the plan (`PeakEvaluator::peak`).
    pub peak_bytes: u64,
    pub tensor_count: usize,
    /// `(base + slab) / peak` — 1.0 is a perfect packing.
    pub fragmentation: f64,
    /// Non-empty classes only, in [`TensorClass::ALL`] order.
    pub by_class: Vec<ClassStat>,
}

/// Plan the arena for a checkpoint plan: extract lifetimes under the S-C
/// schedule (S-C is forced on, mirroring `plan_checkpoints` scoring, so
/// the layout's peak matches the plan's `peak_bytes`) and pack them.
pub fn plan_arena(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    checkpoints: &[usize],
) -> (Lifetimes, ArenaLayout) {
    let mut p = pipeline;
    p.sc = true;
    let ev = PeakEvaluator::new(arch, p, batch);
    let lt = Lifetimes::extract(&ev, checkpoints);
    let layout = pack(&lt);
    (lt, layout)
}

/// Roll a layout up into the per-class report.
pub fn summarize(lt: &Lifetimes, layout: &ArenaLayout) -> ArenaReport {
    let mut by_class: Vec<ClassStat> = TensorClass::ALL
        .iter()
        .map(|&class| ClassStat { class, count: 0, bytes: 0 })
        .collect();
    for t in &lt.tensors {
        let s = by_class.iter_mut().find(|s| s.class == t.class).unwrap();
        s.count += 1;
        s.bytes += t.bytes;
    }
    by_class.retain(|s| s.count > 0);
    ArenaReport {
        slab_bytes: layout.slab_bytes,
        base_bytes: layout.base_bytes,
        peak_bytes: layout.peak_bytes,
        tensor_count: lt.tensors.len(),
        fragmentation: layout.fragmentation_ratio(),
        by_class,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::planner::{plan_checkpoints, PlannerKind};
    use crate::models::arch_by_name;

    #[test]
    fn arena_covers_the_exact_plan_peak() {
        for name in ["resnet18", "efficientnet_b0", "tiny_cnn"] {
            let arch = arch_by_name(name, (64, 64, 3), 10).unwrap();
            let plan = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, 8);
            let (lt, layout) = plan_arena(&arch, Pipeline::BASELINE, 8, &plan.checkpoints);
            validate(&lt, &layout).unwrap();
            assert_eq!(layout.peak_bytes, plan.peak_bytes, "{name}");
            assert!(layout.total_bytes() >= plan.peak_bytes, "{name}");
            let frag = layout.fragmentation_ratio();
            assert!((1.0..=1.25).contains(&frag), "{name}: fragmentation {frag}");
        }
    }

    #[test]
    fn summary_accounts_for_every_tensor() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let plan = plan_checkpoints(&arch, PlannerKind::Sqrt, Pipeline::BASELINE, 4);
        let (lt, layout) = plan_arena(&arch, Pipeline::BASELINE, 4, &plan.checkpoints);
        let rep = summarize(&lt, &layout);
        assert_eq!(rep.tensor_count, lt.tensors.len());
        let counted: usize = rep.by_class.iter().map(|c| c.count).sum();
        assert_eq!(counted, rep.tensor_count);
        let bytes: u64 = rep.by_class.iter().map(|c| c.bytes).sum();
        assert_eq!(bytes, lt.tensors.iter().map(|t| t.bytes).sum::<u64>());
        assert!(rep.by_class.iter().any(|c| c.class == TensorClass::Checkpoint));
        assert!(rep.fragmentation >= 1.0);
        assert_eq!(rep.slab_bytes, layout.slab_bytes);
    }
}
