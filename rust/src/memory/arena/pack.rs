//! Offset assignment: greedy interval packing onto one slab.
//!
//! Dynamic storage allocation (placing sized tensors with known lifetimes
//! into one address range) is NP-hard in general; greedy best-fit over
//! size-decreasing tensors is the standard practical planner (OLLA,
//! Steiner et al. 2022; TFLite's greedy-by-size memory planner) and lands
//! within a few percent of the concurrent-live lower bound on chain
//! schedules. For each tensor we collect the already-placed tensors whose
//! lifetimes overlap it, coalesce their `[offset, offset+size)` ranges
//! into an occupied list, and scan the free gaps between them — a
//! coalescing free-list in space rather than time. Three deterministic
//! (order, fit) strategies are tried and the smallest slab wins, so the
//! layout is a pure function of the lifetimes.
//!
//! The result is an [`ArenaLayout`]: slab size + one offset per tensor,
//! with `base_bytes + slab_bytes ≥ peak_bytes` guaranteed (every step's
//! live tensors occupy disjoint sub-ranges of the slab) and the
//! fragmentation ratio reported against the exact replayed peak.

use crate::memory::arena::lifetime::{Lifetimes, TensorLife};

/// Allocation granularity: every offset and rounded size is a multiple of
/// this, so typed (f32/f64) views of slab ranges stay aligned.
pub const ARENA_ALIGN: u64 = 8;

/// Round `bytes` up to the arena alignment.
pub fn aligned(bytes: u64) -> u64 {
    (bytes + (ARENA_ALIGN - 1)) & !(ARENA_ALIGN - 1)
}

/// A packed slab layout for one plan's lifetimes.
#[derive(Clone, Debug)]
pub struct ArenaLayout {
    /// Dynamic slab size: every tensor's `[offset, offset + size)` fits
    /// below it.
    pub slab_bytes: u64,
    /// Static (params + momentum + input) bytes outside the slab.
    pub base_bytes: u64,
    /// Exact replayed peak of the plan (`base + max concurrent live`) —
    /// identical to `PeakEvaluator::peak` for the same plan.
    pub peak_bytes: u64,
    /// Byte offset per tensor, parallel to [`Lifetimes::tensors`].
    pub offsets: Vec<u64>,
}

impl ArenaLayout {
    /// Bytes the runtime actually reserves: static state + the slab.
    pub fn total_bytes(&self) -> u64 {
        self.base_bytes + self.slab_bytes
    }

    /// `total_bytes / peak_bytes` — 1.0 means the packing wastes nothing
    /// over the exact simulated peak; always ≥ 1.0.
    pub fn fragmentation_ratio(&self) -> f64 {
        if self.peak_bytes == 0 {
            1.0
        } else {
            self.total_bytes() as f64 / self.peak_bytes as f64
        }
    }
}

#[derive(Clone, Copy)]
enum Fit {
    /// Smallest gap that fits (ties to the lowest offset).
    Best,
    /// Lowest-offset gap that fits.
    First,
}

/// Place tensors in `order`, each at its chosen gap among the ranges of
/// already-placed, time-overlapping tensors. Returns (slab, offsets).
fn assign(tensors: &[TensorLife], order: &[usize], fit: Fit) -> (u64, Vec<u64>) {
    let mut offsets = vec![0u64; tensors.len()];
    let mut placed: Vec<usize> = Vec::with_capacity(tensors.len());
    let mut slab = 0u64;
    let mut occ: Vec<(u64, u64)> = Vec::new();
    for &ti in order {
        let t = &tensors[ti];
        let need = aligned(t.bytes);
        occ.clear();
        occ.extend(
            placed
                .iter()
                .filter(|&&pi| tensors[pi].overlaps(t))
                .map(|&pi| (offsets[pi], offsets[pi] + aligned(tensors[pi].bytes))),
        );
        occ.sort_unstable();
        let mut best: Option<(u64, u64)> = None; // (gap, offset)
        let mut cursor = 0u64;
        for &(s, e) in &occ {
            if s > cursor {
                let gap = s - cursor;
                if gap >= need {
                    let better = match (fit, best) {
                        (Fit::First, None) => true,
                        (Fit::First, Some(_)) => false,
                        (Fit::Best, None) => true,
                        (Fit::Best, Some((g, _))) => gap < g,
                    };
                    if better {
                        best = Some((gap, cursor));
                    }
                }
            }
            cursor = cursor.max(e);
        }
        // no interior gap fits → extend past the occupied region
        let off = best.map_or(cursor, |(_, o)| o);
        offsets[ti] = off;
        slab = slab.max(off + need);
        placed.push(ti);
    }
    (slab, offsets)
}

/// Pack lifetimes onto one slab: try size-decreasing best-fit,
/// size-decreasing first-fit and definition-order first-fit, and keep the
/// smallest slab (first strategy wins ties — fully deterministic).
pub fn pack(lt: &Lifetimes) -> ArenaLayout {
    let tensors = &lt.tensors;
    let n = tensors.len();
    let mut by_size: Vec<usize> = (0..n).collect();
    by_size.sort_by_key(|&i| (std::cmp::Reverse(tensors[i].bytes), tensors[i].start, i));
    let mut by_start: Vec<usize> = (0..n).collect();
    by_start.sort_by_key(|&i| (tensors[i].start, std::cmp::Reverse(tensors[i].bytes), i));

    let candidates = [
        assign(tensors, &by_size, Fit::Best),
        assign(tensors, &by_size, Fit::First),
        assign(tensors, &by_start, Fit::First),
    ];
    let (slab_bytes, offsets) = candidates
        .into_iter()
        .min_by_key(|(slab, _)| *slab)
        .unwrap();
    ArenaLayout {
        slab_bytes,
        base_bytes: lt.base_bytes,
        peak_bytes: lt.base_bytes + lt.max_live_bytes(),
        offsets,
    }
}

/// Check a layout against its lifetimes: offsets aligned, every tensor
/// inside the slab, and no pair of time-overlapping tensors sharing a
/// byte. Returns a description of the first violation.
pub fn validate(lt: &Lifetimes, layout: &ArenaLayout) -> Result<(), String> {
    let ts = &lt.tensors;
    if layout.offsets.len() != ts.len() {
        return Err(format!(
            "layout has {} offsets for {} tensors",
            layout.offsets.len(),
            ts.len()
        ));
    }
    for (i, t) in ts.iter().enumerate() {
        if layout.offsets[i] % ARENA_ALIGN != 0 {
            return Err(format!("tensor {i} offset {} misaligned", layout.offsets[i]));
        }
        if layout.offsets[i] + aligned(t.bytes) > layout.slab_bytes {
            return Err(format!(
                "tensor {i} ({} B at {}) overflows the {} B slab",
                t.bytes, layout.offsets[i], layout.slab_bytes
            ));
        }
    }
    for i in 0..ts.len() {
        for j in i + 1..ts.len() {
            if !ts[i].overlaps(&ts[j]) {
                continue;
            }
            let (a0, a1) = (layout.offsets[i], layout.offsets[i] + aligned(ts[i].bytes));
            let (b0, b1) = (layout.offsets[j], layout.offsets[j] + aligned(ts[j].bytes));
            if a0 < b1 && b0 < a1 {
                return Err(format!(
                    "tensors {i} ({:?}) and {j} ({:?}) overlap in time and share \
                     bytes [{}, {}) ∩ [{}, {})",
                    ts[i].class, ts[j].class, a0, a1, b0, b1
                ));
            }
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::arena::lifetime::TensorClass;

    fn tl(bytes: u64, start: usize, end: usize) -> TensorLife {
        TensorLife { class: TensorClass::Activation, layer: 0, bytes, start, end }
    }

    fn lifetimes(tensors: Vec<TensorLife>, steps: usize) -> Lifetimes {
        Lifetimes { tensors, steps, base_bytes: 0 }
    }

    #[test]
    fn aligned_rounds_up_to_eight() {
        assert_eq!(aligned(0), 0);
        assert_eq!(aligned(1), 8);
        assert_eq!(aligned(8), 8);
        assert_eq!(aligned(9), 16);
    }

    #[test]
    fn disjoint_lifetimes_share_an_offset() {
        // A [0,2) and C [2,4) never coexist: C reuses A's range; B overlaps
        // both and stacks above. Slab equals the concurrent-live maximum.
        let lt = lifetimes(vec![tl(64, 0, 2), tl(32, 1, 3), tl(64, 2, 4)], 4);
        let layout = pack(&lt);
        validate(&lt, &layout).unwrap();
        assert_eq!(layout.offsets[0], layout.offsets[2], "disjoint tensors must reuse");
        assert_eq!(layout.slab_bytes, 96);
        assert_eq!(layout.peak_bytes, 96); // base 0
        assert!((layout.fragmentation_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn generations_of_neighbours_reuse_ranges() {
        // Two generations of differently-sized short-lived tensors beside
        // one long-lived tensor: the second generation must land in the
        // first generation's vacated ranges, keeping the slab at the
        // concurrent-live maximum (128 + 64 + 32).
        let lt = lifetimes(
            vec![
                tl(128, 0, 10), // placed first (largest), alive throughout
                tl(32, 0, 2),
                tl(64, 0, 2),
                tl(32, 3, 5), // second generation: reuses the [0,2) ranges
                tl(64, 3, 5),
            ],
            10,
        );
        let layout = pack(&lt);
        validate(&lt, &layout).unwrap();
        assert_eq!(layout.slab_bytes, 128 + 32 + 64);
    }

    #[test]
    fn validate_catches_overlap_and_overflow() {
        let lt = lifetimes(vec![tl(64, 0, 2), tl(64, 1, 3)], 3);
        let mut layout = pack(&lt);
        validate(&lt, &layout).unwrap();
        let saved = layout.offsets[1];
        layout.offsets[1] = layout.offsets[0]; // force an address collision
        let err = validate(&lt, &layout).unwrap_err();
        assert!(err.contains("share"), "{err}");
        layout.offsets[1] = saved;
        layout.offsets[0] = layout.slab_bytes; // force an overflow
        let err = validate(&lt, &layout).unwrap_err();
        assert!(err.contains("overflows"), "{err}");
        layout.offsets[0] = 3; // force misalignment
        let err = validate(&lt, &layout).unwrap_err();
        assert!(err.contains("misaligned"), "{err}");
    }

    #[test]
    fn empty_lifetimes_pack_to_zero() {
        let lt = lifetimes(vec![], 1);
        let layout = pack(&lt);
        assert_eq!(layout.slab_bytes, 0);
        assert!(layout.offsets.is_empty());
        assert_eq!(layout.fragmentation_ratio(), 1.0);
        validate(&lt, &layout).unwrap();
    }

    #[test]
    fn packing_is_deterministic() {
        let lt = lifetimes(
            (0..24usize)
                .map(|i| tl((8 + (i * 37) % 96) as u64, i % 6, i % 6 + 1 + i % 3))
                .collect(),
            12,
        );
        let a = pack(&lt);
        let b = pack(&lt);
        assert_eq!(a.slab_bytes, b.slab_bytes);
        assert_eq!(a.offsets, b.offsets);
        validate(&lt, &a).unwrap();
    }
}
