//! Joint recompute/spill planning: one optimizer over keep / recompute /
//! spill, including param-gradient offload.
//!
//! The sequential pipeline decomposes the budget problem: the DP planner
//! picks a checkpoint frontier first
//! ([`pareto_frontier`](crate::memory::planner::pareto_frontier)), then
//! [`select_for_budget`](crate::memory::offload::select_for_budget)
//! composes the greedy coldest-first spill for each point and keeps the
//! best. MONeT (Shah et al., "Memory Optimization for Deep Networks")
//! shows that deciding location and recomputation *jointly per tensor*
//! strictly dominates that decomposition; [`plan_joint`] is that search
//! over this crate's exact cost models:
//!
//! * **Recompute** comes from the same chain decomposition the PR 2 DP
//!   uses — every candidate checkpoint placement is costed by its exact
//!   re-forward FLOPs
//!   ([`recompute_overhead`](crate::memory::planner::recompute_overhead))
//!   folded into the simulated step time.
//! * **Spill** is costed against the double-buffered link model of
//!   [`simulate_overlap`](crate::memory::offload::simulate_overlap): a
//!   transfer only costs what its stall fails to hide behind compute.
//! * **Param-gradients** join the spill candidate set
//!   ([`grad_candidates`](crate::memory::offload::plan)). A gradient is
//!   idle from its backward step to the optimizer step; spilling it
//!   applies the optimizer update host-side (ZeRO-Offload style), so the
//!   bytes leave the slab for good and only the refreshed parameters ride
//!   the link back. On parameter-heavy nets this drops the device floor
//!   below anything checkpoint spilling can reach.
//!
//! The search: every candidate checkpoint placement — all `2^(n−1)`
//! subsets on chains of at most [`JOINT_EXHAUSTIVE_DEPTH`] layers, the
//! Pareto frontier otherwise — is combined with several deterministic
//! spill orders (sequential coldest-first over checkpoints; a merged
//! checkpoint+gradient order ranked by how hideable each transfer is;
//! gradients first). The shortest fitting prefix of each order is packed
//! and simulated, and the minimum predicted step time wins, ties broken
//! by lower recompute then smaller device total — the same ranking
//! `select_for_budget` uses.
//!
//! **Dominance by construction:** the sequential winner's exact
//! composition (its frontier point, its coldest-first spill prefix, the
//! same packer and the same simulator) is always one of the candidates
//! joint scores, so `plan_joint`'s predicted step time is never worse
//! than `select_for_budget`'s — exactly, in the same arithmetic, not
//! merely approximately. The benches and `tests/prop_joint.rs` hold it to
//! that.

use crate::config::Pipeline;
use crate::memory::arena::{pack, Lifetimes, ScheduleTimes};
use crate::memory::offload::plan::{
    candidates, grad_candidates, host_peak, resident_lifetimes, SpillStep,
};
use crate::memory::offload::schedule::step_flops;
use crate::memory::offload::{
    simulate_overlap, BudgetDecision, InfeasibleBudget, OverlapModel, OverlapReport, SpillPlan,
};
use crate::memory::peak::PeakEvaluator;
use crate::memory::planner::{
    pareto_frontier, recompute_overhead, CheckpointPlan, PlannerKind, DEFAULT_FRONTIER_LEVELS,
};
use crate::models::ArchProfile;

/// Chains up to this many layers are searched over every checkpoint
/// subset (`2^(n−1)` placements); deeper chains fall back to the Pareto
/// frontier. Matches the brute-force optimality bound pinned by
/// `tests/prop_joint.rs`.
pub const JOINT_EXHAUSTIVE_DEPTH: usize = 10;

/// Jointly choose keep / recompute / spill per tensor for `budget` device
/// bytes. `grad_spill` admits param-gradients to the spill candidate set;
/// with it off the search still dominates the sequential pipeline (it
/// scores strictly more checkpoint placements), with it on the reachable
/// floor drops below the resident-gradient minimum. Returns the same
/// [`BudgetDecision`] the sequential
/// [`select_for_budget`](crate::memory::offload::select_for_budget)
/// yields, or [`InfeasibleBudget`] carrying the smallest device total any
/// scored composition reached.
pub fn plan_joint(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    budget: u64,
    lookahead: usize,
    model: &OverlapModel,
    grad_spill: bool,
) -> Result<BudgetDecision, InfeasibleBudget> {
    let mut p = pipeline;
    p.sc = true;
    let lookahead = lookahead.max(1);
    let n = arch.layers.len();
    let placements: Vec<Vec<usize>> = if n == 0 {
        vec![vec![]]
    } else if n <= JOINT_EXHAUSTIVE_DEPTH {
        (0u32..(1u32 << (n - 1)))
            .map(|mask| (0..n - 1).filter(|&i| mask >> i & 1 == 1).collect())
            .collect()
    } else {
        pareto_frontier(arch, p, batch, DEFAULT_FRONTIER_LEVELS)
            .into_iter()
            .map(|pt| pt.checkpoints)
            .collect()
    };
    let mut ev = PeakEvaluator::new(arch, p, batch);
    let mut best: Option<BudgetDecision> = None;
    let mut min_bytes = u64::MAX;
    for cps in placements {
        match joint_spill_for_checkpoints(
            arch, p, batch, &cps, budget, lookahead, model, grad_spill,
        ) {
            Ok((spill, overlap)) => {
                let overhead = recompute_overhead(arch, &cps);
                let replace = match &best {
                    None => true,
                    Some(b) => {
                        let cand = (overlap.predicted_step_secs, overhead, spill.device_total());
                        let cur = (
                            b.overlap.predicted_step_secs,
                            b.plan.recompute_overhead,
                            b.spill.device_total(),
                        );
                        cand.partial_cmp(&cur) == Some(std::cmp::Ordering::Less)
                    }
                };
                if replace {
                    best = Some(BudgetDecision {
                        plan: CheckpointPlan {
                            kind: PlannerKind::Joint,
                            peak_bytes: ev.peak(&cps),
                            recompute_overhead: overhead,
                            checkpoints: cps,
                        },
                        spill,
                        overlap,
                    });
                }
            }
            Err(e) => min_bytes = min_bytes.min(e.min_device_bytes),
        }
    }
    best.ok_or(InfeasibleBudget { budget, min_device_bytes: min_bytes })
}

/// Joint spill selection for one *fixed* checkpoint placement: score every
/// candidate eviction order's shortest fitting prefix and keep the minimum
/// predicted step time (ties: smaller device total). This is the budgeted
/// explicit-checkpoints path of the facade (`PlanRequest::with_checkpoints`
/// under `PlannerKind::Joint`) — the placement is pinned, only location is
/// optimized.
#[allow(clippy::too_many_arguments)]
pub fn joint_spill_for_checkpoints(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    checkpoints: &[usize],
    budget: u64,
    lookahead: usize,
    model: &OverlapModel,
    grad_spill: bool,
) -> Result<(SpillPlan, OverlapReport), InfeasibleBudget> {
    let mut p = pipeline;
    p.sc = true;
    let lookahead = lookahead.max(1);
    let spills =
        joint_spill_for_plan(arch, p, batch, checkpoints, budget, lookahead, model, grad_spill)
            .map_err(|min| InfeasibleBudget { budget, min_device_bytes: min })?;
    let mut best: Option<(SpillPlan, OverlapReport)> = None;
    for spill in spills {
        let overlap = simulate_overlap(arch, batch, &spill, model);
        let replace = match &best {
            None => true,
            Some((bs, bo)) => {
                let cand = (overlap.predicted_step_secs, spill.device_total());
                let cur = (bo.predicted_step_secs, bs.device_total());
                cand.partial_cmp(&cur) == Some(std::cmp::Ordering::Less)
            }
        };
        if replace {
            best = Some((spill, overlap));
        }
    }
    Ok(best.expect("joint_spill_for_plan returns at least one plan on Ok"))
}

/// All fitting spill compositions [`plan_joint`] scores for one
/// checkpoint placement: the shortest fitting prefix of each candidate
/// eviction order (at most one plan per order, deduplicated by step set).
/// `Err` carries the smallest device total any prefix reached when none
/// fit. The first order is the sequential planner's own coldest-first
/// checkpoint order, inserted layer-sorted exactly like
/// [`plan_spill`](crate::memory::offload::plan_spill) — that candidate is
/// byte-identical to the sequential composition, which is what makes the
/// joint result dominant by construction rather than by luck.
#[allow(clippy::too_many_arguments)]
fn joint_spill_for_plan(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    checkpoints: &[usize],
    budget: u64,
    lookahead: usize,
    model: &OverlapModel,
    grad_spill: bool,
) -> Result<Vec<SpillPlan>, u64> {
    let ev = PeakEvaluator::new(arch, pipeline, batch);
    let times = ScheduleTimes::compute(&ev, checkpoints);
    let lt = Lifetimes::extract(&ev, checkpoints);
    let layout = pack(&lt);
    if layout.total_bytes() <= budget {
        return Ok(vec![SpillPlan {
            steps: Vec::new(),
            lifetimes: lt,
            layout,
            times,
            budget,
            spilled_bytes: 0,
            host_peak_bytes: 0,
        }]);
    }
    let ckpts: Vec<SpillStep> =
        candidates(arch, &ev, &times, lookahead).into_iter().map(|c| c.step).collect();
    let grads: Vec<SpillStep> = if grad_spill {
        grad_candidates(arch, &ev, &times, lookahead).into_iter().map(|c| c.step).collect()
    } else {
        Vec::new()
    };

    let mut orders: Vec<Vec<SpillStep>> = vec![ckpts.clone()];
    if !grads.is_empty() {
        // Merged order: cheapest-to-hide first. A transfer of `bytes` each
        // way costs `2·bytes/bw` link seconds against the compute seconds
        // of its idle window — the smaller that ratio, the more of the
        // transfer the overlap model hides for free.
        let flops = step_flops(arch, batch, &times);
        let bw = model.host_bw_bytes_per_sec.max(1.0);
        let speed = model.device_flops_per_sec.max(1.0);
        let hide_ratio = |s: &SpillStep| -> f64 {
            let window: f64 =
                flops[s.evict_step..s.need_step].iter().map(|f| f / speed).sum();
            (2.0 * s.bytes as f64 / bw) / window.max(1e-12)
        };
        let mut merged: Vec<SpillStep> = ckpts.iter().chain(grads.iter()).cloned().collect();
        merged.sort_by(|a, b| {
            hide_ratio(a)
                .partial_cmp(&hide_ratio(b))
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(b.gap_steps.cmp(&a.gap_steps))
                .then(a.layer.cmp(&b.layer))
                .then(a.class.cmp(&b.class))
        });
        orders.push(merged);
        // Gradients first: on parameter-heavy nets the slab peak sits at
        // the optimizer step, where no amount of checkpoint spilling
        // helps; this order reaches that floor with the fewest transfers.
        let mut gf = grads.clone();
        gf.extend(ckpts.iter().cloned());
        orders.push(gf);
    }

    let mut out: Vec<SpillPlan> = Vec::new();
    let mut min_total = layout.total_bytes();
    for order in &orders {
        // Shortest fitting prefix: every further eviction adds link load
        // without freeing budget-relevant bytes, so within one order more
        // spills never predict a faster step.
        let mut chosen: Vec<SpillStep> = Vec::new();
        for step in order {
            let pos = chosen
                .partition_point(|s| (s.layer, s.class) < (step.layer, step.class));
            chosen.insert(pos, step.clone());
            let rl = resident_lifetimes(&lt, &chosen);
            let rlay = pack(&rl);
            min_total = min_total.min(rlay.total_bytes());
            if rlay.total_bytes() <= budget {
                let spilled_bytes = chosen.iter().map(|s| s.bytes).sum();
                let host_peak_bytes = host_peak(&chosen, times.steps);
                let dup = out.iter().any(|p| p.steps == chosen);
                if !dup {
                    out.push(SpillPlan {
                        steps: chosen,
                        lifetimes: rl,
                        layout: rlay,
                        times: times.clone(),
                        budget,
                        spilled_bytes,
                        host_peak_bytes,
                    });
                }
                break;
            }
        }
    }
    if out.is_empty() {
        Err(min_total)
    } else {
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::arena::{plan_arena, validate};
    use crate::memory::offload::{select_for_budget, SpillClass};
    use crate::models::{LayerKind, LayerProfile};

    fn sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    /// Checkpoint-heavy uniform chain (activations dominate parameters).
    fn uniform_chain(depth: usize) -> ArchProfile {
        let layers = (0..depth)
            .map(|i| {
                let c = 64 + 8 * (i % 4);
                let out = (8 * 8 * c) as u64;
                LayerProfile {
                    name: format!("l{i}"),
                    kind: LayerKind::Conv,
                    out_shape: (8, 8, c),
                    act_elems: out * 2,
                    params: (c * 9) as u64,
                    flops_per_image: c as u64 * 10_000,
                }
            })
            .collect();
        ArchProfile { name: format!("chain{depth}"), input: (8, 8, 3), layers }
    }

    /// Parameter-heavy chain: per-layer param bytes rival activation
    /// bytes, so resident gradients set the floor at the optimizer step.
    fn param_heavy_chain(depth: usize) -> ArchProfile {
        let layers = (0..depth)
            .map(|i| {
                let out = (8 * 8 * 64) as u64;
                LayerProfile {
                    name: format!("fc{i}"),
                    kind: LayerKind::Dense,
                    out_shape: (8, 8, 64),
                    act_elems: out * 2,
                    // ≈ batch·act bytes worth of parameters per layer
                    params: out * 16,
                    flops_per_image: 2_000_000,
                }
            })
            .collect();
        ArchProfile { name: format!("fc_chain{depth}"), input: (8, 8, 3), layers }
    }

    #[test]
    fn joint_matches_or_beats_sequential_on_checkpoint_heavy_chain() {
        let arch = uniform_chain(24);
        let (_, layout) = plan_arena(&arch, sc(), 16, &(0..23).collect::<Vec<_>>());
        let model = OverlapModel::default();
        for frac in [4u64, 3, 2] {
            let budget = layout.total_bytes() * frac / 5;
            let seq = select_for_budget(&arch, sc(), 16, budget, 2, &model);
            let joint = plan_joint(&arch, sc(), 16, budget, 2, &model, true);
            match (seq, joint) {
                (Ok(s), Ok(j)) => {
                    assert!(
                        j.overlap.predicted_step_secs <= s.overlap.predicted_step_secs,
                        "budget {budget}: joint {} > seq {}",
                        j.overlap.predicted_step_secs,
                        s.overlap.predicted_step_secs
                    );
                    assert!(j.spill.device_total() <= budget);
                    validate(&j.spill.lifetimes, &j.spill.layout).unwrap();
                }
                (Err(_), Ok(j)) => assert!(j.spill.device_total() <= budget),
                (Ok(_), Err(e)) => {
                    panic!("joint infeasible where sequential fits: {e}")
                }
                (Err(_), Err(_)) => {}
            }
        }
    }

    #[test]
    fn grad_spill_reaches_below_the_sequential_floor() {
        let arch = param_heavy_chain(12);
        let model = OverlapModel::default();
        // The sequential floor: every frontier point with every cold
        // checkpoint spilled still keeps all param-gradients resident.
        let seq_floor = match select_for_budget(&arch, sc(), 16, 1, 2, &model) {
            Err(e) => e.min_device_bytes,
            Ok(_) => panic!("1-byte budget cannot be feasible"),
        };
        let budget = seq_floor - 1;
        assert!(
            select_for_budget(&arch, sc(), 16, budget, 2, &model).is_err(),
            "budget just below the sequential floor must be sequentially infeasible"
        );
        let j = plan_joint(&arch, sc(), 16, budget, 2, &model, true)
            .expect("grad spilling reaches below the sequential floor");
        assert!(j.spill.device_total() <= budget);
        assert!(
            j.spill.steps.iter().any(|s| s.class == SpillClass::ParamGrad),
            "the win must come from param-gradient spills: {:?}",
            j.spill.steps
        );
        validate(&j.spill.lifetimes, &j.spill.layout).unwrap();
        // with grad_spill off the same budget stays infeasible
        assert!(plan_joint(&arch, sc(), 16, budget, 2, &model, false).is_err());
    }

    #[test]
    fn joint_is_deterministic() {
        let arch = param_heavy_chain(10);
        let (_, layout) = plan_arena(&arch, sc(), 16, &(0..9).collect::<Vec<_>>());
        let budget = layout.total_bytes() / 2;
        let model = OverlapModel::default();
        let a = plan_joint(&arch, sc(), 16, budget, 2, &model, true).unwrap();
        let b = plan_joint(&arch, sc(), 16, budget, 2, &model, true).unwrap();
        assert_eq!(a.plan.checkpoints, b.plan.checkpoints);
        assert_eq!(a.spill.steps, b.spill.steps);
        assert_eq!(a.spill.layout.offsets, b.spill.layout.offsets);
        assert_eq!(a.overlap.predicted_step_secs, b.overlap.predicted_step_secs);
    }

    #[test]
    fn generous_budget_degenerates_to_the_cheapest_pure_plan() {
        let arch = uniform_chain(8);
        let model = OverlapModel::default();
        let j = plan_joint(&arch, sc(), 8, u64::MAX, 2, &model, true).unwrap();
        assert!(!j.is_spill());
        assert_eq!(j.plan.recompute_overhead, 0.0);
        assert_eq!(j.overlap.stall_secs, 0.0);
    }

    #[test]
    fn impossible_budget_reports_the_joint_floor() {
        let arch = param_heavy_chain(8);
        let model = OverlapModel::default();
        let err = plan_joint(&arch, sc(), 16, 1, 2, &model, true).unwrap_err();
        assert_eq!(err.budget, 1);
        assert!(err.min_device_bytes > 1);
        // the joint floor is at or below the sequential one
        let seq = select_for_budget(&arch, sc(), 16, 1, 2, &model).unwrap_err();
        assert!(err.min_device_bytes <= seq.min_device_bytes);
    }
}
