//! Training-memory model: the substrate behind Figures 8, 10 and 11.
//!
//! The paper measures GPU memory; this environment has none, so the
//! figures are regenerated from an analytic simulator that replays the
//! exact schedules the pipelines induce (DESIGN.md §5). The simulator is
//! cross-validated against XLA's `compiled.memory_analysis()` on the
//! trainable minis (`python/tests/test_remat_memory.py`).
//!
//! On top of the byte accounting, [`arena`] turns a checkpoint plan into a
//! concrete memory layout: per-tensor lifetimes, slab offset assignment,
//! and the generation-tagged runtime allocator the train step stages
//! buffers through. [`offload`] goes one step further down the hierarchy:
//! when the device budget sits below even the packed slab, it evicts the
//! coldest checkpoints to host memory with a double-buffered prefetch
//! schedule and an honest stall prediction. [`joint`] folds the two
//! decisions into one optimizer — keep / recompute / spill per tensor,
//! param-gradients included — that never predicts a slower step than the
//! sequential plan-then-spill composition.
//!
//! **The primary surface is [`pipeline`]**: one typed
//! [`PlanRequest`](pipeline::PlanRequest) stages the whole
//! plan → pack → spill composition into a
//! [`PlanOutcome`](outcome::PlanOutcome) — the trainer, the `plan` CLI
//! and the memory benches all plan through it. The per-subsystem free
//! functions below it are the documented low-level API.

pub mod arena;
pub mod joint;
pub mod offload;
pub mod outcome;
pub mod peak;
pub mod pipeline;
pub mod planner;
pub mod simulator;
