//! Runtime half of the offload engine: a recycled host-buffer pool and
//! the per-train-step evict/prefetch replay.
//!
//! The planning half ([`plan`](crate::memory::offload::plan)) decides
//! which checkpoint ranges leave the device and when; this module owns
//! the host side of those transfers. [`HostSpillPool`] recycles
//! capacity-retaining byte buffers (the stand-in for pinned allocations —
//! pinning is a PJRT-backend property this build cannot reach), so after
//! the first training step every eviction lands in a reused buffer and
//! the hot loop performs no host allocation. [`OffloadEngine`] replays a
//! [`SpillPlan`]'s transfer schedule once per training step from the
//! `LoadedModel` step flow, keeping eviction/prefetch/byte counters the
//! trainer surfaces in `TrainReport::offload`.

use crate::fault::{link_draw, LinkOutcome};
use crate::memory::offload::plan::SpillPlan;
use crate::memory::offload::schedule::{TransferKind, DEFAULT_HOST_BW_BYTES_PER_SEC};
use crate::trace::ThreadTracer;

/// Recycled host staging buffers, bucketed by capacity best-fit.
#[derive(Debug, Default)]
pub struct HostSpillPool {
    free: Vec<Vec<u8>>,
    allocs: u64,
    reuses: u64,
}

impl HostSpillPool {
    pub fn new() -> HostSpillPool {
        HostSpillPool::default()
    }

    /// A buffer with at least `bytes` capacity: the smallest recycled one
    /// that fits, or a fresh allocation (counted).
    pub fn acquire(&mut self, bytes: usize) -> Vec<u8> {
        let mut pick: Option<usize> = None;
        for (i, b) in self.free.iter().enumerate() {
            if b.capacity() < bytes {
                continue;
            }
            let better = match pick {
                Some(p) => b.capacity() < self.free[p].capacity(),
                None => true,
            };
            if better {
                pick = Some(i);
            }
        }
        match pick {
            Some(i) => {
                self.reuses += 1;
                let mut b = self.free.swap_remove(i);
                b.clear();
                b
            }
            None => {
                self.allocs += 1;
                Vec::with_capacity(bytes)
            }
        }
    }

    /// Return a spent buffer for reuse (capacity is kept).
    pub fn release(&mut self, buf: Vec<u8>) {
        self.free.push(buf);
    }

    /// Fresh allocations performed so far. Flat across steps ⇒ every
    /// eviction reused a recycled buffer.
    pub fn allocs(&self) -> u64 {
        self.allocs
    }

    /// Requests served from the free list.
    pub fn reuses(&self) -> u64 {
        self.reuses
    }

    /// Idle recycled buffers currently held.
    pub fn free_buffers(&self) -> usize {
        self.free.len()
    }

    /// `reuses / (allocs + reuses)`; 0.0 before any request.
    pub fn hit_rate(&self) -> f64 {
        let total = self.allocs + self.reuses;
        if total == 0 {
            0.0
        } else {
            self.reuses as f64 / total as f64
        }
    }
}

/// Injected host-link fault model plus the engine's retry policy
/// (`None` on the engine ⇒ a perfect link, the historical behavior).
/// The numbers mirror a parsed `FaultSpec`'s link events; keeping them
/// as plain fields lets the engine draw outcomes statelessly via
/// [`link_draw`], so a replayed step sees identical faults regardless of
/// thread timing.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct LinkFaults {
    /// Seed forwarded into every stateless draw.
    pub seed: u64,
    /// Per-attempt transfer failure probability.
    pub fail_prob: f64,
    /// `(probability, slowdown factor ≥ 1)` of a degraded transfer.
    pub slow: (f64, f64),
    /// Retry attempts allowed per transfer beyond the first.
    pub max_retries: u32,
    /// Modeled link bandwidth used to charge retried / slowed transfers
    /// as stall seconds.
    pub bytes_per_sec: f64,
}

impl Default for LinkFaults {
    fn default() -> LinkFaults {
        LinkFaults {
            seed: 0,
            fail_prob: 0.0,
            slow: (0.0, 1.0),
            max_retries: DEFAULT_MAX_TRANSFER_RETRIES,
            bytes_per_sec: DEFAULT_HOST_BW_BYTES_PER_SEC as f64,
        }
    }
}

/// Default bounded-retry budget per transfer.
pub const DEFAULT_MAX_TRANSFER_RETRIES: u32 = 3;

/// Base backoff delay charged after a failed attempt; doubles per
/// consecutive failure of the same transfer (bounded by `max_retries`).
const BACKOFF_BASE_SECS: f64 = 1e-4;

/// A transfer that kept failing past the engine's retry budget.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct TransferError {
    pub kind: TransferKind,
    /// Training step (engine replay count) the transfer belonged to.
    pub step: u64,
    /// Spill-plan slot of the tensor being moved.
    pub slot: usize,
    /// Attempts made (1 initial + retries).
    pub attempts: u32,
}

impl std::fmt::Display for TransferError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        let dir = match self.kind {
            TransferKind::Evict => "eviction",
            TransferKind::Prefetch => "prefetch",
        };
        write!(
            f,
            "host-link {dir} of spill slot {} failed {} attempts at train step {}",
            self.slot, self.attempts, self.step
        )
    }
}

impl std::error::Error for TransferError {}

/// Counter snapshot of one engine (surfaced via `TrainReport::offload`).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct OffloadStats {
    /// Training steps the engine has replayed.
    pub steps: u64,
    pub evictions: u64,
    pub prefetches: u64,
    pub bytes_evicted: u64,
    pub bytes_prefetched: u64,
    pub pool_allocs: u64,
    pub pool_reuses: u64,
    /// Injected link faults observed (failed or slowed attempts).
    pub link_faults: u64,
    /// Transfer attempts retried after a failure.
    pub link_retries: u64,
    /// Stall seconds charged to retries, backoff and slowed transfers.
    pub retry_stall_secs: f64,
    /// Peak concurrent host-resident bytes observed within any step —
    /// the runtime's answer to the plan's `host_peak_bytes` prediction.
    pub host_resident_peak_bytes: u64,
}

impl OffloadStats {
    /// Host-pool recycle hit rate over the whole run.
    pub fn hit_rate(&self) -> f64 {
        let total = self.pool_allocs + self.pool_reuses;
        if total == 0 {
            0.0
        } else {
            self.pool_reuses as f64 / total as f64
        }
    }
}

/// One transfer of the engine's per-step replay, in schedule-step order.
#[derive(Clone, Copy, Debug)]
struct EngineOp {
    kind: TransferKind,
    /// Index into the plan's spill steps (the host-buffer slot).
    slot: usize,
    bytes: usize,
}

/// Replays a spill plan's transfer schedule against the host pool once
/// per training step.
#[derive(Debug)]
pub struct OffloadEngine {
    ops: Vec<EngineOp>,
    /// Host buffer currently holding each spilled tensor (between its
    /// eviction and its prefetch within one step).
    held: Vec<Option<Vec<u8>>>,
    pool: HostSpillPool,
    /// Injected link fault model (`None` = perfect link).
    link: Option<LinkFaults>,
    /// Trace buffer for transfer spans and link-fault instants (`None` =
    /// untraced, the zero-cost default).
    trace: Option<ThreadTracer>,
    steps: u64,
    evictions: u64,
    prefetches: u64,
    bytes_evicted: u64,
    bytes_prefetched: u64,
    link_faults: u64,
    link_retries: u64,
    retry_stall_secs: f64,
    /// In-step host-resident high-water of the most recent step (the
    /// held buffers all drain by step end, so this must be tracked
    /// inside the replay, not sampled at step boundaries).
    last_step_host_peak: u64,
    /// Run-global max of `last_step_host_peak`.
    host_resident_peak: u64,
}

impl OffloadEngine {
    pub fn new(plan: &SpillPlan) -> OffloadEngine {
        // Order transfers by schedule step; a prefetch (release) that
        // shares a step with an eviction (acquire) runs first so the
        // freed buffer is immediately reusable.
        let mut keyed: Vec<(usize, bool, EngineOp)> = Vec::with_capacity(2 * plan.steps.len());
        for (slot, s) in plan.steps.iter().enumerate() {
            let bytes = s.bytes as usize;
            keyed.push((s.evict_step, true, EngineOp { kind: TransferKind::Evict, slot, bytes }));
            keyed.push((
                s.need_step,
                false,
                EngineOp { kind: TransferKind::Prefetch, slot, bytes },
            ));
        }
        keyed.sort_unstable_by_key(|&(step, acquire, op)| (step, acquire, op.slot));
        OffloadEngine {
            ops: keyed.into_iter().map(|(_, _, op)| op).collect(),
            held: vec![None; plan.steps.len()],
            pool: HostSpillPool::new(),
            link: None,
            trace: None,
            steps: 0,
            evictions: 0,
            prefetches: 0,
            bytes_evicted: 0,
            bytes_prefetched: 0,
            link_faults: 0,
            link_retries: 0,
            retry_stall_secs: 0.0,
            last_step_host_peak: 0,
            host_resident_peak: 0,
        }
    }

    /// [`OffloadEngine::new`] with an injected link fault model.
    pub fn with_link_faults(plan: &SpillPlan, link: LinkFaults) -> OffloadEngine {
        let mut e = OffloadEngine::new(plan);
        e.link = Some(link);
        e
    }

    /// Install or clear the injected link fault model.
    pub fn set_link_faults(&mut self, link: Option<LinkFaults>) {
        self.link = link;
    }

    /// Install a per-thread trace buffer: every replayed transfer lands as
    /// an `evict`/`prefetch` span (bytes attached) and link faults as
    /// `link-slow` / `link-retry` / `link-giveup` instants. The buffer
    /// flushes to its parent [`crate::trace::Tracer`] when the engine is
    /// dropped or replaced (a replan builds a fresh engine, so callers
    /// re-install after `configure_offload`).
    pub fn set_tracer(&mut self, trace: ThreadTracer) {
        self.trace = Some(trace);
    }

    /// Replay one training step's evictions and prefetches, retrying
    /// failed transfers with exponential backoff (both charged as stall
    /// seconds). `Err` means a transfer kept failing past the retry
    /// budget — the step still completed the remaining transfers, and a
    /// given-up eviction simply leaves its tensor device-resident (its
    /// paired prefetch becomes a no-op), so the engine stays consistent.
    pub fn try_step(&mut self) -> Result<(), TransferError> {
        let step = self.steps;
        let step_t0 = match self.trace.as_ref() {
            Some(t) => t.begin(),
            None => 0,
        };
        let ops = &self.ops;
        let pool = &mut self.pool;
        let held = &mut self.held;
        let link = self.link;
        let mut evictions = 0u64;
        let mut prefetches = 0u64;
        let mut bytes_evicted = 0u64;
        let mut bytes_prefetched = 0u64;
        let mut link_faults = 0u64;
        let mut link_retries = 0u64;
        let mut retry_stall = 0.0f64;
        let mut resident = 0u64;
        let mut resident_peak = 0u64;
        let mut first_err: Option<TransferError> = None;
        for op in ops {
            let op_t0 = match self.trace.as_ref() {
                Some(t) => t.begin(),
                None => 0,
            };
            let mut gave_up = false;
            if let Some(lf) = link {
                // Decorrelate the two transfers of one slot within a step.
                let hslot =
                    (op.slot as u64) * 2 + u64::from(op.kind == TransferKind::Prefetch);
                let bw = lf.bytes_per_sec.max(1.0);
                let mut attempt = 0u32;
                loop {
                    match link_draw(lf.seed, lf.fail_prob, lf.slow, step, hslot, attempt as u64)
                    {
                        LinkOutcome::Healthy => break,
                        LinkOutcome::Slow(factor) => {
                            // Completes, but occupies the link longer.
                            link_faults += 1;
                            retry_stall += (factor - 1.0).max(0.0) * op.bytes as f64 / bw;
                            if let Some(t) = self.trace.as_mut() {
                                t.instant_arg("link-slow", "offload", Some(("factor", factor)));
                            }
                            break;
                        }
                        LinkOutcome::Fail => {
                            link_faults += 1;
                            // The failed attempt occupied the link, then
                            // the engine backs off exponentially.
                            retry_stall += op.bytes as f64 / bw
                                + BACKOFF_BASE_SECS * f64::from(1u32 << attempt.min(16));
                            if attempt >= lf.max_retries {
                                gave_up = true;
                                if first_err.is_none() {
                                    first_err = Some(TransferError {
                                        kind: op.kind,
                                        step,
                                        slot: op.slot,
                                        attempts: attempt + 1,
                                    });
                                }
                                if let Some(t) = self.trace.as_mut() {
                                    t.instant_arg(
                                        "link-giveup",
                                        "offload",
                                        Some(("attempts", f64::from(attempt + 1))),
                                    );
                                }
                                break;
                            }
                            link_retries += 1;
                            if let Some(t) = self.trace.as_mut() {
                                t.instant_arg(
                                    "link-retry",
                                    "offload",
                                    Some(("attempt", f64::from(attempt + 1))),
                                );
                            }
                            attempt += 1;
                        }
                    }
                }
            }
            if gave_up {
                continue;
            }
            match op.kind {
                TransferKind::Evict => {
                    held[op.slot] = Some(pool.acquire(op.bytes));
                    evictions += 1;
                    bytes_evicted += op.bytes as u64;
                    resident += op.bytes as u64;
                    resident_peak = resident_peak.max(resident);
                }
                TransferKind::Prefetch => {
                    if let Some(buf) = held[op.slot].take() {
                        pool.release(buf);
                        prefetches += 1;
                        bytes_prefetched += op.bytes as u64;
                        resident = resident.saturating_sub(op.bytes as u64);
                    }
                }
            }
            if let Some(t) = self.trace.as_mut() {
                let name = match op.kind {
                    TransferKind::Evict => "evict",
                    TransferKind::Prefetch => "prefetch",
                };
                t.end_span_arg(name, "offload", op_t0, Some(("bytes", op.bytes as f64)));
            }
        }
        if let Some(t) = self.trace.as_mut() {
            if !ops.is_empty() {
                t.end_span_arg("offload-step", "offload", step_t0, Some(("step", step as f64)));
            }
        }
        self.evictions += evictions;
        self.prefetches += prefetches;
        self.bytes_evicted += bytes_evicted;
        self.bytes_prefetched += bytes_prefetched;
        self.link_faults += link_faults;
        self.link_retries += link_retries;
        self.retry_stall_secs += retry_stall;
        self.last_step_host_peak = resident_peak;
        self.host_resident_peak = self.host_resident_peak.max(resident_peak);
        self.steps += 1;
        match first_err {
            None => Ok(()),
            Some(e) => Err(e),
        }
    }

    /// Replay one training step's evictions and prefetches. Infallible
    /// convenience over [`OffloadEngine::try_step`]: a transfer that
    /// exhausts its retries is skipped (still counted in the stats).
    pub fn run_step(&mut self) {
        let _ = self.try_step();
    }

    pub fn stats(&self) -> OffloadStats {
        OffloadStats {
            steps: self.steps,
            evictions: self.evictions,
            prefetches: self.prefetches,
            bytes_evicted: self.bytes_evicted,
            bytes_prefetched: self.bytes_prefetched,
            pool_allocs: self.pool.allocs(),
            pool_reuses: self.pool.reuses(),
            link_faults: self.link_faults,
            link_retries: self.link_retries,
            retry_stall_secs: self.retry_stall_secs,
            host_resident_peak_bytes: self.host_resident_peak,
        }
    }

    /// In-step host-resident high-water of the most recent step (0
    /// before the first step or when the plan does not spill).
    pub fn last_step_host_peak_bytes(&self) -> u64 {
        self.last_step_host_peak
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pipeline;
    use crate::memory::arena::plan_arena;
    use crate::memory::offload::plan::plan_spill;
    use crate::models::{ArchProfile, LayerKind, LayerProfile};

    fn chain(depth: usize) -> ArchProfile {
        let layers = (0..depth)
            .map(|i| {
                let out = (8 * 8 * 64) as u64;
                LayerProfile {
                    name: format!("l{i}"),
                    kind: LayerKind::Conv,
                    out_shape: (8, 8, 64),
                    act_elems: out * 2,
                    params: 512,
                    flops_per_image: 1_000_000,
                }
            })
            .collect();
        ArchProfile { name: format!("chain{depth}"), input: (8, 8, 3), layers }
    }

    fn spilled_plan() -> SpillPlan {
        let sc = Pipeline::parse("sc").unwrap();
        let arch = chain(24);
        let cps: Vec<usize> = (0..23).collect();
        let (_, layout) = plan_arena(&arch, sc, 16, &cps);
        let budget = (layout.total_bytes() * 3) / 5;
        plan_spill(&arch, sc, 16, &cps, budget, 2).unwrap()
    }

    #[test]
    fn pool_reuses_buffers_best_fit() {
        let mut pool = HostSpillPool::new();
        let a = pool.acquire(100);
        let b = pool.acquire(50);
        assert_eq!(pool.allocs(), 2);
        pool.release(a);
        pool.release(b);
        // 60 B fits only the 100-cap buffer; 10 B best-fits the 50-cap one
        let c = pool.acquire(60);
        assert!(c.capacity() >= 100);
        let d = pool.acquire(10);
        assert!(d.capacity() >= 50 && d.capacity() < 100);
        assert_eq!(pool.reuses(), 2);
        assert_eq!(pool.allocs(), 2);
        assert!((pool.hit_rate() - 0.5).abs() < 1e-12);
    }

    #[test]
    fn engine_pairs_every_evict_with_a_prefetch() {
        let plan = spilled_plan();
        let n = plan.steps.len() as u64;
        assert!(n > 0);
        let mut engine = OffloadEngine::new(&plan);
        engine.run_step();
        let s = engine.stats();
        assert_eq!(s.steps, 1);
        assert_eq!(s.evictions, n);
        assert_eq!(s.prefetches, n);
        assert_eq!(s.bytes_evicted, plan.spilled_bytes);
        assert_eq!(s.bytes_prefetched, plan.spilled_bytes);
        // every host buffer returned to the pool at step end
        assert!(engine.held.iter().all(Option::is_none));
        // in-step host residency was observed and never exceeded the
        // plan's predicted host peak
        assert!(engine.last_step_host_peak_bytes() > 0);
        assert!(s.host_resident_peak_bytes <= plan.host_peak_bytes);
        assert_eq!(s.host_resident_peak_bytes, engine.last_step_host_peak_bytes());
    }

    #[test]
    fn steady_state_runs_entirely_from_recycled_buffers() {
        let plan = spilled_plan();
        let mut engine = OffloadEngine::new(&plan);
        engine.run_step();
        let warm_allocs = engine.stats().pool_allocs;
        for _ in 0..64 {
            engine.run_step();
        }
        let s = engine.stats();
        assert_eq!(s.pool_allocs, warm_allocs, "steady state allocated");
        assert!(s.pool_reuses > 0);
        assert!(s.hit_rate() > 0.9, "{}", s.hit_rate());
    }

    #[test]
    fn empty_plan_engine_is_a_noop() {
        let sc = Pipeline::parse("sc").unwrap();
        let arch = chain(8);
        let cps: Vec<usize> = (0..7).collect();
        let plan = plan_spill(&arch, sc, 4, &cps, u64::MAX, 2).unwrap();
        let mut engine = OffloadEngine::new(&plan);
        engine.run_step();
        let s = engine.stats();
        assert_eq!(s.evictions, 0);
        assert_eq!(s.pool_allocs, 0);
        assert_eq!(s.steps, 1);
    }

    #[test]
    fn link_fault_outcomes_are_deterministic() {
        let plan = spilled_plan();
        let lf = LinkFaults { seed: 7, fail_prob: 0.3, slow: (0.2, 4.0), ..LinkFaults::default() };
        let mut a = OffloadEngine::with_link_faults(&plan, lf);
        let mut b = OffloadEngine::with_link_faults(&plan, lf);
        for _ in 0..16 {
            assert_eq!(a.try_step(), b.try_step());
        }
        assert_eq!(a.stats(), b.stats());
        assert!(a.stats().link_faults > 0, "p=0.3 over 16 steps must fault");
        assert!(a.stats().link_retries > 0);
    }

    #[test]
    fn dead_link_gives_up_typed_and_stays_consistent() {
        let plan = spilled_plan();
        let lf = LinkFaults { seed: 1, fail_prob: 1.0, ..LinkFaults::default() };
        let mut engine = OffloadEngine::with_link_faults(&plan, lf);
        let err = engine.try_step().unwrap_err();
        assert_eq!(err.attempts, DEFAULT_MAX_TRANSFER_RETRIES + 1);
        assert!(err.to_string().contains("failed"), "{err}");
        let s = engine.stats();
        assert_eq!(s.evictions, 0, "every transfer gave up");
        assert!(engine.held.iter().all(Option::is_none));
        assert!(s.retry_stall_secs > 0.0);
        engine.run_step(); // infallible path must absorb the same failure
        assert_eq!(engine.stats().steps, 2);
    }

    #[test]
    fn traced_engine_emits_one_span_per_transfer() {
        let plan = spilled_plan();
        let n = plan.steps.len();
        let tr = crate::trace::Tracer::enabled();
        let mut engine = OffloadEngine::new(&plan);
        engine.set_tracer(tr.thread("offload/link"));
        engine.run_step();
        drop(engine); // flushes the thread buffer to the collector
        let log = tr.drain();
        assert_eq!(log.tracks.len(), 1);
        assert_eq!(log.tracks[0].name, "offload/link");
        let evicts = log.tracks[0].events.iter().filter(|e| e.name == "evict").count();
        let prefetches =
            log.tracks[0].events.iter().filter(|e| e.name == "prefetch").count();
        assert_eq!(evicts, n);
        assert_eq!(prefetches, n);
        assert_eq!(
            log.tracks[0].events.iter().filter(|e| e.name == "offload-step").count(),
            1
        );
    }

    #[test]
    fn traced_dead_link_records_giveups() {
        let plan = spilled_plan();
        let lf = LinkFaults { seed: 1, fail_prob: 1.0, ..LinkFaults::default() };
        let tr = crate::trace::Tracer::enabled();
        let mut engine = OffloadEngine::with_link_faults(&plan, lf);
        engine.set_tracer(tr.thread("offload/link"));
        engine.run_step();
        let stats = engine.stats();
        drop(engine);
        let log = tr.drain();
        let giveups =
            log.tracks[0].events.iter().filter(|e| e.name == "link-giveup").count() as u64;
        let retries =
            log.tracks[0].events.iter().filter(|e| e.name == "link-retry").count() as u64;
        assert!(giveups > 0);
        assert_eq!(retries, stats.link_retries);
        assert_eq!(
            log.tracks[0].events.iter().filter(|e| e.name == "evict").count(),
            0,
            "a dead link completes no transfers, so no spans"
        );
    }

    #[test]
    fn slow_link_completes_with_stall_accounting() {
        let plan = spilled_plan();
        let n = plan.steps.len() as u64;
        let lf = LinkFaults { seed: 2, fail_prob: 0.0, slow: (1.0, 8.0), ..LinkFaults::default() };
        let mut engine = OffloadEngine::with_link_faults(&plan, lf);
        engine.try_step().unwrap();
        let s = engine.stats();
        assert_eq!(s.evictions, n);
        assert_eq!(s.prefetches, n);
        assert_eq!(s.link_retries, 0, "slowdowns complete without retrying");
        assert!(s.retry_stall_secs > 0.0);
    }
}
