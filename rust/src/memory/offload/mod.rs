//! Host-spill offload: close the gap between the planner's peak and a
//! sub-slab device budget.
//!
//! PR 2's DP planner proves the minimum *simulated* peak of any pure
//! recompute schedule, and PR 3's arena packs it into concrete bytes.
//! When `memory_budget` sits below what recompute alone can reach, the
//! remaining lever is tensor *location* (Beaumont et al. 2019; Shah et
//! al. 2020, MONeT): cold checkpoints sit idle from just after their
//! forward use until the backward pass returns to their segment, and can
//! live in host memory across that window. Three layers:
//!
//! 1. [`plan`] — the spill planner: greedy coldest-first eviction over a
//!    plan's checkpoint lifetimes until the re-packed *resident* layout
//!    fits the budget ([`SpillPlan`]), or a typed [`InfeasibleBudget`].
//! 2. [`schedule`] — the prefetch scheduler: a double-buffered transfer
//!    timeline over one serial host link, predicting stall seconds so
//!    spill plans and recompute plans are compared in the same unit
//!    ([`OverlapReport`]).
//! 3. [`host_pool`] — the runtime half: a recycled host-buffer pool and
//!    the per-train-step evict/prefetch replay hooked into
//!    `LoadedModel` ([`OffloadEngine`]).
//!
//! [`select_for_budget`] is the composition the trainer and the
//! `plan --spill` CLI share: rank every Pareto-frontier point by its
//! *packed* total, compose the cheapest spill plan for each, and pick
//! the minimum predicted step time among everything that fits.

pub mod host_pool;
pub mod plan;
pub mod schedule;

pub use host_pool::{
    HostSpillPool, LinkFaults, OffloadEngine, OffloadStats, TransferError,
    DEFAULT_MAX_TRANSFER_RETRIES,
};
pub use plan::{plan_spill, InfeasibleBudget, SpillClass, SpillPlan, SpillStep};
pub use schedule::{
    simulate_overlap, step_flops, OverlapModel, OverlapReport, Transfer, TransferKind,
    DEFAULT_DEVICE_FLOPS_PER_SEC, DEFAULT_HOST_BW_BYTES_PER_SEC,
};

use crate::config::Pipeline;
use crate::memory::planner::{pareto_frontier, CheckpointPlan, DEFAULT_FRONTIER_LEVELS};
use crate::models::ArchProfile;

/// The budget-constrained choice: a frontier point plus the (possibly
/// empty) spill composition that makes it fit.
#[derive(Clone, Debug)]
pub struct BudgetDecision {
    /// The chosen checkpoint plan.
    pub plan: CheckpointPlan,
    /// Its spill plan; `steps` is empty when the packed layout fit the
    /// budget without host spilling.
    pub spill: SpillPlan,
    /// The simulated transfer/stall timeline for the choice.
    pub overlap: OverlapReport,
}

impl BudgetDecision {
    /// Whether the decision actually moves bytes to the host.
    pub fn is_spill(&self) -> bool {
        !self.spill.steps.is_empty()
    }
}

/// Summary of a spill decision for `TrainReport::offload` and the
/// markdown report. The three runtime counters are zero until a run
/// finishes and the trainer folds the engine's stats in.
#[derive(Clone, Debug)]
pub struct OffloadReport {
    pub budget: u64,
    /// Device bytes actually reserved: static base + resident slab.
    pub device_total: u64,
    pub spilled_tensors: usize,
    /// How many of `spilled_tensors` are param-gradients (joint planner
    /// with `grad_spill`; always 0 for the sequential pipeline).
    pub spilled_grad_tensors: usize,
    pub spilled_bytes: u64,
    pub host_peak_bytes: u64,
    pub predicted_stall_secs: f64,
    pub predicted_step_secs: f64,
    pub host_bw_bytes_per_sec: u64,
    pub lookahead: usize,
    /// Runtime engine counters (filled in after the run).
    pub evictions: u64,
    pub prefetches: u64,
    pub pool_hit_rate: f64,
    /// Injected link faults the engine observed (failed/slowed attempts).
    pub link_faults: u64,
    /// Transfer attempts the engine retried after a failure.
    pub link_retries: u64,
    /// Stall seconds the engine charged to retries, backoff and slowed
    /// transfers.
    pub retry_stall_secs: f64,
}

impl OffloadReport {
    /// Build the plan-side half of the report from a spill plan and its
    /// simulated overlap timeline (runtime counters zeroed until a run
    /// folds the engine's stats in). The one `SpillPlan`/`OverlapReport`
    /// → report mapping — `from_decision` and
    /// [`PlanOutcome::offload_report`](crate::memory::outcome::PlanOutcome::offload_report)
    /// both delegate here.
    pub fn from_parts(
        spill: &SpillPlan,
        overlap: &OverlapReport,
        host_bw_bytes_per_sec: u64,
        lookahead: usize,
    ) -> OffloadReport {
        OffloadReport {
            budget: spill.budget,
            device_total: spill.device_total(),
            spilled_tensors: spill.steps.len(),
            spilled_grad_tensors: spill
                .steps
                .iter()
                .filter(|s| s.class == SpillClass::ParamGrad)
                .count(),
            spilled_bytes: spill.spilled_bytes,
            host_peak_bytes: spill.host_peak_bytes,
            predicted_stall_secs: overlap.stall_secs,
            predicted_step_secs: overlap.predicted_step_secs,
            host_bw_bytes_per_sec,
            lookahead,
            evictions: 0,
            prefetches: 0,
            pool_hit_rate: 0.0,
            link_faults: 0,
            link_retries: 0,
            retry_stall_secs: 0.0,
        }
    }

    /// [`OffloadReport::from_parts`] over a whole [`BudgetDecision`].
    pub fn from_decision(
        decision: &BudgetDecision,
        host_bw_bytes_per_sec: u64,
        lookahead: usize,
    ) -> OffloadReport {
        Self::from_parts(&decision.spill, &decision.overlap, host_bw_bytes_per_sec, lookahead)
    }

    /// Stall share of the predicted step time.
    pub fn stall_frac(&self) -> f64 {
        schedule::stall_fraction(self.predicted_stall_secs, self.predicted_step_secs)
    }
}

/// Choose the best plan for a device budget: every Pareto-frontier point
/// is packed (so fragmentation participates in the fit decision), the
/// cheapest spill composition is planned for each, and the candidate
/// with the minimum predicted step time wins — ties broken by lower
/// recompute FLOPs, then smaller device total, then frontier order.
/// Errors with the smallest achievable device total when no composition
/// fits.
pub fn select_for_budget(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    budget: u64,
    lookahead: usize,
    model: &OverlapModel,
) -> Result<BudgetDecision, InfeasibleBudget> {
    let frontier = pareto_frontier(arch, pipeline, batch, DEFAULT_FRONTIER_LEVELS);
    let mut best: Option<BudgetDecision> = None;
    let mut min_bytes = u64::MAX;
    for point in frontier {
        match plan_spill(arch, pipeline, batch, &point.checkpoints, budget, lookahead) {
            Ok(spill) => {
                let overlap = simulate_overlap(arch, batch, &spill, model);
                let replace = match &best {
                    None => true,
                    Some(b) => {
                        let cand = (
                            overlap.predicted_step_secs,
                            point.recompute_overhead,
                            spill.device_total(),
                        );
                        let cur = (
                            b.overlap.predicted_step_secs,
                            b.plan.recompute_overhead,
                            b.spill.device_total(),
                        );
                        cand.partial_cmp(&cur) == Some(std::cmp::Ordering::Less)
                    }
                };
                if replace {
                    best = Some(BudgetDecision { plan: point, spill, overlap });
                }
            }
            Err(e) => min_bytes = min_bytes.min(e.min_device_bytes),
        }
    }
    best.ok_or(InfeasibleBudget { budget, min_device_bytes: min_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::arena::{plan_arena, validate};
    use crate::models::{arch_by_name, LayerKind, LayerProfile};

    fn sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    fn chain(depth: usize) -> ArchProfile {
        let layers = (0..depth)
            .map(|i| {
                let out = (8 * 8 * 64) as u64;
                LayerProfile {
                    name: format!("l{i}"),
                    kind: LayerKind::Conv,
                    out_shape: (8, 8, 64),
                    act_elems: out * 2,
                    params: 512,
                    flops_per_image: 1_000_000,
                }
            })
            .collect();
        ArchProfile { name: format!("chain{depth}"), input: (8, 8, 3), layers }
    }

    #[test]
    fn generous_budget_picks_a_pure_plan() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let d = select_for_budget(&arch, sc(), 8, u64::MAX, 2, &OverlapModel::default())
            .unwrap();
        assert!(!d.is_spill());
        assert_eq!(d.overlap.stall_secs, 0.0);
        assert!(d.spill.fits());
        // unconstrained, the winner is the cheapest-time frontier point
        assert_eq!(d.plan.recompute_overhead, 0.0);
    }

    #[test]
    fn sub_slab_budget_composes_a_fitting_spill() {
        let arch = chain(32);
        // cheapest-memory pure point: its packed total is the floor any
        // recompute-only plan can reach
        let frontier =
            crate::memory::planner::pareto_frontier(&arch, sc(), 16, DEFAULT_FRONTIER_LEVELS);
        let min_total = frontier
            .iter()
            .map(|p| plan_arena(&arch, sc(), 16, &p.checkpoints).1.total_bytes())
            .min()
            .unwrap();
        let budget = (min_total * 3) / 5; // 60% — below every pure point
        let d = select_for_budget(&arch, sc(), 16, budget, 2, &OverlapModel::default()).unwrap();
        assert!(d.is_spill(), "no pure point fits 60% of the pure minimum");
        assert!(d.spill.device_total() <= budget);
        validate(&d.spill.lifetimes, &d.spill.layout).unwrap();
        assert!(d.overlap.predicted_step_secs >= d.overlap.compute_secs);
        let rep = OffloadReport::from_decision(&d, DEFAULT_HOST_BW_BYTES_PER_SEC, 2);
        assert_eq!(rep.device_total, d.spill.device_total());
        assert_eq!(rep.spilled_tensors, d.spill.steps.len());
        assert!(rep.stall_frac() >= 0.0 && rep.stall_frac() < 1.0);
    }

    #[test]
    fn impossible_budget_reports_the_spilled_floor() {
        let arch = chain(16);
        let err = select_for_budget(&arch, sc(), 16, 1, 2, &OverlapModel::default()).unwrap_err();
        assert_eq!(err.budget, 1);
        assert!(err.min_device_bytes > 1);
        assert!(err.to_string().contains("spilled to host"), "{err}");
    }

    #[test]
    fn decision_is_deterministic() {
        let arch = chain(32);
        let (_, layout) = plan_arena(&arch, sc(), 16, &(0..31).collect::<Vec<_>>());
        let budget = (layout.total_bytes() * 3) / 5;
        let a = select_for_budget(&arch, sc(), 16, budget, 2, &OverlapModel::default()).unwrap();
        let b = select_for_budget(&arch, sc(), 16, budget, 2, &OverlapModel::default()).unwrap();
        assert_eq!(a.plan.checkpoints, b.plan.checkpoints);
        assert_eq!(a.spill.steps, b.spill.steps);
        assert_eq!(a.spill.layout.offsets, b.spill.layout.offsets);
    }
}
