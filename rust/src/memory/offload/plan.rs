//! Spill planning: which checkpoints leave the device, and when.
//!
//! The activation arena proves a plan needs `base + slab` device bytes;
//! when the budget sits below that, the only remaining lever (short of a
//! different checkpoint plan) is *where* cold tensors live. A checkpoint
//! is written once in the forward pass, read once by the next layer's
//! forward, and then sits idle until the backward pass reaches its
//! segment — often the longest-lived, least-touched bytes in the whole
//! schedule (Beaumont et al. 2019). [`plan_spill`] evicts the coldest of
//! those intervals to host memory and re-packs the *resident* lifetimes:
//! each spilled checkpoint occupies the slab only during
//! `[forward, evict)` and `[prefetch, backward-use)`, so the packer can
//! hand its range to other tensors across the idle window.
//!
//! Eviction order is greedy-coldest: longest idle gap between the last
//! forward use and the first backward use, ties broken by
//! bytes-per-FLOP of the covering backward segment (cheaper-to-hide
//! transfers first), then by layer index — fully deterministic. The
//! planner evicts until `base + slab' ≤ budget` or every candidate is
//! spilled, in which case it returns the typed [`InfeasibleBudget`] error
//! carrying the smallest achievable device total.

use crate::config::Pipeline;
use crate::memory::arena::{pack, ArenaLayout, Lifetimes, ScheduleTimes, TensorClass, TensorLife};
use crate::memory::peak::PeakEvaluator;
use crate::models::ArchProfile;

/// What kind of tensor a spill step moves. Checkpoints were the original
/// (and sequential pipeline's only) candidates; the joint optimizer
/// ([`crate::memory::joint`]) adds param-gradients — idle from their
/// backward step until the optimizer step — whose spilled updates are
/// applied host-side (ZeRO-Offload style): the gradient leaves the slab at
/// its eviction and never returns, and the "prefetch" transfer models the
/// refreshed parameters copied back before the optimizer step completes.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub enum SpillClass {
    Checkpoint,
    ParamGrad,
}

impl SpillClass {
    pub fn name(self) -> &'static str {
        match self {
            SpillClass::Checkpoint => "checkpoint",
            SpillClass::ParamGrad => "param-grad",
        }
    }
}

/// One evicted tensor: the transfer endpoints in schedule steps.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpillStep {
    /// What is being spilled (checkpoint boundary or param-gradient).
    pub class: SpillClass,
    /// Layer whose tensor is spilled.
    pub layer: usize,
    /// Bytes moved each way.
    pub bytes: u64,
    /// Step at which the device copy is released (the copy-out is issued
    /// here; the overlap model treats it as write-behind).
    pub evict_step: usize,
    /// Step at which the prefetch is issued and the device range is
    /// reserved again (`lookahead` steps before the first backward use,
    /// clamped to the eviction).
    pub prefetch_step: usize,
    /// First backward-side step that reads the tensor (the segment's
    /// first recompute step, or its topmost backward step).
    pub need_step: usize,
    /// Idle steps between release and first backward use.
    pub gap_steps: usize,
}

/// A budget-fitting spill plan: resident lifetimes/layout plus the
/// evict/prefetch schedule that makes them valid.
#[derive(Clone, Debug)]
pub struct SpillPlan {
    /// Evicted checkpoints, sorted by layer. Empty when the plan already
    /// fit the budget without spilling.
    pub steps: Vec<SpillStep>,
    /// Device-resident lifetimes: spilled checkpoints split into their
    /// pre-evict and post-prefetch windows.
    pub lifetimes: Lifetimes,
    /// Packed layout of the resident lifetimes (`total_bytes() ≤ budget`
    /// whenever [`plan_spill`] returns `Ok`).
    pub layout: ArenaLayout,
    /// Event times of the underlying checkpoint schedule.
    pub times: ScheduleTimes,
    /// The device budget the plan was fit against.
    pub budget: u64,
    /// Total bytes spilled (one way).
    pub spilled_bytes: u64,
    /// Peak concurrent host bytes across the schedule.
    pub host_peak_bytes: u64,
}

impl SpillPlan {
    /// Device bytes the runtime reserves: static state + resident slab.
    pub fn device_total(&self) -> u64 {
        self.layout.total_bytes()
    }

    /// Whether the resident layout fits the budget.
    pub fn fits(&self) -> bool {
        self.device_total() <= self.budget
    }
}

/// Typed error: the budget cannot be met even with every cold checkpoint
/// on the host.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InfeasibleBudget {
    pub budget: u64,
    /// Smallest device total any spill composition of this plan reaches.
    pub min_device_bytes: u64,
}

impl std::fmt::Display for InfeasibleBudget {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "memory budget {} B is below the minimum achievable peak {} B even with \
             every cold checkpoint spilled to host",
            self.budget, self.min_device_bytes
        )
    }
}

impl std::error::Error for InfeasibleBudget {}

/// Spill candidate with its greedy sort key.
pub(crate) struct Candidate {
    pub(crate) step: SpillStep,
    /// Bytes transferred per FLOP of the covering backward segment —
    /// smaller is easier to hide behind compute.
    pub(crate) bytes_per_flop: f64,
}

/// Enumerate evictable checkpoints under `times` with their idle windows.
/// The final layer's checkpoint is never a candidate (the loss gradient
/// consumes it immediately), nor is any checkpoint whose idle window
/// collapses once `lookahead` is subtracted.
pub(crate) fn candidates(
    arch: &ArchProfile,
    ev: &PeakEvaluator,
    times: &ScheduleTimes,
    lookahead: usize,
) -> Vec<Candidate> {
    let n = ev.depth();
    let flops_prefix = arch.flops_prefix();
    let mut out: Vec<Candidate> = Vec::new();
    for i in 0..n.saturating_sub(1) {
        if !times.stored[i] || ev.out_bytes(i) == 0 {
            continue;
        }
        // The checkpoint feeds the backward segment (i..s]: its first
        // read is that segment's first recompute step, or the topmost
        // backward step when nothing is recomputed.
        let s = (i + 1..n).find(|&j| times.stored[j]).unwrap_or(n - 1);
        let need = (i + 1..=s).find_map(|j| times.t_rec[j]).unwrap_or(times.t_bwd[s]);
        // Device copy is last read by layer i+1's forward step.
        let evict = times.t_fwd[i + 1] + 1;
        if need <= evict {
            continue;
        }
        let prefetch = need.saturating_sub(lookahead).max(evict);
        if prefetch <= evict {
            continue; // window too short to free any slab bytes
        }
        let seg_flops = (flops_prefix[s + 1] - flops_prefix[i + 1]).max(1);
        out.push(Candidate {
            step: SpillStep {
                class: SpillClass::Checkpoint,
                layer: i,
                bytes: ev.out_bytes(i),
                evict_step: evict,
                prefetch_step: prefetch,
                need_step: need,
                gap_steps: need - evict,
            },
            bytes_per_flop: ev.out_bytes(i) as f64 / seg_flops as f64,
        });
    }
    out.sort_by(|a, b| {
        b.step
            .gap_steps
            .cmp(&a.step.gap_steps)
            .then(
                a.bytes_per_flop
                    .partial_cmp(&b.bytes_per_flop)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then(a.step.layer.cmp(&b.step.layer))
    });
    out
}

/// Enumerate spillable param-gradients under `times`. A gradient is
/// written at its layer's backward step and then sits idle until the
/// optimizer step — on parameter-heavy nets the dominant cold bytes of
/// the whole backward pass. Spilling one offloads its optimizer update to
/// the host: the gradient is copied out right after its backward step and
/// its slab range is free from then on; the paired "prefetch" transfer is
/// the refreshed parameters returning, due by the optimizer step
/// (`need_step = t_opt`). Layers whose backward runs too close to the
/// optimizer step (no window once `lookahead` is subtracted) are not
/// candidates. Sorted coldest-first like [`candidates`].
pub(crate) fn grad_candidates(
    arch: &ArchProfile,
    ev: &PeakEvaluator,
    times: &ScheduleTimes,
    lookahead: usize,
) -> Vec<Candidate> {
    let n = ev.depth();
    let flops_prefix = arch.flops_prefix();
    let total_flops = flops_prefix.last().copied().unwrap_or(0).max(1);
    let mut out: Vec<Candidate> = Vec::new();
    for i in 0..n {
        let bytes = ev.param_grad_bytes(i);
        if bytes == 0 {
            continue;
        }
        let evict = times.t_bwd[i] + 1;
        let need = times.t_opt;
        if need <= evict {
            continue;
        }
        let prefetch = need.saturating_sub(lookahead).max(evict);
        if prefetch <= evict {
            continue; // backward lands too close to the optimizer step
        }
        out.push(Candidate {
            step: SpillStep {
                class: SpillClass::ParamGrad,
                layer: i,
                bytes,
                evict_step: evict,
                prefetch_step: prefetch,
                need_step: need,
                gap_steps: need - evict,
            },
            // The idle window spans the remaining backward pass; rate the
            // transfer against the whole run's compute (the window's FLOPs
            // are a plan-dependent subset of it).
            bytes_per_flop: bytes as f64 / total_flops as f64,
        });
    }
    out.sort_by(|a, b| {
        b.step
            .gap_steps
            .cmp(&a.step.gap_steps)
            .then(
                a.bytes_per_flop
                    .partial_cmp(&b.bytes_per_flop)
                    .unwrap_or(std::cmp::Ordering::Equal),
            )
            .then(a.step.layer.cmp(&b.step.layer))
    });
    out
}

/// Split the spilled tensors' intervals into their device-resident
/// windows; everything else is untouched. A spilled checkpoint keeps two
/// windows (pre-evict and post-prefetch); a spilled param-gradient keeps
/// only its pre-evict window — its update is applied host-side and the
/// returning transfer refreshes the static parameter storage, not the
/// slab.
pub(crate) fn resident_lifetimes(lt: &Lifetimes, spilled: &[SpillStep]) -> Lifetimes {
    let mut out = lt.clone();
    for s in spilled {
        let class = match s.class {
            SpillClass::Checkpoint => TensorClass::Checkpoint,
            SpillClass::ParamGrad => TensorClass::ParamGrad,
        };
        let idx = out
            .tensors
            .iter()
            .position(|t| t.class == class && t.layer == s.layer)
            .expect("spilled tensor has a lifetime");
        let end = out.tensors[idx].end;
        out.tensors[idx].end = s.evict_step;
        if s.class == SpillClass::Checkpoint {
            out.tensors.push(TensorLife {
                class,
                layer: s.layer,
                bytes: s.bytes,
                start: s.prefetch_step,
                end,
            });
        }
    }
    out
}

/// Peak concurrent host bytes: each spilled tensor occupies host memory
/// from its eviction until its prefetch lands (conservatively, until its
/// first backward use).
pub(crate) fn host_peak(steps: &[SpillStep], total_steps: usize) -> u64 {
    let mut delta = vec![0i128; total_steps + 1];
    for s in steps {
        delta[s.evict_step] += s.bytes as i128;
        delta[s.need_step.min(total_steps)] -= s.bytes as i128;
    }
    let mut live = 0i128;
    let mut max = 0i128;
    for d in &delta {
        live += *d;
        max = max.max(live);
    }
    max as u64
}

/// Fit `checkpoints`' arena under `budget` device bytes by evicting the
/// coldest checkpoints to host (S-C forced on, mirroring `plan_arena`).
/// Returns a [`SpillPlan`] whose resident layout fits — possibly with no
/// evictions at all when the packed plan already fit — or the typed
/// [`InfeasibleBudget`] error when even full eviction cannot reach the
/// budget.
pub fn plan_spill(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    checkpoints: &[usize],
    budget: u64,
    lookahead: usize,
) -> Result<SpillPlan, InfeasibleBudget> {
    let mut p = pipeline;
    p.sc = true;
    let ev = PeakEvaluator::new(arch, p, batch);
    let times = ScheduleTimes::compute(&ev, checkpoints);
    let lt = Lifetimes::extract(&ev, checkpoints);
    let layout = pack(&lt);
    if layout.total_bytes() <= budget {
        return Ok(SpillPlan {
            steps: Vec::new(),
            lifetimes: lt,
            layout,
            times,
            budget,
            spilled_bytes: 0,
            host_peak_bytes: 0,
        });
    }
    let lookahead = lookahead.max(1);
    let cands = candidates(arch, &ev, &times, lookahead);
    // `chosen` is kept sorted by layer so every iteration's packed layout
    // is exactly the layout the returned plan would carry.
    let mut chosen: Vec<SpillStep> = Vec::new();
    let mut min_total = layout.total_bytes();
    for c in cands {
        let pos = chosen.partition_point(|s| s.layer < c.step.layer);
        chosen.insert(pos, c.step);
        let rl = resident_lifetimes(&lt, &chosen);
        let rlay = pack(&rl);
        min_total = min_total.min(rlay.total_bytes());
        if rlay.total_bytes() <= budget {
            let spilled_bytes = chosen.iter().map(|s| s.bytes).sum();
            let host_peak_bytes = host_peak(&chosen, times.steps);
            return Ok(SpillPlan {
                steps: chosen,
                lifetimes: rl,
                layout: rlay,
                times,
                budget,
                spilled_bytes,
                host_peak_bytes,
            });
        }
    }
    Err(InfeasibleBudget { budget, min_device_bytes: min_total })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::arena::{plan_arena, validate};
    use crate::memory::planner::{plan_checkpoints, PlannerKind};
    use crate::models::{arch_by_name, LayerKind, LayerProfile};

    fn sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    /// Uniform checkpoint-heavy chain: Σ boundary outputs dominates any
    /// single backward working set, so host-spill has real headroom (the
    /// regime the offload engine exists for; conv stems like resnet's pin
    /// their peak on one layer's working set instead).
    fn uniform_chain(depth: usize) -> ArchProfile {
        let layers = (0..depth)
            .map(|i| {
                let c = 64 + 8 * (i % 4);
                let out = (8 * 8 * c) as u64;
                LayerProfile {
                    name: format!("l{i}"),
                    kind: LayerKind::Conv,
                    out_shape: (8, 8, c),
                    act_elems: out * 2,
                    params: (c * 9) as u64,
                    flops_per_image: c as u64 * 10_000,
                }
            })
            .collect();
        ArchProfile { name: format!("chain{depth}"), input: (8, 8, 3), layers }
    }

    /// Store-everything plan: every interior layer checkpointed.
    fn all_stored(depth: usize) -> Vec<usize> {
        (0..depth - 1).collect()
    }

    #[test]
    fn generous_budget_needs_no_spill() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let plan = plan_checkpoints(&arch, PlannerKind::Optimal, sc(), 8);
        let spill = plan_spill(&arch, sc(), 8, &plan.checkpoints, u64::MAX, 2).unwrap();
        assert!(spill.steps.is_empty());
        assert!(spill.fits());
        assert_eq!(spill.spilled_bytes, 0);
    }

    #[test]
    fn tight_budget_spills_and_still_packs_soundly() {
        let arch = uniform_chain(24);
        let cps = all_stored(24);
        let (_, layout) = plan_arena(&arch, sc(), 16, &cps);
        // 60% of the packed zero-recompute total: well below the resident
        // checkpoints, well above one segment's working set
        let budget = (layout.total_bytes() * 3) / 5;
        let spill = plan_spill(&arch, sc(), 16, &cps, budget, 2).unwrap();
        assert!(!spill.steps.is_empty(), "a 60% budget must force evictions");
        assert!(spill.fits(), "{} > {}", spill.device_total(), budget);
        validate(&spill.lifetimes, &spill.layout).unwrap();
        for s in &spill.steps {
            assert!(s.evict_step < s.prefetch_step, "{s:?}");
            assert!(s.prefetch_step < s.need_step, "{s:?}");
            assert_eq!(s.gap_steps, s.need_step - s.evict_step, "{s:?}");
        }
        assert!(spill.host_peak_bytes > 0);
        assert!(spill.spilled_bytes >= spill.steps.iter().map(|s| s.bytes).max().unwrap());
        // every spilled checkpoint appears exactly twice in the resident
        // lifetimes (pre-evict + post-prefetch windows)
        for s in &spill.steps {
            let windows = spill
                .lifetimes
                .tensors
                .iter()
                .filter(|t| t.class == TensorClass::Checkpoint && t.layer == s.layer)
                .count();
            assert_eq!(windows, 2, "layer {}", s.layer);
        }
    }

    #[test]
    fn impossible_budget_is_a_typed_error() {
        let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let plan = plan_checkpoints(&arch, PlannerKind::Optimal, sc(), 4);
        let err = plan_spill(&arch, sc(), 4, &plan.checkpoints, 1, 2).unwrap_err();
        assert_eq!(err.budget, 1);
        assert!(err.min_device_bytes > 1);
        let msg = err.to_string();
        assert!(msg.contains("minimum achievable peak"), "{msg}");
    }

    #[test]
    fn spill_plan_is_deterministic() {
        let arch = uniform_chain(24);
        let cps = all_stored(24);
        let (_, layout) = plan_arena(&arch, sc(), 16, &cps);
        let budget = (layout.total_bytes() * 3) / 5;
        let a = plan_spill(&arch, sc(), 16, &cps, budget, 2).unwrap();
        let b = plan_spill(&arch, sc(), 16, &cps, budget, 2).unwrap();
        assert_eq!(a.steps, b.steps);
        assert_eq!(a.layout.offsets, b.layout.offsets);
        assert_eq!(a.layout.slab_bytes, b.layout.slab_bytes);
    }
}
