//! Prefetch scheduling: turn a [`SpillPlan`] into a transfer timeline and
//! an honest stall prediction.
//!
//! The model: one serial host link (pinned-host DMA; evictions and
//! prefetches share it FIFO in issue order) against per-step device
//! compute time derived from the schedule's FLOPs. Each spilled range has
//! a dedicated landing slot in the resident layout from its
//! `prefetch_step` on (that is what the split interval reserves), so a
//! prefetch overlaps compute while the previously prefetched checkpoint
//! is being consumed — the double-buffering the `lookahead` knob sizes.
//! Compute stalls exactly when a prefetch has not landed by its
//! `need_step`; evictions are treated as write-behind (they never stall
//! compute directly but do occupy the link ahead of queued prefetches).
//!
//! The outputs — predicted stall seconds and predicted step seconds
//! (compute + stall) — are what the trainer uses to re-score frontier
//! points when composing spill plans, so recompute FLOPs and transfer
//! stalls are compared in the same unit.

use crate::memory::arena::ScheduleTimes;
use crate::memory::offload::plan::{SpillClass, SpillPlan};
use crate::models::ArchProfile;

/// Default modeled device throughput (FLOP/s) for converting schedule
/// FLOPs into seconds.
pub const DEFAULT_DEVICE_FLOPS_PER_SEC: f64 = 2e12;

/// Default modeled host↔device bandwidth: 12 GiB/s (pinned PCIe-3 x16).
pub const DEFAULT_HOST_BW_BYTES_PER_SEC: u64 = 12 * (1 << 30);

/// Knobs of the simulated overlap model.
#[derive(Clone, Copy, Debug)]
pub struct OverlapModel {
    pub host_bw_bytes_per_sec: f64,
    pub device_flops_per_sec: f64,
}

impl Default for OverlapModel {
    fn default() -> OverlapModel {
        OverlapModel {
            host_bw_bytes_per_sec: DEFAULT_HOST_BW_BYTES_PER_SEC as f64,
            device_flops_per_sec: DEFAULT_DEVICE_FLOPS_PER_SEC,
        }
    }
}

/// Direction of one host transfer.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferKind {
    Evict,
    Prefetch,
}

/// One scheduled transfer with its simulated link occupancy.
#[derive(Clone, Debug)]
pub struct Transfer {
    pub layer: usize,
    /// What the transfer moves (a layer can spill both its checkpoint and
    /// its param-gradient; the pair is distinguished here).
    pub class: SpillClass,
    pub kind: TransferKind,
    pub issue_step: usize,
    pub bytes: u64,
    pub start_sec: f64,
    pub done_sec: f64,
}

/// Simulated timeline of one training step under a spill plan.
#[derive(Clone, Debug)]
pub struct OverlapReport {
    /// Every transfer in link order.
    pub transfers: Vec<Transfer>,
    /// Simulated start time of each schedule step (after any stall).
    pub step_start_secs: Vec<f64>,
    /// Pure compute time (forward + recompute + backward + optimizer).
    pub compute_secs: f64,
    /// Total link-busy time over all transfers.
    pub transfer_secs: f64,
    /// Compute idle time waiting on late prefetches.
    pub stall_secs: f64,
    /// Extra link occupancy from retried / slowed transfers (0 unless a
    /// faulted engine folded its measured retry stall in; the base
    /// simulation assumes a healthy link).
    pub retry_stall_secs: f64,
    /// Predicted wall time of one training step: compute + stall.
    pub predicted_step_secs: f64,
}

/// Stall share of a predicted step time (0 for an empty step) — the one
/// definition behind both [`OverlapReport::stall_frac`] and
/// `OffloadReport::stall_frac`.
pub fn stall_fraction(stall_secs: f64, predicted_step_secs: f64) -> f64 {
    if predicted_step_secs > 0.0 {
        stall_secs / predicted_step_secs
    } else {
        0.0
    }
}

impl OverlapReport {
    /// Stall share of the predicted step (0 when nothing is spilled).
    pub fn stall_frac(&self) -> f64 {
        stall_fraction(self.stall_secs, self.predicted_step_secs)
    }
}

/// Per-schedule-step FLOP cost: forward and recompute steps cost the
/// layer's forward FLOPs, backward steps twice that, the loss step one
/// pass over the logits, the optimizer step two FLOPs per parameter.
pub fn step_flops(arch: &ArchProfile, batch: usize, times: &ScheduleTimes) -> Vec<f64> {
    let mut flops = vec![0.0f64; times.steps];
    if arch.layers.is_empty() {
        return flops;
    }
    let b = batch as f64;
    for (i, layer) in arch.layers.iter().enumerate() {
        let lf = layer.flops_per_image as f64 * b;
        flops[times.t_fwd[i]] += lf;
        if let Some(tr) = times.t_rec[i] {
            flops[tr] += lf;
        }
        flops[times.t_bwd[i]] += 2.0 * lf;
    }
    if let Some(last) = arch.layers.last() {
        flops[times.t_loss] += last.out_elems() as f64 * b;
    }
    flops[times.t_opt] += 2.0 * arch.param_count() as f64;
    flops
}

/// Run the overlap simulation for `spill` (its embedded schedule times)
/// against `arch`'s FLOP profile at `batch`.
pub fn simulate_overlap(
    arch: &ArchProfile,
    batch: usize,
    spill: &SpillPlan,
    model: &OverlapModel,
) -> OverlapReport {
    let times = &spill.times;
    let flops = step_flops(arch, batch, times);
    let bw = model.host_bw_bytes_per_sec.max(1.0);
    let speed = model.device_flops_per_sec.max(1.0);

    // (issue step, prefetch?, layer, class, bytes) — link order is issue
    // order; class keeps a layer's checkpoint and param-grad distinct.
    let mut issues: Vec<(usize, bool, usize, SpillClass, u64)> = Vec::new();
    for s in &spill.steps {
        issues.push((s.evict_step, false, s.layer, s.class, s.bytes));
        issues.push((s.prefetch_step, true, s.layer, s.class, s.bytes));
    }
    issues.sort_unstable();
    // need_step per spilled tensor, in step order.
    let mut needs: Vec<(usize, usize, SpillClass)> =
        spill.steps.iter().map(|s| (s.need_step, s.layer, s.class)).collect();
    needs.sort_unstable();

    let mut now = 0.0f64;
    let mut link_free = 0.0f64;
    let mut stall = 0.0f64;
    let mut transfers: Vec<Transfer> = Vec::with_capacity(issues.len());
    let mut prefetch_done: Vec<(usize, SpillClass, f64)> = Vec::with_capacity(spill.steps.len());
    let mut step_start = Vec::with_capacity(times.steps);
    let mut qi = 0usize;
    let mut ni = 0usize;
    for step in 0..times.steps {
        while qi < issues.len() && issues[qi].0 == step {
            let (_, is_prefetch, layer, class, bytes) = issues[qi];
            qi += 1;
            let start = now.max(link_free);
            let done = start + bytes as f64 / bw;
            link_free = done;
            if is_prefetch {
                prefetch_done.push((layer, class, done));
            }
            transfers.push(Transfer {
                layer,
                class,
                kind: if is_prefetch { TransferKind::Prefetch } else { TransferKind::Evict },
                issue_step: step,
                bytes,
                start_sec: start,
                done_sec: done,
            });
        }
        while ni < needs.len() && needs[ni].0 == step {
            let (_, layer, class) = needs[ni];
            ni += 1;
            if let Some(&(_, _, done)) =
                prefetch_done.iter().find(|&&(l, c, _)| l == layer && c == class)
            {
                if done > now {
                    stall += done - now;
                    now = done;
                }
            }
        }
        step_start.push(now);
        now += flops[step] / speed;
    }
    let compute_secs: f64 = flops.iter().map(|f| f / speed).sum();
    let transfer_secs: f64 = transfers.iter().map(|t| t.bytes as f64 / bw).sum();
    OverlapReport {
        transfers,
        step_start_secs: step_start,
        compute_secs,
        transfer_secs,
        stall_secs: stall,
        retry_stall_secs: 0.0,
        predicted_step_secs: now,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Pipeline;
    use crate::memory::offload::plan::plan_spill;
    use crate::memory::peak::PeakEvaluator;
    use crate::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};

    fn sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    fn chain(depth: usize) -> ArchProfile {
        let layers = (0..depth)
            .map(|i| {
                let out = (8 * 8 * 64) as u64;
                LayerProfile {
                    name: format!("l{i}"),
                    kind: LayerKind::Conv,
                    out_shape: (8, 8, 64),
                    act_elems: out * 2,
                    params: 512,
                    flops_per_image: 1_000_000,
                }
            })
            .collect();
        ArchProfile { name: format!("chain{depth}"), input: (8, 8, 3), layers }
    }

    #[test]
    fn step_flops_cover_the_whole_schedule() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let ev = PeakEvaluator::new(&arch, sc(), 8);
        let times = crate::memory::arena::ScheduleTimes::compute(&ev, &[3, 7]);
        let flops = step_flops(&arch, 8, &times);
        assert_eq!(flops.len(), times.steps);
        // every forward and backward step carries cost; total exceeds
        // 3× one forward pass (fwd + 2× bwd) for a plan with recompute
        let fwd: f64 = arch.flops(8) as f64;
        let total: f64 = flops.iter().sum();
        assert!(total >= 3.0 * fwd, "{total} < {}", 3.0 * fwd);
        assert!(flops[times.t_opt] > 0.0);
    }

    #[test]
    fn no_spill_means_no_stall() {
        let arch = chain(12);
        let cps: Vec<usize> = (0..11).collect();
        let spill = plan_spill(&arch, sc(), 4, &cps, u64::MAX, 2).unwrap();
        let rep = simulate_overlap(&arch, 4, &spill, &OverlapModel::default());
        assert!(rep.transfers.is_empty());
        assert_eq!(rep.stall_secs, 0.0);
        assert!((rep.predicted_step_secs - rep.compute_secs).abs() < 1e-12);
    }

    #[test]
    fn slow_link_stalls_fast_link_does_not() {
        let arch = chain(24);
        let cps: Vec<usize> = (0..23).collect();
        let (_, layout) = crate::memory::arena::plan_arena(&arch, sc(), 16, &cps);
        let budget = (layout.total_bytes() * 3) / 5;
        let spill = plan_spill(&arch, sc(), 16, &cps, budget, 2).unwrap();
        assert!(!spill.steps.is_empty());
        let slow = OverlapModel {
            host_bw_bytes_per_sec: 1e6, // 1 MB/s: transfers dominate
            device_flops_per_sec: 2e12,
        };
        let fast = OverlapModel {
            host_bw_bytes_per_sec: 1e15, // effectively instant
            device_flops_per_sec: 2e12,
        };
        let rs = simulate_overlap(&arch, 16, &spill, &slow);
        let rf = simulate_overlap(&arch, 16, &spill, &fast);
        assert!(rs.stall_secs > 0.0, "1 MB/s link must stall");
        assert!(rf.stall_secs < rs.stall_secs / 100.0, "{} vs {}", rf.stall_secs, rs.stall_secs);
        assert!(rs.predicted_step_secs >= rs.compute_secs);
        assert_eq!(rs.transfers.len(), 2 * spill.steps.len());
        assert!(rs.stall_frac() > 0.0 && rs.stall_frac() <= 1.0);
    }

    #[test]
    fn prefetches_land_before_their_need_step() {
        let arch = chain(24);
        let cps: Vec<usize> = (0..23).collect();
        let (_, layout) = crate::memory::arena::plan_arena(&arch, sc(), 16, &cps);
        let budget = (layout.total_bytes() * 3) / 5;
        let spill = plan_spill(&arch, sc(), 16, &cps, budget, 2).unwrap();
        let rep = simulate_overlap(&arch, 16, &spill, &OverlapModel::default());
        for s in &spill.steps {
            let done = rep
                .transfers
                .iter()
                .find(|t| t.kind == TransferKind::Prefetch && t.layer == s.layer)
                .map(|t| t.done_sec)
                .expect("prefetch simulated");
            // the simulation charges any lateness as stall, so by its own
            // accounting the data is on-device when the need step begins
            assert!(
                done <= rep.step_start_secs[s.need_step] + 1e-9,
                "layer {}: done {done} after step start {}",
                s.layer,
                rep.step_start_secs[s.need_step]
            );
        }
    }
}
