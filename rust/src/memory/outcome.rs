//! [`PlanOutcome`]: the staged results of one [`PlanRequest`] run, plus
//! the serde-free JSON and markdown renderers every consumer (trainer
//! report, `plan --json`, benches) shares.
//!
//! [`PlanRequest`]: crate::memory::pipeline::PlanRequest

use crate::config::Pipeline;
use crate::memory::arena::{ArenaLayout, ArenaReport, Lifetimes};
use crate::memory::offload::{OffloadReport, OverlapReport, SpillClass, SpillPlan};
use crate::memory::pipeline::{PlanError, PlanMode};
use crate::memory::planner::{CheckpointPlan, PlannerKind};
use crate::memory::simulator::MemoryReport;
use crate::models::ArchProfile;
use crate::util::bench::fmt_bytes;
use crate::util::json::{arr, n, obj, s, Json};

/// Everything one planning run produced. Staged results that were not
/// requested (or do not apply) are `None`; the unified accessors read
/// across stages so callers stop re-deriving composites.
#[derive(Clone, Debug)]
pub struct PlanOutcome {
    /// The resolved architecture the run planned over.
    pub arch: ArchProfile,
    pub pipeline: Pipeline,
    pub batch: usize,
    /// Whether this plans a full training step or a forward-only
    /// (inference) pass; [`PlanMode::Infer`] outcomes carry an empty
    /// checkpoint placement, no frontier and no spill stage.
    pub mode: PlanMode,
    /// The device budget the run was constrained by, if any.
    pub budget: Option<u64>,
    /// Overlap-model host bandwidth (bytes/s) the run assumed.
    pub host_bw: u64,
    /// Prefetch lookahead (schedule steps) the run assumed.
    pub lookahead: usize,
    /// Full simulated timeline under the chosen plan (S-C forced on, so
    /// `memory.peak_bytes == plan.peak_bytes`).
    pub memory: MemoryReport,
    /// The chosen checkpoint plan.
    pub plan: CheckpointPlan,
    /// The time/memory Pareto frontier, when requested.
    pub frontier: Option<Vec<CheckpointPlan>>,
    /// Packed totals (`base + slab`) per frontier point, staged when both
    /// the frontier and the arena are requested.
    pub frontier_packed_totals: Option<Vec<u64>>,
    /// Per-class arena rollup (resident layout under spilling).
    pub arena: Option<ArenaReport>,
    /// Tensor lifetimes behind [`PlanOutcome::layout`] for the non-spill
    /// paths (the spill path carries its own inside [`SpillPlan`]).
    pub arena_lifetimes: Option<Lifetimes>,
    /// Packed layout for the non-spill paths.
    pub arena_layout: Option<ArenaLayout>,
    /// The host-spill composition, when a budget was planned with
    /// spilling enabled (`steps` empty when nothing had to move).
    pub spill: Option<SpillPlan>,
    /// The simulated transfer/stall timeline for the budgeted paths.
    pub overlap: Option<OverlapReport>,
}

impl PlanOutcome {
    /// Whether the outcome actually moves bytes to the host.
    pub fn is_spill(&self) -> bool {
        self.spill.as_ref().is_some_and(|s| !s.steps.is_empty())
    }

    /// The packed (resident, under spilling) layout, from whichever stage
    /// produced it.
    pub fn layout(&self) -> Option<&ArenaLayout> {
        self.spill.as_ref().map(|s| &s.layout).or(self.arena_layout.as_ref())
    }

    /// The tensor lifetimes behind [`PlanOutcome::layout`].
    pub fn lifetimes(&self) -> Option<&Lifetimes> {
        self.spill.as_ref().map(|s| &s.lifetimes).or(self.arena_lifetimes.as_ref())
    }

    /// Device bytes the runtime reserves: the packed `base + slab` when a
    /// layout was staged, else the exact simulated peak.
    pub fn device_peak_packed(&self) -> u64 {
        self.layout().map(ArenaLayout::total_bytes).unwrap_or(self.plan.peak_bytes)
    }

    /// Predicted wall seconds of one training step (compute + transfer
    /// stall); `None` when no overlap simulation ran (un-budgeted paths).
    pub fn predicted_step_secs(&self) -> Option<f64> {
        self.overlap.as_ref().map(|o| o.predicted_step_secs)
    }

    /// Whether the outcome's device bytes fit `budget`.
    pub fn fits(&self, budget: u64) -> bool {
        self.device_peak_packed() <= budget
    }

    /// The plan-side offload report (runtime counters zeroed), when the
    /// outcome spills. The trainer folds engine counters in after a run.
    pub fn offload_report(&self) -> Option<OffloadReport> {
        if !self.is_spill() {
            return None;
        }
        Some(OffloadReport::from_parts(
            self.spill.as_ref()?,
            self.overlap.as_ref()?,
            self.host_bw,
            self.lookahead,
        ))
    }

    /// Stable JSON rendering of the whole outcome (the `plan --json`
    /// schema). Deterministic: same outcome, same bytes.
    pub fn to_json(&self) -> Json {
        let mut fields: Vec<(&str, Json)> = vec![
            ("arch", s(&self.arch.name)),
            ("pipeline", s(&self.pipeline.name())),
            ("batch", n(self.batch as f64)),
            ("mode", s(self.mode.name())),
            ("planner", s(&planner_kind_spec(self.plan.kind))),
            (
                "plan",
                obj(vec![
                    (
                        "checkpoints",
                        arr(self.plan.checkpoints.iter().map(|&c| n(c as f64)).collect()),
                    ),
                    ("peak_bytes", n(self.plan.peak_bytes as f64)),
                    ("recompute_overhead", n(self.plan.recompute_overhead)),
                ]),
            ),
            (
                "memory",
                obj(vec![
                    ("peak_bytes", n(self.memory.peak_bytes as f64)),
                    ("state_bytes", n(self.memory.state_bytes as f64)),
                    ("input_bytes", n(self.memory.input_bytes as f64)),
                    ("peak_activation_bytes", n(self.memory.peak_activation_bytes as f64)),
                ]),
            ),
            ("device_peak_packed", n(self.device_peak_packed() as f64)),
        ];
        if let Some(b) = self.budget {
            fields.push(("budget", n(b as f64)));
        }
        if let Some(frontier) = &self.frontier {
            let points = frontier
                .iter()
                .enumerate()
                .map(|(i, p)| {
                    let mut pf = vec![
                        ("peak_bytes", n(p.peak_bytes as f64)),
                        ("recompute_overhead", n(p.recompute_overhead)),
                        (
                            "checkpoints",
                            arr(p.checkpoints.iter().map(|&c| n(c as f64)).collect()),
                        ),
                    ];
                    // `get` rather than indexing: the parallel-length
                    // invariant holds for facade-built outcomes, but every
                    // field is pub and a hand-built outcome must not panic
                    // the renderer.
                    if let Some(&total) =
                        self.frontier_packed_totals.as_ref().and_then(|t| t.get(i))
                    {
                        pf.push(("packed_total", n(total as f64)));
                    }
                    obj(pf)
                })
                .collect();
            fields.push(("frontier", arr(points)));
        }
        if let Some(a) = &self.arena {
            fields.push((
                "arena",
                obj(vec![
                    ("slab_bytes", n(a.slab_bytes as f64)),
                    ("base_bytes", n(a.base_bytes as f64)),
                    ("peak_bytes", n(a.peak_bytes as f64)),
                    ("tensor_count", n(a.tensor_count as f64)),
                    ("fragmentation", n(a.fragmentation)),
                    (
                        "by_class",
                        arr(a
                            .by_class
                            .iter()
                            .map(|c| {
                                obj(vec![
                                    ("class", s(c.class.name())),
                                    ("count", n(c.count as f64)),
                                    ("bytes", n(c.bytes as f64)),
                                ])
                            })
                            .collect()),
                    ),
                ]),
            ));
        }
        if let Some(sp) = &self.spill {
            fields.push((
                "spill",
                obj(vec![
                    ("budget", n(sp.budget as f64)),
                    ("device_total", n(sp.device_total() as f64)),
                    ("spilled_bytes", n(sp.spilled_bytes as f64)),
                    ("host_peak_bytes", n(sp.host_peak_bytes as f64)),
                    (
                        "steps",
                        arr(sp
                            .steps
                            .iter()
                            .map(|st| {
                                obj(vec![
                                    ("class", s(st.class.name())),
                                    ("layer", n(st.layer as f64)),
                                    ("bytes", n(st.bytes as f64)),
                                    ("evict_step", n(st.evict_step as f64)),
                                    ("prefetch_step", n(st.prefetch_step as f64)),
                                    ("need_step", n(st.need_step as f64)),
                                    ("gap_steps", n(st.gap_steps as f64)),
                                ])
                            })
                            .collect()),
                    ),
                ]),
            ));
        }
        if let Some(ov) = &self.overlap {
            fields.push((
                "overlap",
                obj(vec![
                    ("compute_secs", n(ov.compute_secs)),
                    ("transfer_secs", n(ov.transfer_secs)),
                    ("stall_secs", n(ov.stall_secs)),
                    ("predicted_step_secs", n(ov.predicted_step_secs)),
                ]),
            ));
        }
        obj(fields)
    }

    /// Markdown rendering: the same per-stage lines the trainer report
    /// stitches, under one heading.
    pub fn to_markdown(&self) -> String {
        let mut md = format!(
            "### plan: {} / {} @ batch {} ({})\n\n",
            self.arch.name,
            self.pipeline.name(),
            self.batch,
            self.mode.name()
        );
        md.push_str(&plan_summary(&self.plan));
        if let Some(a) = &self.arena {
            md.push_str(&arena_summary(a));
        }
        if let Some(o) = self.offload_report() {
            md.push_str(&offload_summary(&o));
        }
        if let Some(b) = self.budget {
            md.push_str(&format!(
                "budget {}: device bytes {} — {}\n",
                fmt_bytes(b),
                fmt_bytes(self.device_peak_packed()),
                if self.is_spill() { "fits with host spilling" } else { "fits without spilling" },
            ));
        }
        if let Some(f) = &self.frontier {
            md.push('\n');
            md.push_str(&frontier_markdown(f));
        }
        md
    }
}

/// Canonical spec string for a planner kind (round-trips through
/// [`PlannerKind::parse`]).
pub fn planner_kind_spec(kind: PlannerKind) -> String {
    match kind {
        PlannerKind::Sqrt => "sqrt".to_string(),
        PlannerKind::Optimal => "dp".to_string(),
        PlannerKind::Uniform(k) => format!("uniform{k}"),
        PlannerKind::Bottleneck(k) => format!("bottleneck{k}"),
        PlannerKind::Joint => "joint".to_string(),
    }
}

/// One-line description of the checkpoint plan an S-C run trained under.
pub fn plan_summary(plan: &CheckpointPlan) -> String {
    format!(
        "checkpoint plan: {} checkpoints {:?}, simulated peak {}, recompute +{:.1}% fwd FLOPs\n",
        plan.checkpoints.len(),
        plan.checkpoints,
        fmt_bytes(plan.peak_bytes),
        plan.recompute_overhead * 100.0
    )
}

/// One-line description of the packed activation arena for a plan: slab
/// vs exact peak (fragmentation) and the per-class mix.
pub fn arena_summary(a: &ArenaReport) -> String {
    let classes = a
        .by_class
        .iter()
        .map(|c| format!("{} {}", c.count, c.class.name()))
        .collect::<Vec<_>>()
        .join(" · ");
    format!(
        "activation arena: slab {} (+ static {}) vs simulated peak {} — \
         fragmentation {:.2}x, {} tensors ({classes})\n",
        fmt_bytes(a.slab_bytes),
        fmt_bytes(a.base_bytes),
        fmt_bytes(a.peak_bytes),
        a.fragmentation,
        a.tensor_count
    )
}

/// One-line description of a host-spill composition: what left the
/// device, what it costs in predicted stall, and — after a run — the
/// engine's transfer/pool counters.
pub fn offload_summary(o: &OffloadReport) -> String {
    let what = if o.spilled_grad_tensors > 0 {
        format!(
            "{} checkpoints + {} param-grads",
            o.spilled_tensors - o.spilled_grad_tensors,
            o.spilled_grad_tensors
        )
    } else {
        format!("{} checkpoints", o.spilled_tensors)
    };
    let mut s = format!(
        "host-spill offload: device {} ≤ budget {} — {} to host \
         ({} out, host peak {}), predicted stall {:.2} ms/step ({:.1}% of {:.2} ms), \
         bw {}/s, lookahead {}\n",
        fmt_bytes(o.device_total),
        fmt_bytes(o.budget),
        what,
        fmt_bytes(o.spilled_bytes),
        fmt_bytes(o.host_peak_bytes),
        o.predicted_stall_secs * 1e3,
        o.stall_frac() * 100.0,
        o.predicted_step_secs * 1e3,
        fmt_bytes(o.host_bw_bytes_per_sec),
        o.lookahead,
    );
    if o.evictions > 0 {
        s.push_str(&format!(
            "host-spill engine: {} evictions, {} prefetches, pool hit rate {:.1}%\n",
            o.evictions,
            o.prefetches,
            o.pool_hit_rate * 100.0
        ));
    }
    if o.link_faults > 0 {
        s.push_str(&format!(
            "host-link faults: {} observed, {} transfers retried, \
             {:.2} ms/run retry stall\n",
            o.link_faults,
            o.link_retries,
            o.retry_stall_secs * 1e3
        ));
    }
    s
}

/// Side-by-side JSON of a sequential and a joint planning run (the
/// `plan --compare` schema): each side is the full
/// [`PlanOutcome::to_json`], or `{"error": …}` when that side was
/// infeasible.
pub fn compare_json(
    sequential: &Result<PlanOutcome, PlanError>,
    joint: &Result<PlanOutcome, PlanError>,
) -> Json {
    let side = |r: &Result<PlanOutcome, PlanError>| match r {
        Ok(o) => o.to_json(),
        Err(e) => obj(vec![("error", s(&e.to_string()))]),
    };
    obj(vec![("sequential", side(sequential)), ("joint", side(joint))])
}

/// Side-by-side markdown of a sequential and a joint planning run: one
/// metric per row, an infeasible side rendered as a note above the table,
/// and — when both sides planned — the predicted-step verdict.
pub fn compare_markdown(
    sequential: &Result<PlanOutcome, PlanError>,
    joint: &Result<PlanOutcome, PlanError>,
) -> String {
    let mut md = String::from("### plan comparison: sequential vs joint\n\n");
    for (label, r) in [("sequential", sequential), ("joint", joint)] {
        if let Err(e) = r {
            md.push_str(&format!("_{label} infeasible: {e}_\n\n"));
        }
    }
    let spilled = |o: &PlanOutcome| match &o.spill {
        Some(sp) if !sp.steps.is_empty() => {
            let grads =
                sp.steps.iter().filter(|st| st.class == SpillClass::ParamGrad).count();
            format!(
                "{} ({} ckpt + {} grad)",
                fmt_bytes(sp.spilled_bytes),
                sp.steps.len() - grads,
                grads
            )
        }
        _ => "none".to_string(),
    };
    type Metric<'a> = (&'a str, Box<dyn Fn(&PlanOutcome) -> String>);
    let metrics: Vec<Metric> = vec![
        ("planner", Box::new(|o| planner_kind_spec(o.plan.kind))),
        ("checkpoints", Box::new(|o| o.plan.checkpoints.len().to_string())),
        (
            "recompute overhead",
            Box::new(|o| format!("{:.1}%", o.plan.recompute_overhead * 100.0)),
        ),
        ("frontier point peak", Box::new(|o| fmt_bytes(o.plan.peak_bytes))),
        ("device bytes", Box::new(|o| fmt_bytes(o.device_peak_packed()))),
        ("spilled", Box::new(spilled)),
        (
            "predicted stall",
            Box::new(|o| match &o.overlap {
                Some(ov) => format!("{:.3} ms", ov.stall_secs * 1e3),
                None => "—".to_string(),
            }),
        ),
        (
            "predicted step",
            Box::new(|o| match o.predicted_step_secs() {
                Some(t) => format!("{:.3} ms", t * 1e3),
                None => "—".to_string(),
            }),
        ),
    ];
    md.push_str("| metric | sequential | joint |\n|---|---|---|\n");
    for (name, f) in &metrics {
        let cell = |r: &Result<PlanOutcome, PlanError>| match r {
            Ok(o) => f(o),
            Err(_) => "—".to_string(),
        };
        md.push_str(&format!("| {name} | {} | {} |\n", cell(sequential), cell(joint)));
    }
    if let (Ok(sq), Ok(jt)) = (sequential, joint) {
        if let (Some(a), Some(b)) = (sq.predicted_step_secs(), jt.predicted_step_secs()) {
            let verdict = if b < a {
                format!("joint is {:.2}% faster", (1.0 - b / a.max(f64::MIN_POSITIVE)) * 100.0)
            } else if b == a {
                "joint matches sequential".to_string()
            } else {
                "sequential is faster (unexpected — joint should dominate)".to_string()
            };
            md.push_str(&format!("\npredicted step: {verdict}\n"));
        }
    }
    md
}

/// Time/memory Pareto frontier as CSV:
/// `peak_mb,n_checkpoints,recompute_overhead,checkpoints`.
pub fn frontier_csv(plans: &[CheckpointPlan]) -> String {
    let mut s = String::from("peak_mb,n_checkpoints,recompute_overhead,checkpoints\n");
    for p in plans {
        s.push_str(&format!(
            "{:.1},{},{:.4},{}\n",
            p.peak_bytes as f64 / (1024.0 * 1024.0),
            p.checkpoints.len(),
            p.recompute_overhead,
            p.checkpoints
                .iter()
                .map(|c| c.to_string())
                .collect::<Vec<_>>()
                .join(" ")
        ));
    }
    s
}

/// Console table of the Pareto frontier (the `plan --frontier` CLI output
/// and the plan_checkpoints example share this shape).
pub fn frontier_table(plans: &[CheckpointPlan]) -> crate::util::bench::Table {
    let mut t = crate::util::bench::Table::new(&["peak", "checkpoints", "recompute overhead"]);
    for p in plans {
        t.row(&[
            fmt_bytes(p.peak_bytes),
            format!("{}", p.checkpoints.len()),
            format!("{:.1}%", p.recompute_overhead * 100.0),
        ]);
    }
    t
}

/// Markdown table of the Pareto frontier (EXPERIMENTS.md fragments).
pub fn frontier_markdown(plans: &[CheckpointPlan]) -> String {
    let mut s = String::from("| peak | checkpoints | recompute overhead |\n|---|---|---|\n");
    for p in plans {
        s.push_str(&format!(
            "| {} | {} | {:.1}% |\n",
            fmt_bytes(p.peak_bytes),
            p.checkpoints.len(),
            p.recompute_overhead * 100.0
        ));
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::pipeline::PlanRequest;

    fn sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    #[test]
    fn planner_spec_roundtrips() {
        for kind in [
            PlannerKind::Sqrt,
            PlannerKind::Optimal,
            PlannerKind::Joint,
            PlannerKind::Uniform(4),
            PlannerKind::Bottleneck(2),
        ] {
            assert_eq!(PlannerKind::parse(&planner_kind_spec(kind)).unwrap(), kind);
        }
    }

    #[test]
    fn json_has_the_stable_top_level_keys() {
        let out = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .pipeline(sc())
            .batch(8)
            .frontier(true)
            .run()
            .unwrap();
        let j = out.to_json();
        for key in ["arch", "pipeline", "batch", "planner", "plan", "memory", "device_peak_packed", "frontier", "arena"]
        {
            assert!(j.get(key).is_some(), "missing key '{key}'");
        }
        assert_eq!(j.get("arch").unwrap().as_str().unwrap(), "tiny_cnn");
        assert_eq!(
            j.get("plan").unwrap().get("peak_bytes").unwrap().as_f64().unwrap() as u64,
            out.plan.peak_bytes
        );
        // no budget ⇒ no budget/spill/overlap keys
        assert!(j.get("budget").is_none());
        assert!(j.get("spill").is_none());
    }

    #[test]
    fn json_rendering_is_deterministic() {
        let req = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .pipeline(sc())
            .batch(8)
            .frontier(true);
        let a = req.run().unwrap().to_json().to_string();
        let b = req.run().unwrap().to_json().to_string();
        assert_eq!(a, b);
        // and the text re-parses
        crate::util::json::Json::parse(&a).unwrap();
    }

    #[test]
    fn compare_renders_both_sides_and_infeasibility() {
        let seq = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .pipeline(sc())
            .batch(8)
            .memory_budget(1 << 30)
            .run();
        let joint = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .pipeline(sc())
            .batch(8)
            .planner_named("joint")
            .memory_budget(1 << 30)
            .run();
        let j = compare_json(&seq, &joint);
        assert!(j.get("sequential").is_some() && j.get("joint").is_some());
        assert_eq!(
            j.get("joint").unwrap().get("planner").unwrap().as_str().unwrap(),
            "joint"
        );
        let md = compare_markdown(&seq, &joint);
        assert!(md.contains("| metric | sequential | joint |"), "{md}");
        assert!(md.contains("| planner |"), "{md}");
        assert!(md.contains("predicted step:"), "{md}");
        // an infeasible side renders as a note + em-dash cells, not a panic
        let bad = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10).memory_budget(1).run();
        assert!(bad.is_err());
        let md = compare_markdown(&bad, &joint);
        assert!(md.contains("sequential infeasible"), "{md}");
        let j = compare_json(&bad, &joint);
        assert!(j.get("sequential").unwrap().get("error").is_some());
    }

    #[test]
    fn markdown_mentions_every_staged_section() {
        let out = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .pipeline(sc())
            .batch(8)
            .frontier(true)
            .run()
            .unwrap();
        let md = out.to_markdown();
        assert!(md.contains("checkpoint plan:"), "{md}");
        assert!(md.contains("activation arena:"), "{md}");
        assert!(md.contains("| peak |"), "{md}");
    }
}
