//! Zero-allocation peak-memory evaluation — the planner's hot path.
//!
//! [`simulate`](crate::memory::simulator::simulate) materializes a labeled
//! timeline (one heap `String` per event) so Figure 8 can be plotted; a
//! schedule *search* only needs the peak. [`PeakEvaluator`] precomputes the
//! per-layer byte quantities and prefix/suffix sums once per
//! (arch, pipeline, batch) and then replays the exact same schedule
//! arithmetic per plan: O(depth) time, **zero allocations per call**
//! (`peak` takes `&mut self` only to reuse its `stored` scratch buffer).
//!
//! ## Segment decomposition
//!
//! For the S-C schedule the peak also admits a closed form the exact DP
//! planner builds on. Write `out[i]`/`act[i]` for the boundary-output and
//! stored-activation bytes of layer `i`, `AP[i]` for the prefix sum of
//! `act`, `G[i]` for the suffix sum of parameter-gradient bytes, and
//! `base` for the resident state+input bytes. Processing segment
//! `(lo..hi]` during the backward pass, the live-byte candidate recorded
//! at layer `i`'s backward step telescopes to
//!
//! ```text
//! C(i) = W + base + (AP[i+1] − AP[lo]) + out[i−1] + 2·out[i] + G[i]
//!      = W + D(i) − AP[lo],     D(i) = base + AP[i+1] + out[i−1] + 2·out[i] + G[i]
//! ```
//!
//! where `W` is the byte total of checkpoints resident to the segment's
//! *left* — the only cross-segment coupling. Every other event (forward,
//! loss-grad, recompute ramp, optimizer step) is dominated by some `C(i)`,
//! so a plan's peak is `max` over its segments of
//! `W + max(D[lo..hi)) − AP[lo]`. [`PeakEvaluator::seg_coeff`] exposes
//! `D`; the planner's DPs evaluate segment peaks incrementally from it.
//!
//! The decomposition (not the replay) assumes `act_elems ≥ out_elems` for
//! every layer — true of every profile in the registry, where the stored
//! footprint always includes the boundary tensor — because a stored
//! boundary with `act < out` would leave `out − act` bytes live after its
//! segment is consumed, leaking into segments processed later.

use crate::config::Pipeline;
use crate::memory::simulator::{act_dtype_bytes, input_bytes};
use crate::models::ArchProfile;

/// Reusable peak evaluator for one (arch, pipeline, batch) triple.
pub struct PeakEvaluator {
    /// Resident state (params + momentum) + input-batch bytes.
    base: u64,
    /// Parameter bytes alone — the forward-only resident state (no
    /// momentum, no gradients).
    infer_state: u64,
    /// `infer_state` + input-batch bytes: the inference peak floor.
    infer_base: u64,
    sc: bool,
    /// Per-layer boundary-output bytes.
    out: Vec<u64>,
    /// Per-layer stored-activation bytes (internal tensors included).
    act: Vec<u64>,
    /// Per-layer parameter-gradient bytes.
    pb: Vec<u64>,
    /// `grad_suffix[i]` = Σ_{j≥i} pb[j]; length n+1.
    grad_suffix: Vec<u64>,
    /// `act_prefix[i]` = Σ_{j<i} act[j]; length n+1.
    act_prefix: Vec<u64>,
    /// Segment coefficients `D(i)` (see module docs).
    seg: Vec<u64>,
    /// Scratch: forward-stored flags, reused across `peak` calls.
    stored: Vec<bool>,
}

impl PeakEvaluator {
    pub fn new(arch: &ArchProfile, pipeline: Pipeline, batch: usize) -> PeakEvaluator {
        let n = arch.layers.len();
        let ab = act_dtype_bytes(pipeline);
        let b = batch as u64;
        let peb: u64 = if pipeline.mp { 2 } else { 4 };
        let state = arch.param_count() * peb * 2; // params + momentum
        let input = input_bytes(arch, pipeline, batch);
        let base = state + input;
        let infer_state = arch.param_count() * peb;
        let infer_base = infer_state + input;
        let out: Vec<u64> = arch.layers.iter().map(|l| l.out_elems() * b * ab).collect();
        let act: Vec<u64> = arch.layers.iter().map(|l| l.act_elems * b * ab).collect();
        let pb: Vec<u64> = arch.layers.iter().map(|l| l.params * peb).collect();
        let act_prefix: Vec<u64> =
            arch.act_prefix_elems().iter().map(|&e| e * b * ab).collect();
        let grad_suffix: Vec<u64> = arch.param_suffix().iter().map(|&e| e * peb).collect();
        let seg: Vec<u64> = (0..n)
            .map(|i| {
                let outm1 = if i > 0 { out[i - 1] } else { 0 };
                base + act_prefix[i + 1] + outm1 + 2 * out[i] + grad_suffix[i]
            })
            .collect();
        PeakEvaluator {
            base,
            infer_state,
            infer_base,
            sc: pipeline.sc,
            out,
            act,
            pb,
            grad_suffix,
            act_prefix,
            seg,
            stored: vec![false; n],
        }
    }

    pub fn depth(&self) -> usize {
        self.out.len()
    }

    /// Resident state + input bytes (the peak floor).
    pub fn base_bytes(&self) -> u64 {
        self.base
    }

    /// Parameter bytes alone — what a forward-only (inference) pass keeps
    /// resident. No momentum (no optimizer runs) and no gradients.
    pub fn infer_state_bytes(&self) -> u64 {
        self.infer_state
    }

    /// Inference peak floor: parameters + the input batch. The training
    /// [`PeakEvaluator::base_bytes`] additionally carries momentum.
    pub fn infer_base_bytes(&self) -> u64 {
        self.infer_base
    }

    /// Exact peak of the forward-only (inference) schedule: each layer's
    /// boundary output lives only until the next layer consumes it, layer
    /// internals only while their layer runs, and nothing is retained for
    /// a backward pass. O(depth), allocation-free.
    ///
    /// [`Lifetimes::extract_infer`](crate::memory::arena::Lifetimes::extract_infer)
    /// replays the same schedule into intervals; its exactness invariant is
    /// `infer_base_bytes + max_live_bytes() == forward_peak()`.
    pub fn forward_peak(&self) -> u64 {
        let mut peak = self.infer_base;
        let mut prev_out = 0u64;
        for i in 0..self.out.len() {
            // While layer i runs: its input (the previous boundary) plus
            // its full stored footprint (internals + own boundary).
            let footprint = self.act[i].max(self.out[i]);
            peak = peak.max(self.infer_base + prev_out + footprint);
            prev_out = self.out[i];
        }
        peak
    }

    /// Boundary-output bytes of layer `i` — what storing checkpoint `i`
    /// keeps resident for segments to its right.
    pub fn out_bytes(&self, i: usize) -> u64 {
        self.out[i]
    }

    /// Prefix sum of stored-activation bytes over layers `< i`.
    pub fn act_prefix_bytes(&self, i: usize) -> u64 {
        self.act_prefix[i]
    }

    /// Segment coefficient `D(i)` (module docs): a segment `(lo..hi]`
    /// contributes peak `W + max(D[lo..hi)) − act_prefix_bytes(lo)`.
    pub fn seg_coeff(&self, i: usize) -> u64 {
        self.seg[i]
    }

    /// Whether this evaluator models the S-C (checkpointed) schedule.
    pub fn is_sc(&self) -> bool {
        self.sc
    }

    /// Stored-activation bytes of layer `i` (boundary output + internals) —
    /// what the arena's lifetime extraction
    /// ([`Lifetimes`](crate::memory::arena::Lifetimes) /
    /// [`ScheduleTimes`](crate::memory::arena::ScheduleTimes)) replays and
    /// the host-spill planner (`memory::offload`) sizes idle windows from.
    pub fn act_bytes(&self, i: usize) -> u64 {
        self.act[i]
    }

    /// Parameter-gradient bytes of layer `i` (resident from its backward
    /// step to the optimizer step).
    pub fn param_grad_bytes(&self, i: usize) -> u64 {
        self.pb[i]
    }

    /// Exact peak of `simulate(arch, pipeline, batch, checkpoints)` without
    /// materializing a timeline. O(depth), allocation-free.
    ///
    /// `checkpoints` follows the simulator convention: layer indices kept
    /// live under S-C (out-of-range indices ignored, the final layer
    /// implicitly stored); ignored entirely when the pipeline is not S-C.
    pub fn peak(&mut self, checkpoints: &[usize]) -> u64 {
        let n = self.out.len();
        if n == 0 {
            return self.base;
        }
        if self.sc {
            for s in self.stored.iter_mut() {
                *s = false;
            }
            for &c in checkpoints {
                if c < n {
                    self.stored[c] = true;
                }
            }
            self.stored[n - 1] = true;
        } else {
            for s in self.stored.iter_mut() {
                *s = true;
            }
        }

        let mut live = self.base;
        let mut peak = live;
        // ---- forward ----
        for i in 0..n {
            let t = self.out[i];
            live += t;
            peak = peak.max(live);
            if !self.sc {
                live += self.act[i].saturating_sub(t);
                peak = peak.max(live);
            } else if !self.stored[i] {
                live -= t;
            }
        }
        // ---- backward ----
        let mut grad: u64 = 0;
        let mut act_grad = self.out[n - 1];
        live += act_grad;
        peak = peak.max(live);
        if !self.sc {
            for i in (0..n).rev() {
                grad += self.pb[i];
                let nag = if i > 0 { self.out[i - 1] } else { 0 };
                live += nag;
                peak = peak.max(live + grad + self.out[i]);
                live -= self.act[i];
                live -= act_grad;
                act_grad = nag;
            }
        } else {
            let mut hi = n;
            while hi > 0 {
                let lo = (0..hi.saturating_sub(1))
                    .rev()
                    .find(|&i| self.stored[i])
                    .map(|i| i + 1)
                    .unwrap_or(0);
                for i in lo..hi {
                    let delta = if self.stored[i] {
                        self.act[i].saturating_sub(self.out[i])
                    } else {
                        self.act[i]
                    };
                    if delta > 0 {
                        live += delta;
                        peak = peak.max(live + grad);
                    }
                }
                for i in (lo..hi).rev() {
                    grad += self.pb[i];
                    let nag = if i > 0 { self.out[i - 1] } else { 0 };
                    live += nag;
                    peak = peak.max(live + grad + self.out[i]);
                    live -= self.act[i];
                    live -= act_grad;
                    act_grad = nag;
                }
                hi = lo;
            }
        }
        // optimizer step: grads + state resident
        peak.max(self.base + self.grad_suffix[0])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::simulator::simulate;
    use crate::models::arch_by_name;

    fn pipe(s: &str) -> Pipeline {
        Pipeline::parse(s).unwrap()
    }

    #[test]
    fn matches_simulator_across_zoo_and_pipelines() {
        for name in ["resnet18", "resnet50", "efficientnet_b0", "tiny_cnn"] {
            let arch = arch_by_name(name, (64, 64, 3), 10).unwrap();
            let n = arch.layers.len();
            let plans: Vec<Vec<usize>> = vec![
                vec![],
                (0..n).step_by(3).collect(),
                (0..n.saturating_sub(1)).collect(),
                vec![n / 2],
            ];
            for p in ["b", "sc", "mp", "ed+sc", "ed+mp+sc"] {
                let mut ev = PeakEvaluator::new(&arch, pipe(p), 8);
                for plan in &plans {
                    assert_eq!(
                        ev.peak(plan),
                        simulate(&arch, pipe(p), 8, plan).peak_bytes,
                        "{name} [{p}] plan {plan:?}"
                    );
                }
            }
        }
    }

    #[test]
    fn segment_decomposition_matches_replay() {
        // Single-segment plans make `max(D[lo..n)) − AP[lo]` directly
        // comparable with the replayed peak (W = 0 for the lone segment).
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let mut ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        let n = ev.depth();
        let dmax = (0..n).map(|i| ev.seg_coeff(i)).max().unwrap();
        assert_eq!(ev.peak(&[]), dmax.max(ev.base_bytes() + ev.grad_suffix[0]));
    }

    #[test]
    fn empty_arch_peak_is_base() {
        let arch = ArchProfile { name: "empty".into(), input: (8, 8, 3), layers: vec![] };
        let mut ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        assert_eq!(ev.peak(&[]), ev.base_bytes());
        assert_eq!(ev.peak(&[]), simulate(&arch, pipe("sc"), 4, &[]).peak_bytes);
    }

    #[test]
    fn out_of_range_checkpoints_ignored() {
        let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let mut ev = PeakEvaluator::new(&arch, pipe("sc"), 4);
        assert_eq!(ev.peak(&[1, 99]), ev.peak(&[1]));
    }
}
