//! The unified memory-pipeline facade: one typed request in, one staged
//! outcome out.
//!
//! The memory stack spans four subsystems — the simulator/`PeakEvaluator`
//! ([`crate::memory::simulator`], [`crate::memory::peak`]), the DP
//! checkpoint planner and its Pareto frontier
//! ([`crate::memory::planner`]), the activation arena
//! ([`crate::memory::arena`]) and the host-spill offload engine
//! ([`crate::memory::offload`]). MONeT (Shah et al., 2020) and OLLA
//! (Steiner et al., 2022) both argue that checkpointing, lifetime packing
//! and offload must be planned *jointly*; composing the free functions by
//! hand at every call site makes joint decisions structurally awkward.
//! [`PlanRequest`] is the one optimization surface: a builder naming the
//! architecture, pipeline, batch, planner kind and budget/spill knobs,
//! whose [`PlanRequest::run`] stages the whole composition into a
//! [`PlanOutcome`](crate::memory::outcome::PlanOutcome) — or a typed
//! [`PlanError`].
//!
//! The free functions remain available as the documented low-level API
//! (benches and tests exercise them directly); the trainer, the CLI and
//! the memory benches all drive planning through this facade.
//!
//! ```no_run
//! use optorch::prelude::*;
//!
//! let outcome = PlanRequest::for_model("resnet18", (64, 64, 3), 10)
//!     .batch(8)
//!     .memory_budget(512 * 1024 * 1024)
//!     .run()
//!     .unwrap();
//! println!(
//!     "device bytes {} (fits: {}), predicted step {:?} s",
//!     outcome.device_peak_packed(),
//!     outcome.fits(512 * 1024 * 1024),
//!     outcome.predicted_step_secs(),
//! );
//! ```

use crate::config::{parse_bytes, Pipeline};
use crate::fault::{DegradationAction, DegradationReport, DegradeTrigger};
use crate::memory::arena::{pack, plan_arena, summarize, Lifetimes};
use crate::memory::joint::{joint_spill_for_checkpoints, plan_joint};
use crate::memory::offload::{
    plan_spill, select_for_budget, simulate_overlap, InfeasibleBudget, OverlapModel,
    DEFAULT_DEVICE_FLOPS_PER_SEC, DEFAULT_HOST_BW_BYTES_PER_SEC,
};
use crate::memory::outcome::PlanOutcome;
use crate::memory::peak::PeakEvaluator;
use crate::memory::planner::{
    pareto_frontier, plan_checkpoints, plan_for_budget_packed, recompute_overhead,
    CheckpointPlan, InfeasiblePacked, PlannerKind, DEFAULT_FRONTIER_LEVELS,
};
use crate::memory::simulator::simulate;
use crate::models::{arch_by_name, ArchProfile};

/// Typed failure modes of [`PlanRequest::run`], absorbing the stack's
/// previously stringly errors. Every variant renders the same message the
/// legacy free functions produced, so CLI/config error text is stable.
#[derive(Clone, Debug, PartialEq)]
pub enum PlanError {
    /// The named model has no analytic architecture profile to plan over.
    UnknownArch { model: String },
    /// The planner spec did not parse ([`PlannerKind::parse`]'s message).
    UnknownPlanner { reason: String },
    /// A byte-count flag/field did not parse; `field` names the offending
    /// source (`--budget`, `--spill`, `memory_budget`, `device_budget`, …).
    InvalidBytes { field: String, reason: String },
    /// The budget sits below every packed pure-recompute plan and spilling
    /// was not enabled; carries the smallest achievable packed total.
    BudgetBelowPacked(InfeasiblePacked),
    /// The budget cannot be met even with every cold checkpoint spilled to
    /// host; carries the smallest achievable device total.
    BudgetBelowSpilled(InfeasibleBudget),
}

impl std::fmt::Display for PlanError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            PlanError::UnknownArch { model } => write!(
                f,
                "'{model}' has no architecture profile to plan over (see `optorch models`)"
            ),
            PlanError::UnknownPlanner { reason } => write!(f, "{reason}"),
            PlanError::InvalidBytes { field, reason } => write!(f, "{field}: {reason}"),
            PlanError::BudgetBelowPacked(e) => write!(f, "{e}"),
            PlanError::BudgetBelowSpilled(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for PlanError {}

/// What the plan schedules for: a full training step (forward + backward +
/// optimizer — the default) or a forward-only inference pass.
///
/// [`PlanMode::Infer`] drops every backward lifetime: no checkpointing
/// question exists (nothing is retained for a backward pass), so the DP,
/// the frontier and the spill selection are all bypassed. The evaluator's
/// [`forward_peak`](crate::memory::peak::PeakEvaluator::forward_peak)
/// replay is packed directly via
/// [`Lifetimes::extract_infer`](crate::memory::arena::Lifetimes::extract_infer),
/// yielding a much tighter slab than any training plan over the same
/// arch/batch — the margin inference serving's admission control spends.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlanMode {
    /// Forward + backward + optimizer (the training schedule).
    Train,
    /// Forward only: no gradients, no momentum, no recompute.
    Infer,
}

impl PlanMode {
    /// Stable lowercase tag used by the JSON/markdown renderers.
    pub fn name(self) -> &'static str {
        match self {
            PlanMode::Train => "train",
            PlanMode::Infer => "infer",
        }
    }
}

/// The one [`parse_bytes`] entry point every budget-shaped flag and config
/// field routes through: `--budget`, `--spill`, `--host_bw`, the config's
/// `memory_budget`/`host_bw`, and the manifest's `device_budget`. The
/// error names the offending source so each caller stops wrapping its own
/// `map_err`.
pub fn parse_bytes_field(field: &str, text: &str) -> Result<u64, PlanError> {
    parse_bytes(text).map_err(|reason| PlanError::InvalidBytes {
        field: field.to_string(),
        reason,
    })
}

#[derive(Clone, Debug)]
enum ArchSource {
    Named { model: String, input: (usize, usize, usize), classes: usize },
    Profile(ArchProfile),
}

#[derive(Clone, Debug)]
enum PlannerChoice {
    Kind(PlannerKind),
    /// Deferred-parse spec (validated in [`PlanRequest::run`]).
    Named(String),
}

/// A byte-count knob: either already resolved or a deferred-parse string
/// tagged with the flag/field it came from.
#[derive(Clone, Debug)]
enum BytesChoice {
    Bytes(u64),
    Field { field: String, text: String },
}

impl BytesChoice {
    fn resolve(&self) -> Result<u64, PlanError> {
        match self {
            BytesChoice::Bytes(b) => Ok(*b),
            BytesChoice::Field { field, text } => parse_bytes_field(field, text),
        }
    }
}

/// Builder for one joint planning run over the memory stack.
///
/// Knobs and defaults:
///
/// * architecture — by registry name ([`PlanRequest::for_model`]) or an
///   explicit profile ([`PlanRequest::for_arch`])
/// * `pipeline` (default [`Pipeline::BASELINE`]; S-C is forced on by the
///   planning layers, mirroring the free functions)
/// * `batch` (default 16)
/// * `planner` (default [`PlannerKind::Optimal`]) — ignored when a budget
///   selects from the frontier or explicit checkpoints are given, with
///   one exception: [`PlannerKind::Joint`] switches budgeted runs to the
///   joint recompute/spill optimizer ([`plan_joint`])
/// * `memory_budget` — rank the Pareto frontier by *packed* totals and
///   pick the minimum-predicted-step-time composition; with
///   [`PlanRequest::spill`]`(false)` only pure recompute plans are
///   considered ([`plan_for_budget_packed`] semantics)
/// * `grad_spill` (default on) — let the joint planner offload
///   param-gradient optimizer updates to the host
/// * `arena` (default on) — stage the packed layout + [`ArenaReport`]
/// * `frontier` (default off) — stage the full time/memory frontier
/// * `host_bw` / `spill_lookahead` — the offload overlap model's knobs
/// * [`PlanRequest::with_checkpoints`] — bypass the planner and score /
///   pack / spill an explicit placement (the benches' escape hatch)
///
/// [`ArenaReport`]: crate::memory::arena::ArenaReport
#[derive(Clone, Debug)]
pub struct PlanRequest {
    arch: ArchSource,
    pipeline: Pipeline,
    batch: usize,
    planner: PlannerChoice,
    checkpoints: Option<Vec<usize>>,
    memory_budget: Option<BytesChoice>,
    spill: bool,
    grad_spill: bool,
    arena: bool,
    frontier: bool,
    frontier_levels: usize,
    host_bw: BytesChoice,
    spill_lookahead: usize,
    device_flops_per_sec: f64,
    mode: PlanMode,
}

impl PlanRequest {
    fn with_arch(arch: ArchSource) -> PlanRequest {
        PlanRequest {
            arch,
            pipeline: Pipeline::BASELINE,
            batch: 16,
            planner: PlannerChoice::Kind(PlannerKind::Optimal),
            checkpoints: None,
            memory_budget: None,
            spill: true,
            grad_spill: true,
            arena: true,
            frontier: false,
            frontier_levels: DEFAULT_FRONTIER_LEVELS,
            host_bw: BytesChoice::Bytes(DEFAULT_HOST_BW_BYTES_PER_SEC),
            spill_lookahead: 2,
            device_flops_per_sec: DEFAULT_DEVICE_FLOPS_PER_SEC,
            mode: PlanMode::Train,
        }
    }

    /// Plan for a registry model (resolved via [`arch_by_name`] at run
    /// time; an unknown name is [`PlanError::UnknownArch`]).
    pub fn for_model(model: &str, input: (usize, usize, usize), classes: usize) -> PlanRequest {
        Self::with_arch(ArchSource::Named { model: model.to_string(), input, classes })
    }

    /// Plan for an explicit architecture profile.
    pub fn for_arch(arch: ArchProfile) -> PlanRequest {
        Self::with_arch(ArchSource::Profile(arch))
    }

    /// Pipeline the plan models (S-C is forced on internally).
    pub fn pipeline(mut self, p: Pipeline) -> Self {
        self.pipeline = p;
        self
    }

    /// Batch size the byte quantities scale with.
    pub fn batch(mut self, b: usize) -> Self {
        self.batch = b;
        self
    }

    /// Planner strategy for the un-budgeted path.
    pub fn planner(mut self, kind: PlannerKind) -> Self {
        self.planner = PlannerChoice::Kind(kind);
        self
    }

    /// Planner strategy by spec string (`dp`, `sqrt`, `uniformK`,
    /// `bottleneckK`, `joint`); parsed at [`PlanRequest::run`] so a bad
    /// spec is a typed [`PlanError::UnknownPlanner`].
    pub fn planner_named(mut self, spec: &str) -> Self {
        self.planner = PlannerChoice::Named(spec.to_string());
        self
    }

    /// Bypass the planner: score, pack and (under a budget) spill this
    /// explicit checkpoint placement. Out-of-range indices are dropped,
    /// the rest sorted and deduped.
    pub fn with_checkpoints(mut self, checkpoints: Vec<usize>) -> Self {
        self.checkpoints = Some(checkpoints);
        self
    }

    /// Device-memory budget in bytes.
    pub fn memory_budget(mut self, bytes: u64) -> Self {
        self.memory_budget = Some(BytesChoice::Bytes(bytes));
        self
    }

    /// Device-memory budget as unparsed text tagged with its source flag
    /// or field name; parsed by the shared [`parse_bytes_field`] at run
    /// time so every caller reports the same error shape.
    pub fn memory_budget_field(mut self, field: &str, text: &str) -> Self {
        self.memory_budget = Some(BytesChoice::Field {
            field: field.to_string(),
            text: text.to_string(),
        });
        self
    }

    /// Whether a budget may be met by host-spilling (default `true`).
    /// `false` = pure recompute only ([`plan_for_budget_packed`]).
    pub fn spill(mut self, on: bool) -> Self {
        self.spill = on;
        self
    }

    /// Whether the joint planner may spill param-gradients and apply
    /// their optimizer updates host-side (default `true`). Only read when
    /// `planner` is [`PlannerKind::Joint`] and a budget is set; the
    /// sequential pipeline never spills gradients.
    pub fn grad_spill(mut self, on: bool) -> Self {
        self.grad_spill = on;
        self
    }

    /// Whether to stage the packed arena layout + report (default `true`).
    pub fn arena(mut self, on: bool) -> Self {
        self.arena = on;
        self
    }

    /// Whether to stage the full time/memory Pareto frontier.
    pub fn frontier(mut self, on: bool) -> Self {
        self.frontier = on;
        self
    }

    /// Budget-quantization levels for the frontier DP. Only shapes the
    /// staged frontier of *un-budgeted* runs: budgeted selections
    /// ([`select_for_budget`] / [`plan_for_budget_packed`]) always rank
    /// the [`DEFAULT_FRONTIER_LEVELS`]-quantized frontier, and the staged
    /// curve mirrors exactly what was ranked.
    pub fn frontier_levels(mut self, levels: usize) -> Self {
        self.frontier_levels = levels.max(2);
        self
    }

    /// Modeled host↔device bandwidth (bytes/s) for the overlap model.
    pub fn host_bw(mut self, bytes_per_sec: u64) -> Self {
        self.host_bw = BytesChoice::Bytes(bytes_per_sec);
        self
    }

    /// [`PlanRequest::host_bw`] as unparsed text tagged with its source.
    pub fn host_bw_field(mut self, field: &str, text: &str) -> Self {
        self.host_bw = BytesChoice::Field { field: field.to_string(), text: text.to_string() };
        self
    }

    /// Prefetch lookahead (schedule steps, clamped to ≥ 1).
    pub fn spill_lookahead(mut self, steps: usize) -> Self {
        self.spill_lookahead = steps;
        self
    }

    /// Schedule mode: [`PlanMode::Train`] (default) plans the full
    /// forward + backward + optimizer step; [`PlanMode::Infer`] plans a
    /// forward-only pass (no DP — the exact forward replay packed
    /// directly, with `checkpoints`, `planner`, `spill` and `frontier`
    /// knobs ignored).
    pub fn mode(mut self, mode: PlanMode) -> Self {
        self.mode = mode;
        self
    }

    fn resolve_arch(&self) -> Result<ArchProfile, PlanError> {
        match &self.arch {
            ArchSource::Profile(a) => Ok(a.clone()),
            ArchSource::Named { model, input, classes } => arch_by_name(model, *input, *classes)
                .ok_or_else(|| PlanError::UnknownArch { model: model.clone() }),
        }
    }

    fn resolve_planner(&self) -> Result<PlannerKind, PlanError> {
        match &self.planner {
            PlannerChoice::Kind(k) => Ok(*k),
            PlannerChoice::Named(s) => {
                PlannerKind::parse(s).map_err(|reason| PlanError::UnknownPlanner { reason })
            }
        }
    }

    /// Score an explicit checkpoint placement exactly as the planner
    /// scores its own (S-C forced on, exact replayed peak).
    fn score_checkpoints(
        arch: &ArchProfile,
        kind: PlannerKind,
        pipeline: Pipeline,
        batch: usize,
        mut cps: Vec<usize>,
    ) -> CheckpointPlan {
        let mut p = pipeline;
        p.sc = true;
        cps.retain(|&c| c < arch.layers.len());
        cps.sort_unstable();
        cps.dedup();
        let mut ev = PeakEvaluator::new(arch, p, batch);
        CheckpointPlan {
            kind,
            recompute_overhead: recompute_overhead(arch, &cps),
            peak_bytes: ev.peak(&cps),
            checkpoints: cps,
        }
    }

    /// Run the staged composition. Exactly the legacy free-function
    /// chains, selected by the knobs:
    ///
    /// | budget | checkpoints | spill | composition |
    /// |---|---|---|---|
    /// | none | planner | — | [`plan_checkpoints`] (+ [`plan_arena`]) |
    /// | none | explicit | — | exact scoring (+ [`plan_arena`]) |
    /// | set | planner | on | [`select_for_budget`], or [`plan_joint`] for [`PlannerKind::Joint`] |
    /// | set | planner | off | [`plan_for_budget_packed`] |
    /// | set | explicit | on | [`plan_spill`] + [`simulate_overlap`], or [`joint_spill_for_checkpoints`] for [`PlannerKind::Joint`] |
    /// | set | explicit | off | [`plan_arena`] + fit check |
    pub fn run(&self) -> Result<PlanOutcome, PlanError> {
        let arch = self.resolve_arch()?;
        let planner = self.resolve_planner()?;
        let budget = match &self.memory_budget {
            Some(c) => Some(c.resolve()?),
            None => None,
        };
        let host_bw = self.host_bw.resolve()?;
        if self.mode == PlanMode::Infer {
            return self.run_infer(arch, planner, budget, host_bw);
        }
        let lookahead = self.spill_lookahead.max(1);
        let model = OverlapModel {
            host_bw_bytes_per_sec: host_bw as f64,
            device_flops_per_sec: self.device_flops_per_sec,
        };

        // 1. The plan (and, when budgeted, the spill/overlap staging).
        let mut arena_lifetimes: Option<Lifetimes> = None;
        let mut arena_layout = None;
        let mut spill = None;
        let mut overlap = None;
        let plan = match (budget, &self.checkpoints) {
            (None, None) => plan_checkpoints(&arch, planner, self.pipeline, self.batch),
            (None, Some(cps)) => {
                Self::score_checkpoints(&arch, planner, self.pipeline, self.batch, cps.clone())
            }
            (Some(b), Some(cps)) if self.spill => {
                let plan = Self::score_checkpoints(
                    &arch,
                    planner,
                    self.pipeline,
                    self.batch,
                    cps.clone(),
                );
                if planner == PlannerKind::Joint {
                    let (sp, ov) = joint_spill_for_checkpoints(
                        &arch,
                        self.pipeline,
                        self.batch,
                        &plan.checkpoints,
                        b,
                        lookahead,
                        &model,
                        self.grad_spill,
                    )
                    .map_err(PlanError::BudgetBelowSpilled)?;
                    overlap = Some(ov);
                    spill = Some(sp);
                } else {
                    let sp = plan_spill(
                        &arch,
                        self.pipeline,
                        self.batch,
                        &plan.checkpoints,
                        b,
                        lookahead,
                    )
                    .map_err(PlanError::BudgetBelowSpilled)?;
                    overlap = Some(simulate_overlap(&arch, self.batch, &sp, &model));
                    spill = Some(sp);
                }
                plan
            }
            (Some(b), Some(cps)) => {
                let plan = Self::score_checkpoints(
                    &arch,
                    planner,
                    self.pipeline,
                    self.batch,
                    cps.clone(),
                );
                let (lt, layout) =
                    plan_arena(&arch, self.pipeline, self.batch, &plan.checkpoints);
                if layout.total_bytes() > b {
                    return Err(PlanError::BudgetBelowPacked(InfeasiblePacked {
                        budget: b,
                        min_packed_bytes: layout.total_bytes(),
                        arch: arch.name.clone(),
                        batch: self.batch,
                    }));
                }
                arena_lifetimes = Some(lt);
                arena_layout = Some(layout);
                plan
            }
            (Some(b), None) if self.spill => {
                let decision = if planner == PlannerKind::Joint {
                    plan_joint(
                        &arch,
                        self.pipeline,
                        self.batch,
                        b,
                        lookahead,
                        &model,
                        self.grad_spill,
                    )
                    .map_err(PlanError::BudgetBelowSpilled)?
                } else {
                    select_for_budget(&arch, self.pipeline, self.batch, b, lookahead, &model)
                        .map_err(PlanError::BudgetBelowSpilled)?
                };
                overlap = Some(decision.overlap);
                spill = Some(decision.spill);
                decision.plan
            }
            (Some(b), None) => {
                let (plan, lt, layout) =
                    plan_for_budget_packed(&arch, self.pipeline, self.batch, b)
                        .map_err(PlanError::BudgetBelowPacked)?;
                arena_lifetimes = Some(lt);
                arena_layout = Some(layout);
                plan
            }
        };

        // 2. The arena staging for the un-budgeted paths (budgeted paths
        // packed above / inside the spill plan).
        if self.arena && arena_layout.is_none() && spill.is_none() {
            let (lt, layout) = plan_arena(&arch, self.pipeline, self.batch, &plan.checkpoints);
            arena_lifetimes = Some(lt);
            arena_layout = Some(layout);
        }
        let arena = if self.arena {
            match (&arena_lifetimes, &arena_layout, &spill) {
                (_, _, Some(sp)) => Some(summarize(&sp.lifetimes, &sp.layout)),
                (Some(lt), Some(layout), None) => Some(summarize(lt, layout)),
                _ => None,
            }
        } else {
            None
        };

        // 3. Optional frontier staging (+ packed totals when the arena is
        // on, so budget fit decisions can be read off every point). On
        // budgeted runs the selection above packed the same points
        // internally but the low-level API discards those layouts, so
        // requesting both budget and frontier pays the point packs twice —
        // acceptable for a once-per-invocation planning call; teaching
        // `select_for_budget` to surface per-point packs is the fix if
        // this ever sits on a hot path.
        let frontier = if self.frontier {
            // Budgeted selections rank the DEFAULT_FRONTIER_LEVELS curve
            // inside select_for_budget/plan_for_budget_packed — stage that
            // same quantization so the reported frontier is exactly the
            // one the plan was chosen from (frontier_levels only shapes
            // un-budgeted staging).
            let levels = if budget.is_some() {
                DEFAULT_FRONTIER_LEVELS
            } else {
                self.frontier_levels
            };
            Some(pareto_frontier(&arch, self.pipeline, self.batch, levels))
        } else {
            None
        };
        let frontier_packed_totals = match (&frontier, self.arena) {
            (Some(f), true) => Some(
                f.iter()
                    .map(|p| {
                        plan_arena(&arch, self.pipeline, self.batch, &p.checkpoints)
                            .1
                            .total_bytes()
                    })
                    .collect(),
            ),
            _ => None,
        };

        // 4. The simulated memory report under the chosen plan (S-C forced
        // on, so its peak equals the plan's).
        let mut sc_pipeline = self.pipeline;
        sc_pipeline.sc = true;
        let memory = simulate(&arch, sc_pipeline, self.batch, &plan.checkpoints);

        Ok(PlanOutcome {
            arch,
            pipeline: self.pipeline,
            batch: self.batch,
            mode: PlanMode::Train,
            budget,
            host_bw,
            lookahead,
            memory,
            plan,
            frontier,
            frontier_packed_totals,
            arena,
            arena_lifetimes,
            arena_layout,
            spill,
            overlap,
        })
    }

    /// The [`PlanMode::Infer`] composition: the exact forward-only replay
    /// ([`Lifetimes::extract_infer`]) packed directly — no DP, no frontier,
    /// no spill selection, no recompute. A budget is a plain fit check
    /// against the packed forward slab ([`PlanError::BudgetBelowPacked`]
    /// when it doesn't fit). The staged [`OverlapReport`] carries pure
    /// forward compute so `predicted_step_secs` feeds latency models
    /// (the serving micro-batcher's deadline math) the same way training
    /// overlap feeds the trainer.
    ///
    /// [`OverlapReport`]: crate::memory::offload::OverlapReport
    fn run_infer(
        &self,
        arch: ArchProfile,
        planner: PlannerKind,
        budget: Option<u64>,
        host_bw: u64,
    ) -> Result<PlanOutcome, PlanError> {
        let ev = PeakEvaluator::new(&arch, self.pipeline, self.batch);
        let fwd_peak = ev.forward_peak();
        let infer_state = ev.infer_state_bytes();
        let infer_base = ev.infer_base_bytes();
        let lifetimes = Lifetimes::extract_infer(&ev);
        let layout = pack(&lifetimes);
        if let Some(b) = budget {
            if layout.total_bytes() > b {
                return Err(PlanError::BudgetBelowPacked(InfeasiblePacked {
                    budget: b,
                    min_packed_bytes: layout.total_bytes(),
                    arch: arch.name.clone(),
                    batch: self.batch,
                }));
            }
        }
        // A forward pass retains nothing, so the "plan" is trivially the
        // zero-checkpoint placement with no recompute.
        let plan = CheckpointPlan {
            kind: planner,
            recompute_overhead: 0.0,
            peak_bytes: fwd_peak,
            checkpoints: Vec::new(),
        };
        // Forward-only compute, no transfers: the overlap shape every
        // latency consumer already reads, with an empty link timeline.
        let compute_secs = arch.flops(self.batch) as f64 / self.device_flops_per_sec;
        let overlap = crate::memory::offload::OverlapReport {
            transfers: Vec::new(),
            step_start_secs: Vec::new(),
            compute_secs,
            transfer_secs: 0.0,
            stall_secs: 0.0,
            retry_stall_secs: 0.0,
            predicted_step_secs: compute_secs,
        };
        let memory = crate::memory::simulator::MemoryReport {
            model: arch.name.clone(),
            pipeline: self.pipeline,
            batch: self.batch,
            peak_bytes: fwd_peak,
            state_bytes: infer_state,
            input_bytes: infer_base - infer_state,
            peak_activation_bytes: fwd_peak - infer_base,
            timeline: Vec::new(),
        };
        let arena = if self.arena { Some(summarize(&lifetimes, &layout)) } else { None };
        Ok(PlanOutcome {
            arch,
            pipeline: self.pipeline,
            batch: self.batch,
            mode: PlanMode::Infer,
            budget,
            host_bw,
            lookahead: self.spill_lookahead.max(1),
            memory,
            plan,
            frontier: None,
            frontier_packed_totals: None,
            arena,
            arena_lifetimes: Some(lifetimes),
            arena_layout: Some(layout),
            spill: None,
            overlap: Some(overlap),
        })
    }

    /// Like [`PlanRequest::run`], but a budget that cannot be met absorbs
    /// the failure by walking a fixed degradation ladder instead of
    /// erroring:
    ///
    /// 1. **step down the Pareto frontier** — drop any pinned checkpoint
    ///    placement and allow host spilling, letting [`select_for_budget`]
    ///    pick the cheapest-memory composition that still fits;
    /// 2. **shrink the prefetch lookahead** toward 1 (fewer resident
    ///    landing slots, smaller device total);
    /// 3. **heap fallback** — give up on the budget: plan the frontier's
    ///    cheapest-memory point unbudgeted with a heap-backed arena and
    ///    report `met_budget = false`.
    ///
    /// The chosen plan is always a real Pareto-frontier point: rungs 1–2
    /// re-run the budgeted frontier selection, and rung 3 plans the
    /// frontier's cheapest-memory point directly. Every rung taken is
    /// recorded in the returned [`DegradationReport`]. Non-budget errors
    /// (unknown model, bad planner spec, unparseable bytes) still return
    /// `Err` — the ladder cannot fix a malformed request.
    pub fn run_degraded(
        &self,
        trigger: DegradeTrigger,
    ) -> Result<(PlanOutcome, DegradationReport), PlanError> {
        let budget = match &self.memory_budget {
            Some(c) => Some(c.resolve()?),
            None => None,
        };
        let report = |out: &PlanOutcome, actions: Vec<DegradationAction>| DegradationReport {
            trigger,
            actions,
            met_budget: budget.map_or(true, |b| out.device_peak_packed() <= b),
            budget: budget.unwrap_or(0),
            device_total: out.device_peak_packed(),
            predicted_step_secs: out.predicted_step_secs(),
        };

        let mut attempt = self.clone();
        match attempt.run() {
            Ok(out) => {
                let r = report(&out, Vec::new());
                return Ok((out, r));
            }
            Err(PlanError::BudgetBelowPacked(_) | PlanError::BudgetBelowSpilled(_)) => {}
            Err(e) => return Err(e),
        }

        // Rung 1: step down the frontier — release any pinned placement
        // and allow spilling so the selection may choose a cheaper-memory
        // frontier point.
        attempt.checkpoints = None;
        attempt.spill = true;
        if let Ok(out) = attempt.run() {
            let actions = vec![DegradationAction::SteppedDownFrontier {
                device_total: out.device_peak_packed(),
                recompute_overhead: out.plan.recompute_overhead,
            }];
            let r = report(&out, actions);
            return Ok((out, r));
        }

        // Rung 2: shrink the prefetch lookahead toward 1.
        let from = attempt.spill_lookahead.max(1);
        let mut to = from;
        while to > 1 {
            to -= 1;
            attempt.spill_lookahead = to;
            if let Ok(out) = attempt.run() {
                let actions = vec![DegradationAction::ShrunkLookahead { from, to }];
                let r = report(&out, actions);
                return Ok((out, r));
            }
        }

        // Rung 3: abandon the budget — the frontier's cheapest-memory
        // point, heap-backed arena, no spilling.
        let arch = self.resolve_arch()?;
        let frontier =
            pareto_frontier(&arch, self.pipeline, self.batch, DEFAULT_FRONTIER_LEVELS);
        let cheapest = frontier
            .into_iter()
            .min_by_key(|p| p.peak_bytes)
            .expect("pareto_frontier returns at least one point");
        attempt.checkpoints = Some(cheapest.checkpoints);
        attempt.memory_budget = None;
        attempt.spill = false;
        attempt.arena = true;
        let out = attempt.run()?;
        let actions = vec![DegradationAction::HeapFallbackArena];
        let r = report(&out, actions);
        Ok((out, r))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::arena::validate;

    fn sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    #[test]
    fn unbudgeted_request_matches_plan_checkpoints() {
        let out = PlanRequest::for_model("resnet18", (64, 64, 3), 10)
            .pipeline(sc())
            .batch(8)
            .run()
            .unwrap();
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let legacy = plan_checkpoints(&arch, PlannerKind::Optimal, sc(), 8);
        assert_eq!(out.plan.checkpoints, legacy.checkpoints);
        assert_eq!(out.plan.peak_bytes, legacy.peak_bytes);
        assert_eq!(out.memory.peak_bytes, legacy.peak_bytes);
        let (lt, layout) = plan_arena(&arch, sc(), 8, &legacy.checkpoints);
        assert_eq!(out.layout().unwrap().offsets, layout.offsets);
        validate(&lt, &layout).unwrap();
        assert!(out.spill.is_none());
        assert!(!out.is_spill());
        assert!(out.fits(out.device_peak_packed()));
        assert!(!out.fits(out.device_peak_packed() - 1));
    }

    #[test]
    fn unknown_model_is_a_typed_error() {
        let err = PlanRequest::for_model("warp_net", (32, 32, 3), 10).run().unwrap_err();
        assert_eq!(err, PlanError::UnknownArch { model: "warp_net".into() });
        assert!(err.to_string().contains("architecture profile"), "{err}");
    }

    #[test]
    fn bad_planner_spec_is_a_typed_error() {
        let err = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .planner_named("magic")
            .run()
            .unwrap_err();
        match &err {
            PlanError::UnknownPlanner { reason } => {
                assert!(reason.contains("unknown planner"), "{reason}")
            }
            other => panic!("expected UnknownPlanner, got {other:?}"),
        }
    }

    #[test]
    fn bad_bytes_name_the_offending_field() {
        let err = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .memory_budget_field("--budget", "lots")
            .run()
            .unwrap_err();
        let msg = err.to_string();
        assert!(msg.starts_with("--budget:"), "{msg}");
        let err = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .memory_budget(1 << 30)
            .host_bw_field("--host_bw", "fast")
            .run()
            .unwrap_err();
        assert!(err.to_string().starts_with("--host_bw:"), "{err}");
        assert_eq!(
            parse_bytes_field("memory_budget", "512MiB").unwrap(),
            512 * 1024 * 1024
        );
    }

    #[test]
    fn generous_budget_fits_without_spilling() {
        let out = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .pipeline(sc())
            .batch(16)
            .memory_budget(1 << 30)
            .run()
            .unwrap();
        assert!(!out.is_spill(), "1 GiB fits a pure plan");
        assert_eq!(out.plan.recompute_overhead, 0.0);
        assert!(out.device_peak_packed() <= 1 << 30);
        assert!(out.predicted_step_secs().is_some());
        assert!(out.offload_report().is_none());
    }

    #[test]
    fn impossible_budgets_carry_typed_floors() {
        let spilled = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .memory_budget(1)
            .run()
            .unwrap_err();
        match &spilled {
            PlanError::BudgetBelowSpilled(e) => {
                assert_eq!(e.budget, 1);
                assert!(e.min_device_bytes > 1);
            }
            other => panic!("expected BudgetBelowSpilled, got {other:?}"),
        }
        assert!(spilled.to_string().contains("minimum achievable peak"), "{spilled}");
        let packed = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .memory_budget(1)
            .spill(false)
            .run()
            .unwrap_err();
        match &packed {
            PlanError::BudgetBelowPacked(e) => assert!(e.min_packed_bytes > 1),
            other => panic!("expected BudgetBelowPacked, got {other:?}"),
        }
        assert!(packed.to_string().contains("minimum packed total"), "{packed}");
    }

    #[test]
    fn explicit_checkpoints_are_scored_exactly() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let out = PlanRequest::for_arch(arch.clone())
            .batch(8)
            .with_checkpoints(vec![7, 3, 3, 99])
            .run()
            .unwrap();
        assert_eq!(out.plan.checkpoints, vec![3, 7], "sorted, deduped, in range");
        let mut ev = PeakEvaluator::new(&arch, sc(), 8);
        assert_eq!(out.plan.peak_bytes, ev.peak(&[3, 7]));
    }

    #[test]
    fn degraded_run_without_pressure_takes_no_rungs() {
        let (out, report) = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .memory_budget(1 << 30)
            .run_degraded(DegradeTrigger::BudgetShrink { from: None, to: 1 << 30 })
            .unwrap();
        assert!(report.actions.is_empty());
        assert!(report.met_budget);
        assert_eq!(report.device_total, out.device_peak_packed());
    }

    #[test]
    fn degradation_ladder_steps_down_to_a_spill_plan() {
        // Probe the spilled floor, then ask for exactly that budget with
        // spilling disabled: run() fails, the ladder's first rung fixes it.
        let probe = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .memory_budget(1)
            .run()
            .unwrap_err();
        let floor = match probe {
            PlanError::BudgetBelowSpilled(e) => e.min_device_bytes,
            other => panic!("expected BudgetBelowSpilled, got {other:?}"),
        };
        let req = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .memory_budget(floor)
            .spill(false);
        assert!(matches!(req.run(), Err(PlanError::BudgetBelowPacked(_))));
        let (out, report) = req
            .run_degraded(DegradeTrigger::BudgetShrink { from: Some(1 << 30), to: floor })
            .unwrap();
        assert!(report.met_budget, "{report:?}");
        assert_eq!(report.actions.len(), 1);
        assert!(
            matches!(report.actions[0], crate::fault::DegradationAction::SteppedDownFrontier { .. }),
            "{report:?}"
        );
        assert!(out.device_peak_packed() <= floor);
        assert!(report.to_markdown().contains("degradation:"));
    }

    #[test]
    fn degradation_ladder_bottoms_out_in_heap_fallback() {
        let req = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10).memory_budget(1);
        let (out, report) = req
            .run_degraded(DegradeTrigger::LinkFailure { retries_exhausted: 4 })
            .unwrap();
        assert!(!report.met_budget);
        assert_eq!(
            report.actions.last(),
            Some(&crate::fault::DegradationAction::HeapFallbackArena)
        );
        // the fallback plan is a real frontier point (its cheapest-memory
        // placement), packed into a heap-backed arena with no spill
        let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let frontier =
            pareto_frontier(&arch, Pipeline::BASELINE, 16, DEFAULT_FRONTIER_LEVELS);
        assert!(
            frontier.iter().any(|p| p.checkpoints == out.plan.checkpoints),
            "chosen checkpoints {:?} not on the frontier",
            out.plan.checkpoints
        );
        assert!(out.spill.is_none());
        assert!(out.layout().is_some());
    }

    #[test]
    fn degraded_run_still_types_malformed_requests() {
        let err = PlanRequest::for_model("warp_net", (32, 32, 3), 10)
            .run_degraded(DegradeTrigger::BudgetShrink { from: None, to: 1 })
            .unwrap_err();
        assert!(matches!(err, PlanError::UnknownArch { .. }));
    }

    #[test]
    fn infer_mode_packs_the_forward_replay_exactly() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let out = PlanRequest::for_arch(arch.clone())
            .batch(8)
            .mode(PlanMode::Infer)
            .run()
            .unwrap();
        assert_eq!(out.mode, PlanMode::Infer);
        let ev = PeakEvaluator::new(&arch, Pipeline::BASELINE, 8);
        assert_eq!(out.plan.peak_bytes, ev.forward_peak());
        assert!(out.plan.checkpoints.is_empty());
        assert_eq!(out.plan.recompute_overhead, 0.0);
        assert!(out.spill.is_none() && out.frontier.is_none());
        // layout validates against its own lifetimes and the exactness
        // invariant holds through the staged pair
        let lt = out.lifetimes().unwrap();
        validate(lt, out.layout().unwrap()).unwrap();
        assert_eq!(lt.base_bytes + lt.max_live_bytes(), ev.forward_peak());
        // forward-only compute with no transfers
        let ov = out.overlap.as_ref().unwrap();
        assert!(ov.transfers.is_empty());
        assert_eq!(ov.predicted_step_secs, ov.compute_secs);
        assert!(ov.compute_secs > 0.0);
        // JSON carries the mode tag
        assert_eq!(out.to_json().get("mode").unwrap().as_str().unwrap(), "infer");
    }

    #[test]
    fn infer_slab_strictly_smaller_than_training_slab() {
        let train = PlanRequest::for_model("resnet18", (64, 64, 3), 10)
            .batch(8)
            .run()
            .unwrap();
        let infer = PlanRequest::for_model("resnet18", (64, 64, 3), 10)
            .batch(8)
            .mode(PlanMode::Infer)
            .run()
            .unwrap();
        assert_eq!(train.to_json().get("mode").unwrap().as_str().unwrap(), "train");
        assert!(
            infer.device_peak_packed() < train.device_peak_packed(),
            "forward slab {} should undercut training slab {}",
            infer.device_peak_packed(),
            train.device_peak_packed()
        );
    }

    #[test]
    fn infer_mode_budget_is_a_plain_fit_check() {
        let probe = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .batch(4)
            .mode(PlanMode::Infer)
            .run()
            .unwrap();
        let need = probe.device_peak_packed();
        // exactly the packed total fits …
        let fit = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .batch(4)
            .mode(PlanMode::Infer)
            .memory_budget(need)
            .run()
            .unwrap();
        assert!(fit.fits(need));
        // … one byte less is a typed packed-floor error (never spill)
        let err = PlanRequest::for_model("tiny_cnn", (32, 32, 3), 10)
            .batch(4)
            .mode(PlanMode::Infer)
            .memory_budget(need - 1)
            .run()
            .unwrap_err();
        match err {
            PlanError::BudgetBelowPacked(e) => assert_eq!(e.min_packed_bytes, need),
            other => panic!("expected BudgetBelowPacked, got {other:?}"),
        }
    }

    #[test]
    fn frontier_staging_carries_packed_totals() {
        let out = PlanRequest::for_model("resnet18", (64, 64, 3), 10)
            .batch(8)
            .frontier(true)
            .frontier_levels(12)
            .run()
            .unwrap();
        let frontier = out.frontier.as_ref().unwrap();
        let totals = out.frontier_packed_totals.as_ref().unwrap();
        assert_eq!(frontier.len(), totals.len());
        for (p, &t) in frontier.iter().zip(totals) {
            assert!(t >= p.peak_bytes, "packed total below the simulated peak");
        }
    }
}
