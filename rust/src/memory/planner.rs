//! Checkpoint placement planner — Figure 11 and §IV's recommendation.
//!
//! Given an architecture profile, choose which layer outputs to keep live
//! under S-C. Strategies:
//!
//! * [`PlannerKind::Uniform`] — every ⌈n/k⌉-th layer (the naive default).
//! * [`PlannerKind::Sqrt`] — √n segments (Chen et al.'s classic heuristic).
//! * [`PlannerKind::Bottleneck`] — put checkpoints on the *smallest*
//!   activations (the paper's recommendation: checkpoint at narrow layers,
//!   prefer autoencoder/UNet-shaped nets).
//! * [`PlannerKind::Optimal`] — budget-search over segment interiors,
//!   simulator-scored; exact for practical depths.
//!
//! Also estimates the recompute overhead (extra forward FLOPs) so the
//! time/memory trade-off the paper discusses is visible.

use crate::config::Pipeline;
use crate::memory::simulator::simulate;
use crate::models::ArchProfile;

/// Planning strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerKind {
    Uniform(usize),
    Sqrt,
    Bottleneck(usize),
    Optimal,
}

impl PlannerKind {
    pub fn parse(s: &str) -> Result<PlannerKind, String> {
        if s == "sqrt" {
            return Ok(PlannerKind::Sqrt);
        }
        if s == "dp" || s == "optimal" {
            return Ok(PlannerKind::Optimal);
        }
        if let Some(k) = s.strip_prefix("uniform") {
            return k
                .parse()
                .map(PlannerKind::Uniform)
                .map_err(|_| format!("bad uniform arg: {s}"));
        }
        if let Some(k) = s.strip_prefix("bottleneck") {
            return k
                .parse()
                .map(PlannerKind::Bottleneck)
                .map_err(|_| format!("bad bottleneck arg: {s}"));
        }
        Err(format!("unknown planner '{s}' (sqrt|dp|uniformK|bottleneckK)"))
    }
}

/// A scored plan.
#[derive(Clone, Debug)]
pub struct CheckpointPlan {
    pub kind: PlannerKind,
    /// Layer indices whose activations stay live.
    pub checkpoints: Vec<usize>,
    /// Simulated peak bytes under S-C with this plan.
    pub peak_bytes: u64,
    /// Extra forward FLOPs the backward pass re-spends, as a fraction of
    /// one forward pass (0 = no recompute, 1 = a full extra forward).
    pub recompute_overhead: f64,
}

/// Plan checkpoints for `arch` under `pipeline` (S-C forced on) at `batch`.
pub fn plan_checkpoints(
    arch: &ArchProfile,
    kind: PlannerKind,
    pipeline: Pipeline,
    batch: usize,
) -> CheckpointPlan {
    let mut p = pipeline;
    p.sc = true;
    let n = arch.layers.len();
    let checkpoints = match kind {
        PlannerKind::Uniform(k) => uniform(n, k.max(1)),
        PlannerKind::Sqrt => uniform(n, (n as f64).sqrt().round() as usize),
        PlannerKind::Bottleneck(k) => bottleneck(arch, k.max(1)),
        PlannerKind::Optimal => optimal(arch, p, batch),
    };
    score(arch, kind, p, batch, checkpoints)
}

fn score(
    arch: &ArchProfile,
    kind: PlannerKind,
    pipeline: Pipeline,
    batch: usize,
    checkpoints: Vec<usize>,
) -> CheckpointPlan {
    let report = simulate(arch, pipeline, batch, &checkpoints);
    CheckpointPlan {
        kind,
        recompute_overhead: recompute_overhead(arch, &checkpoints),
        checkpoints,
        peak_bytes: report.peak_bytes,
    }
}

/// Fraction of forward FLOPs recomputed in backward for this plan.
pub fn recompute_overhead(arch: &ArchProfile, checkpoints: &[usize]) -> f64 {
    let n = arch.layers.len();
    let mut stored = vec![false; n];
    for &c in checkpoints {
        if c < n {
            stored[c] = true;
        }
    }
    stored[n - 1] = true;
    let total: u64 = arch.layers.iter().map(|l| l.flops_per_image).sum();
    let recomputed: u64 = arch
        .layers
        .iter()
        .enumerate()
        .filter(|(i, _)| !stored[*i])
        .map(|(_, l)| l.flops_per_image)
        .sum();
    if total == 0 {
        0.0
    } else {
        recomputed as f64 / total as f64
    }
}

fn uniform(n: usize, k: usize) -> Vec<usize> {
    if k == 0 || n == 0 {
        return vec![];
    }
    let step = (n as f64 / (k + 1) as f64).max(1.0);
    let mut out: Vec<usize> = (1..=k)
        .map(|j| ((j as f64 * step).round() as usize).min(n - 1))
        .collect();
    out.dedup();
    out
}

/// The paper's recommendation: checkpoint the k *narrowest* layers
/// (smallest stored activation), e.g. an autoencoder's bottleneck.
fn bottleneck(arch: &ArchProfile, k: usize) -> Vec<usize> {
    let n = arch.layers.len();
    let mut idx: Vec<usize> = (0..n.saturating_sub(1)).collect();
    idx.sort_by_key(|&i| arch.layers[i].act_elems);
    let mut out: Vec<usize> = idx.into_iter().take(k).collect();
    out.sort_unstable();
    out
}

/// Budget search: for every candidate interior budget (all contiguous
/// interval sums), greedily pack segments whose interior fits, then keep
/// the simulator-best plan. O(n²) candidates × O(n) packing.
fn optimal(arch: &ArchProfile, pipeline: Pipeline, batch: usize) -> Vec<usize> {
    let n = arch.layers.len();
    let acts: Vec<u64> = arch.layers.iter().map(|l| l.act_elems).collect();
    // candidate budgets: all contiguous sums
    let mut candidates: Vec<u64> = Vec::new();
    for i in 0..n {
        let mut s = 0u64;
        for a in acts.iter().skip(i) {
            s += a;
            candidates.push(s);
        }
    }
    candidates.sort_unstable();
    candidates.dedup();
    let mut best: Option<(u64, Vec<usize>)> = None;
    for &budget in &candidates {
        // greedy: walk forward, close a segment (place a checkpoint) when
        // adding the next layer would exceed the interior budget
        let mut cps = Vec::new();
        let mut interior = 0u64;
        let mut feasible = true;
        for (i, &a) in acts.iter().enumerate() {
            if a > budget {
                feasible = false;
                break;
            }
            if interior + a > budget {
                cps.push(i.saturating_sub(1));
                interior = 0;
            }
            interior += a;
        }
        if !feasible {
            continue;
        }
        cps.dedup();
        let peak = simulate(arch, pipeline, batch, &cps).peak_bytes;
        match &best {
            Some((bp, _)) if *bp <= peak => {}
            _ => best = Some((peak, cps)),
        }
        // budgets only grow from here; once segments collapse to one,
        // larger budgets change nothing
        if best.as_ref().map(|(_, c)| c.is_empty()).unwrap_or(false) {
            break;
        }
    }
    best.map(|(_, c)| c).unwrap_or_default()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};

    fn pipe_sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    /// The paper's Figure-11 7-layer autoencoder: wide–narrow–wide.
    fn autoencoder7() -> ArchProfile {
        let widths = [512usize, 256, 64, 16, 64, 256, 512];
        let layers = widths
            .iter()
            .enumerate()
            .map(|(i, &w)| LayerProfile {
                // width w as a 64x64 feature map with w channels: the stored
                // boundary tensor is the true layer output
                name: format!("dense{i}"),
                kind: LayerKind::Dense,
                out_shape: (64, 64, w),
                act_elems: (3 * 64 * 64 * w) as u64,
                params: (w * 8) as u64,
                flops_per_image: (w * 128) as u64,
            })
            .collect();
        ArchProfile { name: "autoencoder7".into(), input: (1, 1, 512), layers }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(PlannerKind::parse("sqrt").unwrap(), PlannerKind::Sqrt);
        assert_eq!(PlannerKind::parse("dp").unwrap(), PlannerKind::Optimal);
        assert_eq!(PlannerKind::parse("uniform3").unwrap(), PlannerKind::Uniform(3));
        assert_eq!(
            PlannerKind::parse("bottleneck2").unwrap(),
            PlannerKind::Bottleneck(2)
        );
        assert!(PlannerKind::parse("magic").is_err());
    }

    #[test]
    fn uniform_spacing() {
        assert_eq!(uniform(12, 3), vec![3, 6, 9]);
        assert_eq!(uniform(12, 1), vec![6]);
        assert!(uniform(0, 3).is_empty());
    }

    #[test]
    fn bottleneck_picks_narrow_layers() {
        let arch = autoencoder7();
        let cps = bottleneck(&arch, 1);
        // layer 3 (width 16) is the narrowest
        assert_eq!(cps, vec![3]);
    }

    #[test]
    fn fig11_bottleneck_beats_wide_placement() {
        // The paper's Figure-11 message: a checkpoint at the narrow middle
        // (w=16) costs less than the same schedule anchored on a wide layer
        // (w=512) — both in stored bytes and in peak.
        let arch = autoencoder7();
        let narrow = simulate(&arch, pipe_sc(), 16, &[3]); // w=16 bottleneck
        let wide = simulate(&arch, pipe_sc(), 16, &[1]); // w=256 encoder side
        assert!(
            narrow.peak_bytes < wide.peak_bytes,
            "narrow {} wide {}",
            narrow.peak_bytes,
            wide.peak_bytes
        );
        // and the bottleneck planner finds the w=16 layer
        let bn = plan_checkpoints(&arch, PlannerKind::Bottleneck(1), Pipeline::BASELINE, 16);
        assert_eq!(bn.checkpoints, vec![3]);
    }

    #[test]
    fn optimal_never_worse_than_heuristics() {
        for name in ["resnet18", "tiny_cnn"] {
            let arch = arch_by_name(name, (64, 64, 3), 10).unwrap();
            let opt = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, 8);
            for k in [
                PlannerKind::Sqrt,
                PlannerKind::Uniform(2),
                PlannerKind::Uniform(4),
                PlannerKind::Bottleneck(3),
            ] {
                let h = plan_checkpoints(&arch, k, Pipeline::BASELINE, 8);
                assert!(
                    opt.peak_bytes <= h.peak_bytes,
                    "{name}: optimal {} vs {:?} {}",
                    opt.peak_bytes,
                    k,
                    h.peak_bytes
                );
            }
        }
    }

    #[test]
    fn optimal_matches_exhaustive_on_small_net() {
        // Brute-force all checkpoint subsets of a 10-layer net and confirm
        // the budget search finds the same peak.
        let arch = autoencoder7();
        let n = arch.layers.len();
        let mut best = u64::MAX;
        for mask in 0u32..(1 << (n - 1)) {
            let cps: Vec<usize> = (0..n - 1).filter(|i| mask >> i & 1 == 1).collect();
            let peak = simulate(&arch, pipe_sc(), 4, &cps).peak_bytes;
            best = best.min(peak);
        }
        let opt = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, 4);
        assert_eq!(opt.peak_bytes, best);
    }

    #[test]
    fn recompute_overhead_bounds() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let all: Vec<usize> = (0..arch.layers.len()).collect();
        assert_eq!(recompute_overhead(&arch, &all), 0.0);
        let none: Vec<usize> = vec![];
        let o = recompute_overhead(&arch, &none);
        assert!(o > 0.8 && o <= 1.0, "{o}");
        // sqrt plan: strictly between
        let sq = plan_checkpoints(&arch, PlannerKind::Sqrt, Pipeline::BASELINE, 8);
        assert!(sq.recompute_overhead > 0.0 && sq.recompute_overhead < 1.0);
    }

    #[test]
    fn plans_are_sorted_and_in_range() {
        let arch = arch_by_name("resnet50", (128, 128, 3), 10).unwrap();
        for kind in [
            PlannerKind::Sqrt,
            PlannerKind::Uniform(5),
            PlannerKind::Bottleneck(4),
            PlannerKind::Optimal,
        ] {
            let plan = plan_checkpoints(&arch, kind, Pipeline::BASELINE, 4);
            let mut sorted = plan.checkpoints.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted, plan.checkpoints, "{kind:?} not sorted/deduped");
            assert!(plan.checkpoints.iter().all(|&c| c < arch.layers.len()));
        }
    }
}
