//! Checkpoint placement planner — Figure 11, Figure 9's time/memory
//! trade-off, and §IV's recommendation.
//!
//! Given an architecture profile, choose which layer outputs to keep live
//! under S-C. Strategies:
//!
//! * [`PlannerKind::Uniform`] — every ⌈n/k⌉-th layer (the naive default).
//! * [`PlannerKind::Sqrt`] — √n segments (Chen et al.'s classic heuristic).
//! * [`PlannerKind::Bottleneck`] — put checkpoints on the *smallest*
//!   activations (the paper's recommendation: checkpoint at narrow layers,
//!   prefer autoencoder/UNet-shaped nets).
//! * [`PlannerKind::Optimal`] — the exact dynamic program over the
//!   heterogeneous layer chain (Beaumont et al. 1911.13214 / Chen et al.
//!   1604.06174 style): provably minimum simulated peak, found by binary
//!   searching the budget over a min-resident-checkpoint-bytes
//!   feasibility DP built on the
//!   [`PeakEvaluator`](crate::memory::peak::PeakEvaluator) segment
//!   decomposition. No timeline is materialized anywhere on the search
//!   path.
//!
//! Beyond a single plan, [`pareto_frontier`] returns the full
//! (peak bytes, recompute FLOPs) trade-off curve: `best[i][m]` — the
//! minimum recompute FLOPs for layers `i..n` under `m` remaining budget
//! bytes, over quantized budget levels — swept from the exact minimum
//! peak up to the store-everything peak, then exactly rescored and pruned
//! to non-dominated points. [`plan_for_budget`] picks the cheapest-time
//! plan that fits a byte budget (the `memory_budget` training knob).

use crate::config::Pipeline;
use crate::memory::arena::{plan_arena, ArenaLayout, Lifetimes};
use crate::memory::peak::PeakEvaluator;
use crate::models::ArchProfile;

/// Planning strategy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum PlannerKind {
    Uniform(usize),
    Sqrt,
    Bottleneck(usize),
    Optimal,
    /// Joint recompute/spill optimizer ([`crate::memory::joint`]): under a
    /// budget it decides keep / recompute / spill per tensor (including
    /// param-gradients) in one pass; without a budget it degenerates to
    /// [`PlannerKind::Optimal`] (there is nothing to spill).
    Joint,
}

impl PlannerKind {
    /// `(spec, description)` for every parseable kind — the one source of
    /// truth behind the [`PlannerKind::parse`] error message, so a new
    /// variant cannot be forgotten there.
    pub const SPECS: [(&'static str, &'static str); 5] = [
        ("sqrt", "√n segments"),
        ("dp", "exact DP, alias: optimal"),
        ("uniformK", "every ⌈n/K⌉-th layer, K ≥ 1, e.g. uniform4"),
        ("bottleneckK", "K narrowest layers, K ≥ 1, e.g. bottleneck4"),
        ("joint", "joint recompute/spill optimizer for budgeted runs"),
    ];

    pub fn parse(s: &str) -> Result<PlannerKind, String> {
        if s == "sqrt" {
            return Ok(PlannerKind::Sqrt);
        }
        if s == "dp" || s == "optimal" {
            return Ok(PlannerKind::Optimal);
        }
        if s == "joint" {
            return Ok(PlannerKind::Joint);
        }
        if let Some(k) = s.strip_prefix("uniform") {
            let k: usize = k.parse().map_err(|_| format!("bad uniform arg: {s}"))?;
            if k == 0 {
                return Err(format!("'{s}' places no checkpoints — use uniformK with K ≥ 1"));
            }
            return Ok(PlannerKind::Uniform(k));
        }
        if let Some(k) = s.strip_prefix("bottleneck") {
            let k: usize = k.parse().map_err(|_| format!("bad bottleneck arg: {s}"))?;
            if k == 0 {
                return Err(format!(
                    "'{s}' places no checkpoints — use bottleneckK with K ≥ 1"
                ));
            }
            return Ok(PlannerKind::Bottleneck(k));
        }
        let kinds = Self::SPECS
            .iter()
            .map(|(spec, what)| format!("{spec} ({what})"))
            .collect::<Vec<_>>()
            .join(", ");
        Err(format!("unknown planner '{s}' — valid kinds: {kinds}"))
    }
}

/// A scored plan.
#[derive(Clone, Debug)]
pub struct CheckpointPlan {
    pub kind: PlannerKind,
    /// Layer indices whose activations stay live.
    pub checkpoints: Vec<usize>,
    /// Simulated peak bytes under S-C with this plan.
    pub peak_bytes: u64,
    /// Extra forward FLOPs the backward pass re-spends, as a fraction of
    /// one forward pass (0 = no recompute, 1 = a full extra forward).
    pub recompute_overhead: f64,
}

/// Default quantization for [`pareto_frontier`] budget levels.
pub const DEFAULT_FRONTIER_LEVELS: usize = 24;

/// Plan checkpoints for `arch` under `pipeline` (S-C forced on) at `batch`.
pub fn plan_checkpoints(
    arch: &ArchProfile,
    kind: PlannerKind,
    pipeline: Pipeline,
    batch: usize,
) -> CheckpointPlan {
    let mut p = pipeline;
    p.sc = true;
    let n = arch.layers.len();
    let checkpoints = match kind {
        PlannerKind::Uniform(k) => uniform(n, k),
        PlannerKind::Sqrt => uniform(n, (n as f64).sqrt().round() as usize),
        PlannerKind::Bottleneck(k) => bottleneck(arch, k),
        // Un-budgeted joint planning has no spill decisions to make; the
        // exact minimum-peak placement is its degenerate answer.
        PlannerKind::Optimal | PlannerKind::Joint => optimal(arch, p, batch),
    };
    score(arch, kind, p, batch, checkpoints)
}

fn score(
    arch: &ArchProfile,
    kind: PlannerKind,
    pipeline: Pipeline,
    batch: usize,
    checkpoints: Vec<usize>,
) -> CheckpointPlan {
    let mut ev = PeakEvaluator::new(arch, pipeline, batch);
    CheckpointPlan {
        kind,
        recompute_overhead: recompute_overhead(arch, &checkpoints),
        peak_bytes: ev.peak(&checkpoints),
        checkpoints,
    }
}

/// Fraction of forward FLOPs recomputed in backward for this plan.
pub fn recompute_overhead(arch: &ArchProfile, checkpoints: &[usize]) -> f64 {
    let n = arch.layers.len();
    if n == 0 {
        return 0.0;
    }
    let mut stored = vec![false; n];
    for &c in checkpoints {
        if c < n {
            stored[c] = true;
        }
    }
    stored[n - 1] = true;
    let total: u64 = arch.layers.iter().map(|l| l.flops_per_image).sum();
    let recomputed: u64 = arch
        .layers
        .iter()
        .enumerate()
        .filter(|(i, _)| !stored[*i])
        .map(|(_, l)| l.flops_per_image)
        .sum();
    if total == 0 {
        0.0
    } else {
        recomputed as f64 / total as f64
    }
}

fn uniform(n: usize, k: usize) -> Vec<usize> {
    if k == 0 || n == 0 {
        return vec![];
    }
    let step = (n as f64 / (k + 1) as f64).max(1.0);
    let mut out: Vec<usize> = (1..=k)
        .map(|j| ((j as f64 * step).round() as usize).min(n - 1))
        .collect();
    out.dedup();
    out
}

/// The paper's recommendation: checkpoint the k *narrowest* layers
/// (smallest stored activation), e.g. an autoencoder's bottleneck.
fn bottleneck(arch: &ArchProfile, k: usize) -> Vec<usize> {
    let n = arch.layers.len();
    let mut idx: Vec<usize> = (0..n.saturating_sub(1)).collect();
    idx.sort_by_key(|&i| arch.layers[i].act_elems);
    let mut out: Vec<usize> = idx.into_iter().take(k).collect();
    out.sort_unstable();
    out
}

/// Exact minimum-peak plan: binary search on the budget over [`feasible`].
fn optimal(arch: &ArchProfile, pipeline: Pipeline, batch: usize) -> Vec<usize> {
    let mut ev = PeakEvaluator::new(arch, pipeline, batch);
    min_peak_plan(&mut ev)
}

/// Exact minimum-peak plan for the evaluator's (arch, pipeline, batch).
///
/// The optimum is the smallest budget `m` for which [`feasible`] finds a
/// plan; integer binary search over `[0, cheapest probe peak]` lands on it
/// exactly because plan peaks are integers and feasibility is monotone in
/// `m`.
fn min_peak_plan(ev: &mut PeakEvaluator) -> Vec<usize> {
    let n = ev.depth();
    if n == 0 {
        return vec![];
    }
    // Quick probes bound the search from above (each is a concrete plan).
    let all: Vec<usize> = (0..n - 1).collect();
    let sq = uniform(n, (n as f64).sqrt().round() as usize);
    let probes: [&[usize]; 3] = [&[], &all, &sq];
    let mut ub = u64::MAX;
    let mut best_probe: Vec<usize> = vec![];
    for p in probes {
        let peak = ev.peak(p);
        if peak < ub {
            ub = peak;
            best_probe = p.to_vec();
        }
    }
    let mut lo = 0u64;
    let mut hi = ub;
    while lo < hi {
        let mid = lo + (hi - lo) / 2;
        if feasible(ev, mid).is_some() {
            hi = mid;
        } else {
            lo = mid + 1;
        }
    }
    // The probe peak was measured by exact replay, so the DP must accept it
    // unless a profile violates the act ≥ out invariant (see
    // `memory::peak` docs) — fall back to the probe in that case.
    feasible(ev, hi).unwrap_or(best_probe)
}

/// Min-resident-checkpoint-bytes chain DP: is any plan's modeled peak
/// ≤ `budget`? Returns a witness (interior checkpoints, sorted) if so.
///
/// `min_w[p]` is the smallest achievable byte total of stored boundaries
/// over schedules of layers `0..p` whose last boundary is `p − 1` and
/// whose segments all fit `budget`. Smaller resident-checkpoint bytes are
/// never worse for any later segment (they enter every later peak
/// additively), so one value per position is exact.
fn feasible(ev: &PeakEvaluator, budget: u64) -> Option<Vec<usize>> {
    let n = ev.depth();
    const INF: u64 = u64::MAX;
    let mut min_w = vec![INF; n + 1];
    let mut parent = vec![usize::MAX; n + 1];
    min_w[0] = 0;
    for hi in 1..=n {
        let mut dmax = 0u64;
        for lo in (0..hi).rev() {
            dmax = dmax.max(ev.seg_coeff(lo));
            let w = min_w[lo];
            if w == INF {
                continue;
            }
            // segment (lo..hi] peak = W + max(D[lo..hi)) − act_prefix[lo]
            let peak = w.saturating_add(dmax - ev.act_prefix_bytes(lo));
            if peak > budget {
                continue;
            }
            let cand = w + ev.out_bytes(hi - 1);
            if cand < min_w[hi] {
                min_w[hi] = cand;
                parent[hi] = lo;
            }
        }
    }
    if min_w[n] == INF {
        return None;
    }
    let mut cps = Vec::new();
    let mut p = n;
    while p > 0 {
        let lo = parent[p];
        if lo > 0 {
            cps.push(lo - 1);
        }
        p = lo;
    }
    cps.reverse();
    Some(cps)
}

/// `best[i][l]` DP: minimum recompute FLOPs (per image) to schedule layers
/// `i..n` when `grid[l]` budget bytes remain unconsumed by checkpoints
/// already resident to the left. Budget consumption rounds *down* to the
/// nearest level, so returned plans never exceed `m`; quantization can
/// only cost optimality, which the exact rescoring in [`pareto_frontier`]
/// absorbs. Returns the witness plan, or None when `m` is infeasible at
/// this quantization.
fn min_flops_under_budget(
    ev: &PeakEvaluator,
    flops_prefix: &[u64],
    m: u64,
    levels: usize,
) -> Option<Vec<usize>> {
    let n = ev.depth();
    if n == 0 {
        return Some(vec![]);
    }
    let l = levels.max(2);
    let grid: Vec<u64> = (0..l)
        .map(|i| ((m as u128 * i as u128) / (l as u128 - 1)) as u64)
        .collect();
    // Largest level whose budget is ≤ v; grid[0] = 0 so this never fails.
    let snap = |v: u64| -> usize { grid.partition_point(|&g| g <= v) - 1 };
    const INF: u64 = u64::MAX;
    let mut best = vec![INF; (n + 1) * l];
    let mut choice = vec![usize::MAX; (n + 1) * l];
    for li in 0..l {
        best[n * l + li] = 0;
    }
    for i in (0..n).rev() {
        for li in 0..l {
            let rem = grid[li];
            let mut dmax = 0u64;
            let mut bcost = INF;
            let mut bj = usize::MAX;
            for j in i..n {
                dmax = dmax.max(ev.seg_coeff(j));
                let seg = dmax - ev.act_prefix_bytes(i);
                if seg > rem {
                    break; // segment peaks only grow with j
                }
                let rest = if j + 1 == n {
                    0
                } else {
                    let ob = ev.out_bytes(j);
                    if ob > rem {
                        continue;
                    }
                    best[(j + 1) * l + snap(rem - ob)]
                };
                if rest == INF {
                    continue;
                }
                let total = (flops_prefix[j] - flops_prefix[i]) + rest;
                if total < bcost {
                    bcost = total;
                    bj = j;
                }
            }
            best[i * l + li] = bcost;
            choice[i * l + li] = bj;
        }
    }
    if best[l - 1] == INF {
        return None;
    }
    let mut cps = Vec::new();
    let mut i = 0usize;
    let mut li = l - 1;
    while i < n {
        let j = choice[i * l + li];
        if j == usize::MAX {
            return None; // unreachable if best[0][l-1] was finite
        }
        if j + 1 == n {
            break;
        }
        cps.push(j);
        li = snap(grid[li] - ev.out_bytes(j));
        i = j + 1;
    }
    Some(cps)
}

/// The (peak bytes, recompute overhead) Pareto frontier for `arch` under
/// `pipeline` (S-C forced on) at `batch`.
///
/// Sweeps `levels` quantized budget levels from the exact minimum
/// achievable peak to the store-everything peak, runs the
/// min-recompute-FLOPs DP at each, rescores every candidate with the
/// exact peak evaluator, and prunes to non-dominated points. The result
/// is sorted by strictly increasing `peak_bytes` with strictly decreasing
/// `recompute_overhead`; the first entry is the exact minimum-peak plan
/// and the last stores every layer (zero recompute).
pub fn pareto_frontier(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    levels: usize,
) -> Vec<CheckpointPlan> {
    let mut p = pipeline;
    p.sc = true;
    let n = arch.layers.len();
    let mut ev = PeakEvaluator::new(arch, p, batch);
    if n == 0 {
        return vec![CheckpointPlan {
            kind: PlannerKind::Optimal,
            peak_bytes: ev.peak(&[]),
            recompute_overhead: 0.0,
            checkpoints: vec![],
        }];
    }
    let best = min_peak_plan(&mut ev);
    let m_min = ev.peak(&best);
    let all: Vec<usize> = (0..n - 1).collect();
    let m_max = ev.peak(&all);
    let mut raw: Vec<Vec<usize>> = vec![best, all];
    let levels = levels.max(2);
    if m_max > m_min {
        let flops_prefix = arch.flops_prefix();
        for li in 0..levels {
            let m = m_min
                + ((u128::from(m_max - m_min) * li as u128) / (levels as u128 - 1)) as u64;
            if let Some(plan) = min_flops_under_budget(&ev, &flops_prefix, m, levels) {
                raw.push(plan);
            }
        }
    }
    let mut pts: Vec<CheckpointPlan> = raw
        .into_iter()
        .map(|cps| CheckpointPlan {
            kind: PlannerKind::Optimal,
            peak_bytes: ev.peak(&cps),
            recompute_overhead: recompute_overhead(arch, &cps),
            checkpoints: cps,
        })
        .collect();
    pts.sort_by(|a, b| {
        a.peak_bytes.cmp(&b.peak_bytes).then(
            a.recompute_overhead
                .partial_cmp(&b.recompute_overhead)
                .unwrap_or(std::cmp::Ordering::Equal),
        )
    });
    let mut out: Vec<CheckpointPlan> = Vec::new();
    for pl in pts {
        // Sorted by (peak asc, overhead asc): keep a point only when it
        // spends strictly more memory for strictly less recompute.
        let keep = match out.last() {
            Some(last) => {
                pl.peak_bytes > last.peak_bytes
                    && pl.recompute_overhead < last.recompute_overhead
            }
            None => true,
        };
        if keep {
            out.push(pl);
        }
    }
    out
}

/// Typed error of [`plan_for_budget_packed`]: the budget sits below every
/// frontier point's packed total (pure recompute cannot reach it — the
/// budget then needs host spilling,
/// [`crate::memory::offload::select_for_budget`]).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct InfeasiblePacked {
    pub budget: u64,
    /// Smallest packed total (`base + slab`) any frontier point reaches.
    pub min_packed_bytes: u64,
    pub arch: String,
    pub batch: usize,
}

impl std::fmt::Display for InfeasiblePacked {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "memory budget {} B is below the minimum packed total {} B \
             (base + slab) for {} (batch {})",
            self.budget, self.min_packed_bytes, self.arch, self.batch
        )
    }
}

impl std::error::Error for InfeasiblePacked {}

/// The cheapest-time plan whose *packed* total (`base + slab` from a real
/// arena pack of each frontier point) fits `budget` bytes, so packing
/// fragmentation participates in the fit decision. Among fitting points
/// the minimum recompute FLOPs wins, ties broken by the smaller packed
/// total. Returns the plan together with its lifetimes and layout (the
/// caller has already paid for the pack). Errors with the minimum packed
/// total ([`InfeasiblePacked`]) when nothing fits.
pub fn plan_for_budget_packed(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    budget: u64,
) -> Result<(CheckpointPlan, Lifetimes, ArenaLayout), InfeasiblePacked> {
    let frontier = pareto_frontier(arch, pipeline, batch, DEFAULT_FRONTIER_LEVELS);
    let mut min_total = u64::MAX;
    let mut best: Option<(CheckpointPlan, Lifetimes, ArenaLayout)> = None;
    for point in frontier {
        let (lt, layout) = plan_arena(arch, pipeline, batch, &point.checkpoints);
        let total = layout.total_bytes();
        min_total = min_total.min(total);
        if total > budget {
            continue;
        }
        let replace = match &best {
            None => true,
            Some((b, _, bl)) => {
                point.recompute_overhead < b.recompute_overhead
                    || (point.recompute_overhead == b.recompute_overhead
                        && total < bl.total_bytes())
            }
        };
        if replace {
            best = Some((point, lt, layout));
        }
    }
    best.ok_or_else(|| InfeasiblePacked {
        budget,
        min_packed_bytes: min_total,
        arch: arch.name.clone(),
        batch,
    })
}

/// The cheapest-time plan whose simulated peak fits `budget` bytes, from
/// the Pareto frontier. Errors (with the minimum achievable peak in the
/// message) when no plan fits. Prefer [`plan_for_budget_packed`], which
/// ranks by packed bytes instead of the simulated peak.
pub fn plan_for_budget(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    budget: u64,
) -> Result<CheckpointPlan, String> {
    let frontier = pareto_frontier(arch, pipeline, batch, DEFAULT_FRONTIER_LEVELS);
    let min_peak = frontier.first().map(|p| p.peak_bytes).unwrap_or(0);
    frontier
        .into_iter()
        .rev()
        .find(|p| p.peak_bytes <= budget)
        .ok_or_else(|| {
            format!(
                "memory budget {budget} B is below the minimum achievable peak \
                 {min_peak} B for {} (batch {batch})",
                arch.name
            )
        })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::memory::simulator::simulate;
    use crate::models::{arch_by_name, ArchProfile, LayerKind, LayerProfile};

    fn pipe_sc() -> Pipeline {
        Pipeline::parse("sc").unwrap()
    }

    /// The paper's Figure-11 7-layer autoencoder: wide–narrow–wide.
    fn autoencoder7() -> ArchProfile {
        let widths = [512usize, 256, 64, 16, 64, 256, 512];
        let layers = widths
            .iter()
            .enumerate()
            .map(|(i, &w)| LayerProfile {
                // width w as a 64x64 feature map with w channels: the stored
                // boundary tensor is the true layer output
                name: format!("dense{i}"),
                kind: LayerKind::Dense,
                out_shape: (64, 64, w),
                act_elems: (3 * 64 * 64 * w) as u64,
                params: (w * 8) as u64,
                flops_per_image: (w * 128) as u64,
            })
            .collect();
        ArchProfile { name: "autoencoder7".into(), input: (1, 1, 512), layers }
    }

    #[test]
    fn parse_kinds() {
        assert_eq!(PlannerKind::parse("sqrt").unwrap(), PlannerKind::Sqrt);
        assert_eq!(PlannerKind::parse("dp").unwrap(), PlannerKind::Optimal);
        assert_eq!(PlannerKind::parse("joint").unwrap(), PlannerKind::Joint);
        assert_eq!(PlannerKind::parse("uniform3").unwrap(), PlannerKind::Uniform(3));
        assert_eq!(
            PlannerKind::parse("bottleneck2").unwrap(),
            PlannerKind::Bottleneck(2)
        );
        assert!(PlannerKind::parse("magic").is_err());
    }

    #[test]
    fn parse_error_enumerates_valid_kinds() {
        // The error is generated from PlannerKind::SPECS, so it stays
        // exhaustive by construction — this test pins the other half:
        // every enum variant has a spec in SPECS (via its canonical spec
        // string), and every SPECS entry appears in the error.
        let err = PlannerKind::parse("magic").unwrap_err();
        for (spec, _) in PlannerKind::SPECS {
            assert!(err.contains(spec), "error does not mention '{spec}': {err}");
        }
        for kind in [
            PlannerKind::Sqrt,
            PlannerKind::Optimal,
            PlannerKind::Joint,
            PlannerKind::Uniform(4),
            PlannerKind::Bottleneck(4),
        ] {
            let spec = crate::memory::outcome::planner_kind_spec(kind);
            // A parameterized spec like `uniform4` maps onto its SPECS
            // template `uniformK` by stripping the trailing count.
            let template = spec.trim_end_matches(|c: char| c.is_ascii_digit());
            assert!(
                PlannerKind::SPECS
                    .iter()
                    .any(|(s, _)| s.trim_end_matches('K') == template),
                "variant {kind:?} (spec '{spec}') missing from PlannerKind::SPECS"
            );
            assert_eq!(PlannerKind::parse(&spec).unwrap(), kind, "spec '{spec}'");
        }
        assert_eq!(PlannerKind::parse("optimal").unwrap(), PlannerKind::Optimal);
    }

    #[test]
    fn parse_rejects_zero_checkpoint_counts() {
        for s in ["uniform0", "bottleneck0"] {
            let err = PlannerKind::parse(s).unwrap_err();
            assert!(err.contains("places no checkpoints"), "{s}: {err}");
        }
        assert!(PlannerKind::parse("uniformx").is_err());
    }

    #[test]
    fn uniform_spacing() {
        assert_eq!(uniform(12, 3), vec![3, 6, 9]);
        assert_eq!(uniform(12, 1), vec![6]);
        assert!(uniform(0, 3).is_empty());
    }

    #[test]
    fn bottleneck_picks_narrow_layers() {
        let arch = autoencoder7();
        let cps = bottleneck(&arch, 1);
        // layer 3 (width 16) is the narrowest
        assert_eq!(cps, vec![3]);
    }

    #[test]
    fn empty_arch_yields_zero_plan() {
        let arch = ArchProfile { name: "empty".into(), input: (4, 4, 3), layers: vec![] };
        for kind in [
            PlannerKind::Uniform(2),
            PlannerKind::Sqrt,
            PlannerKind::Bottleneck(2),
            PlannerKind::Optimal,
        ] {
            let plan = plan_checkpoints(&arch, kind, Pipeline::BASELINE, 4);
            assert!(plan.checkpoints.is_empty(), "{kind:?}");
            assert_eq!(plan.recompute_overhead, 0.0, "{kind:?}");
        }
        assert_eq!(recompute_overhead(&arch, &[]), 0.0);
        let frontier = pareto_frontier(&arch, Pipeline::BASELINE, 4, 8);
        assert_eq!(frontier.len(), 1);
        assert!(frontier[0].checkpoints.is_empty());
    }

    #[test]
    fn fig11_bottleneck_beats_wide_placement() {
        // The paper's Figure-11 message: a checkpoint at the narrow middle
        // (w=16) costs less than the same schedule anchored on a wide layer
        // (w=512) — both in stored bytes and in peak.
        let arch = autoencoder7();
        let narrow = simulate(&arch, pipe_sc(), 16, &[3]); // w=16 bottleneck
        let wide = simulate(&arch, pipe_sc(), 16, &[1]); // w=256 encoder side
        assert!(
            narrow.peak_bytes < wide.peak_bytes,
            "narrow {} wide {}",
            narrow.peak_bytes,
            wide.peak_bytes
        );
        // and the bottleneck planner finds the w=16 layer
        let bn = plan_checkpoints(&arch, PlannerKind::Bottleneck(1), Pipeline::BASELINE, 16);
        assert_eq!(bn.checkpoints, vec![3]);
    }

    #[test]
    fn optimal_never_worse_than_heuristics() {
        for name in ["resnet18", "tiny_cnn"] {
            let arch = arch_by_name(name, (64, 64, 3), 10).unwrap();
            let opt = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, 8);
            for k in [
                PlannerKind::Sqrt,
                PlannerKind::Uniform(2),
                PlannerKind::Uniform(4),
                PlannerKind::Bottleneck(3),
            ] {
                let h = plan_checkpoints(&arch, k, Pipeline::BASELINE, 8);
                assert!(
                    opt.peak_bytes <= h.peak_bytes,
                    "{name}: optimal {} vs {:?} {}",
                    opt.peak_bytes,
                    k,
                    h.peak_bytes
                );
            }
        }
    }

    #[test]
    fn optimal_matches_exhaustive_on_small_net() {
        // Brute-force all checkpoint subsets of the 7-layer net and confirm
        // the DP finds the same peak.
        let arch = autoencoder7();
        let n = arch.layers.len();
        let mut best = u64::MAX;
        for mask in 0u32..(1 << (n - 1)) {
            let cps: Vec<usize> = (0..n - 1).filter(|i| mask >> i & 1 == 1).collect();
            let peak = simulate(&arch, pipe_sc(), 4, &cps).peak_bytes;
            best = best.min(peak);
        }
        let opt = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, 4);
        assert_eq!(opt.peak_bytes, best);
    }

    #[test]
    fn recompute_overhead_bounds() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let all: Vec<usize> = (0..arch.layers.len()).collect();
        assert_eq!(recompute_overhead(&arch, &all), 0.0);
        let none: Vec<usize> = vec![];
        let o = recompute_overhead(&arch, &none);
        assert!(o > 0.8 && o <= 1.0, "{o}");
        // sqrt plan: strictly between
        let sq = plan_checkpoints(&arch, PlannerKind::Sqrt, Pipeline::BASELINE, 8);
        assert!(sq.recompute_overhead > 0.0 && sq.recompute_overhead < 1.0);
    }

    #[test]
    fn plans_are_sorted_and_in_range() {
        let arch = arch_by_name("resnet50", (128, 128, 3), 10).unwrap();
        for kind in [
            PlannerKind::Sqrt,
            PlannerKind::Uniform(5),
            PlannerKind::Bottleneck(4),
            PlannerKind::Optimal,
        ] {
            let plan = plan_checkpoints(&arch, kind, Pipeline::BASELINE, 4);
            let mut sorted = plan.checkpoints.clone();
            sorted.sort_unstable();
            sorted.dedup();
            assert_eq!(sorted, plan.checkpoints, "{kind:?} not sorted/deduped");
            assert!(plan.checkpoints.iter().all(|&c| c < arch.layers.len()));
        }
    }

    #[test]
    fn frontier_is_strictly_pareto_and_anchored() {
        for name in ["resnet18", "resnet50", "efficientnet_b0"] {
            let arch = arch_by_name(name, (64, 64, 3), 10).unwrap();
            let frontier = pareto_frontier(&arch, Pipeline::BASELINE, 8, 16);
            assert!(!frontier.is_empty(), "{name}");
            for w in frontier.windows(2) {
                assert!(w[0].peak_bytes < w[1].peak_bytes, "{name}: peaks not strict");
                assert!(
                    w[0].recompute_overhead > w[1].recompute_overhead,
                    "{name}: overheads not strictly decreasing"
                );
            }
            // first point = exact minimum peak
            let opt = plan_checkpoints(&arch, PlannerKind::Optimal, Pipeline::BASELINE, 8);
            assert_eq!(frontier[0].peak_bytes, opt.peak_bytes, "{name}");
            // last point = store everything, zero recompute
            assert_eq!(frontier.last().unwrap().recompute_overhead, 0.0, "{name}");
        }
    }

    #[test]
    fn packed_budget_selection_accounts_for_fragmentation() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let frontier = pareto_frontier(&arch, Pipeline::BASELINE, 8, 16);
        let full = frontier.last().unwrap();
        let hi_total = plan_arena(&arch, Pipeline::BASELINE, 8, &full.checkpoints)
            .1
            .total_bytes();
        let (plan, lt, layout) =
            plan_for_budget_packed(&arch, Pipeline::BASELINE, 8, hi_total).unwrap();
        assert!(layout.total_bytes() <= hi_total);
        assert_eq!(plan.recompute_overhead, 0.0, "generous budget → cheapest time");
        assert_eq!(layout.offsets.len(), lt.tensors.len());
        // the fit criterion is the packed total, not the simulated peak
        assert!(layout.total_bytes() >= plan.peak_bytes);
        // below the minimum packed total → typed error naming it
        let err = plan_for_budget_packed(&arch, Pipeline::BASELINE, 8, 1).unwrap_err();
        assert_eq!(err.budget, 1);
        assert!(err.min_packed_bytes > 1);
        assert!(err.to_string().contains("minimum packed total"), "{err}");
    }

    #[test]
    fn budget_selection_fits_and_errors_below_minimum() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let frontier = pareto_frontier(&arch, Pipeline::BASELINE, 8, 16);
        let lo = frontier.first().unwrap().peak_bytes;
        let hi = frontier.last().unwrap().peak_bytes;
        // generous budget → the zero-recompute plan
        let plan = plan_for_budget(&arch, Pipeline::BASELINE, 8, hi).unwrap();
        assert_eq!(plan.recompute_overhead, 0.0);
        assert!(plan.peak_bytes <= hi);
        // mid budget → fits, cheapest time among fitting points
        let mid = lo + (hi - lo) / 2;
        let plan = plan_for_budget(&arch, Pipeline::BASELINE, 8, mid).unwrap();
        assert!(plan.peak_bytes <= mid);
        for p in &frontier {
            if p.peak_bytes <= mid {
                assert!(plan.recompute_overhead <= p.recompute_overhead);
            }
        }
        // impossible budget → clear error naming the minimum
        let err = plan_for_budget(&arch, Pipeline::BASELINE, 8, lo - 1).unwrap_err();
        assert!(err.contains("below the minimum achievable peak"), "{err}");
    }
}
