//! Analytic memory simulator: replays forward/backward schedules over an
//! [`ArchProfile`](crate::models::ArchProfile) and reports the live-byte
//! timeline (Figure 8) and peak (Figure 10).
//!
//! ## Model
//!
//! * **Static**: parameters + optimizer momentum, resident for the whole
//!   iteration; gradients become resident across the backward pass.
//! * **Forward**: layer `i` allocates its stored activation if the
//!   schedule keeps it (all layers for the standard pipeline; checkpoint
//!   layers only under S-C).
//! * **Backward**: walks layers in reverse. Under S-C each segment is
//!   re-forwarded from its checkpoint first (its interior activations
//!   become live), then consumed. Activation gradients are modeled as one
//!   extra live tensor of the current layer's output size.
//! * **Dtypes**: f32 activations/params (4 B); M-P stores state and
//!   activations in f16 (2 B) with transient f32 compute modeled as a
//!   small working-set constant, matching Figure 3's scheme.
//! * **E-D**: the input batch is resident in packed form (8 B per pixel
//!   position per capacity-group) instead of f32 per image; the decode
//!   layer's output is an ordinary activation.

use crate::config::Pipeline;
use crate::models::ArchProfile;

/// One point of the Figure-8 timeline.
#[derive(Clone, Debug)]
pub struct TimelineEvent {
    /// What just happened (`fwd conv1`, `bwd layer4.1`, `recompute …`).
    pub label: String,
    /// Live bytes after the event.
    pub live_bytes: u64,
}

/// Simulation output.
#[derive(Clone, Debug)]
pub struct MemoryReport {
    pub model: String,
    pub pipeline: Pipeline,
    pub batch: usize,
    pub peak_bytes: u64,
    /// Static state (params + momentum) bytes.
    pub state_bytes: u64,
    /// Input batch payload bytes (packed under E-D).
    pub input_bytes: u64,
    /// Peak activation (non-state) bytes.
    pub peak_activation_bytes: u64,
    pub timeline: Vec<TimelineEvent>,
}

/// Bytes per activation/param element under the pipeline.
pub(crate) fn act_dtype_bytes(p: Pipeline) -> u64 {
    if p.mp {
        2
    } else {
        4
    }
}

/// Input-batch resident bytes.
pub(crate) fn input_bytes(arch: &ArchProfile, p: Pipeline, batch: usize) -> u64 {
    let (h, w, c) = arch.input;
    let px = (h * w * c) as u64;
    if p.ed {
        // base-256 f64 words: ceil(batch/6) packed groups of 8-byte words
        let groups = (batch as u64 + 5) / 6;
        groups * px * 8
    } else {
        batch as u64 * px * act_dtype_bytes(p)
    }
}

/// Simulate one training iteration, materializing the full labeled
/// timeline (the Figure-8 output path).
///
/// This is the *reporting* simulator: every event allocates a `String`
/// label. Schedule searches must use
/// [`PeakEvaluator`](crate::memory::peak::PeakEvaluator), which computes
/// the identical peak without building a timeline.
///
/// `checkpoints`: layer indices kept live under S-C (the segment
/// boundaries). Ignored unless `pipeline.sc`. The input (index 0 boundary)
/// is always implicitly a checkpoint.
pub fn simulate(
    arch: &ArchProfile,
    pipeline: Pipeline,
    batch: usize,
    checkpoints: &[usize],
) -> MemoryReport {
    let n = arch.layers.len();
    let ab = act_dtype_bytes(pipeline);
    let b = batch as u64;
    // params: f32 (4B) baseline, f16 (2B) M-P; momentum matches param dtype.
    let param_elem_bytes = if pipeline.mp { 2 } else { 4 };
    let state_bytes = arch.param_count() * param_elem_bytes * 2; // params + momentum
    let input = input_bytes(arch, pipeline, batch);
    if n == 0 {
        // Empty architecture: nothing to schedule — report the resident
        // state+input and a single timeline event instead of indexing
        // `layers[n - 1]`.
        let live = state_bytes + input;
        return MemoryReport {
            model: arch.name.clone(),
            pipeline,
            batch,
            peak_bytes: live,
            state_bytes,
            input_bytes: input,
            peak_activation_bytes: 0,
            timeline: vec![TimelineEvent { label: "state+input".into(), live_bytes: live }],
        };
    }

    // Which layers' activations are stored during the forward pass?
    let mut stored = vec![true; n];
    if pipeline.sc {
        stored = vec![false; n];
        for &c in checkpoints {
            if c < n {
                stored[c] = true;
            }
        }
        // The final output is always needed for the loss.
        stored[n - 1] = true;
    }

    let act = |i: usize| -> u64 { arch.layers[i].act_elems * b * ab };
    let out = |i: usize| -> u64 { arch.layers[i].out_elems() * b * ab };

    let mut live: u64 = state_bytes + input;
    let mut peak = live;
    let mut timeline = vec![TimelineEvent { label: "state+input".into(), live_bytes: live }];
    let push = |label: String, live: u64, peak: &mut u64, timeline: &mut Vec<TimelineEvent>| {
        *peak = (*peak).max(live);
        timeline.push(TimelineEvent { label, live_bytes: live });
    };

    // ---- forward ----
    // The layer's output is live while it executes; what *stays* live
    // afterwards depends on the schedule: standard training keeps the full
    // activation footprint (internal tensors included), S-C keeps only the
    // boundary output at checkpoints.
    for i in 0..n {
        let t = out(i);
        live += t;
        push(format!("fwd {}", arch.layers[i].name), live, &mut peak, &mut timeline);
        if !pipeline.sc {
            // keep full stored activation footprint (internal tensors too)
            live += act(i).saturating_sub(t);
            push(format!("store {}", arch.layers[i].name), live, &mut peak, &mut timeline);
        } else if !stored[i] {
            live -= t;
        }
        // stored[i] under S-C: only the boundary tensor `t` stays live
    }

    // ---- backward ----
    // Gradients of parameters accumulate as we go (same dtype as params);
    // activation gradient = one tensor of the current boundary size.
    let mut grad_bytes: u64 = 0;
    let mut act_grad: u64 = out(n - 1);
    live += act_grad;
    push("loss grad".into(), live, &mut peak, &mut timeline);

    if !pipeline.sc {
        for i in (0..n).rev() {
            grad_bytes += arch.layers[i].params * param_elem_bytes;
            let new_act_grad = if i > 0 { out(i - 1) } else { 0 };
            live += new_act_grad;
            // + out(i): the layer's backward workspace (weight-grad buffer)
            push(
                format!("bwd {}", arch.layers[i].name),
                live + grad_bytes + out(i),
                &mut peak,
                &mut timeline,
            );
            // activation consumed
            live -= act(i);
            live -= act_grad;
            act_grad = new_act_grad;
        }
    } else {
        // segments between checkpoints, processed back to front: each
        // segment spans (prev stored boundary, this boundary], re-forwarded
        // from the earlier checkpoint (or the input) before its backward.
        let mut hi = n; // exclusive upper bound of the current segment
        while hi > 0 {
            let lo = (0..hi.saturating_sub(1))
                .rev()
                .find(|&i| stored[i])
                .map(|i| i + 1)
                .unwrap_or(0);
            // recompute the interior activations the forward pass discarded:
            // full footprint for unstored layers, internal tensors only for
            // the stored boundary (whose output is already live)
            for i in lo..hi {
                let delta = if stored[i] {
                    act(i).saturating_sub(out(i))
                } else {
                    act(i)
                };
                if delta > 0 {
                    live += delta;
                    push(
                        format!("recompute {}", arch.layers[i].name),
                        live + grad_bytes,
                        &mut peak,
                        &mut timeline,
                    );
                }
            }
            for i in (lo..hi).rev() {
                grad_bytes += arch.layers[i].params * param_elem_bytes;
                let new_act_grad = if i > 0 { out(i - 1) } else { 0 };
                live += new_act_grad;
                push(
                    format!("bwd {}", arch.layers[i].name),
                    live + grad_bytes + out(i),
                    &mut peak,
                    &mut timeline,
                );
                live -= act(i);
                live -= act_grad;
                act_grad = new_act_grad;
            }
            hi = lo;
        }
    }

    // optimizer step: grads + state resident
    push("optimizer step".into(), state_bytes + input + grad_bytes, &mut peak, &mut timeline);

    let peak_activation = peak.saturating_sub(state_bytes + input);
    MemoryReport {
        model: arch.name.clone(),
        pipeline,
        batch,
        peak_bytes: peak,
        state_bytes,
        input_bytes: input,
        peak_activation_bytes: peak_activation,
        timeline,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::models::arch_by_name;

    fn pipe(s: &str) -> Pipeline {
        Pipeline::parse(s).unwrap()
    }

    fn resnet18_512() -> ArchProfile {
        arch_by_name("resnet18", (512, 512, 3), 1000).unwrap()
    }

    #[test]
    fn checkpointing_reduces_peak_substantially() {
        // The paper's Fig 8 shape: S-C cuts ResNet-18 peak substantially
        // (≥1.8× at block granularity; the deeper ResNet-50 exceeds 2×,
        // matching the paper's ">50%" claim — see the next test).
        let arch = resnet18_512();
        let base = simulate(&arch, pipe("b"), 16, &[]);
        let plan = crate::memory::planner::plan_checkpoints(
            &arch,
            crate::memory::planner::PlannerKind::Optimal,
            Pipeline::BASELINE,
            16,
        );
        let sc = simulate(&arch, pipe("sc"), 16, &plan.checkpoints);
        let ratio = base.peak_bytes as f64 / sc.peak_bytes as f64;
        assert!(ratio > 1.8, "ratio {ratio}");
    }

    #[test]
    fn resnet50_checkpointing_halves_memory() {
        // Fig 10's ResNet-50 row: S-C reduces memory by more than 50%.
        let arch = arch_by_name("resnet50", (512, 512, 3), 1000).unwrap();
        let base = simulate(&arch, pipe("b"), 16, &[]);
        let plan = crate::memory::planner::plan_checkpoints(
            &arch,
            crate::memory::planner::PlannerKind::Optimal,
            Pipeline::BASELINE,
            16,
        );
        let sc = simulate(&arch, pipe("sc"), 16, &plan.checkpoints);
        assert!(
            sc.peak_bytes * 2 < base.peak_bytes,
            "sc {} vs base {}",
            sc.peak_bytes,
            base.peak_bytes
        );
    }

    #[test]
    fn fig8_baseline_magnitude_plausible() {
        // Paper reports ~7000 MB for baseline ResNet-18 @ 16×512². Our
        // analytic model has no allocator slack / cuDNN workspaces, so it
        // lands lower but must stay the same order of magnitude (2–12 GB).
        let arch = resnet18_512();
        let r = simulate(&arch, pipe("b"), 16, &[]);
        let gb = r.peak_bytes as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!((2.0..12.0).contains(&gb), "baseline peak {gb:.2} GB");
    }

    #[test]
    fn mixed_precision_halves_activation_bytes() {
        let arch = resnet18_512();
        let base = simulate(&arch, pipe("b"), 16, &[]);
        let mp = simulate(&arch, pipe("mp"), 16, &[]);
        let ratio = base.peak_bytes as f64 / mp.peak_bytes as f64;
        assert!((1.8..2.2).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn ed_shrinks_input_bytes() {
        let arch = resnet18_512();
        let base = simulate(&arch, pipe("b"), 16, &[]);
        let ed = simulate(&arch, pipe("ed"), 16, &[]);
        assert!(ed.input_bytes * 2 < base.input_bytes, "ed {} base {}", ed.input_bytes, base.input_bytes);
        // but activations dominate, so total peak barely moves
        assert!(ed.peak_bytes < base.peak_bytes);
    }

    #[test]
    fn combined_pipeline_stacks_savings() {
        let arch = resnet18_512();
        let base = simulate(&arch, pipe("b"), 16, &[]);
        let plan = crate::memory::planner::plan_checkpoints(
            &arch,
            crate::memory::planner::PlannerKind::Optimal,
            Pipeline::parse("ed+mp").unwrap(),
            16,
        );
        let all = simulate(&arch, pipe("ed+mp+sc"), 16, &plan.checkpoints);
        assert!(
            all.peak_bytes * 3 < base.peak_bytes,
            "combined {} vs base {}",
            all.peak_bytes,
            base.peak_bytes
        );
    }

    #[test]
    fn timeline_rises_then_falls() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let r = simulate(&arch, pipe("b"), 4, &[]);
        let peak_idx = r
            .timeline
            .iter()
            .enumerate()
            .max_by_key(|(_, e)| e.live_bytes)
            .unwrap()
            .0;
        // peak must not be at the very start or very end
        assert!(peak_idx > 2 && peak_idx < r.timeline.len() - 2);
        // final live equals state (+grads) which is below peak
        assert!(r.timeline.last().unwrap().live_bytes < r.peak_bytes);
    }

    #[test]
    fn peak_monotonic_in_batch() {
        let arch = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let a = simulate(&arch, pipe("b"), 2, &[]);
        let b = simulate(&arch, pipe("b"), 8, &[]);
        assert!(b.peak_bytes > a.peak_bytes);
        // state is batch-independent
        assert_eq!(a.state_bytes, b.state_bytes);
    }

    #[test]
    fn more_checkpoints_less_memory_than_fewer_up_to_overhead() {
        let arch = resnet18_512();
        let n = arch.layers.len();
        let every2: Vec<usize> = (0..n).step_by(2).collect();
        let every6: Vec<usize> = (0..n).step_by(6).collect();
        let sc2 = simulate(&arch, pipe("sc"), 16, &every2);
        let sc6 = simulate(&arch, pipe("sc"), 16, &every6);
        // both beat baseline; neither is zero
        let base = simulate(&arch, pipe("b"), 16, &[]);
        assert!(sc2.peak_bytes < base.peak_bytes);
        assert!(sc6.peak_bytes < base.peak_bytes);
        assert!(sc2.peak_bytes > 0 && sc6.peak_bytes > 0);
    }

    #[test]
    fn no_checkpoints_sc_degenerates_to_baseline() {
        // S-C with an empty set is ONE segment spanning the whole net: the
        // backward recomputes (and holds) every activation at once, so peak
        // memory matches the baseline within a few percent — checkpointing
        // only helps when there are interior boundaries. This mirrors
        // torch.utils.checkpoint semantics for a single segment.
        let arch = resnet18_512();
        let sc = simulate(&arch, pipe("sc"), 16, &[]);
        let base = simulate(&arch, pipe("b"), 16, &[]);
        let ratio = sc.peak_bytes as f64 / base.peak_bytes as f64;
        assert!((0.9..=1.1).contains(&ratio), "ratio {ratio}");
    }

    #[test]
    fn empty_arch_reports_resident_state_only() {
        let arch = ArchProfile { name: "empty".into(), input: (8, 8, 3), layers: vec![] };
        for p in ["b", "sc", "ed+mp+sc"] {
            let r = simulate(&arch, pipe(p), 4, &[]);
            assert_eq!(r.peak_bytes, r.state_bytes + r.input_bytes, "{p}");
            assert_eq!(r.peak_activation_bytes, 0, "{p}");
            assert_eq!(r.timeline.len(), 1, "{p}");
        }
    }

    #[test]
    fn report_fields_consistent() {
        let arch = arch_by_name("tiny_cnn", (32, 32, 3), 10).unwrap();
        let r = simulate(&arch, pipe("b"), 16, &[]);
        assert_eq!(r.batch, 16);
        assert_eq!(r.model, "tiny_cnn");
        assert!(r.peak_bytes >= r.state_bytes + r.input_bytes);
        assert_eq!(
            r.peak_activation_bytes,
            r.peak_bytes - r.state_bytes - r.input_bytes
        );
        assert!(!r.timeline.is_empty());
    }
}
