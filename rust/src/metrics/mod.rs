//! Training metrics: loss/accuracy trackers, timers, CSV history.

use std::time::Instant;

/// Running mean tracker.
#[derive(Clone, Debug, Default)]
pub struct Mean {
    sum: f64,
    n: u64,
}

impl Mean {
    pub fn add(&mut self, v: f64) {
        self.sum += v;
        self.n += 1;
    }

    pub fn add_weighted(&mut self, v: f64, w: u64) {
        self.sum += v * w as f64;
        self.n += w;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }

    /// Fold another tracker in (combining per-worker stats).
    pub fn merge(&mut self, other: &Mean) {
        self.sum += other.sum;
        self.n += other.n;
    }
}

/// Bucket count of [`Histogram`]: bucket 0 holds the value 0, bucket `b`
/// (1 ≤ b ≤ 64) holds values in `[2^(b-1), 2^b)`.
pub const HISTOGRAM_BUCKETS: usize = 65;

/// Fixed-bucket log2 histogram over `u64` samples (latencies in
/// nanoseconds, byte counts, …). Recording is a shift and two adds — no
/// allocation, ever — and quantiles resolve to the midpoint of their
/// power-of-two bucket, clamped into the exact observed `[min, max]`
/// (≤ 2× resolution, which is plenty for p50/p95/p99 reporting).
#[derive(Clone, Debug, PartialEq)]
pub struct Histogram {
    buckets: [u64; HISTOGRAM_BUCKETS],
    count: u64,
    sum: f64,
    min: u64,
    max: u64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram {
            buckets: [0; HISTOGRAM_BUCKETS],
            count: 0,
            sum: 0.0,
            min: u64::MAX,
            max: 0,
        }
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram::default()
    }

    #[inline]
    fn bucket_index(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    #[inline]
    pub fn record(&mut self, v: u64) {
        self.buckets[Self::bucket_index(v)] += 1;
        self.count += 1;
        self.sum += v as f64;
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn sum(&self) -> f64 {
        self.sum
    }

    /// Exact mean of the recorded samples (NaN when empty).
    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            f64::NAN
        } else {
            self.sum / self.count as f64
        }
    }

    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// The `q`-quantile (`0.0 ..= 1.0`): midpoint of the bucket holding the
    /// `⌈q·count⌉`-th sample, clamped into `[min, max]`. 0 when empty.
    pub fn quantile(&self, q: f64) -> u64 {
        if self.count == 0 {
            return 0;
        }
        let rank = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut seen = 0u64;
        for (b, &n) in self.buckets.iter().enumerate() {
            seen += n;
            if seen >= rank {
                let (lo, hi) = if b == 0 {
                    (0u64, 0u64)
                } else {
                    let lo = 1u64 << (b - 1);
                    let hi = if b == 64 { u64::MAX } else { (1u64 << b) - 1 };
                    (lo, hi)
                };
                let mid = lo + (hi - lo) / 2;
                return mid.clamp(self.min, self.max);
            }
        }
        self.max
    }

    pub fn p50(&self) -> u64 {
        self.quantile(0.50)
    }

    pub fn p95(&self) -> u64 {
        self.quantile(0.95)
    }

    pub fn p99(&self) -> u64 {
        self.quantile(0.99)
    }

    /// Fold another histogram in (same fixed buckets, so merging is exact).
    pub fn merge(&mut self, other: &Histogram) {
        for (a, b) in self.buckets.iter_mut().zip(other.buckets.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum += other.sum;
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }
}

/// One epoch's record.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_accuracy: f64,
    pub eval_loss: Option<f64>,
    pub eval_accuracy: Option<f64>,
    pub wall_secs: f64,
    pub images: u64,
    /// Observed per-step wall-time p50 (seconds); `None` when tracing off.
    pub step_p50_secs: Option<f64>,
    /// Observed per-step wall-time p99 (seconds); `None` when tracing off.
    pub step_p99_secs: Option<f64>,
    /// Observed activation-slab high-water over the epoch (bytes). 0 when
    /// the run planned no arena — recorded unconditionally, no tracing or
    /// metrics endpoint required.
    pub slab_high_water_bytes: u64,
    /// Observed host-spill pool resident high-water over the epoch
    /// (bytes). 0 when nothing spilled — recorded unconditionally.
    pub host_resident_bytes: u64,
}

impl EpochRecord {
    pub fn images_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.images as f64 / self.wall_secs
        }
    }
}

/// Full-run history with CSV export.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub epochs: Vec<EpochRecord>,
}

impl History {
    pub fn push(&mut self, rec: EpochRecord) {
        self.epochs.push(rec);
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.epochs.iter().map(|e| e.wall_secs).sum()
    }

    pub fn final_eval_accuracy(&self) -> Option<f64> {
        self.epochs.iter().rev().find_map(|e| e.eval_accuracy)
    }

    /// CSV with a fixed header; `None` cells are empty (the step quantile
    /// columns stay empty whenever tracing is off). The memory watermark
    /// columns are always populated — 0 means "no arena / no spill", not
    /// "not measured".
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "epoch,train_loss,train_accuracy,eval_loss,eval_accuracy,wall_secs,\
             images_per_sec,step_p50_secs,step_p99_secs,slab_high_water_bytes,\
             host_resident_bytes\n",
        );
        for e in &self.epochs {
            s.push_str(&format!(
                "{},{:.6},{:.4},{},{},{:.3},{:.1},{},{},{},{}\n",
                e.epoch,
                e.train_loss,
                e.train_accuracy,
                e.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
                e.eval_accuracy.map(|v| format!("{v:.4}")).unwrap_or_default(),
                e.wall_secs,
                e.images_per_sec(),
                e.step_p50_secs.map(|v| format!("{v:.6}")).unwrap_or_default(),
                e.step_p99_secs.map(|v| format!("{v:.6}")).unwrap_or_default(),
                e.slab_high_water_bytes,
                e.host_resident_bytes,
            ));
        }
        s
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_accumulates() {
        let mut m = Mean::default();
        assert!(m.mean().is_nan());
        m.add(2.0);
        m.add(4.0);
        assert_eq!(m.mean(), 3.0);
        assert_eq!(m.count(), 2);
        m.add_weighted(10.0, 8);
        assert_eq!(m.count(), 10);
        assert!((m.mean() - 8.6).abs() < 1e-9);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn mean_merge_combines_per_worker_stats() {
        let mut a = Mean::default();
        a.add(2.0);
        a.add(4.0);
        let mut b = Mean::default();
        b.add(6.0);
        let mut whole = Mean::default();
        whole.merge(&a);
        whole.merge(&b);
        assert_eq!(whole.count(), 3);
        assert!((whole.mean() - 4.0).abs() < 1e-9);
        // merging an empty tracker is a no-op
        whole.merge(&Mean::default());
        assert_eq!(whole.count(), 3);
    }

    #[test]
    fn histogram_buckets_and_quantiles() {
        let mut h = Histogram::new();
        assert!(h.is_empty());
        assert_eq!(h.quantile(0.5), 0);
        for v in 1..=100u64 {
            h.record(v);
        }
        assert_eq!(h.count(), 100);
        assert_eq!(h.min(), 1);
        assert_eq!(h.max(), 100);
        assert!((h.mean() - 50.5).abs() < 1e-9);
        // log2 buckets: quantiles land within 2x of the exact value and
        // inside the observed range
        let p50 = h.p50();
        assert!((25..=100).contains(&p50), "p50 {p50}");
        let p99 = h.p99();
        assert!((64..=100).contains(&p99), "p99 {p99}");
        assert!(h.quantile(1.0) >= h.quantile(0.0));
    }

    #[test]
    fn histogram_handles_zero_and_extremes() {
        let mut h = Histogram::new();
        h.record(0);
        h.record(0);
        h.record(u64::MAX);
        assert_eq!(h.min(), 0);
        assert_eq!(h.max(), u64::MAX);
        assert_eq!(h.quantile(0.5), 0, "two of three samples are zero");
        // top-bucket midpoint, clamped into the observed range
        let p100 = h.quantile(1.0);
        assert!(p100 >= 1u64 << 63, "p100 {p100}");
    }

    #[test]
    fn histogram_merge_is_exact() {
        let mut a = Histogram::new();
        let mut b = Histogram::new();
        let mut whole = Histogram::new();
        for v in [3u64, 70, 900, 4096] {
            a.record(v);
            whole.record(v);
        }
        for v in [1u64, 2, 1_000_000] {
            b.record(v);
            whole.record(v);
        }
        a.merge(&b);
        assert_eq!(a, whole, "merge must equal recording everything in one histogram");
        a.merge(&Histogram::new());
        assert_eq!(a, whole);
    }

    #[test]
    fn history_csv_shape() {
        let mut h = History::default();
        h.push(EpochRecord {
            epoch: 0,
            train_loss: 2.30,
            train_accuracy: 0.1,
            eval_loss: None,
            eval_accuracy: None,
            wall_secs: 1.5,
            images: 300,
            step_p50_secs: None,
            step_p99_secs: None,
            slab_high_water_bytes: 0,
            host_resident_bytes: 0,
        });
        h.push(EpochRecord {
            epoch: 1,
            train_loss: 1.20,
            train_accuracy: 0.55,
            eval_loss: Some(1.3),
            eval_accuracy: Some(0.52),
            wall_secs: 1.4,
            images: 300,
            step_p50_secs: Some(0.004),
            step_p99_secs: Some(0.009),
            slab_high_water_bytes: 2048,
            host_resident_bytes: 512,
        });
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 3);
        let header = csv.lines().next().unwrap();
        assert!(header.starts_with("epoch,train_loss,"), "{header}");
        assert!(
            header.ends_with(",step_p50_secs,step_p99_secs,slab_high_water_bytes,host_resident_bytes"),
            "{header}"
        );
        // tracing off → step-quantile cells empty, watermark cells 0
        assert!(csv.lines().nth(1).unwrap().ends_with(",,1.500,200.0,,,0,0"));
        assert!(csv.lines().nth(2).unwrap().ends_with(",0.004000,0.009000,2048,512"));
        assert_eq!(h.final_eval_accuracy(), Some(0.52));
        assert!((h.total_wall_secs() - 2.9).abs() < 1e-9);
    }

    #[test]
    fn images_per_sec_guards_zero() {
        let e = EpochRecord {
            epoch: 0,
            train_loss: 0.0,
            train_accuracy: 0.0,
            eval_loss: None,
            eval_accuracy: None,
            wall_secs: 0.0,
            images: 10,
            step_p50_secs: None,
            step_p99_secs: None,
            slab_high_water_bytes: 0,
            host_resident_bytes: 0,
        };
        assert_eq!(e.images_per_sec(), 0.0);
    }
}
