//! Training metrics: loss/accuracy trackers, timers, CSV history.

use std::time::Instant;

/// Running mean tracker.
#[derive(Clone, Debug, Default)]
pub struct Mean {
    sum: f64,
    n: u64,
}

impl Mean {
    pub fn add(&mut self, v: f64) {
        self.sum += v;
        self.n += 1;
    }

    pub fn add_weighted(&mut self, v: f64, w: u64) {
        self.sum += v * w as f64;
        self.n += w;
    }

    pub fn mean(&self) -> f64 {
        if self.n == 0 {
            f64::NAN
        } else {
            self.sum / self.n as f64
        }
    }

    pub fn count(&self) -> u64 {
        self.n
    }

    pub fn reset(&mut self) {
        self.sum = 0.0;
        self.n = 0;
    }
}

/// One epoch's record.
#[derive(Clone, Debug)]
pub struct EpochRecord {
    pub epoch: usize,
    pub train_loss: f64,
    pub train_accuracy: f64,
    pub eval_loss: Option<f64>,
    pub eval_accuracy: Option<f64>,
    pub wall_secs: f64,
    pub images: u64,
}

impl EpochRecord {
    pub fn images_per_sec(&self) -> f64 {
        if self.wall_secs <= 0.0 {
            0.0
        } else {
            self.images as f64 / self.wall_secs
        }
    }
}

/// Full-run history with CSV export.
#[derive(Clone, Debug, Default)]
pub struct History {
    pub epochs: Vec<EpochRecord>,
}

impl History {
    pub fn push(&mut self, rec: EpochRecord) {
        self.epochs.push(rec);
    }

    pub fn total_wall_secs(&self) -> f64 {
        self.epochs.iter().map(|e| e.wall_secs).sum()
    }

    pub fn final_eval_accuracy(&self) -> Option<f64> {
        self.epochs.iter().rev().find_map(|e| e.eval_accuracy)
    }

    /// CSV with a fixed header; `None` cells are empty.
    pub fn to_csv(&self) -> String {
        let mut s = String::from(
            "epoch,train_loss,train_accuracy,eval_loss,eval_accuracy,wall_secs,images_per_sec\n",
        );
        for e in &self.epochs {
            s.push_str(&format!(
                "{},{:.6},{:.4},{},{},{:.3},{:.1}\n",
                e.epoch,
                e.train_loss,
                e.train_accuracy,
                e.eval_loss.map(|v| format!("{v:.6}")).unwrap_or_default(),
                e.eval_accuracy.map(|v| format!("{v:.4}")).unwrap_or_default(),
                e.wall_secs,
                e.images_per_sec(),
            ));
        }
        s
    }
}

/// Scoped wall-clock timer.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Timer {
        Timer { start: Instant::now() }
    }

    pub fn secs(&self) -> f64 {
        self.start.elapsed().as_secs_f64()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_accumulates() {
        let mut m = Mean::default();
        assert!(m.mean().is_nan());
        m.add(2.0);
        m.add(4.0);
        assert_eq!(m.mean(), 3.0);
        assert_eq!(m.count(), 2);
        m.add_weighted(10.0, 8);
        assert_eq!(m.count(), 10);
        assert!((m.mean() - 8.6).abs() < 1e-9);
        m.reset();
        assert_eq!(m.count(), 0);
    }

    #[test]
    fn history_csv_shape() {
        let mut h = History::default();
        h.push(EpochRecord {
            epoch: 0,
            train_loss: 2.30,
            train_accuracy: 0.1,
            eval_loss: None,
            eval_accuracy: None,
            wall_secs: 1.5,
            images: 300,
        });
        h.push(EpochRecord {
            epoch: 1,
            train_loss: 1.20,
            train_accuracy: 0.55,
            eval_loss: Some(1.3),
            eval_accuracy: Some(0.52),
            wall_secs: 1.4,
            images: 300,
        });
        let csv = h.to_csv();
        assert_eq!(csv.lines().count(), 3);
        assert!(csv.lines().nth(1).unwrap().ends_with(",,1.500,200.0"));
        assert_eq!(h.final_eval_accuracy(), Some(0.52));
        assert!((h.total_wall_secs() - 2.9).abs() < 1e-9);
    }

    #[test]
    fn images_per_sec_guards_zero() {
        let e = EpochRecord {
            epoch: 0,
            train_loss: 0.0,
            train_accuracy: 0.0,
            eval_loss: None,
            eval_accuracy: None,
            wall_secs: 0.0,
            images: 10,
        };
        assert_eq!(e.images_per_sec(), 0.0);
    }
}
