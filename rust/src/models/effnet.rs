//! EfficientNet profiles (Tan & Le 2019), B0–B7 via compound scaling,
//! plus the trainable `effnet_lite` mini (mirrors model.py).

use crate::models::layer::{bn_params, conv2d, dwconv2d, LayerKind, LayerProfile};
use crate::models::ArchProfile;

/// (width, depth, resolution) compound-scaling coefficients.
pub fn scaling(variant: usize) -> (f64, f64, usize) {
    match variant {
        0 => (1.0, 1.0, 224),
        1 => (1.0, 1.1, 240),
        2 => (1.1, 1.2, 260),
        3 => (1.2, 1.4, 300),
        4 => (1.4, 1.8, 380),
        5 => (1.6, 2.2, 456),
        6 => (1.8, 2.6, 528),
        7 => (2.0, 3.1, 600),
        _ => panic!("efficientnet variant b{variant} does not exist"),
    }
}

/// Round channel count to a multiple of 8, never dropping below 90%
/// (the reference `round_filters`).
pub fn round_filters(c: usize, width: f64) -> usize {
    let scaled = c as f64 * width;
    let mut new = ((scaled + 4.0) as usize / 8) * 8;
    new = new.max(8);
    if (new as f64) < 0.9 * scaled {
        new += 8;
    }
    new
}

/// Ceiling depth scaling (the reference `round_repeats`).
pub fn round_repeats(n: usize, depth: f64) -> usize {
    (n as f64 * depth).ceil() as usize
}

/// MBConv block profile. `expand` is the expansion factor (1 or 6).
fn mbconv(
    name: &str,
    in_shape: (usize, usize, usize),
    out_c: usize,
    k: usize,
    stride: usize,
    expand: usize,
) -> (LayerProfile, (usize, usize, usize)) {
    let in_c = in_shape.2;
    let exp_c = in_c * expand;
    let mut params = 0u64;
    let mut flops = 0u64;
    let mut acts = 0u64;
    let mut shape = in_shape;
    if expand != 1 {
        let (s, p, f) = conv2d(shape, exp_c, 1, 1, false);
        params += p + bn_params(exp_c);
        flops += f;
        acts += 3 * (s.0 * s.1 * s.2) as u64;
        shape = s;
    }
    let (s, p, f) = dwconv2d((shape.0, shape.1, exp_c), k, stride);
    params += p + bn_params(exp_c);
    flops += f;
    acts += 3 * (s.0 * s.1 * s.2) as u64;
    shape = s;
    // Squeeze-and-excitation: se_c based on block *input* channels (ratio ¼).
    let se_c = (in_c / 4).max(1);
    params += (exp_c * se_c + se_c) as u64 + (se_c * exp_c + exp_c) as u64;
    flops += 2 * (exp_c * se_c + se_c * exp_c) as u64;
    acts += (se_c + exp_c) as u64 + (shape.0 * shape.1 * exp_c) as u64; // scaled map
    // Projection.
    let (s, p, f) = conv2d(shape, out_c, 1, 1, false);
    params += p + bn_params(out_c);
    flops += f;
    acts += (s.0 * s.1 * s.2) as u64;
    shape = s;
    // Skip connection adds one more live tensor when shapes match.
    if stride == 1 && in_c == out_c {
        acts += (s.0 * s.1 * s.2) as u64;
    }
    (
        LayerProfile {
            name: name.to_string(),
            kind: LayerKind::Block,
            out_shape: shape,
            act_elems: acts,
            params,
            flops_per_image: flops,
        },
        shape,
    )
}

/// Baseline (B0) stage table: (expand, out_c, repeats, stride, kernel).
const B0_STAGES: [(usize, usize, usize, usize, usize); 7] = [
    (1, 16, 1, 1, 3),
    (6, 24, 2, 2, 3),
    (6, 40, 2, 2, 5),
    (6, 80, 3, 2, 3),
    (6, 112, 3, 1, 5),
    (6, 192, 4, 2, 5),
    (6, 320, 1, 1, 3),
];

/// Build EfficientNet-B{variant}. `input` overrides the native resolution
/// (pass the native one for paper-faithful profiles).
pub fn efficientnet(variant: usize, input: (usize, usize, usize), classes: usize) -> ArchProfile {
    let (width, depth, _res) = scaling(variant);
    let mut layers = Vec::new();
    let stem_c = round_filters(32, width);
    let (mut shape, p, f) = conv2d(input, stem_c, 3, 2, false);
    layers.push(LayerProfile {
        name: "stem".into(),
        kind: LayerKind::Conv,
        out_shape: shape,
        act_elems: 3 * (shape.0 * shape.1 * shape.2) as u64,
        params: p + bn_params(stem_c),
        flops_per_image: f,
    });
    for (si, &(expand, out_c, repeats, stride, k)) in B0_STAGES.iter().enumerate() {
        let out_c = round_filters(out_c, width);
        let repeats = round_repeats(repeats, depth);
        for r in 0..repeats {
            let s = if r == 0 { stride } else { 1 };
            let nm = format!("mbconv{}.{}", si + 1, r);
            let (layer, sh) = mbconv(&nm, shape, out_c, k, s, expand);
            shape = sh;
            layers.push(layer);
        }
    }
    let head_c = round_filters(1280, width);
    let (s, p, f) = conv2d(shape, head_c, 1, 1, false);
    layers.push(LayerProfile {
        name: "head_conv".into(),
        kind: LayerKind::Conv,
        out_shape: s,
        act_elems: 3 * (s.0 * s.1 * s.2) as u64,
        params: p + bn_params(head_c),
        flops_per_image: f,
    });
    layers.push(LayerProfile {
        name: "avgpool".into(),
        kind: LayerKind::Pool,
        out_shape: (1, 1, head_c),
        act_elems: head_c as u64,
        params: 0,
        flops_per_image: (s.0 * s.1 * head_c) as u64,
    });
    layers.push(LayerProfile {
        name: "fc".into(),
        kind: LayerKind::Dense,
        out_shape: (1, 1, classes),
        act_elems: classes as u64,
        params: (head_c * classes + classes) as u64,
        flops_per_image: 2 * (head_c * classes) as u64,
    });
    ArchProfile { name: format!("efficientnet_b{variant}"), input, layers }
}

/// Trainable mini: 3 MBConv stages on 32×32 (mirrors model.py::effnet_lite).
pub fn effnet_lite(input: (usize, usize, usize), classes: usize) -> ArchProfile {
    let mut layers = Vec::new();
    let (mut shape, p, f) = conv2d(input, 16, 3, 1, false);
    layers.push(LayerProfile {
        name: "stem".into(),
        kind: LayerKind::Conv,
        out_shape: shape,
        act_elems: 3 * (shape.0 * shape.1 * shape.2) as u64,
        params: p + bn_params(16),
        flops_per_image: f,
    });
    for (i, &(out_c, stride, reps)) in [(24usize, 2usize, 2usize), (40, 2, 2), (80, 2, 1)]
        .iter()
        .enumerate()
    {
        for r in 0..reps {
            let s = if r == 0 { stride } else { 1 };
            let (layer, sh) = mbconv(&format!("mb{}.{r}", i + 1), shape, out_c, 3, s, 6);
            shape = sh;
            layers.push(layer);
        }
    }
    let (s, p, f) = conv2d(shape, 160, 1, 1, false);
    layers.push(LayerProfile {
        name: "head_conv".into(),
        kind: LayerKind::Conv,
        out_shape: s,
        act_elems: 3 * (s.0 * s.1 * s.2) as u64,
        params: p + bn_params(160),
        flops_per_image: f,
    });
    layers.push(LayerProfile {
        name: "avgpool".into(),
        kind: LayerKind::Pool,
        out_shape: (1, 1, 160),
        act_elems: 160,
        params: 0,
        flops_per_image: (s.0 * s.1 * 160) as u64,
    });
    layers.push(LayerProfile {
        name: "fc".into(),
        kind: LayerKind::Dense,
        out_shape: (1, 1, classes),
        act_elems: classes as u64,
        params: (160 * classes + classes) as u64,
        flops_per_image: 2 * (160 * classes) as u64,
    });
    ArchProfile { name: "effnet_lite".into(), input, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_filters_reference_values() {
        assert_eq!(round_filters(32, 1.0), 32);
        assert_eq!(round_filters(32, 1.1), 32); // b2 stem
        assert_eq!(round_filters(32, 1.4), 48); // b4 stem
        assert_eq!(round_filters(320, 2.0), 640); // b7 last stage
        assert_eq!(round_filters(1280, 1.2), 1536); // b3 head
    }

    #[test]
    fn round_repeats_ceils() {
        assert_eq!(round_repeats(2, 1.0), 2);
        assert_eq!(round_repeats(2, 1.1), 3);
        assert_eq!(round_repeats(3, 3.1), 10);
    }

    #[test]
    fn b0_structure() {
        let p = efficientnet(0, (224, 224, 3), 1000);
        // stem + 16 blocks + head_conv + pool + fc
        assert_eq!(p.depth(), 1 + 16 + 3);
        // native B0 downsamples 224 → 7
        let last_block = &p.layers[p.depth() - 4];
        assert_eq!((last_block.out_shape.0, last_block.out_shape.1), (7, 7));
        assert_eq!(last_block.out_shape.2, 320);
    }

    #[test]
    fn deeper_variants_have_more_blocks() {
        let b0 = efficientnet(0, (224, 224, 3), 1000);
        let b3 = efficientnet(3, (300, 300, 3), 1000);
        let b7 = efficientnet(7, (600, 600, 3), 1000);
        assert!(b3.depth() > b0.depth());
        assert!(b7.depth() > b3.depth());
    }

    #[test]
    fn mbconv1_has_no_expansion_conv() {
        // First stage uses expand=1: params must exclude a 1×1 expand conv.
        let (blk, _) = mbconv("t", (112, 112, 32), 16, 3, 1, 1);
        // dw(32,3x3)=288 +bn 64 + se(32→8: 264, 8→32: 288) + proj 32·16=512 + bn 32
        assert_eq!(blk.params, 288 + 64 + (32 * 8 + 8) as u64 + (8 * 32 + 32) as u64 + 512 + 32);
    }

    #[test]
    fn effnet_lite_is_tiny() {
        let p = effnet_lite((32, 32, 3), 10);
        assert!(p.param_count() < 500_000, "{}", p.param_count());
        assert_eq!(p.layers.last().unwrap().out_shape, (1, 1, 10));
    }

    #[test]
    #[should_panic(expected = "does not exist")]
    fn b8_rejected() {
        scaling(8);
    }
}
