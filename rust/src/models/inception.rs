//! Inception-V3 profile (Szegedy et al. 2015), torchvision structure
//! (no aux head), plus the trainable `inception_lite` mini.

use crate::models::layer::{bn_params, LayerKind, LayerProfile};
use crate::models::ArchProfile;

/// conv+BN with an arbitrary (kh, kw) kernel at a fixed resolution.
/// Returns (params, flops, output elems) for `out_hw` spatial size.
fn conv_bn(in_c: usize, out_c: usize, kh: usize, kw: usize, out_hw: (usize, usize)) -> (u64, u64, u64) {
    let params = (in_c * out_c * kh * kw) as u64 + bn_params(out_c);
    let out_elems = (out_hw.0 * out_hw.1 * out_c) as u64;
    let flops = 2 * (out_hw.0 * out_hw.1) as u64 * (in_c * out_c * kh * kw) as u64;
    (params, flops, out_elems)
}

/// VALID conv output size.
fn valid(h: usize, k: usize, s: usize) -> usize {
    (h - k) / s + 1
}

/// Accumulator for a fused inception block.
#[derive(Default)]
struct Acc {
    params: u64,
    flops: u64,
    acts: u64,
}

impl Acc {
    fn add(&mut self, (p, f, e): (u64, u64, u64)) {
        self.params += p;
        self.flops += f;
        // standard training keeps each conv's output + post-BN/ReLU tensor
        self.acts += 3 * e;
    }

    fn into_layer(self, name: &str, out_shape: (usize, usize, usize)) -> LayerProfile {
        LayerProfile {
            name: name.to_string(),
            kind: LayerKind::Block,
            out_shape,
            act_elems: self.acts + (out_shape.0 * out_shape.1 * out_shape.2) as u64, // concat
            params: self.params,
            flops_per_image: self.flops,
        }
    }
}

fn inception_a(name: &str, hw: usize, in_c: usize, pool_f: usize) -> LayerProfile {
    let o = (hw, hw);
    let mut a = Acc::default();
    a.add(conv_bn(in_c, 64, 1, 1, o)); // b1
    a.add(conv_bn(in_c, 48, 1, 1, o)); // b5 reduce
    a.add(conv_bn(48, 64, 5, 5, o));
    a.add(conv_bn(in_c, 64, 1, 1, o)); // b3dbl
    a.add(conv_bn(64, 96, 3, 3, o));
    a.add(conv_bn(96, 96, 3, 3, o));
    a.add(conv_bn(in_c, pool_f, 1, 1, o)); // pool proj
    a.into_layer(name, (hw, hw, 64 + 64 + 96 + pool_f))
}

fn inception_b(name: &str, hw_in: usize, in_c: usize) -> LayerProfile {
    let hw = valid(hw_in, 3, 2);
    let o = (hw, hw);
    let mut a = Acc::default();
    a.add(conv_bn(in_c, 384, 3, 3, o)); // strided 3×3
    a.add(conv_bn(in_c, 64, 1, 1, (hw_in, hw_in)));
    a.add(conv_bn(64, 96, 3, 3, (hw_in, hw_in)));
    a.add(conv_bn(96, 96, 3, 3, o)); // strided
    // maxpool branch passthrough contributes activations only
    a.acts += (hw * hw * in_c) as u64;
    a.into_layer(name, (hw, hw, 384 + 96 + in_c))
}

fn inception_c(name: &str, hw: usize, in_c: usize, c7: usize) -> LayerProfile {
    let o = (hw, hw);
    let mut a = Acc::default();
    a.add(conv_bn(in_c, 192, 1, 1, o)); // b1
    a.add(conv_bn(in_c, c7, 1, 1, o)); // b7
    a.add(conv_bn(c7, c7, 1, 7, o));
    a.add(conv_bn(c7, 192, 7, 1, o));
    a.add(conv_bn(in_c, c7, 1, 1, o)); // b7dbl
    a.add(conv_bn(c7, c7, 7, 1, o));
    a.add(conv_bn(c7, c7, 1, 7, o));
    a.add(conv_bn(c7, c7, 7, 1, o));
    a.add(conv_bn(c7, 192, 1, 7, o));
    a.add(conv_bn(in_c, 192, 1, 1, o)); // pool proj
    a.into_layer(name, (hw, hw, 768))
}

fn inception_d(name: &str, hw_in: usize, in_c: usize) -> LayerProfile {
    let hw = valid(hw_in, 3, 2);
    let o_in = (hw_in, hw_in);
    let o = (hw, hw);
    let mut a = Acc::default();
    a.add(conv_bn(in_c, 192, 1, 1, o_in)); // b3
    a.add(conv_bn(192, 320, 3, 3, o));
    a.add(conv_bn(in_c, 192, 1, 1, o_in)); // b7x3
    a.add(conv_bn(192, 192, 1, 7, o_in));
    a.add(conv_bn(192, 192, 7, 1, o_in));
    a.add(conv_bn(192, 192, 3, 3, o));
    a.acts += (hw * hw * in_c) as u64; // maxpool passthrough
    a.into_layer(name, (hw, hw, 320 + 192 + in_c))
}

fn inception_e(name: &str, hw: usize, in_c: usize) -> LayerProfile {
    let o = (hw, hw);
    let mut a = Acc::default();
    a.add(conv_bn(in_c, 320, 1, 1, o)); // b1
    a.add(conv_bn(in_c, 384, 1, 1, o)); // b3 split
    a.add(conv_bn(384, 384, 1, 3, o));
    a.add(conv_bn(384, 384, 3, 1, o));
    a.add(conv_bn(in_c, 448, 1, 1, o)); // b3dbl split
    a.add(conv_bn(448, 384, 3, 3, o));
    a.add(conv_bn(384, 384, 1, 3, o));
    a.add(conv_bn(384, 384, 3, 1, o));
    a.add(conv_bn(in_c, 192, 1, 1, o)); // pool proj
    a.into_layer(name, (hw, hw, 2048))
}

/// Full Inception-V3 at 299×299 (or any input ≥ 75).
pub fn inception_v3(input: (usize, usize, usize), classes: usize) -> ArchProfile {
    let mut layers = Vec::new();
    let mut hw = input.0;
    let push_conv =
        |layers: &mut Vec<LayerProfile>, name: &str, in_c: usize, out_c: usize, k: usize, s: usize, v: bool, hw: &mut usize| {
            let out_hw = if v { valid(*hw, k, s) } else { (*hw + s - 1) / s };
            let (p, f, e) = conv_bn(in_c, out_c, k, k, (out_hw, out_hw));
            layers.push(LayerProfile {
                name: name.into(),
                kind: LayerKind::Conv,
                out_shape: (out_hw, out_hw, out_c),
                act_elems: 3 * e,
                params: p,
                flops_per_image: f,
            });
            *hw = out_hw;
        };
    push_conv(&mut layers, "conv1a", 3, 32, 3, 2, true, &mut hw);
    push_conv(&mut layers, "conv2a", 32, 32, 3, 1, true, &mut hw);
    push_conv(&mut layers, "conv2b", 32, 64, 3, 1, false, &mut hw);
    hw = valid(hw, 3, 2); // maxpool1
    layers.push(LayerProfile {
        name: "maxpool1".into(),
        kind: LayerKind::Pool,
        out_shape: (hw, hw, 64),
        act_elems: (hw * hw * 64) as u64,
        params: 0,
        flops_per_image: (hw * hw * 64 * 9) as u64,
    });
    push_conv(&mut layers, "conv3b", 64, 80, 1, 1, true, &mut hw);
    push_conv(&mut layers, "conv4a", 80, 192, 3, 1, true, &mut hw);
    hw = valid(hw, 3, 2); // maxpool2
    layers.push(LayerProfile {
        name: "maxpool2".into(),
        kind: LayerKind::Pool,
        out_shape: (hw, hw, 192),
        act_elems: (hw * hw * 192) as u64,
        params: 0,
        flops_per_image: (hw * hw * 192 * 9) as u64,
    });
    // 35×35 stages
    layers.push(inception_a("mixed5b", hw, 192, 32));
    layers.push(inception_a("mixed5c", hw, 256, 64));
    layers.push(inception_a("mixed5d", hw, 288, 64));
    let b = inception_b("mixed6a", hw, 288);
    hw = b.out_shape.0;
    layers.push(b);
    // 17×17 stages
    layers.push(inception_c("mixed6b", hw, 768, 128));
    layers.push(inception_c("mixed6c", hw, 768, 160));
    layers.push(inception_c("mixed6d", hw, 768, 160));
    layers.push(inception_c("mixed6e", hw, 768, 192));
    let d = inception_d("mixed7a", hw, 768);
    hw = d.out_shape.0;
    layers.push(d);
    // 8×8 stages
    layers.push(inception_e("mixed7b", hw, 1280));
    layers.push(inception_e("mixed7c", hw, 2048));
    layers.push(LayerProfile {
        name: "avgpool".into(),
        kind: LayerKind::Pool,
        out_shape: (1, 1, 2048),
        act_elems: 2048,
        params: 0,
        flops_per_image: (hw * hw * 2048) as u64,
    });
    layers.push(LayerProfile {
        name: "fc".into(),
        kind: LayerKind::Dense,
        out_shape: (1, 1, classes),
        act_elems: classes as u64,
        params: (2048 * classes + classes) as u64,
        flops_per_image: 2 * (2048 * classes) as u64,
    });
    ArchProfile { name: "inception_v3".into(), input, layers }
}

/// Trainable mini: stem + 2 small inception-A-style blocks on 32×32
/// (mirrors model.py::inception_lite).
pub fn inception_lite(input: (usize, usize, usize), classes: usize) -> ArchProfile {
    let mut layers = Vec::new();
    let hw = input.0;
    let (p, f, e) = conv_bn(3, 32, 3, 3, (hw, hw));
    layers.push(LayerProfile {
        name: "stem".into(),
        kind: LayerKind::Conv,
        out_shape: (hw, hw, 32),
        act_elems: 3 * e,
        params: p,
        flops_per_image: f,
    });
    let hw2 = hw / 2;
    // mini block 1 at half resolution (stride via pooling)
    layers.push(LayerProfile {
        name: "pool1".into(),
        kind: LayerKind::Pool,
        out_shape: (hw2, hw2, 32),
        act_elems: (hw2 * hw2 * 32) as u64,
        params: 0,
        flops_per_image: (hw2 * hw2 * 32 * 4) as u64,
    });
    let mk_mini = |name: &str, hw: usize, in_c: usize| -> LayerProfile {
        let o = (hw, hw);
        let mut a = Acc::default();
        a.add(conv_bn(in_c, 32, 1, 1, o));
        a.add(conv_bn(in_c, 24, 1, 1, o));
        a.add(conv_bn(24, 32, 3, 3, o));
        a.add(conv_bn(in_c, 16, 1, 1, o));
        a.add(conv_bn(16, 32, 5, 5, o));
        a.into_layer(name, (hw, hw, 96))
    };
    layers.push(mk_mini("mini_a1", hw2, 32));
    let hw4 = hw2 / 2;
    layers.push(LayerProfile {
        name: "pool2".into(),
        kind: LayerKind::Pool,
        out_shape: (hw4, hw4, 96),
        act_elems: (hw4 * hw4 * 96) as u64,
        params: 0,
        flops_per_image: (hw4 * hw4 * 96 * 4) as u64,
    });
    layers.push(mk_mini("mini_a2", hw4, 96));
    layers.push(LayerProfile {
        name: "avgpool".into(),
        kind: LayerKind::Pool,
        out_shape: (1, 1, 96),
        act_elems: 96,
        params: 0,
        flops_per_image: (hw4 * hw4 * 96) as u64,
    });
    layers.push(LayerProfile {
        name: "fc".into(),
        kind: LayerKind::Dense,
        out_shape: (1, 1, classes),
        act_elems: classes as u64,
        params: (96 * classes + classes) as u64,
        flops_per_image: 2 * (96 * classes) as u64,
    });
    ArchProfile { name: "inception_lite".into(), input, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resolution_chain_matches_reference() {
        let p = inception_v3((299, 299, 3), 1000);
        let by_name = |n: &str| p.layers.iter().find(|l| l.name == n).unwrap();
        assert_eq!(by_name("conv1a").out_shape.0, 149);
        assert_eq!(by_name("conv2a").out_shape.0, 147);
        assert_eq!(by_name("maxpool2").out_shape, (35, 35, 192));
        assert_eq!(by_name("mixed5d").out_shape, (35, 35, 288));
        assert_eq!(by_name("mixed6a").out_shape, (17, 17, 768));
        assert_eq!(by_name("mixed7a").out_shape, (8, 8, 1280));
        assert_eq!(by_name("mixed7c").out_shape, (8, 8, 2048));
    }

    #[test]
    fn block_output_channels() {
        let a = inception_a("t", 35, 192, 32);
        assert_eq!(a.out_shape.2, 256);
        let c = inception_c("t", 17, 768, 128);
        assert_eq!(c.out_shape.2, 768);
        let e = inception_e("t", 8, 1280);
        assert_eq!(e.out_shape.2, 2048);
    }

    #[test]
    fn lite_is_small() {
        let p = inception_lite((32, 32, 3), 10);
        assert!(p.param_count() < 300_000, "{}", p.param_count());
        assert_eq!(p.layers.last().unwrap().out_shape, (1, 1, 10));
    }
}
