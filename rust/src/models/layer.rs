//! Layer-level profile: the unit the memory simulator schedules.

/// What kind of computation a layer performs (affects recompute cost
//  accounting and planner heuristics).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum LayerKind {
    Conv,
    /// Depthwise conv (EfficientNet).
    DwConv,
    Pool,
    /// Fused residual/inception super-block.
    Block,
    Dense,
    /// Element-wise (activation, BN at inference granularity).
    Pointwise,
    /// The E-D pipelines' in-graph decode layer.
    Decode,
}

/// One schedulable layer of an architecture profile.
#[derive(Clone, Debug)]
pub struct LayerProfile {
    pub name: String,
    pub kind: LayerKind,
    /// Output shape per image `(h, w, c)`.
    pub out_shape: (usize, usize, usize),
    /// Activation elements this layer keeps live for backward under
    /// standard training, per image. For fused blocks this includes the
    /// internal tensors (both branches, pre-activations), which is what a
    /// framework stores.
    pub act_elems: u64,
    /// Trainable parameters.
    pub params: u64,
    /// Forward FLOPs per image (MACs × 2).
    pub flops_per_image: u64,
}

impl LayerProfile {
    pub fn out_elems(&self) -> u64 {
        let (h, w, c) = self.out_shape;
        (h * w * c) as u64
    }
}

/// Conv2d shape/cost helper: returns (out_h, out_w), params, flops/img.
pub fn conv2d(
    in_shape: (usize, usize, usize),
    out_c: usize,
    k: usize,
    stride: usize,
    bias: bool,
) -> ((usize, usize, usize), u64, u64) {
    let (h, w, in_c) = in_shape;
    // "same"-style padding: out = ceil(in / stride)
    let oh = (h + stride - 1) / stride;
    let ow = (w + stride - 1) / stride;
    let params = (in_c * out_c * k * k + if bias { out_c } else { 0 }) as u64;
    let flops = 2 * (oh * ow) as u64 * (in_c * out_c * k * k) as u64;
    ((oh, ow, out_c), params, flops)
}

/// Depthwise conv helper.
pub fn dwconv2d(
    in_shape: (usize, usize, usize),
    k: usize,
    stride: usize,
) -> ((usize, usize, usize), u64, u64) {
    let (h, w, c) = in_shape;
    let oh = (h + stride - 1) / stride;
    let ow = (w + stride - 1) / stride;
    let params = (c * k * k) as u64;
    let flops = 2 * (oh * ow) as u64 * (c * k * k) as u64;
    ((oh, ow, c), params, flops)
}

/// BatchNorm parameter count (scale + shift; running stats not trainable).
pub fn bn_params(c: usize) -> u64 {
    2 * c as u64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn conv_shapes_and_params() {
        // 3→64, 7×7 stride 2 on 224²: torchvision conv1 = 9408 params.
        let (shape, params, flops) = conv2d((224, 224, 3), 64, 7, 2, false);
        assert_eq!(shape, (112, 112, 64));
        assert_eq!(params, 9408);
        assert_eq!(flops, 2 * 112 * 112 * 9408);
    }

    #[test]
    fn conv_bias_counted() {
        let (_, params, _) = conv2d((8, 8, 16), 32, 3, 1, true);
        assert_eq!(params, 16 * 32 * 9 + 32);
    }

    #[test]
    fn dwconv_params_independent_of_channel_mixing() {
        let (shape, params, _) = dwconv2d((56, 56, 144), 3, 2);
        assert_eq!(shape, (28, 28, 144));
        assert_eq!(params, 144 * 9);
    }

    #[test]
    fn odd_sizes_ceil_divide() {
        let (shape, _, _) = conv2d((299, 299, 3), 32, 3, 2, false);
        assert_eq!(shape, (150, 150, 32));
    }

    #[test]
    fn out_elems() {
        let l = LayerProfile {
            name: "t".into(),
            kind: LayerKind::Conv,
            out_shape: (4, 5, 6),
            act_elems: 1,
            params: 0,
            flops_per_image: 0,
        };
        assert_eq!(l.out_elems(), 120);
    }
}
