//! Architecture profiles — analytic layer graphs for every model the paper
//! evaluates (ResNet-18/34/50/101, EfficientNet-B0…B7, Inception-V3) plus
//! the trainable mini variants the end-to-end experiments use.
//!
//! A profile is a *sequential* list of [`LayerProfile`]s with exact output
//! shapes, parameter counts and FLOP estimates. The memory simulator
//! (`crate::memory`) replays forward/backward schedules over these graphs
//! to reproduce Figures 8 and 10; the checkpoint planner searches over
//! them for Figure 11. Branchy blocks (residual, inception) are modeled as
//! fused sequential super-layers whose activation footprint includes all
//! internal tensors that standard training keeps live — which is the
//! quantity the paper's figures measure.

mod effnet;
mod inception;
mod layer;
mod registry;
mod resnet;

pub use layer::{LayerKind, LayerProfile};
pub use registry::{all_arch_names, arch_by_name, paper_fig10_models, trainable_models};

/// A full architecture profile.
#[derive(Clone, Debug)]
pub struct ArchProfile {
    pub name: String,
    /// Input `(h, w, c)` the profile was built for.
    pub input: (usize, usize, usize),
    pub layers: Vec<LayerProfile>,
}

impl ArchProfile {
    /// Total parameter count.
    pub fn param_count(&self) -> u64 {
        self.layers.iter().map(|l| l.params).sum()
    }

    /// Total forward FLOPs for batch size `b`.
    pub fn flops(&self, b: usize) -> u64 {
        self.layers.iter().map(|l| l.flops_per_image).sum::<u64>() * b as u64
    }

    /// Activation elements stored by standard training across the whole
    /// forward pass (what checkpointing trades away), batch `b`.
    pub fn total_activation_elems(&self, b: usize) -> u64 {
        self.layers.iter().map(|l| l.act_elems).sum::<u64>() * b as u64
    }

    /// Largest single-layer activation, batch `b` (lower bound on any
    /// schedule's working set).
    pub fn max_activation_elems(&self, b: usize) -> u64 {
        self.layers.iter().map(|l| l.act_elems).max().unwrap_or(0) * b as u64
    }

    pub fn depth(&self) -> usize {
        self.layers.len()
    }

    /// Prefix sums of per-image stored-activation elements: entry `i` is the
    /// sum of `act_elems` over layers `< i` (length `depth() + 1`). The
    /// planner's incremental segment-peak evaluation is built on these.
    pub fn act_prefix_elems(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.layers.len() + 1);
        let mut acc = 0u64;
        out.push(0);
        for l in &self.layers {
            acc += l.act_elems;
            out.push(acc);
        }
        out
    }

    /// Prefix sums of per-image forward FLOPs (length `depth() + 1`); the
    /// DP planner reads segment recompute costs off these in O(1).
    pub fn flops_prefix(&self) -> Vec<u64> {
        let mut out = Vec::with_capacity(self.layers.len() + 1);
        let mut acc = 0u64;
        out.push(0);
        for l in &self.layers {
            acc += l.flops_per_image;
            out.push(acc);
        }
        out
    }

    /// Suffix sums of parameter counts: entry `i` is the sum of `params`
    /// over layers `≥ i` (length `depth() + 1`, last entry 0). Gradient
    /// residency during the backward pass follows this curve.
    pub fn param_suffix(&self) -> Vec<u64> {
        let n = self.layers.len();
        let mut out = vec![0u64; n + 1];
        for i in (0..n).rev() {
            out[i] = out[i + 1] + self.layers[i].params;
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_param_count_close_to_reference() {
        // torchvision resnet18: 11,689,512 params. Our analytic profile
        // must land within 2%.
        let p = arch_by_name("resnet18", (224, 224, 3), 1000).unwrap();
        let count = p.param_count() as f64;
        assert!(
            (count - 11_689_512.0).abs() / 11_689_512.0 < 0.02,
            "resnet18 params {count}"
        );
    }

    #[test]
    fn resnet50_param_count_close_to_reference() {
        // torchvision resnet50: 25,557,032 params.
        let p = arch_by_name("resnet50", (224, 224, 3), 1000).unwrap();
        let count = p.param_count() as f64;
        assert!(
            (count - 25_557_032.0).abs() / 25_557_032.0 < 0.02,
            "resnet50 params {count}"
        );
    }

    #[test]
    fn resnet101_deeper_than_resnet50() {
        let a = arch_by_name("resnet50", (224, 224, 3), 1000).unwrap();
        let b = arch_by_name("resnet101", (224, 224, 3), 1000).unwrap();
        assert!(b.depth() > a.depth());
        assert!(b.param_count() > a.param_count());
    }

    #[test]
    fn efficientnet_scaling_monotonic() {
        // B0 < B1 < ... < B7 in params and activations.
        let mut prev: Option<ArchProfile> = None;
        for i in 0..8 {
            let p = arch_by_name(&format!("efficientnet_b{i}"), (224, 224, 3), 1000).unwrap();
            if let Some(q) = &prev {
                assert!(p.param_count() > q.param_count(), "b{i} params");
                assert!(
                    p.total_activation_elems(1) > q.total_activation_elems(1),
                    "b{i} acts"
                );
            }
            prev = Some(p);
        }
    }

    #[test]
    fn efficientnet_b0_param_count_close_to_reference() {
        // torchvision efficientnet_b0: 5,288,548 params. Analytic MBConv
        // bookkeeping tolerates 5%.
        let p = arch_by_name("efficientnet_b0", (224, 224, 3), 1000).unwrap();
        let count = p.param_count() as f64;
        assert!(
            (count - 5_288_548.0).abs() / 5_288_548.0 < 0.05,
            "efficientnet_b0 params {count}"
        );
    }

    #[test]
    fn inception_v3_param_count_close_to_reference() {
        // torchvision inception_v3 (no aux): ~25.1M params.
        let p = arch_by_name("inception_v3", (299, 299, 3), 1000).unwrap();
        let count = p.param_count() as f64;
        assert!(
            (count - 25.1e6).abs() / 25.1e6 < 0.08,
            "inception_v3 params {count}"
        );
    }

    #[test]
    fn shapes_chain_consistently() {
        for name in all_arch_names() {
            let input = if name.contains("inception") { (299, 299, 3) } else { (224, 224, 3) };
            let p = arch_by_name(&name, input, 1000).unwrap();
            assert!(!p.layers.is_empty(), "{name} empty");
            for (i, l) in p.layers.iter().enumerate() {
                assert!(l.act_elems > 0, "{name} layer {i} ({}) zero acts", l.name);
            }
            // final layer is the classifier head: out elems == classes
            let last = p.layers.last().unwrap();
            assert_eq!(last.out_shape, (1, 1, 1000), "{name} head shape");
        }
    }

    #[test]
    fn profiles_scale_with_input_resolution() {
        let small = arch_by_name("resnet18", (32, 32, 3), 10).unwrap();
        let big = arch_by_name("resnet18", (512, 512, 3), 10).unwrap();
        assert_eq!(small.param_count(), big.param_count(), "params are res-independent");
        assert!(big.total_activation_elems(1) > 100 * small.total_activation_elems(1));
    }

    #[test]
    fn trainable_minis_are_small() {
        for name in trainable_models() {
            let p = arch_by_name(&name, (32, 32, 3), 10).unwrap();
            assert!(
                p.param_count() < 5_000_000,
                "{name} too big for CPU training: {}",
                p.param_count()
            );
        }
    }

    #[test]
    fn unknown_arch_is_none() {
        assert!(arch_by_name("alexnet", (224, 224, 3), 1000).is_none());
    }

    #[test]
    fn prefix_sums_match_direct_sums() {
        let p = arch_by_name("resnet18", (64, 64, 3), 10).unwrap();
        let n = p.depth();
        let ap = p.act_prefix_elems();
        let fp = p.flops_prefix();
        let ps = p.param_suffix();
        assert_eq!(ap.len(), n + 1);
        assert_eq!(fp.len(), n + 1);
        assert_eq!(ps.len(), n + 1);
        assert_eq!(ap[0], 0);
        assert_eq!(ps[n], 0);
        assert_eq!(ap[n], p.total_activation_elems(1));
        assert_eq!(fp[n], p.flops(1));
        assert_eq!(ps[0], p.param_count());
        for i in 0..n {
            assert_eq!(ap[i + 1] - ap[i], p.layers[i].act_elems);
            assert_eq!(fp[i + 1] - fp[i], p.layers[i].flops_per_image);
            assert_eq!(ps[i] - ps[i + 1], p.layers[i].params);
        }
    }

    #[test]
    fn stored_activations_cover_boundary_outputs() {
        // The planner's segment decomposition relies on every layer's stored
        // activation footprint including its boundary output tensor.
        for name in all_arch_names() {
            let input = if name.contains("inception") { (299, 299, 3) } else { (64, 64, 3) };
            let p = arch_by_name(&name, input, 10).unwrap();
            for l in &p.layers {
                assert!(
                    l.act_elems >= l.out_elems(),
                    "{name}/{}: act {} < out {}",
                    l.name,
                    l.act_elems,
                    l.out_elems()
                );
            }
        }
    }
}
