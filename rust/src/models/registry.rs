//! Model registry: name → profile builder.

use crate::models::{effnet, inception, resnet, ArchProfile};

/// Build the profile for `name` at `input` resolution with `classes`
/// output classes. `None` for unknown names.
pub fn arch_by_name(name: &str, input: (usize, usize, usize), classes: usize) -> Option<ArchProfile> {
    let p = match name {
        "resnet18" => resnet::resnet(name, input, classes, [2, 2, 2, 2], false),
        "resnet34" => resnet::resnet(name, input, classes, [3, 4, 6, 3], false),
        "resnet50" => resnet::resnet(name, input, classes, [3, 4, 6, 3], true),
        "resnet101" => resnet::resnet(name, input, classes, [3, 4, 23, 3], true),
        "inception_v3" => inception::inception_v3(input, classes),
        "tiny_cnn" => resnet::tiny_cnn(input, classes),
        "resnet_mini18" => resnet::resnet_mini(name, input, classes, [2, 2, 2, 2], false, 16),
        "resnet_mini34" => resnet::resnet_mini(name, input, classes, [3, 4, 6, 3], false, 16),
        "resnet_mini50" => resnet::resnet_mini(name, input, classes, [3, 4, 6, 3], true, 16),
        "effnet_lite" => effnet::effnet_lite(input, classes),
        "inception_lite" => inception::inception_lite(input, classes),
        _ => {
            if let Some(v) = name.strip_prefix("efficientnet_b") {
                let variant: usize = v.parse().ok()?;
                if variant > 7 {
                    return None;
                }
                effnet::efficientnet(variant, input, classes)
            } else {
                return None;
            }
        }
    };
    Some(p)
}

/// Every profiled architecture (full-scale + minis).
pub fn all_arch_names() -> Vec<String> {
    let mut v: Vec<String> = vec![
        "resnet18".into(),
        "resnet34".into(),
        "resnet50".into(),
        "resnet101".into(),
        "inception_v3".into(),
    ];
    for i in 0..8 {
        v.push(format!("efficientnet_b{i}"));
    }
    v.extend(trainable_models());
    v
}

/// Models small enough to train end-to-end on CPU (mirrored in model.py).
pub fn trainable_models() -> Vec<String> {
    vec![
        "tiny_cnn".into(),
        "resnet_mini18".into(),
        "resnet_mini34".into(),
        "resnet_mini50".into(),
        "effnet_lite".into(),
        "inception_lite".into(),
    ]
}

/// The model set Figure 10 plots (full-scale paper models).
pub fn paper_fig10_models() -> Vec<String> {
    let mut v: Vec<String> = vec!["resnet18".into(), "resnet34".into(), "resnet50".into()];
    for i in 0..8 {
        v.push(format!("efficientnet_b{i}"));
    }
    v.push("inception_v3".into());
    v
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn registry_resolves_all_names() {
        for name in all_arch_names() {
            assert!(
                arch_by_name(&name, (224, 224, 3), 10).is_some(),
                "missing {name}"
            );
        }
    }

    #[test]
    fn fig10_set_has_12_models() {
        assert_eq!(paper_fig10_models().len(), 12);
    }

    #[test]
    fn efficientnet_suffix_parsing() {
        assert!(arch_by_name("efficientnet_b9", (224, 224, 3), 10).is_none());
        assert!(arch_by_name("efficientnet_bx", (224, 224, 3), 10).is_none());
        assert!(arch_by_name("efficientnet_b7", (224, 224, 3), 10).is_some());
    }
}
