//! ResNet profiles (He et al. 2015): 18/34 (basic blocks), 50/101
//! (bottlenecks), plus the CIFAR-scale `resnet_mini*` trainable variants
//! that mirror `python/compile/model.py`.

use crate::models::layer::{bn_params, conv2d, LayerKind, LayerProfile};
use crate::models::ArchProfile;

/// Basic residual block (two 3×3 convs). Returns the fused super-layer.
fn basic_block(
    name: &str,
    in_shape: (usize, usize, usize),
    out_c: usize,
    stride: usize,
) -> (LayerProfile, (usize, usize, usize)) {
    let (s1, p1, f1) = conv2d(in_shape, out_c, 3, stride, false);
    let (s2, p2, f2) = conv2d(s1, out_c, 3, 1, false);
    let mut params = p1 + bn_params(out_c) + p2 + bn_params(out_c);
    let mut flops = f1 + f2;
    // Activations standard training keeps: each conv's output plus its
    // post-BN/ReLU tensor, plus the residual sum.
    let mut acts = 3 * (s1.0 * s1.1 * s1.2) as u64 + 3 * (s2.0 * s2.1 * s2.2) as u64
        + (s2.0 * s2.1 * s2.2) as u64;
    let needs_proj = stride != 1 || in_shape.2 != out_c;
    if needs_proj {
        let (sp, pp, fp) = conv2d(in_shape, out_c, 1, stride, false);
        params += pp + bn_params(out_c);
        flops += fp;
        acts += (sp.0 * sp.1 * sp.2) as u64;
    }
    (
        LayerProfile {
            name: name.to_string(),
            kind: LayerKind::Block,
            out_shape: s2,
            act_elems: acts,
            params,
            flops_per_image: flops,
        },
        s2,
    )
}

/// Bottleneck residual block (1×1 → 3×3 → 1×1, expansion 4).
fn bottleneck_block(
    name: &str,
    in_shape: (usize, usize, usize),
    mid_c: usize,
    stride: usize,
) -> (LayerProfile, (usize, usize, usize)) {
    let out_c = mid_c * 4;
    let (s1, p1, f1) = conv2d(in_shape, mid_c, 1, 1, false);
    let (s2, p2, f2) = conv2d(s1, mid_c, 3, stride, false);
    let (s3, p3, f3) = conv2d(s2, out_c, 1, 1, false);
    let mut params =
        p1 + bn_params(mid_c) + p2 + bn_params(mid_c) + p3 + bn_params(out_c);
    let mut flops = f1 + f2 + f3;
    let mut acts = 3 * (s1.0 * s1.1 * s1.2) as u64
        + 3 * (s2.0 * s2.1 * s2.2) as u64
        + 3 * (s3.0 * s3.1 * s3.2) as u64
        + (s3.0 * s3.1 * s3.2) as u64;
    let needs_proj = stride != 1 || in_shape.2 != out_c;
    if needs_proj {
        let (sp, pp, fp) = conv2d(in_shape, out_c, 1, stride, false);
        params += pp + bn_params(out_c);
        flops += fp;
        acts += (sp.0 * sp.1 * sp.2) as u64;
    }
    (
        LayerProfile {
            name: name.to_string(),
            kind: LayerKind::Block,
            out_shape: s3,
            act_elems: acts,
            params,
            flops_per_image: flops,
        },
        s3,
    )
}

/// ImageNet-style stem: 7×7/2 conv + BN/ReLU + 3×3/2 maxpool.
fn imagenet_stem(input: (usize, usize, usize), layers: &mut Vec<LayerProfile>) -> (usize, usize, usize) {
    let (s, p, f) = conv2d(input, 64, 7, 2, false);
    layers.push(LayerProfile {
        name: "conv1".into(),
        kind: LayerKind::Conv,
        out_shape: s,
        act_elems: 3 * (s.0 * s.1 * s.2) as u64,
        params: p + bn_params(64),
        flops_per_image: f,
    });
    let pooled = ((s.0 + 1) / 2, (s.1 + 1) / 2, s.2);
    layers.push(LayerProfile {
        name: "maxpool".into(),
        kind: LayerKind::Pool,
        out_shape: pooled,
        act_elems: (pooled.0 * pooled.1 * pooled.2) as u64,
        params: 0,
        flops_per_image: (pooled.0 * pooled.1 * pooled.2 * 9) as u64,
    });
    pooled
}

fn head(
    shape: (usize, usize, usize),
    classes: usize,
    layers: &mut Vec<LayerProfile>,
) {
    let c = shape.2;
    layers.push(LayerProfile {
        name: "avgpool".into(),
        kind: LayerKind::Pool,
        out_shape: (1, 1, c),
        act_elems: c as u64,
        params: 0,
        flops_per_image: (shape.0 * shape.1 * c) as u64,
    });
    layers.push(LayerProfile {
        name: "fc".into(),
        kind: LayerKind::Dense,
        out_shape: (1, 1, classes),
        act_elems: classes as u64,
        params: (c * classes + classes) as u64,
        flops_per_image: 2 * (c * classes) as u64,
    });
}

/// Generic ResNet builder. `blocks[i]` = number of blocks in stage i,
/// `bottleneck` selects the block type.
pub fn resnet(
    name: &str,
    input: (usize, usize, usize),
    classes: usize,
    blocks: [usize; 4],
    bottleneck: bool,
) -> ArchProfile {
    let mut layers = Vec::new();
    let mut shape = imagenet_stem(input, &mut layers);
    let widths = [64usize, 128, 256, 512];
    for (stage, (&n, &w)) in blocks.iter().zip(widths.iter()).enumerate() {
        for b in 0..n {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let nm = format!("layer{}.{}", stage + 1, b);
            let (layer, s) = if bottleneck {
                bottleneck_block(&nm, shape, w, stride)
            } else {
                basic_block(&nm, shape, w, stride)
            };
            shape = s;
            layers.push(layer);
        }
    }
    head(shape, classes, &mut layers);
    ArchProfile { name: name.to_string(), input, layers }
}

/// CIFAR-scale mini ResNet: 3×3 stem (no maxpool), widths from
/// `base_width`, mirrors `python/compile/model.py::resnet_mini*`.
pub fn resnet_mini(
    name: &str,
    input: (usize, usize, usize),
    classes: usize,
    blocks: [usize; 4],
    bottleneck: bool,
    base_width: usize,
) -> ArchProfile {
    let mut layers = Vec::new();
    let (s, p, f) = conv2d(input, base_width, 3, 1, false);
    layers.push(LayerProfile {
        name: "conv1".into(),
        kind: LayerKind::Conv,
        out_shape: s,
        act_elems: 3 * (s.0 * s.1 * s.2) as u64,
        params: p + bn_params(base_width),
        flops_per_image: f,
    });
    let mut shape = s;
    let widths = [base_width, base_width * 2, base_width * 4, base_width * 8];
    for (stage, (&n, &w)) in blocks.iter().zip(widths.iter()).enumerate() {
        for b in 0..n {
            let stride = if stage > 0 && b == 0 { 2 } else { 1 };
            let nm = format!("layer{}.{}", stage + 1, b);
            let (layer, sh) = if bottleneck {
                bottleneck_block(&nm, shape, w, stride)
            } else {
                basic_block(&nm, shape, w, stride)
            };
            shape = sh;
            layers.push(layer);
        }
    }
    head(shape, classes, &mut layers);
    ArchProfile { name: name.to_string(), input, layers }
}

/// `tiny_cnn`: 3-conv net for fast end-to-end runs; mirrors model.py.
pub fn tiny_cnn(input: (usize, usize, usize), classes: usize) -> ArchProfile {
    let mut layers = Vec::new();
    let mut shape = input;
    for (i, (c, stride)) in [(16usize, 1usize), (32, 2), (64, 2)].iter().enumerate() {
        let (s, p, f) = conv2d(shape, *c, 3, *stride, true);
        layers.push(LayerProfile {
            name: format!("conv{}", i + 1),
            kind: LayerKind::Conv,
            out_shape: s,
            act_elems: 3 * (s.0 * s.1 * s.2) as u64,
            params: p,
            flops_per_image: f,
        });
        shape = s;
    }
    head(shape, classes, &mut layers);
    ArchProfile { name: "tiny_cnn".into(), input, layers }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn resnet18_structure() {
        let p = resnet("resnet18", (224, 224, 3), 1000, [2, 2, 2, 2], false);
        // conv1 + pool + 8 blocks + avgpool + fc
        assert_eq!(p.depth(), 2 + 8 + 2);
        assert_eq!(p.layers[2].out_shape, (56, 56, 64));
        assert_eq!(p.layers[9].out_shape, (7, 7, 512));
    }

    #[test]
    fn resnet50_expansion() {
        let p = resnet("resnet50", (224, 224, 3), 1000, [3, 4, 6, 3], true);
        assert_eq!(p.depth(), 2 + 16 + 2);
        // last stage output has 2048 channels
        let last_block = &p.layers[p.depth() - 3];
        assert_eq!(last_block.out_shape, (7, 7, 2048));
    }

    #[test]
    fn stride_only_first_block_of_stage() {
        let p = resnet("resnet18", (224, 224, 3), 1000, [2, 2, 2, 2], false);
        // stage 2 blocks: first halves resolution, second keeps it
        assert_eq!(p.layers[4].out_shape.0, 28);
        assert_eq!(p.layers[5].out_shape.0, 28);
    }

    #[test]
    fn mini_keeps_resolution_at_stem() {
        let p = resnet_mini("resnet_mini18", (32, 32, 3), 10, [2, 2, 2, 2], false, 16);
        assert_eq!(p.layers[0].out_shape, (32, 32, 16));
        let last_block = &p.layers[p.depth() - 3];
        assert_eq!(last_block.out_shape, (4, 4, 128));
    }

    #[test]
    fn tiny_cnn_small() {
        let p = tiny_cnn((32, 32, 3), 10);
        assert!(p.param_count() < 50_000, "{}", p.param_count());
        assert_eq!(p.layers.last().unwrap().out_shape, (1, 1, 10));
    }

    #[test]
    fn projection_only_when_needed() {
        // stage-1 non-first blocks have no projection: params are exactly
        // 2 convs + 2 bns
        let p = resnet("resnet18", (224, 224, 3), 1000, [2, 2, 2, 2], false);
        let blk = &p.layers[3]; // layer1.1
        assert_eq!(blk.params, (64 * 64 * 9 + 128) as u64 * 2);
    }
}
