//! [`ObsServer`]: a dependency-free HTTP/1.1 listener for metrics and
//! health probes.
//!
//! One `std::net::TcpListener` on one background thread, serving:
//!
//! | path       | response                                                |
//! |------------|---------------------------------------------------------|
//! | `/metrics` | Prometheus text exposition 0.0.4 from the shared hub    |
//! | `/healthz` | `200 ok` while the process is up (liveness)             |
//! | `/readyz`  | `200 ready`, or `503 degraded` while the degradation    |
//! |            | ladder is active or the loader watchdog has fired       |
//!
//! The listener is non-blocking so shutdown is prompt: `Drop` raises a
//! flag and joins the thread (the accept loop polls it every few
//! milliseconds). Requests are parsed down to the request line only —
//! scrapers send no meaningful headers and we close after every
//! response (`Connection: close`).

use std::io::{self, ErrorKind, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::{self, JoinHandle};
use std::time::Duration;

use super::MetricsHub;

/// How often the accept loop checks the shutdown flag.
const ACCEPT_POLL: Duration = Duration::from_millis(5);

/// Per-connection read/write deadline — a stuck scraper must not wedge
/// the (single-threaded) serve loop.
const IO_TIMEOUT: Duration = Duration::from_millis(500);

/// Largest request head we bother reading before answering.
const MAX_REQUEST_BYTES: usize = 4096;

/// The metrics/health endpoint. Binding starts the serve thread;
/// dropping the server stops it and joins the thread.
pub struct ObsServer {
    addr: SocketAddr,
    shutdown: Arc<AtomicBool>,
    handle: Option<JoinHandle<()>>,
}

impl ObsServer {
    /// Bind `addr` (e.g. `127.0.0.1:9184`; port 0 picks a free one) and
    /// serve `hub` until the returned server is dropped.
    pub fn bind(addr: &str, hub: Arc<MetricsHub>) -> io::Result<ObsServer> {
        let listener = TcpListener::bind(addr)?;
        listener.set_nonblocking(true)?;
        let local = listener.local_addr()?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let flag = Arc::clone(&shutdown);
        let handle = thread::Builder::new()
            .name("obs-http".to_string())
            .spawn(move || serve(listener, hub, flag))?;
        Ok(ObsServer { addr: local, shutdown, handle: Some(handle) })
    }

    /// The bound address (resolves the actual port when bound to `:0`).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }
}

impl Drop for ObsServer {
    fn drop(&mut self) {
        self.shutdown.store(true, Ordering::Relaxed);
        if let Some(handle) = self.handle.take() {
            let _ = handle.join();
        }
    }
}

fn serve(listener: TcpListener, hub: Arc<MetricsHub>, shutdown: Arc<AtomicBool>) {
    while !shutdown.load(Ordering::Relaxed) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Serve inline: a scrape is a handful of microseconds of
                // string formatting, and probes arrive one at a time.
                let _ = handle_conn(stream, &hub);
            }
            Err(e) if e.kind() == ErrorKind::WouldBlock => thread::sleep(ACCEPT_POLL),
            // Transient accept errors (ECONNABORTED, EMFILE, …): back off
            // briefly and keep listening rather than killing the endpoint.
            Err(_) => thread::sleep(ACCEPT_POLL),
        }
    }
}

fn handle_conn(mut stream: TcpStream, hub: &MetricsHub) -> io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(IO_TIMEOUT))?;
    stream.set_write_timeout(Some(IO_TIMEOUT))?;
    let path = match read_request_path(&mut stream)? {
        Some(p) => p,
        None => return Ok(()),
    };
    let (status, content_type, body) = route(&path, hub);
    let response = format!(
        "HTTP/1.1 {status}\r\nContent-Type: {content_type}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n{body}",
        body.len(),
    );
    stream.write_all(response.as_bytes())
}

/// Read up to the end of the request head and return the request-line
/// path, or `None` on anything that is not a parseable `GET`-style line.
fn read_request_path(stream: &mut TcpStream) -> io::Result<Option<String>> {
    let mut buf = [0u8; 512];
    let mut head: Vec<u8> = Vec::with_capacity(512);
    loop {
        let n = match stream.read(&mut buf) {
            Ok(0) => break,
            Ok(n) => n,
            Err(e) if e.kind() == ErrorKind::WouldBlock || e.kind() == ErrorKind::TimedOut => break,
            Err(e) => return Err(e),
        };
        head.extend_from_slice(&buf[..n]);
        if head.windows(4).any(|w| w == b"\r\n\r\n") || head.len() >= MAX_REQUEST_BYTES {
            break;
        }
    }
    let text = String::from_utf8_lossy(&head);
    let line = match text.lines().next() {
        Some(l) => l,
        None => return Ok(None),
    };
    let mut parts = line.split_whitespace();
    let (method, path) = match (parts.next(), parts.next()) {
        (Some(m), Some(p)) => (m, p),
        _ => return Ok(None),
    };
    // HEAD is answered like GET (body included — fine for probes).
    if method != "GET" && method != "HEAD" {
        return Ok(Some(format!("!{method}")));
    }
    Ok(Some(path.to_string()))
}

fn route(path: &str, hub: &MetricsHub) -> (&'static str, &'static str, String) {
    const TEXT: &str = "text/plain; charset=utf-8";
    const PROM: &str = "text/plain; version=0.0.4; charset=utf-8";
    if path.starts_with('!') {
        return ("405 Method Not Allowed", TEXT, "method not allowed\n".to_string());
    }
    // Strip any query string: probes sometimes append cache-busters.
    let path = path.split('?').next().unwrap_or(path);
    match path {
        "/metrics" => ("200 OK", PROM, hub.prometheus_text()),
        "/healthz" => ("200 OK", TEXT, "ok\n".to_string()),
        "/readyz" => {
            if hub.is_ready() {
                ("200 OK", TEXT, "ready\n".to_string())
            } else {
                ("503 Service Unavailable", TEXT, "degraded\n".to_string())
            }
        }
        _ => ("404 Not Found", TEXT, "not found\n".to_string()),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn hub() -> Arc<MetricsHub> {
        Arc::new(MetricsHub::new())
    }

    fn get(addr: SocketAddr, path: &str) -> String {
        let mut stream = TcpStream::connect(addr).expect("connect");
        stream
            .write_all(format!("GET {path} HTTP/1.1\r\nHost: x\r\n\r\n").as_bytes())
            .expect("write");
        let mut out = String::new();
        stream.read_to_string(&mut out).expect("read");
        out
    }

    #[test]
    fn serves_metrics_health_and_404() {
        let h = hub();
        h.record_step(crate::obs::StepSample { step: 1, ..Default::default() });
        let server = ObsServer::bind("127.0.0.1:0", Arc::clone(&h)).expect("bind");
        let addr = server.local_addr();

        let metrics = get(addr, "/metrics");
        assert!(metrics.starts_with("HTTP/1.1 200 OK\r\n"), "{metrics}");
        assert!(metrics.contains("version=0.0.4"), "{metrics}");
        assert!(metrics.contains("optorch_steps_total 1"), "{metrics}");

        let health = get(addr, "/healthz");
        assert!(health.starts_with("HTTP/1.1 200 OK\r\n"), "{health}");
        assert!(health.ends_with("ok\n"), "{health}");

        let missing = get(addr, "/nope");
        assert!(missing.starts_with("HTTP/1.1 404 Not Found\r\n"), "{missing}");
    }

    #[test]
    fn readyz_flips_to_503_while_degraded() {
        let h = hub();
        let server = ObsServer::bind("127.0.0.1:0", Arc::clone(&h)).expect("bind");
        let addr = server.local_addr();
        assert!(get(addr, "/readyz").starts_with("HTTP/1.1 200 OK\r\n"));
        h.note_degrade_event(2);
        assert!(get(addr, "/readyz").starts_with("HTTP/1.1 503 Service Unavailable\r\n"));
        h.set_degraded(false);
        assert!(get(addr, "/readyz").starts_with("HTTP/1.1 200 OK\r\n"));
    }

    #[test]
    fn rejects_non_get_methods() {
        let server = ObsServer::bind("127.0.0.1:0", hub()).expect("bind");
        let mut stream = TcpStream::connect(server.local_addr()).expect("connect");
        stream.write_all(b"POST /metrics HTTP/1.1\r\nHost: x\r\n\r\n").expect("write");
        let mut out = String::new();
        stream.read_to_string(&mut out).expect("read");
        assert!(out.starts_with("HTTP/1.1 405 "), "{out}");
    }

    #[test]
    fn drop_stops_the_listener() {
        let h = hub();
        let addr = {
            let server = ObsServer::bind("127.0.0.1:0", Arc::clone(&h)).expect("bind");
            server.local_addr()
        };
        // Dropped: new connections must be refused (give the OS a beat).
        thread::sleep(Duration::from_millis(20));
        assert!(TcpStream::connect(addr).is_err(), "listener still accepting after drop");
    }
}
